#!/usr/bin/env bash
# Tier-1 gate + benchmark wiring check.
#
#   scripts/check.sh            # full tier-1 tests + benchmark smokes
#   scripts/check.sh -m 'not slow'   # extra pytest args pass through
#
# The smoke runs use tiny op counts: they validate that the sharded,
# fused-fast-path, and transaction benchmarks still run end-to-end
# (fig_scaling stays monotonic; fig_fastpath keeps its bit-exact parity
# assertion and its 1-dispatch-per-batch invariant; fig_txn keeps its
# crash-atomicity, 1-dispatch transactional-probe, and single-shard
# fast-path assertions), not the measured numbers.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m benchmarks.fig_scaling --smoke
python -m benchmarks.fig_fastpath --smoke
python -m benchmarks.fig_txn --smoke
echo "check.sh: all green"
