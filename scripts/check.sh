#!/usr/bin/env bash
# Tier-1 gate + benchmark wiring check.
#
#   scripts/check.sh            # full tier-1 tests + benchmark smokes
#   scripts/check.sh -m 'not slow'   # extra pytest args pass through
#
# The smoke runs use tiny op counts: they validate that the sharded,
# fused-fast-path, transaction, and live-migration benchmarks still run
# end-to-end (fig_scaling stays monotonic; fig_fastpath keeps its bit-exact
# parity assertions — set-parallel kernel vs oracle AND device witness vs
# Python witness on the dup/stale-gc/multi-key failure paths — plus its
# 1-dispatch-per-kernel-batch and 1-dispatch-per-cluster-batch invariants
# (single- and cross-shard, device backend); fig_txn keeps its
# crash-atomicity, 1-dispatch transactional-probe, single-shard fast-path,
# fan-out-beats-sequential, and wound/wait-cuts-aborts assertions;
# fig_migration keeps its zero-lost-writes, strict-linearizability,
# untouched-slot fast-ratio, slot-route parity, and rebalance-beats-static
# assertions; fig_crdt keeps the merge-lattice separation — hot-counter
# INCR fast-frac >=0.95 vs plain SET <=0.2 at skew 1.0 — the 16x16
# matrix/scalar and record-kernel/oracle bit-exact parity checks, and the
# merge-aware strict-linearizability assertion on every scenario; fig_slo
# keeps its armor assertions — bounded admission queue, >=5x goodput over
# the naked 2x-overload baseline, AIMD adaptive bound not regressing the
# static one, heartbeat-detected failover with zero lost acked writes, and
# strict-checked migration/crash storm companions; fig_obs keeps the flight
# recorder honest — every storm exports a Perfetto-loadable trace with zero
# leaked spans and resolvable parents, and registry/sampled-tracing
# overhead on the device fast path stays bounded; fig_watchdog proves the
# protocol watchdog non-vacuous — every ChaosConfig switch trips exactly
# its monitor within a bounded event count, clean overload/crash/migration
# storms trip nothing, breach replay is bit-identical, the windowed
# linearizability checker agrees with the strict one, and watched goodput
# stays >=95% of unwatched on the overload ramp), not the measured
# numbers.  bench_gate then reads the recorded BENCH_curp.json deltas:
# soft perf regressions (>10%) report without failing, hard ones (>20%)
# fail the run.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m benchmarks.fig_scaling --smoke
python -m benchmarks.fig_fastpath --smoke
python -m benchmarks.fig_txn --smoke
python -m benchmarks.fig_migration --smoke
python -m benchmarks.fig_crdt --smoke
python -m benchmarks.fig_slo --smoke
python -m benchmarks.fig_obs --smoke
python -m benchmarks.fig_watchdog --smoke

# Perf-regression gate over recorded BENCH_curp.json deltas: report-only
# for soft moves, blocking for >20% regressions (--ci).
python scripts/bench_gate.py --ci

# Observability discipline: production layers report through the metrics
# registry / tracer, never bare print() (benchmarks and scripts may print).
if grep -rnE '^[[:space:]]*print\(' src/repro/core src/repro/sim; then
    echo "check.sh: bare print() in src/repro/{core,sim} — use telemetry" >&2
    exit 1
fi
echo "check.sh: all green"
