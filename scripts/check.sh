#!/usr/bin/env bash
# Tier-1 gate + benchmark wiring check.
#
#   scripts/check.sh            # full tier-1 tests + fig_scaling smoke
#   scripts/check.sh -m 'not slow'   # extra pytest args pass through
#
# The fig_scaling smoke run uses tiny op counts: it validates that the
# sharded benchmark still runs end-to-end (and stays monotonic), not the
# measured numbers.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m benchmarks.fig_scaling --smoke
echo "check.sh: all green"
