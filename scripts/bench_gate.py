#!/usr/bin/env python3
"""Perf-regression gate over BENCH_curp.json.

``benchmarks/run.py`` records, for every metric that moved since the last
run, a ``deltas`` entry ``{figure: {metric: {"prev": x, "now": y}}}``.
This script turns those recorded moves into an exit code:

  * each metric's DIRECTION is inferred from its name (``*_us``/``*_s``/
    ``detect_events``/``aborts`` are lower-is-better; ``*kops``/``*ratio``/
    ``*fraction``/``goodput*`` are higher-is-better; anything unrecognized
    is report-only — a rename can't silently become a gate);
  * a move in the bad direction beyond ``--tolerance`` (default 10%) is a
    REGRESSION -> exit 1;
  * beyond ``--hard`` (default 20%) it is a HARD regression -> exit 2.

CI runs ``--ci``: soft regressions are printed but do not fail the job
(benchmark boxes are noisy); hard regressions (>20%) still exit non-zero.

Exit codes: 0 clean / improvements only, 1 soft regressions, 2 hard.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_JSON = ROOT / "BENCH_curp.json"

# name-fragment -> direction ("up" = higher is better, "down" = lower).
# Checked in order; first hit wins.  Per-metric overrides go first.
_DIRECTION_RULES = [
    ("wall_overhead", "down"),
    ("detect_events", "down"),
    ("us_per_call", "down"),
    ("abort", "down"),
    ("_us", "down"),
    ("_ms", "down"),
    ("wall_s", "down"),
    ("dropped", "down"),
    ("sheds", "down"),
    ("kops", "up"),
    ("ops_per_sec", "up"),
    ("goodput", "up"),
    ("throughput", "up"),
    ("ratio", "up"),
    ("fraction", "up"),
    ("frac", "up"),
    ("speedup", "up"),
    ("ops_checked", "up"),
]


def direction(metric: str) -> str | None:
    m = metric.lower()
    for frag, d in _DIRECTION_RULES:
        if frag in m:
            return d
    return None


def classify(prev: float, now: float, metric: str,
             tolerance: float, hard: float):
    """-> (kind, rel) where kind is 'hard' | 'soft' | 'improved' | 'info'
    and rel is the relative move in the bad direction (>= 0)."""
    d = direction(metric)
    if d is None or not isinstance(prev, (int, float)) \
            or not isinstance(now, (int, float)) or prev == 0:
        return "info", 0.0
    rel = (now - prev) / abs(prev)
    bad = -rel if d == "up" else rel
    if bad <= 0:
        return "improved", bad
    if bad > hard:
        return "hard", bad
    if bad > tolerance:
        return "soft", bad
    return "ok", bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", type=pathlib.Path, default=BENCH_JSON,
                    help="BENCH_curp.json path")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="soft regression threshold (relative; default 0.10)")
    ap.add_argument("--hard", type=float, default=0.20,
                    help="hard (always-blocking) threshold (default 0.20)")
    ap.add_argument("--ci", action="store_true",
                    help="CI mode: soft regressions report but do not fail; "
                         "hard regressions still exit non-zero")
    args = ap.parse_args(argv)

    if not args.json.exists():
        print(f"bench_gate: {args.json} missing — run benchmarks first")
        return 0
    try:
        doc = json.loads(args.json.read_text())
    except json.JSONDecodeError as e:
        print(f"bench_gate: {args.json} unreadable: {e}")
        return 2
    deltas = doc.get("deltas", {})
    if not deltas:
        print("bench_gate: no recorded metric moves — nothing to gate")
        return 0

    rows = []
    worst = {"hard": 0, "soft": 0, "improved": 0, "info": 0, "ok": 0}
    for fig in sorted(deltas):
        for metric in sorted(deltas[fig]):
            mv = deltas[fig][metric]
            kind, bad = classify(mv.get("prev"), mv.get("now"), metric,
                                 args.tolerance, args.hard)
            worst[kind] += 1
            if kind != "ok":
                rows.append((kind, fig, metric, mv.get("prev"),
                             mv.get("now"), bad))

    if rows:
        print(f"{'verdict':9s} {'figure':24s} {'metric':32s} "
              f"{'prev':>12s} {'now':>12s} {'move':>8s}")
        for kind, fig, metric, prev, now, bad in sorted(
                rows, key=lambda r: -r[5]):
            tag = {"hard": "HARD-REG", "soft": "regress",
                   "improved": "improved", "info": "info"}[kind]
            mv = f"{bad * 100:+.1f}%" if kind != "info" else "?"
            print(f"{tag:9s} {fig:24s} {metric:32s} "
                  f"{prev!s:>12s} {now!s:>12s} {mv:>8s}")
    print(f"bench_gate: {worst['hard']} hard, {worst['soft']} soft, "
          f"{worst['improved']} improved, {worst['ok']} within tolerance, "
          f"{worst['info']} report-only "
          f"(tolerance {args.tolerance:.0%}, hard {args.hard:.0%})")

    if worst["hard"]:
        return 2
    if worst["soft"] and not args.ci:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
