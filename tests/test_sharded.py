"""Sharded CURP tests: KeyRouter placement, the single-master protocol
matrix run against every shard of a ShardedCluster, cross-shard multi-key
ops, per-shard crash recovery, and the sharded serving store."""
import pytest

from repro.core import (
    ClientSession,
    KeyRouter,
    Op,
    OpType,
    RecordStatus,
    ShardedCluster,
    keyhash,
)
from repro.core.client import Decision, decide_multi
from repro.core.types import ExecResult
from repro.sim import check_linearizable

N_SHARDS = 4


def key_on_shard(router: KeyRouter, shard: int, tag: str = "k") -> str:
    """Deterministically find a key the router places on ``shard``."""
    for i in range(10_000):
        k = f"{tag}{i}"
        if router.shard_of(k) == shard:
            return k
    raise AssertionError(f"no key found for shard {shard}")


def keys_on_shard(router: KeyRouter, shard: int, n: int, tag: str = "k"):
    out = []
    i = 0
    while len(out) < n:
        k = f"{tag}{i}"
        if router.shard_of(k) == shard:
            out.append(k)
        i += 1
    return out


# ---------------------------------------------------------------- router
class TestKeyRouter:
    def test_deterministic_and_in_range(self):
        r = KeyRouter(N_SHARDS)
        for k in ["a", "b", 17, "user123", b"bytes"]:
            s = r.shard_of(k)
            assert 0 <= s < N_SHARDS
            assert r.shard_of(k) == s

    def test_single_shard_degenerates(self):
        r = KeyRouter(1)
        assert all(r.shard_of(f"k{i}") == 0 for i in range(50))

    def test_covers_all_shards_roughly_evenly(self):
        r = KeyRouter(N_SHARDS)
        counts = [0] * N_SHARDS
        n = 2000
        for i in range(n):
            counts[r.shard_of(f"user{i}")] += 1
        assert min(counts) > n // (N_SHARDS * 3)

    def test_split_keys_partitions(self):
        r = KeyRouter(N_SHARDS)
        keys = [f"x{i}" for i in range(32)]
        parts = r.split_keys(keys)
        seen = sorted(i for idxs in parts.values() for i in idxs)
        assert seen == list(range(32))
        for shard, idxs in parts.items():
            assert all(r.shard_of(keys[i]) == shard for i in idxs)


# ------------------------------------------- per-shard protocol matrix
@pytest.fixture(params=list(range(N_SHARDS)))
def shard(request):
    return request.param


class TestPerShardProtocolMatrix:
    """The LocalCluster protocol tests, replayed against each shard of a
    4-shard cluster via keys pinned to that shard."""

    def test_fast_path_1rtt(self, shard):
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        cl = c.new_client()
        k = key_on_shard(c.router, shard)
        out = c.update(cl, cl.op_set(k, 1))
        assert out.fast_path and out.rtts == 1 and out.witness_accepts == 3

    def test_conflict_2rtt_synced_tag(self, shard):
        c = ShardedCluster(n_shards=N_SHARDS, f=3, sync_batch=50)
        cl = c.new_client()
        k = key_on_shard(c.router, shard)
        c.update(cl, cl.op_set(k, 1))
        out = c.update(cl, cl.op_set(k, 2))
        assert out.synced_path and out.rtts == 2

    def test_read_blocked_by_unsynced_write(self, shard):
        c = ShardedCluster(n_shards=N_SHARDS, f=3, sync_batch=50)
        cl = c.new_client()
        k = key_on_shard(c.router, shard)
        c.update(cl, cl.op_set(k, 1))
        out = c.read(cl, cl.op_get(k))
        assert out.value == 1 and out.rtts == 2   # §3.2.3: sync before read

    def test_witness_drop_slow_path(self, shard):
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        c.shards[shard].witness_drop(1)
        cl = c.new_client()
        k = key_on_shard(c.router, shard)
        out = c.update(cl, cl.op_set(k, 1))
        assert not out.fast_path and out.rtts >= 2
        m = c.shards[shard].master
        assert m.synced_index == len(m.log)
        # other shards are unaffected by the dropped witness
        other = (shard + 1) % N_SHARDS
        out2 = c.update(cl, cl.op_set(key_on_shard(c.router, other), 1))
        assert out2.fast_path

    def test_recovery_preserves_completed(self, shard):
        c = ShardedCluster(n_shards=N_SHARDS, f=3, sync_batch=50)
        cl = c.new_client()
        ks = keys_on_shard(c.router, shard, 12)
        for i, k in enumerate(ks):
            c.update(cl, cl.op_set(k, i))
        rep = c.crash_master(shard)
        assert rep.shard_id == shard and rep.replayed >= 0
        for i, k in enumerate(ks):
            assert c.read(cl, cl.op_get(k)).value == i

    def test_witness_reconfiguration_version_fence(self, shard):
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        cl = c.new_client()
        old_version = c.config.fetch(shard).witness_list_version
        c.shards[shard].replace_witness(0)
        k = key_on_shard(c.router, shard)
        op = cl.op_set(k, 1)
        verdict, res = c.shards[shard].master.handle_update(
            op, old_version, (), 0.0
        )
        assert verdict == "error" and res.error == "WRONG_WITNESS_VERSION"
        out = c.update(cl, cl.op_set(k, 1))
        assert out.value == "OK"


# ---------------------------------------------------------- cross-shard mset
class TestCrossShardMset:
    def test_split_spans_shards_with_globally_unique_rpc_ids(self):
        """Sub-ops split per shard, each under a GLOBALLY-unique rpc_id from
        the client's single shared RIFL space.  (Pre-migration the client
        kept one sequence space per shard, so the same (client_id, seq)
        named different ops on different shards — fatally ambiguous once a
        completion record can MIGRATE to another shard with its key's slot;
        see ShardedClientSession.)"""
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        cl = c.new_client()
        kvs = [(key_on_shard(c.router, s, tag=f"m{s}_"), s)
               for s in range(N_SHARDS)]
        parts = cl.mset_parts(kvs)
        assert sorted(parts) == list(range(N_SHARDS))
        for shard_id, sub in parts.items():
            assert sub.op_type is OpType.MSET
            assert all(c.router.shard_of(k) == shard_id for k in sub.keys)
        ids = [sub.rpc_id for sub in parts.values()]
        assert len(set(ids)) == len(ids)            # no id shared by shards
        assert all(rpc[0] == cl.client_id for rpc in ids)
        parts2 = cl.mset_parts(kvs)
        ids2 = [sub.rpc_id for sub in parts2.values()]
        assert not set(ids) & set(ids2)             # fresh attempt, fresh ids

    def test_fast_path_when_all_shards_accept(self):
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        cl = c.new_client()
        kvs = [(key_on_shard(c.router, s), s * 10) for s in range(N_SHARDS)]
        out = c.mset(cl, kvs)
        assert out.fast_path and out.rtts == 1
        assert out.witness_accepts == 3 * N_SHARDS
        for k, v in kvs:
            assert c.read(cl, cl.op_get(k)).value == v

    def test_sync_fallback_on_one_conflicting_shard(self):
        """A conflict on ONE shard demotes the whole op to 2 RTTs, but the
        other shards still completed via their own witnesses."""
        c = ShardedCluster(n_shards=N_SHARDS, f=3, sync_batch=50)
        cl = c.new_client()
        hot = key_on_shard(c.router, 0)
        c.update(cl, cl.op_set(hot, "warm"))        # leaves shard 0 unsynced
        kvs = [(hot, "clash")] + [
            (key_on_shard(c.router, s), s) for s in range(1, N_SHARDS)
        ]
        out = c.mset(cl, kvs)
        assert not out.fast_path and out.rtts == 2 and out.synced_path
        for k, v in kvs:
            assert c.read(cl, cl.op_get(k)).value == v
        # the conflict synced only shard 0; others still have no conflicts
        assert c.shards[0].master.stats["conflict_syncs"] == 1
        for s in range(1, N_SHARDS):
            assert c.shards[s].master.stats["conflict_syncs"] == 0

    def test_witness_drop_on_one_shard_demotes_only_that_shard(self):
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        c.shards[2].witness_drop(0)
        cl = c.new_client()
        kvs = [(key_on_shard(c.router, s), s) for s in range(N_SHARDS)]
        out = c.mset(cl, kvs)
        assert not out.fast_path and out.rtts == 2
        # shard 2's sub-op is durable via backup sync despite the drop
        m = c.shards[2].master
        assert m.synced_index == len(m.log)

    def test_mset_history_linearizable(self):
        """Cross-shard msets + reads + single-key writes: the recorded local
        history passes the sim linearizability checker."""
        c = ShardedCluster(n_shards=N_SHARDS, f=3, sync_batch=4)
        cl = c.new_client()
        import random

        rng = random.Random(7)
        keys = [f"k{i}" for i in range(12)]
        for step in range(60):
            roll = rng.random()
            if roll < 0.3:
                picked = rng.sample(keys, rng.randrange(2, 5))
                c.mset(cl, [(k, f"v{step}_{k}") for k in picked])
            elif roll < 0.6:
                k = rng.choice(keys)
                c.update(cl, cl.op_set(k, f"v{step}"))
            else:
                c.read(cl, cl.op_get(rng.choice(keys)))
        ok, key = check_linearizable(c.history)
        assert ok, f"violation on {key}"

    def test_mset_crash_retry_reuses_rpc_ids_no_double_apply(self):
        """Satellite regression: a client retrying an mset after a partial
        failure must reuse the original per-shard rpc_ids.  The already-
        applied leg RIFL-dedups (no double-apply, no new log entry); only
        the never-delivered legs execute."""
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        cl = c.new_client()
        kvs = [(key_on_shard(c.router, s, tag=f"r{s}_"), f"v{s}")
               for s in range(N_SHARDS)]
        parts = cl.mset_parts(kvs)
        # The client "crashes" after delivering only shard 0's leg.
        first_shard = min(parts)
        sub = cl.session_for(first_shard)
        c.shards[first_shard].update(sub, parts[first_shard])
        log_len = {s: len(c.shards[s].master.log) for s in range(N_SHARDS)}
        dups0 = c.shards[first_shard].master.stats["dups"]

        # Retry the WHOLE mset with the original parts: shard 0 dedups.
        out = c.mset(cl, kvs, parts=parts)
        assert out.value == "OK"
        assert c.shards[first_shard].master.stats["dups"] == dups0 + 1
        assert len(c.shards[first_shard].master.log) == log_len[first_shard]
        for s in range(N_SHARDS):
            if s != first_shard:
                assert len(c.shards[s].master.log) == log_len[s] + 1
        for k, v in kvs:
            assert c.read(cl, cl.op_get(k)).value == v
        # A second full retry double-applies NOTHING anywhere.
        lens = {s: len(c.shards[s].master.log) for s in range(N_SHARDS)}
        c.mset(cl, kvs, parts=parts)
        assert {s: len(c.shards[s].master.log)
                for s in range(N_SHARDS)} == lens

    def test_mset_parts_without_prev_allocates_fresh_ids(self):
        """Without ``prev`` each call is a NEW mset (fresh rpc_ids) — the
        pre-fix behavior, still correct for non-retry use."""
        c = ShardedCluster(n_shards=2, f=3)
        cl = c.new_client()
        kvs = [(key_on_shard(c.router, s), s) for s in range(2)]
        p1 = cl.mset_parts(kvs)
        p2 = cl.mset_parts(kvs)
        assert all(p1[s].rpc_id != p2[s].rpc_id for s in p1)
        p3 = cl.mset_parts(kvs, prev=p1)
        assert all(p3[s].rpc_id == p1[s].rpc_id for s in p1)

    def test_decide_multi_rules(self):
        acc = [RecordStatus.ACCEPTED] * 3
        rej = [RecordStatus.ACCEPTED, RecordStatus.REJECTED,
               RecordStatus.ACCEPTED]
        fast = ExecResult("OK", synced=False)
        synced = ExecResult("OK", synced=True)
        bad = ExecResult(None, synced=False, ok=False, error="NOT_OWNER")
        assert decide_multi([(fast, acc), (fast, acc)]) is Decision.COMPLETE
        assert decide_multi([(fast, acc), (synced, rej)]) is Decision.COMPLETE
        assert decide_multi([(fast, acc), (fast, rej)]) is Decision.NEED_SYNC
        assert decide_multi([(fast, rej), (bad, acc)]) is Decision.REFETCH_CONFIG


# ------------------------------------------------------- per-shard recovery
class TestShardedRecovery:
    def test_crash_one_shard_replays_only_that_shard(self):
        c = ShardedCluster(n_shards=N_SHARDS, f=3, sync_batch=1000,
                           auto_sync=False)
        cl = c.new_client()
        per_shard_keys = {s: keys_on_shard(c.router, s, 5)
                          for s in range(N_SHARDS)}
        for s, ks in per_shard_keys.items():
            for i, k in enumerate(ks):
                c.update(cl, cl.op_set(k, (s, i)))
        # every shard has a full unsynced window and loaded witnesses
        unsynced_before = {s: c.shards[s].master.unsynced_count
                           for s in range(N_SHARDS)}
        occ_before = {s: c.shards[s].witnesses[0].occupancy
                      for s in range(N_SHARDS)}
        assert all(v == 5 for v in unsynced_before.values())

        victim = 1
        rep = c.crash_master(victim)
        # the victim replayed its 5 unsynced ops from ONE of its witnesses
        assert rep.shard_id == victim
        assert rep.replayed == 5 and rep.witness_requests == 5
        assert rep.new_epoch == 1
        # other shards: unsynced windows and witness contents untouched
        for s in range(N_SHARDS):
            if s == victim:
                continue
            assert c.shards[s].master.unsynced_count == unsynced_before[s]
            assert c.shards[s].witnesses[0].occupancy == occ_before[s]
            assert c.config.epoch(s) == 0
        assert c.config.epoch(victim) == 1
        # nothing lost anywhere
        for s, ks in per_shard_keys.items():
            for i, k in enumerate(ks):
                assert c.read(cl, cl.op_get(k)).value == (s, i)

    def test_per_shard_epochs_fence_only_victim_zombie(self):
        c = ShardedCluster(n_shards=2, f=3, sync_batch=1000, auto_sync=False)
        cl = c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        c.update(cl, cl.op_set(k0, 1))
        c.update(cl, cl.op_set(k1, 1))
        zombie = c.shards[0].master
        c.crash_master(0)
        # zombie of shard 0 is fenced at shard 0's backups
        zombie.want_sync = True
        req = zombie.begin_sync()
        assert req is not None
        assert not c.shards[0].backups[0].handle_sync(req).ok
        # shard 1's original master is NOT fenced (its epoch never moved)
        c.shards[1].sync_now()
        m1 = c.shards[1].master
        assert m1.synced_index == len(m1.log)

    def test_repeated_crashes_accumulate_epochs_independently(self):
        c = ShardedCluster(n_shards=3, f=3)
        cl = c.new_client()
        for s in (0, 0, 2):
            c.update(cl, cl.op_set(key_on_shard(c.router, s), s))
            c.crash_master(s)
        assert c.epochs() == {0: 2, 1: 0, 2: 1}


# ------------------------------------------------------------ sharded serving
class TestShardedSessionStore:
    def test_sessions_spread_and_survive_full_crash(self):
        from repro.serving.kvstore import CurpSessionStore, SessionState

        store = CurpSessionStore(f=3, sync_batch=8, n_shards=4)
        for i in range(16):
            store.commit(SessionState(f"s{i}", [1, 2, i]))
        shards_used = {store.shard_of(f"s{i}") for i in range(16)}
        assert len(shards_used) >= 3
        rep = store.crash_and_recover()
        assert len(rep.per_shard) == 4
        for i in range(16):
            st = store.load(f"s{i}")
            assert st is not None and st.tokens == [1, 2, i]

    def test_one_shard_crash_keeps_other_sessions_unsynced(self):
        from repro.serving.kvstore import CurpSessionStore, SessionState

        store = CurpSessionStore(f=3, sync_batch=1000, n_shards=2)
        # hot_key_window syncs repeats; first commits of distinct sessions
        # stay unsynced until the batch fills
        sids = [f"s{i}" for i in range(8)]
        for sid in sids:
            store.commit(SessionState(sid, [1]))
        by_shard = {0: [], 1: []}
        for sid in sids:
            by_shard[store.shard_of(sid)].append(sid)
        assert by_shard[0] and by_shard[1]
        other = store.cluster.shards[1].master.unsynced_count
        rep = store.crash_shard(0)
        assert rep.shard_id == 0
        assert store.cluster.shards[1].master.unsynced_count == other
        for sid in sids:
            assert store.load(sid) is not None
