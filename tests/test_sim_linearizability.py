"""Simulator-level tests: calibration bands + linearizability under crashes
(property-based over seeds/workloads with hypothesis — optional via the _hyp
shim; non-property tests always run)."""
import statistics

import pytest

from _hyp import HealthCheck, given, settings, st

from repro.core.client import ClientSession
from repro.core.types import Op, OpType
from repro.sim import (
    ShardSkewedWorkload,
    SimParams,
    UniformWriteWorkload,
    YcsbWorkload,
    check_linearizable,
    check_linearizable_strict,
    run_scenario,
    run_sharded_scenario,
)


def median(xs):
    return statistics.median(xs)


class TestCalibration:
    """The paper's headline numbers as bands (DESIGN.md §5)."""

    def test_latency_1rtt_vs_2rtt(self):
        unrep = run_scenario(mode="unreplicated", f=0, n_clients=1, n_ops=1500,
                             op_factory=UniformWriteWorkload(seed=1), seed=42)
        curp = run_scenario(mode="curp", f=3, n_clients=1, n_ops=1500,
                            op_factory=UniformWriteWorkload(seed=1), seed=42)
        sync = run_scenario(mode="sync", f=3, n_clients=1, n_ops=1500,
                            op_factory=UniformWriteWorkload(seed=1), seed=42)
        mu, mc, ms = (median(r.update_latencies) for r in (unrep, curp, sync))
        # paper: 6.9 / 7.3 / 13.8 us
        assert abs(mu - 6.9) < 0.5
        assert abs(mc - 7.3) < 0.5
        assert 1.7 < ms / mc < 2.3          # ~2x improvement
        assert mc - mu < 1.0                # ~0.4us overhead vs unreplicated

    def test_throughput_4x(self):
        res = {}
        for mode, f in [("curp", 3), ("sync", 3), ("async", 3),
                        ("unreplicated", 0)]:
            r = run_scenario(mode=mode, f=f, n_clients=24, n_ops=1500,
                             op_factory=UniformWriteWorkload(seed=1), seed=7)
            res[mode] = r.throughput_ops_per_sec
        assert 3.0 < res["curp"] / res["sync"] < 5.0       # paper ~4x
        assert res["curp"] / res["async"] > 0.85           # <=15% overhead
        assert res["curp"] / res["unreplicated"] > 0.85

    def test_conflicts_complete_in_2rtt(self):
        """YCSB-A zipfian: conflicts kink at ~2 RTT, never more (§5.3)."""
        r = run_scenario(mode="curp", f=3, n_clients=1, n_ops=2000,
                         op_factory=YcsbWorkload(read_fraction=0.5,
                                                 n_items=1000, seed=3),
                         seed=5)
        lat = sorted(r.update_latencies)
        assert r.fast_fraction > 0.5
        # p999 below 3 RTT-ish (~25us): no multi-RTT spirals
        assert lat[int(0.999 * len(lat)) - 1] < 40.0


class TestCrashLinearizability:
    def test_crash_recovery_linearizable(self):
        r = run_scenario(mode="curp", f=3, n_clients=8, n_ops=300,
                         op_factory=UniformWriteWorkload(seed=3), seed=11,
                         crash_at_us=1500.0)
        assert r.recovery is not None
        ok, key = check_linearizable(r.history)
        assert ok, f"violation on {key}"

    @settings(deadline=None, max_examples=8,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 10_000),
           crash_at=st.floats(500.0, 4000.0),
           n_items=st.sampled_from([5, 50, 5000]))
    def test_property_linearizable_under_crash(self, seed, crash_at, n_items):
        """Random crash times x contention levels: completed ops are never
        lost or reordered inconsistently (paper §3.4)."""
        r = run_scenario(
            mode="curp", f=3, n_clients=6, n_ops=120,
            op_factory=UniformWriteWorkload(seed=seed, n_items=n_items),
            seed=seed, crash_at_us=crash_at,
        )
        ok, key = check_linearizable(r.history)
        assert ok, f"violation on {key} (seed={seed}, crash={crash_at})"

    @settings(deadline=None, max_examples=6,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 10_000))
    def test_property_linearizable_ycsb_mixed(self, seed):
        """Reads + writes on a hot zipfian keyspace stay linearizable."""
        r = run_scenario(
            mode="curp", f=3, n_clients=4, n_ops=100,
            op_factory=YcsbWorkload(read_fraction=0.5, n_items=20, seed=seed),
            seed=seed,
        )
        ok, key = check_linearizable(r.history)
        assert ok, f"violation on {key} (seed={seed})"

    def test_drops_and_reordering_still_linearizable(self):
        p = SimParams(drop_prob=0.02, delay_jitter_sigma=0.4, tail_prob=0.05)
        r = run_scenario(mode="curp", f=3, n_clients=4, n_ops=150,
                         params=p,
                         op_factory=UniformWriteWorkload(seed=1, n_items=30),
                         seed=13)
        ok, key = check_linearizable(r.history)
        assert ok, f"violation on {key}"


class TestShardedLinearizability:
    """Multi-master partitioning keeps per-key linearizability (§4/Fig. 3):
    shards only split the keyspace; within a key nothing changes."""

    def test_sharded_uniform_linearizable(self):
        r = run_sharded_scenario(
            n_shards=4, mode="curp", f=3, n_clients=6, n_ops=150,
            op_factory=UniformWriteWorkload(seed=5, n_items=60), seed=17,
        )
        ok, key = check_linearizable(r.history)
        assert ok, f"violation on {key}"
        # load actually spread over several masters
        active = sum(1 for s in r.per_shard_stats
                     if s["fast"] + s["conflict_syncs"] > 0)
        assert active >= 3

    def test_sharded_crash_one_shard_linearizable(self):
        """Crash one shard's master mid-run: that shard replays its own
        witnesses; every other shard is untouched; history stays clean."""
        r = run_sharded_scenario(
            n_shards=4, mode="curp", f=3, n_clients=8, n_ops=200,
            op_factory=UniformWriteWorkload(seed=3, n_items=500), seed=11,
            crash_shard_at=(1500.0, 2),
        )
        assert list(r.recoveries) == [2]     # only shard 2 failed over
        ok, key = check_linearizable(r.history)
        assert ok, f"violation on {key}"

    def test_sharded_skewed_contended_linearizable(self):
        """Hot-shard skew + tiny keyspace: heavy same-key contention on one
        master, cross-shard traffic on the rest."""
        r = run_sharded_scenario(
            n_shards=2, mode="curp", f=3, n_clients=4, n_ops=120,
            op_factory=ShardSkewedWorkload(n_shards=2, hot_frac=0.9,
                                           n_items=40, seed=4,
                                           read_fraction=0.3),
            seed=23,
        )
        ok, key = check_linearizable(r.history)
        assert ok, f"violation on {key}"


def _torn_mset_history():
    """A deliberately TORN cross-shard write: a client crashed mid-MSET
    (maybe-op), k1 ended up with the new value, k2 with the old one — and
    both final states are pinned by completed reads AFTER a common point."""
    mset = Op(OpType.MSET, ("k1", "k2"), ("new1", "new2"), (1, 1))
    r1 = Op(OpType.GET, ("k1",), (), (2, 1))
    r2 = Op(OpType.GET, ("k2",), (), (2, 2))
    return [
        # the crashed (never-completed) multi-key write
        {"op": mset, "invoke": 0.0, "complete": None, "value": None,
         "failed": True, "client": 1},
        # final reads, both strictly after the mset window
        {"op": r1, "invoke": 10.0, "complete": 11.0, "value": "new1",
         "failed": False, "client": 2},
        {"op": r2, "invoke": 12.0, "complete": 13.0, "value": None,
         "failed": False, "client": 2},
    ]


class TestStrictMultiKeyChecker:
    """Satellite regression: the per-key projection cannot see torn
    cross-shard writes (it drops a maybe-MSET's legs independently per
    key); the strict checker forces one include/exclude decision per op."""

    def test_projection_misses_torn_write(self):
        ok, _ = check_linearizable(_torn_mset_history())
        assert ok, "per-key projection is (by design) blind to torn writes"

    def test_strict_catches_torn_write(self):
        ok, key = check_linearizable_strict(_torn_mset_history())
        assert not ok
        assert key in ("k1", "k2")

    def test_strict_accepts_atomic_maybe_applied(self):
        """Crashed mset whose effects landed on BOTH keys: including it
        atomically explains the reads — no violation."""
        h = _torn_mset_history()
        h[2]["value"] = "new2"      # k2 also shows the new value
        ok, _ = check_linearizable_strict(h)
        assert ok

    def test_strict_accepts_atomic_maybe_dropped(self):
        """Crashed mset whose effects landed NOWHERE: excluding it
        atomically explains the reads — no violation."""
        h = _torn_mset_history()
        h[1]["value"] = None        # k1 shows the old value too
        ok, _ = check_linearizable_strict(h)
        assert ok

    def test_strict_matches_plain_checker_on_single_key_histories(self):
        r = run_scenario(mode="curp", f=3, n_clients=4, n_ops=120,
                         op_factory=UniformWriteWorkload(seed=2, n_items=40),
                         seed=9, crash_at_us=1200.0)
        ok_plain, _ = check_linearizable(r.history)
        ok_strict, _ = check_linearizable_strict(r.history)
        assert ok_plain and ok_strict

    def test_strict_point_consistency_across_keys(self):
        """The subtle torn case: reads ORDERED in real time (r1 then r2)
        observe k1=new but k2=old.  Per-key projection places the maybe-mset
        at a different point for each key and passes; a single global
        linearization point cannot satisfy both (before r1 AND after r2)."""
        mset = Op(OpType.MSET, ("k1", "k2"), ("n1", "n2"), (1, 1))
        r1 = Op(OpType.GET, ("k1",), (), (2, 1))
        r2 = Op(OpType.GET, ("k2",), (), (2, 2))
        h = [
            {"op": mset, "invoke": 0.0, "complete": None, "value": None,
             "failed": True, "client": 1},
            {"op": r1, "invoke": 10.0, "complete": 11.0, "value": "n1",
             "failed": False, "client": 2},
            {"op": r2, "invoke": 12.0, "complete": 13.0, "value": None,
             "failed": False, "client": 2},
        ]
        ok_plain, _ = check_linearizable(h)
        assert ok_plain            # blind
        ok_strict, _ = check_linearizable_strict(h)
        assert not ok_strict       # caught


class TestWitnessChecker:
    def test_checker_catches_violation(self):
        """Sanity: the linearizability checker itself detects a fabricated
        lost-update anomaly."""
        w1 = Op(OpType.SET, ("k",), ("v1",), (1, 1))
        rd = Op(OpType.GET, ("k",), (), (2, 1))
        history = [
            {"op": w1, "invoke": 0.0, "complete": 1.0, "value": "OK",
             "failed": False, "client": 1},
            # read AFTER the completed write returns None: violation
            {"op": rd, "invoke": 2.0, "complete": 3.0, "value": None,
             "failed": False, "client": 2},
        ]
        ok, key = check_linearizable(history)
        assert not ok and key == "k"
