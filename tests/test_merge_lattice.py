"""CRDT-CURP merge-lattice tests: matrix/scalar agreement, the multi-key
same-set placement regression (ways must be RESERVED as one op claims them),
Python Witness <-> DeviceWitness decision parity on collision-heavy classed
batches, dup-rpc retries, and §4.5 stale-gc parity.

Capacity caveat baked into the parity tests: the Python witness places at
``kh % n_sets`` while the device places at the keyhash2x32-mixed low lane,
so WHICH set a key lands in legitimately differs between backends.  Conflict
and dup decisions are placement-independent; capacity (rejects_full) is not.
Every parity scenario therefore bounds per-key load well under n_ways and
asserts ``rejects_full == 0`` on BOTH backends, which makes the
decision-parity assertions sound.
"""
from __future__ import annotations

import random

import numpy as np
import pytest

from repro.core.client import ClientSession
from repro.core.merge import (
    CLS_DEL,
    CLS_INCR,
    CLS_OTHER,
    CLS_SET,
    MERGEABLE,
    N_CLASSES,
    conflicts,
    op_hash_classes,
)
from repro.core.types import Op, OpType
from repro.core.witness import RecordStatus, Witness
from repro.kernels import (
    GangTable,
    WitnessTable,
    conflict_matrix_np,
    gang_record_groups,
    matrix_rows,
    np_keyhash2x32,
    ref_witness_record,
    witness_record,
)


def _sessions(n=4):
    return [ClientSession(client_id=i + 1) for i in range(n)]


def _device_witness(n_sets, n_ways):
    from repro.core.device_witness import DeviceWitness

    w = DeviceWitness(n_sets=n_sets, n_ways=n_ways)
    w.start(1)
    return w


# ---------------------------------------------------------------- matrix ----


def test_matrix_matches_scalar_over_all_pairs():
    rows = conflict_matrix_np()
    assert rows.shape == (N_CLASSES,)
    for a in range(N_CLASSES):
        for b in range(N_CLASSES):
            assert bool((int(rows[a]) >> b) & 1) == conflicts(a, b)
    # symmetric: merge-commutativity has no direction
    for a in range(N_CLASSES):
        for b in range(N_CLASSES):
            assert conflicts(a, b) == conflicts(b, a)


def test_matrix_rows_helper_matches_numpy_rows():
    rows = conflict_matrix_np()
    got = np.asarray(matrix_rows(np.arange(N_CLASSES, dtype=np.int32)))
    assert np.array_equal(got, rows.astype(got.dtype))


def test_mergeable_classes_self_commute_others_conflict():
    for cls in MERGEABLE:
        assert not conflicts(cls, cls)
        assert conflicts(cls, CLS_SET)
        assert conflicts(CLS_SET, cls)
        assert conflicts(cls, CLS_DEL)
        assert conflicts(cls, CLS_OTHER)
    assert conflicts(CLS_SET, CLS_SET)


# ------------------------------------------- multi-key placement regression ----


def test_mset_same_set_keys_both_survive_recovery():
    """Satellite regression: with EVERY key forced into one set (n_sets=1),
    a 2-key MSET must claim two distinct ways — the aliasing bug seated both
    keys in the same free way, so the second overwrote the first and one
    key's record silently vanished from recovery."""
    (s,) = _sessions(1)
    w = Witness(n_sets=1, n_ways=4)
    w.start(1)
    op = s.op_mset([("ka", "1"), ("kb", "2")])
    assert len(op.keys) == 2
    assert w.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED
    # both keys occupy their own way of set 0
    held = [slot for slot in w._slots[0] if slot.occupied]
    assert len(held) == 2
    assert {slot.key_hash for slot in held} == set(op.key_hashes())
    # each key independently defends its record: a foreign SET conflicts
    for key in ("ka", "kb"):
        probe = s.op_set(key, "x")
        assert (w.record(1, probe.key_hashes(), probe.rpc_id, probe)
                is RecordStatus.REJECTED)
    got = w.get_recovery_data(1)
    assert [o.rpc_id for o in got] == [op.rpc_id]


def test_gang_kernel_reserves_ways_for_same_set_group():
    """Kernel side of the same regression: one group carrying two DISTINCT
    keys whose mixed placement collides into one set must occupy two ways."""
    n_sets = 8
    # brute-force two raw keyhashes that mix into the same set row
    target = None
    seen = {}
    for raw in range(1, 4096):
        hi, lo = np.uint32(raw * 2654435761 % 2 ** 32), np.uint32(raw)
        mh, ml = np_keyhash2x32(np.array([hi]), np.array([lo]))
        srow = int(ml[0]) & (n_sets - 1)
        if srow in seen and seen[srow][:2] != (int(hi), int(lo)):
            target = (seen[srow], (int(hi), int(lo), srow))
            break
        seen.setdefault(srow, (int(hi), int(lo), srow))
    assert target is not None
    (h1, l1, srow), (h2, l2, srow2) = target
    assert srow == srow2 and (h1, l1) != (h2, l2)

    table = GangTable.empty(n_sets, 4, 1)
    res = gang_record_groups(
        table, n_sets,
        key_hi=[[h1, h2]], key_lo=[[l1, l2]], key_valid=[[1, 1]],
        lanes=[0], rpc_hi=[7], rpc_lo=[1], key_cls=[[CLS_SET, CLS_SET]],
    )
    assert int(res.reasons[0]) == 1  # REASON_INSERT: accepted
    occ_row = np.asarray(res.table.occ)[srow]
    assert int((occ_row > 0).sum()) == 2, (
        "same-set keys of one group must reserve distinct ways"
    )
    held_keys = {
        (int(np.asarray(res.table.keys_hi)[srow, wy]),
         int(np.asarray(res.table.keys_lo)[srow, wy]))
        for wy in range(4) if occ_row[wy] > 0
    }
    assert held_keys == {(int(res.q_hi[0, 0]), int(res.q_lo[0, 0])),
                         (int(res.q_hi[0, 1]), int(res.q_lo[0, 1]))}


# --------------------------------------------------- kernel/oracle parity ----


def test_record_kernel_matches_oracle_on_classed_collisions():
    rng = np.random.default_rng(5)
    base_hi = rng.integers(0, 2 ** 32, size=6, dtype=np.uint32)
    base_lo = rng.integers(0, 2 ** 32, size=6, dtype=np.uint32)
    pick = rng.integers(0, 6, size=128)
    q_hi, q_lo = base_hi[pick], base_lo[pick]
    q_cls = rng.choice(
        np.array([CLS_SET, CLS_DEL, CLS_INCR, CLS_INCR, CLS_INCR],
                 dtype=np.int32), size=128)
    table = WitnessTable.empty(32, 16)
    acc_ref, t_ref = ref_witness_record(table, q_hi, q_lo, q_cls)
    acc_dev, t_dev = witness_record(table, q_hi, q_lo, q_cls)
    assert np.array_equal(np.asarray(acc_ref), np.asarray(acc_dev))
    for name in ("keys_hi", "keys_lo", "occ"):
        assert np.array_equal(np.asarray(getattr(t_ref, name)),
                              np.asarray(getattr(t_dev, name))), name
    acc = np.asarray(acc_ref)
    assert 0 < int(acc.sum()) < len(acc)


def test_all_set_batch_keeps_legacy_occ_encoding():
    """CLS_SET == 0, so a classless (all-SET) table must stay bit-identical
    to the pre-widening 0/1 occupancy encoding."""
    rng = np.random.default_rng(9)
    q_hi = rng.integers(0, 2 ** 32, size=64, dtype=np.uint32)
    q_lo = rng.integers(0, 2 ** 32, size=64, dtype=np.uint32)
    table = WitnessTable.empty(32, 4)
    _, t_cls = witness_record(table, q_hi, q_lo,
                              np.zeros(64, np.int32))
    _, t_legacy = witness_record(table, q_hi, q_lo)  # q_cls defaulted
    occ = np.asarray(t_cls.occ)
    assert set(np.unique(occ)) <= {0, 1}
    for name in ("keys_hi", "keys_lo", "occ"):
        assert np.array_equal(np.asarray(getattr(t_cls, name)),
                              np.asarray(getattr(t_legacy, name))), name


# ----------------------------------------- Witness <-> DeviceWitness parity ----


def _collision_heavy_ops(seed, n_ops=72, n_keys=8, incr_cap=6):
    """INCR/INCR stacks + SET/INCR mixes over few keys; per-key mergeable
    load stays under incr_cap so capacity never decides (see module doc)."""
    sessions = _sessions(4)
    rng = random.Random(seed)
    keys = [f"k{i}" for i in range(n_keys)]
    per_key = {k: 0 for k in keys}
    ops = []
    for _ in range(n_ops):
        s = rng.choice(sessions)
        k = rng.choice(keys)
        if rng.random() < 0.7 and per_key[k] < incr_cap:
            per_key[k] += 1
            ops.append(s.op_incr(k, 1))
        else:
            ops.append(s.op_set(k, "v"))
    return ops


@pytest.mark.parametrize("seed", [3, 11])
def test_python_vs_device_decision_parity(seed):
    pyw = Witness(n_sets=64, n_ways=16)
    pyw.start(1)
    dw = _device_witness(64, 16)
    for op in _collision_heavy_ops(seed):
        a = pyw.record(1, op.key_hashes(), op.rpc_id, op)
        b = dw.record(1, op.key_hashes(), op.rpc_id, op)
        assert a is b, f"decision diverged on {op.op_type} {op.keys}: {a}/{b}"
    assert pyw.stats["rejects_full"] == 0
    assert dw.stats["rejects_full"] == 0
    assert pyw.stats["accepts"] == dw.stats["accepts"]
    # same surviving rpc set on both sides
    pa = {o.rpc_id for o in pyw.get_recovery_data(1)}
    da = {o.rpc_id for o in dw.get_recovery_data(1)}
    assert pa == da


def test_device_batch_matches_python_sequential():
    """record_batch (one gang dispatch) must make the same decisions as the
    Python witness fed the same ops one at a time, in batch order."""
    ops = _collision_heavy_ops(seed=21, n_ops=48)
    pyw = Witness(n_sets=64, n_ways=16)
    pyw.start(1)
    dw = _device_witness(64, 16)
    want = [pyw.record(1, op.key_hashes(), op.rpc_id, op) for op in ops]
    got = dw.record_batch(1, ops)
    assert got == want
    assert pyw.stats["rejects_full"] == 0
    assert dw.stats["rejects_full"] == 0


def test_dup_rpc_retry_parity():
    """A retried rpc (same RIFL id) is idempotently ACCEPTED by both
    backends and holds exactly one record."""
    (s,) = _sessions(1)
    pyw = Witness(n_sets=16, n_ways=8)
    pyw.start(1)
    dw = _device_witness(16, 8)
    op = s.op_incr("ctr", 1)
    for w in (pyw, dw):
        assert w.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED
        assert w.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED
    assert len(pyw.get_recovery_data(1)) == 1
    assert len(dw.get_recovery_data(1)) == 1


def test_stale_gc_suspicion_parity():
    """§4.5: both backends must suspect the SAME records as uncollected
    garbage after SUSPECT_AGE unserviced gc rounds, and a gc that names the
    record must clear it on both (mergeable stacks included)."""
    sessions = _sessions(2)
    pyw = Witness(n_sets=16, n_ways=8)
    pyw.start(1)
    dw = _device_witness(16, 8)
    ops = [sessions[0].op_incr("hot", 1), sessions[1].op_incr("hot", 1),
           sessions[0].op_set("cold", "v")]
    for op in ops:
        assert pyw.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED
        assert dw.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED
    # gc away ONE of the stacked INCRs; the other two records age out
    entries = tuple((kh, ops[0].rpc_id)
                    for kh, _cls in op_hash_classes(ops[0]))
    assert pyw.gc(entries).stale_requests == ()
    assert dw.gc(entries).stale_requests == ()
    for rnd in range(Witness.SUSPECT_AGE + 1):
        p = pyw.gc(())
        d = dw.gc(())
        assert ({o.rpc_id for o in p.stale_requests}
                == {o.rpc_id for o in d.stale_requests}), f"round {rnd}"
    # the aged-out survivors are exactly the two un-gc'd ops
    assert ({o.rpc_id for o in p.stale_requests}
            == {ops[1].rpc_id, ops[2].rpc_id})


def test_mixed_set_incr_conflict_is_order_dependent_but_parity_holds():
    """SET-then-INCR and INCR-then-SET both conflict (matrix is symmetric for
    SET vs INCR), while INCR-then-INCR stacks — on both backends."""
    sessions = _sessions(3)
    for first_kind in ("SET", "INCR"):
        pyw = Witness(n_sets=16, n_ways=8)
        pyw.start(1)
        dw = _device_witness(16, 8)
        mk = {"SET": lambda s: s.op_set("k", "v"),
              "INCR": lambda s: s.op_incr("k", 1)}
        first = mk[first_kind](sessions[0])
        second = mk["INCR" if first_kind == "SET" else "SET"](sessions[1])
        third = sessions[2].op_incr("k", 1)
        for w in (pyw, dw):
            assert w.record(1, first.key_hashes(), first.rpc_id,
                            first) is RecordStatus.ACCEPTED
            assert w.record(1, second.key_hashes(), second.rpc_id,
                            second) is RecordStatus.REJECTED
            expect = (RecordStatus.ACCEPTED if first_kind == "INCR"
                      else RecordStatus.REJECTED)
            assert w.record(1, third.key_hashes(), third.rpc_id,
                            third) is expect
