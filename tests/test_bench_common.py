"""Unit tests for benchmark helpers (benchmarks/common.py)."""
import pytest

from benchmarks.common import pct, summarize


class TestPct:
    def test_nearest_rank_basic(self):
        xs = list(range(1, 11))          # 1..10
        assert pct(xs, 0.50) == 5        # ceil(5) -> 5th value
        assert pct(xs, 0.90) == 9        # ceil(9) -> 9th, NOT the max
        assert pct(xs, 0.99) == 10       # ceil(9.9) -> 10th
        assert pct(xs, 1.00) == 10

    def test_small_sample_not_biased_high(self):
        # The old int(p * len) indexing returned the MAX for p90 of 10
        # samples; nearest-rank must return the 9th value.
        xs = [1.0] * 9 + [100.0]
        assert pct(xs, 0.90) == 1.0
        assert pct(xs, 0.91) == 100.0

    def test_single_element_and_bounds(self):
        assert pct([7.0], 0.5) == 7.0
        assert pct([7.0], 0.999) == 7.0
        assert pct([3.0, 1.0], 0.0) == 1.0   # p<=0 -> min
        assert pct([3.0, 1.0], 1.0) == 3.0

    def test_unsorted_input(self):
        assert pct([5.0, 1.0, 9.0, 3.0], 0.5) == 3.0  # ceil(2) -> 2nd sorted

    def test_empty_raises(self):
        with pytest.raises(ValueError):
            pct([], 0.5)

    def test_p999_needs_thousand_samples(self):
        xs = list(range(1000))           # 0..999
        assert pct(xs, 0.999) == 998     # ceil(999) -> 999th value
        assert pct(xs, 0.9995) == 999


class TestSummarize:
    def test_keys_and_consistency(self):
        xs = [float(i) for i in range(1, 101)]
        s = summarize(xs)
        assert set(s) == {"median", "mean", "p90", "p99", "p999"}
        assert s["median"] == 50.5
        assert s["p90"] == 90.0          # nearest rank of 100 samples
        assert s["p99"] == 99.0
        assert s["p90"] <= s["p99"] <= s["p999"]
