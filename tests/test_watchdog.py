"""Protocol-watchdog tests: chaos exactness, clean-run silence, windowed/
strict checker agreement, journal-ring dump well-formedness, and
deterministic breach replay.

The chaos matrix is the watchdog's own non-vacuousness proof: every
``ChaosConfig`` switch must trip EXACTLY the monitor ``CHAOS_MONITOR``
maps it to (a monitor nothing can trip is dead code wearing a pager), and
clean storms — overload, crash failover, hot-slot migration, cross-shard
2PC — must trip nothing at all.
"""
import json

import pytest

from repro.core.shard import KeyRouter
from repro.core.telemetry import Tracer
from repro.core.types import splitmix64
from repro.sim import (
    CHAOS_MONITOR,
    ChaosConfig,
    WindowedChecker,
    YcsbWorkload,
    OpenLoopWorkload,
    check_linearizable_strict,
    check_linearizable_windowed,
    replay,
    run_intent_leak_scenario,
    run_scenario,
    run_watched_scenario,
)

DUR = 3_000.0
DUR_MIG = 6_000.0


def _hot_slot(n_items=64):
    r = KeyRouter(2)
    slot = r.slot_of(f"user{splitmix64(0) % (n_items * 8)}")
    return slot, 1 - r.slot_map[slot]


def _run(switch=None, **over):
    chaos = ChaosConfig(**{switch: True}) if switch else None
    kw = dict(scenario="openloop", duration_us=DUR, seed=3)
    kw.update(over)
    return run_watched_scenario(chaos=chaos, **kw)


def _mig_kwargs():
    slot, dst = _hot_slot()
    return dict(duration_us=DUR_MIG, n_shards=2,
                workload=OpenLoopWorkload(rate_ops_per_us=0.5, seed=3,
                                          n_items=64),
                migrate_slots=[(0.25 * DUR_MIG, slot, dst)])


# ---------------------------------------------------------------------------
# chaos exactness: each switch trips exactly its monitor
# ---------------------------------------------------------------------------
class TestChaosExactness:
    def _assert_exact(self, wd, switch):
        expect = CHAOS_MONITOR[switch]
        assert wd.fired_monitors() == (expect,), (
            f"{switch}: fired {wd.fired_monitors()}, "
            f"want exactly ({expect},)")
        assert wd.blackbox is not None

    def test_early_ack_trips_durability(self):
        _r, wd = _run("early_ack")
        self._assert_exact(wd, "early_ack")

    def test_force_commute_trips_commutativity(self):
        _r, wd = _run("force_commute")
        self._assert_exact(wd, "force_commute")

    def test_rifl_rollback_trips_rifl(self):
        _r, wd = _run("rifl_rollback")
        self._assert_exact(wd, "rifl_rollback")

    def test_corrupt_value_trips_linearizability(self):
        _r, wd = _run("corrupt_value", workload=OpenLoopWorkload(
            rate_ops_per_us=0.5, seed=3, read_fraction=0.3, n_items=64))
        self._assert_exact(wd, "corrupt_value")

    def test_skip_fence_trips_single_owner(self):
        _r, wd = _run("skip_fence", **_mig_kwargs())
        self._assert_exact(wd, "skip_fence")

    def test_skip_epoch_bump_trips_epoch(self):
        _r, wd = _run("skip_epoch_bump", duration_us=DUR_MIG,
                      fail_master_at={0: 2_000.0}, heartbeat=True)
        self._assert_exact(wd, "skip_epoch_bump")

    def test_leak_intent_trips_intent(self):
        wd = run_intent_leak_scenario(
            chaos=ChaosConfig(leak_intent=True), intent_bound=200)
        assert wd.fired_monitors() == ("intent",)
        assert "undecided" in wd.breaches[0].reason


# ---------------------------------------------------------------------------
# clean runs: zero breaches, even through storms
# ---------------------------------------------------------------------------
class TestCleanSilence:
    def test_plain_openloop(self):
        _r, wd = _run()
        assert wd.ok, wd.breaches[0].reason

    def test_read_mixed(self):
        _r, wd = _run(workload=OpenLoopWorkload(
            rate_ops_per_us=0.5, seed=3, read_fraction=0.3, n_items=64))
        assert wd.ok, wd.breaches[0].reason

    def test_migration_storm(self):
        r, wd = _run(**_mig_kwargs())
        assert wd.ok, wd.breaches[0].reason
        # the migration actually happened and every handover window closed
        assert r.migrations
        assert not wd._moving

    def test_crash_failover_storm(self):
        _r, wd = _run(duration_us=DUR_MIG, fail_master_at={0: 2_000.0},
                      heartbeat=True)
        assert wd.ok, wd.breaches[0].reason
        kinds = {e.kind for e in wd.journal.events()}
        assert "fence" in kinds

    def test_clean_2pc(self):
        wd = run_intent_leak_scenario(chaos=None, intent_bound=200)
        assert wd.ok, wd.breaches[0].reason

    def test_tracer_drains_on_chaos_dump(self):
        """The black box drains the flight recorder through the same
        Tracer.drain teardown uses — no span leaks under chaos."""
        tracer = Tracer(sample=1.0)
        _r, wd = _run("early_ack", tracer=tracer)
        assert wd.blackbox is not None
        assert "trace_spans_sealed" in wd.blackbox
        assert not tracer.open_spans()


# ---------------------------------------------------------------------------
# windowed checker agrees with the strict checker
# ---------------------------------------------------------------------------
class TestWindowedAgreement:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_clean_histories_agree(self, seed):
        r = run_scenario(mode="curp", f=1, n_clients=4, n_ops=150,
                         seed=seed,
                         op_factory=YcsbWorkload(read_fraction=0.5,
                                                 n_items=64, seed=seed))
        ok_s, _ = check_linearizable_strict(r.history)
        ok_w, _ = check_linearizable_windowed(r.history)
        assert ok_s and ok_w

    def test_corrupted_history_rejected_by_both(self):
        r = run_scenario(mode="curp", f=1, n_clients=4, n_ops=150, seed=0,
                         op_factory=YcsbWorkload(read_fraction=0.5,
                                                 n_items=64, seed=0))
        bad = [dict(h) for h in r.history]
        for h in bad:
            if h["op"].op_type.name == "GET" and not h.get("failed") \
                    and h.get("complete") is not None:
                h["value"] = "~nobody-ever-wrote-this~"
                break
        else:
            pytest.skip("history had no completed reads")
        ok_s, _ = check_linearizable_strict(bad)
        ok_w, _ = check_linearizable_windowed(bad)
        assert not ok_s and not ok_w

    def test_saturation_is_explicit_not_wrong(self):
        """An entangled pile-up saturates (honest coverage limit) instead
        of false-alarming: 40 mutually-concurrent writes on one key."""
        chk = WindowedChecker(flush_every=8, maybe_horizon=None)
        from repro.core.types import Op, OpType
        hist = []
        for i in range(40):
            op = Op(rpc_id=(1, i + 1), op_type=OpType.SET,
                    keys=("k",), args=(f"v{i}",))
            hist.append({"op": op, "invoke": 0.0, "complete": 100.0 + i,
                         "value": "OK"})
        for h in hist:
            chk.invoke(h["op"].rpc_id, h["invoke"])
        for h in hist:
            chk.complete(h)
        chk.finish()
        assert chk.saturated
        assert chk.violation is None


# ---------------------------------------------------------------------------
# journal ring overwrite keeps dumps well-formed
# ---------------------------------------------------------------------------
class TestBlackBox:
    def test_ring_overwrite_dump_well_formed(self):
        """Tiny journal capacity: the ring overwrites long before the
        breach, and the dump must still be JSON-serializable, carry the
        breach, and report the drop count."""
        _r, wd = _run("skip_fence", watchdog_kwargs={"capacity": 64},
                      **_mig_kwargs())
        assert wd.fired_monitors() == ("single_owner",)
        box = wd.blackbox
        assert box["journal_dropped"] > 0
        assert len(box["journal"]) <= 64
        assert box["breach"]["monitor"] == "single_owner"
        json.dumps(box)   # the whole box must be plain data
        # ring events are the LAST n: seq strictly increasing, ending at
        # the journal's head at dump time
        seqs = [e["seq"] for e in box["journal"]]
        assert seqs == sorted(seqs)

    def test_report_shape(self):
        _r, wd = _run()
        rep = wd.report()
        assert rep["ok"] is True
        assert rep["monitors_fired"] == []
        assert rep["checker"]["ops_checked"] > 0
        json.dumps(rep)


# ---------------------------------------------------------------------------
# deterministic replay
# ---------------------------------------------------------------------------
class TestReplay:
    def test_replay_reproduces_breach_bit_identically(self):
        _r, wd = _run("early_ack")
        wd2, identical = replay(wd)
        assert identical
        assert [b.key() for b in wd2.breaches] == \
            [b.key() for b in wd.breaches]

    def test_replay_with_stateful_workload(self):
        """Workload objects carry RNG state; replay must re-run from the
        pristine snapshot, not the mutated live object."""
        _r, wd = _run("corrupt_value", workload=OpenLoopWorkload(
            rate_ops_per_us=0.5, seed=3, read_fraction=0.3, n_items=64))
        assert wd.breaches
        _wd2, identical = replay(wd)
        assert identical

    def test_clean_replay_stays_clean(self):
        _r, wd = _run()
        assert wd.ok
        wd2, identical = replay(wd)
        assert identical and wd2.ok
