"""Production traffic armor: overload policy units (core.overload), the
heartbeat failure detector, migrated-RIFL ack gc, witness per-class budgets,
and open-loop storm scenarios through the linearizability checkers."""
from repro.core.client import ClientSession
from repro.core.config import HeartbeatDetector
from repro.core.master import DUP, Master
from repro.core.overload import (
    AdmissionQueue,
    ArmorConfig,
    BreakerState,
    CircuitBreaker,
    DegradeLevel,
    TokenBucket,
    degrade_level,
)
from repro.core.types import Op, OpType, keyhash
from repro.core.witness import RecordStatus, Witness
from repro.sim import (
    OpenLoopWorkload,
    SimParams,
    check_linearizable,
    check_linearizable_strict,
    run_openloop_scenario,
)


# ---------------------------------------------------------------------------
# policy units
# ---------------------------------------------------------------------------
class TestAdmissionQueue:
    def test_bound_and_shed_accounting(self):
        q = AdmissionQueue(2)
        assert q.admit() and q.admit()
        assert not q.admit()            # full -> shed
        assert q.shed == 1 and q.admitted == 2 and q.frac() == 1.0
        q.release()
        assert q.admit()                # slot freed
        assert q.max_depth == 2


class TestTokenBucket:
    def test_rate_and_burst(self):
        b = TokenBucket(rate=1.0, burst=2.0, now=0.0)   # 1 token/us
        assert b.allow(0.0) and b.allow(0.0)            # burst
        assert not b.allow(0.0)                         # drained
        assert b.allow(1.0)                             # refilled 1 token
        assert not b.allow(1.0)


class TestCircuitBreaker:
    def test_trip_half_open_reopen_close_cycle(self):
        br = CircuitBreaker(failure_threshold=3, reset_timeout=100.0,
                            half_open_probes=1)
        for _ in range(3):
            br.record_failure(now=0.0)
        assert br.state is BreakerState.OPEN
        assert not br.allow(50.0)                  # cooling down: fast fail
        assert br.allow(150.0)                     # HALF_OPEN probe admitted
        assert not br.allow(150.0)                 # probe budget spent
        br.record_failure(now=150.0)               # probe failed: re-OPEN
        assert br.state is BreakerState.OPEN
        assert br.allow(260.0)                     # second probe window
        br.record_success()
        assert br.state is BreakerState.CLOSED
        assert br.allow(260.0)
        assert br.stats["trips"] == 2 and br.stats["closes"] == 1

    def test_consecutive_not_total_failures(self):
        br = CircuitBreaker(failure_threshold=3)
        br.record_failure(0.0)
        br.record_failure(0.0)
        br.record_success()                        # resets the streak
        br.record_failure(0.0)
        br.record_failure(0.0)
        assert br.state is BreakerState.CLOSED


class TestDegradeHysteresis:
    def test_enter_high_leave_low(self):
        lvl = DegradeLevel.NORMAL
        lvl = degrade_level(0.5, lvl, hi=0.75, lo=0.40)
        assert lvl is DegradeLevel.NORMAL
        lvl = degrade_level(0.8, lvl, hi=0.75, lo=0.40)
        assert lvl is DegradeLevel.DEFER_SLOW
        lvl = degrade_level(0.5, lvl, hi=0.75, lo=0.40)   # between lo and hi
        assert lvl is DegradeLevel.DEFER_SLOW             # no flap
        lvl = degrade_level(0.3, lvl, hi=0.75, lo=0.40)
        assert lvl is DegradeLevel.NORMAL


class TestHeartbeatDetector:
    def test_suspect_after_silent_intervals_once(self):
        d = HeartbeatDetector(interval=100.0, miss_threshold=5)
        d.watch(0, 0.0)
        d.beat(0, 250.0)
        assert d.check(700.0) == []           # deadline is 250 + 500
        assert d.check(800.0) == [0]
        assert d.suspected(0)
        assert d.check(900.0) == []           # reported exactly once
        d.beat(0, 950.0)                      # zombie beats are ignored
        assert d.suspected(0)
        d.watch(0, 1000.0)                    # failover done: re-arm
        assert not d.suspected(0)
        assert d.check(1400.0) == []
        assert d.check(1500.0) == [0]


# ---------------------------------------------------------------------------
# migrated-RIFL ack-driven gc (satellite regression)
# ---------------------------------------------------------------------------
class TestMigratedRiflGc:
    def _master_with_overlay(self):
        m = Master(1, epoch=0, sync_batch=50)
        kh = (keyhash("a"),)
        m.migrated_rifl[((7, 1), kh)] = "r1"
        m.migrated_rifl[((7, 5), kh)] = "r5"
        m.migrated_rifl[((8, 2), kh)] = "x2"
        return m, kh

    def test_ack_frontier_prunes_only_below(self):
        m, kh = self._master_with_overlay()
        s = ClientSession(client_id=9)
        m.handle_update(s.op_set("zz", "v"), m.witness_list_version,
                        client_acks=((7, 4),), now=0.0)
        # seq 1 < frontier 4: the client can never retry it -> dropped;
        # seq 5 and the other client's record must survive.
        assert ((7, 1), kh) not in m.migrated_rifl
        assert ((7, 5), kh) in m.migrated_rifl
        assert ((8, 2), kh) in m.migrated_rifl
        assert m.stats["migrated_rifl_gcd"] == 1

    def test_surviving_record_still_dedups(self):
        m, kh = self._master_with_overlay()
        s = ClientSession(client_id=9)
        m.handle_update(s.op_set("zz", "v"), m.witness_list_version,
                        client_acks=((7, 4),), now=0.0)
        retry = Op(OpType.SET, ("a",), ("v",), (7, 5))
        verdict, result = m.handle_update(retry, m.witness_list_version,
                                          now=1.0)
        assert verdict == DUP and result.value == "r5"

    def test_install_skips_below_acked_frontier(self):
        m, kh = self._master_with_overlay()
        s = ClientSession(client_id=9)
        m.handle_update(s.op_set("zz", "v"), m.witness_list_version,
                        client_acks=((7, 4),), now=0.0)
        # A later (chained) migration tries to re-install seq 2 and seq 4:
        # 2 is below the acked frontier and must NOT be resurrected; 4 is
        # the first incomplete seq and must land.
        mig = Op(OpType.MIGRATE_IN, (), ((), (((7, 2), kh, "r2"),
                                              ((7, 4), kh, "r4"))), (1, 99))
        m._install_migrated(mig)
        assert ((7, 2), kh) not in m.migrated_rifl
        assert ((7, 4), kh) in m.migrated_rifl


# ---------------------------------------------------------------------------
# witness per-class way budget (satellite)
# ---------------------------------------------------------------------------
class TestWitnessClassBudget:
    def _incrs(self, n, key="hot"):
        s = ClientSession(client_id=3)
        return [s.op_incr(key) for _ in range(n)]

    def test_budget_caps_merge_stack_but_not_other_classes(self):
        # One set, 4 ways, budget 3: the INCR storm may hold at most 3 ways,
        # so a SET on another key still finds a seat in the same set.
        w = Witness(n_sets=1, n_ways=4, class_budget=3)
        w.start(1)
        sts = [w.record(1, op.key_hashes(), op.rpc_id, op)
               for op in self._incrs(4)]
        assert sts[:3] == [RecordStatus.ACCEPTED] * 3
        assert sts[3] is RecordStatus.REJECTED
        assert w.stats["rejects_budget"] == 1
        other = ClientSession(client_id=4).op_set("cold", "v")
        assert w.record(1, other.key_hashes(), other.rpc_id, other) \
            is RecordStatus.ACCEPTED

    def test_without_budget_storm_starves_the_set(self):
        # Paper behavior (default): 4 INCRs fill all 4 ways; the SET rejects
        # as full and must take the 2-RTT sync path.
        w = Witness(n_sets=1, n_ways=4)
        w.start(1)
        for op in self._incrs(4):
            assert w.record(1, op.key_hashes(), op.rpc_id, op) \
                is RecordStatus.ACCEPTED
        other = ClientSession(client_id=4).op_set("cold", "v")
        assert w.record(1, other.key_hashes(), other.rpc_id, other) \
            is RecordStatus.REJECTED
        assert w.stats["rejects_full"] == 1
        assert w.stats["rejects_budget"] == 0

    def test_duplicate_record_rpc_not_budget_rejected(self):
        # A client retry of an already-held record is an idempotent accept
        # even when the stack is at budget.
        w = Witness(n_sets=1, n_ways=4, class_budget=3)
        w.start(1)
        ops = self._incrs(3)
        for op in ops:
            w.record(1, op.key_hashes(), op.rpc_id, op)
        assert w.record(1, ops[0].key_hashes(), ops[0].rpc_id, ops[0]) \
            is RecordStatus.ACCEPTED


# ---------------------------------------------------------------------------
# open-loop storms through the checkers
# ---------------------------------------------------------------------------
class TestOpenLoopStorms:
    def test_overload_bounded_queue_vs_naked(self):
        wl = dict(rate_ops_per_us=1.5, n_clients=2000)
        naked = run_openloop_scenario(
            workload=OpenLoopWorkload(seed=2, **wl), duration_us=3000.0,
            f=1, armor=None, seed=2)
        armored = run_openloop_scenario(
            workload=OpenLoopWorkload(seed=2, **wl), duration_us=3000.0,
            f=1, armor=ArmorConfig(queue_capacity=16), seed=2)
        assert armored.max_qdepth <= 16
        assert naked.max_qdepth > 160           # unbounded growth
        assert armored.client_stats["sheds_seen"] > 0
        assert armored.witness_sheds >= 0       # witness bound wired in

    def test_drops_and_duplicate_delivery_strict(self):
        # Lossy, jittery transport: dropped MUpdate/MRecordResp force
        # timeouts; the retry re-delivers to a master that may have already
        # executed (RIFL dedups).  The STRICT checker must still pass.
        p = SimParams(drop_prob=0.03, delay_jitter_sigma=0.4, tail_prob=0.05)
        r = run_openloop_scenario(
            workload=OpenLoopWorkload(rate_ops_per_us=0.04, n_clients=5,
                                      n_items=8, seed=7),
            duration_us=8000.0, f=1, armor=True, params=p, seed=7,
            record_history=True)
        assert r.client_stats["timeouts"] > 0   # duplicates actually flew
        ok, key = check_linearizable_strict(r.history)
        assert ok, f"violation on {key}"

    def test_heartbeat_failover_with_inflight_ops_strict(self):
        # Silent master kill, NO harness recovery: the coordinator's
        # detector must drive failover, acked writes survive, and the
        # strict checker passes over the full storm.
        r = run_openloop_scenario(
            workload=OpenLoopWorkload(rate_ops_per_us=0.05, n_clients=6,
                                      n_items=8, seed=5),
            duration_us=8000.0, f=1, armor=True, seed=5, heartbeat=True,
            fail_master_at={0: 3000.0}, record_history=True)
        assert r.failovers and r.failovers[0]["shard"] == 0
        assert all(rep["detected_by"] == "heartbeat"
                   for rep in r.recoveries.values())
        rec_at = max(rep["recovered_at"] for rep in r.recoveries.values())
        assert any(h["complete"] is not None and h["complete"] > rec_at
                   for h in r.history)          # service resumed
        ok, key = check_linearizable_strict(r.history)
        assert ok, f"violation on {key}"

    def test_migration_storm_cached_map_strict(self):
        # Live slot handovers under open-loop traffic: cached slot maps go
        # stale, NOT_OWNER redirects force the §3.6 refetch, and nothing is
        # lost or duplicated across the handover.
        r = run_openloop_scenario(
            workload=OpenLoopWorkload(rate_ops_per_us=0.04, n_clients=5,
                                      n_items=10, seed=19),
            duration_us=6000.0, f=1, n_shards=2,
            armor=ArmorConfig(queue_capacity=16), seed=19,
            migrate_slots=[(2000.0, 0, 1), (3000.0, 2, 1)],
            record_history=True)
        assert len(r.migrations) == 2
        ok, key = check_linearizable_strict(r.history)
        assert ok, f"violation on {key}"

    def test_per_key_checker_on_bigger_mixed_run(self):
        # theta 0.6 keeps the hottest key's concurrent window small enough
        # for the per-key checker's search to stay fast across the crash.
        r = run_openloop_scenario(
            workload=OpenLoopWorkload(rate_ops_per_us=0.2, n_clients=300,
                                      n_items=500, read_fraction=0.3,
                                      theta=0.6, seed=23),
            duration_us=4000.0, f=1, armor=True, seed=23,
            heartbeat=True, fail_master_at={0: 1500.0}, record_history=True)
        ok, key = check_linearizable(r.history)
        assert ok, f"violation on {key}"
