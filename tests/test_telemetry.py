"""Flight-recorder tests: registry instruments, causal tracing, AIMD
admission, and device-vs-host reason-code counter parity.

The parity tests are the contract for the in-dispatch telemetry plane: the
[lane, 5] counters the record kernels scatter-accumulate on device must be
BIT-EXACT with the host ``DeviceWitness.stats["reason_*"]`` accounting over
the same drain interval, on every record path (set-parallel, grouped
multi-key, fused cluster fast path) — otherwise the cheap on-device view
cannot be trusted as a stand-in for host bookkeeping.
"""
import numpy as np
import pytest

from repro.core import DeviceWitness, ShardedCluster, Witness, telemetry
from repro.core.client import ClientSession
from repro.core.device_witness import WitnessGang
from repro.core.overload import AdmissionQueue, AimdBound
from repro.core.telemetry import (
    Histogram,
    MetricsRegistry,
    Tracer,
    _mix_id,
    stage_attribution,
)
from repro.core.types import Op, OpType, RecordStatus

# Kernel reason-code columns (index 0 unused).
_R_INSERT, _R_DUP, _R_CONFLICT, _R_FULL = 1, 2, 3, 4
_STAT_OF = {_R_INSERT: "reason_insert", _R_DUP: "reason_dup",
            _R_CONFLICT: "reason_conflict", _R_FULL: "reason_full"}


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------
class TestInstruments:
    def test_histogram_percentiles_match_numpy(self):
        r = np.random.default_rng(7)
        xs = np.concatenate([
            r.lognormal(mean=2.0, sigma=1.5, size=4000),
            r.uniform(0.0, 5.0, size=1000),
        ])
        h = Histogram("t")
        for v in xs:
            h.record(float(v))
        assert h.count == len(xs)
        assert h.max == pytest.approx(float(xs.max()))
        assert h.mean == pytest.approx(float(xs.mean()), rel=1e-9)
        for q in (0.5, 0.9, 0.99):
            exact = float(np.quantile(xs, q))
            # log-bucket resolution at _SUB=5 bounds relative error ~2.2%;
            # nearest-rank vs interpolation adds a little on small tails.
            assert h.percentile(q) == pytest.approx(exact, rel=0.10), q

    def test_histogram_small_and_zero(self):
        h = Histogram("t")
        assert h.percentile(0.99) == 0.0
        h.record(0.0)
        assert h.percentile(0.5) == 0.0   # capped at observed max
        h.record(1000.0)
        assert h.percentile(1.0) == pytest.approx(1000.0, rel=0.05)

    def test_registry_reset_in_place_keeps_handles(self):
        reg = MetricsRegistry()
        c = reg.counter("c")
        g = reg.gauge("g")
        h = reg.histogram("h")
        c.inc(3)
        g.set(9.0)
        h.record(5.0)
        reg.reset()
        # The SAME objects are live and zeroed — hot-path holders never
        # re-fetch between scenario runs.
        assert c is reg.counter("c") and c.value == 0
        assert g is reg.gauge("g") and g.max == 0.0
        assert h is reg.histogram("h") and h.count == 0
        c.inc()
        assert reg.counter("c").value == 1

    def test_registry_type_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_null_registry_while_disabled(self):
        telemetry.disable()
        try:
            inst = telemetry.get_registry().histogram("nope")
            inst.record(5.0)
            assert inst.percentile(0.5) == 0.0
            assert inst.count == 0
        finally:
            telemetry.enable()
        assert telemetry.get_registry() is telemetry.registry()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------
class TestTracer:
    def test_sampling_is_deterministic_and_roughly_proportional(self):
        tr = Tracer(sample=0.25)
        ids = [(c, s) for c in range(40) for s in range(25)]
        kept = [i for i in ids if tr.sampled(i)]
        assert kept == [i for i in ids if tr.sampled(i)]  # stable
        assert 0.15 < len(kept) / len(ids) < 0.35
        assert _mix_id((1, 2)) != _mix_id((2, 1))

    def test_children_parent_to_root_and_close_open(self):
        tr = Tracer()
        root = tr.begin((1, 1), "op", 0.0, actor="client")
        tr.span((1, 1), "witness_record", 1.0, 2.0, actor="w0")
        tr.span((1, 1), "master_update", 3.0, 1.5, actor="m0")
        tr.end(root, 10.0, status="1rtt")
        # forced spans get their own trace
        tr.span(("sync", "m0"), "master_sync", 5.0, 2.0, force=True)
        leaked = tr.begin((9, 9), "op", 8.0)
        assert leaked is not None
        assert tr.close_open(20.0) == 1
        ids = {s.span_id for s in tr.spans}
        for s in tr.spans:
            assert s.end is not None
            assert s.parent is None or s.parent in ids
        kids = [s for s in tr.spans if s.trace_id == (1, 1) and s.parent]
        assert {s.parent for s in kids} == {root}
        assert [s.status for s in tr.spans if s.trace_id == (9, 9)] \
            == ["unfinished"]

    def test_export_chrome_roundtrip(self, tmp_path):
        import json

        tr = Tracer()
        r = tr.begin((1, 2), "op", 0.0, actor="client")
        tr.span((1, 2), "witness_record", 1.0, 2.0, actor="w0",
                status="accepted")
        tr.instant((1, 2), "timeout", 5.0, actor="client")
        tr.end(r, 6.0)
        path = tmp_path / "trace.json"
        doc = tr.export_chrome(str(path))
        loaded = json.loads(path.read_text())
        assert loaded == doc
        evs = loaded["traceEvents"]
        assert {e["ph"] for e in evs} == {"X", "i", "M"}
        xs = [e for e in evs if e["ph"] == "X"]
        assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
        names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
        assert {"client", "w0"} <= names

    def test_stage_attribution_tail_cohort(self):
        tr = Tracer()
        for i in range(100):
            r = tr.begin((1, i), "op", 0.0)
            dur = 1.0 + float(i)   # distinct durations: clean p99 cut
            tr.span((1, i), "master_update", 0.1, dur)
            tr.end(r, dur + 0.2)
        att = stage_attribution(tr, tail_q=0.99)
        assert att["n_ops"] == 100
        assert att["tail_n"] == 2          # ops 98 and 99 at/above the cut
        assert att["stages_tail"]["master_update"] == pytest.approx(99.5)
        assert att["stages_all"]["master_update"] == pytest.approx(50.5)


# ---------------------------------------------------------------------------
# trace survives a mid-scenario master crash
# ---------------------------------------------------------------------------
class TestTraceCrashSurvival:
    def test_spans_closed_and_parents_resolve_across_crash(self):
        from repro.sim import OpenLoopWorkload, run_openloop_scenario
        from repro.core.overload import ArmorConfig

        tr = Tracer(sample=1.0)
        r = run_openloop_scenario(
            workload=OpenLoopWorkload(rate_ops_per_us=0.05, n_clients=8,
                                      n_items=8, seed=5),
            duration_us=6_000.0, f=1, armor=ArmorConfig(queue_capacity=16),
            seed=5, heartbeat=True, fail_master_at={0: 2_500.0}, tracer=tr,
        )
        assert r.failovers, "crash was never detected"
        assert tr.spans, "tracer saw nothing"
        assert not tr.open_spans(), "spans leaked past scenario teardown"
        ids = {s.span_id for s in tr.spans}
        for s in tr.spans:
            assert s.end is not None and s.end >= s.start
            assert s.parent is None or s.parent in ids
        # The kill is visible in the trace: ops in flight at the crash
        # either closed as failed/unfinished or paid timeout retries before
        # completing against the recovered master.
        roots = [s for s in tr.spans if s.name == "op"]
        assert roots
        detours = {ev["name"] for ev in tr.instants}
        assert "timeout" in detours or any(
            s.status in ("failed", "unfinished") for s in roots)


# ---------------------------------------------------------------------------
# AIMD adaptive admission
# ---------------------------------------------------------------------------
class TestAimdBound:
    def test_converges_to_delay_target_and_backs_off(self):
        q = AdmissionQueue(4, scope="t1")
        h = Histogram("svc")
        for _ in range(100):
            h.record(2.0)          # p50 ~= 2 µs
        ctl = AimdBound(q, h, target_delay_us=40.0)
        for _ in range(50):
            ctl.tick()
        assert abs(q.capacity - 20) <= 1   # 40 / 2 = 20, additive approach
        # Service time inflates 10x -> multiplicative decrease toward 4.
        h.reset()
        for _ in range(100):
            h.record(20.0)
        caps = [ctl.tick() for _ in range(6)]
        assert caps[0] < 20 and q.capacity <= max(4, caps[0])
        assert q.capacity >= ctl.min_cap

    def test_holds_bound_without_signal(self):
        q = AdmissionQueue(16, scope="t2")
        h = Histogram("svc")
        ctl = AimdBound(q, h, target_delay_us=40.0)
        for _ in range(5):
            assert ctl.tick() == 16    # < 16 samples: no move
        h.record(0.0)                  # degenerate p50 == 0 guard
        for _ in range(20):
            h.record(0.0)
        assert ctl.tick() == 16


# ---------------------------------------------------------------------------
# device-vs-host reason-code counter parity
# ---------------------------------------------------------------------------
def _drain_total(gang: WitnessGang) -> np.ndarray:
    """Sum the per-lane plane into one [5] vector (and zero the plane)."""
    return gang.drain_counters().sum(axis=0)


def _host_reasons(*witnesses) -> np.ndarray:
    out = np.zeros(5, np.int64)
    for w in witnesses:
        for code, stat in _STAT_OF.items():
            out[code] += w.stats[stat]
    return out


class TestReasonCounterParity:
    def test_collision_heavy_setparallel_batch(self):
        s = ClientSession(client_id=1)
        dw = DeviceWitness(16, 2)
        dw.start(master_id=1)
        # Tiny keyspace: inserts, then conflicts on the same keys, then a
        # full set; retries of recorded rpcs are dups.
        ops = [s.op_set(f"k{i % 6}", "v") for i in range(40)]
        st = dw.record_batch(1, ops)
        st += dw.record_batch(1, ops[:10])   # exact dup retries
        device = _drain_total(dw.gang)
        host = _host_reasons(dw)
        np.testing.assert_array_equal(device, host)
        assert device[_R_INSERT] > 0 and device[_R_CONFLICT] > 0
        assert device[_R_DUP] > 0
        assert device.sum() == len(st)

    def test_dup_retry_single_op_grouped_path(self):
        s = ClientSession(client_id=2)
        dw = DeviceWitness(16, 2)
        dw.start(master_id=1)
        op = s.op_set("x", "v")
        for _ in range(3):   # first insert, then 2 idempotent dup accepts
            assert dw.record(1, op.key_hashes(), op.rpc_id, op) \
                is RecordStatus.ACCEPTED
        op2 = s.op_set("x", "w")
        assert dw.record(1, op2.key_hashes(), op2.rpc_id, op2) \
            is RecordStatus.REJECTED
        device = _drain_total(dw.gang)
        np.testing.assert_array_equal(device, _host_reasons(dw))
        assert device[_R_INSERT] == 1
        assert device[_R_DUP] == 2
        assert device[_R_CONFLICT] == 1

    def test_multikey_groups_batch(self):
        s = ClientSession(client_id=3)
        dw = DeviceWitness(16, 2)
        dw.start(master_id=1)
        ops = [s.op_mset([(f"a{i}", "1"), (f"b{i % 3}", "2")])
               for i in range(12)]
        dw.record_batch(1, ops)
        dw.record_batch(1, ops[:4])          # multi-key dup retries
        device = _drain_total(dw.gang)
        host = _host_reasons(dw)
        np.testing.assert_array_equal(device, host)
        # Grouped accounting is per-GROUP (one count per op), like _settle.
        assert device.sum() == 16

    def test_full_sets_reason_full(self):
        s = ClientSession(client_id=4)
        dw = DeviceWitness(2, 1)   # 2 sets x 1 way: fills instantly
        dw.start(master_id=1)
        ops = [s.op_set(f"u{i}", "v") for i in range(16)]
        dw.record_batch(1, ops)
        device = _drain_total(dw.gang)
        np.testing.assert_array_equal(device, _host_reasons(dw))
        assert device[_R_FULL] + device[_R_CONFLICT] > 0

    def test_parity_matches_python_witness_outcomes(self):
        """Same batch on both witness backends: the device counter plane
        agrees with the python Witness's own outcome bookkeeping."""
        s = ClientSession(client_id=5)
        ops = [s.op_set(f"k{i % 5}", "v") for i in range(30)]
        pw, dw = Witness(64, 4), DeviceWitness(64, 4)
        pw.start(master_id=9)
        dw.start(master_id=9)
        assert pw.record_batch(9, ops) == dw.record_batch(9, ops)
        device = _drain_total(dw.gang)
        assert device[_R_INSERT] == \
            pw.stats["accepts"] - pw.stats["accepts_dup"]
        assert device[_R_DUP] == pw.stats["accepts_dup"]
        assert device[_R_CONFLICT] == pw.stats["rejects_conflict"]
        assert device[_R_FULL] == pw.stats["rejects_full"]

    def test_fused_cluster_fastpath_parity(self):
        """The one-dispatch multi-shard fast path accumulates one count per
        (op, witness copy) — the same granularity the driver settles at."""
        from repro.sim.workload import BatchedWorkload

        cluster = ShardedCluster(n_shards=2, f=2, seed=3,
                                 witness_backend="device")
        session = cluster.new_client()
        wl = BatchedWorkload(batch_size=32, conflict_frac=0.3, seed=3)
        for _ in range(3):
            cluster.update_batch(session, wl.batch(session))
        witnesses = [w for sh in cluster.shards for w in sh.witnesses]
        device = _drain_total(cluster.gang)
        host = _host_reasons(*witnesses)
        np.testing.assert_array_equal(device, host)
        assert device.sum() > 0 and device[_R_INSERT] > 0

    def test_drain_zeroes_and_lane_recycle_resets(self):
        s = ClientSession(client_id=6)
        gang = WitnessGang(16, 2, n_lanes=2)
        w = DeviceWitness(16, 2, gang=gang)
        w.start(master_id=1)
        op = s.op_set("x", "v")
        w.record(1, op.key_hashes(), op.rpc_id, op)
        assert _drain_total(gang).sum() == 1
        assert _drain_total(gang).sum() == 0     # drained plane is zero
        # Recycled lane starts from zero even without a drain.
        op2 = s.op_set("y", "v")
        w.record(1, op2.key_hashes(), op2.rpc_id, op2)
        lane = w.lane
        w.end()
        w2 = DeviceWitness(16, 2, gang=gang)
        w2.start(master_id=2)
        w3 = DeviceWitness(16, 2, gang=gang)
        w3.start(master_id=3)
        assert lane in (w2.lane, w3.lane)        # lane actually recycled
        assert np.asarray(gang.counters)[lane].sum() == 0


# ---------------------------------------------------------------------------
# dispatch-count shim rides the registry
# ---------------------------------------------------------------------------
class TestDispatchShim:
    def test_dispatch_count_is_a_registry_counter(self):
        from repro.kernels import dispatch_count, reset_dispatch_count

        reset_dispatch_count()
        before = telemetry.registry().counter("kernels.dispatches").value
        assert dispatch_count() == before == 0
        s = ClientSession(client_id=7)
        dw = DeviceWitness(16, 2)
        dw.start(master_id=1)
        dw.record_batch(1, [s.op_set("a", "v")])
        assert dispatch_count() == \
            telemetry.registry().counter("kernels.dispatches").value > 0
