"""Mini-transaction subsystem tests (repro.core.txn): single-shard
short-circuit, cross-shard 2PC, coordinator/participant crashes at every
2PC stage, recovery resolution, the prepare/resolve race, and the serving
store's atomic group commit."""
import pytest

from repro.core import (
    CoordinatorCrash,
    ShardedCluster,
    TxnStatus,
    Witness,
)
from repro.core.txn import (
    TxnPending,
    abort_op,
    participant_state,
    prepare_op,
    resolve_txn,
)
from repro.sim import (
    TXN_CRASH_STAGES,
    check_linearizable_strict,
    run_txn_crash_scenario,
)

N_SHARDS = 4


def key_on_shard(router, shard: int, tag: str = "k") -> str:
    for i in range(10_000):
        k = f"{tag}{i}"
        if router.shard_of(k) == shard:
            return k
    raise AssertionError(f"no key found for shard {shard}")


@pytest.fixture(params=["python", "device"])
def cluster(request):
    sets = 1024 if request.param == "python" else 256
    return ShardedCluster(n_shards=N_SHARDS, f=3,
                          witness_backend=request.param, witness_sets=sets)


class TestTxnBasics:
    def test_single_shard_short_circuit_1rtt(self, cluster):
        cl = cluster.new_client()
        k1 = key_on_shard(cluster.router, 0, "a")
        k2 = key_on_shard(cluster.router, 0, "b")
        out = cluster.txn(cl, writes=[(k1, 1), (k2, 2)])
        assert out.status is TxnStatus.COMMITTED
        assert out.rtts == 1 and out.fast_path and out.n_shards == 1
        assert cluster.read(cl, cl.op_get(k1)).value == 1
        assert cluster.read(cl, cl.op_get(k2)).value == 2

    def test_cross_shard_commit_two_rounds(self, cluster):
        cl = cluster.new_client()
        kvs = [(key_on_shard(cluster.router, s), s * 10)
               for s in range(N_SHARDS)]
        out = cluster.txn(cl, writes=kvs)
        assert out.status is TxnStatus.COMMITTED
        assert out.rtts == 2 and out.fast_path
        assert out.n_shards == N_SHARDS
        for k, v in kvs:
            assert cluster.read(cl, cl.op_get(k)).value == v

    def test_read_set_values_returned_on_commit(self):
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        cl = c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        c.update(cl, cl.op_set(k0, "seed"))
        c.sync_all()
        out = c.txn(cl, writes=[(k1, "w")], reads=[k0])
        assert out.status is TxnStatus.COMMITTED
        assert out.reads == {k0: "seed"}

    def test_single_shard_read_write_history_recorded_once(self):
        """Regression: a committed single-shard txn that reads AND writes
        the same key must appear in the history exactly once — a duplicate
        entry would force two linearization points for one atomic op and
        make the strict checker reject a correct execution."""
        c = ShardedCluster(n_shards=2, f=3)
        cl = c.new_client()
        c.update(cl, cl.op_set("k", "old"))
        c.sync_all()
        out = c.txn(cl, writes=[("k", "new")], reads=["k"])
        assert out.status is TxnStatus.COMMITTED
        assert out.reads == {"k": "old"}
        from repro.core.types import OpType

        txn_entries = [h for h in c.history
                       if h["op"].op_type is OpType.TXN]
        assert len(txn_entries) == 1
        ok, key = check_linearizable_strict(c.history)
        assert ok, f"phantom violation on {key}"

    def test_mset_atomic_matches_mset_values(self):
        c = ShardedCluster(n_shards=N_SHARDS, f=3)
        cl = c.new_client()
        kvs = [(key_on_shard(c.router, s, "ma"), f"v{s}")
               for s in range(N_SHARDS)]
        out = c.mset_atomic(cl, kvs)
        assert out.status is TxnStatus.COMMITTED
        for k, v in kvs:
            assert c.read(cl, cl.op_get(k)).value == v

    def test_same_spec_rerun_is_idempotent(self):
        c = ShardedCluster(n_shards=2, f=3)
        cl = c.new_client()
        kvs = [(key_on_shard(c.router, 0), 1), (key_on_shard(c.router, 1), 2)]
        spec = cl.txn_spec(kvs)
        out1 = c.txn(cl, None, spec=spec)
        lens = [len(g.master.log) for g in c.shards]
        out2 = c.txn(cl, None, spec=spec)   # full client retry
        assert out1.status is out2.status is TxnStatus.COMMITTED
        assert [len(g.master.log) for g in c.shards] == lens  # no re-apply

    def test_conflicting_concurrent_txn_aborts(self):
        """B's prepare hits A's undecided intent lock -> B votes NO and
        aborts; A then commits untouched."""
        c = ShardedCluster(n_shards=2, f=3)
        ca, cb = c.new_client(), c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        spec_a = ca.txn_spec([(k0, "a0"), (k1, "a1")])
        for p in spec_a.parts:   # A prepares everywhere, doesn't decide yet
            vote = c.shards[p.shard_id].txn_prepare(
                ca.session_for(p.shard_id), prepare_op(spec_a, p))
            assert vote.granted
        out_b = c.txn(cb, writes=[(k0, "b0"), (k1, "b1")])
        assert out_b.status is TxnStatus.ABORTED
        assert out_b.abort_reason == "TXN_LOCKED"
        # finish A
        from repro.core.txn import commit_op

        for p in spec_a.parts:
            c.shards[p.shard_id].txn_decide(
                commit_op(spec_a, p), ca.session_for(p.shard_id))
        assert c.read(ca, ca.op_get(k0)).value == "a0"
        assert c.read(ca, ca.op_get(k1)).value == "a1"

    def test_regular_op_blocked_then_resolved(self):
        """A plain SET on an intent-locked key trips TXN_PENDING; the
        cluster resolves the orphan (abort: not all prepared) and retries."""
        c = ShardedCluster(n_shards=2, f=3)
        ca, cb = c.new_client(), c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        spec = ca.txn_spec([(k0, "x"), (k1, "y")])
        p0 = spec.parts[0]
        assert c.shards[p0.shard_id].txn_prepare(
            ca.session_for(p0.shard_id), prepare_op(spec, p0)).granted
        locked = p0.write_kvs[0][0]
        out = c.update(cb, cb.op_set(locked, "after"))
        assert out.value == "OK"
        assert c.read(cb, cb.op_get(locked)).value == "after"
        assert participant_state(
            c.shards[p0.shard_id].master, spec, p0) == "aborted"

    def test_txn_pending_raised_without_resolution(self):
        """ShardGroup-level: the raw master path raises TxnPending with the
        blocking spec attached (the cluster layer is what resolves)."""
        c = ShardedCluster(n_shards=2, f=3)
        ca = c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        spec = ca.txn_spec([(k0, "x"), (k1, "y")])
        p0 = spec.parts[0]
        c.shards[p0.shard_id].txn_prepare(
            ca.session_for(p0.shard_id), prepare_op(spec, p0))
        locked = p0.write_kvs[0][0]
        sub = ca.session_for(p0.shard_id)
        with pytest.raises(TxnPending) as ei:
            c.shards[p0.shard_id].update(sub, sub.op_set(locked, "z"))
        assert ei.value.spec.txn_id == spec.txn_id


class TestTxnCrashStages:
    """Coordinator/participant crashes at every 2PC message stage: the
    strict checker passes and no intent leaks past recovery."""

    @pytest.mark.parametrize("stage", TXN_CRASH_STAGES)
    @pytest.mark.parametrize("participant_crash", [False, True])
    def test_stage_crash_atomic(self, stage, participant_crash):
        r = run_txn_crash_scenario(
            stage=stage, n_shards=3, n_txns=10,
            participant_crash=participant_crash, seed=5,
        )
        assert r.intents_after == 0, "intent leaked past recovery"
        assert r.history_ok, f"strict violation on {r.offending_key}"
        if stage == "prepare-sent":
            # Not every leg prepared: resolution must abort.
            assert r.crashed_decision == "ABORTED"
        else:
            # Every leg prepared (decision possibly already partially
            # applied): resolution must commit.
            assert r.crashed_decision == "COMMITTED"

    def test_commit_sent_final_state_complete(self):
        """Crash after the first COMMIT leg: resolution re-commits the rest,
        so every write of the crashed txn is visible."""
        r = run_txn_crash_scenario(stage="commit-sent", n_shards=3,
                                   n_txns=8, seed=2)
        assert r.crashed_decision == "COMMITTED"
        assert r.history_ok and r.intents_after == 0

    def test_prepare_sent_no_partial_write(self):
        """Crash after the first PREPARE: resolution aborts; none of the
        crashed txn's writes may be visible (no torn write)."""
        r = run_txn_crash_scenario(stage="prepare-sent", n_shards=3,
                                   n_txns=8, seed=4)
        assert r.crashed_decision == "ABORTED"
        assert r.history_ok and r.intents_after == 0


class TestTxnRecoveryRaces:
    def test_straggler_prepare_refused_after_abort_resolution(self):
        """The classic 2PC race: resolution aborts a half-prepared txn;
        a delayed PREPARE for the missing leg must be refused (tombstone),
        not re-open the transaction."""
        c = ShardedCluster(n_shards=2, f=3)
        cl = c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        spec = cl.txn_spec([(k0, "v0"), (k1, "v1")])
        p0, p1 = spec.parts
        assert c.shards[p0.shard_id].txn_prepare(
            cl.session_for(p0.shard_id), prepare_op(spec, p0)).granted
        assert resolve_txn(c, spec) is TxnStatus.ABORTED
        vote = c.shards[p1.shard_id].txn_prepare(
            cl.session_for(p1.shard_id), prepare_op(spec, p1))
        assert not vote.granted and vote.error == "TXN_DECIDED"
        assert c.read(cl, cl.op_get(k0)).value is None
        assert c.read(cl, cl.op_get(k1)).value is None

    def test_participant_crash_resurfaces_intent_and_resolves(self):
        """A participant master dies holding a prepared intent: backup
        restore + witness replay re-surface it; recovery resolves it
        cluster-wide (commit: all legs were prepared)."""
        c = ShardedCluster(n_shards=2, f=3, sync_batch=1000, auto_sync=False)
        cl = c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)

        def crash_before_decide(stage, shard_id, idx):
            if stage == "decide" and idx == 0:
                raise CoordinatorCrash()

        with pytest.raises(CoordinatorCrash):
            c.txn(cl, writes=[(k0, "x"), (k1, "y")],
                  on_message=crash_before_decide)
        victim = c.router.shard_of(k0)
        assert c.shards[victim].master.store.txn_intents()
        rep = c.crash_master(victim)
        assert rep.txn_intents == 1          # intent survived into recovery
        assert rep.txn_resolved == 1 and rep.txn_committed == 1
        assert c.read(cl, cl.op_get(k0)).value == "x"
        assert c.read(cl, cl.op_get(k1)).value == "y"
        assert not any(g.master.store.txn_intents() for g in c.shards)

    def test_abort_tombstone_survives_master_crash(self):
        """The decision tombstone (RIFL record under decide_rpc) must be
        durable across a participant failover once synced."""
        c = ShardedCluster(n_shards=2, f=3)
        cl = c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        spec = cl.txn_spec([(k0, "v0"), (k1, "v1")])
        p0, p1 = spec.parts
        c.shards[p0.shard_id].txn_prepare(
            cl.session_for(p0.shard_id), prepare_op(spec, p0))
        resolve_txn(c, spec)                 # aborts + tombstones both legs
        c.sync_all()
        c.crash_master(p1.shard_id)
        vote = c.shards[p1.shard_id].txn_prepare(
            cl.session_for(p1.shard_id), prepare_op(spec, p1))
        assert not vote.granted and vote.error == "TXN_DECIDED"

    def test_history_strict_linearizable_through_crash_and_recovery(self):
        c = ShardedCluster(n_shards=3, f=3)
        cl = c.new_client()
        keys = {s: key_on_shard(c.router, s, "h") for s in range(3)}

        def crash_mid_decide(stage, shard_id, idx):
            if stage == "decide" and idx == 1:
                raise CoordinatorCrash()

        c.txn(cl, writes=[(keys[0], "a"), (keys[1], "b")])
        with pytest.raises(CoordinatorCrash):
            c.txn(cl, writes=[(keys[1], "c"), (keys[2], "d")],
                  on_message=crash_mid_decide)
        c.crash_master(1)
        for k in keys.values():
            c.read(cl, cl.op_get(k))
        ok, key = check_linearizable_strict(c.history)
        assert ok, f"violation on {key}"


class TestWitnessIntentTombstones:
    def test_prepare_records_conflict_with_overlapping_keys(self):
        """A recorded PREPARE occupies its keys at the witness: an
        overlapping single-key record must be rejected until gc (the
        'tombstoned intent' that keeps commutativity checks sound)."""
        from repro.core.types import Op, OpType, RecordStatus, keyhash

        c = ShardedCluster(n_shards=2, f=3, sync_batch=1000, auto_sync=False)
        cl = c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        spec = cl.txn_spec([(k0, "x"), (k1, "y")])
        p0 = spec.parts[0]
        c.shards[p0.shard_id].txn_prepare(
            cl.session_for(p0.shard_id), prepare_op(spec, p0))
        w: Witness = c.shards[p0.shard_id].witnesses[0]
        probe = Op(OpType.SET, (k0,), ("z",), (4242, 1))
        st = w.record(c.config.fetch(p0.shard_id).master_id,
                      probe.key_hashes(), probe.rpc_id, probe)
        assert st is RecordStatus.REJECTED
        assert not w.commutes_with_all((keyhash(k0),))

    def test_prepare_witness_records_gcd_after_sync(self):
        """Once the prepare is synced to backups its witness records are
        collected — capacity is returned even before the decision."""
        c = ShardedCluster(n_shards=2, f=3)
        cl = c.new_client()
        k0 = key_on_shard(c.router, 0)
        k1 = key_on_shard(c.router, 1)
        spec = cl.txn_spec([(k0, "x"), (k1, "y")])
        p0 = spec.parts[0]
        c.shards[p0.shard_id].txn_prepare(
            cl.session_for(p0.shard_id), prepare_op(spec, p0))
        occ_before = c.shards[p0.shard_id].witnesses[0].occupancy
        assert occ_before >= 1
        c.shards[p0.shard_id].sync_now()
        assert c.shards[p0.shard_id].witnesses[0].occupancy == 0
        # the intent itself is still there (undecided), now backup-durable
        assert c.shards[p0.shard_id].master.store.txn_intent(spec.txn_id)
        c.shards[p0.shard_id].txn_decide(
            abort_op(spec, p0), cl.session_for(p0.shard_id))


class TestServingAtomicCommit:
    def test_store_txn_atomic_group_commit(self):
        from repro.serving.kvstore import CurpSessionStore, SessionState

        store = CurpSessionStore(f=3, sync_batch=8, n_shards=4)
        group = [SessionState(f"g{i}", [1, i]) for i in range(6)]
        out = store.txn(group)
        assert out.status is TxnStatus.COMMITTED
        shards = {store.shard_of(s.session_id) for s in group}
        assert out.n_shards == len(shards) >= 2
        for s in group:
            st = store.load(s.session_id)
            assert st is not None and st.tokens == s.tokens

    def test_store_txn_survives_full_crash(self):
        from repro.serving.kvstore import CurpSessionStore, SessionState

        store = CurpSessionStore(f=3, sync_batch=1000, n_shards=2)
        store.txn([SessionState(f"c{i}", [i]) for i in range(4)])
        store.crash_and_recover()
        for i in range(4):
            st = store.load(f"c{i}")
            assert st is not None and st.tokens == [i]

    def test_store_txn_empty_group_noop(self):
        from repro.serving.kvstore import CurpSessionStore

        store = CurpSessionStore(f=3, n_shards=2)
        out = store.txn([])
        assert out.status is TxnStatus.COMMITTED and out.n_shards == 0
