"""Fast-path pipeline tests: set-parallel kernel parity on adversarial
batches, the fused fastpath_batch op, buffer-donation round-trips, and the
batched client path (update_batch / commit_batch) on both witness backends.

Property tests go through the _hyp shim (skips cleanly without hypothesis);
each has a deterministic companion so the invariants stay covered either way.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import (
    DeviceWitness,
    ShardedCluster,
    Witness,
    WitnessGeometry,
)
from repro.core.types import RecordStatus
from repro.kernels import (
    WitnessTable,
    dispatch_count,
    fastpath_batch,
    ref_conflict_scan,
    ref_keyhash2x32,
    ref_witness_record,
    reset_dispatch_count,
    witness_gc,
    witness_record,
    witness_record_seq,
)


def rng(seed=0):
    return np.random.default_rng(seed)


def assert_tables_equal(a: WitnessTable, b: WitnessTable):
    np.testing.assert_array_equal(np.asarray(a.occ), np.asarray(b.occ))
    np.testing.assert_array_equal(np.asarray(a.keys_hi), np.asarray(b.keys_hi))
    np.testing.assert_array_equal(np.asarray(a.keys_lo), np.asarray(b.keys_lo))


class TestSetParallelParity:
    """The set-parallel kernel is bit-exact with ref_witness_record."""

    @pytest.mark.parametrize("sets,ways,batch,kspan,span", [
        (16, 2, 200, 4, 8),          # duplicate keys, tiny keyspace
        (16, 4, 300, 6, 4),          # capacity-full sets
        (64, 4, 512, 2**32 - 1, 64),  # every set overcommitted
        (1024, 4, 1000, 2**32 - 1, 2**32 - 1),
        (128, 2, 127, 3, 3),         # odd batch (bucket-padding path)
    ])
    def test_collision_heavy_matches_oracle(self, sets, ways, batch,
                                            kspan, span):
        r = rng(sets + batch)
        t = WitnessTable.empty(sets, ways)
        qh = r.integers(0, kspan, batch).astype(np.uint32)
        ql = r.integers(0, span, batch).astype(np.uint32)
        acc_k, t_k = witness_record(t, qh, ql)
        acc_r, t_r = ref_witness_record(t, jnp.asarray(qh), jnp.asarray(ql))
        np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_r))
        assert_tables_equal(t_k, t_r)
        # ... and with the pre-refactor sequential kernel.
        acc_s, t_s = witness_record_seq(t, qh, ql)
        np.testing.assert_array_equal(np.asarray(acc_s), np.asarray(acc_r))
        assert_tables_equal(t_s, t_r)

    def test_duplicate_keys_single_batch(self):
        """Same key B times in one batch: exactly one accept (the first)."""
        t = WitnessTable.empty(16, 4)
        qh = np.full(9, 7, np.uint32)
        ql = np.full(9, 3, np.uint32)
        acc, t2 = witness_record(t, qh, ql)
        assert np.asarray(acc).tolist() == [1] + [0] * 8
        assert int(np.asarray(t2.occ).sum()) == 1

    def test_full_set_capacity_rejects(self):
        """W+k distinct keys probing one set: exactly W accepts, in order."""
        t = WitnessTable.empty(16, 4)
        S = 16
        qh = np.arange(7, dtype=np.uint32)           # distinct keys
        ql = np.full(7, 5, np.uint32)                # same set (5 & 15)
        acc, t2 = witness_record(t, qh, ql)
        assert np.asarray(acc).tolist() == [1, 1, 1, 1, 0, 0, 0]
        assert int(np.asarray(t2.occ)[5].sum()) == 4

    def test_cross_set_permutation_invariance(self):
        """Permuting ops of OTHER sets never changes an op's accept bit —
        the set-level independence the kernel parallelizes over."""
        r = rng(3)
        S, B = 16, 240
        t = WitnessTable.empty(S, 4)
        qh = r.integers(0, 6, B).astype(np.uint32)
        ql = r.integers(0, 64, B).astype(np.uint32)
        acc0, t0 = witness_record(t, qh, ql)
        sets = ql & (S - 1)
        # Stable-sort by set id: reorders across sets, preserves order within.
        perm = np.argsort(sets, kind="stable")
        acc1, t1 = witness_record(t, qh[perm], ql[perm])
        np.testing.assert_array_equal(np.asarray(acc0)[perm],
                                      np.asarray(acc1))
        assert_tables_equal(t0, t1)

    @pytest.mark.parametrize("tile_sets,sets", [(64, 256), (32, 128)])
    def test_multi_cell_grid_matches_oracle(self, tile_sets, sets):
        """Grids with several set-tiles (tile_sets < n_sets): the per-tile
        masking + accumulate-on-revisit accept vector must stay bit-exact."""
        r = rng(tile_sets + sets)
        t = WitnessTable.empty(sets, 4)
        qh = r.integers(0, 16, 600).astype(np.uint32)
        ql = r.integers(0, sets * 5, 600).astype(np.uint32)
        acc_k, t_k = witness_record(t, qh, ql, tile_sets=tile_sets)
        acc_r, t_r = ref_witness_record(t, jnp.asarray(qh), jnp.asarray(ql))
        np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_r))
        assert_tables_equal(t_k, t_r)

    def test_non_dividing_tile_rejected(self):
        t = WitnessTable.empty(256, 4)
        with pytest.raises(AssertionError):
            witness_record(t, np.zeros(4, np.uint32), np.zeros(4, np.uint32),
                           tile_sets=96)

    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 10_000), sets=st.sampled_from([16, 64, 256]),
           ways=st.sampled_from([2, 4, 8]), batch=st.integers(1, 300),
           kspan=st.sampled_from([2, 5, 2**32 - 1]))
    def test_property_matches_oracle(self, seed, sets, ways, batch, kspan):
        r = rng(seed)
        t = WitnessTable.empty(sets, ways)
        qh = r.integers(0, kspan, batch).astype(np.uint32)
        ql = r.integers(0, max(2, sets * 3), batch).astype(np.uint32)
        acc_k, t_k = witness_record(t, qh, ql)
        acc_r, t_r = ref_witness_record(t, jnp.asarray(qh), jnp.asarray(ql))
        np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_r))
        assert_tables_equal(t_k, t_r)

    @settings(deadline=None, max_examples=15)
    @given(seed=st.integers(0, 10_000))
    def test_property_permutation_invariance(self, seed):
        r = rng(seed)
        S, B = 32, 100
        t = WitnessTable.empty(S, 2)
        qh = r.integers(0, 4, B).astype(np.uint32)
        ql = r.integers(0, 128, B).astype(np.uint32)
        acc0, _ = witness_record(t, qh, ql)
        perm = np.argsort(ql & (S - 1), kind="stable")
        acc1, _ = witness_record(t, qh[perm], ql[perm])
        np.testing.assert_array_equal(np.asarray(acc0)[perm],
                                      np.asarray(acc1))


class TestGcDonationRoundTrip:
    def test_record_gc_record_no_stale_occupancy(self):
        """record -> gc -> record round-trips: gc leaves no stale occupancy
        and a full re-record of the same keys is accepted again."""
        r = rng(9)
        t = WitnessTable.empty(64, 4)
        qh = r.integers(0, 2**32, 120).astype(np.uint32)
        ql = np.arange(120, dtype=np.uint32)       # distinct sets mod 64? no:
        acc1, t = witness_record(t, qh, ql)        # 2 rounds over 64 sets
        occupied = int(np.asarray(t.occ).sum())
        assert occupied == int(np.asarray(acc1).sum()) > 0
        t = witness_gc(t, qh, ql)
        assert int(np.asarray(t.occ).sum()) == 0   # no stale occupancy
        acc2, t = witness_record(t, qh, ql)
        np.testing.assert_array_equal(np.asarray(acc2), np.asarray(acc1))

    def test_gc_then_accept_chain_reuses_table(self):
        """Functional chain that rebinds the table each call (the donation
        pattern): many record/gc cycles stay self-consistent."""
        t = WitnessTable.empty(16, 2)
        qh = np.array([5, 6, 7], np.uint32)
        ql = np.array([1, 2, 3], np.uint32)
        for _ in range(5):
            acc, t = witness_record(t, qh, ql)
            assert np.asarray(acc).tolist() == [1, 1, 1]
            t = witness_gc(t, qh, ql)
        assert int(np.asarray(t.occ).sum()) == 0


class TestFusedFastPath:
    def test_single_dispatch_per_batch(self):
        t = WitnessTable.empty(64, 4)
        r = rng(1)
        khi = r.integers(0, 2**32, 33).astype(np.uint32)
        klo = r.integers(0, 2**32, 33).astype(np.uint32)
        fastpath_batch(t, khi, klo)            # warm
        reset_dispatch_count()
        fastpath_batch(t, khi, klo)
        assert dispatch_count() == 1
        reset_dispatch_count()

    def test_matches_unfused_pipeline(self):
        """fastpath_batch == keyhash2x32 -> record -> conflict_scan, bit for
        bit, including shard routing."""
        r = rng(5)
        t = WitnessTable.empty(128, 4)
        khi = r.integers(0, 2**32, 70).astype(np.uint32)
        klo = r.integers(0, 2**32, 70).astype(np.uint32)
        res = fastpath_batch(t, khi, klo, n_shards=4)
        qh, ql = ref_keyhash2x32(jnp.asarray(khi), jnp.asarray(klo))
        acc_r, t_r = ref_witness_record(t, qh, ql)
        np.testing.assert_array_equal(np.asarray(res.accepted),
                                      np.asarray(acc_r))
        assert_tables_equal(res.table, t_r)
        np.testing.assert_array_equal(
            np.asarray(res.shard_ids),
            np.asarray((ql % jnp.uint32(4)).astype(jnp.int32)))
        # Window conflicts against previously recorded mixed lanes.
        wv = np.ones(10, np.int32)
        res2 = fastpath_batch(res.table, khi[:20], klo[:20],
                              window_hi=res.q_hi[:10],
                              window_lo=res.q_lo[:10], window_valid=wv)
        con_r = ref_conflict_scan(res.q_hi[:10], res.q_lo[:10],
                                  jnp.asarray(wv), qh[:20], ql[:20])
        np.testing.assert_array_equal(np.asarray(res2.conflicts),
                                      np.asarray(con_r))

    def test_window_valid_defaults_to_all_live(self):
        """window_valid omitted => every window entry counts; partial window
        specs fail loudly instead of deep in jnp."""
        r = rng(8)
        t = WitnessTable.empty(64, 4)
        khi = r.integers(0, 2**32, 12).astype(np.uint32)
        klo = r.integers(0, 2**32, 12).astype(np.uint32)
        res = fastpath_batch(t, khi, klo)
        res2 = fastpath_batch(res.table, khi[:6], klo[:6],
                              window_hi=res.q_hi[:4], window_lo=res.q_lo[:4])
        con_r = ref_conflict_scan(
            res.q_hi[:4], res.q_lo[:4], jnp.ones(4, jnp.int32),
            res.q_hi[:6], res.q_lo[:6])
        np.testing.assert_array_equal(np.asarray(res2.conflicts),
                                      np.asarray(con_r))
        with pytest.raises(ValueError):
            fastpath_batch(t, khi, klo, window_hi=res.q_hi[:4])
        with pytest.raises(ValueError):
            fastpath_batch(t, khi, klo, window_lo=res.q_lo[:4])

    def test_shard_route_matches_key_router(self):
        from repro.core.shard import KeyRouter
        from repro.core.types import keyhash

        keys = [f"s{i}" for i in range(64)]
        khs = [keyhash(k) for k in keys]
        hi = np.array([(h >> 32) & 0xFFFFFFFF for h in khs], np.uint32)
        lo = np.array([h & 0xFFFFFFFF for h in khs], np.uint32)
        res = fastpath_batch(WitnessTable.empty(64, 4), hi, lo, n_shards=3)
        router = KeyRouter(3)
        np.testing.assert_array_equal(
            np.asarray(res.shard_ids),
            np.array([router.shard_of(k) for k in keys]))


class TestTxnProbe:
    """All-or-nothing multi-key record: one dispatch on accept AND reject."""

    def _oracle(self, table, hi, lo, own=None):
        from repro.kernels import ref_witness_record_txn
        from repro.kernels.ops import _pad_valid

        (K,) = np.asarray(hi).shape
        qh, ql = ref_keyhash2x32(jnp.asarray(hi, jnp.uint32),
                                 jnp.asarray(lo, jnp.uint32))
        own = np.zeros(K, np.int32) if own is None else np.asarray(own)
        qhp, qlp, ownp, valid = _pad_valid(K, np.asarray(qh), np.asarray(ql),
                                           own)
        return ref_witness_record_txn(
            table, jnp.asarray(qhp), jnp.asarray(qlp), jnp.asarray(ownp),
            jnp.asarray(valid))

    def test_accept_and_reject_single_dispatch(self):
        from repro.kernels import txn_probe

        t = WitnessTable.empty(16, 2)
        hi = np.array([1, 2, 3], np.uint32)
        lo = np.array([1, 2, 3], np.uint32)
        txn_probe(t, hi, lo)            # warm the jit cache
        reset_dispatch_count()
        res = txn_probe(t, hi, lo)
        assert res.accepted and dispatch_count() == 1
        reset_dispatch_count()
        # Conflict: same keys again (different op) — rejects, still 1 call.
        res2 = txn_probe(res.table, hi, lo)
        assert not res2.accepted and dispatch_count() == 1
        reset_dispatch_count()

    def test_reject_leaves_table_bit_identical(self):
        from repro.kernels import txn_probe

        r = rng(4)
        t = WitnessTable.empty(16, 2)
        res = txn_probe(t, np.array([7], np.uint32), np.array([7], np.uint32))
        t = res.table
        # Op with one fresh key and one conflicting key: must reject and
        # leave the table untouched (no partial insert, no rollback).
        res2 = txn_probe(t, np.array([5, 7], np.uint32),
                         np.array([5, 7], np.uint32))
        assert not res2.accepted
        assert_tables_equal(res2.table, t)

    @pytest.mark.parametrize("sets,ways,kspan", [
        (8, 2, 4), (16, 4, 6), (64, 4, 3),
    ])
    def test_matches_oracle_collision_heavy(self, sets, ways, kspan):
        from repro.kernels import txn_probe

        r = rng(sets + ways)
        table = WitnessTable.empty(sets, ways)
        oracle = WitnessTable.empty(sets, ways)
        for i in range(80):
            K = int(r.integers(1, 7))
            hi = r.integers(0, kspan, K).astype(np.uint32)
            lo = r.integers(0, kspan, K).astype(np.uint32)
            res = txn_probe(table, hi, lo)
            acc_r, hit_r, oracle = self._oracle(oracle, hi, lo)
            assert res.accepted == bool(np.asarray(acc_r)[0]), i
            np.testing.assert_array_equal(np.asarray(res.hit),
                                          np.asarray(hit_r)[:K])
            table = res.table
            assert_tables_equal(table, oracle)

    def test_own_bit_makes_retry_idempotent(self):
        from repro.kernels import txn_probe

        t = WitnessTable.empty(16, 4)
        hi = np.array([3, 4], np.uint32)
        lo = np.array([3, 4], np.uint32)
        res = txn_probe(t, hi, lo)
        assert res.accepted
        # Same op retried without own bits: same-key hits -> conflict.
        res2 = txn_probe(res.table, hi, lo)
        assert not res2.accepted
        # With own bits (the caller knows these are its keys): accepted,
        # table unchanged (keys already placed).
        res3 = txn_probe(res.table, hi, lo, own=np.array([1, 1], np.int32))
        assert res3.accepted
        assert np.asarray(res3.hit).tolist() == [1, 1]
        assert_tables_equal(res3.table, res.table)

    def test_capacity_reject_all_or_nothing(self):
        from repro.kernels import txn_probe

        t = WitnessTable.empty(1, 2)    # one set, two ways
        # Fill both ways with two separate single-key ops (keys of ONE op
        # compute placement against the pre-op state — Python Witness
        # semantics — so one 2-key op would land in a single way).
        for k in (1, 2):
            res = txn_probe(t, np.array([k], np.uint32),
                            np.array([k], np.uint32))
            assert res.accepted
            t = res.table
        assert int(np.asarray(t.occ).sum()) == 2
        res2 = txn_probe(t, np.array([9, 10], np.uint32),
                         np.array([9, 10], np.uint32))
        assert not res2.accepted        # capacity: whole op rejected
        assert_tables_equal(res2.table, t)

    def test_device_witness_multikey_one_dispatch_no_rollback(self):
        """DeviceWitness multi-key records go through the probe: 1 kernel
        dispatch whether the op accepts or rejects (the old path paid 2 on
        reject), with statuses identical to the rollback implementation."""
        from repro.core import DeviceWitness
        from repro.core.types import Op, OpType

        def fresh():
            w = DeviceWitness(64, 4)
            w.start(1)
            w.record(1, (7,), (1, 1), Op(OpType.SET, ("x",), (0,), (1, 1)))
            return w

        reject_op = Op(OpType.MSET, ("a", "b"), (1, 2), (2, 1))
        w = fresh()
        reset_dispatch_count()
        st = w._record_keys((5, 7), reject_op.rpc_id, reject_op)
        assert dispatch_count() == 1
        w2 = fresh()
        reset_dispatch_count()
        st2 = w2._record_keys_rollback((5, 7), reject_op.rpc_id, reject_op)
        assert dispatch_count() == 2
        assert st == st2
        # Mirror and stats agree with the Python reference on the reject.
        assert w.stats["rejects_conflict"] == 1
        assert w.occupancy == w2.occupancy == 1


class TestDeviceWitness:
    def test_matches_python_witness_semantics(self):
        from repro.core.client import ClientSession

        s = ClientSession(client_id=1)
        ops = [s.op_set(f"k{i % 5}", "v") for i in range(20)]
        pw = Witness(64, 4)
        dw = DeviceWitness(64, 4)
        pw.start(master_id=9)
        dw.start(master_id=9)
        st_p = pw.record_batch(9, ops)
        st_d = dw.record_batch(9, ops)
        assert st_p == st_d
        assert pw.occupancy == dw.occupancy == 5

    def test_duplicate_retry_idempotent_accept(self):
        from repro.core.client import ClientSession

        s = ClientSession(client_id=2)
        op = s.op_set("x", "v")
        dw = DeviceWitness(16, 2)
        dw.start(master_id=1)
        assert dw.record(1, op.key_hashes(), op.rpc_id, op) \
            is RecordStatus.ACCEPTED
        # Same rpc retry: idempotent accept; different rpc: conflict.
        assert dw.record(1, op.key_hashes(), op.rpc_id, op) \
            is RecordStatus.ACCEPTED
        op2 = s.op_set("x", "w")
        assert dw.record(1, op2.key_hashes(), op2.rpc_id, op2) \
            is RecordStatus.REJECTED

    def test_stale_gc_never_drops_newer_record(self):
        from repro.core.client import ClientSession

        s = ClientSession(client_id=3)
        op1 = s.op_set("k", "a")
        dw = DeviceWitness(16, 2)
        dw.start(master_id=1)
        dw.record(1, op1.key_hashes(), op1.rpc_id, op1)
        dw.gc(tuple((kh, op1.rpc_id) for kh in op1.key_hashes()))
        op2 = s.op_set("k", "b")
        assert dw.record(1, op2.key_hashes(), op2.rpc_id, op2) \
            is RecordStatus.ACCEPTED
        # gc carrying op1's (stale) rpc must NOT drop op2's record.
        dw.gc(tuple((kh, op1.rpc_id) for kh in op1.key_hashes()))
        assert dw.occupancy == 1
        assert not dw.commutes_with_all(op2.key_hashes())

    def test_mixed_batch_preserves_order_vs_python(self):
        """A batch interleaving multi-key and single-key ops must resolve in
        batch order on both backends (regression: the device path used to
        record all single-key ops first)."""
        from repro.core.client import ClientSession

        s = ClientSession(client_id=7)
        ops = [
            s.op_mset([("a", "1"), ("b", "2")]),   # takes a+b
            s.op_set("a", "3"),                    # conflicts with the mset
            s.op_set("c", "4"),
            s.op_mset([("c", "5"), ("d", "6")]),   # conflicts on c
            s.op_set("d", "7"),                    # d is free (mset rolled back)
        ]
        pw, dw = Witness(64, 4), DeviceWitness(64, 4)
        pw.start(master_id=1)
        dw.start(master_id=1)
        st_p = pw.record_batch(1, ops)
        st_d = dw.record_batch(1, ops)
        assert st_d == st_p
        assert st_p == [RecordStatus.ACCEPTED, RecordStatus.REJECTED,
                        RecordStatus.ACCEPTED, RecordStatus.REJECTED,
                        RecordStatus.ACCEPTED]

    def test_repeated_key_within_one_op_accepted(self):
        """An op listing the same key twice occupies one slot and is
        accepted — parity with the Python witness (regression)."""
        from repro.core.client import ClientSession

        s = ClientSession(client_id=11)
        op = s.op_mset([("a", "1"), ("a", "2")])
        for w in (Witness(64, 4), DeviceWitness(64, 4)):
            w.start(master_id=1)
            assert w.record(1, op.key_hashes(), op.rpc_id, op) \
                is RecordStatus.ACCEPTED
            assert w.occupancy == 1

    def test_multikey_retry_after_partial_gc_accepted(self):
        """Retrying an accepted multi-key op after one of its keys was gc'd:
        the still-held key is an idempotent hit, the gc'd key re-inserts —
        ACCEPTED on both backends (regression)."""
        from repro.core.client import ClientSession

        s = ClientSession(client_id=12)
        op = s.op_mset([("p", "1"), ("q", "2")])
        kh_p = op.key_hashes()[0]
        for w in (Witness(64, 4), DeviceWitness(64, 4)):
            w.start(master_id=1)
            assert w.record(1, op.key_hashes(), op.rpc_id, op) \
                is RecordStatus.ACCEPTED
            w.gc(((kh_p, op.rpc_id),))           # drop only key p
            assert w.record(1, op.key_hashes(), op.rpc_id, op) \
                is RecordStatus.ACCEPTED
            assert w.occupancy == 2

    def test_record_batch_wrong_master_rejected(self):
        """record_batch addressed to the wrong master must reject everything
        (same guard as the per-op path)."""
        from repro.core.client import ClientSession

        s = ClientSession(client_id=8)
        ops = [s.op_set("x", "v")]
        for w in (Witness(16, 2), DeviceWitness(16, 2)):
            w.start(master_id=42)
            assert w.record_batch(99, ops) == [RecordStatus.REJECTED]
            assert w.record_batch(42, ops) == [RecordStatus.ACCEPTED]

    def test_recovery_data_and_suspects(self):
        from repro.core.client import ClientSession

        s = ClientSession(client_id=4)
        ops = [s.op_set(f"r{i}", "v") for i in range(4)]
        dw = DeviceWitness(64, 4)
        dw.start(master_id=1)
        dw.record_batch(1, ops)
        # Age past SUSPECT_AGE with unrelated gcs -> stale reports.
        stale = ()
        for _ in range(DeviceWitness.SUSPECT_AGE):
            stale = dw.gc(()).stale_requests
        assert {o.rpc_id for o in stale} == {o.rpc_id for o in ops}
        rec = dw.get_recovery_data(1)
        assert {o.rpc_id for o in rec} == {o.rpc_id for o in ops}
        # Frozen after recovery handoff.
        op = s.op_set("z", "v")
        assert dw.record(1, op.key_hashes(), op.rpc_id, op) \
            is RecordStatus.REJECTED


class TestBatchedClientPath:
    @pytest.mark.parametrize("backend", ["python", "device"])
    def test_update_batch_accounting(self, backend):
        c = ShardedCluster(n_shards=2, f=3, witness_backend=backend,
                           geometry=WitnessGeometry(256, 4))
        s = c.new_client()
        ops = [s.op_set(f"k{i}", "v") for i in range(30)]
        outs = c.update_batch(s, ops)
        assert len(outs) == 30
        assert all(o.fast_path and o.rtts == 1 for o in outs)
        assert all(o.witness_accepts == 3 for o in outs)

    @pytest.mark.parametrize("backend", ["python", "device"])
    def test_update_batch_same_key_conflicts(self, backend):
        c = ShardedCluster(n_shards=1, f=3, witness_backend=backend)
        s = c.new_client()
        ops = [s.op_set("dup", "a"), s.op_set("dup", "b"),
               s.op_set("other", "c")]
        outs = c.update_batch(s, ops)
        assert [o.fast_path for o in outs] == [True, False, True]
        assert [o.rtts for o in outs] == [1, 2, 1]

    @pytest.mark.parametrize("backend", ["python", "device"])
    def test_update_batch_then_crash_recovers(self, backend):
        c = ShardedCluster(n_shards=2, f=3, witness_backend=backend,
                           auto_sync=False)
        s = c.new_client()
        c.update_batch(s, [s.op_set(f"k{i}", f"v{i}") for i in range(12)])
        for shard in range(2):
            c.crash_master(shard)
        for i in range(12):
            assert c.read(s, s.op_get(f"k{i}")).value == f"v{i}"

    def test_batch_matches_per_op_decisions(self):
        """Batched and per-op paths agree on fast/slow classification for a
        conflict-free workload (same keys, fresh clusters)."""
        keys = [f"q{i}" for i in range(20)]
        c1 = ShardedCluster(n_shards=2, f=3)
        s1 = c1.new_client()
        per_op = [c1.update(s1, s1.op_set(k, "v")).fast_path for k in keys]
        c2 = ShardedCluster(n_shards=2, f=3)
        s2 = c2.new_client()
        batched = [o.fast_path for o in
                   c2.update_batch(s2, [s2.op_set(k, "v") for k in keys])]
        assert per_op == batched

    def test_dropped_witness_forces_slow_path(self):
        c = ShardedCluster(n_shards=1, f=3)
        s = c.new_client()
        c.shards[0].witness_drop(0)
        outs = c.update_batch(s, [s.op_set("a", "1"), s.op_set("b", "2")])
        assert all(not o.fast_path and o.rtts == 2 for o in outs)
        assert all(o.witness_accepts == 2 for o in outs)

    def test_update_batch_rejects_cross_shard_op(self):
        c = ShardedCluster(n_shards=4, f=1)
        s = c.new_client()
        kvs = [("a", "1"), ("b", "2"), ("c", "3"), ("d", "4")]
        op = s.session_for(0).op_mset(kvs)
        with pytest.raises(ValueError):
            c.update_batch(s, [op])


class TestCommitBatch:
    @pytest.mark.parametrize("backend", ["python", "device"])
    def test_commit_batch_fast_and_recoverable(self, backend):
        from repro.serving.kvstore import CurpSessionStore, SessionState

        store = CurpSessionStore(n_shards=2, witness_backend=backend,
                                 geometry=WitnessGeometry(256, 4))
        states = [SessionState(f"s{i}", [1, 2, i]) for i in range(6)]
        store.commit_batch(states)
        assert store.fast_commits == 6 and store.slow_commits == 0
        # Second commit of each session is the one §4.4 slow commit (the
        # first update wasn't "recently updated" yet, so it stayed unsynced
        # and the re-commit conflicts); it arms the hot-key preemptive sync.
        for st_ in states:
            st_.tokens.append(9)
        store.commit_batch(states)
        assert store.fast_commits == 6 and store.slow_commits == 6
        # From the third commit on, every step stays on the 1-RTT path.
        for st_ in states:
            st_.tokens.append(11)
        store.commit_batch(states)
        assert store.fast_commits == 12 and store.slow_commits == 6
        assert sum(store.per_shard_commits()) == 18
        store.crash_and_recover()
        for i in range(6):
            got = store.load(f"s{i}")
            assert got is not None and got.tokens == [1, 2, i, 9, 11]

    def test_commit_batch_empty_noop(self):
        from repro.serving.kvstore import CurpSessionStore

        store = CurpSessionStore()
        store.commit_batch([])
        assert store.fast_commits == 0 and store.slow_commits == 0


class TestGangKernelState:
    """Kernel-held RIFL/age state: dup and stale-gc verdicts resolve on
    device; the host mirror is a recovery-time view only."""

    def test_decisions_ignore_the_host_mirror(self):
        """Wiping the mirror must not change accept/dup/conflict verdicts —
        they come from the kernel's rpc lanes, not host state."""
        from repro.core.client import ClientSession

        s = ClientSession(client_id=21)
        op = s.op_set("k", "v")
        dw = DeviceWitness(64, 4)
        dw.start(master_id=1)
        assert dw.record(1, op.key_hashes(), op.rpc_id, op) \
            is RecordStatus.ACCEPTED
        dw._held.clear()                      # corrupt the recovery view
        assert dw.record(1, op.key_hashes(), op.rpc_id, op) \
            is RecordStatus.ACCEPTED          # in-kernel dup hit
        op2 = s.op_set("k", "w")
        assert dw.record(1, op2.key_hashes(), op2.rpc_id, op2) \
            is RecordStatus.REJECTED          # in-kernel conflict
        assert dw.stats["rejects_conflict"] == 1

    def test_stale_gc_suppressed_in_kernel(self):
        """A gc entry with a superseded rpc must not clear the slot even if
        the mirror has been wiped — suppression is in-kernel."""
        from repro.core.client import ClientSession

        s = ClientSession(client_id=22)
        op1 = s.op_set("k", "a")
        dw = DeviceWitness(16, 2)
        dw.start(master_id=1)
        dw.record(1, op1.key_hashes(), op1.rpc_id, op1)
        dw.gc(tuple((kh, op1.rpc_id) for kh in op1.key_hashes()))
        op2 = s.op_set("k", "b")
        dw.record(1, op2.key_hashes(), op2.rpc_id, op2)
        drops_before = dw.stats["gc_drops"]
        dw._held.clear()
        dw.gc(tuple((kh, op1.rpc_id) for kh in op1.key_hashes()))
        assert dw.stats["gc_drops"] == drops_before
        op3 = s.op_set("k", "c")
        assert dw.record(1, op3.key_hashes(), op3.rpc_id, op3) \
            is RecordStatus.REJECTED          # op2's record survived

    def test_recovery_data_matches_python_witness(self):
        """After the same record/gc history the device recovery set equals
        the Python witness's, and both freeze irreversibly."""
        from repro.core.client import ClientSession

        s = ClientSession(client_id=23)
        ops = [s.op_set(f"k{i % 6}", f"v{i}") for i in range(14)]
        ops.append(s.op_mset([("m1", "x"), ("m2", "y")]))
        pw, dw = Witness(64, 4), DeviceWitness(64, 4)
        pw.start(master_id=1)
        dw.start(master_id=1)
        assert pw.record_batch(1, ops) == dw.record_batch(1, ops)
        gc_entries = tuple(
            (kh, ops[0].rpc_id) for kh in ops[0].key_hashes()
        ) + tuple((kh, ops[3].rpc_id) for kh in ops[3].key_hashes())
        pw.gc(gc_entries)
        dw.gc(gc_entries)
        rec_p = {o.rpc_id for o in pw.get_recovery_data(1)}
        rec_d = {o.rpc_id for o in dw.get_recovery_data(1)}
        assert rec_p == rec_d
        late = s.op_set("late", "v")
        for w in (pw, dw):
            assert w.record(1, late.key_hashes(), late.rpc_id, late) \
                is RecordStatus.REJECTED      # RECOVERY mode is frozen

    def test_shared_gang_lane_isolation(self):
        """Witnesses stacked in one gang are independent tables: the same
        key records at every lane, and gc at one lane leaves the others."""
        from repro.core.client import ClientSession
        from repro.core.device_witness import WitnessGang, gc_many

        gang = WitnessGang(64, 4, n_lanes=2)
        w1 = DeviceWitness(64, 4, gang=gang)
        w2 = DeviceWitness(64, 4, gang=gang)
        w1.start(master_id=1)
        w2.start(master_id=1)
        s = ClientSession(client_id=24)
        op = s.op_set("shared", "v")
        assert w1.record(1, op.key_hashes(), op.rpc_id, op) \
            is RecordStatus.ACCEPTED
        assert w2.record(1, op.key_hashes(), op.rpc_id, op) \
            is RecordStatus.ACCEPTED
        w1.gc(tuple((kh, op.rpc_id) for kh in op.key_hashes()))
        assert w1.occupancy == 0 and w2.occupancy == 1
        op2 = s.op_set("shared", "w")
        assert w1.record(1, op2.key_hashes(), op2.rpc_id, op2) \
            is RecordStatus.ACCEPTED          # lane 1 slot was freed
        assert w2.record(1, op2.key_hashes(), op2.rpc_id, op2) \
            is RecordStatus.REJECTED          # lane 2 still holds op

    def test_gc_many_one_dispatch_matches_per_witness(self):
        """Stacked gc: one dispatch covers every witness of the gang, with
        per-witness results equal to individual gc calls."""
        from repro.core.client import ClientSession
        from repro.core.device_witness import WitnessGang, gc_many

        def build():
            gang = WitnessGang(64, 4, n_lanes=4)
            ws = [DeviceWitness(64, 4, gang=gang) for _ in range(3)]
            for w in ws:
                w.start(master_id=1)
            s = ClientSession(client_id=25)
            ops = [s.op_set(f"g{i}", "v") for i in range(8)]
            for w in ws:
                w.record_batch(1, ops)
            return ws, ops

        ws, ops = build()
        entries = tuple((kh, op.rpc_id) for op in ops[:4]
                        for kh in op.key_hashes())
        reset_dispatch_count()
        resps = gc_many(ws, entries)
        assert dispatch_count() == 1
        reset_dispatch_count()
        ws2, _ = build()
        resps2 = [w.gc(entries) for w in ws2]
        assert [r.stale_requests for r in resps] == \
            [r.stale_requests for r in resps2]
        assert [w.occupancy for w in ws] == [w.occupancy for w in ws2] \
            == [4, 4, 4]
        assert [w.stats["gc_drops"] for w in ws] == \
            [w.stats["gc_drops"] for w in ws2] == [4, 4, 4]

    def test_gang_record_one_dispatch_and_bounded_jit_cache(self):
        """Batches of any size are ONE dispatch, and bucket padding keeps
        the jit cache logarithmic in the largest batch seen."""
        from repro.core.client import ClientSession
        from repro.kernels.ops import _gang_record_impl

        s = ClientSession(client_id=26)
        sizes = [1, 2, 3, 5, 9, 17, 33, 64, 65, 100, 127, 128]
        cache_before = _gang_record_impl._cache_size()
        for n in sizes:
            dw = DeviceWitness(1024, 4)  # fresh table: no capacity carryover
            dw.start(master_id=1)
            ops = [s.op_set(f"c{n}_{i}", "v") for i in range(n)]
            reset_dispatch_count()
            st = dw.record_batch(1, ops)
            assert dispatch_count() == 1
            # A stray reject can only be a genuine 5-keys-in-one-set
            # capacity collision (covered by the parity tests above).
            assert st.count(RecordStatus.ACCEPTED) >= n - 4
        grown = _gang_record_impl._cache_size() - cache_before
        # Buckets are pow2 with a floor of 16: sizes up to 128 can hit at
        # most {16, 32, 64, 128} -> O(log B), not O(B).
        assert grown <= 4, f"jit cache grew by {grown} entries"


class TestFusedClusterBatch:
    """The fused multi-shard driver (core/fastbatch.py): one dispatch per
    routed batch, outcome parity with the Python backend, safe fallback."""

    def _mk(self, backend, **kw):
        kw.setdefault("geometry", WitnessGeometry(256, 4))
        c = ShardedCluster(n_shards=4, f=3, witness_backend=backend,
                           seed=7, **kw)
        return c, c.new_client()

    def test_cross_shard_batch_single_dispatch(self):
        c, s = self._mk("device")
        c.update_batch(s, [s.op_set(f"w{i}", "v") for i in range(8)])
        ops = [s.op_set(f"k{i}", "v") for i in range(16)]
        assert len({c.shard_of(op.keys[0]) for op in ops}) > 1
        reset_dispatch_count()
        outs = c.update_batch(s, ops)
        assert dispatch_count() == 1      # ONE dispatch, all shards
        reset_dispatch_count()
        assert all(o.fast_path and o.witness_accepts == 3 for o in outs)
        assert c._fused.stats["fused_batches"] == 2

    def test_single_shard_batch_single_dispatch(self):
        c, s = self._mk("device")
        keys = [f"s{i}" for i in range(200) if c.shard_of(f"s{i}") == 0][:8]
        c.update_batch(s, [s.op_set(k + "_warm", "v") for k in keys])
        reset_dispatch_count()
        c.update_batch(s, [s.op_set(k, "v") for k in keys])
        assert dispatch_count() == 1
        reset_dispatch_count()

    def test_outcomes_match_python_backend(self):
        """Same mixed workload (conflicts, deletes, increments, RIFL retry,
        drains) on both backends: per-op outcomes and master stats must be
        identical."""
        import random

        def drive(backend):
            c, s = self._mk(backend, sync_batch=10)
            rng_ = random.Random(5)
            seen = []
            out = []
            for r in range(6):
                ops = []
                for _ in range(12):
                    k = f"k{rng_.randrange(8)}"
                    ops.append(s.op_set(k, f"v{r}") if rng_.random() < .7
                               else s.op_incr(k))
                if seen and r == 4:
                    ops[0] = seen[0]          # RIFL retry of an old op
                seen.extend(ops)
                for o in c.update_batch(s, ops):
                    out.append((o.value, o.rtts, o.fast_path, o.synced_path,
                                o.witness_accepts))
            return c, out

        cd, od = drive("device")
        cp, op_ = drive("python")
        assert od == op_
        for sid in range(4):
            assert cd.shards[sid].master.stats == cp.shards[sid].master.stats
        assert cd._fused.stats["fused_ops"] > 0

    def test_ring_window_conflicts_match_host(self):
        """auto_sync=False keeps the unsynced window alive across batches:
        the device ring must flag the same conflicts the host dict would."""
        def drive(backend):
            c, s = self._mk(backend, auto_sync=False, sync_batch=1000)
            o1 = c.update_batch(s, [s.op_set("a", "1"), s.op_set("b", "2")])
            o2 = c.update_batch(s, [s.op_set("a", "3"), s.op_set("c", "4")])
            return [(o.fast_path, o.synced_path, o.rtts) for o in o1 + o2]

        assert drive("device") == drive("python")

    def test_multikey_op_declines_to_fallback(self):
        c = ShardedCluster(n_shards=1, f=3, witness_backend="device",
                           geometry=WitnessGeometry(256, 4))
        s = c.new_client()
        op = s.session_for(0).op_mset([("m1", "1"), ("m2", "2")])
        outs = c.update_batch(s, [op, s.op_set("plain", "3")])
        assert all(o.witness_accepts == 3 for o in outs)
        assert c._fused.stats["declined"] == 1
        assert c._fused.stats["fused_batches"] == 0
        # The NEXT all-plain batch fuses again (ring rebuilds from the log).
        outs2 = c.update_batch(s, [s.op_set("p2", "4")])
        assert outs2[0].fast_path
        assert c._fused.stats["fused_batches"] == 1

    def test_crash_recovery_invalidates_ring(self):
        """A master crash between fused batches must not leak stale ring
        state: replayed ops live in the new window, batches stay correct."""
        c, s = self._mk("device", auto_sync=False, sync_batch=1000)
        c.update_batch(s, [s.op_set(f"k{i}", f"v{i}") for i in range(12)])
        for sid in range(4):
            c.shards[sid].crash_master()
        outs = c.update_batch(s, [s.op_set(f"k{i}", "post") for i in range(12)])
        assert len(outs) == 12
        for i in range(12):
            assert c.read(s, s.op_get(f"k{i}")).value == "post"

    def test_fused_respects_dropped_witness(self):
        c, s = self._mk("device")
        c.shards[0].witness_drop(0)
        keys = [f"d{i}" for i in range(400) if c.shard_of(f"d{i}") == 0][:4]
        outs = c.update_batch(s, [s.op_set(k, "v") for k in keys])
        assert all(not o.fast_path and o.witness_accepts == 2 for o in outs)
        assert c._fused.stats["declined"] >= 1
