"""Live-reconfiguration tests (repro.core.migration): SlotRouter <->
shard_route parity on random slot maps, online slot handover under traffic,
donor/receiver crashes mid-handover, the §3.6 fences, duplicate RIFL retries
across a slot move, hot-shard auto-split, and the serving store's live
session migration."""
import numpy as np
import pytest

from repro.core import (
    ShardedCluster,
    SlotMoving,
    SlotRouter,
    plan_rebalance,
)
from repro.core.types import keyhash
from repro.sim import check_linearizable_strict, run_migration_scenario


def key_on_shard(router, shard: int, tag: str = "k") -> str:
    for i in range(10_000):
        k = f"{tag}{i}"
        if router.shard_of(k) == shard:
            return k
    raise AssertionError(f"no key found for shard {shard}")


# ------------------------------------------------------------------ router
class TestSlotRouter:
    def test_uniform_map_matches_legacy_mod_n(self):
        """For pow2 shard counts dividing n_slots, slot routing reproduces
        the pre-slot-map mod-N placement exactly."""
        from repro.core.shard import _M32, mix2x32

        for n_shards in (1, 2, 4):
            r = SlotRouter.uniform(n_shards, n_slots=256)
            for i in range(200):
                kh = keyhash(f"u{i}")
                _, h3 = mix2x32((kh >> 32) & _M32, kh & _M32)
                assert r.shard_of(f"u{i}") == h3 % n_shards

    def test_assign_moves_slots_and_bumps_version(self):
        r = SlotRouter.uniform(2, n_slots=16)
        v0 = r.version
        moved = [s for s in range(16) if r.slot_map[s] == 0][:3]
        r.assign(moved, 1)
        assert r.version == v0 + 1
        assert all(r.slot_map[s] == 1 for s in moved)
        assert r.slots_of_shard(1) == sorted(
            set(r.slots_of_shard(1))
        )

    def test_parity_with_pallas_on_random_slot_maps(self):
        """Satellite: SlotRouter <-> kernels.shard_route bit-exact on random
        slot maps (the table-gather contract)."""
        from repro.kernels import shard_route

        rng = np.random.default_rng(11)
        keys = [f"user{i}" for i in range(300)] + list(range(64))
        khs = [keyhash(k) for k in keys]
        hi = np.array([(h >> 32) & 0xFFFFFFFF for h in khs], np.uint32)
        lo = np.array([h & 0xFFFFFFFF for h in khs], np.uint32)
        for n_slots in (64, 256):
            for n_shards in (2, 3, 5):
                slot_map = rng.integers(0, n_shards, n_slots)
                router = SlotRouter(list(slot_map), n_shards=n_shards)
                dev = np.asarray(shard_route(hi, lo, slot_map=slot_map))
                py = np.array([router.shard_of(k) for k in keys])
                np.testing.assert_array_equal(dev, py)

    def test_fastpath_batch_routes_by_slot_map(self):
        from repro.kernels import WitnessTable, fastpath_batch

        rng = np.random.default_rng(3)
        keys = [f"fk{i}" for i in range(100)]
        khs = [keyhash(k) for k in keys]
        hi = np.array([(h >> 32) & 0xFFFFFFFF for h in khs], np.uint32)
        lo = np.array([h & 0xFFFFFFFF for h in khs], np.uint32)
        slot_map = rng.integers(0, 3, 64)
        router = SlotRouter(list(slot_map), n_shards=3)
        res = fastpath_batch(WitnessTable.empty(64, 4), hi, lo,
                             slot_map=slot_map)
        np.testing.assert_array_equal(
            np.asarray(res.shard_ids),
            np.array([router.shard_of(k) for k in keys]),
        )


# ------------------------------------------------------- basic handover
class TestSlotHandover:
    def _seeded(self, n_shards=2, n_slots=64):
        c = ShardedCluster(n_shards=n_shards, f=3, n_slots=n_slots)
        cl = c.new_client()
        keys = [f"k{i}" for i in range(48)]
        for i, k in enumerate(keys):
            c.update(cl, cl.op_set(k, i))
        return c, cl, keys

    def test_migrate_moves_data_and_routing(self):
        c, cl, keys = self._seeded()
        dst = c.add_shard()
        slots = c.router.slots_of_shard(0)[:16]
        reports = c.migrate_slots(slots, dst)
        assert sum(r.keys_moved for r in reports) > 0
        moved = [k for k in keys if c.router.slot_of(k) in set(slots)]
        assert moved, "no seeded key lived in the moved slots"
        for k in moved:
            assert c.shard_of(k) == dst
            # data lives at the receiver, not the donor
            assert c.shards[dst].master.store.get(k) is not None
            assert c.shards[0].master.store.get(k) is None
        for i, k in enumerate(keys):     # nothing lost anywhere
            assert c.read(cl, cl.op_get(k)).value == i

    def test_moving_slot_redirects_then_serves(self):
        c, cl, keys = self._seeded()
        k = keys[0]
        slot = c.slot_of(k)
        dst = 1 - c.shard_of(k)
        migs = c.start_migration([slot], dst)
        with pytest.raises(SlotMoving):
            c.update(cl, cl.op_set(k, "during"))
        with pytest.raises(SlotMoving):
            c.read(cl, cl.op_get(k))
        for m in migs:
            m.run()
        # redirected op re-issues fresh and lands at the new owner
        out = c.update(cl, cl.op_set(k, "after"))
        assert out.value == "OK" and c.shard_of(k) == dst
        assert c.read(cl, cl.op_get(k)).value == "after"

    def test_untouched_slots_stay_fast_during_migration(self):
        c, cl, _keys = self._seeded()
        c.sync_all()                      # clean windows: no false conflicts
        dst = c.add_shard()
        slots = set(c.router.slots_of_shard(0)[:8])
        # Fresh, distinct keys on NON-moving slots (repeat writes to one key
        # would trip the ordinary §3.2.3 conflict path, not migration).
        fresh = iter(k for i in range(100_000)
                     if c.router.slot_of(k := f"u{i}") not in slots)
        migs = c.start_migration(sorted(slots), dst)
        for m in migs:
            while m.stage != "done":
                m.step()
                for _ in range(4):
                    k = next(fresh)
                    out = c.update(cl, cl.op_set(k, m.stage))
                    assert out.fast_path and out.rtts == 1, (k, m.stage)

    def test_rifl_duplicate_retry_across_slot_move(self):
        """Satellite: an op completed on the donor, retried after its slot
        moved, must RIFL-dedup at the receiver (same result, no
        double-apply) — the completion record migrated with the data."""
        c, cl, _ = self._seeded()
        op = cl.op_incr("counter")
        assert c.update(cl, op).value == 1
        # Model the lost-response retry RIFL actually permits: the client
        # never saw the first result, so its piggybacked ack frontier must
        # still sit AT the op's seq (an acked op is by contract never
        # retried, and ack-driven gc is free to forget its moved completion
        # record).  Rewind the completion state the harness advanced when it
        # delivered the response the "client" supposedly lost.
        cl._ids.first_incomplete = min(cl._ids.first_incomplete, op.rpc_id[1])
        cl._ids._completed.discard(op.rpc_id[1])
        slot = c.slot_of("counter")
        src = c.shard_of("counter")
        dst = 1 - src
        c.migrate_slots([slot], dst)
        dups_before = c.shards[dst].master.stats["dups"]
        log_before = len(c.shards[dst].master.log)
        out = c.update(cl, op)           # exact retry of the moved op
        assert out.value == 1            # original result re-externalized
        assert c.shards[dst].master.stats["dups"] == dups_before + 1
        assert len(c.shards[dst].master.log) == log_before
        assert c.read(cl, cl.op_get("counter")).value == 1
        # The retry completed and acked; the next op to reach THIS master
        # piggybacks the advanced frontier, which gc's the moved record
        # (the ack-driven overlay truncation).
        k_dst = next(f"after{i}" for i in range(10_000)
                     if c.shard_of(f"after{i}") == dst)
        assert c.update(cl, cl.op_set(k_dst, "1")).value is not None
        assert c.shards[dst].master.migrated_rifl == {}
        assert c.shards[dst].master.stats["migrated_rifl_gcd"] >= 1
        ok, key = check_linearizable_strict(c.history)
        assert ok, f"violation on {key}"

    def test_fenced_stale_witness_record_rejected(self):
        """Satellite: an in-flight update carrying the pre-handover
        WitnessListVersion (its records landed at the OLD witnesses) is
        refused by the master after the fence; the §3.6 refetch-and-retry
        then lands it at the new owner."""
        c, cl, keys = self._seeded()
        k = keys[0]
        src = c.shard_of(k)
        dst = 1 - src
        stale_wlv = c.config.fetch(src).witness_list_version
        op = cl.op_set(k, "stale")
        c.migrate_slots([c.slot_of(k)], dst)
        assert c.config.fetch(src).witness_list_version == stale_wlv + 1
        verdict, res = c.shards[src].master.handle_update(
            op, stale_wlv, (), 0.0
        )
        assert verdict == "error" and res.error == "WRONG_WITNESS_VERSION"
        # ... and even with a fresh wlv the donor no longer owns the key.
        verdict, res = c.shards[src].master.handle_update(
            op, c.config.fetch(src).witness_list_version, (), 0.0
        )
        assert verdict == "error" and res.error == "NOT_OWNER"
        out = c.update(cl, cl.op_set(k, "fresh"))   # client re-routes
        assert out.value == "OK"
        assert c.shards[dst].master.store.get(k) == "fresh"

    def test_donor_recovery_ignores_migrated_witness_remnants(self):
        """§3.6: after the handover, a donor crash must NOT replay witness
        records for the moved slots back into its store."""
        c = ShardedCluster(n_shards=2, f=3, n_slots=64, sync_batch=1000,
                           auto_sync=False)
        cl = c.new_client()
        keys = [f"w{i}" for i in range(24)]
        for i, k in enumerate(keys):
            c.update(cl, cl.op_set(k, i))     # unsynced + witness-recorded
        slots = c.router.slots_of_shard(0)[:32]
        c.migrate_slots(slots, 1)
        moved = [k for k in keys if c.router.slot_of(k) in set(slots)]
        assert all(c.shard_of(k) == 1 for k in moved)
        c.crash_master(0)
        for k in moved:
            assert c.shards[0].master.store.get(k) is None
            assert c.read(cl, cl.op_get(k)).value == keys.index(k)

    def test_add_and_remove_shard_round_trip(self):
        c, cl, keys = self._seeded()
        dst = c.add_shard()
        assert c.n_shards == 3
        c.migrate_slots(c.router.slots_of_shard(0)[:20], dst)
        reports = c.remove_shard(dst)
        assert c.shards[dst].retired and c.n_shards == 2
        assert sum(r.keys_moved for r in reports) >= 0
        assert not c.router.slots_of_shard(dst)
        for i, k in enumerate(keys):
            assert c.read(cl, cl.op_get(k)).value == i
        ok, key = check_linearizable_strict(c.history)
        assert ok, f"violation on {key}"


    def test_acked_op_duplicate_across_move_still_ignored(self):
        """Review regression: an op whose completion record was already
        ACKED away migrates as the ignore-as-duplicate marker (result
        None); a delayed network duplicate at the receiver must be ignored,
        not re-executed (re-execution would clobber later writes)."""
        c, cl, _ = self._seeded()
        op = cl.op_set("dupkey", "v1")
        assert c.update(cl, op).value == "OK"
        # Later traffic piggybacks the ack; the donor deletes the record.
        for i in range(3):
            c.update(cl, cl.op_set(f"after{i}", i))
        src = c.shard_of("dupkey")
        assert c.shards[src].master.rifl.check_duplicate(op.rpc_id).result \
            is None                      # synthetic ignore-marker now
        dst = 1 - src
        c.migrate_slots([c.slot_of("dupkey")], dst)
        c.update(cl, cl.op_set("dupkey", "v2"))      # newer write at recv
        verdict, res = c.shards[dst].master.handle_update(
            op, c.config.fetch(dst).witness_list_version, (), 0.0
        )
        assert verdict == "dup"                      # ignored, NOT re-run
        assert c.read(cl, cl.op_get("dupkey")).value == "v2"

    def test_mset_retry_follows_migrated_leg(self):
        """Review regression: retrying an mset with its original ``parts``
        after one leg's slots migrated must route that leg to the NEW owner
        and RIFL-dedup there (the completion records moved with the data).
        """
        c = ShardedCluster(n_shards=2, f=3, n_slots=64)
        cl = c.new_client()
        k0 = key_on_shard(c.router, 0, "ma")
        k1 = key_on_shard(c.router, 1, "mb")
        parts = cl.mset_parts([(k0, "x"), (k1, "y")])
        # Both legs actually applied, but the client never saw the reply.
        for sid, sub in parts.items():
            c.shards[sid].update(cl.session_for(sid), sub)
        src = c.shard_of(k0)
        dst = 1 - src
        c.migrate_slots([c.slot_of(k0)], dst)        # k0's leg moves
        logs = {s: len(c.shards[s].master.log) for s in range(2)}
        out = c.mset(cl, [(k0, "x"), (k1, "y")], parts=parts)
        assert out.value == "OK"
        # both legs deduped: no new MSET log entries anywhere
        assert {s: len(c.shards[s].master.log) for s in range(2)} == logs
        assert c.read(cl, cl.op_get(k0)).value == "x"
        assert c.read(cl, cl.op_get(k1)).value == "y"

    def test_mset_retry_split_leg_fails_loudly(self):
        """A migration that SPLITS one leg's keys across shards makes the
        original identity unreplayable: the retry raises a descriptive
        error instead of double-applying."""
        c = ShardedCluster(n_shards=2, f=3, n_slots=64)
        cl = c.new_client()
        ks = [f"sp{i}" for i in range(200) if c.shard_of(f"sp{i}") == 0]
        a = next(k for k in ks)
        b = next(k for k in ks if c.slot_of(k) != c.slot_of(a))
        parts = cl.mset_parts([(a, 1), (b, 2)])
        assert len(parts) == 1                       # one 2-key leg
        c.migrate_slots([c.slot_of(a)], 1)           # split the leg
        with pytest.raises(ValueError, match="invalidated"):
            cl.mset_parts([(a, 1), (b, 2)], prev=parts)

    def test_redirected_fresh_identities_released(self):
        """Review regression: a SlotMoving redirect must not freeze the
        client's ack frontier — identities the cluster allocated for the
        redirected mset/txn are abandoned, so later acks keep advancing."""
        c = ShardedCluster(n_shards=2, f=3, n_slots=64)
        cl = c.new_client()
        k = key_on_shard(c.router, 0, "fr")
        migs = c.start_migration([c.slot_of(k)], 1)
        with pytest.raises(SlotMoving):
            c.mset(cl, [(k, "v")])
        with pytest.raises(SlotMoving):
            c.txn(cl, writes=[(k, "v")])
        for m in migs:
            m.run()
        out = c.update(cl, cl.op_set(k, "v"))
        assert out.value == "OK"
        sess = cl.session_for(0)
        # the frontier advanced past every allocated id: no hole means the
        # redirected mset/txn identities were released, not leaked
        assert sess.first_incomplete > 1
        assert not sess._completed, \
            "abandoned ids left a hole in the ack frontier"


# --------------------------------------------------- crash mid-handover
class TestCrashMidHandover:
    @pytest.mark.parametrize("crash", ["donor", "receiver"])
    def test_crash_between_transfer_and_commit(self, crash):
        """Satellite: donor/receiver failover after the transfer but before
        the commit point; resume() redoes sync->transfer->handover and the
        strict checker stays green with zero lost writes."""
        r = run_migration_scenario(
            n_shards_before=2, n_shards_after=4, n_slots=64,
            ops_per_window=12, n_keys=64, crash=crash, seed=13,
        )
        assert r.resumed >= 1, "crash was never injected mid-handover"
        assert r.mismatches == 0
        assert r.history_ok, f"violation on {r.offending_key}"

    def test_clean_live_reshard_scenario(self):
        r = run_migration_scenario(
            n_shards_before=2, n_shards_after=4, n_slots=64,
            ops_per_window=30, n_keys=160, crash=None, seed=3,
        )
        assert r.mismatches == 0 and r.history_ok
        assert r.redirects == 0 or r.redirected_retried_ok >= 0
        # untouched slots stayed within 5% of steady-state fast ratio
        assert r.steady_fast - r.migration_fast_untouched <= 0.05

    def test_donor_crash_before_sync_replays_then_moves(self):
        """Crash the donor while slots are frozen but BEFORE the sync
        stage: witness replay restores the unsynced ops (slots still owned),
        and the resumed handover moves the recovered data."""
        c = ShardedCluster(n_shards=2, f=3, n_slots=64, sync_batch=1000,
                           auto_sync=False)
        cl = c.new_client()
        keys = [f"c{i}" for i in range(16)]
        for i, k in enumerate(keys):
            c.update(cl, cl.op_set(k, i))     # all unsynced
        slots = c.router.slots_of_shard(0)[:32]
        migs = c.start_migration(slots, 1)
        for m in migs:
            m.step()                           # freeze done, sync pending
            rep = c.crash_master(m.src)
            assert rep.replayed >= 0
            m.resume()
            m.run()
        for i, k in enumerate(keys):
            assert c.read(cl, cl.op_get(k)).value == i


# ------------------------------------------------------- hot-shard split
class TestHotShardRebalance:
    def test_plan_rebalance_moves_hottest_slots(self):
        loads = [0] * 16
        slot_map = [0] * 8 + [1] * 8
        for s in range(8):
            loads[s] = 100                    # shard 0 very hot
        moves = plan_rebalance(loads, slot_map, [0, 1], max_moves=16)
        assert moves, "no moves planned for an 8x imbalance"
        moved = [s for slots in moves.values() for s in slots]
        assert all(slot_map[s] == 0 for s in moved)
        assert 1 in moves

    def test_plan_rebalance_noops_when_balanced(self):
        loads = [10] * 16
        slot_map = [i % 4 for i in range(16)]
        assert plan_rebalance(loads, slot_map, [0, 1, 2, 3]) == {}

    def test_cluster_rebalance_spreads_hot_shard(self):
        import random

        c = ShardedCluster(n_shards=4, f=3)
        cl = c.new_client()
        rng = random.Random(5)
        hot = [k for k in (f"h{i}" for i in range(600))
               if c.shard_of(k) == 0][:40]
        for _ in range(300):
            c.update(cl, cl.op_set(rng.choice(hot), "v"))
        out = c.rebalance()
        assert sum(len(v) for v in out["moves"].values()) > 0
        # counters reset for the next measurement window (checked before the
        # verification reads below re-feed them)
        assert all(not g.slot_ops for g in c.shards)
        spread = {s: sum(1 for k in hot if c.shard_of(k) == s)
                  for s in range(4)}
        assert spread[0] < len(hot)           # hot shard shed load
        assert sum(spread.values()) == len(hot)
        for k in hot:                          # nothing lost
            assert c.read(cl, cl.op_get(k)).value == "v"


# -------------------------------------------------------- serving layer
class TestServingLiveMigration:
    def test_sessions_survive_live_migration_and_crash(self):
        from repro.serving.kvstore import CurpSessionStore, SessionState

        store = CurpSessionStore(f=3, sync_batch=8, n_shards=2, n_slots=64)
        for i in range(12):
            store.commit(SessionState(f"s{i}", [1, 2, i]))
        placed = {f"s{i}": store.shard_of(f"s{i}") for i in range(12)}
        dst = store.add_shard()
        slots = store.cluster.router.slots_of_shard(0)[:16]
        store.migrate_sessions(slots, dst)
        moved = [sid for sid in placed
                 if store.cluster.router.slot_of(
                     f"session:{sid}") in set(slots)]
        # the version-keyed cache refetched the new placement
        for sid in moved:
            assert store.shard_of(sid) == dst
        for i in range(12):                   # commits keep flowing
            store.commit(SessionState(f"s{i}", [1, 2, i, 99]))
        store.crash_and_recover()
        for i in range(12):
            st = store.load(f"s{i}")
            assert st is not None and st.tokens == [1, 2, i, 99]

    def test_store_rebalance_passthrough(self):
        from repro.serving.kvstore import CurpSessionStore, SessionState

        store = CurpSessionStore(f=3, n_shards=2, n_slots=64)
        for i in range(30):
            store.commit(SessionState(f"r{i}", [i]))
        out = store.rebalance()
        assert "moves" in out and "reports" in out
        for i in range(30):
            st = store.load(f"r{i}")
            assert st is not None and st.tokens == [i]
