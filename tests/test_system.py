"""End-to-end behaviour tests: the paper's claims exercised through the whole
stack (protocol -> simulator -> framework integration)."""
import statistics

import pytest

from repro.core import LocalCluster
from repro.sim import UniformWriteWorkload, run_scenario


def test_end_to_end_paper_story():
    """The abstract's three claims, end to end:
    1. CURP completes updates in 1 RTT (vs 2 for primary-backup);
    2. latency ~halves vs synchronous replication;
    3. consistency survives a master crash."""
    curp = run_scenario(mode="curp", f=3, n_clients=1, n_ops=800,
                        op_factory=UniformWriteWorkload(seed=1), seed=1)
    sync = run_scenario(mode="sync", f=3, n_clients=1, n_ops=800,
                        op_factory=UniformWriteWorkload(seed=1), seed=1)
    assert curp.fast_fraction > 0.98                       # 1-RTT fast path
    m_curp = statistics.median(curp.update_latencies)
    m_sync = statistics.median(sync.update_latencies)
    assert m_sync / m_curp > 1.7                           # ~2x

    crash = run_scenario(mode="curp", f=3, n_clients=4, n_ops=200,
                         op_factory=UniformWriteWorkload(seed=2), seed=3,
                         crash_at_us=1200.0)
    from repro.sim import check_linearizable

    ok, key = check_linearizable(crash.history)
    assert ok and crash.recovery is not None


def test_witness_capacity_figure11_shape():
    """Appendix B.1: 4-way associativity massively outlasts direct-mapped."""
    import numpy as np

    from repro.kernels import WitnessTable, witness_record

    def inserts_to_first_reject(ways: int, slots: int = 256, seed: int = 0):
        rng = np.random.default_rng(seed)
        t = WitnessTable.empty(slots // ways, ways)
        qh = rng.integers(0, 2**32, slots * 4, dtype=np.uint32)
        ql = rng.integers(0, 2**32, slots * 4, dtype=np.uint32)
        acc, _ = witness_record(t, qh, ql)
        acc = np.asarray(acc)
        rejects = np.where(acc == 0)[0]
        return int(rejects[0]) if len(rejects) else len(acc)

    direct = statistics.mean(
        inserts_to_first_reject(1, seed=s) for s in range(5)
    )
    assoc4 = statistics.mean(
        inserts_to_first_reject(4, seed=s) for s in range(5)
    )
    assert assoc4 > 2.5 * direct


def test_cluster_migration_filtering():
    """§3.6 case 3: ops on a migrated partition are rejected/ignored."""
    c = LocalCluster(f=3)
    cl = c.new_client()
    c.update(cl, cl.op_set("mine", 1))
    # master gives up ownership of keys starting with "theirs"
    c.sync_now()
    c.master.owned_partition = lambda k: not str(k).startswith("theirs")
    op = cl.op_set("theirs:x", 5)
    verdict, res = c.master.handle_update(
        op, c.config.fetch(0).witness_list_version, (), 0.0
    )
    assert verdict == "error" and res.error == "NOT_OWNER"
    # replay of a stray witness record for a migrated key is ignored too
    n = c.master.replay_from_witness([op])
    assert n == 0
