"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles, plus
hypothesis property tests for the hash and witness-table invariants.

hypothesis is optional: the _hyp shim turns the property tests into skips
when it isn't installed, so this file always collects (the oracle sweeps and
smoke tests below run regardless).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.kernels import (
    WitnessTable,
    conflict_scan,
    keyhash2x32,
    ref_conflict_scan,
    ref_keyhash2x32,
    ref_witness_gc,
    ref_witness_record,
    shard_route,
    witness_gc,
    witness_record,
)


def rng(seed=0):
    return np.random.default_rng(seed)


class TestKeyhash:
    @pytest.mark.parametrize("n", [1, 7, 128, 1000, 4096])
    @pytest.mark.parametrize("dtype", [np.uint32, np.int32])
    def test_matches_oracle(self, n, dtype):
        r = rng(n)
        hi = r.integers(0, 2**31, n).astype(dtype)
        lo = r.integers(0, 2**31, n).astype(dtype)
        kh, kl = keyhash2x32(hi, lo)
        rh, rl = ref_keyhash2x32(jnp.asarray(hi), jnp.asarray(lo))
        np.testing.assert_array_equal(np.asarray(kh), np.asarray(rh))
        np.testing.assert_array_equal(np.asarray(kl), np.asarray(rl))

    def test_smoke_deterministic(self):
        """No-hypothesis smoke: fixed input, fixed expected behaviour — this
        file must never collect to zero tests."""
        hi = np.arange(8, dtype=np.uint32)
        lo = np.arange(8, dtype=np.uint32)[::-1].copy()
        oh1, ol1 = keyhash2x32(hi, lo)
        oh2, ol2 = keyhash2x32(hi, lo)
        np.testing.assert_array_equal(np.asarray(oh1), np.asarray(oh2))
        np.testing.assert_array_equal(np.asarray(ol1), np.asarray(ol2))
        # distinct inputs should not collide on this tiny sample
        assert len(set(np.asarray(ol1).tolist())) == 8

    def test_shard_route_matches_python_router(self):
        """Device placement must agree bit-for-bit with the protocol-side
        KeyRouter (shared fmix32 chain) for every shard count we deploy."""
        from repro.core.shard import KeyRouter
        from repro.core.types import keyhash

        keys = [f"user{i}" for i in range(300)] + list(range(100))
        khs = [keyhash(k) for k in keys]
        hi = np.array([(h >> 32) & 0xFFFFFFFF for h in khs], np.uint32)
        lo = np.array([h & 0xFFFFFFFF for h in khs], np.uint32)
        for n_shards in (1, 2, 3, 4, 8):
            router = KeyRouter(n_shards)
            dev = np.asarray(shard_route(hi, lo, n_shards))
            py = np.array([router.shard_of(k) for k in keys])
            np.testing.assert_array_equal(dev, py)
        # 4-way split is roughly balanced (hash quality, not exactness)
        counts = np.bincount(np.asarray(shard_route(hi, lo, 4)), minlength=4)
        assert counts.min() > len(keys) // 8

    @settings(deadline=None, max_examples=20)
    @given(a=st.integers(0, 2**32 - 1), b=st.integers(0, 2**32 - 1),
           bit=st.integers(0, 63))
    def test_avalanche(self, a, b, bit):
        """Flipping one input bit flips a healthy share of output bits."""
        hi1, lo1 = np.uint32(a), np.uint32(b)
        x = (int(a) << 32) | int(b)
        y = x ^ (1 << bit)
        hi2, lo2 = np.uint32(y >> 32), np.uint32(y & 0xFFFFFFFF)
        o1 = ref_keyhash2x32(jnp.uint32(hi1), jnp.uint32(lo1))
        o2 = ref_keyhash2x32(jnp.uint32(hi2), jnp.uint32(lo2))
        diff = (int(o1[0]) ^ int(o2[0])).bit_count() + \
               (int(o1[1]) ^ int(o2[1])).bit_count()
        assert diff >= 10   # 64 output bits; ideal ~32


class TestWitnessRecord:
    @pytest.mark.parametrize("sets,ways,batch", [
        (16, 2, 64), (64, 4, 300), (256, 4, 512), (1024, 4, 1000),
        (1024, 8, 257),
    ])
    def test_matches_oracle(self, sets, ways, batch):
        r = rng(sets * ways + batch)
        t = WitnessTable.empty(sets, ways)
        qh = r.integers(0, 2**32, batch, dtype=np.uint32)
        ql = r.integers(0, sets * 6, batch, dtype=np.uint32)  # force pressure
        acc_k, tk = witness_record(t, qh, ql)
        acc_r, tr = ref_witness_record(t, jnp.asarray(qh), jnp.asarray(ql))
        np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_r))
        np.testing.assert_array_equal(np.asarray(tk.occ), np.asarray(tr.occ))
        np.testing.assert_array_equal(
            np.asarray(tk.keys_lo), np.asarray(tr.keys_lo))

    def test_conflict_semantics(self):
        """Same key twice => second rejected (the x<-1 / x<-5 rule)."""
        t = WitnessTable.empty(16, 4)
        qh = np.array([7, 7], dtype=np.uint32)
        ql = np.array([3, 3], dtype=np.uint32)
        acc, t2 = witness_record(t, qh, ql)
        assert list(np.asarray(acc)) == [1, 0]

    def test_gc_then_accept(self):
        t = WitnessTable.empty(16, 4)
        qh = np.array([7], np.uint32)
        ql = np.array([3], np.uint32)
        acc, t = witness_record(t, qh, ql)
        t = witness_gc(t, qh, ql)
        acc2, t = witness_record(t, qh, ql)
        assert int(acc2[0]) == 1

    @settings(deadline=None, max_examples=15)
    @given(seed=st.integers(0, 1000), sets=st.sampled_from([16, 64]),
           ways=st.sampled_from([2, 4]))
    def test_property_no_duplicate_keys_live(self, seed, sets, ways):
        """Invariant: an occupied witness never holds two slots with the same
        (hi, lo) key — the commutativity guarantee in table form."""
        r = rng(seed)
        n = 200
        t = WitnessTable.empty(sets, ways)
        qh = r.integers(0, 8, n, dtype=np.uint32)     # tiny keyspace
        ql = r.integers(0, 8, n, dtype=np.uint32)
        acc, t = witness_record(t, qh, ql)
        occ = np.asarray(t.occ)
        hi = np.asarray(t.keys_hi)
        lo = np.asarray(t.keys_lo)
        live = [(int(h), int(l)) for h, l, o in
                zip(hi.ravel(), lo.ravel(), occ.ravel()) if o]
        assert len(live) == len(set(live))

    def test_gc_matches_oracle_sweep(self):
        r = rng(5)
        t = WitnessTable.empty(64, 4)
        qh = r.integers(0, 2**32, 200, dtype=np.uint32)
        ql = r.integers(0, 512, 200, dtype=np.uint32)
        _, t = witness_record(t, qh, ql)
        gk = witness_gc(t, qh[:77], ql[:77])
        gr = ref_witness_gc(t, jnp.asarray(qh[:77]), jnp.asarray(ql[:77]))
        np.testing.assert_array_equal(np.asarray(gk.occ), np.asarray(gr.occ))


class TestConflictScan:
    @pytest.mark.parametrize("u,b", [(64, 16), (512, 256), (700, 123),
                                     (2048, 1024)])
    def test_matches_oracle(self, u, b):
        r = rng(u + b)
        wh = r.integers(0, 2**32, u, dtype=np.uint32)
        wl = r.integers(0, 2**32, u, dtype=np.uint32)
        wv = r.integers(0, 2, u, dtype=np.int32)
        qh = np.concatenate([wh[: b // 4], r.integers(0, 2**32, b - b // 4,
                                                      dtype=np.uint32)])
        ql = np.concatenate([wl[: b // 4], r.integers(0, 2**32, b - b // 4,
                                                      dtype=np.uint32)])
        ck = conflict_scan(wh, wl, wv, qh, ql)
        cr = ref_conflict_scan(jnp.asarray(wh), jnp.asarray(wl),
                               jnp.asarray(wv), jnp.asarray(qh),
                               jnp.asarray(ql))
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))

    def test_invalid_window_entries_never_hit(self):
        wh = np.array([5, 5], np.uint32)
        wl = np.array([9, 9], np.uint32)
        wv = np.array([0, 0], np.int32)
        c = conflict_scan(wh, wl, wv, np.array([5], np.uint32),
                          np.array([9], np.uint32))
        assert int(c[0]) == 0
