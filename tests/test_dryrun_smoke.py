"""Dry-run smoke: spawn dryrun.py as a subprocess (it forces 512 host
devices, which must never leak into this test process) on a small 4x4 mesh
for a representative arch subset, and check the artifacts."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("smollm-360m", "train_4k"),
    ("qwen3-moe-30b-a3b", "decode_32k"),
    ("mamba2-130m", "long_500k"),
])
def test_dryrun_small_mesh(arch, shape, tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", "4x4",
         "--out", str(tmp_path), "--no-probes"],
        capture_output=True, text=True, timeout=540,
        cwd=ROOT, env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
                       "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    arts = list(tmp_path.glob("*.json"))
    assert len(arts) == 1
    rec = json.loads(arts[0].read_text())
    assert rec["status"] == "ok", rec
    assert rec["flops_per_device"] > 0
    assert rec["terms"]["dominant"] in ("compute", "memory", "collective")


def test_this_process_has_one_device():
    """The 512-device XLA flag must never leak outside dryrun.py."""
    import jax

    assert len(jax.devices()) == 1
