"""CURP-FT + CURP-Serve integration tests (the framework-level guarantees)."""
import shutil

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data.pipeline import DataConfig
from repro.ft import (
    FTConfig,
    FaultTolerantTrainer,
    StragglerPolicy,
    plan_elastic_remesh,
)
from repro.models.config import reduced
from repro.optim import AdamWConfig, compress_grads, roundtrip_leaf
from repro.serving import CurpServeDriver, ServeConfig


@pytest.fixture
def small_cfg():
    return reduced(ARCHS["smollm-360m"])


class TestCurpFT:
    def test_bit_exact_recovery(self, small_cfg, tmp_path):
        dc = DataConfig(batch=2, seq=16)
        a = FaultTolerantTrainer(
            small_cfg, dc, FTConfig(f=3, sync_every=5, workdir=tmp_path / "a")
        )
        a.train(13)
        da = a.params_digest()

        b = FaultTolerantTrainer(
            small_cfg, dc, FTConfig(f=3, sync_every=5, workdir=tmp_path / "b")
        )
        b.train(8)
        b.crash()
        rep = b.recover()
        assert rep["restored_step"] == 5 and rep["replayed"] == 3
        b.train(13 - b.step)
        assert b.params_digest() == da

    def test_journal_survives_process_restart(self, small_cfg, tmp_path):
        """FileWitness rebuilds from its durable log (flash-backed-DRAM
        analogue)."""
        from repro.ft.journal import FileWitness, StepOp

        w1 = FileWitness(tmp_path / "w.jsonl", master_id=1)
        for i in range(5):
            w1.record(StepOp(i, 42, 0))
        w1.gc([0, 1])
        # "restart": new object from same file
        w2 = FileWitness(tmp_path / "w.jsonl", master_id=1)
        steps = [s.step for s in w2.get_recovery_data()]
        assert steps == [2, 3, 4]

    def test_backup_checksum_detects_corruption(self, small_cfg, tmp_path):
        dc = DataConfig(batch=2, seq=16)
        t = FaultTolerantTrainer(
            small_cfg, dc, FTConfig(f=1, sync_every=5, workdir=tmp_path)
        )
        t.train(5)
        b = t.backups[0]
        step = b.newest_step()
        npz = b.root / f"step{step}" / "state.npz"
        data = bytearray(npz.read_bytes())
        data[100] ^= 0xFF
        npz.write_bytes(bytes(data))
        with pytest.raises(IOError):
            b.restore(step)


class TestElastic:
    def test_remesh_keeps_tokens_constant(self):
        full = plan_elastic_remesh(2, global_batch=256, baseline_pods=2)
        degraded = plan_elastic_remesh(1, global_batch=256, baseline_pods=2)
        assert full.per_pod_batch * full.n_pods * full.grad_accum == 256
        assert (degraded.per_pod_batch * degraded.n_pods
                * degraded.grad_accum) == 256
        assert degraded.grad_accum == 2

    def test_straggler_demotion(self):
        pol = StragglerPolicy(deadline_factor=3.0, demote_after=2)
        verdict = None
        for _ in range(10):
            pol.observe(0, 1.0)
        for _ in range(2):
            verdict = pol.observe(1, 10.0)
        assert verdict == "demote"


class TestCompression:
    def test_roundtrip_error_bounded(self):
        r = np.random.default_rng(0)
        g = jax.numpy.asarray(r.normal(0, 0.01, (1000,)), jax.numpy.float32)
        q = roundtrip_leaf(g)
        rel = float(np.abs(np.asarray(q - g)).max() /
                    (np.abs(np.asarray(g)).max() + 1e-12))
        assert rel < 0.01   # int8 per-block: <1% of block max

    def test_error_feedback_unbiased_over_steps(self):
        """With a CONSTANT gradient, the mean of error-fed quantized sends
        converges to the true gradient (the EF guarantee)."""
        r = np.random.default_rng(0)
        g = {"w": jax.numpy.asarray(r.normal(0, 1, (512,)),
                                    jax.numpy.float32)}
        ef = None
        acc = np.zeros(512, np.float64)
        n = 20
        for _ in range(n):
            deq, ef = compress_grads(g, ef)
            acc += np.asarray(deq["w"], np.float64)
        mean_sent = acc / n
        err = np.abs(mean_sent - np.asarray(g["w"])).max()
        one_shot = np.abs(
            np.asarray(compress_grads(g)[0]["w"]) - np.asarray(g["w"])
        ).max()
        assert err <= one_shot + 1e-6   # EF never worse than one-shot
        assert err < 0.01


class TestCurpServe:
    @pytest.mark.parametrize("arch", ["llama3.2-1b", "hymba-1.5b"])
    def test_crash_recovery_identical_tokens(self, arch):
        cfg = reduced(ARCHS[arch])
        sc = ServeConfig(max_batch=4, max_seq=64, f=3, sync_batch=8)
        a = CurpServeDriver(cfg, sc, seed=3)
        a.submit("s1", [5, 17, 99])
        a.submit("s2", [1, 2])
        a.generate(8)
        ref = {sid: list(s.tokens) for sid, s in a.sessions.items()}

        b = CurpServeDriver(cfg, sc, seed=3)
        b.submit("s1", [5, 17, 99])
        b.submit("s2", [1, 2])
        b.generate(5)
        rep = b.crash_and_recover()
        assert rep["recovered_sessions"] == 2
        b.generate(3)
        got = {sid: list(s.tokens) for sid, s in b.sessions.items()}
        assert got == ref

    def test_commits_take_fast_path(self):
        cfg = reduced(ARCHS["llama3.2-1b"])
        sc = ServeConfig(max_batch=2, max_seq=32, f=3, sync_batch=50)
        d = CurpServeDriver(cfg, sc, seed=0)
        d.submit("a", [1, 2])
        d.submit("b", [3])
        d.generate(6)
        # Distinct session keys commute; the same session's NEXT commit is
        # kept fast by the §4.4 hot-key preemptive sync.  At most one slow
        # (2-RTT, still-complete) commit per session is expected.
        assert d.store.fast_commits >= 10
        assert d.store.slow_commits <= 2
