"""Import-or-skip shim for hypothesis (optional test dependency).

Test modules import the hypothesis surface from here instead of hard-importing
``hypothesis`` — the hard import errored the whole file at collection when the
package is absent.  With hypothesis installed the real objects pass through
unchanged and every property test runs; without it the decorators degrade to
``pytest.mark.skip``, so files still collect and their non-property tests run.

(Equivalent in effect to ``pytest.importorskip("hypothesis")``, but scoped to
the property tests only instead of skipping whole files.)
"""
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def _skipping_decorator_factory(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    given = _skipping_decorator_factory
    settings = _skipping_decorator_factory

    class _Anything:
        """Stands in for ``strategies`` / ``HealthCheck``: any attribute
        access or call returns another stub, so decorator arguments like
        ``st.integers(0, 10)`` still evaluate at class-body time."""

        def __getattr__(self, _name):
            return _Anything()

        def __call__(self, *_a, **_k):
            return _Anything()

    st = _Anything()
    HealthCheck = _Anything()

__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
