"""CURP protocol unit tests: witness, master, RIFL, recovery, consensus."""
import pytest

from repro.core import (
    ClientSession,
    ConsensusCluster,
    KVStore,
    LocalCluster,
    Op,
    OpType,
    RecordStatus,
    RiflTable,
    Witness,
    WitnessMode,
    keyhash,
    replay_threshold,
    superquorum,
)


# ---------------------------------------------------------------- witnesses
class TestWitness:
    def test_accept_commutative(self):
        w = Witness(64, 4)
        w.start(1)
        for i in range(10):
            op = Op(OpType.SET, (f"k{i}",), ("v",), (1, i))
            assert w.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED

    def test_reject_same_key(self):
        """'if a witness already accepted x<-1, it cannot accept x<-5' (§3.2.2)"""
        w = Witness(64, 4)
        w.start(1)
        op1 = Op(OpType.SET, ("x",), (1,), (1, 1))
        op2 = Op(OpType.SET, ("x",), (5,), (2, 1))
        assert w.record(1, op1.key_hashes(), op1.rpc_id, op1) is RecordStatus.ACCEPTED
        assert w.record(1, op2.key_hashes(), op2.rpc_id, op2) is RecordStatus.REJECTED

    def test_duplicate_retry_idempotent(self):
        w = Witness(64, 4)
        w.start(1)
        op = Op(OpType.SET, ("x",), (1,), (1, 1))
        assert w.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED
        assert w.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED

    def test_wrong_master_rejected(self):
        w = Witness(64, 4)
        w.start(1)
        op = Op(OpType.SET, ("x",), (1,), (1, 1))
        assert w.record(2, op.key_hashes(), op.rpc_id, op) is RecordStatus.REJECTED

    def test_set_full_rejects(self):
        w = Witness(1, 2)   # 1 set, 2 ways
        w.start(1)
        accepted = 0
        for i in range(5):
            op = Op(OpType.SET, (f"k{i}",), ("v",), (1, i))
            if w.record(1, op.key_hashes(), op.rpc_id, op) is RecordStatus.ACCEPTED:
                accepted += 1
        assert accepted == 2

    def test_gc_frees_slots(self):
        w = Witness(1, 2)
        w.start(1)
        op = Op(OpType.SET, ("a",), (1,), (1, 1))
        w.record(1, op.key_hashes(), op.rpc_id, op)
        w.gc(tuple((kh, op.rpc_id) for kh in op.key_hashes()))
        assert w.occupancy == 0

    def test_recovery_mode_irreversible(self):
        w = Witness(64, 4)
        w.start(1)
        op = Op(OpType.SET, ("x",), (1,), (1, 1))
        w.record(1, op.key_hashes(), op.rpc_id, op)
        data = w.get_recovery_data(1)
        assert len(data) == 1
        assert w.mode is WitnessMode.RECOVERY
        op2 = Op(OpType.SET, ("y",), (1,), (1, 2))
        assert w.record(1, op2.key_hashes(), op2.rpc_id, op2) is RecordStatus.REJECTED

    def test_multikey_all_or_nothing(self):
        w = Witness(64, 4)
        w.start(1)
        op1 = Op(OpType.SET, ("a",), (1,), (1, 1))
        w.record(1, op1.key_hashes(), op1.rpc_id, op1)
        mop = Op(OpType.MSET, ("a", "b"), (2, 3), (2, 1))
        assert w.record(1, mop.key_hashes(), mop.rpc_id, mop) is RecordStatus.REJECTED
        # 'b' slot must NOT be occupied by the failed multi-key record
        ok = Op(OpType.SET, ("b",), (9,), (3, 1))
        assert w.record(1, ok.key_hashes(), ok.rpc_id, ok) is RecordStatus.ACCEPTED

    def test_uncollected_garbage_surfaces(self):
        """§4.5: records surviving >=3 gc rounds are reported as stale."""
        w = Witness(64, 4)
        w.start(1)
        op = Op(OpType.SET, ("orphan",), (1,), (99, 1))
        w.record(1, op.key_hashes(), op.rpc_id, op)
        stale = ()
        for _ in range(4):
            stale = w.gc(()).stale_requests
        assert any(o.rpc_id == (99, 1) for o in stale)

    def test_suspect_age_boundary_and_counters(self):
        """A record is suspected only after SUSPECT_AGE survived gc rounds;
        gc of a matching entry increments gc_drops and resets the slot."""
        w = Witness(64, 4)
        w.start(1)
        op = Op(OpType.SET, ("orphan",), (1,), (99, 1))
        w.record(1, op.key_hashes(), op.rpc_id, op)
        for round_no in range(1, Witness.SUSPECT_AGE):
            assert w.gc(()).stale_requests == (), round_no
        assert any(o.rpc_id == (99, 1) for o in w.gc(()).stale_requests)
        # the master retires it via a (late) gc entry: slot freed + counted
        before = w.stats["gc_drops"]
        w.gc(tuple((kh, op.rpc_id) for kh in op.key_hashes()))
        assert w.stats["gc_drops"] == before + 1
        assert w.occupancy == 0

    def test_gc_retry_path_drops_stale_record(self):
        """§4.5 end-to-end: a witness record whose master-side execution was
        lost (no gc entry ever names it) survives SUSPECT_AGE gc rounds, is
        retried through RIFL by the master, and the NEXT sync's gc finally
        drops it — gc_drops observed on the witness."""
        c = LocalCluster(f=3, sync_batch=1000, auto_sync=False)
        cl = c.new_client()
        # Orphan: recorded at witness 0 as if the client's update RPC to the
        # master was lost after the record RPCs went out.
        orphan = Op(OpType.SET, ("orphan",), ("lost",), (777, 1))
        w0 = c.witnesses[0]
        assert w0.record(c.master.master_id, orphan.key_hashes(),
                         orphan.rpc_id, orphan) is RecordStatus.ACCEPTED
        # Drive SUSPECT_AGE+1 sync/gc rounds with unrelated traffic.
        for i in range(w0.SUSPECT_AGE + 1):
            c.update(cl, cl.op_set(f"other{i}", i))
            c.sync_now()
        # The stale record was replayed through the master (RIFL filtered
        # nothing: the master never saw it) and then gc'd off the witness.
        assert c.master.store.get("orphan") == "lost"
        # one drop per retired round-op plus the retried orphan itself
        assert w0.stats["gc_drops"] == w0.SUSPECT_AGE + 2
        assert all(s.rpc_id != orphan.rpc_id
                   for row in w0._slots for s in row if s.occupied)

    def test_rejects_full_counter(self):
        """Capacity rejections (set full) are counted separately from
        conflict rejections."""
        w = Witness(1, 2)   # 1 set, 2 ways -> third distinct key won't fit
        w.start(1)
        for i in range(4):
            op = Op(OpType.SET, (f"k{i}",), ("v",), (1, i))
            w.record(1, op.key_hashes(), op.rpc_id, op)
        assert w.stats["rejects_full"] == 2
        assert w.stats["accepts"] == 2


# ---------------------------------------------------------------- RIFL
class TestRifl:
    def test_duplicate_detection(self):
        r = RiflTable()
        r.record_completion((1, 1), "res", synced=False)
        rec = r.check_duplicate((1, 1))
        assert rec is not None and rec.result == "res"

    def test_acks_delete_records(self):
        r = RiflTable()
        r.record_completion((1, 1), "a", True)
        r.record_completion((1, 2), "b", True)
        r.apply_client_acks([(1, 2)])
        assert r.check_duplicate((1, 1)) is not None  # acked => still dup
        assert r.check_duplicate((1, 2)).result == "b"

    def test_acks_ignored_in_replay_mode(self):
        """§4.8 modification 1."""
        r = RiflTable()
        r.record_completion((1, 1), "a", True)
        r.replay_mode = True
        r.apply_client_acks([(1, 5)])
        r.replay_mode = False
        rec = r.check_duplicate((1, 1))
        assert rec is not None and rec.result == "a"

    def test_lease_expiry_requires_sync(self):
        """§4.8 modification 2."""
        r = RiflTable()
        r.record_completion((1, 1), "a", synced=False)
        assert not r.expire_client(1, all_synced=r.all_synced_for(1))
        r.mark_synced_through([(1, 1)])
        assert r.expire_client(1, all_synced=r.all_synced_for(1))
        assert r.check_duplicate((1, 2)) is not None  # expired => ignored


# ---------------------------------------------------------------- protocol paths
class TestLocalCluster:
    def test_fast_path_1rtt(self):
        c = LocalCluster(f=3)
        cl = c.new_client()
        out = c.update(cl, cl.op_set("x", 1))
        assert out.fast_path and out.rtts == 1 and out.witness_accepts == 3

    def test_conflict_2rtt_synced_tag(self):
        c = LocalCluster(f=3, sync_batch=50)
        cl = c.new_client()
        c.update(cl, cl.op_set("x", 1))
        out = c.update(cl, cl.op_set("x", 2))
        assert out.synced_path and out.rtts == 2

    def test_read_blocked_by_unsynced_write(self):
        c = LocalCluster(f=3, sync_batch=50)
        cl = c.new_client()
        c.update(cl, cl.op_set("x", 1))
        out = c.read(cl, cl.op_get("x"))
        assert out.value == 1 and out.rtts == 2   # §3.2.3: sync before read

    def test_witness_drop_slow_path(self):
        c = LocalCluster(f=3)
        c.witness_drop(1)
        cl = c.new_client()
        out = c.update(cl, cl.op_set("x", 1))
        assert not out.fast_path and out.rtts >= 2
        # the op is durable via backup sync despite the dropped witness
        assert c.master.synced_index == len(c.master.log)

    def test_recovery_preserves_completed(self):
        c = LocalCluster(f=3, sync_batch=50)
        cl = c.new_client()
        for i in range(30):
            c.update(cl, cl.op_set(f"k{i}", i))
        rep = c.crash_master()
        assert rep.replayed >= 0
        for i in range(30):
            assert c.read(cl, cl.op_get(f"k{i}")).value == i

    def test_retry_after_crash_rifl_filtered(self):
        c = LocalCluster(f=3, sync_batch=50)
        cl = c.new_client()
        op = cl.op_incr("ctr")
        out = c.update(cl, op)
        assert out.value == 1
        c.crash_master()
        # client retries the SAME rpc: must not re-execute
        verdict, res = c.master.handle_update(
            op, c.config.fetch(0).witness_list_version, (), 0.0
        )
        assert verdict == "dup" and res.value == 1
        assert c.read(cl, cl.op_get("ctr")).value == 1

    def test_witness_reconfiguration_version_fence(self):
        """§3.6: stale WitnessListVersion must be rejected by the master."""
        c = LocalCluster(f=3)
        cl = c.new_client()
        old_version = c.config.fetch(0).witness_list_version
        c.replace_witness(0)
        op = cl.op_set("x", 1)
        verdict, res = c.master.handle_update(op, old_version, (), 0.0)
        assert verdict == "error" and res.error == "WRONG_WITNESS_VERSION"
        # with the fresh config it succeeds
        out = c.update(cl, cl.op_set("x", 1))
        assert out.value == "OK"

    def test_zombie_master_fenced_at_backups(self):
        """§4.7: epoch fence rejects sync RPCs from a deposed master."""
        c = LocalCluster(f=3, sync_batch=1000, auto_sync=False)
        cl = c.new_client()
        c.update(cl, cl.op_set("x", 1))
        zombie = c.master
        c.crash_master()
        zombie.want_sync = True
        req = zombie.begin_sync()
        assert req is not None
        resp = c.backups[0].handle_sync(req)
        assert not resp.ok and c.backups[0].stats["rejected_epoch"] >= 1

    def test_backup_read_consistency(self):
        """§A.1: commutativity check against a witness gates backup reads."""
        c = LocalCluster(f=3, sync_batch=50)
        cl = c.new_client()
        c.update(cl, cl.op_set("x", 1))
        c.sync_now()
        # synced: backup read allowed and fresh
        v, from_backup = c.read_from_backup(cl, cl.op_get("x"))
        assert v == 1 and from_backup
        # unsynced write: witness holds x -> must fall back to master
        c.update(cl, cl.op_set("x", 2))
        v, from_backup = c.read_from_backup(cl, cl.op_get("x"))
        assert v == 2 and not from_backup

    def test_hot_key_preemptive_sync(self):
        c = LocalCluster(f=3, sync_batch=1000, hot_key_window=10.0)
        cl = c.new_client()
        c.update(cl, cl.op_set("k", 1), now=0.0)
        c.sync_now()
        # synced but recently updated => next update is fast AND triggers a
        # preemptive sync (§4.4), keeping future updates unblocked.
        out = c.update(cl, cl.op_set("k", 2), now=5.0)
        assert out.fast_path
        assert c.master.stats["hot_key_syncs"] == 1
        # far outside the window: no preemptive sync
        c.sync_now()
        c.update(cl, cl.op_set("k", 3), now=500.0)
        assert c.master.stats["hot_key_syncs"] == 1


# ---------------------------------------------------------------- consensus (§A.2)
class TestConsensus:
    def test_superquorum_math(self):
        assert superquorum(2) == 4 and replay_threshold(2) == 2
        assert superquorum(3) == 6 and replay_threshold(3) == 3

    def test_fast_path_and_leader_change(self):
        cc = ConsensusCluster(f=2)
        s = ClientSession(client_id=7)
        vals = {}
        for i in range(10):
            op = s.op_set(f"k{i}", i)
            _, fast = cc.update(op)
            assert fast
            vals[f"k{i}"] = i
        cc.crash(cc.leader.replica_id)
        info = cc.change_leader()
        for k, v in vals.items():
            assert cc.store.get(k) == v, (k, info)

    def test_completed_op_survives_f_failures(self):
        cc = ConsensusCluster(f=2)
        s = ClientSession(client_id=7)
        op = s.op_set("precious", 42)
        _, fast = cc.update(op)
        assert fast
        # kill the leader AND one more replica (f = 2 failures)
        cc.crash(cc.leader.replica_id)
        live = [r.replica_id for r in cc.live()]
        cc.crash(live[-1])
        cc.change_leader()
        assert cc.store.get("precious") == 42


# ---------------------------------------------------------------- §A.2 property
from _hyp import given, settings, st


class TestConsensusProperty:
    @settings(deadline=None, max_examples=12)
    @given(seed=st.integers(0, 10_000), n_ops=st.integers(1, 25),
           f=st.sampled_from([1, 2]))
    def test_fast_completed_ops_survive_any_f_failures(self, seed, n_ops, f):
        """§A.2 safety: every op completed via the witness superquorum
        survives ANY f replica failures (including the leader)."""
        import random

        rng = random.Random(seed)
        cc = ConsensusCluster(f=f, commit_batch=7)
        s = ClientSession(client_id=5)
        completed = {}
        for i in range(n_ops):
            op = s.op_set(f"k{rng.randrange(40)}", (seed, i))
            res, fast = cc.update(op)
            # Both paths complete durably: fast = witness superquorum,
            # slow = committed to a majority before replying.
            completed[op.keys[0]] = op.args[0]
        # crash f replicas, leader first
        victims = [cc.leader.replica_id]
        others = [r.replica_id for r in cc.live()
                  if r.replica_id not in victims]
        rng.shuffle(others)
        victims += others[: f - 1]
        for v in victims:
            cc.crash(v)
        cc.change_leader()
        for k, v in completed.items():
            assert cc.store.get(k) == v, (k, seed)
