"""Unit tests for distribution plumbing that doesn't need >1 device:
spec sanitization, HLO collective parsing, analytic roofline math, and the
roofline-table renderer against the real artifacts."""
import json
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES
from repro.launch.hlo_analysis import (
    analytic_hbm_bytes,
    collective_bytes,
    roofline_terms,
)
from repro.launch.sharding import param_specs, sanitize_specs

AX = {"data": 16, "model": 16}


class TestSanitize:
    def test_drops_nondivisible_axes(self):
        specs = {"embed": P("model", "data")}
        shapes = {"embed": jax.ShapeDtypeStruct((50280, 768), "float32")}
        out = sanitize_specs(specs, shapes, AX)
        assert out["embed"] == P(None, "data")   # 50280 % 16 != 0; 768 ok

    def test_tuple_axes_product(self):
        specs = {"x": P(("pod", "data"), None)}
        shapes = {"x": jax.ShapeDtypeStruct((48, 8), "float32")}
        out = sanitize_specs(specs, shapes, {"pod": 2, "data": 16, "model": 16})
        assert out["x"] == P(None, None)          # 48 % 32 != 0
        shapes2 = {"x": jax.ShapeDtypeStruct((64, 8), "float32")}
        out2 = sanitize_specs(specs, shapes2, {"pod": 2, "data": 16})
        assert out2["x"] == P(("pod", "data"), None)

    def test_param_specs_cover_every_leaf(self):
        """Every arch's param tree must be congruent with its spec tree."""
        from repro.models.transformer import init_params

        for name, cfg in ARCHS.items():
            shapes = jax.eval_shape(
                lambda c=cfg: init_params(c, jax.random.PRNGKey(0))
            )
            specs = param_specs(cfg, tp=16)
            # tree_map raises on structure mismatch
            out = sanitize_specs(specs, shapes, AX)
            n = len(jax.tree_util.tree_leaves(
                out, is_leaf=lambda x: isinstance(x, P)
            ))
            assert n == len(jax.tree_util.tree_leaves(shapes)), name


class TestHloParser:
    def test_counts_result_bytes_by_type(self):
        hlo = """
  %all-gather.1 = bf16[16,2048]{1,0} all-gather(bf16[1,2048] %p), replica_groups={}
  %ar = f32[128]{0} all-reduce(f32[128] %x), to_apply=%add
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), dimensions={0}
  %done = f32[8] all-gather-done(%start)
"""
        out = collective_bytes(hlo)
        assert out["all-gather"] == 16 * 2048 * 2
        assert out["all-reduce"] == 128 * 4
        assert out["reduce-scatter"] == 2 * 64 * 4
        assert out["n_all-gather"] == 1   # -done lines don't double count

    def test_start_forms_counted_once(self):
        hlo = "%s = bf16[256]{0} all-reduce-start(bf16[256] %x)\n" \
              "%d = bf16[256]{0} all-reduce-done(%s)\n"
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 256 * 2
        assert out["n_all-reduce"] == 1


class TestRooflineMath:
    def test_dominant_selection(self):
        t = roofline_terms(197e12, 0, 50e9 * 2.0,
                           peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
                           analytic_bytes_per_device=819e9 * 0.5)
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(0.5)
        assert t["collective_s"] == pytest.approx(2.0)
        assert t["dominant"] == "collective"
        assert t["bound_step_s"] == pytest.approx(2.0)

    def test_analytic_bytes_scales_sanely(self):
        cfg = ARCHS["llama3.2-1b"]
        train = analytic_hbm_bytes(cfg, SHAPES["train_4k"], 256, 16, 16)
        dec = analytic_hbm_bytes(cfg, SHAPES["decode_32k"], 256, 16, 16)
        # train moves params 3x + activations; decode reads a weight shard
        assert train > dec
        # decode weight-stationary: ~params*2/16 plus KV
        assert dec < cfg.n_params() * 2


class TestArtifacts:
    """Validate the shipped dry-run artifacts (deliverable e/g)."""

    ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

    @pytest.mark.skipif(not (ART / "smollm-360m__train_4k__16x16.json").exists(),
                        reason="dry-run artifacts not present")
    def test_every_cell_ok_or_documented_skip(self):
        import glob

        for mesh in ("16x16", "2x16x16"):
            ok = skipped = err = 0
            for f in self.ART.glob(f"*__{mesh}.json"):
                d = json.loads(f.read_text())
                if d["status"] == "ok":
                    ok += 1
                    assert d["flops_per_device"] >= 0
                    assert d["terms"]["dominant"] in (
                        "compute", "memory", "collective")
                elif d["status"] == "skipped":
                    skipped += 1
                    assert d["skip_reason"]
                else:
                    err += 1
            assert ok == 31 and skipped == 9 and err == 0, (mesh, ok, skipped, err)
