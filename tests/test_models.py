"""Per-arch smoke tests (reduced configs, one fwd/train step, no NaNs) +
model-level correctness: blockwise attention vs direct SDPA, Mamba2 SSD
chunked-vs-recurrent, MoE capacity-vs-dense, decode/train consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, concrete_batch
from repro.models import (
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
)
from repro.models.config import reduced
from repro.models.layers import _sdpa, _sdpa_blockwise, make_attn_mask
from repro.models.moe import init_moe_params, moe_mlp, moe_mlp_capacity

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train(arch):
    """Assigned-architecture smoke: reduced config, one train step on CPU,
    output shapes + finite loss."""
    cfg = reduced(ARCHS[arch])
    params = init_params(cfg, KEY)
    batch = concrete_batch(cfg, "train", batch=2, seq=32)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    loss, metrics = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", [a for a in sorted(ARCHS)
                                  if ARCHS[a].can_decode])
def test_arch_smoke_decode(arch):
    cfg = reduced(ARCHS[arch])
    params = init_params(cfg, KEY)
    cache = init_decode_cache(cfg, 2, 64)
    db = concrete_batch(cfg, "decode", batch=2, seq=1, with_labels=False)
    logits, cache = jax.jit(
        lambda p, b, c: decode_step(cfg, p, b, c)
    )(params, db, cache)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["pos"][0]) == 1


class TestBlockwiseAttention:
    @pytest.mark.parametrize("attn,is_global,causal", [
        ("full", True, True), ("swa", False, True), ("full", True, False),
    ])
    def test_vs_direct(self, attn, is_global, causal):
        cfg = dataclasses.replace(
            reduced(ARCHS["smollm-360m"]), attn=attn, causal=causal,
            swa_window=40,
        )
        r = np.random.default_rng(0)
        B, S, Hq, Hkv, dh = 2, 2048, 4, 2, 16
        q = jnp.asarray(r.normal(0, 1, (B, S, Hq, dh)), jnp.float32)
        k = jnp.asarray(r.normal(0, 1, (B, S, Hkv, dh)), jnp.float32)
        v = jnp.asarray(r.normal(0, 1, (B, S, Hkv, dh)), jnp.float32)
        o_blk = _sdpa_blockwise(cfg, q, k, v, is_global=is_global, block=256)
        o_ref = _sdpa(cfg, q, k, v, make_attn_mask(cfg, S, is_global))
        np.testing.assert_allclose(
            np.asarray(o_blk), np.asarray(o_ref), atol=2e-5
        )


class TestDecodeTrainConsistency:
    """Autoregressive decode must reproduce the training-forward logits —
    the property CURP-Serve recovery (re-prefill) depends on."""

    @pytest.mark.parametrize("arch", ["mamba2-130m", "llama3.2-1b",
                                      "hymba-1.5b"])
    def test_stepwise_matches_parallel(self, arch):
        cfg = reduced(ARCHS[arch])
        params = init_params(cfg, KEY)
        T = 16
        toks = jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (1, T)), jnp.int32
        )
        logits_par, _ = forward(cfg, params, {"tokens": toks})
        cache = init_decode_cache(cfg, 1, T)
        outs = []
        for t in range(T):
            lg, cache = decode_step(
                cfg, params, {"tokens": toks[:, t:t + 1]}, cache
            )
            outs.append(lg)
        logits_seq = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(logits_par[0]), np.asarray(logits_seq[0]),
            atol=5e-3, rtol=1e-3,
        )

    def test_active_mask_freezes_rows(self):
        cfg = reduced(ARCHS["llama3.2-1b"])
        params = init_params(cfg, KEY)
        cache = init_decode_cache(cfg, 2, 16)
        b = {"tokens": jnp.array([[3], [4]], jnp.int32),
             "active": jnp.array([1, 0], jnp.int32)}
        _, cache = decode_step(cfg, params, b, cache)
        assert int(cache["pos"][0]) == 1 and int(cache["pos"][1]) == 0
        k0 = np.asarray(cache["segments"][0]["k"])
        assert np.abs(k0[:, 1]).sum() == 0.0   # inactive row untouched


class TestMoE:
    def test_capacity_matches_dense_at_high_cf(self):
        cfg = dataclasses.replace(
            reduced(ARCHS["qwen3-moe-30b-a3b"]), moe_capacity_factor=8.0
        )
        p = init_moe_params(cfg, KEY, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        o_d, _ = moe_mlp(cfg, p, x)
        o_c, _ = moe_mlp_capacity(cfg, p, x)
        np.testing.assert_allclose(np.asarray(o_d), np.asarray(o_c),
                                   atol=1e-5)

    def test_capacity_drops_overflow_gracefully(self):
        cfg = dataclasses.replace(
            reduced(ARCHS["qwen3-moe-30b-a3b"]), moe_capacity_factor=0.25
        )
        p = init_moe_params(cfg, KEY, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        o, aux = moe_mlp_capacity(cfg, p, x)
        assert np.isfinite(np.asarray(o)).all()


def test_param_count_sanity():
    """Analytic n_params should land near the arch's nameplate size."""
    approx = {
        "llama3.2-1b": (1.0e9, 1.7e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "nemotron-4-340b": (300e9, 360e9),
        "qwen3-moe-30b-a3b": (25e9, 33e9),
        "mamba2-130m": (0.10e9, 0.18e9),
        "hymba-1.5b": (1.2e9, 1.9e9),
    }
    for name, (lo, hi) in approx.items():
        n = ARCHS[name].n_params()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
