"""Figure 12 / Appendix C.1: throughput vs sync batch size.

Paper: "Since RAMCloud allows only one outstanding sync, syncs are naturally
batched for around 15 writes even at 1 minimum batch size" — i.e. CURP's
curve is FLAT in the batch knob (natural batching) and the 4x lives between
CURP (any batch) and the original per-op-sync primary-backup.  We reproduce
both facts: the flat CURP curve and the ~4x vs the per-op baseline.
"""
from __future__ import annotations

import dataclasses

from repro.sim import DEFAULT, UniformWriteWorkload, run_scenario

from .common import emit


def main(n_ops: int = 2000) -> dict:
    rows = []
    derived = {}
    for batch in (1, 5, 10, 50, 100):
        p = dataclasses.replace(DEFAULT, sync_batch=batch)
        r = run_scenario(mode="curp", f=3, n_clients=24, n_ops=n_ops,
                         params=p,
                         op_factory=UniformWriteWorkload(seed=1), seed=7)
        rows.append({"mode": "curp", "sync_batch": batch,
                     "kops_per_s": r.throughput_ops_per_sec / 1e3})
        derived[f"curp_batch{batch}"] = r.throughput_ops_per_sec / 1e3
    # the pre-CURP baseline: one sync per op, blocking (original RAMCloud)
    r = run_scenario(mode="sync", f=3, n_clients=24, n_ops=n_ops,
                     op_factory=UniformWriteWorkload(seed=1), seed=7)
    rows.append({"mode": "sync_per_op", "sync_batch": 1,
                 "kops_per_s": r.throughput_ops_per_sec / 1e3})
    derived["original_per_op_sync"] = r.throughput_ops_per_sec / 1e3
    emit(rows, "fig12: throughput vs sync batching (kops/s)")
    derived["curp_vs_per_op"] = (
        derived["curp_batch50"] / derived["original_per_op_sync"]
    )
    # natural batching: CURP flat in the knob (paper §C.1)
    derived["flatness_batch1_vs_50"] = (
        derived["curp_batch1"] / derived["curp_batch50"]
    )
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    main()
