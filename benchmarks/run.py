"""Benchmark harness entry point: one benchmark per paper table/figure.
Prints ``name,us_per_call,derived`` CSV at the end (per the grading
contract), after each figure's own detailed tables, and writes the same
numbers to ``BENCH_curp.json`` at the repo root so the perf trajectory is
machine-readable across PRs.
"""
from __future__ import annotations

import json
import pathlib
import time

BENCH_DIR = pathlib.Path(__file__).resolve().parent
BENCH_JSON = BENCH_DIR.parent / "BENCH_curp.json"
BENCH_HISTORY = BENCH_DIR.parent / "BENCH_history.jsonl"


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return float(v)


def write_bench_json(results, path: pathlib.Path = BENCH_JSON) -> None:
    """Persist every figure's derived metrics (the summary CSV, structured).

    Schema: {"schema": 1, "unix_time": ..., "figures": {name:
    {"us_per_call": ..., "derived": {...}}}} — stable keys so a driver can
    diff BENCH_curp.json between PRs.

    MERGES into an existing file instead of overwriting it: figures run now
    replace their own entries, figures not in ``results`` keep their prior
    numbers — so a partial run (or a PR that adds a new figure) never drops
    the rest of the perf trajectory.
    """
    figures = {}
    prior_time = None
    if path.exists():
        try:
            prior = json.loads(path.read_text())
            figures = dict(prior.get("figures", {}))
            prior_time = prior.get("unix_time")
        except (json.JSONDecodeError, OSError):
            figures = {}
    # Backfill: entries written before per-figure stamping landed carry no
    # unix_time, so the staleness guard below can never fire for them.
    # Stamp them with the file-level time (the best known lower bound on
    # when they last ran) so every preserved entry is staleness-checkable.
    for entry in figures.values():
        if "unix_time" not in entry:
            entry["unix_time"] = prior_time if prior_time is not None \
                else time.time()
    # Perf trajectory: for every numeric metric that already had a recorded
    # value, keep the previous number next to the new one so a driver can
    # read deltas (e.g. fig_fastpath proto_device_kops across PRs) without
    # diffing git history.
    deltas = {}
    for name, _dt, derived in results:
        prev = figures.get(name, {}).get("derived", {})
        moved = {
            k: {"prev": prev[k], "now": _jsonable(v)}
            for k, v in derived.items()
            if k in prev and isinstance(prev[k], (int, float))
            and isinstance(_jsonable(v), (int, float))
            and _jsonable(v) != prev[k]
        }
        if moved:
            deltas[name] = moved
    now = time.time()
    figures.update({
        name: {
            "us_per_call": dt,
            "unix_time": now,
            "derived": {k: _jsonable(v) for k, v in derived.items()},
        }
        for name, dt, derived in results
    })
    # Staleness guard: a figure carried over from the prior file whose
    # benchmark module was edited AFTER the figure last ran is showing
    # numbers the current code may no longer produce (how fig10's recorded
    # medians survived a cost-model change unnoticed).  Warn, don't fail —
    # partial runs are legitimate; the warning says which job to re-run.
    ran = {name for name, _dt, _d in results}
    for name, entry in sorted(figures.items()):
        if name in ran:
            continue
        mod = BENCH_DIR / f"{name}.py"
        stamp = entry.get("unix_time", prior_time)
        if mod.exists() and stamp is not None and mod.stat().st_mtime > stamp:
            print(f"WARNING: {path.name} entry '{name}' predates "
                  f"benchmarks/{mod.name} (module edited since that figure "
                  f"last ran) — stale numbers; re-run it")
    payload = {
        "schema": 1,
        "unix_time": time.time(),
        "figures": figures,
        "deltas": deltas,
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    print(f"wrote {path} ({len(results)} updated, "
          f"{len(figures) - len(results)} preserved)")
    # Append-only trajectory: one line per merge, so the perf history
    # survives BENCH_curp.json's in-place updates (scripts/bench_gate.py
    # gates on the latest deltas; the jsonl is the long view).
    hist_line = {
        "unix_time": payload["unix_time"],
        "updated": sorted(ran),
        "deltas": deltas,
        "figures": {
            name: {"us_per_call": dt,
                   "derived": {k: _jsonable(v) for k, v in derived.items()
                               if isinstance(_jsonable(v), (int, float))}}
            for name, dt, derived in results
        },
    }
    hist_path = path.parent / BENCH_HISTORY.name
    with hist_path.open("a") as fh:
        fh.write(json.dumps(hist_line, sort_keys=True) + "\n")
    print(f"appended {hist_path.name} ({len(results)} figures)")
    fp = deltas.get("fig_fastpath", {}).get("proto_device_kops")
    if fp:
        print(f"proto_device_kops: {fp['prev']:.2f} -> {fp['now']:.2f}")


def main() -> None:
    from . import (
        fig5_latency_cdf,
        fig6_throughput,
        fig7_ycsb,
        fig8_redis,
        fig10_ops,
        fig11_witness_capacity,
        fig12_batchsize,
        fig_crdt,
        fig_fastpath,
        fig_migration,
        fig_obs,
        fig_scaling,
        fig_slo,
        fig_txn,
        fig_watchdog,
        roofline_table,
    )

    jobs = [
        ("fig5_latency_cdf", fig5_latency_cdf.main),
        ("fig6_throughput", fig6_throughput.main),
        ("fig7_ycsb", fig7_ycsb.main),
        ("fig8_redis", fig8_redis.main),
        ("fig10_ops", fig10_ops.main),
        ("fig11_witness_capacity", fig11_witness_capacity.main),
        ("fig12_batchsize", fig12_batchsize.main),
        ("fig_scaling", fig_scaling.main),
        ("fig_fastpath", fig_fastpath.main),
        ("fig_txn", fig_txn.main),
        ("fig_migration", fig_migration.main),
        ("fig_crdt", fig_crdt.main),
        ("fig_slo", fig_slo.main),
        ("fig_obs", fig_obs.main),
        ("fig_watchdog", fig_watchdog.main),
        ("roofline_table", roofline_table.main),
    ]
    results = []
    for name, fn in jobs:
        t0 = time.time()
        derived = fn()
        dt = (time.time() - t0) * 1e6
        results.append((name, dt, derived))

    print("\n== summary CSV ==")
    print("name,us_per_call,derived")
    for name, dt, derived in results:
        compact = ";".join(
            f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
            for k, v in list(derived.items())[:8]
        )
        print(f"{name},{dt:.0f},{compact}")

    write_bench_json(results)


if __name__ == "__main__":
    main()
