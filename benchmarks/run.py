"""Benchmark harness entry point: one benchmark per paper table/figure.
Prints ``name,us_per_call,derived`` CSV at the end (per the grading
contract), after each figure's own detailed tables."""
from __future__ import annotations

import time


def main() -> None:
    from . import (
        fig5_latency_cdf,
        fig6_throughput,
        fig7_ycsb,
        fig8_redis,
        fig10_ops,
        fig11_witness_capacity,
        fig12_batchsize,
        fig_scaling,
        roofline_table,
    )

    jobs = [
        ("fig5_latency_cdf", fig5_latency_cdf.main),
        ("fig6_throughput", fig6_throughput.main),
        ("fig7_ycsb", fig7_ycsb.main),
        ("fig8_redis", fig8_redis.main),
        ("fig10_ops", fig10_ops.main),
        ("fig11_witness_capacity", fig11_witness_capacity.main),
        ("fig12_batchsize", fig12_batchsize.main),
        ("fig_scaling", fig_scaling.main),
        ("roofline_table", roofline_table.main),
    ]
    results = []
    for name, fn in jobs:
        t0 = time.time()
        derived = fn()
        dt = (time.time() - t0) * 1e6
        results.append((name, dt, derived))

    print("\n== summary CSV ==")
    print("name,us_per_call,derived")
    for name, dt, derived in results:
        compact = ";".join(
            f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
            for k, v in list(derived.items())[:8]
        )
        print(f"{name},{dt:.0f},{compact}")


if __name__ == "__main__":
    main()
