"""fig_crdt: fast-path fraction vs hot-key skew under the CRDT-CURP merge
lattice.

Classic CURP treats any same-key pair of updates as a conflict, so a
contended counter (every client INCRing one hot key) collapses onto the
2-RTT sync path exactly when the fast path matters most.  The merge lattice
(repro.core.merge) widens commutativity per op CLASS: INCR/INCR, SADD/SADD,
APPEND/APPEND, MAX/MAX, and HMSETs on disjoint fields merge deterministically
and therefore keep the 1-RTT fast path, while SET/anything still conflicts.

Scenarios (every history runs through the merge-aware STRICT Wing&Gong
checker — widening commutativity must not widen observable behaviour):

  * skew sweep — fast-path fraction vs probability of hitting the ONE hot
    key, INCR (mergeable) vs SET (plain).  At skew=1.0 the INCR series must
    keep >=0.95 fast-path while plain SET collapses to <=0.2.
  * merge classes — SADD / APPEND / MAX hot-key workloads at skew=1.0 stay
    fast for the same reason.
  * HMSET fields — per-field subkeys make disjoint-field HMSETs on one key
    commute (fast) while same-field HMSETs conflict (slow): commutativity is
    decided at field granularity, not key granularity.
  * parity — the Pallas conflict decision is bit-exact with Python:
    CONFLICT_MATRIX rows == scalar ``conflicts`` over all 16x16 class pairs,
    and the set-parallel record kernel matches the sequential oracle on a
    collision-heavy batch mixing INCR/INCR stacks with SET/INCR conflicts
    (accept lanes AND resulting table planes compared bit-for-bit).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core.client import ClientSession
from repro.core.merge import N_CLASSES, conflicts
from repro.core.types import Op
from repro.kernels import (
    WitnessTable,
    conflict_matrix_np,
    ref_witness_record,
    witness_record,
)
from repro.sim import HotKeyWorkload, SimParams, check_linearizable_strict, run_scenario

from .common import emit

# Small sync batches + deep ways: the adversarial regime where mergeable
# records must STACK in one witness set between syncs (a shallow table would
# mask lattice rejects behind capacity rejects).
CRDT = SimParams(sync_batch=8, witness_ways=16)

SKEWS = (0.0, 0.5, 0.9, 1.0)


def _run(kind: str, skew: float, n_ops: int, seed: int):
    r = run_scenario(mode="curp", f=1, n_clients=4, n_ops=n_ops, params=CRDT,
                     op_factory=HotKeyWorkload(skew=skew, kind=kind, seed=seed),
                     seed=seed)
    ok, blame = check_linearizable_strict(r.history)
    assert ok, (
        f"fig_crdt {kind}@skew={skew}: merge-aware strict checker failed "
        f"(key={blame!r}) — deterministic merge diverged from a legal "
        f"linearization"
    )
    return r


def hmset_factory(disjoint: bool):
    """Every client HMSETs the SAME key; ``disjoint`` writes a fresh field
    per op (all ops commute via field subkeys even though the key is shared
    — key-granular CURP would serialize every one of them) vs one shared
    field, where same-field last-wins order makes every pair conflict."""
    seq = [0]

    def factory(session: ClientSession) -> Op:
        seq[0] += 1
        field = f"f{session.client_id}_{seq[0]}" if disjoint else "f0"
        return session.op_hmset("hobj", [(field, "x" * 8)])

    return factory


def _run_factory(label: str, factory, n_ops: int, seed: int):
    r = run_scenario(mode="curp", f=1, n_clients=4, n_ops=n_ops, params=CRDT,
                     op_factory=factory, seed=seed)
    ok, blame = check_linearizable_strict(r.history)
    assert ok, f"fig_crdt {label}: strict checker failed (key={blame!r})"
    return r


def check_parity(n_queries: int = 256, seed: int = 7) -> int:
    """Python<->Pallas conflict-decision parity, bit-exact.

    1. matrix encoding: every (a, b) of the 16x16 CONFLICT_MATRIX row plane
       must equal the scalar ``conflicts`` predicate the Python witness uses.
    2. record kernel: set-parallel Pallas witness_record vs the sequential
       oracle on a collision-heavy classed batch (8 hot keys, 64 sets, INCR
       stacks + SET/INCR mixes) — accept lanes and all table planes equal.
    """
    rows = conflict_matrix_np()
    for a in range(N_CLASSES):
        for b in range(N_CLASSES):
            assert bool((int(rows[a]) >> b) & 1) == conflicts(a, b), (
                f"matrix/scalar divergence at classes ({a}, {b})"
            )

    rng = np.random.default_rng(seed)
    # 8 distinct (hi, lo) pairs -> heavy same-set collisions at 64 sets.
    base_hi = rng.integers(0, 2 ** 32, size=8, dtype=np.uint32)
    base_lo = rng.integers(0, 2 ** 32, size=8, dtype=np.uint32)
    pick = rng.integers(0, 8, size=n_queries)
    q_hi = base_hi[pick]
    q_lo = base_lo[pick]
    # Mix mergeable INCR runs with plain SETs and DELs on the same keys.
    q_cls = rng.choice(np.array([0, 1, 2, 2, 2, 5], dtype=np.int32),
                       size=n_queries)
    table = WitnessTable.empty(64, 16)
    acc_ref, t_ref = ref_witness_record(
        table, np.asarray(q_hi), np.asarray(q_lo), np.asarray(q_cls))
    acc_dev, t_dev = witness_record(
        table, np.asarray(q_hi), np.asarray(q_lo), np.asarray(q_cls))
    assert np.array_equal(np.asarray(acc_ref), np.asarray(acc_dev)), (
        "accept lanes diverge: Pallas record kernel vs sequential oracle"
    )
    for name in ("keys_hi", "keys_lo", "occ"):
        a = np.asarray(getattr(t_ref, name))
        b = np.asarray(getattr(t_dev, name))
        assert np.array_equal(a, b), f"table plane {name} diverges"
    n_acc = int(np.asarray(acc_ref).sum())
    # The batch is built to exercise both verdicts; an all-accept or
    # all-reject run means the collision setup regressed.
    assert 0 < n_acc < n_queries, (
        f"degenerate parity batch: {n_acc}/{n_queries} accepted"
    )
    return n_acc


def main(n_ops: int = 300) -> dict:
    rows = []
    derived = {}

    for kind in ("INCR", "SET"):
        for skew in SKEWS:
            r = _run(kind, skew, n_ops, seed=11)
            ff = r.fast_fraction
            rows.append({"kind": kind, "skew": skew, "fast_frac": round(ff, 4)})
            derived[f"{kind.lower()}_fastfrac_skew{skew:g}"] = ff
    for kind in ("SADD", "APPEND", "MAX"):
        r = _run(kind, 1.0, n_ops, seed=13)
        ff = r.fast_fraction
        rows.append({"kind": kind, "skew": 1.0, "fast_frac": round(ff, 4)})
        derived[f"{kind.lower()}_fastfrac_skew1"] = ff
    for label, disjoint in (("hmset_disjoint", True), ("hmset_samefield", False)):
        r = _run_factory(label, hmset_factory(disjoint), n_ops, seed=17)
        ff = r.fast_fraction
        rows.append({"kind": label, "skew": 1.0, "fast_frac": round(ff, 4)})
        derived[f"{label}_fastfrac"] = ff

    derived["parity_accepted"] = check_parity()
    derived["parity_ok"] = 1

    # The tentpole claim: the merge lattice keeps the hot counter on the
    # 1-RTT fast path where classic (SET-conflict) CURP collapses.
    incr1 = derived["incr_fastfrac_skew1"]
    set1 = derived["set_fastfrac_skew1"]
    assert incr1 >= 0.95, f"hot INCR counter fell off the fast path: {incr1}"
    assert set1 <= 0.2, f"plain SET should collapse at skew 1.0: {set1}"
    for kind in ("sadd", "append", "max"):
        v = derived[f"{kind}_fastfrac_skew1"]
        assert v >= 0.9, f"merge class {kind} fell off the fast path: {v}"
    hd = derived["hmset_disjoint_fastfrac"]
    hs = derived["hmset_samefield_fastfrac"]
    assert hd >= 0.9, f"disjoint-field HMSETs should commute: {hd}"
    assert hs <= 0.35, f"same-field HMSETs should conflict: {hs}"
    # Widening must be monotone in skew for the mergeable series: more
    # contention must NOT lose fast-path share (that is the whole point).
    assert incr1 >= derived["incr_fastfrac_skew0"] - 0.05, (
        "INCR fast fraction degraded with skew"
    )

    emit(rows, "fig_crdt: fast-path fraction vs hot-key skew")
    print("derived:", {k: (round(v, 4) if isinstance(v, float) else v)
                       for k, v in derived.items()})
    return derived


if __name__ == "__main__":
    main(n_ops=60 if "--smoke" in sys.argv[1:] else 300)
