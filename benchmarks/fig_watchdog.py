"""Watchdog figure: the monitors are non-vacuous, silent on clean storms,
and effectively free.

Four halves, all asserted:

1. **Chaos matrix** — run every ``ChaosConfig`` switch through its
   scenario and assert the matching monitor (``CHAOS_MONITOR``) fires,
   that NO other monitor fires, and that detection lands within a bounded
   number of journal events of the injection (the watchdog is an online
   auditor, not a teardown check).  One run is replayed from its black box
   and must reproduce the identical breach sequence (``Breach.key()``).

2. **Clean storms** — the fig_slo-shaped storms (overload ramp with a
   flash crowd, silent-crash failover, hot-slot migration burst) with the
   watchdog attached and no chaos: zero breaches, zero monitors fired.
   The crash storm also runs with a full-sampling tracer and must leak no
   open spans (teardown drains them; the black-box path reuses the same
   ``Tracer.drain``).

3. **Overhead** — the watched overload ramp must keep >= 95% of the
   unwatched SIMULATED goodput (the watchdog is an observer: journal emits
   and monitor updates never touch sim time or the RNG, so this ratio
   should be exactly 1.0 — the assertion catches any future hook that
   perturbs the protocol).  Wall-clock cost of watching rides along as a
   reported metric.

4. **Strict agreement** — the windowed incremental checker's verdict must
   match ``check_linearizable_strict`` on closed-loop companion histories,
   both on clean histories (ok) and with an injected read corruption
   (violation).

Simulated quantities are µs; ``wall_*`` metrics are real wall clock.
"""
from __future__ import annotations

import argparse
import time

from repro.core.overload import ArmorConfig
from repro.core.shard import KeyRouter
from repro.core.telemetry import Tracer
from repro.core.types import splitmix64
from repro.sim import (
    CHAOS_MONITOR,
    ChaosConfig,
    OpenLoopWorkload,
    YcsbWorkload,
    check_linearizable_strict,
    check_linearizable_windowed,
    replay,
    run_intent_leak_scenario,
    run_openloop_scenario,
    run_scenario,
    run_watched_scenario,
)

from .common import emit

ARMOR = ArmorConfig(queue_capacity=16)
SLO_US = 200.0
# Detection bound: a breach must land within this many journal events of
# the injection (chaos._fire_seq stamps the injection; ``leak_intent`` is
# bounded by the intent monitor's own event bound instead).
DETECT_EVENTS = 5_000
INTENT_BOUND = 300


def _hot_slot_migration(n_items: int = 64):
    """(slot, dst) of the zipf rank-0 key's slot, so migration traffic is
    guaranteed: chaos skip_fence keeps the donor executing on a slot that
    actually sees client writes mid-handover."""
    r = KeyRouter(2)
    hot_key = f"user{splitmix64(0) % (n_items * 8)}"
    slot = r.slot_of(hot_key)
    return slot, 1 - r.slot_map[slot]


def _chaos_runs(smoke: bool):
    """switch -> (kind, kwargs): the scenario that provokes it."""
    dur = 3_000.0 if smoke else 5_000.0
    dur_mig = 6_000.0 if smoke else 8_000.0
    slot, dst = _hot_slot_migration()
    return {
        "early_ack": ("openloop", dict(duration_us=dur, seed=3)),
        "force_commute": ("openloop", dict(duration_us=dur, seed=3)),
        "rifl_rollback": ("openloop", dict(duration_us=dur, seed=3)),
        "corrupt_value": ("openloop", dict(
            duration_us=dur, seed=3,
            workload=OpenLoopWorkload(rate_ops_per_us=0.5, seed=3,
                                      read_fraction=0.3, n_items=64),
        )),
        "skip_fence": ("openloop", dict(
            duration_us=dur_mig, seed=3, n_shards=2,
            workload=OpenLoopWorkload(rate_ops_per_us=0.5, seed=3,
                                      n_items=64),
            migrate_slots=[(0.25 * dur_mig, slot, dst)],
        )),
        "skip_epoch_bump": ("openloop", dict(
            duration_us=dur_mig, seed=3, fail_master_at={0: 2_000.0},
            heartbeat=True,
        )),
        "leak_intent": ("intent", dict(intent_bound=INTENT_BOUND)),
    }


def chaos_matrix(smoke: bool = False) -> dict:
    rows, derived = [], {}
    for switch, (kind, kwargs) in _chaos_runs(smoke).items():
        expect = CHAOS_MONITOR[switch]
        chaos = ChaosConfig(**{switch: True})
        if kind == "intent":
            wd = run_intent_leak_scenario(chaos=chaos, **kwargs)
        else:
            _r, wd = run_watched_scenario(scenario=kind, chaos=chaos,
                                          **kwargs)
        fired = wd.fired_monitors()
        assert fired == (expect,), (
            f"{switch}: expected exactly ['{expect}'], got {list(fired)} "
            f"({len(wd.breaches)} breaches)")
        assert wd.blackbox is not None, f"{switch}: no black box sealed"
        b0 = wd.breaches[0]
        inj = wd.chaos._fire_seq.get(switch)
        if switch == "leak_intent":
            bound, base = INTENT_BOUND + 64, inj or 0
        elif inj is not None:
            bound, base = DETECT_EVENTS, inj
        else:   # force_commute never latches: it lies on EVERY op
            bound, base = DETECT_EVENTS, 0
        detect = b0.seq - base
        assert 0 <= detect <= bound, (
            f"{switch}: breach at event #{b0.seq}, injected at #{base} — "
            f"detection took {detect} events (bound {bound})")
        rows.append({"switch": switch, "monitor": expect,
                     "breaches": len(wd.breaches), "detect_events": detect,
                     "journal_events": wd.events_seen})
        derived[f"{switch}_detect_events"] = detect
        if switch == "early_ack":
            _wd2, identical = replay(wd)
            assert identical, \
                "early_ack replay did not reproduce the breach sequence"
            derived["replay_identical"] = 1
    emit(rows, "fig_watchdog: chaos switch -> monitor (detection latency "
               "in journal events)")
    return derived


# ---------------------------------------------------------------------------
# clean storms: zero breaches
# ---------------------------------------------------------------------------
def _overload_cfg(smoke: bool):
    dur = 4_000.0 if smoke else 10_000.0
    return dict(
        workload=OpenLoopWorkload(
            rate_ops_per_us=1.5, n_clients=200_000,
            diurnal_amplitude=0.25, diurnal_period_us=dur,
            flash_crowds=((0.45 * dur, 0.55 * dur, 3.0),), seed=11,
        ),
        duration_us=dur, f=1, armor=ARMOR, seed=11, slo_us=SLO_US,
    )


def _storm_configs(smoke: bool):
    dur_c = 6_000.0 if smoke else 12_000.0
    dur_m = 6_000.0 if smoke else 12_000.0
    slot, dst = _hot_slot_migration()
    return {
        "overload": _overload_cfg(smoke),
        "crash": dict(
            workload=OpenLoopWorkload(rate_ops_per_us=0.2, n_clients=50_000,
                                      seed=13),
            duration_us=dur_c, f=1, armor=ARMOR, seed=13, slo_us=SLO_US,
            heartbeat=True, fail_master_at={0: 0.4 * dur_c},
        ),
        "migration": dict(
            workload=OpenLoopWorkload(rate_ops_per_us=0.4, n_clients=50_000,
                                      seed=17),
            duration_us=dur_m, f=1, n_shards=2, armor=ARMOR, seed=17,
            migrate_slots=[(0.3 * dur_m, slot, dst),
                           (0.3 * dur_m + 400.0, 0, 1),
                           (0.3 * dur_m + 800.0, 2, 1)],
            slo_us=SLO_US,
        ),
    }


def clean_storms(smoke: bool = False) -> dict:
    rows, derived = [], {}
    for storm, cfg in _storm_configs(smoke).items():
        tracer = Tracer(sample=1.0) if storm == "crash" else None
        r, wd = run_watched_scenario(scenario="openloop", tracer=tracer,
                                     **cfg)
        assert wd.ok, (
            f"clean {storm} storm raised {wd.fired_monitors()}: "
            f"{wd.breaches[0].reason}")
        if tracer is not None:
            leaked = tracer.open_spans()
            assert not leaked, (
                f"clean {storm} storm leaked {len(leaked)} open spans "
                f"(first: {leaked[0].name})")
        st = wd.checker.stats()
        rows.append({"storm": storm, "breaches": len(wd.breaches),
                     "events": wd.events_seen,
                     "ops_checked": st["ops_checked"],
                     "saturated": int(st["saturated"]),
                     "goodput_kops": r.goodput_ops_per_sec / 1e3})
        derived[f"{storm}_events"] = wd.events_seen
        derived[f"{storm}_ops_checked"] = st["ops_checked"]
    emit(rows, "fig_watchdog: clean storms (zero breaches required)")
    return derived


# ---------------------------------------------------------------------------
# overhead: watched vs unwatched overload ramp
# ---------------------------------------------------------------------------
def overhead(smoke: bool = False) -> dict:
    # Fresh config per run: the workload object carries RNG state, so
    # sharing one across runs would compare different arrival sequences.
    t0 = time.time()
    bare = run_openloop_scenario(**_overload_cfg(smoke))
    wall_off = time.time() - t0
    t0 = time.time()
    watched, wd = run_watched_scenario(scenario="openloop",
                                       **_overload_cfg(smoke))
    wall_on = time.time() - t0
    assert wd.ok, f"watched overload ramp breached: {wd.breaches[0].reason}"
    ratio = watched.goodput_ops_per_sec / max(bare.goodput_ops_per_sec, 1e-9)
    emit([{"mode": "off", "goodput_kops": bare.goodput_ops_per_sec / 1e3,
           "wall_s": wall_off},
          {"mode": "watched", "goodput_kops":
           watched.goodput_ops_per_sec / 1e3, "wall_s": wall_on}],
         "fig_watchdog: watchdog overhead on the fig_slo overload ramp")
    assert ratio >= 0.95, (
        f"watchdog cost goodput: {watched.goodput_ops_per_sec:.0f} vs "
        f"{bare.goodput_ops_per_sec:.0f} ops/s (ratio {ratio:.3f})")
    return {
        "goodput_off_kops": bare.goodput_ops_per_sec / 1e3,
        "goodput_watched_kops": watched.goodput_ops_per_sec / 1e3,
        "goodput_ratio": ratio,
        "wall_overhead_x": wall_on / max(wall_off, 1e-9),
        "events_per_op": wd.events_seen / max(watched.completed, 1),
    }


# ---------------------------------------------------------------------------
# windowed checker vs strict checker on companion histories
# ---------------------------------------------------------------------------
def agreement(smoke: bool = False) -> dict:
    seeds = (0, 1) if smoke else (0, 1, 2, 3)
    n_ops = 120 if smoke else 300
    checked = 0
    for seed in seeds:
        r = run_scenario(mode="curp", f=1, n_clients=4, n_ops=n_ops,
                         seed=seed,
                         op_factory=YcsbWorkload(read_fraction=0.5,
                                                 n_items=64, seed=seed))
        hist = r.history
        ok_s, _k = check_linearizable_strict(hist)
        ok_w, _k = check_linearizable_windowed(hist)
        assert ok_s == ok_w, f"seed {seed}: strict {ok_s} != windowed {ok_w}"
        assert ok_s, f"seed {seed}: clean closed-loop history not linearizable"
        checked += len(hist)
        # Inject a read corruption: both checkers must reject it.
        bad = [dict(h) for h in hist]
        for h in bad:
            if h["op"].op_type.name == "GET" and not h.get("failed") \
                    and h.get("complete") is not None:
                h["value"] = "~nobody-ever-wrote-this~"
                break
        else:
            continue
        ok_s, _ = check_linearizable_strict(bad)
        ok_w, _ = check_linearizable_windowed(bad)
        assert not ok_s and not ok_w, (
            f"seed {seed}: corrupted history accepted "
            f"(strict={ok_s}, windowed={ok_w})")
    emit([{"seeds": len(seeds), "ops_checked": checked,
           "verdicts_agree": 1}],
         "fig_watchdog: windowed vs strict checker agreement")
    return {"agreement_ops": checked}


def main(smoke: bool = False) -> dict:
    derived = {}
    derived.update(chaos_matrix(smoke=smoke))
    derived.update(clean_storms(smoke=smoke))
    derived.update(overhead(smoke=smoke))
    derived.update(agreement(smoke=smoke))
    derived["monitors"] = len(CHAOS_MONITOR)
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short storms (assertions still run; not a "
                         "measurement)")
    args = ap.parse_args()
    main(smoke=args.smoke)
