"""Figure 5: CCDF of write latency — CURP f in {1,2,3} vs original
(synchronous) RAMCloud vs unreplicated.  Paper: 13.8 -> 7.3 us median at
f=3; +0.4 us vs unreplicated."""
from __future__ import annotations

from repro.sim import UniformWriteWorkload, run_scenario

from .common import cdf_points, emit, summarize


def main(n_ops: int = 4000) -> dict:
    rows = []
    series = {}
    for label, mode, f in [
        ("unreplicated", "unreplicated", 0),
        ("curp_f1", "curp", 1),
        ("curp_f2", "curp", 2),
        ("curp_f3", "curp", 3),
        ("original_sync_f3", "sync", 3),
    ]:
        r = run_scenario(mode=mode, f=f, n_clients=1, n_ops=n_ops,
                         op_factory=UniformWriteWorkload(seed=1), seed=42)
        s = summarize(r.update_latencies)
        series[label] = r.update_latencies
        rows.append({"series": label, **s,
                     "fast_frac": r.fast_fraction})
    emit(rows, "fig5: write latency (us), 1 client")
    med_curp = rows[3]["median"]
    med_sync = rows[4]["median"]
    med_unrep = rows[0]["median"]
    derived = {
        "median_curp_f3_us": med_curp,
        "median_sync_us": med_sync,
        "median_unrep_us": med_unrep,
        "speedup_vs_sync": med_sync / med_curp,
        "overhead_vs_unrep_us": med_curp - med_unrep,
        "paper_speedup": 13.8 / 7.3,
        "paper_overhead_us": 0.4,
    }
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    main()
