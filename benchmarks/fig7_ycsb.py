"""Figure 7: YCSB-A/B (zipfian 0.99, 1M items) write latency under CURP.
Paper: ~1% conflicts; conflicting writes complete in 2 RTTs (CCDF kinks at
~14us); latency otherwise unchanged."""
from __future__ import annotations

from repro.sim import YcsbWorkload, run_scenario

from .common import emit, pct, summarize


def main(n_ops: int = 5000) -> dict:
    rows = []
    derived = {}
    for name, read_frac in [("ycsb_a_50w", 0.5), ("ycsb_b_5w", 0.95)]:
        for mode in ("curp", "sync"):
            r = run_scenario(
                mode=mode, f=3, n_clients=1, n_ops=n_ops,
                op_factory=YcsbWorkload(read_fraction=read_frac,
                                        n_items=1_000_000, seed=3),
                seed=5,
            )
            if not r.update_latencies:
                continue
            s = summarize(r.update_latencies)
            rows.append({"workload": name, "mode": mode, **s,
                         "fast_frac": r.fast_fraction})
            if mode == "curp":
                derived[f"{name}_fast_frac"] = r.fast_fraction
                derived[f"{name}_median_us"] = s["median"]
                derived[f"{name}_p99_us"] = s["p99"]
    emit(rows, "fig7: YCSB zipfian(0.99) write latency (us)")
    derived["paper_conflict_frac"] = 0.01
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    main()
