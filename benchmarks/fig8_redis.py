"""Figures 8+9: Redis with CURP — hiding the fsync behind witnesses.

The Redis deployment (Table 1): 10 GbE TCP (syscall-heavy, ~2.5 us/call),
NVMe fsync 50-100 us.  'Durable redis' = fsync before reply (sync mode with
the disk as the lone backup); 'CURP redis' = witnesses give durability while
the AOF fsync happens asynchronously.  Paper: +3 us (12%) median latency vs
non-durable; ~18% throughput cost; durable-original ~10x worse latency."""
from __future__ import annotations

import dataclasses

from repro.sim import SimParams, UniformWriteWorkload, run_scenario

from .common import emit, summarize

REDIS = SimParams(
    one_way_delay_us=10.0,            # TCP/10GbE kernel path
    client_send_cost_us=2.5,          # syscall per RPC (paper §5.4)
    client_record_send_cost_us=2.5,
    client_recv_cost_us=2.5,
    master_update_cost_us=3.0,
    backup_service_us=75.0,           # NVMe fsync 50-100us
    repl_send_cost_us=1.0,
    repl_ack_cost_us=0.5,
    witness_service_us=1.5,
    sync_poll_waste_us=0.0,           # redis blocks the event loop instead
    sync_batch=50,
)


def main(n_ops: int = 1200) -> dict:
    rows = []
    med = {}
    thr = {}
    for label, mode, f in [
        ("nondurable", "unreplicated", 0),
        ("curp_1w", "curp", 1),
        ("curp_2w", "curp", 2),
        ("durable_fsync", "sync", 1),
    ]:
        r = run_scenario(mode=mode, f=f, n_clients=1, n_ops=n_ops,
                         params=REDIS,
                         op_factory=UniformWriteWorkload(seed=1), seed=21)
        s = summarize(r.update_latencies)
        med[label] = s["median"]
        rows.append({"series": label, **s})
        # throughput at 16 clients (fig 9)
        r2 = run_scenario(mode=mode, f=f, n_clients=16, n_ops=max(400, n_ops // 3),
                          params=REDIS,
                          op_factory=UniformWriteWorkload(seed=1), seed=22)
        thr[label] = r2.throughput_ops_per_sec
    emit(rows, "fig8: Redis SET latency (us), 1 client")
    derived = {
        "curp1_overhead_us": med["curp_1w"] - med["nondurable"],
        "curp1_overhead_frac": med["curp_1w"] / med["nondurable"] - 1,
        "durable_vs_curp1": med["durable_fsync"] / med["curp_1w"],
        "paper_overhead_us": 3.0,
        "paper_overhead_frac": 0.12,
        "thr_curp_vs_nondurable": thr["curp_1w"] / thr["nondurable"],
        "paper_thr_cost_frac": 0.18,
    }
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    main()
