"""Transaction figure: the RIFL-identified mini-transaction subsystem
(repro.core.txn) over the per-shard CURP fast paths.

Four claims, measured (the first three asserted, not just reported):

  1. **Atomicity under crashes** — coordinator crashes injected at every
     2PC message stage (prepare-sent / prepared / commit-sent), with and
     without a follow-on participant-master crash: the strict multi-key
     linearizability checker passes and no undecided intent survives
     recovery (run_txn_crash_scenario).
  2. **Single-shard short-circuit** — transactions whose keys land on one
     shard keep the 1-RTT fast path: their fast-path ratio matches the
     fig_scaling level (~1.0 on an uncontended workload), while cross-shard
     transactions pay exactly one extra decide round.
  3. **Transactional kernel probe** — a multi-key witness record resolves in
     ONE device dispatch on accept AND reject (repro.kernels.txn_probe),
     vs 2 dispatches for the record-then-rollback scheme it replaces; and
     the probe is bit-exact with the Python witness's accept/reject
     decisions on collision-heavy multi-key batches (plus slot-for-slot
     with the jnp oracle).
  4. **Abort rate vs contention** — interleaved coordinators over a shrinking
     hot keyset: the intent-lock abort rate rises with contention (reported
     as a sweep).

Throughput view: wall-clock txns/s of all-single-shard vs all-cross-shard
transaction streams (the price of the second round).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (
    DeviceWitness,
    ShardedCluster,
    TxnStatus,
    Witness,
)
from repro.core.txn import abort_op, commit_op, prepare_op
from repro.core.types import Op, OpType
from repro.kernels import (
    WitnessTable,
    dispatch_count,
    ref_witness_record_txn,
    reset_dispatch_count,
    txn_probe,
)
from repro.sim import TXN_CRASH_STAGES, TxnWorkload, run_txn_crash_scenario

from .common import emit


# ---------------------------------------------------------------------------
# 1. atomicity under injected crashes (assertion)
# ---------------------------------------------------------------------------
def check_crash_atomicity(n_txns: int = 12, n_shards: int = 3) -> int:
    """Every 2PC stage x {lazy resolution, participant crash}: strict
    checker green, zero leaked intents.  Raises on violation; returns the
    number of scenarios."""
    cases = 0
    for stage in TXN_CRASH_STAGES:
        for participant_crash in (False, True):
            r = run_txn_crash_scenario(
                stage=stage, n_shards=n_shards, n_txns=n_txns,
                participant_crash=participant_crash, seed=11 + cases,
            )
            assert r.intents_after == 0, \
                f"{stage}: {r.intents_after} intents leaked past recovery"
            assert r.history_ok, \
                f"{stage}: strict checker violation on {r.offending_key}"
            assert r.crashed_decision in ("COMMITTED", "ABORTED"), r
            cases += 1
    return cases


# ---------------------------------------------------------------------------
# 2+throughput. single- vs multi-shard transaction streams
# ---------------------------------------------------------------------------
def txn_throughput(n_txns: int = 200, n_shards: int = 4) -> dict:
    rows = []
    out = {}
    for label, cross in (("single", 0.0), ("cross", 1.0)):
        cluster = ShardedCluster(n_shards=n_shards, f=3, seed=5)
        session = cluster.new_client()
        wl = TxnWorkload(n_shards=n_shards, cross_shard_frac=cross,
                         keys_per_txn=2, seed=9)
        fast = rounds = 0
        t0 = time.perf_counter()
        for _ in range(n_txns):
            writes, reads = wl.next_txn()
            o = cluster.txn(session, writes, reads)
            assert o.status is TxnStatus.COMMITTED
            fast += int(o.fast_path)
            rounds += o.rtts
        wall = time.perf_counter() - t0
        rows.append({
            "stream": label, "txns": n_txns,
            "ktxn_per_s": n_txns / wall / 1e3,
            "mean_rounds": rounds / n_txns,
            "fast_frac": fast / n_txns,
        })
        out[f"{label}_ktxn_per_s"] = n_txns / wall / 1e3
        out[f"{label}_fast_frac"] = fast / n_txns
        out[f"{label}_mean_rounds"] = rounds / n_txns
    emit(rows, "fig_txn: single- vs cross-shard transaction streams")
    return out


# ---------------------------------------------------------------------------
# 3. transactional kernel probe: dispatches + parity (assertions)
# ---------------------------------------------------------------------------
def probe_dispatches() -> dict:
    """One multi-key record = 1 dispatch via the txn probe (accept AND
    reject), vs 2 on the reject path of the record-then-rollback scheme."""
    def fresh():
        w = DeviceWitness(256, 4)
        w.start(master_id=1)
        # Preload a conflicting record so the multi-key op REJECTS: key 7
        # is held by another rpc.
        w.record(1, (7,), (999, 1), Op(OpType.SET, ("c",), ("v",), (999, 1)))
        return w

    multi = Op(OpType.MSET, ("a", "b", "c"), (1, 2, 3), (1000, 1))
    khs = (5, 6, 7)   # key 7 conflicts

    w = fresh()
    reset_dispatch_count()
    st_new = w._record_keys(khs, multi.rpc_id, multi)
    new_reject = dispatch_count()

    w = fresh()
    reset_dispatch_count()
    st_old = w._record_keys_rollback(khs, multi.rpc_id, multi)
    old_reject = dispatch_count()
    assert st_new == st_old, (st_new, st_old)

    w = fresh()
    reset_dispatch_count()
    w._record_keys((5, 6, 8), (1001, 1), multi)   # no conflict: accepts
    new_accept = dispatch_count()
    reset_dispatch_count()
    return {
        "probe_dispatches_accept": new_accept,
        "probe_dispatches_reject": new_reject,
        "rollback_dispatches_reject": old_reject,
    }


def check_probe_parity(n_ops: int = 60, seed: int = 7) -> int:
    """Collision-heavy multi-key batches: the DeviceWitness (txn probe
    kernel) and the Python Witness must agree accept-for-accept, and the
    kernel table must match the jnp oracle slot-for-slot.  Conflicts here
    are same-key collisions (placement-independent), so both backends see
    identical decisions despite their different set mappings."""
    r = np.random.default_rng(seed)
    py = Witness(1024, 4)
    dv = DeviceWitness(1024, 4)
    py.start(1)
    dv.start(1)
    cases = 0
    for i in range(n_ops):
        n_keys = int(r.integers(1, 5))
        khs = tuple(int(k) for k in r.integers(0, 24, n_keys))
        rpc = (50 + i, 1)
        op = Op(OpType.MSET, tuple(f"k{k}" for k in khs),
                tuple(range(n_keys)), rpc)
        st_py = py.record(1, khs, rpc, op)
        st_dv = dv.record(1, khs, rpc, op)
        assert st_py == st_dv, (i, khs, st_py, st_dv)
        # retry idempotence: same rpc, same keys -> same (accepting) verdict
        if st_py.value == "ACCEPTED":
            assert dv.record(1, khs, rpc, op) == py.record(1, khs, rpc, op)
        cases += 1

    # Kernel vs oracle: random ops against one evolving table.
    from repro.kernels.ops import _pad_valid
    from repro.kernels.ref import ref_keyhash2x32
    import jax.numpy as jnp

    table = WitnessTable.empty(64, 4)
    oracle = WitnessTable.empty(64, 4)
    for i in range(n_ops):
        n_keys = int(r.integers(1, 6))
        hi = r.integers(0, 4, n_keys).astype(np.uint32)
        lo = r.integers(0, 4, n_keys).astype(np.uint32)
        res = txn_probe(table, hi, lo)
        table = res.table
        qh, ql = ref_keyhash2x32(jnp.asarray(hi), jnp.asarray(lo))
        qhp, qlp, ownp, valid = _pad_valid(
            n_keys, np.asarray(qh), np.asarray(ql), np.zeros(n_keys, np.int32)
        )
        acc_r, _hit, oracle = ref_witness_record_txn(
            oracle, jnp.asarray(qhp), jnp.asarray(qlp),
            jnp.asarray(ownp), jnp.asarray(valid),
        )
        assert res.accepted == bool(np.asarray(acc_r)[0]), i
        np.testing.assert_array_equal(np.asarray(table.occ),
                                      np.asarray(oracle.occ))
        np.testing.assert_array_equal(np.asarray(table.keys_hi),
                                      np.asarray(oracle.keys_hi))
        np.testing.assert_array_equal(np.asarray(table.keys_lo),
                                      np.asarray(oracle.keys_lo))
        cases += 1
    return cases


# ---------------------------------------------------------------------------
# 4. abort rate vs contention (interleaved coordinators), per conflict policy
# ---------------------------------------------------------------------------
def _interleaved_round(cluster, pairs, policy: str) -> int:
    """Run N coordinators' transactions with prepare legs INTERLEAVED
    leg-by-leg (leg 0 of every txn, then leg 1, ...), under one of two
    intent-conflict policies:

      * ``vote-no``    — any foreign intent refuses the prepare outright
                         (the pre-policy behavior): the txn aborts.
      * ``wound-wait`` — deterministic ordering by txn_id (repro.core.txn):
                         an OLDER (lower-id) txn wounds the younger holder
                         via the safe resolve primitive and retries; a
                         YOUNGER txn parks the leg and retries it after the
                         older holders decide (wait-by-retry).

    Decides in txn_id order (lower first — the deterministic winner), then
    retries parked legs.  Returns the number of aborted transactions.
    """
    from repro.core.txn import resolve_txn

    txns = [
        {"spec": spec, "sess": sess, "votes": {}, "parked": [],
         "dead": False}
        for spec, sess in pairs
    ]
    max_legs = max(len(t["spec"].parts) for t in txns)
    for leg in range(max_legs):
        for t in txns:
            if t["dead"] or leg >= len(t["spec"].parts):
                continue
            part = t["spec"].parts[leg]
            vote = cluster.shards[part.shard_id].txn_prepare(
                t["sess"].session_for(part.shard_id),
                prepare_op(t["spec"], part))
            if vote.granted:
                t["votes"][part.shard_id] = vote
            elif policy == "wound-wait" and vote.error == "TXN_LOCKED" \
                    and vote.blocking is not None:
                if t["spec"].txn_id < vote.blocking.txn_id:
                    # Older: wound the younger holder, retry immediately.
                    resolve_txn(cluster, vote.blocking)
                    vote = cluster.shards[part.shard_id].txn_prepare(
                        t["sess"].session_for(part.shard_id),
                        prepare_op(t["spec"], part))
                    if vote.granted:
                        t["votes"][part.shard_id] = vote
                    else:
                        t["dead"] = True
                else:
                    # Younger: wait-by-retry after the older txns decide.
                    t["parked"].append(part)
            else:
                t["dead"] = True
    aborted = 0
    for t in sorted(txns, key=lambda t: t["spec"].txn_id):
        spec, sess = t["spec"], t["sess"]
        for part in t["parked"]:         # the blockers have decided by now
            vote = cluster.shards[part.shard_id].txn_prepare(
                sess.session_for(part.shard_id), prepare_op(spec, part))
            if vote.granted:
                t["votes"][part.shard_id] = vote
            else:
                t["dead"] = True
        commit = (not t["dead"]
                  and len(t["votes"]) == len(spec.parts)
                  and all(v.granted for v in t["votes"].values()))
        for p in spec.parts:
            op = commit_op(spec, p) if commit else abort_op(spec, p)
            cluster.shards[p.shard_id].txn_decide(
                op, sess.session_for(p.shard_id))
        if not commit:
            aborted += 1
    return aborted


def abort_sweep(n_rounds: int = 40, n_shards: int = 4,
                hot_fracs=(0.0, 0.5, 0.9)) -> tuple:
    """Two coordinators per round with leg-interleaved prepares, swept over
    keyset hotness AND conflict policy: the wound/wait ordering (lower
    txn_id wins, higher waits-by-retry) must cut the abort rate vs the old
    vote-NO-on-any-foreign-intent behavior (ROADMAP follow-on)."""
    rows = []
    rates = {"vote-no": {}, "wound-wait": {}}
    for hot in hot_fracs:
        for policy in ("vote-no", "wound-wait"):
            cluster = ShardedCluster(n_shards=n_shards, f=3, seed=2)
            sa = cluster.new_client()
            sb = cluster.new_client()
            wl = TxnWorkload(n_shards=n_shards, cross_shard_frac=1.0,
                             keys_per_txn=2, hot_frac=hot, hot_items=2,
                             seed=3)
            aborted = 0
            for _ in range(n_rounds):
                wa, _ = wl.next_txn()
                wb, _ = wl.next_txn()
                aborted += _interleaved_round(
                    cluster,
                    [(sa.txn_spec(wa), sa), (sb.txn_spec(wb), sb)],
                    policy,
                )
            assert not any(g.master.store.txn_intents()
                           for g in cluster.shards)
            rate = aborted / (2 * n_rounds)
            rates[policy][hot] = rate
            rows.append({"policy": policy, "hot_frac": hot,
                         "rounds": n_rounds, "abort_rate": rate})
    emit(rows, "fig_txn: abort rate vs contention (interleaved 2PCs, "
               "vote-no vs wound-wait)")
    return rows, rates


# ---------------------------------------------------------------------------
# 5. timed 2PC: concurrent prepare fan-out vs sequential vs per-shard mset
# ---------------------------------------------------------------------------
def timed_rounds(n_txns: int = 60, span: int = 3) -> dict:
    """True 2-round latency in the discrete-event transport: the fan-out
    coordinator (prepare legs concurrent) must beat the sequential baseline
    and cost ~one extra round over the non-atomic per-shard mset."""
    from repro.sim import run_timed_txn_scenario

    out = {}
    rows = []
    for mode in ("mset", "fanout", "sequential"):
        t = run_timed_txn_scenario(mode=mode, n_shards=4, span=span,
                                   n_txns=n_txns, n_clients=2, seed=6)
        rows.append({"mode": mode, "span": span, "mean_us": t.mean_us,
                     "p50_us": t.p50_us, "p99_us": t.p99_us,
                     "committed": t.committed, "aborted": t.aborted})
        out[f"timed_{mode}_us"] = t.mean_us
    emit(rows, "fig_txn: timed 2PC latency (fan-out vs sequential vs mset)")
    out["fanout_speedup_vs_seq"] = (out["timed_sequential_us"]
                                    / max(1e-9, out["timed_fanout_us"]))
    return out


def main(smoke: bool = False) -> dict:
    crash_cases = check_crash_atomicity(n_txns=8 if smoke else 12)
    parity_cases = check_probe_parity(n_ops=30 if smoke else 60)
    disp = probe_dispatches()
    assert disp["probe_dispatches_accept"] == 1, disp
    assert disp["probe_dispatches_reject"] == 1, disp
    assert disp["rollback_dispatches_reject"] == 2, disp

    thr = txn_throughput(n_txns=40 if smoke else 200)
    # Acceptance: single-shard txns keep the 1-RTT fast-path ratio
    # fig_scaling shows for uncontended uniform writes (~1.0).
    assert thr["single_fast_frac"] >= 0.95, thr
    assert thr["single_mean_rounds"] <= 1.05, thr
    assert thr["cross_mean_rounds"] >= 2.0, thr

    timed = timed_rounds(n_txns=20 if smoke else 60)
    # The fan-out coordinator's prepare round is concurrent: a 3-leg txn
    # must be well under the sequential per-leg baseline.
    assert timed["timed_fanout_us"] < timed["timed_sequential_us"], timed

    _rows, rates = abort_sweep(n_rounds=12 if smoke else 40)
    hots = sorted(rates["vote-no"])
    hottest = hots[-1]
    # Wound/wait must not abort MORE at any contention level, and must
    # strictly cut aborts at the hottest setting.
    for h in hots:
        assert rates["wound-wait"][h] <= rates["vote-no"][h], rates
    assert rates["wound-wait"][hottest] < rates["vote-no"][hottest], rates
    derived = {
        "crash_cases": crash_cases,
        "parity_cases": parity_cases,
        "probe_dispatches_reject": disp["probe_dispatches_reject"],
        "rollback_dispatches_reject": disp["rollback_dispatches_reject"],
        **thr,
        **timed,
        **{f"abort_rate_hot{h}": rates["vote-no"][h] for h in hots},
        **{f"ww_abort_rate_hot{h}": rates["wound-wait"][h] for h in hots},
        "abort_monotone": int(rates["vote-no"][hots[0]]
                              <= rates["vote-no"][hottest]),
        "ww_abort_cut": (rates["vote-no"][hottest]
                         - rates["wound-wait"][hottest]),
    }
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny counts (CI wiring + atomicity/parity "
                         "assertions, not a measurement)")
    args = ap.parse_args()
    d = main(smoke=args.smoke)
    if not args.smoke:
        assert d["abort_monotone"] == 1, \
            f"abort rate not monotone in contention: {d}"
