"""Fast-path figure: set-parallel, donated, fused witness pipeline.

Three claims, measured:

  1. **Dispatches/op** — the fused ``fastpath_batch`` op (keyhash2x32 ->
     shard_route -> witness_record -> conflict_scan in one jitted program)
     issues exactly ONE device dispatch per batch; the per-op path pays 3
     dispatches per update (hash, record, scan).  Counted via
     ``repro.kernels.dispatch_count``.
  2. **Records/s vs batch size** — at fixed geometry, fused-path throughput
     grows monotonically with batch size (per-dispatch overhead amortizes;
     the set-parallel kernel's wall-clock scales with the longest per-set
     run, not the batch).  Also swept across table geometries
     (WitnessGeometry) and compared against the pre-refactor sequential
     kernel (witness_record_seq).
  3. **Bit-exactness** — on collision-heavy batches (tiny keyspace: duplicate
     keys in one batch, capacity-full sets) the set-parallel kernel matches
     ``ref_witness_record`` accept-for-accept and slot-for-slot.  Asserted,
     not just reported.

Plus the end-to-end protocol view: ShardedCluster.update_batch driven by a
BatchedWorkload (sim), per-op vs batched client path, python vs device
witness backends.

The device-resident fast path adds two more asserted claims:

  4. **Gang parity** — the kernel-held witness state (rpc/age lanes,
     per-group all-or-nothing probes) matches the Python ``Witness`` oracle
     on the failure paths: RIFL duplicate retries, stale-gc suppression,
     multi-key group rejects, recovery extraction.
  5. **One dispatch per cluster batch** — a warm fused
     ``ShardedCluster.update_batch`` is ONE device dispatch end to end,
     whether the batch lands on one shard or routes across all of them; and
     the recorded steady-state ``proto_device_kops`` clears 5x the
     pre-refactor 0.38 kops baseline.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import WitnessGeometry
from repro.kernels import (
    WitnessTable,
    conflict_scan,
    dispatch_count,
    fastpath_batch,
    keyhash2x32,
    ref_witness_record,
    reset_dispatch_count,
    witness_record,
    witness_record_seq,
)
from repro.sim import run_batched_throughput

from .common import emit

GEOMETRIES = (WitnessGeometry(256, 4), WitnessGeometry(1024, 4),
              WitnessGeometry(1024, 8))
BATCH_SIZES = (64, 512, 4096)


# ---------------------------------------------------------------------------
# 3. parity on collision-heavy batches (assertion, not measurement)
# ---------------------------------------------------------------------------
def check_parity(batch: int = 512) -> int:
    """Bit-exactness of the set-parallel kernel vs the jnp oracle on
    adversarial batches: duplicate keys within one batch, full-set capacity
    rejects, tiny keyspaces.  Raises on any mismatch; returns #cases."""
    r = np.random.default_rng(7)
    cases = 0
    for geo in ((16, 2), (64, 4), (1024, 4)):
        S, W = geo
        for span, kspan in ((8, 4), (S * 2, 8), (S * 8, 2 ** 32 - 1)):
            t = WitnessTable.empty(S, W)
            qh = r.integers(0, kspan, batch).astype(np.uint32)
            ql = r.integers(0, span, batch).astype(np.uint32)
            acc_k, t_k = witness_record(t, qh, ql)
            acc_r, t_r = ref_witness_record(t, qh, ql)
            np.testing.assert_array_equal(np.asarray(acc_k), np.asarray(acc_r))
            np.testing.assert_array_equal(np.asarray(t_k.occ), np.asarray(t_r.occ))
            np.testing.assert_array_equal(
                np.asarray(t_k.keys_hi), np.asarray(t_r.keys_hi))
            np.testing.assert_array_equal(
                np.asarray(t_k.keys_lo), np.asarray(t_r.keys_lo))
            cases += 1
    return cases


# ---------------------------------------------------------------------------
# 4. gang parity: kernel-held rpc/age lanes vs the Python Witness oracle
# ---------------------------------------------------------------------------
def check_gang_parity() -> int:
    """Run identical failure-path scripts through a Python ``Witness`` and a
    ``DeviceWitness`` and assert the observable protocol behaviour matches:
    RIFL duplicate retries accept idempotently, superseded gc entries do not
    collect newer records, multi-key groups reject all-or-nothing, and
    recovery extraction returns the same rpc set.  Raises on divergence;
    returns #cases."""
    from repro.core.device_witness import DeviceWitness
    from repro.core.types import Op, OpType, RecordStatus
    from repro.core.witness import Witness

    def op(rpc: int, *keys, kind=OpType.SET) -> Op:
        return Op(kind, tuple(keys), ("v",), rpc_id=(1, rpc))

    pw, dw = Witness(64, 4), DeviceWitness(64, 4)
    pw.start(0)
    dw.start(0)

    def both(fn):
        a, b = fn(pw), fn(dw)
        assert a == b, f"python={a} device={b}"
        return a

    def rec(o: Op) -> RecordStatus:
        return both(lambda w: w.record(0, o.key_hashes(), o.rpc_id, o))

    cases = 0

    # RIFL duplicate: a client retry (same rpc_id) re-records idempotently;
    # a different rpc on the same key is a commutativity conflict.
    o1 = op(1, "k1")
    assert rec(o1) is RecordStatus.ACCEPTED
    assert rec(o1) is RecordStatus.ACCEPTED           # retry, not a conflict
    assert rec(op(2, "k1")) is RecordStatus.REJECTED
    cases += 1

    # Stale-gc suppression: after (kh, rpc3) is collected and rpc4 claims the
    # key, a replayed gc for rpc3 must not drop rpc4's record.
    o3, o4 = op(3, "k3"), op(4, "k3")
    entry3 = (o3.key_hashes()[0], o3.rpc_id)
    assert rec(o3) is RecordStatus.ACCEPTED
    for w in (pw, dw):
        w.gc((entry3,))                               # collect rpc3
    assert rec(o4) is RecordStatus.ACCEPTED
    for w in (pw, dw):
        w.gc((entry3,))                               # stale replay: no-op
    both(lambda w: w.stats["gc_drops"])
    assert rec(op(5, "k3")) is RecordStatus.REJECTED  # rpc4 must survive
    cases += 1

    # All-or-nothing multi-key group: one conflicting key rejects the whole
    # group and leaves the other keys free.
    assert rec(op(10, "a")) is RecordStatus.ACCEPTED
    assert rec(op(11, "a", "b", kind=OpType.MSET)) is RecordStatus.REJECTED
    assert rec(op(12, "b")) is RecordStatus.ACCEPTED  # no partial insert
    cases += 1

    # Recovery extraction over the whole shared history.
    prpc = both(lambda w: {o.rpc_id for o in w.get_recovery_data(0)})
    assert prpc == {(1, 1), (1, 4), (1, 10), (1, 12)}, prpc
    cases += 1
    return cases


# ---------------------------------------------------------------------------
# 5. cluster dispatch accounting: one dispatch per fused batch, end to end
# ---------------------------------------------------------------------------
def cluster_dispatches(batch: int = 16) -> dict:
    """A warm fused ShardedCluster.update_batch is ONE device dispatch,
    whether the batch stays on one shard or routes across four."""
    from repro.core import ShardedCluster
    from repro.sim.workload import BatchedWorkload

    out = {}
    for label, n_shards in (("single_shard", 1), ("cross_shard", 4)):
        cluster = ShardedCluster(
            n_shards=n_shards, f=3, seed=5, witness_backend="device",
            geometry=WitnessGeometry(256, 4),
        )
        session = cluster.new_client()
        wl = BatchedWorkload(batch_size=batch, seed=5)
        cluster.update_batch(session, wl.batch(session))   # warm the jit cache
        reset_dispatch_count()
        outs = cluster.update_batch(session, wl.batch(session))
        assert all(o.fast_path for o in outs)
        out[f"dispatches_{label}"] = dispatch_count()
        reset_dispatch_count()
    return out


# ---------------------------------------------------------------------------
# 1. dispatch accounting: per-op pipeline vs fused batch
# ---------------------------------------------------------------------------
def count_dispatches(batch: int = 64) -> dict:
    r = np.random.default_rng(3)
    khi = r.integers(0, 2 ** 32, batch).astype(np.uint32)
    klo = r.integers(0, 2 ** 32, batch).astype(np.uint32)
    win = np.zeros(8, np.uint32)
    wv = np.zeros(8, np.int32)

    # Old path: one hash + one record + one conflict scan PER OP.
    t = WitnessTable.empty(1024, 4)
    reset_dispatch_count()
    for i in range(batch):
        qh, ql = keyhash2x32(khi[i:i + 1], klo[i:i + 1])
        _acc, t = witness_record(t, qh, ql)
        _con = conflict_scan(win, win, wv, qh, ql)
    old = dispatch_count()

    # Fused path: ONE dispatch for the whole batch.
    t = WitnessTable.empty(1024, 4)
    reset_dispatch_count()
    res = fastpath_batch(t, khi, klo, window_hi=win, window_lo=win,
                         window_valid=wv)
    jax.block_until_ready(res.accepted)
    new = dispatch_count()
    reset_dispatch_count()
    return {
        "old_dispatches_per_op": old / batch,
        "new_dispatches_per_batch": new,
        "new_dispatches_per_op": new / batch,
    }


# ---------------------------------------------------------------------------
# 2. records/s sweeps
# ---------------------------------------------------------------------------
def _time_calls(fn, reps: int, repeat: int = 3) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = None
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def sweep(batches=BATCH_SIZES, geometries=GEOMETRIES, reps: int = 5) -> tuple:
    r = np.random.default_rng(11)
    rows = []
    recs_by_batch = {}
    base_geo = WitnessGeometry(1024, 4)
    for geo in geometries:
        for B in batches:
            khi = r.integers(0, 2 ** 32, B).astype(np.uint32)
            klo = r.integers(0, 2 ** 32, B).astype(np.uint32)
            table = WitnessTable.empty(geo.n_sets, geo.n_ways)
            fastpath_batch(table, khi, klo)          # warm the jit cache

            def call(table=table, khi=khi, klo=klo):
                return fastpath_batch(table, khi, klo).accepted

            dt = _time_calls(call, reps)
            recs = B / dt
            rows.append({
                "geometry": f"{geo.n_sets}x{geo.n_ways}", "batch": B,
                "us_per_batch": dt * 1e6, "krec_per_s": recs / 1e3,
                "vmem_kib": geo.vmem_bytes / 1024,
            })
            if geo == base_geo:
                recs_by_batch[B] = recs
    # Old (sequential-kernel) path at the base geometry for the comparison.
    seq_rows = []
    for B in batches:
        khi = r.integers(0, 2 ** 32, B).astype(np.uint32)
        klo = r.integers(0, 2 ** 32, B).astype(np.uint32)
        qh, ql = keyhash2x32(khi, klo)
        table = WitnessTable.empty(base_geo.n_sets, base_geo.n_ways)
        witness_record_seq(table, qh, ql)

        def call(table=table, qh=qh, ql=ql):
            return witness_record_seq(table, qh, ql)[0]

        dt = _time_calls(call, max(1, reps // 2))
        seq_rows.append({
            "geometry": f"{base_geo.n_sets}x{base_geo.n_ways}", "batch": B,
            "us_per_batch": dt * 1e6, "krec_per_s": B / dt / 1e3,
        })
    return rows, seq_rows, recs_by_batch


# ---------------------------------------------------------------------------
# End-to-end: batched protocol path (BatchedWorkload -> update_batch)
# ---------------------------------------------------------------------------
def protocol_view(batch_size: int = 64, n_batches: int = 6) -> dict:
    out = {}
    for backend in ("python", "device"):
        r = run_batched_throughput(
            n_shards=2, batch_size=batch_size, n_batches=n_batches,
            witness_backend=backend, geometry=WitnessGeometry(1024, 4),
        )
        out[f"proto_{backend}_kops"] = r.ops_per_sec / 1e3
        out[f"proto_{backend}_fast_frac"] = r.fast_fraction
    return out


def main(smoke: bool = False) -> dict:
    batches = (16, 64) if smoke else BATCH_SIZES
    geometries = GEOMETRIES[:2] if smoke else GEOMETRIES
    parity_cases = check_parity(batch=128 if smoke else 512)
    gang_parity_cases = check_gang_parity()
    disp = count_dispatches(batch=16 if smoke else 64)
    assert disp["new_dispatches_per_batch"] == 1, disp
    assert disp["old_dispatches_per_op"] >= 3, disp
    cdisp = cluster_dispatches()
    assert cdisp["dispatches_single_shard"] == 1, cdisp
    assert cdisp["dispatches_cross_shard"] == 1, cdisp

    rows, seq_rows, recs_by_batch = sweep(
        batches=batches, geometries=geometries, reps=2 if smoke else 5
    )
    emit(rows, "fig_fastpath: fused set-parallel path (records/s)")
    emit(seq_rows, "fig_fastpath: pre-refactor sequential kernel")
    proto = protocol_view(batch_size=16 if smoke else 64,
                          n_batches=3 if smoke else 6)

    bs = sorted(recs_by_batch)
    monotonic = int(all(
        recs_by_batch[a] < recs_by_batch[b] for a, b in zip(bs, bs[1:])
    ))
    derived = {
        "parity_cases": parity_cases,
        "gang_parity_cases": gang_parity_cases,
        "dispatches_per_batch": disp["new_dispatches_per_batch"],
        "old_dispatches_per_op": disp["old_dispatches_per_op"],
        **cdisp,
        f"krec_per_s_b{bs[-1]}": recs_by_batch[bs[-1]] / 1e3,
        "records_monotonic_in_batch": monotonic,
        **proto,
    }
    if not smoke:
        # Steady-state floor: 5x the pre-refactor per-op device path
        # (0.38 kops).  The warmup in run_batched_throughput keeps jit
        # compiles out of the timed window, so this is protocol cost.
        assert derived["proto_device_kops"] >= 5 * 0.38, derived
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep (CI wiring + parity check, not a "
                         "measurement)")
    args = ap.parse_args()
    d = main(smoke=args.smoke)
    if not args.smoke:
        assert d["records_monotonic_in_batch"] == 1, \
            f"records/s not monotone in batch size: {d}"
