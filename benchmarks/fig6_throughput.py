"""Figure 6: single-master write throughput vs number of clients.
Paper: CURP ~4x original RAMCloud (728k vs ~180k writes/s); ~6% below
unreplicated; ~10% below unsafe async."""
from __future__ import annotations

from repro.sim import UniformWriteWorkload, run_scenario

from .common import emit


def main(n_ops: int = 2500) -> dict:
    rows = []
    peak = {}
    for mode, f in [("unreplicated", 0), ("async", 3), ("curp", 3),
                    ("sync", 3)]:
        best = 0.0
        for n_clients in (1, 2, 4, 8, 16, 24):
            r = run_scenario(mode=mode, f=f, n_clients=n_clients,
                             n_ops=n_ops,
                             op_factory=UniformWriteWorkload(seed=1), seed=7)
            rows.append({"mode": mode, "clients": n_clients,
                         "kops_per_s": r.throughput_ops_per_sec / 1e3})
            best = max(best, r.throughput_ops_per_sec)
        peak[mode] = best
    emit(rows, "fig6: throughput vs clients (kops/s)")
    derived = {
        "curp_peak_kops": peak["curp"] / 1e3,
        "curp_vs_sync": peak["curp"] / peak["sync"],
        "curp_vs_async": peak["curp"] / peak["async"],
        "curp_vs_unrep": peak["curp"] / peak["unreplicated"],
        "paper_curp_vs_sync": 4.0,
        "paper_curp_kops": 728.0,
    }
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    main()
