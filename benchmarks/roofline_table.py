"""Roofline table: renders artifacts/dryrun/*.json into the EXPERIMENTS.md
§Roofline markdown + a benchmarks CSV."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

ARCH_ORDER = [
    "smollm-360m", "llama3.2-1b", "deepseek-coder-33b", "nemotron-4-340b",
    "qwen3-moe-30b-a3b", "qwen2-moe-a2.7b", "hubert-xlarge", "qwen2-vl-2b",
    "mamba2-130m", "hymba-1.5b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str = "16x16", tag: Optional[str] = None) -> List[Dict]:
    suffix = mesh if tag is None else f"{mesh}+{tag}"
    out = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            p = ART / f"{arch}__{shape}__{suffix}.json"
            if p.exists():
                out.append(json.loads(p.read_text()))
    return out


def fmt_ms(s: float) -> str:
    return f"{s*1e3:.1f}"


def markdown_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | MODEL_FLOPs | useful/HLO | roofline | fits 16GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP: "
                f"{r['skip_reason']} | — | — | — | — |"
            )
            continue
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |"
            )
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(t['compute_s'])} | "
            f"{fmt_ms(t['memory_s'])} | {fmt_ms(t['collective_s'])} | "
            f"{t['dominant']} | {r['model_flops_total']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{'yes' if r['memory']['fits_16GiB'] else 'NO'} |"
        )
    return "\n".join(lines)


def main() -> dict:
    recs = load("16x16")
    print(markdown_table(recs))
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    derived = {
        "cells_ok": len(ok),
        "cells_skipped": len(skipped),
        "cells_error": len(recs) - len(ok) - len(skipped),
        "mean_roofline": (
            sum(r["roofline_fraction"] for r in ok) / max(len(ok), 1)
        ),
        "fits_all": all(r["memory"]["fits_16GiB"] for r in ok),
    }
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    main()
