"""SLO-survival figure: production traffic armor under open-loop storms.

The paper's evaluation drives closed loops (clients wait for replies, so
offered load self-throttles and overload is invisible).  This figure drives
the OPEN-loop timed workload — Poisson arrivals with diurnal ramps and a
flash crowd, clients that never wait on each other — through three storms,
armor off vs on, and reports p50/p99/p99.9 plus *goodput* (completions
under the latency SLO inside the measure window):

  1. **Overload ramp** (asserted) — ~2x single-master capacity.  Naked, the
     master's RPC queue grows without bound and nothing finishes inside any
     useful deadline; armored (bounded admission queue + explicit shed
     replies + client backoff), goodput must be >= 5x the naked baseline,
     the queue must stay at its bound, and p99 of completions must stay
     bounded by the retry-backoff cap.  A throttled variant shows one hot
     client being rate-limited while the rest keep their share.
  2. **Crash storm** (asserted) — the master is killed SILENTLY mid-run.
     No harness recovery is scheduled: the ConfigManager-side heartbeat
     detector must notice the silence and drive the standard §3.3 recovery
     (recovery_report["detected_by"] == "heartbeat"), with zero lost acked
     writes (per-key checker over the big history; STRICT Wing&Gong checker
     over a small companion run of the same storm).
  3. **Migration storm** (asserted) — a burst of live slot handovers under
     open-loop traffic.  Clients route on a CACHED slot map and pay the
     §3.6 config refetch only when a master answers NOT_OWNER; every move
     must commit, redirects must be observed, and both checkers must pass.

All latencies are simulated µs (see repro/sim/params.py calibration).
"""
from __future__ import annotations

import argparse

from repro.core.overload import ArmorConfig
from repro.sim import (
    OpenLoopWorkload,
    check_linearizable,
    check_linearizable_strict,
    run_openloop_scenario,
)

from .common import emit

# Armor tuning for the figure: a 16-deep admission queue bounds the worst
# in-queue wait to ~21 µs of service, so admitted ops complete well inside
# the SLO while the rest are shed fast and back off.
ARMOR = ArmorConfig(queue_capacity=16)
SLO_US = 200.0


def _row(tag: str, r) -> dict:
    return {
        "run": tag,
        "offered_kops": r.offered_ops_per_sec / 1e3,
        "goodput_kops": r.goodput_ops_per_sec / 1e3,
        "p50_us": r.p50_us,
        "p99_us": r.p99_us,
        "p999_us": r.p999_us,
        "fast_frac": r.fast_fraction,
        "max_qdepth": r.max_qdepth,
        "failed": r.failed,
    }


# ---------------------------------------------------------------------------
# 1. overload ramp: ~2x capacity, armor off vs on (assertions)
# ---------------------------------------------------------------------------
def overload_ramp(smoke: bool = False) -> dict:
    dur = 5_000.0 if smoke else 12_000.0
    # ~2x the calibrated single-master capacity (1/1.3 µs ≈ 0.77 ops/µs),
    # with a diurnal ramp and a 3x flash crowd in the middle of the window.
    def wl():
        return OpenLoopWorkload(
            rate_ops_per_us=1.5, n_clients=200_000,
            diurnal_amplitude=0.25, diurnal_period_us=dur,
            flash_crowds=((0.45 * dur, 0.55 * dur, 3.0),),
            seed=11,
        )

    naked = run_openloop_scenario(workload=wl(), duration_us=dur, f=1,
                                  armor=None, seed=11, slo_us=SLO_US)
    armored = run_openloop_scenario(workload=wl(), duration_us=dur, f=1,
                                    armor=ARMOR, seed=11, slo_us=SLO_US)
    # Adaptive variant: the queue bound is not a hand-tuned constant but an
    # AIMD controller steering depth x p50(service) toward the same ~21 µs
    # worst in-queue wait the static bound was tuned for, fed by the
    # registry's live service-time histogram — the constant is DERIVED from
    # measured service times, so it tracks an op-mix change the static
    # bound would mis-size.
    adaptive_cfg = ArmorConfig(queue_capacity=16, adaptive=True,
                               adaptive_target_delay_us=21.0)
    adaptive = run_openloop_scenario(workload=wl(), duration_us=dur, f=1,
                                     armor=adaptive_cfg, seed=11,
                                     slo_us=SLO_US)
    # Per-client throttling: a hot client owns 30% of arrivals; cap every
    # client at 0.02 ops/µs so it cannot monopolize admission slots.
    thr_cfg = ArmorConfig(queue_capacity=16, throttle_rate=0.02)
    thr_wl = OpenLoopWorkload(
        rate_ops_per_us=1.5, n_clients=200_000, hot_client_frac=0.3, seed=11,
    )
    throttled = run_openloop_scenario(workload=thr_wl, duration_us=dur, f=1,
                                      armor=thr_cfg, seed=11, slo_us=SLO_US)

    emit([_row("naked 2x overload", naked),
          _row("armored", armored),
          _row("armored+adaptive", adaptive),
          _row("armored+throttle", throttled)],
         f"fig_slo: open-loop overload ramp (SLO {SLO_US:.0f} us)")

    p = armored  # alias for the assertions below
    ratio = p.goodput_ops_per_sec / max(1.0, naked.goodput_ops_per_sec)
    assert ratio >= 5.0, (
        f"armored goodput {p.goodput_ops_per_sec:.0f}/s is not >=5x naked "
        f"{naked.goodput_ops_per_sec:.0f}/s")
    assert p.max_qdepth <= ARMOR.queue_capacity, \
        f"admission bound violated: {p.max_qdepth} > {ARMOR.queue_capacity}"
    assert naked.max_qdepth > 10 * ARMOR.queue_capacity, \
        f"naked queue never grew ({naked.max_qdepth}) — ramp not an overload"
    assert p.p99_us <= 2 * 8_000.0, f"armored p99 unbounded: {p.p99_us}"
    assert p.client_stats["sheds_seen"] > 0, "armor never shed"
    assert throttled.armor_stats["shed_throttle"] > 0, \
        "hot client was never throttled"
    # The AIMD bound must not cost goodput vs the hand-tuned static bound
    # under the same 2x overload (it may gain by widening when service
    # times allow).
    adaptive_ratio = (adaptive.goodput_ops_per_sec
                      / max(1.0, p.goodput_ops_per_sec))
    assert adaptive_ratio >= 0.9, (
        f"adaptive admission regressed goodput: "
        f"{adaptive.goodput_ops_per_sec:.0f}/s vs static "
        f"{p.goodput_ops_per_sec:.0f}/s")
    return {
        "adaptive_goodput_kops": adaptive.goodput_ops_per_sec / 1e3,
        "adaptive_vs_static": adaptive_ratio,
        "adaptive_p99_us": adaptive.p99_us,
        "goodput_ratio": ratio,
        "naked_goodput_kops": naked.goodput_ops_per_sec / 1e3,
        "armored_goodput_kops": p.goodput_ops_per_sec / 1e3,
        "armored_p99_us": p.p99_us,
        "naked_max_qdepth": naked.max_qdepth,
        "armored_max_qdepth": p.max_qdepth,
        "sheds": p.client_stats["sheds_seen"],
        "throttle_sheds": throttled.armor_stats["shed_throttle"],
        "deferred_gcs": p.armor_stats["deferred_gcs"],
    }


# ---------------------------------------------------------------------------
# 2. crash storm: silent kill, heartbeat-detected failover (assertions)
# ---------------------------------------------------------------------------
def crash_storm(smoke: bool = False) -> dict:
    dur = 8_000.0 if smoke else 16_000.0
    kill_at = 0.4 * dur
    wl = OpenLoopWorkload(rate_ops_per_us=0.2 if smoke else 0.35,
                          n_clients=50_000, seed=13)
    r = run_openloop_scenario(
        workload=wl, duration_us=dur, f=1, armor=ARMOR, seed=13,
        slo_us=SLO_US, heartbeat=True, fail_master_at={0: kill_at},
        record_history=True,
    )
    emit([_row("crash storm (armored)", r)],
         "fig_slo: silent master kill + heartbeat failover")

    assert r.failovers, "coordinator never detected the silent crash"
    assert r.recoveries and all(
        rep["detected_by"] == "heartbeat" for rep in r.recoveries.values()
    ), f"recovery not heartbeat-driven: {r.recoveries}"
    detect_us = r.failovers[0]["detected_at"] - kill_at
    # Zero lost acked writes: every completed op must be explained by a
    # linearizable order (never-completed ops are "maybes").
    ok, key = check_linearizable(r.history)
    assert ok, f"acked write lost/duplicated across failover (key {key})"
    # Service resumed: ops completed after the recovery point.
    rec_at = max(rep["recovered_at"] for rep in r.recoveries.values())
    after = sum(1 for h in r.history
                if h["complete"] is not None and h["complete"] > rec_at)
    assert after > 0, "no completions after heartbeat-driven recovery"

    # STRICT checker companion: same storm, few clients/keys so the
    # exponential Wing&Gong search is tractable.
    small = run_openloop_scenario(
        workload=OpenLoopWorkload(rate_ops_per_us=0.05, n_clients=6,
                                  n_items=8, seed=5),
        duration_us=8_000.0, f=1, armor=ARMOR, seed=5, slo_us=SLO_US,
        heartbeat=True, fail_master_at={0: 3_000.0}, record_history=True,
    )
    sok, skey = check_linearizable_strict(small.history)
    assert sok, f"strict checker violation in crash storm (key {skey})"
    assert small.recoveries and all(
        rep["detected_by"] == "heartbeat" for rep in small.recoveries.values()
    )
    return {
        "detect_us": detect_us,
        "recovered_at_us": rec_at,
        "completions_after_recovery": after,
        "crash_goodput_kops": r.goodput_ops_per_sec / 1e3,
        "crash_p99_us": r.p99_us,
        "breaker_trips": r.breaker_stats.get("trips", 0),
    }


# ---------------------------------------------------------------------------
# 3. migration storm: burst of live slot handovers (assertions)
# ---------------------------------------------------------------------------
def migration_storm(smoke: bool = False) -> dict:
    dur = 8_000.0 if smoke else 14_000.0
    n_moves = 8 if smoke else 20
    wl = OpenLoopWorkload(rate_ops_per_us=0.4 if smoke else 0.6,
                          n_clients=50_000, seed=17)
    moves = [(0.3 * dur + 200.0 * i, 2 * i, (2 * i + 1) % 2)
             for i in range(n_moves)]
    r = run_openloop_scenario(
        workload=wl, duration_us=dur, f=1, n_shards=2, armor=ARMOR,
        seed=17, migrate_slots=moves, slo_us=SLO_US, record_history=True,
    )
    emit([_row("migration storm (armored)", r)],
         f"fig_slo: {n_moves} live slot handovers under open-loop traffic")

    assert len(r.migrations) == n_moves, \
        f"only {len(r.migrations)}/{n_moves} handovers committed"
    assert r.client_stats["not_owner"] > 0, \
        "no NOT_OWNER redirects — cached slot maps never went stale"
    assert r.client_stats["refetches"] > 0, "no §3.6 config refetches paid"
    assert r.p99_us <= 2 * 8_000.0, f"migration p99 unbounded: {r.p99_us}"
    ok, key = check_linearizable(r.history)
    assert ok, f"write lost/duplicated across slot handover (key {key})"

    # STRICT companion: two moves, tiny key/client space.
    small = run_openloop_scenario(
        workload=OpenLoopWorkload(rate_ops_per_us=0.04, n_clients=5,
                                  n_items=10, seed=19),
        duration_us=6_000.0, f=1, n_shards=2, armor=ARMOR, seed=19,
        migrate_slots=[(2_000.0, 0, 1), (3_000.0, 2, 1)],
        slo_us=SLO_US, record_history=True,
    )
    sok, skey = check_linearizable_strict(small.history)
    assert sok, f"strict checker violation in migration storm (key {skey})"
    return {
        "handovers": len(r.migrations),
        "not_owner_redirects": r.client_stats["not_owner"],
        "map_refetches": r.client_stats["refetches"],
        "migration_goodput_kops": r.goodput_ops_per_sec / 1e3,
        "migration_p99_us": r.p99_us,
        "keys_moved": sum(m["keys_moved"] for m in r.migrations),
        "rifl_moved": sum(m["rifl_moved"] for m in r.migrations),
    }


def main(smoke: bool = False) -> dict:
    ramp = overload_ramp(smoke=smoke)
    crash = crash_storm(smoke=smoke)
    mig = migration_storm(smoke=smoke)
    derived = {**ramp, **crash, **mig}
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short storms (armor/failover/handover assertions "
                         "still run; not a measurement)")
    args = ap.parse_args()
    main(smoke=args.smoke)
