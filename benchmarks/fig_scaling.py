"""Scaling figure (sharding, §4/Fig. 3): aggregate committed-ops/s and
fast-path ratio vs shard count, uniform and shard-skewed workloads.

Each shard is a full CURP group (master + f witnesses + f backups) in one
simulated network; clients route by the protocol's KeyRouter.  Expected
shape: aggregate throughput grows monotonically with shards on a uniform
workload while the fast-path ratio stays at the single-shard level (disjoint
partitions can't conflict more by being split); a hot-shard skew caps the
gain at the hot master's capacity — the case witness migration / resharding
(ROADMAP) would address.
"""
from __future__ import annotations

import argparse

from repro.sim import ShardSkewedWorkload, UniformWriteWorkload, run_sharded_scenario

from .common import emit

SHARD_COUNTS = (1, 2, 4)


def main(n_ops: int = 1200, n_clients: int = 16) -> dict:
    rows = []
    thr = {}
    fast = {}
    for n_shards in SHARD_COUNTS:
        r = run_sharded_scenario(
            n_shards=n_shards, mode="curp", f=3, n_clients=n_clients,
            n_ops=n_ops, op_factory=UniformWriteWorkload(seed=1), seed=7,
        )
        thr[n_shards] = r.throughput_ops_per_sec
        fast[n_shards] = r.fast_fraction
        rows.append({
            "workload": "uniform", "shards": n_shards,
            "kops_per_s": r.throughput_ops_per_sec / 1e3,
            "fast_frac": r.fast_fraction,
        })
    skew = {}
    for n_shards in SHARD_COUNTS:
        r = run_sharded_scenario(
            n_shards=n_shards, mode="curp", f=3, n_clients=n_clients,
            n_ops=n_ops,
            op_factory=ShardSkewedWorkload(
                n_shards=n_shards, hot_frac=0.8,
                n_items=max(4000, 1000 * n_shards), seed=2,
            ),
            seed=7,
        )
        skew[n_shards] = r.throughput_ops_per_sec
        rows.append({
            "workload": "skew80", "shards": n_shards,
            "kops_per_s": r.throughput_ops_per_sec / 1e3,
            "fast_frac": r.fast_fraction,
        })
    emit(rows, "fig_scaling: throughput & fast-path vs shard count")
    hi = SHARD_COUNTS[-1]
    derived = {
        "thr_1shard_kops": thr[1] / 1e3,
        f"thr_{hi}shard_kops": thr[hi] / 1e3,
        f"speedup_{hi}x": thr[hi] / thr[1],
        "monotonic": int(all(thr[a] < thr[b] for a, b in
                             zip(SHARD_COUNTS, SHARD_COUNTS[1:]))),
        f"fast_ratio_{hi}_vs_1": fast[hi] / fast[1],
        f"skew_speedup_{hi}x": skew[hi] / skew[1],
    }
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny op counts (CI wiring check, not a measurement)")
    args = ap.parse_args()
    if args.smoke:
        d = main(n_ops=120, n_clients=8)
    else:
        d = main()
    assert d["monotonic"] == 1, f"throughput not monotonic in shards: {d}"
