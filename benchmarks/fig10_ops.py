"""Figure 10: median latency by Redis command type (SET / HMSET / INCR)
with and without CURP witnesses — CURP applies to every update type whose
commutativity is key-determined (§5.5)."""
from __future__ import annotations

import random

from repro.core.client import ClientSession
from repro.core.types import Op
from repro.sim import run_scenario

from .common import emit
from .fig8_redis import REDIS


def op_factory_for(kind: str, seed: int = 0):
    rng = random.Random(seed)

    def factory(session: ClientSession) -> Op:
        key = f"u{rng.randrange(2_000_000)}"
        if kind == "SET":
            return session.op_set(key, "x" * 100)
        if kind == "HMSET":
            return session.op_hmset(key, [("f", "x" * 100)])
        if kind == "INCR":
            return session.op_incr(key)
        raise ValueError(kind)

    return factory


def main(n_ops: int = 800) -> dict:
    rows = []
    derived = {}
    for kind in ("SET", "HMSET", "INCR"):
        for label, mode, f in [("nondurable", "unreplicated", 0),
                               ("curp_1w", "curp", 1),
                               ("curp_2w", "curp", 2)]:
            r = run_scenario(mode=mode, f=f, n_clients=1, n_ops=n_ops,
                             params=REDIS,
                             op_factory=op_factory_for(kind), seed=31)
            import statistics

            m = statistics.median(r.update_latencies)
            rows.append({"cmd": kind, "series": label, "median_us": m})
            derived[f"{kind}_{label}"] = m
    # Command types are priced differently (SimParams.op_cost_extra_us);
    # identical medians across commands would mean the per-op cost model
    # regressed to the flat master_update_cost_us again.
    for label in ("nondurable", "curp_1w", "curp_2w"):
        incr, st, hm = (derived[f"{k}_{label}"]
                        for k in ("INCR", "SET", "HMSET"))
        assert incr < st < hm, (
            f"fig10 {label}: expected INCR < SET < HMSET medians, "
            f"got {incr} / {st} / {hm}"
        )
    emit(rows, "fig10: latency by command type (us)")
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    main()
