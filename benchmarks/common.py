"""Shared benchmark helpers."""
from __future__ import annotations

import statistics
from typing import Dict, List, Sequence


def pct(xs: Sequence[float], p: float) -> float:
    xs = sorted(xs)
    i = min(len(xs) - 1, max(0, int(p * len(xs))))
    return xs[i]


def cdf_points(xs: Sequence[float], n: int = 20) -> List[tuple]:
    xs = sorted(xs)
    out = []
    for k in range(n + 1):
        q = k / n
        out.append((q, xs[min(len(xs) - 1, int(q * len(xs)))]))
    return out


def summarize(xs: Sequence[float]) -> Dict[str, float]:
    return {
        "median": statistics.median(xs),
        "mean": statistics.fmean(xs),
        "p90": pct(xs, 0.90),
        "p99": pct(xs, 0.99),
        "p999": pct(xs, 0.999),
    }


def emit(rows: List[Dict], title: str) -> None:
    if not rows:
        return
    keys = list(rows[0].keys())
    print(f"\n== {title} ==")
    print(",".join(keys))
    for r in rows:
        print(",".join(
            f"{v:.2f}" if isinstance(v, float) else str(v) for v in r.values()
        ))
