"""Shared benchmark helpers."""
from __future__ import annotations

import math
import statistics
from typing import Dict, List, Sequence


def pct(xs: Sequence[float], p: float) -> float:
    """Nearest-rank percentile: the smallest sample whose empirical CDF is
    >= p, i.e. xs_sorted[ceil(p * n) - 1].  (The previous ``int(p * len)``
    indexing truncated instead of rounding the rank up, which biased p90/p99
    one sample high on small samples — e.g. p90 of 10 samples returned the
    maximum instead of the 9th value.)"""
    if not xs:
        raise ValueError("pct() of empty sequence")
    xs = sorted(xs)
    n = len(xs)
    if p <= 0:
        return xs[0]
    return xs[min(n, max(1, math.ceil(p * n))) - 1]


def cdf_points(xs: Sequence[float], n: int = 20) -> List[tuple]:
    xs = sorted(xs)
    out = []
    for k in range(n + 1):
        q = k / n
        out.append((q, xs[min(len(xs) - 1, int(q * len(xs)))]))
    return out


def summarize(xs: Sequence[float]) -> Dict[str, float]:
    return {
        "median": statistics.median(xs),
        "mean": statistics.fmean(xs),
        "p90": pct(xs, 0.90),
        "p99": pct(xs, 0.99),
        "p999": pct(xs, 0.999),
    }


def emit(rows: List[Dict], title: str) -> None:
    if not rows:
        return
    keys = list(rows[0].keys())
    print(f"\n== {title} ==")
    print(",".join(keys))
    for r in rows:
        print(",".join(
            f"{v:.2f}" if isinstance(v, float) else str(v) for v in r.values()
        ))
