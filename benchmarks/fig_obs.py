"""Flight-recorder figure: where does tail latency go, and what does
watching cost?

Two halves, both asserted:

1. **Stage attribution** — re-run the three fig_slo storms (overload ramp,
   silent-crash failover, migration burst) with a full-sampling ``Tracer``
   attached to the sim and the metrics registry reset per storm.  Every
   storm must emit a Perfetto-loadable Chrome-trace JSON
   (``benchmarks/out/trace_<storm>.json``) with zero leaked (unclosed)
   spans and fully-resolvable parent ids, plus a registry snapshot.  The
   report body is ``stage_attribution``: per-stage µs attributed to the
   p99 tail cohort vs the full population, so "p99 is queueing, not
   witness work" is a number, not a guess.

2. **Overhead** — the whole point of keeping telemetry on by default is
   that it is nearly free.  Measure the wall-clock device fast path
   (``run_batched_throughput``, the fig_fastpath ``proto_device_kops``
   quantity) in three modes — registry disabled, registry on, registry on
   + tracing at 5% sampling — best-of-N interleaved, and assert the
   registry-only and sampled-tracing modes keep >=95% of the disabled
   throughput (<5% overhead).  Smoke mode keeps the assertion but loosens
   the bar: single short reps on a shared CI box measure noise, not cost.

All simulated latencies are µs; the overhead half is real wall clock.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.core import telemetry
from repro.core.overload import ArmorConfig
from repro.core.telemetry import Tracer, stage_attribution
from repro.sim import (
    OpenLoopWorkload,
    run_batched_throughput,
    run_openloop_scenario,
)

from .common import emit

OUT_DIR = pathlib.Path(__file__).resolve().parent / "out"
ARMOR = ArmorConfig(queue_capacity=16)
SLO_US = 200.0


# ---------------------------------------------------------------------------
# 1. storm traces + stage attribution
# ---------------------------------------------------------------------------
def _storm_configs(smoke: bool):
    """The fig_slo storms, armored variants only (the traced production
    configuration; the naked baseline has nothing to attribute)."""
    dur_o = 4_000.0 if smoke else 10_000.0
    dur_c = 6_000.0 if smoke else 12_000.0
    dur_m = 6_000.0 if smoke else 12_000.0
    return {
        "overload": dict(
            workload=OpenLoopWorkload(
                rate_ops_per_us=1.5, n_clients=200_000,
                diurnal_amplitude=0.25, diurnal_period_us=dur_o,
                flash_crowds=((0.45 * dur_o, 0.55 * dur_o, 3.0),), seed=11,
            ),
            duration_us=dur_o, f=1, armor=ARMOR, seed=11, slo_us=SLO_US,
        ),
        "crash": dict(
            workload=OpenLoopWorkload(rate_ops_per_us=0.2, n_clients=50_000,
                                      seed=13),
            duration_us=dur_c, f=1, armor=ARMOR, seed=13, slo_us=SLO_US,
            heartbeat=True, fail_master_at={0: 0.4 * dur_c},
        ),
        "migration": dict(
            workload=OpenLoopWorkload(rate_ops_per_us=0.4, n_clients=50_000,
                                      seed=17),
            duration_us=dur_m, f=1, n_shards=2, armor=ARMOR, seed=17,
            migrate_slots=[(0.3 * dur_m + 200.0 * i, 2 * i, (2 * i + 1) % 2)
                           for i in range(6)],
            slo_us=SLO_US,
        ),
    }


def _check_trace(tracer: Tracer, storm: str) -> None:
    """Well-formedness: no leaked spans, every parent id resolves."""
    leaked = tracer.open_spans()
    assert not leaked, (
        f"{storm}: {len(leaked)} spans leaked unclosed "
        f"(first: {leaked[0].name})")
    ids = {s.span_id for s in tracer.spans}
    for s in tracer.spans:
        assert s.parent is None or s.parent in ids, \
            f"{storm}: span {s.span_id} ({s.name}) has dangling parent"


def storm_traces(smoke: bool = False) -> dict:
    OUT_DIR.mkdir(exist_ok=True)
    rows, derived = [], {}
    for storm, cfg in _storm_configs(smoke).items():
        telemetry.reset_registry()
        tracer = Tracer(sample=1.0)
        r = run_openloop_scenario(tracer=tracer, **cfg)
        _check_trace(tracer, storm)

        path = OUT_DIR / f"trace_{storm}.json"
        tracer.export_chrome(str(path))
        # Round-trip: the artifact a human loads into Perfetto must parse.
        doc = json.loads(path.read_text())
        assert doc["traceEvents"], f"{storm}: empty trace export"

        att = stage_attribution(tracer, tail_q=0.99)
        snap = telemetry.registry().snapshot()
        rows.append(({
            "storm": storm,
            "ops": att["n_ops"],
            "p99_us": att["p99_us"],
            "spans": len(tracer.spans),
            "events": len(doc["traceEvents"]),
        }, att["stages_tail"]))
        derived[f"{storm}_p99_us"] = att["p99_us"]
        derived[f"{storm}_spans"] = len(tracer.spans)
        # Tail attribution: which stage dominates the p99 cohort.
        if att["stages_tail"]:
            top = max(att["stages_tail"].items(), key=lambda kv: kv[1])
            derived[f"{storm}_tail_stage"] = top[0]
            derived[f"{storm}_tail_stage_us"] = top[1]
        derived[f"{storm}_snapshot"] = snap
        # Every storm must exercise the full pipeline: client root spans
        # plus witness + master child stages.
        names = {s.name for s in tracer.spans}
        assert {"op", "witness_record", "master_update"} <= names, \
            f"{storm}: missing pipeline stages (saw {sorted(names)})"
    # Normalize stage columns across storms (emit assumes uniform keys).
    stage_names = sorted({k for _fixed, st in rows for k in st})
    emit([{**fixed, **{f"tail_{k}_us": st.get(k, 0.0) for k in stage_names}}
          for fixed, st in rows],
         "fig_obs: p99 attribution by stage (tail cohort, us)")
    return derived


# ---------------------------------------------------------------------------
# 2. telemetry overhead on the device fast path
# ---------------------------------------------------------------------------
def _device_kops(tracer=None) -> float:
    from repro.core import WitnessGeometry

    r = run_batched_throughput(
        n_shards=2, batch_size=64, n_batches=4, witness_backend="device",
        geometry=WitnessGeometry(1024, 4), tracer=tracer,
    )
    return r.ops_per_sec / 1e3


def overhead(smoke: bool = False) -> dict:
    reps = 2 if smoke else 4
    modes = {"off": None, "registry": None, "traced": None}
    best = {m: 0.0 for m in modes}
    # Interleave reps across modes so drift (thermal, noisy neighbours)
    # hits all three alike; keep best-of-N per mode (canonical wall-clock
    # discipline: minimum is the least-noise estimate of the true cost).
    for _ in range(reps):
        for mode in modes:
            if mode == "off":
                telemetry.disable()
                kops = _device_kops()
                telemetry.enable()
            elif mode == "registry":
                kops = _device_kops()
            else:
                kops = _device_kops(tracer=Tracer(sample=0.05))
            best[mode] = max(best[mode], kops)
    reg_ratio = best["registry"] / max(best["off"], 1e-9)
    trc_ratio = best["traced"] / max(best["off"], 1e-9)
    emit([{"mode": m, "best_kops": v,
           "vs_off": v / max(best["off"], 1e-9)} for m, v in best.items()],
         "fig_obs: telemetry overhead on device fast path (wall clock)")
    # <5% overhead budget.  Smoke runs 2 short reps on shared CI — the
    # spread there is scheduler noise, so only a gross regression fails.
    floor = 0.70 if smoke else 0.95
    assert reg_ratio >= floor, (
        f"registry overhead too high: {best['registry']:.1f} vs "
        f"{best['off']:.1f} kops ({(1 - reg_ratio) * 100:.1f}%)")
    assert trc_ratio >= floor, (
        f"sampled tracing overhead too high: {best['traced']:.1f} vs "
        f"{best['off']:.1f} kops ({(1 - trc_ratio) * 100:.1f}%)")
    return {
        "off_kops": best["off"],
        "registry_kops": best["registry"],
        "traced_kops": best["traced"],
        "registry_ratio": reg_ratio,
        "traced_ratio": trc_ratio,
    }


def main(smoke: bool = False) -> dict:
    storms = storm_traces(smoke=smoke)
    ovh = overhead(smoke=smoke)
    derived = {**ovh}
    for k, v in storms.items():
        if k.endswith("_snapshot"):
            continue  # full snapshots are too wide for the CSV line
        derived[k] = v
    # Registry snapshots ride along in BENCH_curp.json under one key so the
    # counters (sheds, breaker trips, dup hits, reason codes...) are
    # machine-diffable across PRs without polluting the summary CSV.
    derived["snapshots"] = {
        k[: -len("_snapshot")]: v
        for k, v in storms.items() if k.endswith("_snapshot")
    }
    print("derived:", {k: v for k, v in derived.items() if k != "snapshots"})
    return derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short storms + loose overhead bar (assertions "
                         "still run; not a measurement)")
    args = ap.parse_args()
    main(smoke=args.smoke)
