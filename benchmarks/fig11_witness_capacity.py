"""Figure 11 / Appendix B.1: expected recordings before a witness-slot
conflict, by associativity — Monte Carlo over random keys driven through the
PALLAS witness_record kernel (vmapped tables).  Paper: direct-mapped 4096
slots conflicts after ~80 inserts; 4-way associativity fixes it."""
from __future__ import annotations

import numpy as np

from repro.kernels import WitnessTable, witness_record

from .common import emit


def inserts_to_first_reject(ways: int, slots: int, seed: int) -> int:
    rng = np.random.default_rng(seed)
    t = WitnessTable.empty(slots // ways, ways)
    n = slots * 2
    qh = rng.integers(0, 2**32, n, dtype=np.uint32)
    ql = rng.integers(0, 2**32, n, dtype=np.uint32)
    acc, _ = witness_record(t, qh, ql)
    acc = np.asarray(acc)
    rejects = np.where(acc == 0)[0]
    return int(rejects[0]) if len(rejects) else n


def main(slots: int = 4096, trials: int = 12) -> dict:
    rows = []
    derived = {}
    for ways in (1, 2, 4, 8):
        xs = [inserts_to_first_reject(ways, slots, s) for s in range(trials)]
        mean = float(np.mean(xs))
        rows.append({"ways": ways, "slots": slots,
                     "mean_inserts_to_conflict": mean})
        derived[f"ways{ways}"] = mean
    emit(rows, "fig11: witness capacity vs associativity")
    derived["paper_direct_mapped"] = 80.0
    derived["assoc4_vs_direct"] = derived["ways4"] / derived["ways1"]
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    main()
