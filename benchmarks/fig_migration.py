"""Live-reconfiguration figure: slot-based routing + online shard migration
(repro.core.migration) and the hot-shard auto-split.

Four claims, the first three asserted:

  1. **Zero lost/duplicated writes under a live reshard** — a 2 -> 4 shard
     slot handover runs under continuous client traffic (plus donor- and
     receiver-crash mid-handover variants): a shadow map catches any
     lost/duplicated write, every redirected (SlotMoving) write lands on
     re-issue, and the STRICT multi-key linearizability checker passes over
     the full history (run_migration_scenario).
  2. **Untouched slots never leave the 1-RTT fast path** — the fast-path
     ratio of ops on non-moving slots during the migration stays within 5%
     of the pre-reshard steady state (per-window timeline reported).
  3. **Routing parity** — the Pallas ``shard_route`` table gather matches
     the Python ``SlotRouter`` bit-for-bit on random slot maps (including
     mid-migration-shaped ones), and the round-robin default map matches
     the legacy mod-N placement for power-of-two shard counts.
  4. **Hot-shard auto-split beats the static skew80 line** — per-slot op
     counters from a skewed instant-cluster run feed ``rebalance``; the
     rebalanced slot map re-runs fig_scaling's skew80 scenario in the timed
     sim and must beat the static-placement throughput (the scaling cap the
     ROADMAP called out).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import ShardedCluster, SlotRouter
from repro.core.types import keyhash
from repro.kernels import shard_route
from repro.sim import (
    ShardSkewedWorkload,
    run_migration_scenario,
    run_sharded_scenario,
)

from .common import emit


# ---------------------------------------------------------------------------
# 3. routing parity on random slot maps (assertion)
# ---------------------------------------------------------------------------
def check_route_parity(n_keys: int = 400, seed: int = 5) -> int:
    """SlotRouter <-> shard_route bit-exactness on random slot maps."""
    r = np.random.default_rng(seed)
    keys = [f"user{i}" for i in range(n_keys)] + list(range(64))
    khs = [keyhash(k) for k in keys]
    hi = np.array([(h >> 32) & 0xFFFFFFFF for h in khs], np.uint32)
    lo = np.array([h & 0xFFFFFFFF for h in khs], np.uint32)
    cases = 0
    for n_slots in (64, 256):
        for n_shards in (2, 3, 4, 7):
            slot_map = r.integers(0, n_shards, n_slots).astype(np.int32)
            router = SlotRouter(list(slot_map), n_shards=n_shards)
            dev = np.asarray(shard_route(hi, lo, slot_map=slot_map))
            py = np.array([router.shard_of(k) for k in keys])
            np.testing.assert_array_equal(dev, py)
            cases += 1
    # The round-robin default map == legacy mod-N for pow2 shard counts
    # (the pre-slot-map placement this change must not disturb).
    legacy_low = np.array([_mix_low(h) for h in khs], np.uint64)
    for n_shards in (1, 2, 4):
        dev = np.asarray(shard_route(hi, lo, n_shards))
        np.testing.assert_array_equal(dev, (legacy_low % n_shards)
                                      .astype(np.int32))
        cases += 1
    return cases


def _mix_low(kh64: int) -> int:
    from repro.core.shard import _M32, mix2x32

    _, h3 = mix2x32((kh64 >> 32) & _M32, kh64 & _M32)
    return h3


# ---------------------------------------------------------------------------
# 1+2. live reshard timeline under continuous traffic (assertions)
# ---------------------------------------------------------------------------
def live_reshard(smoke: bool = False) -> dict:
    ops = 16 if smoke else 30
    keys = 80 if smoke else 160
    out = {}
    rows = []
    for crash in (None, "donor", "receiver"):
        r = run_migration_scenario(
            n_shards_before=2, n_shards_after=4, n_slots=64,
            ops_per_window=ops, n_keys=keys, crash=crash,
            seed=3 if crash is None else 7,
        )
        tag = crash or "clean"
        assert r.mismatches == 0, f"{tag}: {r.mismatches} lost/dup writes"
        assert r.history_ok, \
            f"{tag}: strict checker violation on {r.offending_key}"
        if crash is not None:
            assert r.resumed >= 1, f"{tag}: crash never hit the handover"
        drop = r.steady_fast - r.migration_fast_untouched
        assert drop <= 0.05, \
            f"{tag}: untouched-slot fast ratio dropped {drop:.3f} (>5%)"
        out[f"{tag}_redirects"] = r.redirects
        out[f"{tag}_fast_drop"] = drop
        if crash is None:
            out["steady_fast"] = r.steady_fast
            out["migration_fast_untouched"] = r.migration_fast_untouched
            out["keys_moved"] = sum(rep.keys_moved for rep in r.reports)
            out["rifl_moved"] = sum(rep.rifl_moved for rep in r.reports)
            rows = [
                {"phase": w["phase"], "t": w["t"], "ops": w["ops"],
                 "fast": (f"{w['fast_frac']:.2f}"
                          if w["fast_frac"] is not None else "-"),
                 "fast_untouched": (f"{w['fast_frac_untouched']:.2f}"
                                    if w["fast_frac_untouched"] is not None
                                    else "-"),
                 "redirects": w["redirects"]}
                for w in r.windows
            ]
    emit(rows, "fig_migration: live 2->4 reshard timeline (clean run)")
    return out


# ---------------------------------------------------------------------------
# 4. hot-shard auto-split vs the static skew80 line (assertion)
# ---------------------------------------------------------------------------
def skew_rebalance(smoke: bool = False) -> dict:
    n_shards = 4
    # Feed the per-slot counters with the SAME skewed workload fig_scaling
    # uses, through a real instant cluster, then auto-rebalance.
    cluster = ShardedCluster(n_shards=n_shards, f=3, seed=7)
    wl = ShardSkewedWorkload(n_shards=n_shards, hot_frac=0.8,
                             n_items=max(4000, 1000 * n_shards), seed=2)
    session = cluster.new_client()
    for _ in range(200 if smoke else 1200):
        cluster.update(session, wl(session))
    loads = cluster.slot_loads()
    hot_share_before = sum(
        loads[s] for s in cluster.router.slots_of_shard(0)
    ) / max(1, sum(loads))
    plan = cluster.rebalance(max_moves=128)
    moved = sum(len(v) for v in plan["moves"].values())
    rebalanced = SlotRouter(list(cluster.router.slot_map),
                            n_shards=n_shards)

    # Timed sim: fig_scaling's skew80 parameters, static vs rebalanced map.
    n_ops, n_clients = (120, 8) if smoke else (1200, 16)
    common = dict(
        n_shards=n_shards, mode="curp", f=3, n_clients=n_clients,
        n_ops=n_ops, seed=7,
        op_factory=ShardSkewedWorkload(
            n_shards=n_shards, hot_frac=0.8,
            n_items=max(4000, 1000 * n_shards), seed=2,
        ),
    )
    static = run_sharded_scenario(**common)
    common["op_factory"] = ShardSkewedWorkload(
        n_shards=n_shards, hot_frac=0.8,
        n_items=max(4000, 1000 * n_shards), seed=2,
    )
    rebal = run_sharded_scenario(router=rebalanced, **common)
    emit([
        {"placement": "static skew80", "kops_per_s":
            static.throughput_ops_per_sec / 1e3,
         "fast_frac": static.fast_fraction},
        {"placement": "auto-rebalanced", "kops_per_s":
            rebal.throughput_ops_per_sec / 1e3,
         "fast_frac": rebal.fast_fraction},
    ], "fig_migration: skew80 throughput, static vs auto-rebalanced slots")
    return {
        "slots_moved": moved,
        "hot_share_before": hot_share_before,
        "skew_static_kops": static.throughput_ops_per_sec / 1e3,
        "skew_rebal_kops": rebal.throughput_ops_per_sec / 1e3,
        "rebal_speedup": (rebal.throughput_ops_per_sec /
                          max(1e-9, static.throughput_ops_per_sec)),
    }


def main(smoke: bool = False) -> dict:
    parity_cases = check_route_parity()
    reshard = live_reshard(smoke=smoke)
    skew = skew_rebalance(smoke=smoke)
    assert skew["slots_moved"] > 0, skew
    assert skew["rebal_speedup"] > 1.0, \
        f"rebalance did not beat static skew80: {skew}"
    derived = {"parity_cases": parity_cases, **reshard, **skew}
    print("derived:", derived)
    return derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny counts (CI wiring + atomicity/parity/"
                         "fast-ratio assertions, not a measurement)")
    args = ap.parse_args()
    main(smoke=args.smoke)
