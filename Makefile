.PHONY: check test bench-scaling

check:
	bash scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench-scaling:
	PYTHONPATH=src python -m benchmarks.fig_scaling
