.PHONY: check test bench-scaling bench-fastpath bench-txn bench-migration bench-crdt bench-slo bench-watchdog bench-gate

check:
	bash scripts/check.sh

test:
	PYTHONPATH=src python -m pytest -x -q

bench-scaling:
	PYTHONPATH=src python -m benchmarks.fig_scaling

bench-fastpath:
	PYTHONPATH=src python -m benchmarks.fig_fastpath

bench-txn:
	PYTHONPATH=src python -m benchmarks.fig_txn

bench-migration:
	PYTHONPATH=src python -m benchmarks.fig_migration

bench-crdt:
	PYTHONPATH=src python -m benchmarks.fig_crdt

bench-slo:
	PYTHONPATH=src python -m benchmarks.fig_slo

bench-watchdog:
	PYTHONPATH=src python -m benchmarks.fig_watchdog

bench-gate:
	python scripts/bench_gate.py
