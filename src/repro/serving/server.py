"""CurpServeDriver: batched autoregressive serving with CURP-durable
sessions.

The serving master is speculative state (model KV caches + live sessions);
durability comes from (a) witness-recorded session commits (1 RTT) and (b)
batched backup syncs — both via CurpSessionStore.  After a master crash the
driver restores sessions from the recovered store and REBUILDS the KV caches
by re-prefilling each live session's tokens (the compute-for-durability
trade CURP makes: journal bytes are tiny because state is recomputable).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import WitnessGeometry
from repro.core.telemetry import get_registry
from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_decode_cache, init_params

from .kvstore import CurpSessionStore, SessionState


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 128
    commit_every: int = 1      # session commits per generated token
    f: int = 3
    sync_batch: int = 50
    n_shards: int = 1          # session partitions (one master group each)
    # Slot-table size for the session router: the unit of live migration
    # (CurpSessionStore.migrate_sessions / rebalance moves slots between
    # master groups with no serving pause on untouched slots).
    n_slots: int = 256
    # Witness table shape (S x W), threaded down to the Pallas kernels.
    witness_geometry: WitnessGeometry = field(default_factory=WitnessGeometry)
    # "python" (protocol-reference slot walk) or "device" (set-parallel
    # kernel; one dispatch per commit batch).
    witness_backend: str = "python"
    # Commit each decode step's sessions as ONE atomic cross-shard
    # mini-transaction (CurpSessionStore.txn) instead of the per-session
    # durable batch: a crash can never persist half a step's sessions.
    atomic_step_commit: bool = False


class CurpServeDriver:
    def __init__(self, cfg: ModelConfig, serve: ServeConfig,
                 params=None, seed: int = 0) -> None:
        assert cfg.can_decode, "serving needs a decoder"
        self.cfg = cfg
        self.serve = serve
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed)
        )
        self.store = CurpSessionStore(f=serve.f, sync_batch=serve.sync_batch,
                                      n_shards=serve.n_shards,
                                      geometry=serve.witness_geometry,
                                      witness_backend=serve.witness_backend,
                                      n_slots=serve.n_slots)
        self.sessions: Dict[str, SessionState] = {}
        self._decode = jax.jit(
            lambda p, b, c: decode_step(cfg, p, b, c)
        )
        self._reset_cache()
        self.tokens_served = 0
        reg = get_registry()
        self._m_tokens = reg.counter("serve.tokens")
        self._h_commit = reg.histogram("serve.commit_sessions")
        self._m_recoveries = reg.counter("serve.recoveries")
        self._m_replayed = reg.counter("serve.replayed_ops")

    def _reset_cache(self) -> None:
        self.cache = init_decode_cache(
            self.cfg, self.serve.max_batch, self.serve.max_seq,
        )
        self.slots: List[Optional[str]] = [None] * self.serve.max_batch

    # -- session management --------------------------------------------------------
    def submit(self, session_id: str, prompt: List[int]) -> None:
        s = SessionState(session_id, list(prompt))
        self.sessions[session_id] = s
        self.store.commit(s)
        slot = self.slots.index(None)
        self.slots[slot] = session_id
        # Feed all but the last token: step() feeds tokens[-1], keeping the
        # fed-token stream identical across normal and recovered runs.
        self._replay_tokens(slot, s.tokens[:-1])

    def _replay_tokens(self, slot: int, tokens: List[int]) -> None:
        """Feed tokens through decode to build this slot's KV/SSM state; the
        per-slot active mask keeps other sessions' caches and positions
        untouched (mixed-length batching)."""
        for t in tokens:
            batch = self._batch_for(slot, t)
            _, self.cache = self._decode(self.params, batch, self.cache)

    def _batch_for(self, slot: int, token: int) -> Dict[str, jnp.ndarray]:
        toks = np.zeros((self.serve.max_batch, 1), np.int32)
        toks[slot, 0] = token
        active = np.zeros((self.serve.max_batch,), np.int32)
        active[slot] = 1
        return {"tokens": jnp.asarray(toks), "active": jnp.asarray(active)}

    # -- decoding -----------------------------------------------------------------
    def step(self) -> Dict[str, int]:
        """One batched decode step for every live slot; commit via CURP."""
        live = [(i, sid) for i, sid in enumerate(self.slots) if sid]
        if not live:
            return {}
        last = np.zeros((self.serve.max_batch, 1), np.int32)
        active = np.zeros((self.serve.max_batch,), np.int32)
        for i, sid in live:
            last[i, 0] = self.sessions[sid].tokens[-1]
            active[i] = 1
        logits, self.cache = self._decode(
            self.params,
            {"tokens": jnp.asarray(last), "active": jnp.asarray(active)},
            self.cache,
        )
        out: Dict[str, int] = {}
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        to_commit: List[SessionState] = []
        for i, sid in live:
            tok = int(nxt[i])
            s = self.sessions[sid]
            s.tokens.append(tok)
            out[sid] = tok
            self.tokens_served += 1
            self._m_tokens.inc()
            if len(s.tokens) % self.serve.commit_every == 0:
                to_commit.append(s)
        # One batched CURP round for the whole decode step: distinct session
        # keys commute, so the batch completes via each shard's 1-RTT path.
        # With atomic_step_commit the step commits as ONE mini-transaction
        # instead (all-or-nothing across shards; single-shard steps keep the
        # 1-RTT short-circuit).
        self._h_commit.record(len(to_commit))
        if self.serve.atomic_step_commit:
            self.store.txn(to_commit)
        else:
            self.store.commit_batch(to_commit)
        return out

    def generate(self, n_tokens: int) -> None:
        for _ in range(n_tokens):
            self.step()

    # -- failures -----------------------------------------------------------------
    def crash_and_recover(self) -> Dict[str, int]:
        """Master (driver state) dies; sessions recover from CURP store; KV
        caches rebuild by re-prefill."""
        report = self.store.crash_and_recover()
        live_ids = [sid for sid in self.slots if sid]
        self.sessions = {}
        self._reset_cache()
        recovered = 0
        for sid in live_ids:
            s = self.store.load(sid)
            if s is None:
                continue
            self.sessions[sid] = s
            slot = self.slots.index(None)
            self.slots[slot] = sid
            self._replay_tokens(slot, s.tokens[:-1])
            recovered += 1
        self._m_recoveries.inc()
        self._m_replayed.inc(report.replayed)
        return {"recovered_sessions": recovered,
                "replayed_ops": report.replayed}
