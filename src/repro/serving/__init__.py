from .kvstore import CurpSessionStore, SessionState
from .server import CurpServeDriver, ServeConfig

__all__ = ["CurpSessionStore", "SessionState", "CurpServeDriver",
           "ServeConfig"]
