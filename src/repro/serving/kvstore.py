"""CURP-Serve session store.

Sessions are the keys: per-session state updates commute across sessions
(disjoint primary keys), so CURP's fast path applies to almost every decode
commit — two concurrent updates hit the same key only if the same session is
decoded twice within one unsynced window, which the driver never does.

Built directly on the protocol objects (ShardedCluster): every session commit
is a real CURP update (witness records + speculative master + batched backup
syncs), and crash recovery rebuilds the session map via backup restore +
witness replay.  With ``n_shards > 1`` sessions are partitioned across
independent master groups by session-id hash (the KeyRouter over the
``session:{id}`` key), so commit load spreads across masters and a single
master crash only replays that shard's witnesses.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import (
    ClusterRecoveryReport,
    ShardedClientSession,
    ShardedCluster,
    TxnOutcome,
    TxnStatus,
    WitnessGeometry,
)


@dataclass
class SessionState:
    session_id: str
    tokens: List[int]
    done: bool = False


class CurpSessionStore:
    def __init__(self, f: int = 3, sync_batch: int = 50, seed: int = 0,
                 n_shards: int = 1,
                 geometry: Optional[WitnessGeometry] = None,
                 witness_backend: str = "python",
                 n_slots: int = 256) -> None:
        # Sessions are hot keys by construction (one update per token), so we
        # enable the paper's §4.4 preemptive-sync heuristic: the master syncs
        # right after responding to an update of a recently-updated key,
        # keeping the NEXT commit of that session on the 1-RTT fast path.
        self.n_shards = n_shards
        self.cluster = ShardedCluster(
            n_shards=n_shards, f=f, sync_batch=sync_batch, seed=seed,
            hot_key_window=1e12, geometry=geometry,
            witness_backend=witness_backend, n_slots=n_slots,
        )
        self.client: ShardedClientSession = self.cluster.new_client()
        self.fast_commits = 0
        self.slow_commits = 0
        # Counted store-side so the numbers survive master failovers (the
        # per-shard Master.stats reset when recovery installs a new master).
        self._commits_by_shard: Dict[int, int] = {
            s: 0 for s in range(n_shards)
        }
        # Session placement is slot-map routing; memoize it per ROUTER
        # VERSION — a live slot migration bumps the version, invalidating
        # cached placements exactly like a client config refetch (§3.6).
        self._shard_cache: Dict[str, Tuple[int, int]] = {}

    @staticmethod
    def _key(session_id: str) -> str:
        return f"session:{session_id}"

    def shard_of(self, session_id: str) -> int:
        """Which master group owns this session (slot-map routing, cached
        per router version so live migrations invalidate the cache)."""
        version = self.cluster.router.version
        hit = self._shard_cache.get(session_id)
        if hit is not None and hit[0] == version:
            return hit[1]
        shard = self.cluster.shard_of(self._key(session_id))
        self._shard_cache[session_id] = (version, shard)
        return shard

    def _count_commit(self, session_id: str) -> None:
        shard = self.shard_of(session_id)
        self._commits_by_shard[shard] = \
            self._commits_by_shard.get(shard, 0) + 1

    # -- live reconfiguration ---------------------------------------------------
    def migrate_sessions(self, slots, dst_shard: int):
        """Live-move the sessions living in ``slots`` to another master
        group (repro.core.migration): commits keep flowing on untouched
        slots throughout; the moved sessions' RIFL records travel with
        them."""
        return self.cluster.migrate_slots(slots, dst_shard)

    def add_shard(self) -> int:
        """Grow the serving store by one (initially empty) master group."""
        sid = self.cluster.add_shard()
        self.n_shards = self.cluster.n_shards
        self._commits_by_shard.setdefault(sid, 0)
        return sid

    def rebalance(self, max_moves: int = 64):
        """Hot-shard auto-split: shed the hottest sessions' slots off the
        hottest master group (per-slot op counters -> plan_rebalance)."""
        return self.cluster.rebalance(max_moves=max_moves)

    # -- write path -------------------------------------------------------------
    def commit(self, s: SessionState) -> None:
        """Durably commit a session snapshot (1 RTT on the fast path): a
        batch of one, so both paths share op construction and accounting."""
        self.commit_batch([s])

    def commit_batch(self, states: Sequence[SessionState]) -> None:
        """Durably commit a whole decode step's sessions in one batched CURP
        round: ops grouped per shard, each shard's witnesses record the batch
        in a single invocation (one kernel dispatch on the device backend),
        per-session fast/slow accounting preserved.  Distinct sessions have
        distinct keys, so a multi-session batch stays on the 1-RTT path."""
        if not states:
            return
        ops = [
            self.client.op_set(
                self._key(s.session_id),
                json.dumps({"tokens": s.tokens, "done": s.done}),
            )
            for s in states
        ]
        outs = self.cluster.update_batch(self.client, ops)
        for s, out in zip(states, outs):
            self._count_commit(s.session_id)
            if out.fast_path:
                self.fast_commits += 1
            else:
                self.slow_commits += 1

    def txn(self, states: Sequence[SessionState]) -> TxnOutcome:
        """Atomically commit a GROUP of sessions (all-or-nothing across
        shards) via the mini-transaction subsystem (repro.core.txn).

        ``commit_batch`` gives per-session durability — a crash mid-batch
        can persist some sessions of a linked group and not others.  This
        path makes the group atomic: sessions on one shard short-circuit to
        the same 1-RTT fast path as ``commit``; a cross-shard group pays
        one RIFL-identified 2PC (prepare round + decide round).
        """
        if not states:
            return TxnOutcome(status=TxnStatus.COMMITTED, reads={},
                              rtts=0, fast_path=True, n_shards=0)
        writes = [
            (self._key(s.session_id),
             json.dumps({"tokens": s.tokens, "done": s.done}))
            for s in states
        ]
        out = self.cluster.txn(self.client, writes)
        for s in states:
            self._count_commit(s.session_id)
            if out.fast_path:
                self.fast_commits += 1
            else:
                self.slow_commits += 1
        return out

    # -- read path ----------------------------------------------------------------
    def load(self, session_id: str) -> Optional[SessionState]:
        out = self.cluster.read(
            self.client, self.client.op_get(self._key(session_id))
        )
        if out.value is None:
            return None
        d = json.loads(out.value)
        return SessionState(session_id, d["tokens"], d["done"])

    # -- failures -------------------------------------------------------------------
    def crash_and_recover(self) -> ClusterRecoveryReport:
        """Total serving-node loss: every shard's master dies and recovers
        (each from its own backups + one of its own witnesses)."""
        return self.cluster.crash_all()

    def crash_shard(self, shard_id: int):
        """Partial failure: one master group dies; sessions on other shards
        keep their unsynced windows and witnesses untouched."""
        return self.cluster.crash_master(shard_id)

    # -- stats -----------------------------------------------------------------------
    def per_shard_commits(self) -> List[int]:
        return [self._commits_by_shard.get(s, 0)
                for s in range(len(self.cluster.shards))]
