"""CURP-Serve session store.

Sessions are the keys: per-session state updates commute across sessions
(disjoint primary keys), so CURP's fast path applies to almost every decode
commit — two concurrent updates hit the same key only if the same session is
decoded twice within one unsynced window, which the driver never does.

Built directly on the protocol objects (LocalCluster): every session commit
is a real CURP update (witness records + speculative master + batched backup
syncs), and crash recovery rebuilds the session map via backup restore +
witness replay.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core import ClientSession, LocalCluster


@dataclass
class SessionState:
    session_id: str
    tokens: List[int]
    done: bool = False


class CurpSessionStore:
    def __init__(self, f: int = 3, sync_batch: int = 50, seed: int = 0) -> None:
        # Sessions are hot keys by construction (one update per token), so we
        # enable the paper's §4.4 preemptive-sync heuristic: the master syncs
        # right after responding to an update of a recently-updated key,
        # keeping the NEXT commit of that session on the 1-RTT fast path.
        self.cluster = LocalCluster(
            f=f, sync_batch=sync_batch, seed=seed, hot_key_window=1e12,
        )
        self.client = self.cluster.new_client()
        self.fast_commits = 0
        self.slow_commits = 0

    # -- write path -------------------------------------------------------------
    def commit(self, s: SessionState) -> None:
        """Durably commit a session snapshot (1 RTT on the fast path)."""
        op = self.client.op_set(
            f"session:{s.session_id}",
            json.dumps({"tokens": s.tokens, "done": s.done}),
        )
        out = self.cluster.update(self.client, op)
        if out.fast_path:
            self.fast_commits += 1
        else:
            self.slow_commits += 1

    # -- read path ----------------------------------------------------------------
    def load(self, session_id: str) -> Optional[SessionState]:
        out = self.cluster.read(
            self.client, self.client.op_get(f"session:{session_id}")
        )
        if out.value is None:
            return None
        d = json.loads(out.value)
        return SessionState(session_id, d["tokens"], d["done"])

    # -- failures -------------------------------------------------------------------
    def crash_and_recover(self):
        return self.cluster.crash_master()
