"""int8 gradient compression with per-block scales + error feedback.

Applied to the cross-pod gradient all-reduce in the multi-pod config (the
slow inter-pod links dominate there; see EXPERIMENTS.md §Perf).  Error
feedback keeps the quantization bias out of the optimizer trajectory
(Seide et al. / 1-bit SGD lineage).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize_leaf(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_leaf(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads, error_feedback=None):
    """Returns (dequantized-after-wire pytree, new error feedback pytree)."""
    if error_feedback is not None:
        grads = jax.tree_util.tree_map(
            lambda g, e: g.astype(jnp.float32) + e, grads, error_feedback
        )
    flat, tdef = jax.tree_util.tree_flatten(grads)
    deq_flat, ef_flat = [], []
    for g in flat:
        q, s = _quantize_leaf(g)
        d = _dequantize_leaf(q, s, g.shape)
        deq_flat.append(d.astype(g.dtype))
        ef_flat.append(g.astype(jnp.float32) - d)
    return (
        jax.tree_util.tree_unflatten(tdef, deq_flat),
        jax.tree_util.tree_unflatten(tdef, ef_flat),
    )


def roundtrip_leaf(g: jnp.ndarray) -> jnp.ndarray:
    """Quantize->dequantize one leaf (what the wire sees)."""
    q, s = _quantize_leaf(g)
    return _dequantize_leaf(q, s, g.shape).astype(g.dtype)
