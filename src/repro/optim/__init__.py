from .adamw import AdamWConfig, adamw_update, global_norm, init_opt_state, lr_at
from .compression import compress_grads, roundtrip_leaf

__all__ = [
    "AdamWConfig", "adamw_update", "global_norm", "init_opt_state", "lr_at",
    "compress_grads", "roundtrip_leaf",
]
