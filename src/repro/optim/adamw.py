"""AdamW with dtype-configurable moments (bf16 moments fit the 340B config
in 16 GiB/chip HBM; see DESIGN.md §6) + cosine LR schedule + global-norm clip.

Pure pytree functions; optimizer state shards exactly like params (ZeRO-3:
the param specs are reused for m/v)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # "bfloat16" for memory-bound giants
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    params, grads, opt_state, cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
