"""Deterministic synthetic data pipeline.

CURP-FT replays train steps from witness journals, so a batch must be
reconstructible from its metadata alone: batch_for(step) is a pure function
of (seed, step).  This is exactly the property the paper needs from RIFL'd
requests — the *operation* (not the result) is what gets journaled.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    batch: int = 8
    seq: int = 128


class SyntheticPipeline:
    """Markov-ish token stream: next-token structure so loss can decrease."""

    def __init__(self, cfg: ModelConfig, data: DataConfig) -> None:
        self.cfg = cfg
        self.data = data
        rng = np.random.default_rng(data.seed)
        # A fixed random transition table gives learnable structure.
        self._trans = rng.integers(
            0, cfg.vocab, size=(min(cfg.vocab, 4096), 4), dtype=np.int64
        )

    def batch_for(self, step: int) -> Dict[str, jnp.ndarray]:
        """Pure function of (seed, step): the CURP-FT replay contract."""
        d = self.data
        rng = np.random.default_rng((self.data.seed, step))
        toks = np.empty((d.batch, d.seq + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, self.cfg.vocab, d.batch)
        pick = rng.integers(0, 4, size=(d.batch, d.seq))
        noise = rng.random((d.batch, d.seq)) < 0.1
        rand = rng.integers(0, self.cfg.vocab, (d.batch, d.seq))
        for t in range(d.seq):
            nxt = self._trans[toks[:, t] % self._trans.shape[0], pick[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        batch = {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
        if self.cfg.frontend != "token":
            fd = self.cfg.frontend_dim or self.cfg.d_model
            em = np.asarray(
                np.random.default_rng((self.data.seed, step, 7)).normal(
                    0, 1, (d.batch, d.seq, fd)
                ),
                np.float32,
            )
            batch["embeds"] = jnp.asarray(em, jnp.dtype(self.cfg.dtype))
            del batch["tokens"]
        if self.cfg.pos == "mrope":
            pos = np.broadcast_to(
                np.arange(d.seq, dtype=np.int32), (3, d.batch, d.seq)
            )
            batch["positions"] = jnp.asarray(pos)
        return batch
