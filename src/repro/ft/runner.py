"""FaultTolerantTrainer: CURP-FT end to end.

Per step:
  1. build the batch from (seed, step) — pure function (data/pipeline.py);
  2. record the StepOp to all f witnesses (1-RTT durability; file-fsync'd);
  3. execute the jitted train_step (speculative: state not yet on backups);
  4. every `sync_every` steps: sync full state to all f backup replicas,
     then gc the witnessed steps (the paper's batched syncs, §3.5/§4.4).

crash(): drops ALL in-memory state (master loss).
recover(): restore newest complete backup -> replay journaled steps (in
step order — ordering metadata rides in the op, commutativity makes witness
order irrelevant) -> sync -> fresh witnesses.  Deterministic data + fixed
step rng make recovery BIT-EXACT (tested).
"""
from __future__ import annotations

import shutil
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import RecordStatus
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.launch.steps import make_train_step
from repro.models.config import ModelConfig
from repro.models.transformer import init_params
from repro.optim import AdamWConfig, init_opt_state

from .checkpoint import BackupReplica, restore_into
from .journal import FileWitness, StepOp


@dataclass
class FTConfig:
    f: int = 3
    sync_every: int = 10        # backup sync batch (paper: 50)
    workdir: str = "/tmp/curp_ft"
    seed: int = 0


class FaultTolerantTrainer:
    def __init__(self, model_cfg: ModelConfig, data_cfg: DataConfig,
                 ft: FTConfig, opt_cfg: Optional[AdamWConfig] = None) -> None:
        self.cfg = model_cfg
        self.data_cfg = data_cfg
        self.ft = ft
        self.opt_cfg = opt_cfg or AdamWConfig(warmup_steps=5, total_steps=1000)
        self.root = Path(ft.workdir)
        self.root.mkdir(parents=True, exist_ok=True)
        self.pipeline = SyntheticPipeline(model_cfg, data_cfg)
        self._train_step = jax.jit(make_train_step(model_cfg, self.opt_cfg))
        self.epoch = 0
        self.master_id = 1
        self.backups = [BackupReplica(self.root, i) for i in range(ft.f)]
        self.witnesses = [
            FileWitness(self.root / f"witness{i}.jsonl", self.master_id)
            for i in range(ft.f)
        ]
        self.params = init_params(model_cfg, jax.random.PRNGKey(ft.seed))
        self.opt_state = init_opt_state(self.params, self.opt_cfg)
        self.step = 0
        self._journaled: List[int] = []
        self.metrics_log: List[Dict[str, float]] = []
        # step 0 state is the implicit first backup
        self._sync_backups()

    # ------------------------------------------------------------------ train
    def train(self, n_steps: int) -> None:
        for _ in range(n_steps):
            self._one_step()

    def _one_step(self) -> None:
        sop = StepOp(self.step, self.data_cfg.seed, self.ft.seed)
        # 1-RTT durability: all f witnesses must accept (distinct step keys
        # always commute; a reject would mean journal corruption).
        for w in self.witnesses:
            st = w.record(sop)
            assert st is RecordStatus.ACCEPTED, f"witness rejected {sop}"
        batch = self.pipeline.batch_for(self.step)
        self.params, self.opt_state, metrics = self._train_step(
            self.params, self.opt_state, batch
        )
        self.metrics_log.append(
            {k: float(v) for k, v in metrics.items()}
        )
        self._journaled.append(self.step)
        self.step += 1
        if self.step % self.ft.sync_every == 0:
            self._sync_backups()

    def _sync_backups(self) -> None:
        state = {"params": self.params, "opt": self.opt_state}
        for b in self.backups:
            ok = b.sync(self.step, state, epoch=self.epoch)
            assert ok, "backup rejected sync (zombie fence?)"
        if self._journaled:
            for w in self.witnesses:
                w.gc(self._journaled)
            self._journaled = []

    # --------------------------------------------------------------- failures
    def crash(self) -> None:
        """Master dies: all in-memory state is gone."""
        self.params = None
        self.opt_state = None
        self._journaled = []

    def recover(self) -> Dict[str, Any]:
        """Restore newest backup + replay witnessed steps (bit-exact)."""
        self.epoch += 1
        newest = max(
            (b for b in self.backups if b.newest_step() is not None),
            key=lambda b: b.newest_step(),
        )
        restored_step = newest.newest_step()
        flat, _ = newest.restore(restored_step)
        template_p = jax.eval_shape(
            lambda: init_params(self.cfg, jax.random.PRNGKey(self.ft.seed))
        )
        template_o = jax.eval_shape(
            lambda: init_opt_state(template_p, self.opt_cfg)
        )
        self.params = restore_into(template_p, flat["params"])
        self.opt_state = restore_into(template_o, flat["opt"])
        self.step = restored_step

        # Replay from ONE witness (any — all contain every completed op).
        sops = self.witnesses[0].get_recovery_data()
        replayed = 0
        for sop in sops:
            if sop.step < restored_step:
                continue   # RIFL: already folded into the checkpoint
            batch = self.pipeline.batch_for(sop.step)
            self.params, self.opt_state, _ = self._train_step(
                self.params, self.opt_state, batch
            )
            self.step = sop.step + 1
            replayed += 1
        # Fresh witnesses under the new epoch; sync what we replayed.
        self.master_id += 1
        for i in range(self.ft.f):
            p = self.root / f"witness{i}.jsonl"
            p.unlink(missing_ok=True)
        self.witnesses = [
            FileWitness(self.root / f"witness{i}.jsonl", self.master_id)
            for i in range(self.ft.f)
        ]
        self._sync_backups()
        return {"restored_step": restored_step, "replayed": replayed,
                "resumed_at": self.step}

    # ------------------------------------------------------------------ utils
    def params_digest(self) -> str:
        import hashlib

        h = hashlib.sha256()
        for _, leaf in sorted(
            jax.tree_util.tree_flatten_with_path(self.params)[0],
            key=lambda kv: str(kv[0]),
        ):
            h.update(np.asarray(leaf).tobytes())
        return h.hexdigest()
