"""CURP-FT witness journal: durable, unordered records of train-step ops.

The CURP mapping (DESIGN.md §3): a train step is deterministic given
(step_id, data seed, rng) — ~100 bytes.  The driver records that op to f
witnesses in parallel with executing the step (the 1-RTT fast path); full
state syncs to backup replicas only every `sync_every` steps (the paper's
§4.4 batching).  Recovery = restore newest backup + replay journaled steps;
RIFL filtering degenerates to "step_id <= restored step" because the
checkpoint IS the completion record for every folded-in step.

Commutativity: step ops carry distinct keys (step:<n>), so witnesses accept
them unordered; replay order is recovered from the op metadata (exactly like
RIFL rpc_ids order duplicate detection in the paper).

Witness storage is a host-side append-only file per witness — the analogue
of the paper's flash-backed DRAM (DESIGN.md §9.2).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core.types import Op, OpType, RecordStatus
from repro.core.witness import Witness


@dataclass(frozen=True)
class StepOp:
    step: int
    data_seed: int
    rng_seed: int
    driver_id: int = 0

    def to_op(self) -> Op:
        return Op(
            OpType.SET,
            keys=(f"step:{self.step}",),
            args=(json.dumps({
                "step": self.step, "data_seed": self.data_seed,
                "rng_seed": self.rng_seed,
            }),),
            rpc_id=(self.driver_id, self.step),
        )

    @staticmethod
    def from_op(op: Op) -> "StepOp":
        d = json.loads(op.args[0])
        return StepOp(d["step"], d["data_seed"], d["rng_seed"],
                      op.rpc_id[0])


class FileWitness:
    """core.Witness semantics + append-only file durability."""

    def __init__(self, path: Path, master_id: int,
                 n_sets: int = 1024, n_ways: int = 4) -> None:
        self.path = Path(path)
        self.core = Witness(n_sets, n_ways)
        self.core.start(master_id)
        self.master_id = master_id
        self.path.parent.mkdir(parents=True, exist_ok=True)
        if self.path.exists():
            self._replay_file()
        else:
            self.path.touch()

    def _replay_file(self) -> None:
        """Rebuild in-memory table from the durable log (process restart)."""
        live: Dict[int, StepOp] = {}
        for line in self.path.read_text().splitlines():
            if not line.strip():
                continue
            rec = json.loads(line)
            if rec["t"] == "record":
                live[rec["step"]] = StepOp(
                    rec["step"], rec["data_seed"], rec["rng_seed"],
                    rec.get("driver", 0),
                )
            elif rec["t"] == "gc":
                for s in rec["steps"]:
                    live.pop(s, None)
        for sop in live.values():
            op = sop.to_op()
            self.core.record(self.master_id, op.key_hashes(), op.rpc_id, op)

    # -- witness API -----------------------------------------------------------
    def record(self, sop: StepOp) -> RecordStatus:
        op = sop.to_op()
        st = self.core.record(self.master_id, op.key_hashes(), op.rpc_id, op)
        if st is RecordStatus.ACCEPTED:
            with self.path.open("a") as f:
                f.write(json.dumps({
                    "t": "record", "step": sop.step,
                    "data_seed": sop.data_seed, "rng_seed": sop.rng_seed,
                    "driver": sop.driver_id,
                }) + "\n")
                f.flush()
                os.fsync(f.fileno())
        return st

    def gc(self, steps: List[int]) -> None:
        entries = []
        for s in steps:
            op = StepOp(s, 0, 0).to_op()
            entries.append((op.key_hashes()[0], (op.rpc_id[0], s)))
        # gc by key hash; rpc client id must match the recorded one — use
        # driver 0 default; core gc matches on (keyhash, rpc_id) so rebuild
        # rpc ids from the live table instead:
        live = {
            op.rpc_id[1]: op for op in self._live_ops()
        }
        entries = [
            (live[s].key_hashes()[0], live[s].rpc_id)
            for s in steps if s in live
        ]
        self.core.gc(tuple(entries))
        with self.path.open("a") as f:
            f.write(json.dumps({"t": "gc", "steps": steps}) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def _live_ops(self) -> List[Op]:
        out = []
        for ways in self.core._slots:
            for slot in ways:
                if slot.occupied and slot.request is not None:
                    out.append(slot.request)
        return out

    def get_recovery_data(self) -> List[StepOp]:
        ops = self.core.get_recovery_data(self.master_id)
        return sorted(
            (StepOp.from_op(op) for op in ops), key=lambda s: s.step
        )
