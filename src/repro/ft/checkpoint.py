"""Backup checkpoint replicas for CURP-FT.

Backups hold *ordered* state (the full params/opt pytree at a step), exactly
like the paper's backups hold the ordered op log.  `sync_every` steps of
journal records batch into one backup sync (§4.4); f replicas tolerate f-1
replica losses on top of the master loss.

Checkpoints are written atomically (tmp + rename) with a manifest carrying
the step and a content checksum, so a crash mid-sync never corrupts the
newest complete replica.
"""
from __future__ import annotations

import hashlib
import json
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


class BackupReplica:
    def __init__(self, root: Path, replica_id: int) -> None:
        self.root = Path(root) / f"backup{replica_id}"
        self.root.mkdir(parents=True, exist_ok=True)
        self.replica_id = replica_id
        self.epoch = 0

    def sync(self, step: int, state: Dict[str, Any], epoch: int = 0) -> bool:
        """Atomic full-state checkpoint at `step` (zombie-fenced by epoch)."""
        if epoch < self.epoch:
            return False   # §4.7: reject deposed masters
        self.epoch = epoch
        tmp = self.root / f".tmp_step{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        arrays = {}
        for tree_name, tree in state.items():
            for key, arr in _flatten(tree):
                arrays[f"{tree_name}::{key}"] = arr
        np.savez(tmp / "state.npz", **arrays)
        digest = hashlib.sha256((tmp / "state.npz").read_bytes()).hexdigest()
        (tmp / "manifest.json").write_text(json.dumps({
            "step": step, "epoch": epoch, "sha256": digest,
        }))
        final = self.root / f"step{step}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        # keep only the 2 newest
        steps = sorted(self._steps())
        for s in steps[:-2]:
            shutil.rmtree(self.root / f"step{s}")
        return True

    def _steps(self) -> List[int]:
        return [
            int(p.name[4:]) for p in self.root.glob("step*")
            if (p / "manifest.json").exists()
        ]

    def newest_step(self) -> Optional[int]:
        steps = self._steps()
        return max(steps) if steps else None

    def restore(self, step: int) -> Tuple[Dict[str, Dict[str, np.ndarray]], int]:
        d = self.root / f"step{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        digest = hashlib.sha256((d / "state.npz").read_bytes()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checksum mismatch in {d}")
        raw = np.load(d / "state.npz")
        out: Dict[str, Dict[str, np.ndarray]] = {}
        for k in raw.files:
            tree_name, key = k.split("::", 1)
            out.setdefault(tree_name, {})[key] = raw[k]
        return out, manifest["step"]


def restore_into(template, flat: Dict[str, np.ndarray]):
    """Rebuild a pytree congruent with `template` from flattened arrays."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(p) for p in path)
        arr = flat[key]
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
