"""Elastic scaling + straggler mitigation policies (1000+-node posture).

These are the control-plane decisions; the data plane is the dry-run's
sharding (launch/sharding.py) and CURP-FT's journal/backup machinery:

* Pod loss: re-carve the mesh without the lost pod, re-balance the global
  batch over surviving pods, restore from backups + journal replay (the
  journal is pod-independent — StepOps are pure metadata).
* Straggling backup: syncs are ASYNC in CURP, so a slow backup never blocks
  the fast path; if it misses `demote_after` consecutive deadlines it is
  demoted (dropped from the sync set) and a replacement is installed via the
  §3.6 reconfiguration (sync-then-bump-WitnessListVersion ordering).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MeshPlan:
    n_pods: int
    pod_shape: Tuple[int, int]       # (data, model) per pod
    global_batch: int
    per_pod_batch: int
    grad_accum: int                  # keeps tokens/step constant across scale


def plan_elastic_remesh(
    n_live_pods: int, *, pod_data: int = 16, pod_model: int = 16,
    global_batch: int = 256, target_tokens_constant: bool = True,
    baseline_pods: int = 2,
) -> MeshPlan:
    """Re-carve after pod loss/gain.

    Keeps the GLOBAL batch (and thus the optimizer trajectory / journal
    semantics) constant by folding the lost pods' share into gradient
    accumulation: tokens-per-step is invariant, so journal replay remains
    bit-exact across mesh sizes."""
    assert n_live_pods >= 1
    per_pod = global_batch // n_live_pods
    accum = 1
    if target_tokens_constant and n_live_pods < baseline_pods:
        # fold missing pods into accumulation steps
        accum = -(-baseline_pods // n_live_pods)
        per_pod = global_batch // (n_live_pods * accum)
    return MeshPlan(
        n_pods=n_live_pods,
        pod_shape=(pod_data, pod_model),
        global_batch=global_batch,
        per_pod_batch=per_pod,
        grad_accum=accum,
    )


@dataclass
class StragglerPolicy:
    """Deadline-based backup demotion (mirrors §3.6 backup reconfiguration)."""
    deadline_factor: float = 3.0      # x median sync latency
    demote_after: int = 3             # consecutive misses
    _misses: Dict[int, int] = field(default_factory=dict)
    _latencies: List[float] = field(default_factory=list)

    def observe(self, backup_id: int, latency: float) -> Optional[str]:
        """Feed one sync latency; returns 'demote' when policy fires."""
        self._latencies.append(latency)
        med = sorted(self._latencies)[len(self._latencies) // 2]
        if latency > self.deadline_factor * med and len(self._latencies) >= 5:
            self._misses[backup_id] = self._misses.get(backup_id, 0) + 1
            if self._misses[backup_id] >= self.demote_after:
                self._misses[backup_id] = 0
                return "demote"
        else:
            self._misses[backup_id] = 0
        return None
