from .checkpoint import BackupReplica, restore_into
from .elastic import MeshPlan, StragglerPolicy, plan_elastic_remesh
from .journal import FileWitness, StepOp
from .runner import FTConfig, FaultTolerantTrainer

__all__ = [
    "BackupReplica", "restore_into", "MeshPlan", "StragglerPolicy",
    "plan_elastic_remesh", "FileWitness", "StepOp", "FTConfig",
    "FaultTolerantTrainer",
]
