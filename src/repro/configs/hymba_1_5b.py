"""--arch hymba_1_5b: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import HYMBA_1_5B as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
