"""--arch llama3_2_1b: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import LLAMA32_1B as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
