"""--arch smollm_360m: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import SMOLLM_360M as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
