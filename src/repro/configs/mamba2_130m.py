"""--arch mamba2_130m: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import MAMBA2_130M as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
