"""--arch hubert_xlarge: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import HUBERT_XLARGE as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
