"""--arch deepseek_coder_33b: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import DEEPSEEK_CODER_33B as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
