"""The 10 assigned architectures, exactly as specified (source tags inline).

Every config is selectable via --arch <id> in the launchers; reduced smoke
variants come from repro.models.config.reduced().
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

# --- dense ---------------------------------------------------------------
SMOLLM_360M = ModelConfig(
    # [hf:HuggingFaceTB/SmolLM-135M; hf] — llama-arch small
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_head=64,
    d_ff=2560, vocab=49_152, act="swiglu", attn="full", pos="rope",
)

LLAMA32_1B = ModelConfig(
    # [hf:meta-llama/Llama-3.2-1B; unverified] — small llama3
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
    d_ff=8192, vocab=128_256, act="swiglu", attn="full", pos="rope",
    rope_theta=500_000.0, tie_embeddings=True,
)

DEEPSEEK_CODER_33B = ModelConfig(
    # [arXiv:2401.14196; hf] — llama-arch
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=19_200, vocab=32_256, act="swiglu", attn="full", pos="rope",
)

NEMOTRON_4_340B = ModelConfig(
    # [arXiv:2402.16819; unverified] — GQA, squared-ReLU
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18_432, n_heads=96, n_kv_heads=8, d_head=192,
    d_ff=73_728, vocab=256_000, act="relu2", attn="full", pos="rope",
)

# --- MoE ------------------------------------------------------------------
QWEN3_MOE_30B = ModelConfig(
    # [hf:Qwen/Qwen3-30B-A3B; hf] — 128 experts top-8
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=0, vocab=151_936, act="swiglu", attn="full", pos="rope",
    n_experts=128, top_k=8, moe_d_ff=768, qk_norm=True,
)

QWEN2_MOE_A27B = ModelConfig(
    # [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] — 4 shared + 60 routed top-4
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=0, vocab=151_936, act="swiglu", attn="full", pos="rope",
    n_experts=60, top_k=4, moe_d_ff=1408,
    n_shared_experts=4, shared_d_ff=5632,
)

# --- audio (encoder-only; frontend = stub frame embeddings) -----------------
HUBERT_XLARGE = ModelConfig(
    # [arXiv:2106.07447; unverified] — encoder-only, w2v2 arch
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_head=80,
    d_ff=5120, vocab=504, act="swiglu", attn="full", causal=False,
    pos="none", frontend="audio", frontend_dim=512,
)

# --- VLM backbone (frontend = stub patch embeddings; M-RoPE) -----------------
QWEN2_VL_2B = ModelConfig(
    # [arXiv:2409.12191; hf] — M-RoPE, dynamic resolution
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_head=128,
    d_ff=8960, vocab=151_936, act="swiglu", attn="full", pos="mrope",
    mrope_sections=(16, 24, 24), frontend="vision", frontend_dim=1536,
)

# --- SSM ----------------------------------------------------------------------
MAMBA2_130M = ModelConfig(
    # [arXiv:2405.21060; unverified] — SSD (state-space duality)
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50_280, attn="none", pos="none",
    ssm=True, ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
)

# --- hybrid ----------------------------------------------------------------------
HYMBA_1_5B = ModelConfig(
    # [arXiv:2411.13676; hf] — parallel attn+mamba heads; SWA + 3 global layers
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab=32_001, act="swiglu",
    attn="swa", swa_window=1024, global_attn_layers=(0, 15, 31), pos="rope",
    ssm=True, ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=64,
)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        SMOLLM_360M, LLAMA32_1B, DEEPSEEK_CODER_33B, NEMOTRON_4_340B,
        QWEN3_MOE_30B, QWEN2_MOE_A27B, HUBERT_XLARGE, QWEN2_VL_2B,
        MAMBA2_130M, HYMBA_1_5B,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]
