"""--arch qwen3_moe_30b_a3b: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import QWEN3_MOE_30B as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
