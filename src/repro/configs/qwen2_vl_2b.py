"""--arch qwen2_vl_2b: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import QWEN2_VL_2B as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
