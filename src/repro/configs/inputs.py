"""input_specs(): ShapeDtypeStruct stand-ins (dry-run) or concrete arrays
(smoke tests) for every (arch x shape) cell.

For [audio]/[vlm] archs the modality frontend is a STUB per the assignment:
specs provide precomputed frame/patch embeddings (+ M-RoPE position ids for
qwen2-vl).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_decode_cache

from .shapes import ShapeSpec


def _act_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def batch_specs(
    cfg: ModelConfig, shape: ShapeSpec, *, with_labels: bool,
) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract specs for the model-input batch."""
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.frontend == "token":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        fd = cfg.frontend_dim or cfg.d_model
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, fd), _act_dtype(cfg))
    if cfg.pos == "mrope":
        specs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return specs


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract decode-cache pytree for serve_step cells (no allocation)."""
    B = shape.global_batch
    return jax.eval_shape(
        lambda: init_decode_cache(cfg, B, shape.seq_len)
    )


def concrete_batch(
    cfg: ModelConfig, shape_kind: str, batch: int, seq: int, seed: int = 0,
    *, with_labels: bool = True,
) -> Dict[str, jnp.ndarray]:
    """Concrete random batch for smoke tests / examples (small shapes)."""
    rng = np.random.default_rng(seed)
    S = 1 if shape_kind == "decode" else seq
    out: Dict[str, Any] = {}
    if cfg.frontend == "token":
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, S)), jnp.int32
        )
    else:
        fd = cfg.frontend_dim or cfg.d_model
        out["embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, S, fd)), _act_dtype(cfg)
        )
    if cfg.pos == "mrope":
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (3, batch, S))
        out["positions"] = jnp.asarray(pos)
    if with_labels and shape_kind != "decode":
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, S)), jnp.int32
        )
    return out
