"""--arch qwen2_moe_a2_7b: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import QWEN2_MOE_A27B as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
