"""--arch nemotron_4_340b: exact assigned config (see archs.py for source tags)."""
from repro.models.config import reduced

from .archs import NEMOTRON_4_340B as CONFIG

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
