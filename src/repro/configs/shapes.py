"""The assigned input-shape set (LM-family): every (arch x shape) cell of the
dry-run matrix is defined here.

  train_4k     seq 4096   global_batch 256   -> train_step
  prefill_32k  seq 32768  global_batch 32    -> prefill (forward) step
  decode_32k   seq 32768  global_batch 128   -> serve_step (1 new token,
                                                KV cache of seq_len)
  long_500k    seq 524288 global_batch 1     -> serve_step; sub-quadratic
                                                archs only (SSM / hybrid-SWA)

Encoder-only archs (hubert) have no decode; pure full-attention archs skip
long_500k (DESIGN.md §6).  Skips are explicit rows in the roofline table.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg, shape: ShapeSpec) -> Tuple[bool, Optional[str]]:
    """(runs?, skip_reason)."""
    if shape.kind == "decode" and not cfg.can_decode:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention: long_500k designated sub-quadratic-only"
    return True, None
