"""repro.configs — assigned architectures x input shapes."""
from .archs import ARCHS, get_arch
from .inputs import batch_specs, cache_specs, concrete_batch
from .shapes import SHAPES, ShapeSpec, applicable

__all__ = [
    "ARCHS", "get_arch", "batch_specs", "cache_specs", "concrete_batch",
    "SHAPES", "ShapeSpec", "applicable",
]
