import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, applicable, batch_specs, get_arch
from repro.configs.shapes import ShapeSpec
from repro.launch import sharding as sh
from repro.launch.hlo_analysis import (
    analytic_hbm_bytes, collective_bytes, roofline_terms,
)
from repro.launch.mesh import (
    HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16,
    make_mesh_from, make_production_mesh,
)
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models.config import ModelConfig
from repro.models.shardctx import activation_sharding
from repro.models.transformer import init_decode_cache, init_params
from repro.optim import AdamWConfig, init_opt_state


def _abstract(tree, shardings):
    return jax.tree_util.tree_map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings,
    )


def _moment_dtype(cfg: ModelConfig) -> str:
    # bf16 moments for the memory-bound giant (fits 16 GiB/chip; DESIGN §6).
    return "bfloat16" if cfg.name.startswith("nemotron") else "float32"


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *, multi_pod: bool,
               strategy: str = "seq"):
    """Build abstract inputs + jit the right step; returns lowered."""
    with activation_sharding(
        sh.activation_rules(cfg, shape, mesh, multi_pod=multi_pod,
                            strategy=strategy)
    ):
        return _lower_cell_inner(cfg, shape, mesh, multi_pod=multi_pod)


def _lower_cell_inner(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                      multi_pod: bool):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axis_sizes.get("model", 1)
    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    raw_pspec = (
        sh.param_specs_decode(cfg, tp=tp) if shape.kind == "decode"
        else sh.param_specs(cfg, tp=tp)
    )
    pspec = sh.sanitize_specs(raw_pspec, params_shape, axis_sizes)
    pshard = sh.to_shardings(mesh, pspec)
    params_abs = _abstract(params_shape, pshard)

    n_dev = int(mesh.devices.size)
    bspec_tree = batch_specs(cfg, shape, with_labels=(shape.kind == "train"))
    bpspec = sh.sanitize_specs(
        sh.batch_pspecs(cfg, shape, multi_pod=multi_pod,
                        with_labels=(shape.kind == "train"), n_dev=n_dev),
        bspec_tree, axis_sizes,
    )
    bshard = sh.to_shardings(mesh, bpspec)
    batch_abs = _abstract(bspec_tree, bshard)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=_moment_dtype(cfg))
        ospec = sh.opt_specs(pspec)
        oshard = sh.to_shardings(mesh, ospec)
        opt_shape = jax.eval_shape(
            lambda: init_opt_state(params_shape, opt_cfg)
        )
        opt_abs = _abstract(opt_shape, oshard)
        step = make_train_step(cfg, opt_cfg)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        bdim = sh.dp_axes(multi_pod) if shape.global_batch >= 16 else None
        dp_total = n_dev // tp
        out_shard = NamedSharding(
            mesh, P(bdim, "model" if cfg.vocab % tp == 0 else None),
        )
        jitted = jax.jit(
            step, in_shardings=(pshard, bshard), out_shardings=out_shard,
        )
        lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        cache_shape = jax.eval_shape(
            lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len)
        )
        cpspec = sh.sanitize_specs(
            sh.cache_pspecs(cfg, shape, multi_pod=multi_pod),
            cache_shape, axis_sizes,
        )
        cshard = sh.to_shardings(mesh, cpspec)
        cache_abs = _abstract(cache_shape, cshard)
        step = make_serve_step(cfg)
        tok_shard = NamedSharding(mesh, P(None))  # [B] tokens: tiny, replicated
        jitted = jax.jit(
            step,
            in_shardings=(pshard, bshard, cshard),
            out_shardings=(tok_shard, cshard),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(params_abs, batch_abs, cache_abs)
    return lowered


def _measure(cfg: ModelConfig, shape: ShapeSpec, mesh, multi_pod: bool,
             strategy: str = "seq"):
    """(flops, bytes, coll_bytes) per device for one lowered+compiled step."""
    lowered = lower_cell(cfg, shape, mesh, multi_pod=multi_pod,
                         strategy=strategy)
    compiled = lowered.compile()
    c = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    import numpy as _np

    return _np.array([
        float(c.get("flops", 0.0)),
        float(c.get("bytes accessed", 0.0)),
        float(sum(v for k, v in coll.items() if not k.startswith("n_"))),
    ])


PROBE_S = (2048, 4096, 8192)


def corrected_metrics(cfg: ModelConfig, shape: ShapeSpec, mesh,
                      multi_pod: bool, strategy: str = "seq"):
    """XLA's HloCostAnalysis counts a while-loop (layer scan, blockwise-attn
    KV scan, SSD chunk scan) body ONCE, so the full-depth compile undercounts
    flops/bytes/collectives.  Correction: probe small fully-UNROLLED models —
    L in {1,2} x S in {2048,4096,8192} — and extrapolate

        total(L, S) = base(S) + L * per_layer(S)

    with per_layer(S) an exact quadratic fit (attention is quadratic in S;
    everything else linear, so the degree-2 polynomial through 3 points is
    the true law) and base(S) linear.  Decode shapes have no S-dependent
    inner scans, so they probe L in {1,2} directly at the target cache
    length.  Hybrids (global-attn layers among SWA layers) get an extra
    probe family to price the two layer kinds separately."""
    from dataclasses import replace
    import numpy as np

    hybrid = cfg.attn == "swa" and bool(cfg.global_attn_layers)
    n_g = len(cfg.global_attn_layers) if hybrid else 0
    n_s = cfg.n_layers - n_g

    def probe(n_layers, global_layers, seq=None):
        cfg_p = replace(
            cfg, n_layers=n_layers, global_attn_layers=global_layers,
            scan_unroll=True,
        )
        sp = shape if seq is None else ShapeSpec(
            shape.name, shape.kind, seq, shape.global_batch
        )
        return _measure(cfg_p, sp, mesh, multi_pod, strategy)

    if shape.kind == "decode":
        m1 = probe(1, (0,) if hybrid and 0 in cfg.global_attn_layers else ())
        if hybrid:
            s1 = probe(1, ())
            s2 = probe(2, ())
            per_swa = s2 - s1
            base = s1 - per_swa
            g1 = probe(1, (0,))
            per_g = g1 - base
            tot = base + n_s * per_swa + n_g * per_g
        else:
            m1 = probe(1, ())
            m2 = probe(2, ())
            per = m2 - m1
            tot = m1 + (cfg.n_layers - 1) * per
    else:
        # Train/prefill: blockwise attention computes every KV block (the
        # mask is elementwise), so global vs SWA layers cost the SAME — one
        # probe family suffices even for hybrids.  (Decode differs: cache
        # sizes diverge; handled above.)
        Ss = np.array(PROBE_S, dtype=float)
        pers, bases = [], []
        for S in PROBE_S:
            m1 = probe(1, (), seq=S)
            m2 = probe(2, (), seq=S)
            per = m2 - m1
            pers.append(per)
            bases.append(m1 - per)
        St = float(shape.seq_len)
        tot = np.zeros(3)
        for i in range(3):   # flops, bytes, coll_bytes
            per_poly = np.polyfit(Ss, [p[i] for p in pers], 2)
            base_lin = np.polyfit(Ss, [b[i] for b in bases], 1)
            per_t = float(np.polyval(per_poly, St))
            base_t = float(np.polyval(base_lin, St))
            tot[i] = base_t + cfg.n_layers * per_t
    return {
        "flops": float(max(tot[0], 0.0)),
        "bytes": float(max(tot[1], 0.0)),
        "coll_bytes": float(max(tot[2], 0.0)),
    }


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch   # decode: 1 token per sequence


def run_cell(arch: str, shape_name: str, mesh, mesh_tag: str, *,
             multi_pod: bool, out_dir: Path, probes: bool = True,
             strategy: str = "seq", remat: bool = True) -> dict:
    cfg = get_arch(arch)
    if not remat:
        from dataclasses import replace as _replace

        cfg = _replace(cfg, remat=False)
    shape = SHAPES[shape_name]
    ok, skip = applicable(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "multi_pod": multi_pod,
        "n_devices": int(mesh.devices.size),
    }
    if not ok:
        rec.update(status="skipped", skip_reason=skip)
        return rec
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh, multi_pod=multi_pod,
                         strategy=strategy)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per computation
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    if probes:
        corr = corrected_metrics(cfg, shape, mesh, multi_pod, strategy)
        flops_dev = corr["flops"]
        bytes_dev = corr["bytes"]
        coll_dev = corr["coll_bytes"]
        rec["raw_uncorrected"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        }
    else:
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        coll_dev = float(
            sum(v for k, v in coll.items() if not k.startswith("n_"))
        )
    n_dev = int(mesh.devices.size)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axis_sizes.get("model", 1)
    dp = n_dev // tp
    analytic_bytes = analytic_hbm_bytes(cfg, shape, n_dev, tp, dp)
    terms = roofline_terms(
        flops_dev, bytes_dev, coll_dev,
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW,
        analytic_bytes_per_device=analytic_bytes,
    )
    mflops = model_flops(cfg, shape)
    hlo_total = flops_dev * n_dev
    peak_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                  - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    rec.update(
        status="ok",
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        analytic_bytes_per_device=analytic_bytes,
        collective_bytes_per_device=coll_dev,
        collectives=coll,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": peak_bytes,
            "fits_16GiB": bool(peak_bytes < HBM_BYTES),
        },
        terms=terms,
        model_flops_total=mflops,
        hlo_flops_total=hlo_total,
        useful_flops_ratio=(mflops / hlo_total if hlo_total else 0.0),
        roofline_fraction=(
            (mflops / n_dev / PEAK_FLOPS_BF16) / terms["bound_step_s"]
            if terms["bound_step_s"] > 0 else 0.0
        ),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="CURP framework multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="16x16",
                    help="16x16 | 2x16x16 | RxC (test meshes)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default=None, help="variant tag for perf runs")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the scan-correction probe compiles")
    ap.add_argument("--strategy", default="seq", choices=["seq", "tp", "moe_ep", "hp"],
                    help="activation sharding strategy (perf iterations)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing (perf iterations)")
    args = ap.parse_args()

    dims = tuple(int(x) for x in args.mesh.split("x"))
    multi_pod = len(dims) == 3
    if dims == (16, 16):
        mesh = make_production_mesh(multi_pod=False)
    elif dims == (2, 16, 16):
        mesh = make_production_mesh(multi_pod=True)
    else:
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = make_mesh_from(dims, axes)
    mesh_tag = args.mesh if args.tag is None else f"{args.mesh}+{args.tag}"

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")

    for arch in archs:
        for shape_name in shapes:
            fname = out_dir / f"{arch}__{shape_name}__{mesh_tag}.json".replace(
                "/", "_"
            )
            if args.skip_existing and fname.exists():
                print(f"[skip-existing] {fname.name}")
                continue
            try:
                rec = run_cell(arch, shape_name, mesh, mesh_tag,
                               multi_pod=multi_pod, out_dir=out_dir,
                               probes=not args.no_probes,
                               strategy=args.strategy,
                               remat=not args.no_remat)
            except Exception as e:  # a cell failure is a bug — record it
                rec = {
                    "arch": arch, "shape": shape_name, "mesh": mesh_tag,
                    "status": "error", "error": repr(e),
                    "traceback": traceback.format_exc()[-2000:],
                }
            fname.write_text(json.dumps(rec, indent=1))
            s = rec.get("status")
            if s == "ok":
                t = rec["terms"]
                print(
                    f"[{arch} x {shape_name} x {mesh_tag}] OK "
                    f"compile={rec['compile_s']}s "
                    f"compute={t['compute_s']*1e3:.1f}ms "
                    f"mem={t['memory_s']*1e3:.1f}ms "
                    f"coll={t['collective_s']*1e3:.1f}ms "
                    f"dom={t['dominant']} "
                    f"roofline={rec['roofline_fraction']:.2f} "
                    f"fits={rec['memory']['fits_16GiB']}",
                    flush=True,
                )
            elif s == "skipped":
                print(f"[{arch} x {shape_name}] SKIP: {rec['skip_reason']}",
                      flush=True)
            else:
                print(f"[{arch} x {shape_name} x {mesh_tag}] ERROR: "
                      f"{rec['error']}", flush=True)


if __name__ == "__main__":
    main()
