"""Training launcher: CURP-FT fault-tolerant training for any --arch.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
        --smoke --steps 50 --sync-every 10 --crash-at 23

On this CPU container --smoke (reduced config) is the practical mode; on a
real pod the same entry point runs the full config under the production
mesh (the dry-run proves the sharded step compiles; multi-process init via
jax.distributed is guarded behind --distributed).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description="CURP-FT training launcher")
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--f", type=int, default=3, help="witness/backup count")
    ap.add_argument("--sync-every", type=int, default=10,
                    help="backup sync batch (paper §4.4)")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a master crash at this step, then recover")
    ap.add_argument("--workdir", default="/tmp/curp_ft_run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="multi-process pod launch (jax.distributed)")
    args = ap.parse_args()

    if args.distributed:
        import jax

        jax.distributed.initialize()

    from repro.configs import get_arch
    from repro.data.pipeline import DataConfig
    from repro.ft import FTConfig, FaultTolerantTrainer
    from repro.models.config import reduced

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model}")

    trainer = FaultTolerantTrainer(
        cfg,
        DataConfig(seed=1234, batch=args.batch, seq=args.seq),
        FTConfig(f=args.f, sync_every=args.sync_every,
                 workdir=args.workdir, seed=args.seed),
    )
    t0 = time.time()
    if args.crash_at is not None and args.crash_at < args.steps:
        trainer.train(args.crash_at)
        print(f"[{args.crash_at}] injecting master crash...")
        trainer.crash()
        rep = trainer.recover()
        print(f"  recovered: backup@{rep['restored_step']} "
              f"+ {rep['replayed']} replayed journal steps")
        trainer.train(args.steps - trainer.step)
    else:
        trainer.train(args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in trainer.metrics_log]
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"digest {trainer.params_digest()[:16]}")


if __name__ == "__main__":
    main()
