"""Roofline-term extraction from compiled dry-run artifacts.

All numbers are PER-DEVICE (verified: cost_analysis() on the SPMD-partitioned
module reports the per-device program; so do memory_analysis and the
post-SPMD HLO text).  The three roofline terms are therefore per-device
times, equivalent to the spec's total/(chips x rate) form.

collective_bytes is not in cost_analysis: we parse the compiled HLO and sum
the RESULT buffer sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (including -start forms),
bucketed by collective type.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum per-device result-buffer bytes by collective type."""
    out: Dict[str, int] = {
        "all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
        "all-to-all": 0, "collective-permute": 0,
    }
    counts: Dict[str, int] = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # -start carries the buffers; -done would double count
        kind = m.group(1)
        lhs = line.split(" = ", 1)
        if len(lhs) != 2:
            continue
        # Everything before the op name is the result type (tuple-aware).
        result_type = lhs[1][: lhs[1].find(m.group(0))]
        total = 0
        for dt, dims in _SHAPE_RE.findall(result_type):
            total += _shape_bytes(dt, dims)
        out[kind] += total
        counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts}


def analytic_hbm_bytes(cfg, shape, n_dev: int, tp: int, dp: int) -> float:
    """Per-device-per-step HBM traffic estimate (lower-bound napkin model).

    XLA:CPU HloCostAnalysis 'bytes accessed' counts EVERY op's operands with
    no fusion model — measured 50-100x above credible TPU HBM traffic — so
    the memory roofline term uses this analytic model (the HLO number is
    still recorded as memory_hlo_s).  Terms:

      weights  : dense params are ZeRO-gathered => read in full per pass
                 (train: fwd+bwd+remat = 3 passes); MoE expert params are
                 expert-stationary => /tp.
      optimizer: local param shard f32 m/v read+write + grad + param (train).
      acts     : residual-stream saves/restores + block boundary I/O,
                 ~6 x tokens x D x L (train), 2 x (prefill/decode).
      kv       : attention K/V gathered per layer (seq-sharded scheme);
                 decode reads the cache shard (C/tp per model rank).
      logits   : [tokens, V] write + re-read(s).
    """
    act = 2.0  # bf16
    S = shape.seq_len
    B = shape.global_batch
    kind = shape.kind
    L = cfg.n_layers
    d = cfg.d_model
    V = cfg.vocab
    b_dev = max(B // dp, 1)
    tokens_dev = b_dev * (1 if kind == "decode" else S) / (
        tp if kind != "decode" and S % tp == 0 else 1
    )
    P_tot = cfg.n_params()
    expert_params = (
        L * cfg.n_experts * 3 * d * cfg.moe_d_ff if cfg.has_moe else 0
    )
    dense_params = P_tot - expert_params
    passes = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[kind]
    if kind == "decode":
        # weight-stationary decode (param_specs_decode): each chip reads only
        # its weight shard per token step.
        shard = tp if cfg.n_params() * act / tp < 8e9 else n_dev
        w = (dense_params + expert_params) * act / shard
    else:
        w = passes * (dense_params + expert_params / tp) * act
    o = (P_tot / n_dev) * 16.0 if kind == "train" else 0.0
    a_mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
    a = a_mult * tokens_dev * d * L * act
    kv = 0.0
    if cfg.has_attn:
        hkv = cfg.n_kv_heads * cfg.d_head
        if kind == "decode":
            # global-attn layers read C/tp of cache; swa layers read window
            n_glob = (
                L if cfg.attn == "full" else len(cfg.global_attn_layers)
            )
            n_swa = L - n_glob if cfg.attn == "swa" else 0
            kv = b_dev * 2 * act * hkv * (
                n_glob * (S / tp) + n_swa * min(cfg.swa_window, S)
            )
        else:
            # K/V gathered per layer per device (fwd; bwd re-gathers)
            kv = passes * L * b_dev * S * hkv * 2 * act
    ssm_t = 0.0
    if cfg.ssm:
        state = cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        if kind == "decode":
            ssm_t = b_dev * L * state * 2 * act
        else:
            n_chunks = max(S // cfg.ssm_chunk, 1)
            ssm_t = tokens_dev / S * n_chunks * L * state * 2 * act if S else 0
    lg_mult = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[kind]
    lg = lg_mult * tokens_dev * V * act if kind != "prefill" else (
        b_dev * V * act  # prefill emits last-token logits only
    )
    return w + o + a + kv + ssm_t + lg


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    coll_bytes_per_device: float,
    *,
    peak_flops: float,
    hbm_bw: float,
    ici_bw: float,
    analytic_bytes_per_device: float | None = None,
) -> Dict[str, float]:
    compute_s = flops_per_device / peak_flops
    memory_hlo_s = bytes_per_device / hbm_bw
    memory_s = (
        analytic_bytes_per_device / hbm_bw
        if analytic_bytes_per_device is not None else memory_hlo_s
    )
    coll_s = coll_bytes_per_device / ici_bw
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    total = max(compute_s, memory_s, coll_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_hlo_s": memory_hlo_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "bound_step_s": total,
    }
