"""Step builders: train_step / prefill_step / serve_step for any arch config.

These are the functions the dry-run lowers and the launchers run.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, forward, loss_fn
from repro.optim import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        new_p, new_o, om = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **aux, **om}
        return new_p, new_o, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _ = forward(cfg, params, batch)
        return logits[:, -1, :].astype(jnp.float32)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, batch, cache):
        logits, new_cache = decode_step(cfg, params, batch, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return serve_step
