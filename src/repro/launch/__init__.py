"""repro.launch — meshes, sharding strategies, dry-run, launchers.

NOTE: importing this package never touches jax device state; dryrun.py sets
its XLA device-count flag in its own first two lines.
"""
from .mesh import (
    HBM_BW,
    HBM_BYTES,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_mesh_from,
    make_production_mesh,
)

__all__ = [
    "HBM_BW", "HBM_BYTES", "ICI_BW", "PEAK_FLOPS_BF16",
    "make_mesh_from", "make_production_mesh",
]
