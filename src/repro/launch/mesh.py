"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (TPU v5e pod slice);
multi-pod: 2x16x16 = 512 chips with a leading "pod" data-parallel axis over
the inter-pod DCI links.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_mesh_from(shape, axes):
    """Arbitrary mesh over a device-subset (test/small dry-runs)."""
    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


# TPU v5e hardware constants (roofline denominators; see EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per chip effective)
HBM_BYTES = 16 * 2**30          # 16 GiB per chip
