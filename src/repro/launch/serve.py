"""Serving launcher: CURP-Serve batched decoding for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
        --requests 6 --tokens 16 --crash-at 8
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description="CURP-Serve launcher")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--f", type=int, default=3)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="crash the serving master after N generated tokens")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_arch
    from repro.models.config import reduced
    from repro.serving import CurpServeDriver, ServeConfig

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if not cfg.can_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M")

    driver = CurpServeDriver(
        cfg,
        ServeConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                    f=args.f),
        seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, rng.integers(1, 6)).tolist()
        driver.submit(f"req{i}", prompt)
    t0 = time.time()
    if args.crash_at is not None and args.crash_at < args.tokens:
        driver.generate(args.crash_at)
        print(f"[{args.crash_at} tokens] crashing serving master...")
        rep = driver.crash_and_recover()
        print(f"  recovered {rep['recovered_sessions']} sessions "
              f"({rep['replayed_ops']} witness-replayed commits)")
        driver.generate(args.tokens - args.crash_at)
    else:
        driver.generate(args.tokens)
    dt = time.time() - t0
    for sid, s in driver.sessions.items():
        print(f"  {sid}: {s.tokens}")
    print(f"served {driver.tokens_served} tokens in {dt:.1f}s "
          f"({driver.tokens_served/dt:.0f} tok/s); "
          f"commits fast={driver.store.fast_commits} "
          f"slow={driver.store.slow_commits}")


if __name__ == "__main__":
    main()
