"""Sharding rules: 2-D FSDP("data") x TP("model"), pod-DP on batch.

Parameters shard (data, model) jointly — ZeRO-3 over "data" (XLA inserts the
gather at use) and tensor-parallel over "model" (heads / d_ff / experts).
Head dims that don't divide the model axis stay replicated on that axis
(smollm 15H, hymba 25H, deepseek 56H, qwen2-vl 12H — noted in DESIGN.md §6);
their FSDP sharding still applies.  Optimizer moments reuse the param specs.

All functions return pytrees of PartitionSpec matching the param/batch/cache
trees produced by repro.models.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.transformer import segments
from repro.configs.shapes import ShapeSpec


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def _div(n: int, by: int) -> bool:
    return n % by == 0


def param_specs(cfg: ModelConfig, *, tp: int = 16) -> Dict[str, Any]:
    """PartitionSpec pytree congruent with init_params(cfg)."""
    d, dh = cfg.d_model, cfg.d_head
    heads_tp = "model" if _div(cfg.n_heads * dh, tp) else None
    kv_tp = "model" if _div(cfg.n_kv_heads * dh, tp) else None

    attn = {
        "wq": P(None, "data", heads_tp),
        "wk": P(None, "data", kv_tp),
        "wv": P(None, "data", kv_tp),
        "wo": P(None, heads_tp, "data"),
    }
    if cfg.qk_norm:
        attn["q_norm"] = P(None, None)
        attn["k_norm"] = P(None, None)

    layers: Dict[str, Any] = {"norm1": P(None, None)}
    if cfg.has_attn:
        layers["attn"] = attn
    if cfg.ssm:
        di_tp = "model" if _div(cfg.ssm_d_inner, tp) else None
        layers["ssm"] = {
            "in_proj": P(None, "data", None),
            "conv_w": P(None, None, None),
            "conv_b": P(None, None),
            "A_log": P(None, None),
            "D": P(None, None),
            "dt_bias": P(None, None),
            "ssm_norm": P(None, None),
            "out_proj": P(None, di_tp, "data"),
        }
    if cfg.has_moe:
        layers["norm2"] = P(None, None)
        if _div(cfg.n_experts, tp):
            # expert parallelism over "model"
            moe = {
                "router": P(None, "data", None),
                "w_gate": P(None, "model", "data", None),
                "w_up": P(None, "model", "data", None),
                "w_down": P(None, "model", None, "data"),
            }
        else:
            # uneven expert count (e.g. 60): TP inside each expert's FFN
            moe = {
                "router": P(None, "data", None),
                "w_gate": P(None, None, "data", "model"),
                "w_up": P(None, None, "data", "model"),
                "w_down": P(None, None, "model", "data"),
            }
        if cfg.n_shared_experts:
            sff_tp = "model" if _div(cfg.shared_d_ff, tp) else None
            moe["shared"] = {
                "w_gate": P(None, "data", sff_tp),
                "w_up": P(None, "data", sff_tp),
                "w_down": P(None, sff_tp, "data"),
            }
        layers["moe"] = moe
    elif cfg.has_dense_mlp:
        ff_tp = "model" if _div(cfg.d_ff, tp) else None
        layers["norm2"] = P(None, None)
        mlp = {
            "w_up": P(None, "data", ff_tp),
            "w_down": P(None, ff_tp, "data"),
        }
        if cfg.act == "swiglu":
            mlp["w_gate"] = P(None, "data", ff_tp)
        layers["mlp"] = mlp

    out: Dict[str, Any] = {
        "embed": P("model", "data"),
        "layers": layers,
        "final_norm": P(None),
    }
    if cfg.frontend != "token":
        out["frontend_proj"] = P(None, "data")
    if not cfg.tie_embeddings:
        out["lm_head"] = P("data", "model")
    return out


def param_specs_decode(cfg: ModelConfig, *, tp: int = 16) -> Dict[str, Any]:
    """Weight-stationary 2-D TP for serve_step: every weight matrix shards
    (in -> "data", out -> "model").  Each chip then computes its [D/dp x
    F/tp] tile per matmul (x is gathered — tiny at S=1 — and partial sums
    psum over "data"), so NO weight ever moves: decode stops re-gathering
    the full parameter set every token (66 GB/step for deepseek-33b under
    the training specs; the measured fix is in EXPERIMENTS §Perf)."""
    base = param_specs(cfg, tp=tp)

    # Models whose bf16 weights fit 16-way sharded (<8 GiB/chip) drop the
    # "data"-axis FSDP entirely at decode: zero weight collectives per token.
    # The giants (nemotron) keep 2-D tiles ([D/dp x F/tp] per chip).
    small = cfg.n_params() * 2 / tp < 8e9
    in_axis = None if small else "data"

    def flip(spec_tree):
        def fix(s: P) -> P:
            ent = list(s)
            if len(ent) == 3:        # stacked [L, in, out]
                return P(None, in_axis, "model")
            # 4-dim (MoE experts) are already expert-stationary: keep.
            return s
        return jax.tree_util.tree_map(
            fix, spec_tree, is_leaf=lambda x: isinstance(x, P)
        )

    out = flip(base)
    out["embed"] = P("model", "data")
    if not cfg.tie_embeddings:
        out["lm_head"] = P("data", "model")
    return out


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, *, multi_pod: bool,
                 with_labels: bool, n_dev: int = 256) -> Dict[str, P]:
    dp = dp_axes(multi_pod)
    specs: Dict[str, P] = {}
    # long_500k has global_batch=1: can't shard batch; leave it unsharded.
    bshard = dp if shape.global_batch >= 16 else None
    if (cfg.ssm and shape.kind != "decode"
            and shape.global_batch % n_dev == 0):
        bshard = dp + ("model",)   # match activation_rules' SSM strategy
    if cfg.frontend == "token":
        specs["tokens"] = P(bshard, None)
    else:
        specs["embeds"] = P(bshard, None, None)
    if cfg.pos == "mrope":
        specs["positions"] = P(None, bshard, None)
    if with_labels:
        specs["labels"] = P(bshard, None)
    return specs


def cache_pspecs(cfg: ModelConfig, shape: ShapeSpec, *, multi_pod: bool):
    """Decode-cache pytree specs, congruent with init_decode_cache.

    KV caches [n, B, C, Hkv, dh]: batch over the DP axes when it's large
    enough; the cache length C shards over "model" (each model shard holds a
    sequence chunk; GSPMD turns softmax/contract over C into partial-reduce +
    all-reduce).  This is what keeps 32k x 128-batch KV under HBM.
    """
    dp = dp_axes(multi_pod)
    bshard = dp if shape.global_batch >= 16 else None
    segs = []
    for kind, s, e in segments(cfg):
        entry: Dict[str, Any] = {}
        if cfg.has_attn:
            entry["k"] = P(None, bshard, "model", None, None)
            entry["v"] = P(None, bshard, "model", None, None)
        if cfg.ssm:
            entry["ssm"] = {
                "state": P(None, bshard, None, None, None),
                "conv": P(None, bshard, None, None),
            }
        segs.append(entry)
    return {"pos": P(), "segments": segs}


def activation_rules(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                     multi_pod: bool, strategy: str = "seq") -> Dict[str, Any]:
    """NamedShardings for models.shardctx.constrain kinds.

    Strategy: "2-D token parallelism" — batch shards over the DP axes,
    SEQUENCE shards over "model".  Every per-token op (projections, MLPs,
    norms, logits, loss) then splits over all 256 chips regardless of head
    counts (15/25/56-head configs don't divide 16).  Attention q-blocks are
    sequence-sharded too; K/V are gathered per layer (the all-gathers show up
    honestly in the collective roofline term).  MoE expert buffers shard over
    "model" (EP); decode steps (S=1) shard batch only and lean on the
    C-sharded KV cache.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axis_sizes.get("model", 1)
    n_dev = 1
    for v in axis_sizes.values():
        n_dev *= v
    dp = dp_axes(multi_pod)
    b = dp if shape.global_batch >= 16 else None
    S = 1 if shape.kind == "decode" else shape.seq_len
    sp = "model" if (S % tp == 0 and S // tp >= 128) else None
    # SSM recurrences are sequential over chunks: sequence sharding would put
    # per-step broadcasts on the critical path.  When the global batch covers
    # the whole mesh, shard batch over BOTH axes instead (fully local
    # recurrence; attention/MLP local too).
    if cfg.ssm and shape.kind != "decode" and shape.global_batch % n_dev == 0:
        b = dp + ("model",)
        sp = None
    # Decode: per-token activations are tiny ([B,1,D]); REPLICATE them so the
    # 2-D weight-stationary decode specs never force a weight gather — only
    # x-gathers and [B, F/tp] partial-sum psums move (MBs, not the 10s of GB
    # of re-gathered weights).  The KV cache keeps its (batch x cache-len)
    # sharding separately (cache_pspecs).
    if shape.kind == "decode":
        b = None
    # §Perf "tp" strategy (archs whose heads AND d_ff divide the model axis):
    # weights stay model-sharded at use (Megatron TP) — the ZeRO gather only
    # spans "data" (16x less weight traffic); activations pay [B,S,D] psums.
    if strategy == "tp" and shape.kind != "decode":
        heads_tp = "model" if _div(cfg.n_heads, tp) else None
        ff = cfg.d_ff if cfg.has_dense_mlp else 0
        rules = {
            # NOTE: a Megatron-SP variant (residual seq-sharded between TP
            # blocks) was tried and REFUTED: the per-block x re-gather over
            # "model" costs what the reduce-scatter saves (112.3s vs 75.8s
            # collective on nemotron train_4k — see EXPERIMENTS §Perf B2).
            "residual": P(b, None, None),
            "heads": P(b, None, heads_tp, None),
            "kv_heads": P(b, None,
                          "model" if _div(cfg.n_kv_heads, tp) else None, None),
            "ffn": P(b, None, "model" if ff and _div(ff, tp) else None),
            "moe": P(b, None, None, None),
            "moe_buf": P("model" if _div(cfg.n_experts or 1, tp) else None,
                         None, None),
            "moe_hidden": P("model" if _div(cfg.n_experts or 1, tp) else None,
                            None, None),
            "logits": P(b, None, "model" if _div(cfg.vocab, tp) else None),
            "ssm_states": P(None, b, None, None, None),
            "scores5": None,
        }
        return {k: NamedSharding(mesh, v) for k, v in rules.items()
                if v is not None}
    # expert buffers [E, C, D]: EP over experts when divisible, else shard
    # the capacity dim (C is rounded to a multiple of 64 in moe.py).
    if cfg.has_moe and _div(cfg.n_experts, tp):
        moe_buf = P("model", None, None)
    else:
        moe_buf = P(None, "model", None)
    rules = {
        "residual": P(b, sp, None),
        "heads": P(b, sp, None, None),
        "kv_heads": P(b, None, None, None),   # gathered for attention
        "ffn": P(b, sp, None),
        "moe": P(b, sp, None, None),          # dense-dispatch hidden
        "moe_buf": moe_buf,                   # [E, C, D]
        "moe_hidden": moe_buf,                # [E, C, F]
        "logits": P(b, sp, "model" if sp is None and b == dp
                    and _div(cfg.vocab, tp) else None),
        # decode attention scores [B, G, rep, 1, C]: keep the cache-length
        # axis sharded (partial softmax + psum instead of cache all-gather).
        "scores5": (P(None, None, None, None, "model")
                    if shape.kind == "decode" else None),
        # inter-chunk SSD states [c, B, H, P, N]: replicate over "model" so
        # the sequential recurrence runs locally (one gather, not c
        # broadcasts) when the sequence is model-sharded.
        "ssm_states": P(None, b if isinstance(b, tuple) or b is None else b,
                        None, None, None),
    }
    if strategy == "moe_ep" and cfg.has_moe and shape.kind != "decode":
        # marker: moe_forward switches to the explicit-all-to-all shard_map
        # dispatch (models/moe.py) when this rule is installed.
        rules["moe_ep"] = P()
    if (strategy == "hp" and shape.kind != "decode"
            and _div(cfg.n_heads, tp) and _div(cfg.n_kv_heads, tp)):
        # §Perf "hp": head-parallel attention for full-MHA archs (KV heads
        # divide the mesh).  The residual stays sequence-sharded; entering
        # attention, q/k/v reshard seq->heads (an all-to-all moving only
        # local shards, ~8x cheaper than all-gathering full MHA K/V), the
        # whole attention computes head-parallel with NO KV gather, and the
        # output reshards back.
        rules["heads"] = P(b, None, "model", None)
        rules["kv_heads"] = P(b, None, "model", None)
    return {
        k: NamedSharding(mesh, v) for k, v in rules.items() if v is not None
    }


def opt_specs(pspecs) -> Dict[str, Any]:
    return {
        "m": pspecs,
        "v": pspecs,
        "step": P(),
    }


def sanitize_specs(spec_tree, shape_tree, axis_sizes: Dict[str, int]):
    """Drop mesh axes from any spec dim that doesn't divide evenly (pjit
    rejects uneven explicit arg shardings).  E.g. vocab 50280 can't shard
    16-way; 60 experts can't either — those dims fall back to replicated and
    an alternative dim carries the parallelism."""

    def fix(spec: P, leaf) -> P:
        shape = leaf.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for dim, ent in zip(shape, entries):
            if ent is None:
                out.append(None)
                continue
            axes = ent if isinstance(ent, tuple) else (ent,)
            size = 1
            for a in axes:
                size *= axis_sizes.get(a, 1)
            out.append(ent if dim % size == 0 else None)
        return P(*out)

    return jax.tree_util.tree_map(
        lambda s, l: fix(s, l), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def to_shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
