"""Pallas TPU kernel: batched 2x32-bit key hashing (keyhash2x32).

TPU adaptation (DESIGN.md §4): VPU lanes are 32-bit, so the 64-bit key hash
is carried as (hi, lo) uint32 lanes and mixed with murmur3 fmix32 finalizers
— pure element-wise VPU work, tiled over VMEM blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _C1, _C2, _GOLD, _MIX5, _MIXC, U32


def _fmix32(x):
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def _keyhash_kernel(hi_ref, lo_ref, out_hi_ref, out_lo_ref):
    hi = hi_ref[...].astype(U32)
    lo = lo_ref[...].astype(U32)
    h1 = _fmix32(lo + _GOLD)
    h2 = _fmix32(hi ^ h1)
    h3 = _fmix32(h1 + h2 * _MIX5 + _MIXC)
    out_hi_ref[...] = h2
    out_lo_ref[...] = h3


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def keyhash2x32_pallas(
    hi: jnp.ndarray, lo: jnp.ndarray, *, block: int = 1024,
    interpret: bool = True,
):
    """[N]-shaped (hi, lo) -> mixed (hi', lo').  N must be a multiple of
    ``block``; callers pad (ops.py handles it)."""
    (n,) = hi.shape
    assert n % block == 0, (n, block)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    out_hi, out_lo = pl.pallas_call(
        _keyhash_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), U32),
            jax.ShapeDtypeStruct((n,), U32),
        ],
        interpret=interpret,
    )(hi.astype(U32), lo.astype(U32))
    return out_hi, out_lo
