"""Pallas TPU kernels: set-parallel batched witness record (§4.2) + gc.

Fast-path pipeline (DESIGN.md §4, this PR's layout)
---------------------------------------------------
The witness table is S sets x W ways of 2x32-bit keyhash slots.  Records are
order-dependent *within one set* (an accepted record occupies a way that later
same-key records must conflict with) but **commute across sets** — two records
that probe different sets touch disjoint table rows and disjoint accept bits.
The set-parallel kernel exploits exactly that independence:

  1. A prep pass (repro.kernels.ops._setpar_prep, plain XLA so it fuses with
     the hash) buckets the query batch by probed set ``lo & (S-1)``: a stable
     sort by set id, then a stable sort by rank-within-set.  After the second
     sort, "round" r (the r-th query of every set's run) is one contiguous,
     set-ascending span of the reordered batch.
  2. The kernel runs a grid over set-tiles (TILE_S rows of the table per grid
     cell).  Each cell loops over rounds; one round loads a contiguous query
     chunk (dynamic start, static size), masks it to this cell's sets, and
     resolves up to TILE_S sets **simultaneously** with pure VPU work — every
     set in the round probes, conflict-checks, and fills its first free way in
     the same vectorized step.  The per-cell loop length is the longest run in
     the batch (≈ B/S for hashed keys), not B: the old kernel's O(B)
     sequential ``fori_loop`` becomes O(max-run) with S-wide parallelism.
  3. Accept bits are written round-chunk-contiguously into a [B] output that
     all grid cells revisit (accumulate-on-revisit, same pattern as
     conflict_scan); ops.py unsorts them back to caller order.

VMEM budget: the table tile is 3 x TILE_S x W x 4 B (48 KiB at the default
1024x4 tile = the paper's full geometry), the reordered query batch is
3 x B x 4 B (48 KiB at B=4096) plus the [B+1] round index — far under the
~16 MiB budget at every supported geometry; ``WitnessGeometry.vmem_bytes``
(repro.core.config) computes the whole-table figure used to sanity-check
configured geometries.

Donation / aliasing contract
----------------------------
Both kernels declare ``input_output_aliases`` for the table buffers
(keys_hi/keys_lo/occ -> the corresponding outputs).  What that buys, and
what it does not:

  * WITHIN one jitted program the table is updated in place: the pallas_call
    consumes its operand buffer instead of allocating + copying a second
    [S, W] triple, and in the fused ``ops.fastpath_batch`` the table threads
    prep -> kernel -> result with no intermediate copy.
  * ACROSS public-op calls the jax.jit boundary still owns the buffers:
    without jit-level donation (``donate_argnums``) XLA materializes a fresh
    output buffer per call, and we deliberately do not donate there — the
    oracle/differential tests replay one table against several ops, and CPU
    (where the kernels run in interpret mode) ignores jit donation anyway.
    Cross-call in-place reuse is a TPU deployment follow-up (ROADMAP), wired
    by donating the table argument at the caller's jit boundary.

Op-class plane / merge-lattice consult (CRDT-CURP)
--------------------------------------------------
Occupancy packs the held op's merge-lattice class (repro.core.merge):
``occ == 0`` is empty, ``occ == 1 + class`` is occupied; class SET == 0, so
all-SET tables keep the legacy 0/1 encoding bit-exactly.  Record queries
carry a ``q_cls`` lane; a same-key hit conflicts only when
``(CONFLICT_MATRIX[q_cls] >> (occ - 1)) & 1`` is set — the matrix is a
static 16-entry constant that inlines into the kernel as a where-sum
(``ref.matrix_rows``), so the in-dispatch decision is bit-exact with the
Python ``Witness.record`` lattice check.  README.md details the encoding
and its VMEM cost (zero extra table bytes; one extra [B] query lane).

The sequential reference kernel (`witness_record_seq_pallas`, the pre-refactor
fori_loop design) is kept for the old-vs-new comparison in
benchmarks/fig_fastpath.py and for differential testing; it predates the
op-class plane (classless all-SET semantics, unchanged).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import U32, GangTable, WitnessTable, matrix_rows

# Default number of table rows (sets) handled by one grid cell.  At the
# paper's 1024x4 geometry one tile is the whole table (48 KiB — trivially
# VMEM-resident), so the grid has a single cell; larger geometries split into
# S/TILE_S cells.  Smaller tiles trade VMEM residency for redundant query
# scans (every cell walks the full round sequence and masks to its sets), so
# shrink the tile only when the table itself outgrows VMEM.
DEFAULT_TILE_SETS = 1024


# ---------------------------------------------------------------------------
# Set-parallel record kernel (optionally fused with the conflict scan)
# ---------------------------------------------------------------------------
def _setpar_kernel_body(
    tile_lo, r_blk, nrounds_ref, qhi_ref, qlo_ref, sets_ref, qcls_ref,
    rstart_ref, khi_in, klo_in, occ_in, acc_ref, khi_ref, klo_ref, occ_ref,
):
    """Resolve every set's (short, ordered) query run for one table tile.

    Queries arrive sorted by (rank-within-set, set): round r is a contiguous
    chunk in which each set appears at most once, so one round is a fully
    vectorized [r_blk]-wide probe/insert with no intra-round hazards.
    """
    TILE_S, W = khi_in.shape
    B = qhi_ref.shape[0]
    way_iota = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    rstart = rstart_ref[...]                      # [B + 1] round offsets
    n_rounds = nrounds_ref[0]

    def round_body(r, carry):
        khi, klo, occ = carry
        start = rstart[r]
        end = rstart[r + 1]
        # Static-size window clamped into range; the valid mask trims it to
        # the round's true [start, end) span.
        base = jnp.minimum(start, B - r_blk)
        qhi_c = pl.load(qhi_ref, (pl.ds(base, r_blk),))
        qlo_c = pl.load(qlo_ref, (pl.ds(base, r_blk),))
        sets_c = pl.load(sets_ref, (pl.ds(base, r_blk),))
        qcls_c = pl.load(qcls_ref, (pl.ds(base, r_blk),))
        pos = base + jax.lax.iota(jnp.int32, r_blk)
        valid = (pos >= start) & (pos < end)
        row = sets_c - tile_lo
        in_tile = (row >= 0) & (row < TILE_S)
        m = valid & in_tile
        rowc = jnp.clip(row, 0, TILE_S - 1)
        row_hi = khi[rowc]                        # [r_blk, W] gathers
        row_lo = klo[rowc]
        row_occ = occ[rowc]
        # Merge-lattice consult: a same-key hit conflicts only when the
        # matrix row of the query's class has the held class's bit set
        # (occ packs 1 + class; all-SET tables reproduce the old any-hit
        # conflict exactly).
        mrow = matrix_rows(qcls_c)                # [r_blk] matrix rows
        wcls = jnp.maximum(row_occ - 1, 0)
        conflict = jnp.any(
            (row_occ > 0)
            & (row_hi == qhi_c[:, None])
            & (row_lo == qlo_c[:, None])
            & (((mrow[:, None] >> wcls) & 1) == 1),
            axis=1,
        )
        free = row_occ == 0
        has_free = jnp.any(free, axis=1)
        way = jnp.argmax(free, axis=1)            # first free way per set
        accq = m & ~conflict & has_free           # [r_blk]
        sel = (way_iota == way[:, None]) & accq[:, None]
        new_hi = jnp.where(sel, qhi_c[:, None], row_hi)
        new_lo = jnp.where(sel, qlo_c[:, None], row_lo)
        new_occ = jnp.where(sel, 1 + qcls_c[:, None], row_occ)
        # Distinct sets within a round => distinct rows: scatter is race-free.
        # Non-accepted lanes are routed out of range and dropped.
        srow = jnp.where(accq, rowc, TILE_S)
        khi = khi.at[srow].set(new_hi, mode="drop")
        klo = klo.at[srow].set(new_lo, mode="drop")
        occ = occ.at[srow].set(new_occ, mode="drop")
        old_acc = pl.load(acc_ref, (pl.ds(base, r_blk),))
        pl.store(acc_ref, (pl.ds(base, r_blk),),
                 jnp.where(m, accq.astype(jnp.int32), old_acc))
        return khi, klo, occ

    khi, klo, occ = jax.lax.fori_loop(
        0, n_rounds, round_body, (khi_in[...], klo_in[...], occ_in[...])
    )
    khi_ref[...] = khi
    klo_ref[...] = klo
    occ_ref[...] = occ


def _make_record_kernel(r_blk: int, tile_s: int):
    def kernel(nrounds_ref, qhi_ref, qlo_ref, sets_ref, qcls_ref, rstart_ref,
               khi_in, klo_in, occ_in,
               acc_ref, khi_ref, klo_ref, occ_ref):
        g = pl.program_id(0)

        @pl.when(g == 0)
        def _init_acc():
            # The [B] accept vector is revisited by every cell; cell 0 zeroes
            # it, later cells only overwrite their own sets' positions.
            acc_ref[...] = jnp.zeros_like(acc_ref)

        _setpar_kernel_body(
            g * tile_s, r_blk, nrounds_ref, qhi_ref, qlo_ref, sets_ref,
            qcls_ref, rstart_ref, khi_in, klo_in, occ_in,
            acc_ref, khi_ref, klo_ref, occ_ref,
        )
    return kernel


def _make_fused_kernel(r_blk: int, tile_s: int):
    """Record kernel fused with the §4.3 conflict scan: one pallas_call per
    batch resolves witness accept bits AND master-window conflicts."""
    def kernel(nrounds_ref, qhi_ref, qlo_ref, sets_ref, qcls_ref, rstart_ref,
               whi_ref, wlo_ref, wval_ref,
               khi_in, klo_in, occ_in,
               acc_ref, con_ref, khi_ref, klo_ref, occ_ref):
        g = pl.program_id(0)

        @pl.when(g == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            # Conflict scan touches the whole (tiny) unsynced window, so a
            # single cell computes it; the window stays VMEM-resident.
            # wval packs the window entry's class (0 invalid, else
            # 1 + class); the same matrix consult as the record path.
            qhi = qhi_ref[...]
            qlo = qlo_ref[...]
            wval = wval_ref[...]
            mrow = matrix_rows(qcls_ref[...])
            wcls = jnp.maximum(wval - 1, 0)
            eq = (
                (whi_ref[...][None, :] == qhi[:, None])
                & (wlo_ref[...][None, :] == qlo[:, None])
                & (wval[None, :] > 0)
                & (((mrow[:, None] >> wcls[None, :]) & 1) == 1)
            )
            con_ref[...] = jnp.any(eq, axis=1).astype(jnp.int32)

        _setpar_kernel_body(
            g * tile_s, r_blk, nrounds_ref, qhi_ref, qlo_ref, sets_ref,
            qcls_ref, rstart_ref, khi_in, klo_in, occ_in,
            acc_ref, khi_ref, klo_ref, occ_ref,
        )
    return kernel


def _grid_and_specs(S: int, W: int, B: int, tile_s: int):
    # A non-dividing tile would silently leave table rows uncovered (their
    # queries would all "reject" and their output rows would be garbage).
    assert S % tile_s == 0, f"tile_sets {tile_s} must divide n_sets {S}"
    grid = (S // tile_s,)
    full = lambda shape: pl.BlockSpec(shape, lambda g: tuple(0 for _ in shape))
    tile = pl.BlockSpec((tile_s, W), lambda g: (g, 0))
    return grid, full, tile


@functools.partial(
    jax.jit, static_argnames=("tile_sets", "interpret")
)
def witness_record_setpar_pallas(
    table: WitnessTable,
    qhi_f: jnp.ndarray, qlo_f: jnp.ndarray, sets_f: jnp.ndarray,
    qcls_f: jnp.ndarray, round_start: jnp.ndarray, n_rounds: jnp.ndarray,
    *, tile_sets: int = DEFAULT_TILE_SETS, interpret: bool = True,
):
    """Set-parallel batched record over prep-sorted queries.

    Inputs must come from ``ops._setpar_prep`` (sorted by (rank, set) with
    round offsets); ``qcls_f`` is the per-query merge-lattice op class in
    the same sorted order.  Returns (accepted-in-sorted-order [B], new
    table).  The table inputs are aliased to the table outputs
    (input_output_aliases); see the module docstring for the exact donation
    contract.
    """
    S, W = table.occ.shape
    (B,) = qhi_f.shape
    tile_s = min(tile_sets, S)
    r_blk = min(B, S)
    grid, full, tile = _grid_and_specs(S, W, B, tile_s)
    out = pl.pallas_call(
        _make_record_kernel(r_blk, tile_s),
        grid=grid,
        in_specs=[
            full((1,)), full((B,)), full((B,)), full((B,)), full((B,)),
            full((B + 1,)),
            tile, tile, tile,
        ],
        out_specs=[full((B,)), tile, tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), jnp.int32),
        ],
        input_output_aliases={6: 1, 7: 2, 8: 3},
        interpret=interpret,
    )(n_rounds, qhi_f, qlo_f, sets_f, qcls_f.astype(jnp.int32), round_start,
      table.keys_hi, table.keys_lo, table.occ)
    acc, khi, klo, occ = out
    return acc, WitnessTable(khi, klo, occ)


@functools.partial(
    jax.jit, static_argnames=("tile_sets", "interpret")
)
def fastpath_record_scan_pallas(
    table: WitnessTable,
    qhi_f: jnp.ndarray, qlo_f: jnp.ndarray, sets_f: jnp.ndarray,
    qcls_f: jnp.ndarray, round_start: jnp.ndarray, n_rounds: jnp.ndarray,
    w_hi: jnp.ndarray, w_lo: jnp.ndarray, w_valid: jnp.ndarray,
    *, tile_sets: int = DEFAULT_TILE_SETS, interpret: bool = True,
):
    """Fused fast-path kernel: set-parallel record + conflict scan in ONE
    pallas_call.  Same prep contract as witness_record_setpar_pallas; the
    window (w_hi/w_lo/w_valid) is the master's unsynced-op keyhash window,
    with ``w_valid`` packing each entry's op class (0 invalid, else
    1 + class) and ``qcls_f`` the per-query class, so the in-dispatch
    commutativity decision consults the same merge lattice as the record.

    Returns (accepted [B], conflicts [B], new table), accepted/conflicts in
    sorted order.
    """
    S, W = table.occ.shape
    (B,) = qhi_f.shape
    (U,) = w_hi.shape
    tile_s = min(tile_sets, S)
    r_blk = min(B, S)
    grid, full, tile = _grid_and_specs(S, W, B, tile_s)
    out = pl.pallas_call(
        _make_fused_kernel(r_blk, tile_s),
        grid=grid,
        in_specs=[
            full((1,)), full((B,)), full((B,)), full((B,)), full((B,)),
            full((B + 1,)),
            full((U,)), full((U,)), full((U,)),
            tile, tile, tile,
        ],
        out_specs=[full((B,)), full((B,)), tile, tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), jnp.int32),
        ],
        input_output_aliases={9: 2, 10: 3, 11: 4},
        interpret=interpret,
    )(n_rounds, qhi_f, qlo_f, sets_f, qcls_f.astype(jnp.int32), round_start,
      w_hi, w_lo, w_valid.astype(jnp.int32),
      table.keys_hi, table.keys_lo, table.occ)
    acc, con, khi, klo, occ = out
    return acc, con, WitnessTable(khi, klo, occ)


# ---------------------------------------------------------------------------
# Sequential reference kernel (pre-refactor design, kept for old-vs-new
# benchmarking and differential tests)
# ---------------------------------------------------------------------------
def _record_seq_kernel(qhi_ref, qlo_ref, khi_in, klo_in, occ_in,
                       acc_ref, khi_ref, klo_ref, occ_ref):
    S, W = khi_in.shape
    set_mask = jnp.uint32(S - 1)
    khi_ref[...] = khi_in[...]
    klo_ref[...] = klo_in[...]
    occ_ref[...] = occ_in[...]
    B = qhi_ref.shape[0]
    way_iota = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)

    def body(b, _):
        qhi = pl.load(qhi_ref, (pl.ds(b, 1),))           # [1]
        qlo = pl.load(qlo_ref, (pl.ds(b, 1),))
        s = (qlo[0] & set_mask).astype(jnp.int32)
        row_hi = pl.load(khi_ref, (pl.ds(s, 1), slice(None)))   # [1, W]
        row_lo = pl.load(klo_ref, (pl.ds(s, 1), slice(None)))
        row_occ = pl.load(occ_ref, (pl.ds(s, 1), slice(None)))
        conflict = jnp.any(
            (row_occ == 1) & (row_hi == qhi[0]) & (row_lo == qlo[0])
        )
        free = row_occ == 0
        has_free = jnp.any(free)
        way = jnp.argmax(free)           # first free way
        acc = jnp.logical_and(~conflict, has_free)
        sel = (way_iota == way) & acc
        pl.store(khi_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, qhi[0], row_hi))
        pl.store(klo_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, qlo[0], row_lo))
        pl.store(occ_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, 1, row_occ))
        pl.store(acc_ref, (pl.ds(b, 1),),
                 acc.astype(jnp.int32).reshape((1,)))
        return 0

    jax.lax.fori_loop(0, B, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def witness_record_seq_pallas(
    table: WitnessTable, q_hi: jnp.ndarray, q_lo: jnp.ndarray,
    *, interpret: bool = True,
):
    """Pre-refactor sequential kernel: the whole batch is one ordered
    fori_loop over a single grid cell.  O(B) serial steps — the throughput
    ceiling fig_fastpath measures the set-parallel design against."""
    S, W = table.occ.shape
    (B,) = q_hi.shape
    out = pl.pallas_call(
        _record_seq_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), jnp.int32),
        ],
        interpret=interpret,
    )(q_hi.astype(U32), q_lo.astype(U32),
      table.keys_hi, table.keys_lo, table.occ)
    accepted, khi, klo, occ = out
    return accepted, WitnessTable(khi, klo, occ)


# ---------------------------------------------------------------------------
# Transactional probe: all-or-nothing multi-key record, ONE dispatch
# ---------------------------------------------------------------------------
def _record_txn_kernel(qhi_ref, qlo_ref, own_ref, valid_ref,
                       khi_in, klo_in, occ_in,
                       acc_ref, hit_ref, khi_ref, klo_ref, occ_ref):
    """All K keys of one op accept together or none do (§4.2 multi-object
    updates, without the record-then-rollback second dispatch).

    Decision pass (vectorized over K): every key probes the PRE-op table —
    conflict (same-key hit under a foreign rpc, i.e. ``own == 0``) vetoes
    the whole op, and each inserting key must SEAT: ranked among the op's
    earlier same-set inserters, it claims the set's (rank+1)-th free way, so
    two same-set keys of one op land in distinct ways (the old first-free
    placement aliased them and the second write clobbered the first) and
    the op rejects as full when a set cannot seat all of its keys.  Write
    pass (tiny fori_loop over K, predicated on the op-level accept bit):
    non-hit keys insert at their reserved way.
    """
    S, W = khi_in.shape
    K = qhi_ref.shape[0]
    set_mask = jnp.uint32(S - 1)
    qhi = qhi_ref[...]
    qlo = qlo_ref[...]
    own = own_ref[...]
    valid = valid_ref[...]
    khi0 = khi_in[...]
    klo0 = klo_in[...]
    occ0 = occ_in[...]
    sets = (qlo & set_mask).astype(jnp.int32)                  # [K]
    row_hi = khi0[sets]                                        # [K, W]
    row_lo = klo0[sets]
    row_occ = occ0[sets]
    hit = jnp.any(
        (row_occ > 0) & (row_hi == qhi[:, None]) & (row_lo == qlo[:, None]),
        axis=1,
    )
    free = row_occ == 0
    claim = (valid == 1) & ~hit
    k_iota = jax.lax.iota(jnp.int32, K)
    earlier = k_iota[None, :] < k_iota[:, None]                # [K, K] j < k
    rank = jnp.sum(
        ((sets[:, None] == sets[None, :]) & earlier
         & claim[None, :]).astype(jnp.int32),
        axis=1,
    )
    n_free = jnp.sum(free.astype(jnp.int32), axis=1)
    seat = n_free > rank
    cfree = jnp.cumsum(free.astype(jnp.int32), axis=1)
    selw = free & (cfree == (rank + 1)[:, None])
    way = jnp.argmax(selw, axis=1)                             # reserved way
    ok = jnp.where(own == 1, hit | seat, ~hit & seat)
    accepted = jnp.all(ok | (valid == 0))
    write = accepted & (valid == 1) & ~hit
    acc_ref[...] = accepted.astype(jnp.int32).reshape((1,))
    hit_ref[...] = (hit & (valid == 1)).astype(jnp.int32)
    way_iota = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)

    khi_ref[...] = khi0
    klo_ref[...] = klo0
    occ_ref[...] = occ0

    def body(k, _):
        s = sets[k]
        sel = (way_iota == way[k]) & write[k]                  # [1, W]
        row_hi_k = pl.load(khi_ref, (pl.ds(s, 1), slice(None)))
        row_lo_k = pl.load(klo_ref, (pl.ds(s, 1), slice(None)))
        row_occ_k = pl.load(occ_ref, (pl.ds(s, 1), slice(None)))
        pl.store(khi_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, qhi[k], row_hi_k))
        pl.store(klo_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, qlo[k], row_lo_k))
        pl.store(occ_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, 1, row_occ_k))
        return 0

    jax.lax.fori_loop(0, qhi.shape[0], body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def witness_record_txn_pallas(
    table: WitnessTable,
    q_hi: jnp.ndarray, q_lo: jnp.ndarray,
    own: jnp.ndarray, valid: jnp.ndarray,
    *, interpret: bool = True,
):
    """One-dispatch all-or-nothing record of one op's K (mixed-lane) keys.

    Returns (accepted [1], hit [K], new table): the table outputs alias the
    inputs (same donation contract as the other record kernels) and are
    bit-identical to the inputs when the op rejects — no rollback dispatch
    ever needed.  ``own`` marks keys held under this op's own rpc_id
    (idempotent retry hits, resolved host-side); ``valid`` masks padding.
    """
    S, W = table.occ.shape
    (K,) = q_hi.shape
    out = pl.pallas_call(
        _record_txn_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.int32),
            jax.ShapeDtypeStruct((K,), jnp.int32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), jnp.int32),
        ],
        input_output_aliases={4: 2, 5: 3, 6: 4},
        interpret=interpret,
    )(q_hi.astype(U32), q_lo.astype(U32),
      own.astype(jnp.int32), valid.astype(jnp.int32),
      table.keys_hi, table.keys_lo, table.occ)
    acc, hit, khi, klo, occ = out
    return acc, hit, WitnessTable(khi, klo, occ)


# ---------------------------------------------------------------------------
# GC kernel (order-independent), with the same donation contract
# ---------------------------------------------------------------------------
def _gc_kernel(ghi_ref, glo_ref, khi_in, klo_in, occ_in, occ_ref):
    # occ[s,w] = 0 wherever (hi, lo) matches any gc entry.  G is one gc batch
    # (<= a sync batch), so the [S, W, G] compare cube stays tiny.
    khi = khi_in[...]
    klo = klo_in[...]
    occ = occ_in[...]
    ghi = ghi_ref[...]
    glo = glo_ref[...]
    m = (
        (khi[:, :, None] == ghi[None, None, :])
        & (klo[:, :, None] == glo[None, None, :])
        & (occ[:, :, None] > 0)
    )
    occ_ref[...] = jnp.where(jnp.any(m, axis=-1), 0, occ)


# ---------------------------------------------------------------------------
# Gang kernels: stacked lanes + kernel-held RIFL identity and gc-age state
# ---------------------------------------------------------------------------
# A GangTable is L witness tables flattened to [L*S, W]; queries arrive with
# *global* set rows (lane * S + (q_lo & (S-1))) so the existing set-parallel
# round machinery runs unchanged over the union of all lanes.  Every slot
# additionally holds the recording op's rpc identity and a gc-age counter:
# duplicate-retry acceptance (same key + same rpc), stale-gc suppression
# (clear only on key AND rpc match) and §4.5 age bumping all resolve inside
# the dispatch.  Reason codes (see repro.kernels.ref): 1 insert / 2 dup /
# 3 conflict / 4 set-full / 0 padding.


def _gang_setpar_body(
    tile_lo, r_blk, nrounds_ref,
    qhi_ref, qlo_ref, qrh_ref, qrl_ref, qcls_ref, sets_ref, rstart_ref,
    khi_in, klo_in, occ_in, rh_in, rl_in, age_in,
    rsn_ref, khi_ref, klo_ref, occ_ref, rh_ref, rl_ref, age_ref,
):
    """Set-parallel gang record for one table tile: _setpar_kernel_body
    extended with rpc/age lanes and a per-query reason output."""
    TILE_S, W = khi_in.shape
    B = qhi_ref.shape[0]
    way_iota = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    rstart = rstart_ref[...]
    n_rounds = nrounds_ref[0]

    def round_body(r, carry):
        khi, klo, occ, rh, rl, age = carry
        start = rstart[r]
        end = rstart[r + 1]
        base = jnp.minimum(start, B - r_blk)
        qhi_c = pl.load(qhi_ref, (pl.ds(base, r_blk),))
        qlo_c = pl.load(qlo_ref, (pl.ds(base, r_blk),))
        qrh_c = pl.load(qrh_ref, (pl.ds(base, r_blk),))
        qrl_c = pl.load(qrl_ref, (pl.ds(base, r_blk),))
        qcls_c = pl.load(qcls_ref, (pl.ds(base, r_blk),))
        sets_c = pl.load(sets_ref, (pl.ds(base, r_blk),))
        pos = base + jax.lax.iota(jnp.int32, r_blk)
        valid = (pos >= start) & (pos < end)
        row = sets_c - tile_lo
        in_tile = (row >= 0) & (row < TILE_S)
        m = valid & in_tile
        rowc = jnp.clip(row, 0, TILE_S - 1)
        row_hi = khi[rowc]                            # [r_blk, W] gathers
        row_lo = klo[rowc]
        row_occ = occ[rowc]
        row_rh = rh[rowc]
        row_rl = rl[rowc]
        row_age = age[rowc]
        keym = (
            (row_occ > 0)
            & (row_hi == qhi_c[:, None])
            & (row_lo == qlo_c[:, None])
        )
        rpcm = (row_rh == qrh_c[:, None]) & (row_rl == qrl_c[:, None])
        dupm = keym & rpcm                            # idempotent retry hit
        # Foreign-rpc same-key hit conflicts only when the merge lattice
        # says so (occ packs 1 + class; matrix bit test as in the plain
        # record kernel) — commuting classes stack in sibling ways.
        mrow = matrix_rows(qcls_c)
        wcls = jnp.maximum(row_occ - 1, 0)
        confm = keym & ~rpcm & (((mrow[:, None] >> wcls) & 1) == 1)
        is_dup = jnp.any(dupm, axis=1)
        is_conf = jnp.any(confm, axis=1)
        free = row_occ == 0
        has_free = jnp.any(free, axis=1)
        way = jnp.where(is_dup, jnp.argmax(dupm, axis=1),
                        jnp.argmax(free, axis=1))
        acc = ~is_conf & (is_dup | has_free)
        reason = jnp.where(
            is_conf, 3, jnp.where(is_dup, 2, jnp.where(has_free, 1, 4))
        ).astype(jnp.int32)
        accq = m & acc
        sel = (way_iota == way[:, None]) & accq[:, None]
        new_hi = jnp.where(sel, qhi_c[:, None], row_hi)
        new_lo = jnp.where(sel, qlo_c[:, None], row_lo)
        new_occ = jnp.where(sel, 1 + qcls_c[:, None], row_occ)
        new_rh = jnp.where(sel, qrh_c[:, None], row_rh)
        new_rl = jnp.where(sel, qrl_c[:, None], row_rl)
        new_age = jnp.where(sel, 0, row_age)          # accept resets age
        srow = jnp.where(accq, rowc, TILE_S)
        khi = khi.at[srow].set(new_hi, mode="drop")
        klo = klo.at[srow].set(new_lo, mode="drop")
        occ = occ.at[srow].set(new_occ, mode="drop")
        rh = rh.at[srow].set(new_rh, mode="drop")
        rl = rl.at[srow].set(new_rl, mode="drop")
        age = age.at[srow].set(new_age, mode="drop")
        old_rsn = pl.load(rsn_ref, (pl.ds(base, r_blk),))
        pl.store(rsn_ref, (pl.ds(base, r_blk),),
                 jnp.where(m, reason, old_rsn))
        return khi, klo, occ, rh, rl, age

    khi, klo, occ, rh, rl, age = jax.lax.fori_loop(
        0, n_rounds, round_body,
        (khi_in[...], klo_in[...], occ_in[...],
         rh_in[...], rl_in[...], age_in[...]),
    )
    khi_ref[...] = khi
    klo_ref[...] = klo
    occ_ref[...] = occ
    rh_ref[...] = rh
    rl_ref[...] = rl
    age_ref[...] = age


def _make_gang_record_kernel(r_blk: int, tile_s: int):
    def kernel(nrounds_ref, qhi_ref, qlo_ref, qrh_ref, qrl_ref, qcls_ref,
               sets_ref, rstart_ref,
               khi_in, klo_in, occ_in, rh_in, rl_in, age_in,
               rsn_ref, khi_ref, klo_ref, occ_ref, rh_ref, rl_ref, age_ref):
        g = pl.program_id(0)

        @pl.when(g == 0)
        def _init():
            rsn_ref[...] = jnp.zeros_like(rsn_ref)

        _gang_setpar_body(
            g * tile_s, r_blk, nrounds_ref,
            qhi_ref, qlo_ref, qrh_ref, qrl_ref, qcls_ref, sets_ref,
            rstart_ref,
            khi_in, klo_in, occ_in, rh_in, rl_in, age_in,
            rsn_ref, khi_ref, klo_ref, occ_ref, rh_ref, rl_ref, age_ref,
        )
    return kernel


@functools.partial(jax.jit, static_argnames=("tile_sets", "interpret"))
def gang_record_setpar_pallas(
    table: GangTable,
    qhi_f: jnp.ndarray, qlo_f: jnp.ndarray,
    qrh_f: jnp.ndarray, qrl_f: jnp.ndarray, qcls_f: jnp.ndarray,
    sets_f: jnp.ndarray, round_start: jnp.ndarray, n_rounds: jnp.ndarray,
    *, tile_sets: int = DEFAULT_TILE_SETS, interpret: bool = True,
):
    """Set-parallel single-key record over a stacked gang table.

    Same prep contract as ``witness_record_setpar_pallas`` except the set
    ids are *global* rows (lane * S + local set) and each query carries its
    rpc identity plus its merge-lattice op class (``qcls_f``).  Returns
    (reasons-in-sorted-order [B], new gang table); all six table buffers
    alias their outputs.
    """
    R, W = table.occ.shape
    (B,) = qhi_f.shape
    tile_s = min(tile_sets, R)
    r_blk = min(B, R)
    grid, full, tile = _grid_and_specs(R, W, B, tile_s)
    out = pl.pallas_call(
        _make_gang_record_kernel(r_blk, tile_s),
        grid=grid,
        in_specs=[
            full((1,)), full((B,)), full((B,)), full((B,)), full((B,)),
            full((B,)), full((B,)), full((B + 1,)),
            tile, tile, tile, tile, tile, tile,
        ],
        out_specs=[full((B,)), tile, tile, tile, tile, tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((R, W), U32),
            jax.ShapeDtypeStruct((R, W), U32),
            jax.ShapeDtypeStruct((R, W), jnp.int32),
            jax.ShapeDtypeStruct((R, W), U32),
            jax.ShapeDtypeStruct((R, W), U32),
            jax.ShapeDtypeStruct((R, W), jnp.int32),
        ],
        input_output_aliases={8: 1, 9: 2, 10: 3, 11: 4, 12: 5, 13: 6},
        interpret=interpret,
    )(n_rounds, qhi_f, qlo_f, qrh_f, qrl_f, qcls_f.astype(jnp.int32),
      sets_f, round_start,
      table.keys_hi, table.keys_lo, table.occ,
      table.rpc_hi, table.rpc_lo, table.age)
    rsn = out[0]
    return rsn, GangTable(*out[1:])


def _make_gang_groups_kernel(K: int):
    """Sequential per-group all-or-nothing record: one fori_loop over G
    groups; each group's K (padded) keys decide together against the
    current table and, on accept, write sequentially in key order.  Free
    ways are RESERVED in key order (the k-th same-row inserter takes the
    row's (rank+1)-th free way), matching the fixed Python placement loop —
    same-row keys of one group land in distinct ways instead of aliasing."""
    def kernel(qhi_ref, qlo_ref, qrow_ref, qval_ref, qcls_ref,
               grh_ref, grl_ref, gval_ref,
               khi_in, klo_in, occ_in, rh_in, rl_in, age_in,
               rsn_ref, khi_ref, klo_ref, occ_ref, rh_ref, rl_ref, age_ref):
        W = khi_in.shape[1]
        G = qhi_ref.shape[0]
        khi_ref[...] = khi_in[...]
        klo_ref[...] = klo_in[...]
        occ_ref[...] = occ_in[...]
        rh_ref[...] = rh_in[...]
        rl_ref[...] = rl_in[...]
        age_ref[...] = age_in[...]
        way_iota = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
        k_iota = jax.lax.iota(jnp.int32, K)
        earlier = k_iota[None, :] < k_iota[:, None]            # [K, K] j < k

        def body(g, _):
            qhi_g = pl.load(qhi_ref, (pl.ds(g, 1), slice(None)))[0]   # [K]
            qlo_g = pl.load(qlo_ref, (pl.ds(g, 1), slice(None)))[0]
            qrow_g = pl.load(qrow_ref, (pl.ds(g, 1), slice(None)))[0]
            qval_g = pl.load(qval_ref, (pl.ds(g, 1), slice(None)))[0]
            qcls_g = pl.load(qcls_ref, (pl.ds(g, 1), slice(None)))[0]
            rc = pl.load(grh_ref, (pl.ds(g, 1),))[0]
            rs = pl.load(grl_ref, (pl.ds(g, 1),))[0]
            gv = pl.load(gval_ref, (pl.ds(g, 1),))[0]
            # Decision pass: every key probes the table as left by previous
            # groups (K-row gather, statically unrolled — K is tiny).
            rows = [
                (pl.load(khi_ref, (pl.ds(qrow_g[k], 1), slice(None))),
                 pl.load(klo_ref, (pl.ds(qrow_g[k], 1), slice(None))),
                 pl.load(occ_ref, (pl.ds(qrow_g[k], 1), slice(None))),
                 pl.load(rh_ref, (pl.ds(qrow_g[k], 1), slice(None))),
                 pl.load(rl_ref, (pl.ds(qrow_g[k], 1), slice(None))))
                for k in range(K)
            ]
            row_hi = jnp.concatenate([r[0] for r in rows], axis=0)     # [K, W]
            row_lo = jnp.concatenate([r[1] for r in rows], axis=0)
            row_occ = jnp.concatenate([r[2] for r in rows], axis=0)
            row_rh = jnp.concatenate([r[3] for r in rows], axis=0)
            row_rl = jnp.concatenate([r[4] for r in rows], axis=0)
            keym = (
                (row_occ > 0)
                & (row_hi == qhi_g[:, None])
                & (row_lo == qlo_g[:, None])
            )
            rpcm = (row_rh == rc) & (row_rl == rs)
            dupm = keym & rpcm
            # Merge-lattice consult, same bit test as the setpar kernels.
            mrow = matrix_rows(qcls_g)
            wcls = jnp.maximum(row_occ - 1, 0)
            confm = keym & ~rpcm & (((mrow[:, None] >> wcls) & 1) == 1)
            dup_k = jnp.any(dupm, axis=1)
            conf_k = jnp.any(confm, axis=1)
            free = row_occ == 0
            # Way reservation: rank each inserting key among the group's
            # earlier same-row inserters; it seats iff free ways remain
            # and takes the (rank+1)-th free way.
            claim = (qval_g == 1) & ~dup_k
            rank = jnp.sum(
                ((qrow_g[:, None] == qrow_g[None, :]) & earlier
                 & claim[None, :]).astype(jnp.int32),
                axis=1,
            )
            n_free = jnp.sum(free.astype(jnp.int32), axis=1)
            seat = n_free > rank
            cfree = jnp.cumsum(free.astype(jnp.int32), axis=1)
            selw = free & (cfree == (rank + 1)[:, None])
            way_k = jnp.where(dup_k, jnp.argmax(dupm, axis=1),
                              jnp.argmax(selw, axis=1))
            ok_k = ~conf_k & (dup_k | seat)
            vk = qval_g == 1
            acc = jnp.all(ok_k | ~vk) & (gv == 1)
            all_dup = jnp.all(dup_k | ~vk) & jnp.any(vk)
            # Reject reason comes from the FIRST failing key, like the
            # Python loop that returns at the first conflict/full key.
            fail = vk & ~ok_k
            fail_conf = conf_k[jnp.argmax(fail)]
            reason = jnp.where(
                acc, jnp.where(all_dup, 2, 1), jnp.where(fail_conf, 3, 4)
            )
            reason = jnp.where(gv == 1, reason, 0).astype(jnp.int32)
            pl.store(rsn_ref, (pl.ds(g, 1),), reason.reshape((1,)))
            # Write pass: sequential in key order; ways are pre-reserved so
            # same-row keys never alias.  Rows reload because an earlier
            # key of this group may share the row.
            for k in range(K):
                r = qrow_g[k]
                sel = (way_iota == way_k[k]) & (acc & vk[k])
                hi_k = pl.load(khi_ref, (pl.ds(r, 1), slice(None)))
                lo_k = pl.load(klo_ref, (pl.ds(r, 1), slice(None)))
                oc_k = pl.load(occ_ref, (pl.ds(r, 1), slice(None)))
                rh_k = pl.load(rh_ref, (pl.ds(r, 1), slice(None)))
                rl_k = pl.load(rl_ref, (pl.ds(r, 1), slice(None)))
                ag_k = pl.load(age_ref, (pl.ds(r, 1), slice(None)))
                pl.store(khi_ref, (pl.ds(r, 1), slice(None)),
                         jnp.where(sel, qhi_g[k], hi_k))
                pl.store(klo_ref, (pl.ds(r, 1), slice(None)),
                         jnp.where(sel, qlo_g[k], lo_k))
                pl.store(occ_ref, (pl.ds(r, 1), slice(None)),
                         jnp.where(sel, 1 + qcls_g[k], oc_k))
                pl.store(rh_ref, (pl.ds(r, 1), slice(None)),
                         jnp.where(sel, rc, rh_k))
                pl.store(rl_ref, (pl.ds(r, 1), slice(None)),
                         jnp.where(sel, rs, rl_k))
                pl.store(age_ref, (pl.ds(r, 1), slice(None)),
                         jnp.where(sel, 0, ag_k))
            return 0

        jax.lax.fori_loop(0, G, body, 0)
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def gang_record_groups_pallas(
    table: GangTable,
    qhi: jnp.ndarray, qlo: jnp.ndarray,
    qrow: jnp.ndarray, qval: jnp.ndarray, qcls: jnp.ndarray,
    grh: jnp.ndarray, grl: jnp.ndarray, gval: jnp.ndarray,
    *, interpret: bool = True,
):
    """One-dispatch batch of per-group all-or-nothing records.

    ``qhi/qlo/qrow/qval/qcls`` are [G, K] padded key arrays (mixed lanes,
    global rows, merge-lattice classes); ``grh/grl/gval`` are the per-group
    rpc identity and validity.  Groups resolve sequentially in index order —
    single-key ops are groups of size 1, bit-exact with ``Witness.record``.
    Returns (reason per group [G], new gang table).
    """
    R, W = table.occ.shape
    G, K = qhi.shape
    out = pl.pallas_call(
        _make_gang_groups_kernel(K),
        out_shape=[
            jax.ShapeDtypeStruct((G,), jnp.int32),
            jax.ShapeDtypeStruct((R, W), U32),
            jax.ShapeDtypeStruct((R, W), U32),
            jax.ShapeDtypeStruct((R, W), jnp.int32),
            jax.ShapeDtypeStruct((R, W), U32),
            jax.ShapeDtypeStruct((R, W), U32),
            jax.ShapeDtypeStruct((R, W), jnp.int32),
        ],
        input_output_aliases={8: 1, 9: 2, 10: 3, 11: 4, 12: 5, 13: 6},
        interpret=interpret,
    )(qhi.astype(U32), qlo.astype(U32),
      qrow.astype(jnp.int32), qval.astype(jnp.int32), qcls.astype(jnp.int32),
      grh.astype(U32), grl.astype(U32), gval.astype(jnp.int32),
      table.keys_hi, table.keys_lo, table.occ,
      table.rpc_hi, table.rpc_lo, table.age)
    rsn = out[0]
    return rsn, GangTable(*out[1:])


def _make_gang_gc_kernel(tile_s: int, do_age: bool):
    def kernel(ghi_ref, glo_ref, grh_ref, grl_ref, grow_ref, gval_ref,
               aged_ref,
               khi_in, klo_in, occ_in, rh_in, rl_in, age_in,
               clr_ref, occ_ref, age_ref):
        g = pl.program_id(0)
        tile_lo = g * tile_s
        khi = khi_in[...]                          # [T, W]
        klo = klo_in[...]
        occ = occ_in[...]
        rh = rh_in[...]
        rl = rl_in[...]
        age = age_in[...]
        rows = tile_lo + jax.lax.iota(jnp.int32, tile_s)
        # [T, W, G] cube: clear only where key AND rpc AND row all match —
        # a newer record under a different rpc survives a stale gc entry.
        m = (
            (khi[:, :, None] == ghi_ref[...][None, None, :])
            & (klo[:, :, None] == glo_ref[...][None, None, :])
            & (rh[:, :, None] == grh_ref[...][None, None, :])
            & (rl[:, :, None] == grl_ref[...][None, None, :])
            & (occ[:, :, None] > 0)
            & (rows[:, None, None] == grow_ref[...][None, None, :])
            & (gval_ref[...][None, None, :] == 1)
        )
        clr = jnp.any(m, axis=-1)
        occ_new = jnp.where(clr, 0, occ)
        age_new = jnp.where(clr, 0, age)
        if do_age:
            aged_t = aged_ref[...]                 # [T] per-row age mask
            age_new = jnp.where(
                aged_t[:, None] == 1,
                jnp.where(occ_new > 0, age_new + 1, 0),
                age_new,
            )
        occ_ref[...] = occ_new
        age_ref[...] = age_new
        mine = jnp.any(m, axis=(0, 1)).astype(jnp.int32)   # [G]

        @pl.when(g == 0)
        def _init():
            clr_ref[...] = mine

        @pl.when(g != 0)
        def _accum():
            clr_ref[...] = jnp.maximum(clr_ref[...], mine)
    return kernel


@functools.partial(
    jax.jit, static_argnames=("do_age", "tile_sets", "interpret")
)
def gang_gc_pallas(
    table: GangTable,
    g_hi: jnp.ndarray, g_lo: jnp.ndarray,
    g_rh: jnp.ndarray, g_rl: jnp.ndarray,
    g_row: jnp.ndarray, g_valid: jnp.ndarray,
    aged_rows: jnp.ndarray,
    *, do_age: bool = True,
    tile_sets: int = DEFAULT_TILE_SETS, interpret: bool = True,
):
    """Gang gc: rpc-matched clears + in-kernel §4.5 aging, ONE dispatch.

    Entries carry (key lanes, rpc lanes, global row); a slot clears only on
    a full match, so stale entries never drop a newer same-key record.
    Survivors in rows flagged by ``aged_rows`` age by one round (cleared /
    empty slots reset to 0); ``do_age=False`` is the rollback variant.
    Returns (cleared bit per entry [G], new gang table); occ and age alias
    their outputs, key/rpc lanes are untouched.
    """
    R, W = table.occ.shape
    (G,) = g_hi.shape
    tile_s = min(tile_sets, R)
    grid, full, tile = _grid_and_specs(R, W, G, tile_s)
    row_tile = pl.BlockSpec((tile_s,), lambda g: (g,))
    out = pl.pallas_call(
        _make_gang_gc_kernel(tile_s, do_age),
        grid=grid,
        in_specs=[
            full((G,)), full((G,)), full((G,)), full((G,)),
            full((G,)), full((G,)), row_tile,
            tile, tile, tile, tile, tile, tile,
        ],
        out_specs=[full((G,)), tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((G,), jnp.int32),
            jax.ShapeDtypeStruct((R, W), jnp.int32),
            jax.ShapeDtypeStruct((R, W), jnp.int32),
        ],
        input_output_aliases={9: 1, 12: 2},
        interpret=interpret,
    )(g_hi.astype(U32), g_lo.astype(U32),
      g_rh.astype(U32), g_rl.astype(U32),
      g_row.astype(jnp.int32), g_valid.astype(jnp.int32),
      aged_rows.astype(jnp.int32),
      table.keys_hi, table.keys_lo, table.occ,
      table.rpc_hi, table.rpc_lo, table.age)
    clr, occ, age = out
    return clr, GangTable(table.keys_hi, table.keys_lo, occ,
                          table.rpc_hi, table.rpc_lo, age)


@functools.partial(jax.jit, static_argnames=("interpret",))
def witness_gc_pallas(
    table: WitnessTable, g_hi: jnp.ndarray, g_lo: jnp.ndarray,
    *, interpret: bool = True,
):
    """Clear synced entries.  The occupancy buffer is aliased in-program
    (input_output_aliases: occ in -> occ out), so the dispatch mutates one
    [S, W] occupancy buffer instead of copying it (module docstring has the
    full donation contract)."""
    S, W = table.occ.shape
    occ = pl.pallas_call(
        _gc_kernel,
        out_shape=jax.ShapeDtypeStruct((S, W), jnp.int32),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(g_hi.astype(U32), g_lo.astype(U32),
      table.keys_hi, table.keys_lo, table.occ)
    return WitnessTable(table.keys_hi, table.keys_lo, occ)


# ---------------------------------------------------------------------------
# In-dispatch reason-code counters plane (flight recorder)
# ---------------------------------------------------------------------------

N_REASON_CODES = 5  # index 0 unused; 1..4 = INSERT / DUP / CONFLICT / FULL


def reason_counts_update(
    counters: jnp.ndarray, lanes: jnp.ndarray, reasons: jnp.ndarray,
    valid: jnp.ndarray,
) -> jnp.ndarray:
    """Scatter-accumulate per-lane reason-code outcomes on device.

    ``counters`` is the [n_lanes, N_REASON_CODES] int32 telemetry plane owned
    by the caller's ``WitnessGang``; ``lanes``/``reasons``/``valid`` are flat
    [N] vectors (lane id, REASON_* code, 0/1 participation mask) for the rows
    resolved by one record dispatch.  This is plain-XLA scatter-add, not a
    pallas kernel, on purpose: called inside the jitted record impls it fuses
    into the same single dispatch as the prep sorts (module docstring's
    "plain XLA around one pallas_call" layout), so tracking adds zero extra
    dispatches.  ``mode="drop"`` discards padding rows that carry an
    out-of-range lane.

    VMEM cost: N_REASON_CODES x 4 B per lane (20 B) — noise next to the six
    [L*S, W] table planes (kernels/README.md has the full budget table).
    """
    return counters.at[lanes, reasons].add(
        valid.astype(jnp.int32), mode="drop")
