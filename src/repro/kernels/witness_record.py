"""Pallas TPU kernel: batched set-associative witness record (§4.2).

The witness table (S sets x W ways of 2x32-bit keyhash slots, DESIGN.md §4)
lives entirely in VMEM — at the paper's 1024x4 geometry that is 48 KiB of
state, far under the ~16 MiB VMEM budget, so a single kernel invocation
amortizes the HBM round-trip over a whole batch of record requests.

Records are ORDER-DEPENDENT within a batch (an accepted record occupies a
slot that later conflicting records must see), so the kernel runs a
``fori_loop`` over the batch; each iteration is vectorized across the W ways
of the probed set (VPU lanes).  Accept/reject semantics match
repro.core.witness for single-key records:

  reject  if any occupied way holds the same (hi, lo) keyhash   (conflict)
  reject  if no way in the set is free                          (capacity)
  accept  otherwise, writing the first free way

A companion gc kernel clears synced entries (order-independent, fully
vectorized over the table).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import U32, WitnessTable


def _record_kernel(qhi_ref, qlo_ref, khi_in, klo_in, occ_in,
                   acc_ref, khi_ref, klo_ref, occ_ref):
    S, W = khi_in.shape
    set_mask = jnp.uint32(S - 1)
    # Copy table state into the output refs; the loop mutates those.
    khi_ref[...] = khi_in[...]
    klo_ref[...] = klo_in[...]
    occ_ref[...] = occ_in[...]
    B = qhi_ref.shape[0]
    way_iota = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)

    def body(b, _):
        qhi = pl.load(qhi_ref, (pl.ds(b, 1),))           # [1]
        qlo = pl.load(qlo_ref, (pl.ds(b, 1),))
        s = (qlo[0] & set_mask).astype(jnp.int32)
        row_hi = pl.load(khi_ref, (pl.ds(s, 1), slice(None)))   # [1, W]
        row_lo = pl.load(klo_ref, (pl.ds(s, 1), slice(None)))
        row_occ = pl.load(occ_ref, (pl.ds(s, 1), slice(None)))
        conflict = jnp.any(
            (row_occ == 1) & (row_hi == qhi[0]) & (row_lo == qlo[0])
        )
        free = row_occ == 0
        has_free = jnp.any(free)
        way = jnp.argmax(free)           # first free way
        acc = jnp.logical_and(~conflict, has_free)
        sel = (way_iota == way) & acc
        pl.store(khi_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, qhi[0], row_hi))
        pl.store(klo_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, qlo[0], row_lo))
        pl.store(occ_ref, (pl.ds(s, 1), slice(None)),
                 jnp.where(sel, 1, row_occ))
        pl.store(acc_ref, (pl.ds(b, 1),),
                 acc.astype(jnp.int32).reshape((1,)))
        return 0

    jax.lax.fori_loop(0, B, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def witness_record_pallas(
    table: WitnessTable, q_hi: jnp.ndarray, q_lo: jnp.ndarray,
    *, interpret: bool = True,
):
    """Process a batch of records against the table.  Single grid cell: the
    whole table is the working set and the batch is a sequential scan."""
    S, W = table.occ.shape
    (B,) = q_hi.shape
    out = pl.pallas_call(
        _record_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), U32),
            jax.ShapeDtypeStruct((S, W), jnp.int32),
        ],
        interpret=interpret,
    )(q_hi.astype(U32), q_lo.astype(U32),
      table.keys_hi, table.keys_lo, table.occ)
    accepted, khi, klo, occ = out
    return accepted, WitnessTable(khi, klo, occ)


def _gc_kernel(ghi_ref, glo_ref, khi_in, klo_in, occ_in, occ_ref):
    # occ[s,w] = 0 wherever (hi, lo) matches any gc entry.  G is one gc batch
    # (<= a sync batch), so the [S, W, G] compare cube stays tiny.
    khi = khi_in[...]
    klo = klo_in[...]
    occ = occ_in[...]
    ghi = ghi_ref[...]
    glo = glo_ref[...]
    m = (
        (khi[:, :, None] == ghi[None, None, :])
        & (klo[:, :, None] == glo[None, None, :])
        & (occ[:, :, None] == 1)
    )
    occ_ref[...] = jnp.where(jnp.any(m, axis=-1), 0, occ)


@functools.partial(jax.jit, static_argnames=("interpret",))
def witness_gc_pallas(
    table: WitnessTable, g_hi: jnp.ndarray, g_lo: jnp.ndarray,
    *, interpret: bool = True,
):
    S, W = table.occ.shape
    occ = pl.pallas_call(
        _gc_kernel,
        out_shape=jax.ShapeDtypeStruct((S, W), jnp.int32),
        interpret=interpret,
    )(g_hi.astype(U32), g_lo.astype(U32),
      table.keys_hi, table.keys_lo, table.occ)
    return WitnessTable(table.keys_hi, table.keys_lo, occ)
