"""Pallas TPU kernel: master-side commutativity check (§4.3).

conflicts[b] = any_u( valid[u] & window[u] == query[b] & classes conflict )
— a broadcast compare-reduce between the B incoming keyhashes and the
U-entry unsynced window.  Tiled as a (B-tile x U-tile) grid: the query tile
stays resident in VMEM while window tiles stream through; partial ORs
accumulate into the output block across the U-axis of the grid
(accumulate-on-revisit pattern).

Merge-lattice widening (CRDT-CURP): ``w_valid`` packs the window entry's op
class (0 = invalid, else 1 + class; legacy 0/1 callers get class SET, which
conflicts with everything), and each query carries its own class lane.  The
in-kernel decision is the same one-bit matrix test as the witness record
kernels (ref.matrix_rows), so a same-key INCR over an unsynced INCR is NOT
a conflict — the §4.3 check admits exactly what the widened witness admits.

Tile sizes default to (256, 512): the [Bt, Ut] compare cube is 256x512x4 B
= 512 KiB of VMEM intermediates, well within budget, and the minor dimension
is a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import U32, matrix_rows


def _conflict_kernel(whi_ref, wlo_ref, wval_ref, qhi_ref, qlo_ref, qcls_ref,
                     out_ref):
    u = pl.program_id(1)

    @pl.when(u == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qhi = qhi_ref[...]                     # [Bt]
    qlo = qlo_ref[...]
    whi = whi_ref[...]                     # [Ut]
    wlo = wlo_ref[...]
    wval = wval_ref[...]
    mrow = matrix_rows(qcls_ref[...])      # [Bt] matrix rows
    wcls = jnp.maximum(wval - 1, 0)
    eq = (
        (whi[None, :] == qhi[:, None])
        & (wlo[None, :] == qlo[:, None])
        & (wval[None, :] > 0)
        & (((mrow[:, None] >> wcls[None, :]) & 1) == 1)
    )
    hit = jnp.any(eq, axis=1).astype(jnp.int32)   # [Bt]
    out_ref[...] = jnp.maximum(out_ref[...], hit)  # OR across window tiles


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_u", "interpret")
)
def conflict_scan_pallas(
    w_hi: jnp.ndarray, w_lo: jnp.ndarray, w_valid: jnp.ndarray,
    q_hi: jnp.ndarray, q_lo: jnp.ndarray, q_cls: jnp.ndarray,
    *, block_b: int = 256, block_u: int = 512, interpret: bool = True,
):
    (U,) = w_hi.shape
    (B,) = q_hi.shape
    assert B % block_b == 0 and U % block_u == 0, (B, U, block_b, block_u)
    grid = (B // block_b, U // block_u)
    wspec = pl.BlockSpec((block_u,), lambda b, u: (u,))
    qspec = pl.BlockSpec((block_b,), lambda b, u: (b,))
    out = pl.pallas_call(
        _conflict_kernel,
        grid=grid,
        in_specs=[wspec, wspec, wspec, qspec, qspec, qspec],
        out_specs=pl.BlockSpec((block_b,), lambda b, u: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(w_hi.astype(U32), w_lo.astype(U32), w_valid.astype(jnp.int32),
      q_hi.astype(U32), q_lo.astype(U32), q_cls.astype(jnp.int32))
    return out
