"""jit'd public wrappers around the CURP Pallas kernels.

Each op pads/validates shapes, picks interpret mode automatically (interpret
on CPU — the kernels target TPU), and exposes a pytree-friendly API used by
the device-side witness in repro.serving.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .conflict_scan import conflict_scan_pallas
from .keyhash import keyhash2x32_pallas
from .ref import (
    U32,
    WitnessTable,
    ref_conflict_scan,
    ref_keyhash2x32,
    ref_witness_gc,
    ref_witness_record,
)
from .witness_record import witness_gc_pallas, witness_record_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, m: int, fill=0) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % m
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])
    return x, n


def keyhash2x32(hi, lo, *, block: int = 1024, interpret: bool | None = None):
    """Batched 64-bit-equivalent key hash as (hi, lo) uint32 lanes."""
    if interpret is None:
        interpret = not _on_tpu()
    hi = jnp.asarray(hi, U32)
    lo = jnp.asarray(lo, U32)
    hp, n = _pad_to(hi, block)
    lp, _ = _pad_to(lo, block)
    oh, ol = keyhash2x32_pallas(hp, lp, block=block, interpret=interpret)
    return oh[:n], ol[:n]


def shard_route(hi, lo, n_shards: int, *, block: int = 1024,
                interpret: bool | None = None) -> jnp.ndarray:
    """Batched key -> shard placement: keyhash2x32 mix, low lane mod
    ``n_shards``.  Must agree bit-for-bit with the pure-Python
    ``repro.core.shard.KeyRouter`` (same fmix32 chain) so device-side routing
    and protocol-side placement never disagree.  Returns [N] int32 shard ids.
    """
    _, ol = keyhash2x32(hi, lo, block=block, interpret=interpret)
    return (ol % jnp.uint32(n_shards)).astype(jnp.int32)


def witness_record(table: WitnessTable, q_hi, q_lo,
                   *, interpret: bool | None = None):
    """Batched record RPCs against a device-side witness table.

    Returns (accepted [B] int32, new_table).
    """
    if interpret is None:
        interpret = not _on_tpu()
    q_hi = jnp.asarray(q_hi, U32)
    q_lo = jnp.asarray(q_lo, U32)
    return witness_record_pallas(table, q_hi, q_lo, interpret=interpret)


def witness_gc(table: WitnessTable, g_hi, g_lo,
               *, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return witness_gc_pallas(
        table, jnp.asarray(g_hi, U32), jnp.asarray(g_lo, U32),
        interpret=interpret,
    )


def conflict_scan(w_hi, w_lo, w_valid, q_hi, q_lo,
                  *, block_b: int = 256, block_u: int = 512,
                  interpret: bool | None = None):
    """Commutativity check of B queries vs a U-entry unsynced window."""
    if interpret is None:
        interpret = not _on_tpu()
    w_hi = jnp.asarray(w_hi, U32)
    w_lo = jnp.asarray(w_lo, U32)
    w_valid = jnp.asarray(w_valid, jnp.int32)
    q_hi = jnp.asarray(q_hi, U32)
    q_lo = jnp.asarray(q_lo, U32)
    whp, u = _pad_to(w_hi, block_u)
    wlp, _ = _pad_to(w_lo, block_u)
    wvp, _ = _pad_to(w_valid, block_u)      # padding is valid=0 => no hits
    qhp, b = _pad_to(q_hi, block_b)
    qlp, _ = _pad_to(q_lo, block_b)
    out = conflict_scan_pallas(
        whp, wlp, wvp, qhp, qlp,
        block_b=block_b, block_u=block_u, interpret=interpret,
    )
    return out[:b]


__all__ = [
    "WitnessTable", "keyhash2x32", "shard_route", "witness_record",
    "witness_gc", "conflict_scan",
    "ref_keyhash2x32", "ref_witness_record", "ref_witness_gc",
    "ref_conflict_scan",
]
