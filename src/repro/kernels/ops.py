"""jit'd public wrappers around the CURP Pallas kernels.

Each op pads/validates shapes, picks interpret mode automatically (interpret
on CPU — the kernels target TPU), and exposes a pytree-friendly API used by
the device-side witness (repro.core.device_witness) and the fast-path
benchmarks.

Fast-path pipeline
------------------
``fastpath_batch`` is the one-dispatch-per-batch op: it fuses

    keyhash2x32 -> shard_route -> witness_record -> conflict_scan

into a single jitted call whose only pallas_call is the fused set-parallel
record+scan kernel (the hash/route/sort prep is plain XLA that fuses around
it).  The per-op path costs 3-4 device dispatches per update (hash, record,
scan, sometimes route); the fused path costs exactly one per *batch*.
``dispatch_count()`` exposes a host-side counter that fig_fastpath uses to
demonstrate the difference.

The set-parallel prep (``_setpar_prep``) buckets a query batch by probed set:
a stable sort by ``lo & (S-1)``, a rank-within-set computation, and a second
stable sort by rank — after which "round" r (the r-th query of every set) is
one contiguous span and the kernel resolves whole rounds vectorized across
sets.  See repro/kernels/witness_record.py for the kernel-side story and the
buffer-donation contract.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .conflict_scan import conflict_scan_pallas
from .keyhash import keyhash2x32_pallas
from .ref import (
    U32,
    GangTable,
    WitnessTable,
    conflict_matrix_np,
    matrix_rows,
    np_keyhash2x32,
    ref_conflict_scan,
    ref_gang_gc,
    ref_gang_record,
    ref_keyhash2x32,
    ref_witness_gc,
    ref_witness_record,
    ref_witness_record_txn,
)
from .witness_record import (
    DEFAULT_TILE_SETS,
    N_REASON_CODES,
    fastpath_record_scan_pallas,
    gang_gc_pallas,
    gang_record_groups_pallas,
    gang_record_setpar_pallas,
    reason_counts_update,
    witness_gc_pallas,
    witness_record_seq_pallas,
    witness_record_setpar_pallas,
    witness_record_txn_pallas,
)

# ---------------------------------------------------------------------------
# Host-side dispatch accounting (benchmarks read this; see module docstring)
# ---------------------------------------------------------------------------
# Backed by the telemetry metrics registry ("kernels.dispatches") so the
# flight recorder sees device-program launches next to the protocol counters;
# the three functions below are kept as the stable public API.  The import is
# lazy because repro.core's package __init__ imports this module (device
# witness) — telemetry itself is a leaf with no repro imports.
_DISPATCH_COUNTER = "kernels.dispatches"


def _count_dispatch(n: int = 1) -> None:
    from repro.core.telemetry import registry

    registry().counter(_DISPATCH_COUNTER).inc(n)


def dispatch_count() -> int:
    """Jitted-program launches issued via this module since the last reset.

    Structural accounting, not a device-side trace: each public op wraps
    exactly one jitted program (every prep/pad step is host-side numpy, so
    the jitted call is the only device program a wrapper launches), and the
    counter increments once per wrapper call.  fig_fastpath uses it to show
    the API-level amortization — 3 program launches per op on the per-op
    path vs 1 per *batch* on the fused path.  It does not see launches made
    outside this module, nor would it catch a second pallas_call added
    inside an impl (the parity tests pin the impl's behavior instead).
    """
    from repro.core.telemetry import registry

    return registry().counter(_DISPATCH_COUNTER).value


def reset_dispatch_count() -> None:
    from repro.core.telemetry import registry

    registry().counter(_DISPATCH_COUNTER).reset()


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Slot-table routing (live reconfiguration)
# ---------------------------------------------------------------------------
# Default size of the slot table: keys hash to one of DEFAULT_N_SLOTS slots
# (mixed low lane mod n_slots) and a slot -> shard table names the owner.
# Migration moves SLOTS between shards by editing the table — the hash never
# changes, so only the gather array does.  Must match
# repro.core.shard.N_SLOTS (the pure-Python mirror).
DEFAULT_N_SLOTS = 256


def default_slot_map(n_shards: int, n_slots: int = DEFAULT_N_SLOTS) -> np.ndarray:
    """Round-robin slot -> shard table: slot i is owned by shard i % N.

    For power-of-two shard counts that divide ``n_slots`` this reproduces
    the pre-slot-map ``% n_shards`` placement exactly
    ((h % n_slots) % n == h % n when n | n_slots).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    return (np.arange(n_slots, dtype=np.int32) % n_shards).astype(np.int32)


def _pad_to(x: jnp.ndarray, m: int, fill=0) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % m
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])
    return x, n


# ---------------------------------------------------------------------------
# Set-parallel prep: bucket the batch by probed set (traced; fuses into the
# surrounding jit)
# ---------------------------------------------------------------------------
def _setpar_prep(n_sets: int, q_hi: jnp.ndarray, q_lo: jnp.ndarray,
                 q_valid: jnp.ndarray | None = None,
                 sets: jnp.ndarray | None = None):
    """Sort a query batch into round-contiguous set-parallel order.

    Returns (qhi_f, qlo_f, sets_f, round_start, n_rounds, perm) where
    ``perm`` maps final positions -> original batch positions,
    ``round_start[r]`` is the offset of round r in the final order (round r
    holds every set's r-th query, set-ascending), and ``n_rounds`` is a [1]
    int32 array (the longest per-set run).

    ``q_valid`` marks bucket-padding lanes: invalid queries get the
    out-of-range set id ``n_sets`` and rank B, so they sort to the tail,
    fall beyond ``n_rounds``, and are never touched by the kernel (their
    accept bit stays 0).

    ``sets`` optionally supplies precomputed set ids (the gang path derives
    GLOBAL rows ``lane * S + (lo & (S-1))`` over the stacked table, with
    ``n_sets`` = total rows); by default the ids come from the low lane.
    Permute any additional per-query arrays with the returned ``perm``.
    """
    (B,) = q_hi.shape
    if sets is None:
        sets = (q_lo & jnp.uint32(n_sets - 1)).astype(jnp.int32)   # [B]
    else:
        sets = sets.astype(jnp.int32)
    if q_valid is None:
        valid = jnp.ones((B,), jnp.int32)
    else:
        valid = q_valid.astype(jnp.int32)
        sets = jnp.where(valid == 1, sets, jnp.int32(n_sets))
    order1 = jnp.argsort(sets, stable=True)                        # by set
    sets_s = sets[order1]
    seg_count = jnp.zeros((n_sets,), jnp.int32).at[sets].add(
        valid, mode="drop"
    )
    seg_start = jnp.cumsum(seg_count) - seg_count                  # exclusive
    rank_s = jnp.where(
        sets_s < n_sets,
        jnp.arange(B, dtype=jnp.int32)
        - seg_start[jnp.clip(sets_s, 0, n_sets - 1)],
        jnp.int32(B),
    )
    # Stable sort by rank keeps the set-ascending order within each round.
    order2 = jnp.argsort(rank_s, stable=True)
    perm = order1[order2]
    rank_f = rank_s[order2]
    round_start = jnp.searchsorted(
        rank_f, jnp.arange(B + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    # Longest VALID run (invalid lanes carry the rank-B sentinel).
    n_rounds = (
        jnp.max(jnp.where(rank_f >= B, jnp.int32(-1), rank_f)) + 1
    ).reshape((1,))
    return q_hi[perm], q_lo[perm], sets_s[order2], round_start, n_rounds, perm


def _unsort(perm: jnp.ndarray, x_sorted: jnp.ndarray) -> jnp.ndarray:
    return jnp.zeros_like(x_sorted).at[perm].set(x_sorted)


def _bucket(n: int, lo: int = 16) -> int:
    """Next power-of-two >= n (>= lo): stable jit-cache keys across the
    varying batch sizes the protocol layer produces."""
    b = lo
    while b < n:
        b <<= 1
    return b


def _pad_valid(B: int, *arrays):
    """Pad 1-D arrays to the bucket size; returns (padded..., valid).

    Host-side numpy on purpose: padding must happen OUTSIDE the jit (the
    cache keys on shapes, and bucketing is what keeps it O(log B)), and
    doing it in numpy means it costs zero device-op launches — the padded
    arrays enter the device once, at the jitted call's transfer.
    """
    pad = _bucket(B) - B
    valid = np.ones((B + pad,), np.int32)
    valid[B:] = 0
    out = tuple(
        np.concatenate([np.asarray(a), np.zeros((pad,), np.asarray(a).dtype)])
        if pad else np.asarray(a)
        for a in arrays
    )
    return out + (valid,)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_sets"))
def _witness_record_impl(table: WitnessTable, q_hi, q_lo, q_cls, q_valid,
                         interpret: bool, tile_sets: int):
    S, _W = table.occ.shape
    qhi_f, qlo_f, sets_f, rstart, n_rounds, perm = _setpar_prep(
        S, q_hi, q_lo, q_valid
    )
    acc_f, new_table = witness_record_setpar_pallas(
        table, qhi_f, qlo_f, sets_f, q_cls[perm], rstart, n_rounds,
        tile_sets=tile_sets, interpret=interpret,
    )
    return _unsort(perm, acc_f), new_table


# ---------------------------------------------------------------------------
# Public ops
# ---------------------------------------------------------------------------
def keyhash2x32(hi, lo, *, block: int = 1024, interpret: bool | None = None):
    """Batched 64-bit-equivalent key hash as (hi, lo) uint32 lanes."""
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    hi = jnp.asarray(hi, U32)
    lo = jnp.asarray(lo, U32)
    hp, n = _pad_to(hi, block)
    lp, _ = _pad_to(lo, block)
    oh, ol = keyhash2x32_pallas(hp, lp, block=block, interpret=interpret)
    return oh[:n], ol[:n]


@functools.partial(jax.jit, static_argnames=("n_slots", "block", "interpret"))
def _shard_route_impl(hi, lo, slot_map, n_slots: int, block: int,
                      interpret: bool):
    _oh, ol = keyhash2x32_pallas(hi, lo, block=block, interpret=interpret)
    slots = (ol % jnp.uint32(n_slots)).astype(jnp.int32)
    return slot_map[slots]


def shard_route(hi, lo, n_shards: int | None = None, *,
                slot_map=None, n_slots: int = DEFAULT_N_SLOTS,
                block: int = 1024,
                interpret: bool | None = None) -> jnp.ndarray:
    """Batched key -> shard placement by SLOT-TABLE GATHER: keyhash2x32 mix,
    low lane mod ``n_slots`` picks a slot, ``slot_map[slot]`` names the
    shard.  Must agree bit-for-bit with the pure-Python
    ``repro.core.shard.SlotRouter`` (same fmix32 chain, same table) so
    device-side routing and protocol-side placement never disagree — on any
    slot map, including mid-migration ones.  Returns [N] int32 shard ids.

    ``slot_map`` is a traced array input, NOT a static arg: editing it (a
    live slot handover) never recompiles.  With only ``n_shards`` given, the
    round-robin ``default_slot_map`` is used — the mod-N compatibility
    placement.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if slot_map is None:
        if n_shards is None:
            raise ValueError("shard_route needs n_shards or slot_map")
        slot_map = default_slot_map(n_shards, n_slots)
    slot_map = jnp.asarray(np.asarray(slot_map, np.int32))
    n_slots = int(slot_map.shape[0])
    _count_dispatch()
    hi = jnp.asarray(hi, U32)
    lo = jnp.asarray(lo, U32)
    hp, n = _pad_to(hi, block)
    lp, _ = _pad_to(lo, block)
    out = _shard_route_impl(hp, lp, slot_map, n_slots, block, interpret)
    return out[:n]


def witness_record(table: WitnessTable, q_hi, q_lo, q_cls=None,
                   *, interpret: bool | None = None,
                   tile_sets: int = DEFAULT_TILE_SETS):
    """Batched record RPCs against a device-side witness table, resolved by
    the set-parallel kernel (order preserved per set; sets in parallel).

    ``q_cls`` is the optional per-query merge-lattice op class
    (repro.core.merge; default SET, which reproduces the classless any-hit
    conflict rule).  Returns (accepted [B] int32, new_table).  Table buffers
    are aliased in-program (no intermediate copy inside the dispatch);
    rebind ``table`` to the returned table (see witness_record.py for the
    exact contract).
    """
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    q_hi = np.asarray(q_hi, np.uint32)
    q_lo = np.asarray(q_lo, np.uint32)
    (B,) = q_hi.shape
    q_cls = (np.zeros((B,), np.int32) if q_cls is None
             else np.asarray(q_cls, np.int32))
    q_hi, q_lo, q_cls, valid = _pad_valid(B, q_hi, q_lo, q_cls)
    acc, new_table = _witness_record_impl(
        table, q_hi, q_lo, jnp.asarray(q_cls), valid, interpret, tile_sets
    )
    return acc[:B], new_table


def witness_record_seq(table: WitnessTable, q_hi, q_lo,
                       *, interpret: bool | None = None):
    """Pre-refactor sequential-kernel record path (whole batch = one ordered
    fori_loop).  Kept for old-vs-new benchmarking and differential tests."""
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    q_hi = jnp.asarray(q_hi, U32)
    q_lo = jnp.asarray(q_lo, U32)
    return witness_record_seq_pallas(table, q_hi, q_lo, interpret=interpret)


def witness_gc(table: WitnessTable, g_hi, g_lo,
               *, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    return witness_gc_pallas(
        table, jnp.asarray(g_hi, U32), jnp.asarray(g_lo, U32),
        interpret=interpret,
    )


def conflict_scan(w_hi, w_lo, w_valid, q_hi, q_lo, q_cls=None,
                  *, block_b: int = 256, block_u: int = 512,
                  interpret: bool | None = None):
    """Commutativity check of B queries vs a U-entry unsynced window.

    ``w_valid`` packs each window entry's merge-lattice class (0 invalid,
    else 1 + class; legacy 0/1 callers get class SET) and ``q_cls`` is the
    optional per-query class — same in-dispatch matrix consult as the
    witness record kernels.
    """
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    w_hi = jnp.asarray(w_hi, U32)
    w_lo = jnp.asarray(w_lo, U32)
    w_valid = jnp.asarray(w_valid, jnp.int32)
    q_hi = jnp.asarray(q_hi, U32)
    q_lo = jnp.asarray(q_lo, U32)
    if q_cls is None:
        q_cls = jnp.zeros(q_hi.shape, jnp.int32)
    else:
        q_cls = jnp.asarray(q_cls, jnp.int32)
    whp, u = _pad_to(w_hi, block_u)
    wlp, _ = _pad_to(w_lo, block_u)
    wvp, _ = _pad_to(w_valid, block_u)      # padding is valid=0 => no hits
    qhp, b = _pad_to(q_hi, block_b)
    qlp, _ = _pad_to(q_lo, block_b)
    qcp, _ = _pad_to(q_cls, block_b)
    out = conflict_scan_pallas(
        whp, wlp, wvp, qhp, qlp, qcp,
        block_b=block_b, block_u=block_u, interpret=interpret,
    )
    return out[:b]


# ---------------------------------------------------------------------------
# Fused fast path: hash -> route -> record -> conflict scan, one dispatch
# ---------------------------------------------------------------------------
class FastPathResult(NamedTuple):
    """Result of one fused fast-path batch (all [B], caller order)."""
    accepted: jnp.ndarray    # witness accept bit per op
    conflicts: jnp.ndarray   # master-window conflict bit per op
    shard_ids: jnp.ndarray   # keyhash2x32 placement (int32)
    q_hi: jnp.ndarray        # mixed keyhash lanes — callers extend their
    q_lo: jnp.ndarray        # unsynced window with these on accept
    table: WitnessTable      # updated witness table (donated buffers)


@functools.partial(
    jax.jit, static_argnames=("n_slots", "interpret", "tile_sets")
)
def _fastpath_impl(table, w_hi, w_lo, w_valid, k_hi, k_lo, k_cls, k_valid,
                   slot_map, n_slots: int, interpret: bool, tile_sets: int):
    # Hash: bit-exact with the keyhash2x32 Pallas kernel (same fmix32 chain);
    # inlined here so XLA fuses it with the sort/segment prep.
    qh, ql = ref_keyhash2x32(k_hi, k_lo)
    # Slot-table routing: the gather is plain XLA fused around the single
    # pallas_call; the map is a traced input, so a live slot handover (table
    # edit) never recompiles this program.
    slots = (ql % jnp.uint32(n_slots)).astype(jnp.int32)
    shard_ids = slot_map[slots]
    S, _W = table.occ.shape
    qhi_f, qlo_f, sets_f, rstart, n_rounds, perm = _setpar_prep(
        S, qh, ql, k_valid
    )
    acc_f, con_f, new_table = fastpath_record_scan_pallas(
        table, qhi_f, qlo_f, sets_f, k_cls[perm], rstart, n_rounds,
        w_hi, w_lo, w_valid, tile_sets=tile_sets, interpret=interpret,
    )
    return (_unsort(perm, acc_f), _unsort(perm, con_f), shard_ids,
            qh, ql, new_table)


def fastpath_batch(
    table: WitnessTable, key_hi, key_lo, key_cls=None,
    *, window_hi=None, window_lo=None, window_valid=None,
    n_shards: int = 1, slot_map=None, n_slots: int = DEFAULT_N_SLOTS,
    interpret: bool | None = None,
    tile_sets: int = DEFAULT_TILE_SETS,
) -> FastPathResult:
    """One fused device dispatch for a whole update batch.

    ``key_hi``/``key_lo`` are the RAW 64-bit keyhash lanes (types.keyhash
    split into uint32 halves); the op mixes them (keyhash2x32), derives shard
    placement by slot-table gather (``slot_map``, or the round-robin default
    for ``n_shards``; the map is a traced input, so live slot handovers
    never recompile), resolves witness accept/reject via the set-parallel
    kernel, and checks commutativity against the master's unsynced window —
    all in a single jitted program containing a single pallas_call.

    ``key_cls`` is the optional per-op merge-lattice class (default SET);
    it widens BOTH in-dispatch decisions — witness record and window scan —
    with the same matrix as the Python path.  The window arguments are
    MIXED lanes (as previously returned in ``FastPathResult.q_hi/q_lo``),
    with ``window_valid`` packing the entry class (0 invalid, else
    1 + class; plain 0/1 means class SET); omit them for an empty window.
    Table buffers are donated; rebind to ``result.table``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if slot_map is None:
        slot_map = default_slot_map(n_shards, n_slots)
    slot_map = np.asarray(slot_map, np.int32)
    n_slots = int(slot_map.shape[0])
    _count_dispatch()
    key_hi = np.asarray(key_hi, np.uint32)
    key_lo = np.asarray(key_lo, np.uint32)
    if window_hi is None or np.asarray(window_hi).shape[0] == 0:
        if window_lo is not None and np.asarray(window_lo).shape[0] > 0:
            raise ValueError("window_lo given without window_hi")
        w_hi = np.zeros((1,), np.uint32)
        w_lo = np.zeros((1,), np.uint32)
        w_val = np.zeros((1,), np.int32)
    else:
        if window_lo is None:
            raise ValueError("window_hi given without window_lo")
        w_hi = np.asarray(window_hi, np.uint32)
        w_lo = np.asarray(window_lo, np.uint32)
        w_val = (np.ones(w_hi.shape, np.int32) if window_valid is None
                 else np.asarray(window_valid, np.int32))
    # Bucket-pad the batch and the window (host-side): the protocol layer
    # produces arbitrary sizes per shard; padding keeps the jit cache to
    # O(log B) entries.  Padded query lanes are masked out end to end;
    # padded window lanes carry valid=0 and can never hit.
    (B,) = key_hi.shape
    key_cls = (np.zeros((B,), np.int32) if key_cls is None
               else np.asarray(key_cls, np.int32))
    key_hi, key_lo, key_cls, k_valid = _pad_valid(B, key_hi, key_lo, key_cls)
    (U,) = w_hi.shape
    pad_u = _bucket(U) - U
    if pad_u:
        w_hi = np.concatenate([w_hi, np.zeros((pad_u,), np.uint32)])
        w_lo = np.concatenate([w_lo, np.zeros((pad_u,), np.uint32)])
        w_val = np.concatenate([w_val, np.zeros((pad_u,), np.int32)])
    acc, con, shard_ids, qh, ql, new_table = _fastpath_impl(
        table, w_hi, w_lo, w_val, key_hi, key_lo, jnp.asarray(key_cls),
        k_valid, jnp.asarray(slot_map), n_slots, interpret, tile_sets,
    )
    return FastPathResult(
        acc[:B], con[:B], shard_ids[:B], qh[:B], ql[:B], new_table
    )


# ---------------------------------------------------------------------------
# Transactional probe: all-or-nothing multi-key record in ONE dispatch
# ---------------------------------------------------------------------------
class TxnProbeResult(NamedTuple):
    """Result of one all-or-nothing multi-key record (ONE dispatch)."""
    accepted: bool           # the whole op accepted (all keys placed/hit)
    hit: jnp.ndarray         # [K] same-key table hit per key (caller order)
    q_hi: jnp.ndarray        # mixed keyhash lanes of the op's keys — callers
    q_lo: jnp.ndarray        # gc with these, extend windows on accept
    table: WitnessTable      # updated iff accepted; bit-identical otherwise


@functools.partial(jax.jit, static_argnames=("interpret",))
def _txn_probe_impl(table, k_hi, k_lo, own, valid, interpret: bool):
    qh, ql = ref_keyhash2x32(k_hi, k_lo)    # fuses with the probe's jit
    acc, hit, new_table = witness_record_txn_pallas(
        table, qh, ql, own, valid, interpret=interpret
    )
    return acc, hit, qh, ql, new_table


def txn_probe(table: WitnessTable, key_hi, key_lo, own=None,
              *, interpret: bool | None = None) -> TxnProbeResult:
    """All-or-nothing record of ONE multi-key op — a single device dispatch
    on BOTH the accept and the reject path (the record-then-rollback scheme
    this replaces paid a second gc dispatch on reject).

    ``key_hi``/``key_lo`` are the RAW 64-bit keyhash lanes of the op's
    (deduplicated) keys; ``own[k] = 1`` marks keys the caller knows are
    already held under this op's rpc_id (idempotent retry), resolved from
    the host mirror.  The kernel leaves the table bit-identical when the op
    rejects, so callers can rebind ``result.table`` unconditionally.
    """
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    key_hi = np.asarray(key_hi, np.uint32)
    key_lo = np.asarray(key_lo, np.uint32)
    (K,) = key_hi.shape
    own_arr = (np.zeros((K,), np.int32) if own is None
               else np.asarray(own, np.int32))
    key_hi, key_lo, own_arr, valid = _pad_valid(K, key_hi, key_lo, own_arr)
    acc, hit, qh, ql, new_table = _txn_probe_impl(
        table, key_hi, key_lo, own_arr, valid, interpret
    )
    return TxnProbeResult(
        bool(np.asarray(acc)[0]), hit[:K], qh[:K], ql[:K], new_table
    )


# ---------------------------------------------------------------------------
# Gang ops: stacked witness lanes with kernel-held RIFL/gc state
# ---------------------------------------------------------------------------
# A gang stacks L witness instances (all shards x all witnesses) into one
# [L*S, W] device table whose slots carry rpc identity and gc age alongside
# the keyhash lanes (repro.kernels.ref.GangTable).  The ops below keep the
# whole serving hot loop at ONE dispatch per *cluster* batch: reason codes
# (1 insert / 2 dup / 3 conflict / 4 full) come back per op so the host
# updates stats/mirrors without consulting device state, and all outputs are
# materialized to numpy HERE — callers slice/index host-side for free instead
# of paying one device program per jnp ``__getitem__``.

class GangRecordResult(NamedTuple):
    """Result of one grouped gang record (all caller order)."""
    reasons: np.ndarray      # [G] reason code per group
    q_hi: np.ndarray         # [G, K] mixed lanes of every key (padding = 0)
    q_lo: np.ndarray         # [G, K]
    table: GangTable         # updated gang table (donated buffers)
    counters: jnp.ndarray | None = None  # [L, 5] reason-counter plane, if fed


def _dummy_counters() -> jnp.ndarray:
    """Placeholder counters operand for untracked dispatches (track=False
    is jit-static, so the scatter-add never traces; the buffer just keeps
    the jitted signature stable)."""
    return jnp.zeros((1, N_REASON_CODES), jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_sets", "track", "interpret"))
def _gang_groups_impl(table, k_hi, k_lo, k_cls, k_valid, lanes, r_hi, r_lo,
                      g_valid, counters, n_sets: int, track: bool,
                      interpret: bool):
    G, K = k_hi.shape
    qh, ql = ref_keyhash2x32(k_hi.reshape(-1), k_lo.reshape(-1))
    qh = qh.reshape(G, K)
    ql = ql.reshape(G, K)
    rows = (
        lanes[:, None] * n_sets
        + (ql & jnp.uint32(n_sets - 1)).astype(jnp.int32)
    )
    rsn, new_table = gang_record_groups_pallas(
        table, qh, ql, rows, k_valid, k_cls, r_hi, r_lo, g_valid,
        interpret=interpret,
    )
    if track:
        # One count per GROUP (the host settles grouped ops group-wise).
        counters = reason_counts_update(counters, lanes, rsn, g_valid)
    return rsn, qh, ql, new_table, counters


def gang_record_groups(
    table: GangTable, n_sets: int,
    key_hi, key_lo, key_valid, lanes, rpc_hi, rpc_lo, key_cls=None,
    *, counters=None, interpret: bool | None = None,
) -> GangRecordResult:
    """Batched per-group all-or-nothing record: ONE dispatch for a whole
    batch of (possibly multi-key) ops.

    ``key_hi``/``key_lo``/``key_valid`` are [G, K] RAW keyhash lanes padded
    to a common key count; ``key_cls`` is the optional [G, K] merge-lattice
    class per key (default SET); ``lanes``/``rpc_hi``/``rpc_lo`` are [G]
    (target witness lane, rpc identity).  Groups resolve sequentially in
    index order with the Python reference's exact placement semantics; dup/
    conflict decisions use the kernel-held rpc lanes (no host mirror
    input).  Rebind ``result.table``.

    ``counters`` is the optional [L, 5] device reason-counter plane; when
    passed, each group's reason code is accumulated at its lane inside the
    same dispatch and the updated plane comes back as ``result.counters``
    (rebind it alongside the table).
    """
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    key_hi = np.asarray(key_hi, np.uint32)
    key_lo = np.asarray(key_lo, np.uint32)
    key_valid = np.asarray(key_valid, np.int32)
    G, K = key_hi.shape
    key_cls = (np.zeros((G, K), np.int32) if key_cls is None
               else np.asarray(key_cls, np.int32))
    Gp, Kp = _bucket(G, lo=4), _bucket(K, lo=2)
    pad2 = ((0, Gp - G), (0, Kp - K))
    key_hi = np.pad(key_hi, pad2)
    key_lo = np.pad(key_lo, pad2)
    key_valid = np.pad(key_valid, pad2)
    key_cls = np.pad(key_cls, pad2)
    lanes = np.pad(np.asarray(lanes, np.int32), (0, Gp - G))
    rpc_hi = np.pad(np.asarray(rpc_hi, np.uint32), (0, Gp - G))
    rpc_lo = np.pad(np.asarray(rpc_lo, np.uint32), (0, Gp - G))
    g_valid = np.zeros((Gp,), np.int32)
    g_valid[:G] = 1
    track = counters is not None
    rsn, qh, ql, new_table, new_counters = _gang_groups_impl(
        table, key_hi, key_lo, jnp.asarray(key_cls), key_valid, lanes,
        rpc_hi, rpc_lo, jnp.asarray(g_valid),
        counters if track else _dummy_counters(), n_sets, track, interpret,
    )
    return GangRecordResult(
        np.asarray(rsn)[:G], np.asarray(qh)[:G, :K], np.asarray(ql)[:G, :K],
        new_table, new_counters if track else None,
    )


@functools.partial(jax.jit, static_argnames=("n_sets", "track", "interpret",
                                             "tile_sets"))
def _gang_record_impl(table, k_hi, k_lo, k_cls, k_valid, lanes, r_hi, r_lo,
                      counters, n_sets: int, track: bool, interpret: bool,
                      tile_sets: int):
    R, _W = table.occ.shape
    qh, ql = ref_keyhash2x32(k_hi, k_lo)
    rows = (
        lanes * n_sets + (ql & jnp.uint32(n_sets - 1)).astype(jnp.int32)
    )
    qhi_f, qlo_f, sets_f, rstart, n_rounds, perm = _setpar_prep(
        R, qh, ql, k_valid, sets=rows
    )
    rsn_f, new_table = gang_record_setpar_pallas(
        table, qhi_f, qlo_f, r_hi[perm], r_lo[perm], k_cls[perm], sets_f,
        rstart, n_rounds, tile_sets=tile_sets, interpret=interpret,
    )
    rsn = _unsort(perm, rsn_f)
    if track:
        # One count per ROW, mirroring the host's per-op settle accounting.
        counters = reason_counts_update(counters, lanes, rsn, k_valid)
    return rsn, qh, ql, new_table, counters


def gang_record(
    table: GangTable, n_sets: int, key_hi, key_lo, lanes, rpc_hi, rpc_lo,
    key_cls=None,
    *, counters=None, interpret: bool | None = None,
    tile_sets: int = DEFAULT_TILE_SETS,
):
    """Set-parallel single-key record over the gang: ONE dispatch for a
    batch of [B] single-key ops (each with its own lane + rpc identity).
    ``key_cls`` is the optional [B] merge-lattice class lane (default SET).

    Returns (reasons [B], q_hi [B], q_lo [B], table) — numpy outputs,
    caller order, same reason codes as ``gang_record_groups``.  With the
    optional ``counters`` plane ([L, 5] int32) the return grows a fifth
    element: the updated plane with each op's reason accumulated at its
    lane inside the same dispatch.
    """
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    key_hi = np.asarray(key_hi, np.uint32)
    key_lo = np.asarray(key_lo, np.uint32)
    (B,) = key_hi.shape
    key_cls = (np.zeros((B,), np.int32) if key_cls is None
               else np.asarray(key_cls, np.int32))
    key_hi, key_lo, key_cls, lanes, rpc_hi, rpc_lo, valid = _pad_valid(
        B, key_hi, key_lo, key_cls,
        np.asarray(lanes, np.int32),
        np.asarray(rpc_hi, np.uint32), np.asarray(rpc_lo, np.uint32),
    )
    track = counters is not None
    rsn, qh, ql, new_table, new_counters = _gang_record_impl(
        table, key_hi, key_lo, jnp.asarray(key_cls), valid, lanes,
        rpc_hi, rpc_lo, counters if track else _dummy_counters(),
        n_sets, track, interpret, tile_sets,
    )
    out = (np.asarray(rsn)[:B], np.asarray(qh)[:B], np.asarray(ql)[:B],
           new_table)
    return out + (new_counters,) if track else out


@functools.partial(jax.jit, static_argnames=("n_sets", "do_age", "interpret",
                                             "tile_sets"))
def _gang_gc_impl(table, g_hi, g_lo, g_rh, g_rl, g_lane, g_valid, aged_lanes,
                  n_sets: int, do_age: bool, interpret: bool, tile_sets: int):
    rows = (
        g_lane * n_sets + (g_lo & jnp.uint32(n_sets - 1)).astype(jnp.int32)
    )
    aged_rows = jnp.repeat(aged_lanes.astype(jnp.int32), n_sets)
    clr, new_table = gang_gc_pallas(
        table, g_hi, g_lo, g_rh, g_rl, rows, g_valid, aged_rows,
        do_age=do_age, tile_sets=tile_sets, interpret=interpret,
    )
    return clr, new_table


def gang_gc(
    table: GangTable, n_sets: int,
    g_hi, g_lo, g_rpc_hi, g_rpc_lo, g_lane, aged_lanes,
    *, do_age: bool = True,
    interpret: bool | None = None, tile_sets: int = DEFAULT_TILE_SETS,
):
    """Gang gc, ONE dispatch: rpc-matched clears + in-kernel aging.

    Entry lanes are MIXED key lanes (as returned by the record ops) plus
    the recording rpc identity and target lane; a slot clears only on a
    full (key, rpc, lane) match, so a stale gc entry never drops a newer
    same-key record.  ``aged_lanes`` is an [L] 0/1 mask of lanes whose
    survivors age this round (§4.5); ``do_age=False`` is the rollback
    variant.  Returns (cleared [G] numpy bit per entry, new table).
    """
    if interpret is None:
        interpret = not _on_tpu()
    _count_dispatch()
    g_hi = np.asarray(g_hi, np.uint32)
    (G,) = g_hi.shape
    g_hi, g_lo, g_rh, g_rl, g_lane, valid = _pad_valid(
        G, g_hi, np.asarray(g_lo, np.uint32),
        np.asarray(g_rpc_hi, np.uint32), np.asarray(g_rpc_lo, np.uint32),
        np.asarray(g_lane, np.int32),
    )
    clr, new_table = _gang_gc_impl(
        table, g_hi, g_lo, g_rh, g_rl, g_lane, valid,
        jnp.asarray(np.asarray(aged_lanes, np.int32)),
        n_sets, do_age, interpret, tile_sets,
    )
    return np.asarray(clr)[:G], new_table


# ---------------------------------------------------------------------------
# Fused gang fast path: ONE dispatch for a routed multi-shard batch
# ---------------------------------------------------------------------------
class GangFastPathResult(NamedTuple):
    """Result of one fused cluster-batch dispatch (all caller order)."""
    reasons: np.ndarray      # [B, f] reason code per op per witness copy
    conflicts: np.ndarray    # [B] device master-window conflict bit
    shard_ids: np.ndarray    # [B] slot-table placement
    q_hi: np.ndarray         # [B] mixed keyhash lanes
    q_lo: np.ndarray         # [B]
    table: GangTable         # updated gang table (donated buffers)
    ring_hi: jnp.ndarray     # [NS, CAP] updated unsynced-window rings
    ring_lo: jnp.ndarray     # [NS, CAP]
    counts: np.ndarray       # [NS] post-append live-entry count per ring
    ring_cls: jnp.ndarray    # [NS, CAP] merge-lattice class per ring entry
    counters: jnp.ndarray | None = None  # [L, 5] reason-counter plane, if fed


@functools.partial(jax.jit, static_argnames=("n_slots", "n_sets", "f",
                                             "track", "interpret",
                                             "tile_sets"))
def _gang_fastpath_impl(table, k_hi, k_lo, k_cls, k_valid, r_hi, r_lo,
                        exec_pred, slot_map, lane_map, ring_hi, ring_lo,
                        ring_cls, tail_slot, count, counters,
                        n_slots: int, n_sets: int, f: int, track: bool,
                        interpret: bool, tile_sets: int):
    (B,) = k_hi.shape
    R, _W = table.occ.shape
    NS, CAP = ring_hi.shape
    qh, ql = ref_keyhash2x32(k_hi, k_lo)
    slots = (ql % jnp.uint32(n_slots)).astype(jnp.int32)
    shard = slot_map[slots]                                        # [B]
    valid = k_valid.astype(jnp.int32)
    qcls = k_cls.astype(jnp.int32)
    mrow = matrix_rows(qcls)                                       # [B]
    # --- device-resident master window: ring conflict scan -----------------
    rhi_b = ring_hi[shard]                                         # [B, CAP]
    rlo_b = ring_lo[shard]
    rcls_b = ring_cls[shard]                                       # [B, CAP]
    c_iota = jax.lax.iota(jnp.int32, CAP)[None, :]
    live = ((c_iota - tail_slot[shard][:, None]) % CAP) < count[shard][:, None]
    ring_hit = jnp.any(
        live & (rhi_b == qh[:, None]) & (rlo_b == ql[:, None])
        & (((mrow[:, None] >> rcls_b) & 1) == 1), axis=1
    )
    # Intra-batch window growth: op i also conflicts with any EARLIER op j
    # of the same shard and key that will itself enter the window — unless
    # the merge lattice says their classes commute (e.g. INCR over INCR).
    app = (exec_pred == 1) & (valid == 1)                          # [B]
    b_iota = jax.lax.iota(jnp.int32, B)
    earlier = b_iota[:, None] > b_iota[None, :]
    same = (
        (qh[:, None] == qh[None, :])
        & (ql[:, None] == ql[None, :])
        & (shard[:, None] == shard[None, :])
        & (((mrow[:, None] >> qcls[None, :]) & 1) == 1)
        & earlier & app[None, :]
    )
    intra_hit = jnp.any(same, axis=1)
    conflicts = ((ring_hit | intra_hit) & (valid == 1)).astype(jnp.int32)
    # --- ring append (executed ops only, in batch order per shard) ---------
    shard_eq = shard[:, None] == shard[None, :]
    rank = jnp.sum(shard_eq & earlier & app[None, :], axis=1)
    slot_pos = (tail_slot[shard] + count[shard] + rank) % CAP
    srow = jnp.where(app, shard, NS)
    ring_hi = ring_hi.at[srow, slot_pos].set(qh, mode="drop")
    ring_lo = ring_lo.at[srow, slot_pos].set(ql, mode="drop")
    ring_cls = ring_cls.at[srow, slot_pos].set(qcls, mode="drop")
    new_count = count + jnp.zeros((NS,), jnp.int32).at[shard].add(
        app.astype(jnp.int32)
    )
    # --- witness record, expanded to every shard's f witness lanes ---------
    lanes_e = lane_map[shard].reshape(-1)                          # [B*f]
    rep = lambda x: jnp.repeat(x, f)
    qh_e, ql_e = rep(qh), rep(ql)
    rows_e = lanes_e * n_sets + (ql_e & jnp.uint32(n_sets - 1)).astype(
        jnp.int32
    )
    qhi_f, qlo_f, sets_f, rstart, n_rounds, perm = _setpar_prep(
        R, qh_e, ql_e, rep(valid), sets=rows_e
    )
    rsn_f, new_table = gang_record_setpar_pallas(
        table, qhi_f, qlo_f, rep(r_hi)[perm], rep(r_lo)[perm],
        rep(qcls)[perm], sets_f, rstart, n_rounds,
        tile_sets=tile_sets, interpret=interpret,
    )
    rsn_flat = _unsort(perm, rsn_f)                                # [B*f]
    if track:
        # One count per (op, witness copy) at the copy's lane — the same
        # granularity the host settles at (FusedBatchDriver settles every
        # witness of every op individually).
        counters = reason_counts_update(
            counters, lanes_e, rsn_flat, rep(valid))
    reasons = rsn_flat.reshape(B, f)
    return (reasons, conflicts, shard, qh, ql, new_table,
            ring_hi, ring_lo, new_count, ring_cls, counters)


def gang_fastpath_batch(
    table: GangTable, n_sets: int,
    key_hi, key_lo, rpc_hi, rpc_lo, exec_pred,
    slot_map, lane_map,
    ring_hi, ring_lo, tail_slot, count,
    *, key_cls=None, ring_cls=None, counters=None,
    interpret: bool | None = None,
    tile_sets: int = DEFAULT_TILE_SETS,
) -> GangFastPathResult:
    """The whole cluster-batch hot loop in ONE device dispatch:

        hash -> slot route -> ring conflict scan (device-resident master
        window, incl. intra-batch growth) -> ring append -> record at every
        target shard's f witness lanes (stacked gang, rpc/age held
        in-kernel)

    ``lane_map`` is [NS, f] (gang lane of witness j of shard s);
    ``ring_hi/ring_lo`` are the [NS, CAP] per-shard unsynced-keyhash rings
    with ``tail_slot``/``count`` the live span (count + appends must fit
    CAP — callers drain first).  ``exec_pred[b]=1`` marks ops that will
    execute at their master (RIFL duplicates don't re-enter the window).
    ``key_cls`` ([B]) and ``ring_cls`` ([NS, CAP]) carry the merge-lattice
    op classes for queries and ring entries (default SET = conflict with
    everything, the legacy behaviour).  Reasons/conflicts come back per op
    as numpy; ring buffers and table stay on device.  Rebind table and
    ring state (including ``ring_cls``) from the result.

    ``counters`` is the optional [L, 5] reason-counter plane: when passed,
    every (op, witness copy) outcome is accumulated at the copy's lane
    inside the same dispatch; rebind ``result.counters``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    slot_map = np.asarray(slot_map, np.int32)
    n_slots = int(slot_map.shape[0])
    lane_map = np.asarray(lane_map, np.int32)
    NS, f = lane_map.shape
    _count_dispatch()
    key_hi = np.asarray(key_hi, np.uint32)
    (B,) = key_hi.shape
    key_cls = (np.zeros((B,), np.int32) if key_cls is None
               else np.asarray(key_cls, np.int32))
    if ring_cls is None:
        ring_cls = jnp.zeros(ring_hi.shape, jnp.int32)
    key_hi, key_lo, key_cls, rpc_hi, rpc_lo, exec_pred, valid = _pad_valid(
        B, key_hi, np.asarray(key_lo, np.uint32), key_cls,
        np.asarray(rpc_hi, np.uint32), np.asarray(rpc_lo, np.uint32),
        np.asarray(exec_pred, np.int32),
    )
    track = counters is not None
    out = _gang_fastpath_impl(
        table, key_hi, key_lo, jnp.asarray(key_cls), valid, rpc_hi, rpc_lo,
        exec_pred, jnp.asarray(slot_map), jnp.asarray(lane_map),
        ring_hi, ring_lo, ring_cls,
        jnp.asarray(np.asarray(tail_slot, np.int32)),
        jnp.asarray(np.asarray(count, np.int32)),
        counters if track else _dummy_counters(),
        n_slots, n_sets, f, track, interpret, tile_sets,
    )
    (reasons, conflicts, shard, qh, ql, new_table, rh, rl, new_count,
     rcls, new_counters) = out
    return GangFastPathResult(
        np.asarray(reasons)[:B], np.asarray(conflicts)[:B],
        np.asarray(shard)[:B], np.asarray(qh)[:B], np.asarray(ql)[:B],
        new_table, rh, rl, np.asarray(new_count), rcls,
        new_counters if track else None,
    )


__all__ = [
    "WitnessTable", "FastPathResult", "TxnProbeResult", "keyhash2x32",
    "DEFAULT_N_SLOTS", "default_slot_map",
    "shard_route", "witness_record", "witness_record_seq", "witness_gc",
    "conflict_scan", "fastpath_batch", "txn_probe", "dispatch_count",
    "reset_dispatch_count", "ref_keyhash2x32", "ref_witness_record",
    "ref_witness_gc", "ref_conflict_scan", "ref_witness_record_txn",
    "GangTable", "GangRecordResult", "GangFastPathResult", "N_REASON_CODES",
    "gang_record", "gang_record_groups", "gang_gc", "gang_fastpath_batch",
    "np_keyhash2x32", "ref_gang_record", "ref_gang_gc",
    "matrix_rows", "conflict_matrix_np",
]
