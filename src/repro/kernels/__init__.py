"""Pallas TPU kernels for the CURP protocol hot spots (DESIGN.md §4).

witness_record — SET-PARALLEL batched witness record (paper §4.2): the batch
                 is bucketed by probed set and whole "rounds" (one query per
                 set) resolve vectorized, so wall-clock scales with the
                 longest per-set run, not the batch size
conflict_scan  — master commutativity check vs the unsynced window (§4.3)
keyhash        — 2x32-lane key hashing (TPU adaptation of the 64-bit hash)
fastpath_batch — the fused pipeline: keyhash2x32 -> shard_route ->
                 witness_record -> conflict_scan as ONE device dispatch per
                 update batch (vs 3-4 dispatches per op on the per-op path)
txn_probe      — all-or-nothing transactional probe: ONE op's multi-key
                 record resolved in ONE dispatch on accept AND reject (the
                 record-then-rollback scheme paid a second gc dispatch)

Fast-path pipeline docs (set-parallel layout, VMEM budget, and the buffer
donation/aliasing contract) live in witness_record.py's module docstring and
in README.md next to this file.  Validated in interpret mode against the
pure-jnp oracles in ref.py; the model-zoo code deliberately contains no
Pallas so the dry-run roofline reflects real XLA numbers (DESIGN.md §4).
"""
from .ops import (
    DEFAULT_N_SLOTS,
    FastPathResult,
    conflict_matrix_np,
    matrix_rows,
    GangFastPathResult,
    GangRecordResult,
    N_REASON_CODES,
    GangTable,
    TxnProbeResult,
    WitnessTable,
    conflict_scan,
    default_slot_map,
    dispatch_count,
    fastpath_batch,
    gang_fastpath_batch,
    gang_gc,
    gang_record,
    gang_record_groups,
    keyhash2x32,
    np_keyhash2x32,
    ref_conflict_scan,
    ref_gang_gc,
    ref_gang_record,
    ref_keyhash2x32,
    ref_witness_gc,
    ref_witness_record,
    ref_witness_record_txn,
    reset_dispatch_count,
    shard_route,
    txn_probe,
    witness_gc,
    witness_record,
    witness_record_seq,
)

__all__ = [
    "DEFAULT_N_SLOTS", "default_slot_map",
    "FastPathResult", "TxnProbeResult", "WitnessTable", "conflict_scan",
    "keyhash2x32", "shard_route", "witness_gc", "witness_record",
    "witness_record_seq", "fastpath_batch", "txn_probe", "dispatch_count",
    "reset_dispatch_count", "ref_conflict_scan", "ref_keyhash2x32",
    "ref_witness_gc", "ref_witness_record", "ref_witness_record_txn",
    "GangTable", "GangRecordResult", "GangFastPathResult",
    "N_REASON_CODES",
    "gang_record", "gang_record_groups", "gang_gc", "gang_fastpath_batch",
    "np_keyhash2x32", "ref_gang_record", "ref_gang_gc",
    "matrix_rows", "conflict_matrix_np",
]
