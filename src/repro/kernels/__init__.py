"""Pallas TPU kernels for the CURP protocol hot spots (DESIGN.md §4).

witness_record — batched set-associative witness record (paper §4.2)
conflict_scan  — master commutativity check vs the unsynced window (§4.3)
keyhash        — 2x32-lane key hashing (TPU adaptation of the 64-bit hash)

Validated in interpret mode against the pure-jnp oracles in ref.py; the
model-zoo code deliberately contains no Pallas so the dry-run roofline
reflects real XLA numbers (DESIGN.md §4).
"""
from .ops import (
    WitnessTable,
    conflict_scan,
    keyhash2x32,
    ref_conflict_scan,
    ref_keyhash2x32,
    ref_witness_gc,
    ref_witness_record,
    shard_route,
    witness_gc,
    witness_record,
)

__all__ = [
    "WitnessTable", "conflict_scan", "keyhash2x32", "shard_route",
    "witness_gc", "witness_record", "ref_conflict_scan", "ref_keyhash2x32",
    "ref_witness_gc", "ref_witness_record",
]
