"""Pure-jnp oracles for the CURP Pallas kernels.

The protocol's hot spots (DESIGN.md §4) are integer data-structure ops, not
GEMMs, so the TPU adaptation swaps 64-bit scalar code for 32-bit vector-lane
math (TPU VPU lanes are 32-bit):

  * keyhash2x32 — a 64-bit-equivalent key hash carried as (hi, lo) uint32
    lanes, built from two murmur3 fmix32 finalizers with cross-lane mixing.
  * witness_record — batched set-associative record (§4.2): order-dependent
    within a batch (earlier accepts occupy slots).
  * conflict_scan — master-side commutativity check (§4.3): B incoming
    keyhashes vs the U-entry unsynced window -> conflict bitmap.

Semantics notes vs the Python Witness (repro.core.witness): the kernel path
handles single-key records and treats any same-key hit as a conflict
(duplicate retries are resolved by the Python layer); this matches how the
device-side witness is used by CURP-Serve (one record per session key).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
# numpy scalars: they inline as literals (Pallas kernels may not close over
# traced jnp constants).
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLD = np.uint32(0x9E3779B9)
_MIX5 = np.uint32(5)
_MIXC = np.uint32(0xE6546B64)


# ---------------------------------------------------------------------------
# Merge-lattice conflict matrix (repro.core.merge), kernel-consumable form.
#
# Occupancy packs the held op class: occ == 0 is empty, occ == 1 + class is
# occupied (class SET == 0, so legacy all-SET tables are bit-identical with
# the old 0/1 encoding).  A query carries its class as a separate q_cls lane;
# conflict against an occupied way is then ONE bit test:
#     ((CONFLICT_MATRIX[q_cls] >> (occ - 1)) & 1) == 1
# ---------------------------------------------------------------------------
def conflict_matrix_np() -> np.ndarray:
    """The merge-lattice matrix as int32 bitmask rows.  Imported lazily:
    repro.core imports repro.kernels at package init, so a module-level
    back-edge from here into repro.core would cycle."""
    from repro.core.merge import CONFLICT_MATRIX

    return np.asarray(CONFLICT_MATRIX, np.int32)


def matrix_rows(q_cls: jnp.ndarray) -> jnp.ndarray:
    """``mrow[i] = CONFLICT_MATRIX[q_cls[i]]`` without a gather.

    The matrix is a static Python constant, so the lookup unrolls to a
    16-way where-sum over scalar literals — legal inside a Pallas kernel
    body (no dynamic indexing of traced constants) and trivially fused by
    XLA on the jnp oracle path.  Shared by oracles AND kernels so both
    consult the exact same matrix.
    """
    rows = conflict_matrix_np()
    q_cls = q_cls.astype(jnp.int32)
    mrow = jnp.zeros(q_cls.shape, jnp.int32)
    for c in range(rows.shape[0]):
        mrow = mrow + jnp.where(q_cls == c, np.int32(rows[c]), np.int32(0))
    return mrow


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer (full avalanche)."""
    x = x.astype(U32)
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def ref_keyhash2x32(hi: jnp.ndarray, lo: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """64-bit-equivalent hash as two cross-mixed 32-bit lanes."""
    hi = hi.astype(U32)
    lo = lo.astype(U32)
    h1 = fmix32(lo + _GOLD)
    h2 = fmix32(hi ^ h1)
    h3 = fmix32(h1 + h2 * _MIX5 + _MIXC)
    return h2, h3


class WitnessTable(NamedTuple):
    """Device-side witness state: S sets x W ways of (hi, lo) keyhash slots.

    ``occ`` packs the held op class: 0 = empty, 1 + class = occupied
    (repro.core.merge; class SET == 0, so an all-SET table reads 0/1 exactly
    as before the merge-lattice widening).
    """
    keys_hi: jnp.ndarray   # [S, W] uint32
    keys_lo: jnp.ndarray   # [S, W] uint32
    occ: jnp.ndarray       # [S, W] int32 (0 = empty, else 1 + op class)

    @staticmethod
    def empty(n_sets: int, n_ways: int) -> "WitnessTable":
        assert n_sets & (n_sets - 1) == 0, "n_sets must be a power of two"
        return WitnessTable(
            keys_hi=jnp.zeros((n_sets, n_ways), U32),
            keys_lo=jnp.zeros((n_sets, n_ways), U32),
            occ=jnp.zeros((n_sets, n_ways), jnp.int32),
        )


def ref_witness_record(
    table: WitnessTable, q_hi: jnp.ndarray, q_lo: jnp.ndarray,
    q_cls: jnp.ndarray = None,
) -> Tuple[jnp.ndarray, WitnessTable]:
    """Sequential batched record.  Returns (accepted [B] int32, new table).

    ``q_cls`` is the per-query merge-lattice op class (default SET): a
    same-key hit conflicts only when the matrix says the classes conflict,
    so e.g. INCR records stack in different ways of one set.
    """
    S, W = table.occ.shape
    set_mask = jnp.uint32(S - 1)
    if q_cls is None:
        q_cls = jnp.zeros(q_hi.shape, jnp.int32)

    def body(carry, q):
        khi, klo, occ = carry
        qhi, qlo, qc, mrow = q
        s = (qlo & set_mask).astype(jnp.int32)
        row_hi, row_lo, row_occ = khi[s], klo[s], occ[s]
        wcls = jnp.maximum(row_occ - 1, 0)
        conflict = jnp.any(
            (row_occ > 0) & (row_hi == qhi) & (row_lo == qlo)
            & (((mrow >> wcls) & 1) == 1)
        )
        free = row_occ == 0
        has_free = jnp.any(free)
        way = jnp.argmax(free)
        acc = jnp.logical_and(~conflict, has_free)
        sel = (jnp.arange(W) == way) & acc
        khi = khi.at[s].set(jnp.where(sel, qhi, row_hi))
        klo = klo.at[s].set(jnp.where(sel, qlo, row_lo))
        occ = occ.at[s].set(jnp.where(sel, 1 + qc, row_occ))
        return (khi, klo, occ), acc.astype(jnp.int32)

    (khi, klo, occ), accepted = jax.lax.scan(
        body, (table.keys_hi, table.keys_lo, table.occ),
        (q_hi.astype(U32), q_lo.astype(U32),
         q_cls.astype(jnp.int32), matrix_rows(q_cls)),
    )
    return accepted, WitnessTable(khi, klo, occ)


def ref_witness_gc(
    table: WitnessTable, g_hi: jnp.ndarray, g_lo: jnp.ndarray
) -> WitnessTable:
    """Clear every slot whose key matches a gc entry (vectorized: no order
    dependence — clears are idempotent and commutative)."""
    S, W = table.occ.shape
    # [S, W, G] match cube; G is small (one gc batch).
    m = (
        (table.keys_hi[:, :, None] == g_hi[None, None, :].astype(U32))
        & (table.keys_lo[:, :, None] == g_lo[None, None, :].astype(U32))
        & (table.occ[:, :, None] > 0)
    )
    cleared = jnp.any(m, axis=-1)
    return WitnessTable(
        keys_hi=table.keys_hi,
        keys_lo=table.keys_lo,
        occ=jnp.where(cleared, 0, table.occ),
    )


def ref_witness_record_txn(
    table: WitnessTable, q_hi: jnp.ndarray, q_lo: jnp.ndarray,
    own: jnp.ndarray, valid: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, WitnessTable]:
    """All-or-nothing transactional probe oracle: the K keys of ONE op.

    Placement follows the (fixed) Python ``Witness.record`` semantics: the
    conflict decision is made against the PRE-op table, but free ways are
    RESERVED in key order — the k-th same-set inserter takes the set's
    (rank+1)-th free way, and the op rejects as full when a set cannot seat
    all of its inserters.  (The old oracle gave every key the set's FIRST
    free way, so two same-set keys of one op aliased and the second write
    clobbered the first out of the table.)

    ``own[k] = 1`` marks a key already held under this op's rpc_id (client
    retry, resolved host-side from the mirror): its table hit counts as
    placed, not as a conflict.  ``valid[k] = 0`` marks padding lanes.

    Returns (accepted [1] int32, hit [K] int32, new table); the table is
    untouched unless the whole op accepted.
    """
    S, W = table.occ.shape
    K = q_hi.shape[0]
    set_mask = jnp.uint32(S - 1)
    q_hi = q_hi.astype(U32)
    q_lo = q_lo.astype(U32)
    own = own.astype(jnp.int32)
    valid = valid.astype(jnp.int32)
    sets = (q_lo & set_mask).astype(jnp.int32)                 # [K]
    row_hi = table.keys_hi[sets]                               # [K, W]
    row_lo = table.keys_lo[sets]
    row_occ = table.occ[sets]
    hit = jnp.any(
        (row_occ > 0) & (row_hi == q_hi[:, None]) & (row_lo == q_lo[:, None]),
        axis=1,
    )
    free = row_occ == 0
    # Way reservation: rank this key among the op's earlier same-set
    # inserters; it seats iff the set still has a free way left after them,
    # and takes the (rank+1)-th free way so the writes never alias.
    claim = (valid == 1) & ~hit
    earlier = jnp.arange(K)[None, :] < jnp.arange(K)[:, None]  # [K, K] j < k
    rank = jnp.sum(
        (sets[:, None] == sets[None, :]) & earlier & claim[None, :], axis=1
    )
    n_free = jnp.sum(free.astype(jnp.int32), axis=1)
    seat = n_free > rank
    cfree = jnp.cumsum(free.astype(jnp.int32), axis=1)
    selw = free & (cfree == (rank + 1)[:, None])
    way = jnp.argmax(selw, axis=1)                             # reserved way
    ok = jnp.where(own == 1, hit | seat, ~hit & seat)
    accepted = jnp.all(ok | (valid == 0))
    # Keys already present (hit) keep their slot; everything else inserts at
    # its reserved free way — own keys included, should the table have lost
    # them (keeps table and host mirror convergent).
    write = accepted & (valid == 1) & ~hit

    def body(k, carry):
        khi, klo, occ = carry
        sel = (jnp.arange(W) == way[k]) & write[k]
        s = sets[k]
        khi = khi.at[s].set(jnp.where(sel, q_hi[k], khi[s]))
        klo = klo.at[s].set(jnp.where(sel, q_lo[k], klo[s]))
        occ = occ.at[s].set(jnp.where(sel, 1, occ[s]))
        return khi, klo, occ

    khi, klo, occ = jax.lax.fori_loop(
        0, q_hi.shape[0], body, (table.keys_hi, table.keys_lo, table.occ)
    )
    return (
        accepted.astype(jnp.int32).reshape((1,)),
        (hit & (valid == 1)).astype(jnp.int32),
        WitnessTable(khi, klo, occ),
    )


def ref_conflict_scan(
    w_hi: jnp.ndarray, w_lo: jnp.ndarray, w_valid: jnp.ndarray,
    q_hi: jnp.ndarray, q_lo: jnp.ndarray, q_cls: jnp.ndarray = None,
) -> jnp.ndarray:
    """conflicts[b] = any_u(valid[u] & w[u] == q[b] & classes conflict).

    ``w_valid`` packs the window entry's op class (0 = invalid, else
    1 + class; legacy callers passing 0/1 get class SET, which conflicts
    with everything — the original key-equality scan).  ``q_cls`` is the
    per-query class (default SET).  [B] int32.
    """
    if q_cls is None:
        q_cls = jnp.zeros(q_hi.shape, jnp.int32)
    w_valid = w_valid.astype(jnp.int32)
    wcls = jnp.maximum(w_valid - 1, 0)
    mrow = matrix_rows(q_cls)
    eq = (
        (w_hi[None, :] == q_hi[:, None].astype(U32))
        & (w_lo[None, :] == q_lo[:, None].astype(U32))
        & (w_valid[None, :] > 0)
        & (((mrow[:, None] >> wcls[None, :]) & 1) == 1)
    )
    return jnp.any(eq, axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Host-side keyhash mix (numpy): bit-exact with ref_keyhash2x32, zero device
# dispatches.  The protocol layer uses this to mix gc entries / mirror keys.
# ---------------------------------------------------------------------------
def np_fmix32(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * _C1
    x = x ^ (x >> np.uint32(13))
    x = x * _C2
    x = x ^ (x >> np.uint32(16))
    return x


def np_keyhash2x32(hi: np.ndarray, lo: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized numpy mirror of ``ref_keyhash2x32`` (same fmix32 chain)."""
    old = np.seterr(over="ignore")
    try:
        hi = np.asarray(hi, np.uint32)
        lo = np.asarray(lo, np.uint32)
        h1 = np_fmix32(lo + _GOLD)
        h2 = np_fmix32(hi ^ h1)
        h3 = np_fmix32(h1 + h2 * _MIX5 + _MIXC)
    finally:
        np.seterr(**old)
    return h2, h3


# ---------------------------------------------------------------------------
# Gang table: many witness instances stacked into one device-resident array
# ---------------------------------------------------------------------------
# Per-slot reason codes emitted by the gang record kernels.  ACCEPT_* both
# mean RecordStatus.ACCEPTED at the protocol layer; the split keeps host
# stats exact without consulting the host mirror.
REASON_NONE = 0        # padding lane / not processed
REASON_INSERT = 1      # accepted: inserted into a free way
REASON_DUP = 2         # accepted: idempotent duplicate (same key, same rpc)
REASON_CONFLICT = 3    # rejected: same key held under a foreign rpc
REASON_FULL = 4        # rejected: probed set is out of ways


class GangTable(NamedTuple):
    """L stacked witness tables, flattened to [L*S, W] so the set-parallel
    record kernel runs unchanged over the union of all lanes' sets (global
    set row = lane * S + (q_lo & (S-1))).

    Beyond the key lanes of :class:`WitnessTable`, every slot carries the
    recording op's RIFL identity (rpc_hi = client id, rpc_lo = seq) and a
    §4.5 gc-age counter, so duplicate-retry acceptance, stale-gc
    suppression, and garbage suspicion resolve in-kernel.
    """
    keys_hi: jnp.ndarray   # [L*S, W] uint32
    keys_lo: jnp.ndarray   # [L*S, W] uint32
    occ: jnp.ndarray       # [L*S, W] int32 (0 = empty, else 1 + op class)
    rpc_hi: jnp.ndarray    # [L*S, W] uint32 (client id)
    rpc_lo: jnp.ndarray    # [L*S, W] uint32 (sequence number)
    age: jnp.ndarray       # [L*S, W] int32 (gc rounds survived)

    @staticmethod
    def empty(n_sets: int, n_ways: int, n_lanes: int = 1) -> "GangTable":
        assert n_sets & (n_sets - 1) == 0, "n_sets must be a power of two"
        R = n_lanes * n_sets
        return GangTable(
            keys_hi=jnp.zeros((R, n_ways), U32),
            keys_lo=jnp.zeros((R, n_ways), U32),
            occ=jnp.zeros((R, n_ways), jnp.int32),
            rpc_hi=jnp.zeros((R, n_ways), U32),
            rpc_lo=jnp.zeros((R, n_ways), U32),
            age=jnp.zeros((R, n_ways), jnp.int32),
        )


def _gang_np(table: GangTable):
    return tuple(np.array(np.asarray(a)) for a in table)


def ref_gang_record(table: GangTable, n_sets: int, groups):
    """Pure-Python oracle for the gang record kernels.

    ``groups`` is a sequence of ``(lane, (rpc_hi, rpc_lo), keys)`` where
    ``keys`` is a list of ``(q_hi, q_lo)`` or ``(q_hi, q_lo, cls)`` lane
    triples (``cls`` defaults to SET) — ONE group is one op (single-key ops
    are groups of size 1).  Semantics transcribe
    ``repro.core.witness.Witness.record`` exactly: a same-key hit under a
    foreign rpc conflicts only when the merge lattice says the classes
    conflict, and free ways are RESERVED as the placement loop claims them,
    so two same-set keys of one op take distinct ways.

    Returns (reasons per group, new GangTable) with numpy state.
    """
    khi, klo, occ, rhi, rlo, age = _gang_np(table)
    matrix = conflict_matrix_np()
    W = occ.shape[1]
    reasons = []
    for lane, (rc, rs), keys in groups:
        rc, rs = np.uint32(rc), np.uint32(rs)
        placements = []
        claimed = set()
        reason = None
        for entry in keys:
            qh, ql, cls = entry if len(entry) == 3 else (*entry, 0)
            qh, ql = np.uint32(qh), np.uint32(ql)
            row = lane * n_sets + (int(ql) & (n_sets - 1))
            free_way = None
            conflicted = False
            for w in range(W):
                if occ[row, w] > 0:
                    same = khi[row, w] == qh and klo[row, w] == ql
                    if same and rhi[row, w] == rc and rlo[row, w] == rs:
                        free_way = w           # idempotent duplicate hit
                        break
                    if same and (int(matrix[cls]) >> (int(occ[row, w]) - 1)) & 1:
                        conflicted = True
                        break
                elif free_way is None and (row, w) not in claimed:
                    free_way = w
            if conflicted:
                reason = REASON_CONFLICT
                break
            if free_way is None:
                reason = REASON_FULL
                break
            claimed.add((row, free_way))
            placements.append((row, free_way, qh, ql, cls,
                               occ[row, free_way] > 0))
        if reason is None:
            all_dup = all(p[5] for p in placements) and len(placements) > 0
            reason = REASON_DUP if all_dup else REASON_INSERT
            for row, w, qh, ql, cls, _dup in placements:
                khi[row, w] = qh
                klo[row, w] = ql
                occ[row, w] = 1 + cls
                rhi[row, w] = rc
                rlo[row, w] = rs
                age[row, w] = 0
        reasons.append(reason)
    return reasons, GangTable(*(jnp.asarray(a) for a in
                                (khi, klo, occ, rhi, rlo, age)))


def ref_gang_gc(table: GangTable, n_sets: int, entries, aged_lanes):
    """Oracle for the gang gc kernel.

    ``entries`` is a sequence of ``(lane, (q_hi, q_lo), (rpc_hi, rpc_lo))``;
    a slot is cleared only when key AND rpc match (stale-gc suppression
    in-kernel).  Every occupied survivor in ``aged_lanes`` then ages by one
    round (§4.5).  Returns (cleared bit per entry, new GangTable).
    """
    khi, klo, occ, rhi, rlo, age = _gang_np(table)
    W = occ.shape[1]
    cleared = []
    for lane, (qh, ql), (rc, rs) in entries:
        qh, ql = np.uint32(qh), np.uint32(ql)
        row = lane * n_sets + (int(ql) & (n_sets - 1))
        hit = False
        for w in range(W):
            if (occ[row, w] > 0 and khi[row, w] == qh and klo[row, w] == ql
                    and rhi[row, w] == np.uint32(rc)
                    and rlo[row, w] == np.uint32(rs)):
                occ[row, w] = 0
                age[row, w] = 0
                hit = True
        cleared.append(hit)
    for lane in aged_lanes:
        rows = slice(lane * n_sets, (lane + 1) * n_sets)
        age[rows] = np.where(occ[rows] > 0, age[rows] + 1, 0)
    return cleared, GangTable(*(jnp.asarray(a) for a in
                                (khi, klo, occ, rhi, rlo, age)))
