"""Linearizability checkers (Wing & Gong search with memoization).

CURP's guarantee (§3.4) is linearizability of single-/multi-key NoSQL ops.
Our histories come from the simulator and the in-process harnesses: each
entry has invoke/complete times, the op, and the externalized value.  Ops
whose completion was never externalized (client crashed / gave up / sim
ended) are "maybe" ops: a valid linearization may either include them at any
legal point or exclude them.

Two checkers live here:

* ``check_linearizable`` — the per-key projection.  Single-key histories
  decompose per key, which keeps the NP-hard search tractable; multi-key
  ops (MSET / TXN) are projected onto each touched key.  **This projection
  is blind to torn multi-key writes**: a "maybe" MSET's per-key legs are
  dropped or kept INDEPENDENTLY per key, so a client crash that applied the
  write on shard A but not shard B still passes — each key's sub-history is
  individually fine.
* ``check_linearizable_strict`` — strict multi-key atomicity.  A GLOBAL
  Wing & Gong search over whole ops and a whole-store state: every
  multi-key op (MSET / TXN) linearizes at ONE point that all of its keys
  share, and a maybe op is included at some single point or excluded
  entirely.  Per-key decomposition fundamentally cannot express this —
  each key's sub-search may place the same op at a different point, which
  is exactly how a torn write hides — so the strict checker does not
  decompose.  It is what catches a torn cross-shard ``mset`` and what the
  transaction subsystem (repro.core.txn) must pass under crash injection.
  Cost: exponential in true concurrency; our harness histories are
  near-sequential (disjoint logical windows), so the memoized search stays
  effectively linear.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, List, Optional, Tuple

from repro.core.store import merge_append, merge_max, merge_sadd
from repro.core.types import Op, OpType

# Op types the checkers model.  The merge classes (INCR/SADD/APPEND/MAX/
# HMSET) use the STORE's own merge functions as their legality model —
# imported, not re-implemented, so checker and state machine cannot drift.
_SINGLE = (OpType.SET, OpType.GET, OpType.INCR, OpType.DEL,
           OpType.SADD, OpType.APPEND, OpType.MAX, OpType.HMSET)
_MULTI = (OpType.MSET, OpType.TXN)

# Merge ops whose argument is args[0] and whose externalized value is the
# uninformative "OK" (no per-op contradiction check; legality is the state).
_MERGE_ARG0 = (OpType.SADD, OpType.APPEND, OpType.MAX)


def _canon(v):
    """Hashable canonical form of a store value (the Wing & Gong memo keys
    on state, so dict values from HMSET must canonicalize)."""
    if isinstance(v, dict):
        return ("#H", tuple(sorted(v.items(), key=repr)))
    return v


def _canon_hmset(cur, fields):
    """Apply HMSET fields over a canonicalized prior hash value."""
    h = (dict(cur[1]) if isinstance(cur, tuple) and len(cur) == 2
         and cur[0] == "#H" else {})
    for f, v in fields:
        h[f] = v
    return _canon(h)


@dataclass(frozen=True)
class HEvent:
    idx: int
    invoke: float
    complete: Optional[float]   # None => "maybe" op (no externalized response)
    op_type: OpType
    arg: Any                    # SET value / INCR delta / None
    value: Any                  # externalized result (GET value, INCR result)


def _txn_legs(op: Op, value: Any):
    """(write_kvs, read_kvs) of a TXN history entry: writes from the spec,
    read values from the externalized result (None when never completed)."""
    spec = op.args[0]
    write_kvs = tuple(spec.write_kvs)
    read_kvs: Tuple[Tuple[Any, Any], ...] = ()
    if isinstance(value, tuple) and len(value) == 2 and value[0] == "COMMITTED":
        read_kvs = tuple(zip(spec.read_keys, value[1]))
    return write_kvs, read_kvs


def _project(history: List[dict]) -> Dict[Any, List[HEvent]]:
    """Per-key projection (the fast, torn-write-blind decomposition)."""
    per_key: Dict[Any, List[HEvent]] = {}
    idx = 0

    def add(key, invoke, complete, op_type, arg, value):
        nonlocal idx
        per_key.setdefault(key, []).append(HEvent(
            idx=idx, invoke=invoke, complete=complete, op_type=op_type,
            arg=arg, value=value,
        ))
        idx += 1

    for h in history:
        op: Op = h["op"]
        if op.op_type not in _SINGLE + _MULTI:
            continue
        complete = h["complete"] if not h.get("failed") else None
        if op.op_type is OpType.TXN:
            write_kvs, read_kvs = _txn_legs(op, h["value"])
            for k, v in write_kvs:
                add(k, h["invoke"], complete, OpType.SET, v, h["value"])
            for k, v in read_kvs:
                # Read legs externalize only with a committed result.
                add(k, h["invoke"], complete, OpType.GET, None, v)
            continue
        for ki, key in enumerate(op.keys):
            if op.op_type == OpType.MSET:
                arg = op.args[ki]
            elif op.op_type == OpType.SET:
                arg = op.args[0]
            elif op.op_type == OpType.INCR:
                arg = op.args[0] if op.args else 1
            elif op.op_type in _MERGE_ARG0:
                arg = op.args[0]
            elif op.op_type == OpType.HMSET:
                arg = tuple(op.args[0]) if op.args else ()
            else:
                arg = None
            add(key, h["invoke"], complete,
                (OpType.SET if op.op_type == OpType.MSET else op.op_type),
                arg, h["value"])
    return per_key


def _check_key(events: List[HEvent]) -> bool:
    """Search for a linearization of one key's history."""
    events = sorted(events, key=lambda e: e.invoke)
    n = len(events)
    if n == 0:
        return True
    all_ids = frozenset(range(n))
    ev = {i: events[i] for i in range(n)}

    def apply(state, e: HEvent):
        """Returns next state, or None if e's externalized value contradicts."""
        if e.op_type == OpType.SET:
            return ("V", e.arg)
        if e.op_type == OpType.DEL:
            return ("V", None)
        if e.op_type == OpType.INCR:
            base = state[1] if state[0] == "V" and isinstance(state[1], int) else 0
            new = base + (e.arg if e.arg is not None else 1)
            if e.complete is not None and e.value is not None and e.value != new:
                return None
            return ("V", new)
        if e.op_type == OpType.SADD:
            return ("V", merge_sadd(state[1] if state[0] == "V" else None,
                                    e.arg))
        if e.op_type == OpType.APPEND:
            return ("V", merge_append(state[1] if state[0] == "V" else None,
                                      e.arg))
        if e.op_type == OpType.MAX:
            return ("V", merge_max(state[1] if state[0] == "V" else None,
                                   e.arg))
        if e.op_type == OpType.HMSET:
            return ("V", _canon_hmset(state[1] if state[0] == "V" else None,
                                      e.arg))
        if e.op_type == OpType.GET:
            cur = state[1] if state[0] == "V" else None
            if e.complete is not None and _canon(e.value) != _canon(cur):
                return None
            return state
        return state

    import sys
    sys.setrecursionlimit(10000)
    seen = set()

    def search(remaining: FrozenSet[int], state) -> bool:
        if not remaining:
            return True
        key = (remaining, state)
        if key in seen:
            return False
        # Candidates: ops that are minimal in the real-time order, i.e. whose
        # invocation precedes every remaining op's completion.
        min_complete = min(
            (ev[i].complete for i in remaining if ev[i].complete is not None),
            default=float("inf"),
        )
        for i in remaining:
            e = ev[i]
            if e.invoke > min_complete:
                continue
            nxt = apply(state, e)
            if nxt is not None and search(remaining - {i}, nxt):
                return True
            # Maybe-ops can also be dropped entirely (they never took effect).
            if e.complete is None and search(remaining - {i}, state):
                return True
        seen.add(key)
        return False

    # Completed ops must all be linearized; maybe-ops may be dropped.  The
    # search above handles dropping inline.
    return search(all_ids, ("V", None))


def _check_projection(per_key) -> Tuple[bool, Optional[Any]]:
    for key, events in per_key.items():
        if not _check_key(events):
            return False, key
    return True, None


def check_linearizable(history: List[dict]) -> Tuple[bool, Optional[Any]]:
    """Per-key projection checker.  Returns (ok, offending_key).  Sound for
    single-key ops; CANNOT detect torn multi-key writes (see module
    docstring) — use ``check_linearizable_strict`` for those."""
    return _check_projection(_project(history))


# ---------------------------------------------------------------------------
# Strict multi-key atomicity: a single global linearization order
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class _GEvent:
    """One whole op (all keys) for the global search."""
    idx: int
    invoke: float
    complete: Optional[float]
    # Effects: ((key, new_value) writes, (key, incr_delta) incrs,
    #           (key, expected) reads-with-externalized-values,
    #           (key,) unchecked reads) — reads check only when completed.
    writes: Tuple[Tuple[Any, Any], ...]
    incrs: Tuple[Tuple[Any, int], ...]
    reads: Tuple[Tuple[Any, Any], ...]
    incr_expect: Any = None      # externalized INCR result (None: unchecked)
    # Merge-class effects: (key, op_type, arg) folded through the store's
    # own merge functions (SADD/APPEND/MAX) or the canonical hash (HMSET).
    merges: Tuple[Tuple[Any, Any, Any], ...] = ()


def _global_events(history: List[dict]) -> List[_GEvent]:
    events: List[_GEvent] = []
    for h in history:
        op: Op = h["op"]
        if op.op_type not in _SINGLE + _MULTI:
            continue
        complete = h["complete"] if not h.get("failed") else None
        value = h["value"]
        writes: Tuple = ()
        incrs: Tuple = ()
        reads: Tuple = ()
        merges: Tuple = ()
        incr_expect = None
        if op.op_type is OpType.SET:
            writes = ((op.keys[0], op.args[0]),)
        elif op.op_type is OpType.DEL:
            writes = ((op.keys[0], None),)
        elif op.op_type is OpType.INCR:
            incrs = ((op.keys[0], op.args[0] if op.args else 1),)
            if complete is not None:
                incr_expect = value
        elif op.op_type in _MERGE_ARG0:
            merges = ((op.keys[0], op.op_type, op.args[0]),)
        elif op.op_type is OpType.HMSET:
            merges = ((op.keys[0], OpType.HMSET,
                       tuple(op.args[0]) if op.args else ()),)
        elif op.op_type is OpType.GET:
            if complete is not None:
                reads = ((op.keys[0], value),)
        elif op.op_type is OpType.MSET:
            writes = tuple(zip(op.keys, op.args))
        elif op.op_type is OpType.TXN:
            write_kvs, read_kvs = _txn_legs(op, value)
            writes = tuple(write_kvs)
            if complete is not None:
                reads = tuple(read_kvs)
        events.append(_GEvent(
            idx=len(events), invoke=h["invoke"], complete=complete,
            writes=writes, incrs=incrs, reads=reads,
            incr_expect=incr_expect, merges=merges,
        ))
    return events


def check_linearizable_strict(
    history: List[dict],
) -> Tuple[bool, Optional[Any]]:
    """Strict multi-key linearizability: ONE global linearization order over
    whole ops and a whole-store state.

    A multi-key op takes effect at a single point for ALL of its keys (the
    per-key projection lets each key's sub-search place the same op at a
    different point — the loophole a torn write hides in), and a maybe op
    is included at one point or excluded entirely.  Returns (ok,
    offending_key) where the key is taken from the op that could not be
    linearized (diagnostic).  Worst-case exponential in true concurrency;
    near-linear on our harness histories (disjoint logical windows).
    """
    events = _global_events(history)
    n = len(events)
    if n == 0:
        return True, None
    ev = {e.idx: e for e in events}
    all_ids = frozenset(ev)

    def apply(state: Tuple[Tuple[Any, Any], ...], e: _GEvent):
        d = dict(state)
        for k, expect in e.reads:
            if _canon(d.get(k)) != _canon(expect):
                return None
        for k, delta in e.incrs:
            base = d.get(k)
            new = (base if isinstance(base, int) else 0) + delta
            if e.incr_expect is not None and e.incr_expect != new:
                return None
            d[k] = new
        for k, t, arg in e.merges:
            cur = d.get(k)
            if t is OpType.SADD:
                d[k] = merge_sadd(cur, arg)
            elif t is OpType.APPEND:
                d[k] = merge_append(cur, arg)
            elif t is OpType.MAX:
                d[k] = merge_max(cur, arg)
            else:   # HMSET over the canonical hashable hash value
                d[k] = _canon_hmset(cur, arg)
        for k, v in e.writes:
            d[k] = v
        return tuple(sorted(d.items(), key=lambda kv: repr(kv[0])))

    import sys
    sys.setrecursionlimit(100_000)
    seen = set()
    blamed: List[_GEvent] = []

    def search(remaining: FrozenSet[int], state) -> bool:
        if not remaining:
            return True
        key = (remaining, state)
        if key in seen:
            return False
        min_complete = min(
            (ev[i].complete for i in remaining if ev[i].complete is not None),
            default=float("inf"),
        )
        for i in remaining:
            e = ev[i]
            if e.invoke > min_complete:
                continue
            nxt = apply(state, e)
            if nxt is not None and search(remaining - {i}, nxt):
                return True
            if nxt is None and not blamed:
                blamed.append(e)
            # Maybe-ops may be excluded entirely (they never took effect —
            # ATOMICALLY: this drops every key's effect at once).
            if e.complete is None and search(remaining - {i}, state):
                return True
        seen.add(key)
        return False

    if search(all_ids, ()):
        return True, None
    offender = None
    if blamed:
        e = blamed[0]
        for group in (e.reads, e.writes, e.incrs, e.merges):
            if group:
                offender = group[0][0]
                break
    return False, offender
