"""Linearizability checkers (Wing & Gong search with memoization).

CURP's guarantee (§3.4) is linearizability of single-/multi-key NoSQL ops.
Our histories come from the simulator and the in-process harnesses: each
entry has invoke/complete times, the op, and the externalized value.  Ops
whose completion was never externalized (client crashed / gave up / sim
ended) are "maybe" ops: a valid linearization may either include them at any
legal point or exclude them.

Two checkers live here:

* ``check_linearizable`` — the per-key projection.  Single-key histories
  decompose per key, which keeps the NP-hard search tractable; multi-key
  ops (MSET / TXN) are projected onto each touched key.  **This projection
  is blind to torn multi-key writes**: a "maybe" MSET's per-key legs are
  dropped or kept INDEPENDENTLY per key, so a client crash that applied the
  write on shard A but not shard B still passes — each key's sub-history is
  individually fine.
* ``check_linearizable_strict`` — strict multi-key atomicity.  A GLOBAL
  Wing & Gong search over whole ops and a whole-store state: every
  multi-key op (MSET / TXN) linearizes at ONE point that all of its keys
  share, and a maybe op is included at some single point or excluded
  entirely.  Per-key decomposition fundamentally cannot express this —
  each key's sub-search may place the same op at a different point, which
  is exactly how a torn write hides — so the strict checker does not
  decompose.  It is what catches a torn cross-shard ``mset`` and what the
  transaction subsystem (repro.core.txn) must pass under crash injection.
  Cost: exponential in true concurrency; our harness histories are
  near-sequential (disjoint logical windows), so the memoized search stays
  effectively linear.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, List, Optional, Tuple

from repro.core.store import merge_append, merge_max, merge_sadd
from repro.core.types import Op, OpType

# Op types the checkers model.  The merge classes (INCR/SADD/APPEND/MAX/
# HMSET) use the STORE's own merge functions as their legality model —
# imported, not re-implemented, so checker and state machine cannot drift.
_SINGLE = (OpType.SET, OpType.GET, OpType.INCR, OpType.DEL,
           OpType.SADD, OpType.APPEND, OpType.MAX, OpType.HMSET)
_MULTI = (OpType.MSET, OpType.TXN)

# Merge ops whose argument is args[0] and whose externalized value is the
# uninformative "OK" (no per-op contradiction check; legality is the state).
_MERGE_ARG0 = (OpType.SADD, OpType.APPEND, OpType.MAX)


def _canon(v):
    """Hashable canonical form of a store value (the Wing & Gong memo keys
    on state, so dict values from HMSET must canonicalize)."""
    if isinstance(v, dict):
        return ("#H", tuple(sorted(v.items(), key=repr)))
    return v


def _canon_hmset(cur, fields):
    """Apply HMSET fields over a canonicalized prior hash value."""
    h = (dict(cur[1]) if isinstance(cur, tuple) and len(cur) == 2
         and cur[0] == "#H" else {})
    for f, v in fields:
        h[f] = v
    return _canon(h)


@dataclass(frozen=True)
class HEvent:
    idx: int
    invoke: float
    complete: Optional[float]   # None => "maybe" op (no externalized response)
    op_type: OpType
    arg: Any                    # SET value / INCR delta / None
    value: Any                  # externalized result (GET value, INCR result)


def _txn_legs(op: Op, value: Any):
    """(write_kvs, read_kvs) of a TXN history entry: writes from the spec,
    read values from the externalized result (None when never completed)."""
    spec = op.args[0]
    write_kvs = tuple(spec.write_kvs)
    read_kvs: Tuple[Tuple[Any, Any], ...] = ()
    if isinstance(value, tuple) and len(value) == 2 and value[0] == "COMMITTED":
        read_kvs = tuple(zip(spec.read_keys, value[1]))
    return write_kvs, read_kvs


def _project(history: List[dict]) -> Dict[Any, List[HEvent]]:
    """Per-key projection (the fast, torn-write-blind decomposition)."""
    per_key: Dict[Any, List[HEvent]] = {}
    idx = 0

    def add(key, invoke, complete, op_type, arg, value):
        nonlocal idx
        per_key.setdefault(key, []).append(HEvent(
            idx=idx, invoke=invoke, complete=complete, op_type=op_type,
            arg=arg, value=value,
        ))
        idx += 1

    for h in history:
        op: Op = h["op"]
        if op.op_type not in _SINGLE + _MULTI:
            continue
        complete = h["complete"] if not h.get("failed") else None
        if op.op_type is OpType.TXN:
            write_kvs, read_kvs = _txn_legs(op, h["value"])
            for k, v in write_kvs:
                add(k, h["invoke"], complete, OpType.SET, v, h["value"])
            for k, v in read_kvs:
                # Read legs externalize only with a committed result.
                add(k, h["invoke"], complete, OpType.GET, None, v)
            continue
        for ki, key in enumerate(op.keys):
            if op.op_type == OpType.MSET:
                arg = op.args[ki]
            elif op.op_type == OpType.SET:
                arg = op.args[0]
            elif op.op_type == OpType.INCR:
                arg = op.args[0] if op.args else 1
            elif op.op_type in _MERGE_ARG0:
                arg = op.args[0]
            elif op.op_type == OpType.HMSET:
                arg = tuple(op.args[0]) if op.args else ()
            else:
                arg = None
            add(key, h["invoke"], complete,
                (OpType.SET if op.op_type == OpType.MSET else op.op_type),
                arg, h["value"])
    return per_key


def _check_key(events: List[HEvent]) -> bool:
    """Search for a linearization of one key's history."""
    events = sorted(events, key=lambda e: e.invoke)
    n = len(events)
    if n == 0:
        return True
    all_ids = frozenset(range(n))
    ev = {i: events[i] for i in range(n)}

    def apply(state, e: HEvent):
        """Returns next state, or None if e's externalized value contradicts."""
        if e.op_type == OpType.SET:
            return ("V", e.arg)
        if e.op_type == OpType.DEL:
            return ("V", None)
        if e.op_type == OpType.INCR:
            base = state[1] if state[0] == "V" and isinstance(state[1], int) else 0
            new = base + (e.arg if e.arg is not None else 1)
            if e.complete is not None and e.value is not None and e.value != new:
                return None
            return ("V", new)
        if e.op_type == OpType.SADD:
            return ("V", merge_sadd(state[1] if state[0] == "V" else None,
                                    e.arg))
        if e.op_type == OpType.APPEND:
            return ("V", merge_append(state[1] if state[0] == "V" else None,
                                      e.arg))
        if e.op_type == OpType.MAX:
            return ("V", merge_max(state[1] if state[0] == "V" else None,
                                   e.arg))
        if e.op_type == OpType.HMSET:
            return ("V", _canon_hmset(state[1] if state[0] == "V" else None,
                                      e.arg))
        if e.op_type == OpType.GET:
            cur = state[1] if state[0] == "V" else None
            if e.complete is not None and _canon(e.value) != _canon(cur):
                return None
            return state
        return state

    import sys
    sys.setrecursionlimit(10000)
    seen = set()

    def search(remaining: FrozenSet[int], state) -> bool:
        if not remaining:
            return True
        key = (remaining, state)
        if key in seen:
            return False
        # Candidates: ops that are minimal in the real-time order, i.e. whose
        # invocation precedes every remaining op's completion.
        min_complete = min(
            (ev[i].complete for i in remaining if ev[i].complete is not None),
            default=float("inf"),
        )
        for i in remaining:
            e = ev[i]
            if e.invoke > min_complete:
                continue
            nxt = apply(state, e)
            if nxt is not None and search(remaining - {i}, nxt):
                return True
            # Maybe-ops can also be dropped entirely (they never took effect).
            if e.complete is None and search(remaining - {i}, state):
                return True
        seen.add(key)
        return False

    # Completed ops must all be linearized; maybe-ops may be dropped.  The
    # search above handles dropping inline.
    return search(all_ids, ("V", None))


def _check_projection(per_key) -> Tuple[bool, Optional[Any]]:
    for key, events in per_key.items():
        if not _check_key(events):
            return False, key
    return True, None


def check_linearizable(history: List[dict]) -> Tuple[bool, Optional[Any]]:
    """Per-key projection checker.  Returns (ok, offending_key).  Sound for
    single-key ops; CANNOT detect torn multi-key writes (see module
    docstring) — use ``check_linearizable_strict`` for those."""
    return _check_projection(_project(history))


# ---------------------------------------------------------------------------
# Strict multi-key atomicity: a single global linearization order
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class _GEvent:
    """One whole op (all keys) for the global search."""
    idx: int
    invoke: float
    complete: Optional[float]
    # Effects: ((key, new_value) writes, (key, incr_delta) incrs,
    #           (key, expected) reads-with-externalized-values,
    #           (key,) unchecked reads) — reads check only when completed.
    writes: Tuple[Tuple[Any, Any], ...]
    incrs: Tuple[Tuple[Any, int], ...]
    reads: Tuple[Tuple[Any, Any], ...]
    incr_expect: Any = None      # externalized INCR result (None: unchecked)
    # Merge-class effects: (key, op_type, arg) folded through the store's
    # own merge functions (SADD/APPEND/MAX) or the canonical hash (HMSET).
    merges: Tuple[Tuple[Any, Any, Any], ...] = ()


def _global_events(history: List[dict]) -> List[_GEvent]:
    events: List[_GEvent] = []
    for h in history:
        op: Op = h["op"]
        if op.op_type not in _SINGLE + _MULTI:
            continue
        complete = h["complete"] if not h.get("failed") else None
        value = h["value"]
        writes: Tuple = ()
        incrs: Tuple = ()
        reads: Tuple = ()
        merges: Tuple = ()
        incr_expect = None
        if op.op_type is OpType.SET:
            writes = ((op.keys[0], op.args[0]),)
        elif op.op_type is OpType.DEL:
            writes = ((op.keys[0], None),)
        elif op.op_type is OpType.INCR:
            incrs = ((op.keys[0], op.args[0] if op.args else 1),)
            if complete is not None:
                incr_expect = value
        elif op.op_type in _MERGE_ARG0:
            merges = ((op.keys[0], op.op_type, op.args[0]),)
        elif op.op_type is OpType.HMSET:
            merges = ((op.keys[0], OpType.HMSET,
                       tuple(op.args[0]) if op.args else ()),)
        elif op.op_type is OpType.GET:
            if complete is not None:
                reads = ((op.keys[0], value),)
        elif op.op_type is OpType.MSET:
            writes = tuple(zip(op.keys, op.args))
        elif op.op_type is OpType.TXN:
            write_kvs, read_kvs = _txn_legs(op, value)
            writes = tuple(write_kvs)
            if complete is not None:
                reads = tuple(read_kvs)
        events.append(_GEvent(
            idx=len(events), invoke=h["invoke"], complete=complete,
            writes=writes, incrs=incrs, reads=reads,
            incr_expect=incr_expect, merges=merges,
        ))
    return events


def _apply_global(state: Tuple[Tuple[Any, Any], ...], e: _GEvent):
    """Apply one whole op to a canonical whole-store state; None if the
    op's externalized values contradict the state.  Shared by the strict
    whole-history search and the windowed incremental checker, so the two
    can never disagree on op semantics."""
    d = dict(state)
    for k, expect in e.reads:
        if _canon(d.get(k)) != _canon(expect):
            return None
    for k, delta in e.incrs:
        base = d.get(k)
        new = (base if isinstance(base, int) else 0) + delta
        if e.incr_expect is not None and e.incr_expect != new:
            return None
        d[k] = new
    for k, t, arg in e.merges:
        cur = d.get(k)
        if t is OpType.SADD:
            d[k] = merge_sadd(cur, arg)
        elif t is OpType.APPEND:
            d[k] = merge_append(cur, arg)
        elif t is OpType.MAX:
            d[k] = merge_max(cur, arg)
        else:   # HMSET over the canonical hashable hash value
            d[k] = _canon_hmset(cur, arg)
    for k, v in e.writes:
        d[k] = v
    return tuple(sorted(d.items(), key=lambda kv: repr(kv[0])))


def check_linearizable_strict(
    history: List[dict],
) -> Tuple[bool, Optional[Any]]:
    """Strict multi-key linearizability: ONE global linearization order over
    whole ops and a whole-store state.

    A multi-key op takes effect at a single point for ALL of its keys (the
    per-key projection lets each key's sub-search place the same op at a
    different point — the loophole a torn write hides in), and a maybe op
    is included at one point or excluded entirely.  Returns (ok,
    offending_key) where the key is taken from the op that could not be
    linearized (diagnostic).  Worst-case exponential in true concurrency;
    near-linear on our harness histories (disjoint logical windows).
    """
    events = _global_events(history)
    n = len(events)
    if n == 0:
        return True, None
    ev = {e.idx: e for e in events}
    all_ids = frozenset(ev)
    apply = _apply_global

    import sys
    sys.setrecursionlimit(100_000)
    seen = set()
    blamed: List[_GEvent] = []

    def search(remaining: FrozenSet[int], state) -> bool:
        if not remaining:
            return True
        key = (remaining, state)
        if key in seen:
            return False
        min_complete = min(
            (ev[i].complete for i in remaining if ev[i].complete is not None),
            default=float("inf"),
        )
        for i in remaining:
            e = ev[i]
            if e.invoke > min_complete:
                continue
            nxt = apply(state, e)
            if nxt is not None and search(remaining - {i}, nxt):
                return True
            if nxt is None and not blamed:
                blamed.append(e)
            # Maybe-ops may be excluded entirely (they never took effect —
            # ATOMICALLY: this drops every key's effect at once).
            if e.complete is None and search(remaining - {i}, state):
                return True
        seen.add(key)
        return False

    if search(all_ids, ()):
        return True, None
    offender = None
    if blamed:
        e = blamed[0]
        for group in (e.reads, e.writes, e.incrs, e.merges):
            if group:
                offender = group[0][0]
                break
    return False, offender


# ---------------------------------------------------------------------------
# Windowed incremental checker: strict semantics, bounded memory
# ---------------------------------------------------------------------------
class _Saturated(Exception):
    pass


def _blame_key(e: _GEvent):
    for group in (e.reads, e.writes, e.incrs, e.merges):
        if group:
            return group[0][0]
    return None


def _event_keys(e: _GEvent) -> set:
    ks = set()
    for k, _ in e.writes:
        ks.add(k)
    for k, _ in e.incrs:
        ks.add(k)
    for k, _ in e.reads:
        ks.add(k)
    for k, _t, _a in e.merges:
        ks.add(k)
    return ks


def _components(chunk: List[_GEvent]) -> List[Tuple[set, List[_GEvent]]]:
    """Partition a chunk into key-connected components.

    Linearizability is compositional over disjoint objects (Herlihy & Wing
    locality): ops that share no key — directly or through a chain of
    multi-key ops — constrain each other only through real-time order, and
    any per-component linearization interleaves into a global one that
    respects it.  Every multi-key op keeps ALL its keys in one component,
    so torn-write atomicity is preserved exactly.  This is what makes the
    windowed checker tractable on open-loop histories: hundreds of
    concurrent ops over a spread key space decompose into near-singleton
    searches, while a genuinely entangled (hot-key) chunk stays whole and
    falls back on the node budget.  Events with no effects at all (e.g. a
    never-completed read) constrain nothing and are dropped."""
    parent: Dict[Any, Any] = {}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ev_keys = []
    for e in chunk:
        ks = _event_keys(e)
        ev_keys.append(ks)
        it = iter(ks)
        first = next(it, None)
        if first is not None and first not in parent:
            parent[first] = first
        for k in it:
            if k not in parent:
                parent[k] = k
            ra, rb = find(first), find(k)
            if ra != rb:
                parent[ra] = rb
    comps: Dict[Any, Tuple[set, List[_GEvent]]] = {}
    for e, ks in zip(chunk, ev_keys):
        if not ks:
            continue
        entry = comps.setdefault(find(next(iter(ks))), (set(), []))
        entry[0].update(ks)
        entry[1].append(e)
    return list(comps.values())


class WindowedChecker:
    """Strict Wing & Gong, advanced incrementally over a completed-op
    frontier so 10^5–10^6-op open-loop runs are checked online in bounded
    memory (the whole-history checker holds every op until the end).

    Feed: ``invoke(rpc_id, t)`` when an op is issued, ``complete(entry)``
    when its history entry is known (including give-ups: ``failed`` entries
    become maybe-ops), ``finish()`` at teardown.

    Retirement rule: pending ops sorted by invoke; a prefix is *closed*
    when every op in it settles strictly before both (a) the earliest
    invoke of any later pending op and (b) the earliest invoke of any
    still-in-flight op.  No op outside a closed prefix can linearize inside
    it, so the prefix is searched exactly (collecting ALL reachable end
    states — carrying a single greedy state would mis-blame later chunks)
    and then discarded.  This is the same decomposition that keeps the
    strict checker near-linear on near-sequential histories, made explicit.

    Maybe-ops never complete, so they would pin the frontier forever; they
    settle at ``invoke + maybe_horizon`` instead.  The search may still
    drop them (a maybe both applied-and-not is two states in the carried
    set), but their effect is assumed to land within the horizon — sound
    for the sim, whose abandoned packets die within the retry/drain bound.
    ``maybe_horizon=None`` disables the assumption (exact, but a maybe op
    then blocks retirement of everything after it until ``finish``).

    Saturation (``max_pending`` overlapping ops, ``max_states`` carried
    states, or ``max_nodes`` search nodes per chunk) sets ``saturated`` and
    stops checking rather than guessing: no false alarms, explicitly
    reported coverage.
    """

    def __init__(self, flush_every: int = 256,
                 maybe_horizon: Optional[float] = None,
                 max_pending: int = 50_000, max_states: int = 256,
                 max_nodes: int = 500_000,
                 max_maybe: Optional[int] = 32,
                 max_overlap: Optional[int] = 16) -> None:
        self.flush_every = flush_every
        self.maybe_horizon = maybe_horizon
        self.max_pending = max_pending
        self.max_states = max_states
        self.max_nodes = max_nodes
        self.max_maybe = max_maybe
        self.max_overlap = max_overlap
        self._open: Dict[Any, float] = {}      # rpc_id -> invoke time
        self._pending: List[_GEvent] = []
        self._states: set = {()}
        self._since_flush = 0
        self.violation: Optional[Tuple[Any, dict]] = None
        self.saturated = False
        self.ops_checked = 0
        self.chunks = 0
        self.max_chunk = 0

    # ------------------------------------------------------------------ feed
    def invoke(self, rpc_id, t: float) -> None:
        self._open[rpc_id] = t

    def complete(self, entry: dict) -> None:
        """Ingest one finished history entry (completed or failed)."""
        self._open.pop(entry["op"].rpc_id, None)
        if self.violation is not None or self.saturated:
            return
        for g in _global_events([entry]):
            self._pending.append(g)
        self._since_flush += 1
        if self._since_flush >= self.flush_every:
            self._since_flush = 0
            self._flush(final=False)

    def finish(self) -> bool:
        self._flush(final=True)
        return self.ok

    @property
    def ok(self) -> bool:
        return self.violation is None

    @property
    def pending(self) -> int:
        return len(self._pending)

    def stats(self) -> dict:
        return {
            "ops_checked": self.ops_checked, "chunks": self.chunks,
            "max_chunk": self.max_chunk, "pending": len(self._pending),
            "states": len(self._states), "saturated": self.saturated,
            "ok": self.ok,
        }

    # ----------------------------------------------------------------- flush
    def _settle(self, e: _GEvent) -> float:
        if e.complete is not None:
            return e.complete
        if self.maybe_horizon is None:
            return float("inf")
        return e.invoke + self.maybe_horizon

    def _flush(self, final: bool) -> None:
        if self.violation is not None or self.saturated:
            return
        self._pending.sort(key=lambda e: e.invoke)
        if final:
            chunk, rest = self._pending, []
        else:
            cut = min(self._open.values(), default=float("inf"))
            split, hi = 0, float("-inf")
            for i, e in enumerate(self._pending):
                if hi < cut and hi < e.invoke:
                    split = i   # prefix [0, i) is real-time closed
                hi = max(hi, self._settle(e))
            if hi < cut:
                split = len(self._pending)
            chunk, rest = self._pending[:split], self._pending[split:]
        if not chunk:
            if len(self._pending) > self.max_pending:
                self.saturated = True
            return
        # Each maybe-op forks the search (included-or-dropped); a chunk
        # dense with them — crash fallout, mass give-ups — would only burn
        # the whole node budget before saturating anyway.  Bail up front:
        # same verdict (saturated, honestly reported), none of the cost.
        if self.max_maybe is not None and \
                sum(1 for e in chunk if e.complete is None) > self.max_maybe:
            self.saturated = True
            return
        self._search_chunk(chunk)
        self._pending = rest
        if self.violation is None and not self.saturated:
            self.ops_checked += len(chunk)
            self.chunks += 1
            self.max_chunk = max(self.max_chunk, len(chunk))

    def _search_chunk(self, chunk: List[_GEvent]) -> None:
        """Search one real-time-closed chunk: decompose into key-connected
        components (see ``_components`` — exact by locality), run the
        Wing & Gong search per component from each carried state's
        projection, and carry the cross product of per-component end
        substates forward.  A chunk fails only when NO carried state admits
        a linearization of every component."""
        import itertools
        import sys

        sys.setrecursionlimit(100_000)
        comps = _components(chunk)
        if not comps:
            return
        comp_keys: set = set()
        for keys, _evs in comps:
            comp_keys |= keys
        nodes = [0]
        blamed: List[_GEvent] = []
        # Component results memoized on the start SUBSTATE: carried states
        # usually agree on a component's keys, so each search runs once.
        memo: Dict[Tuple[int, Tuple], FrozenSet] = {}
        new_states: set = set()

        try:
            for st in self._states:
                parts: List[List[Tuple]] = []
                ok = True
                for ci, (keys, evs) in enumerate(comps):
                    sub0 = tuple(sorted(
                        (kv for kv in st if kv[0] in keys),
                        key=lambda kv: repr(kv[0]),
                    ))
                    finals = memo.get((ci, sub0))
                    if finals is None:
                        finals = self._search_component(
                            evs, sub0, nodes, blamed)
                        memo[(ci, sub0)] = finals
                    if not finals:
                        ok = False
                        break
                    parts.append(sorted(finals))
                if not ok:
                    continue
                base = [kv for kv in st if kv[0] not in comp_keys]
                for combo in itertools.product(*parts):
                    d = dict(base)
                    for sub in combo:
                        d.update(sub)
                    new_states.add(tuple(
                        sorted(d.items(), key=lambda kv: repr(kv[0]))))
                    if len(new_states) > self.max_states:
                        self.saturated = True
                        return
        except _Saturated:
            self.saturated = True
            return
        if not new_states:
            e = blamed[0] if blamed else chunk[0]
            self.violation = (_blame_key(e), {
                "chunk_ops": len(chunk), "invoke": e.invoke,
                "complete": e.complete,
            })
            return
        self._states = new_states

    def _search_component(self, evs: List[_GEvent], start: Tuple,
                          nodes: List[int],
                          blamed: List[_GEvent]) -> FrozenSet:
        """All reachable end substates of one component from ``start``
        (empty set: no linearization exists).  The shared ``nodes`` budget
        spans the whole chunk, so one entangled component cannot starve
        the rest silently — it saturates the checker instead."""
        # Concurrency guard: k mutually-overlapping ops admit up to k!
        # interleavings — a crash-window retry pile-up on one hot key (50+
        # concurrent ops) would only grind the node budget down before
        # saturating anyway.  Measure the overlap degree up front and bail
        # with the same verdict at none of the cost.
        if self.max_overlap is not None and len(evs) > self.max_overlap:
            marks: List[Tuple[float, int]] = []
            for e in evs:
                marks.append((e.invoke, 1))
                if e.complete is not None:
                    marks.append((e.complete, -1))
            marks.sort()
            depth = peak = 0
            for _t, d in marks:
                depth += d
                peak = max(peak, depth)
            if peak > self.max_overlap:
                raise _Saturated

        ev = {i: e for i, e in enumerate(evs)}
        all_ids = frozenset(ev)
        finals: set = set()
        seen: set = set()

        def rec(remaining: FrozenSet[int], state) -> None:
            key = (remaining, state)
            if key in seen:
                return
            seen.add(key)
            nodes[0] += 1
            if nodes[0] > self.max_nodes:
                raise _Saturated
            if not remaining:
                finals.add(state)
                return
            min_complete = min(
                (ev[i].complete for i in remaining
                 if ev[i].complete is not None),
                default=float("inf"),
            )
            for i in remaining:
                e = ev[i]
                if e.invoke > min_complete:
                    continue
                nxt = _apply_global(state, e)
                if nxt is not None:
                    rec(remaining - {i}, nxt)
                elif not blamed:
                    blamed.append(e)
                if e.complete is None:   # maybe-op: droppable atomically
                    rec(remaining - {i}, state)

        rec(all_ids, start)
        return frozenset(finals)


def check_linearizable_windowed(
    history: List[dict], flush_every: int = 64,
    maybe_horizon: Optional[float] = None,
) -> Tuple[bool, Optional[Any]]:
    """Drive a WindowedChecker over a recorded history in event-time order
    (invokes and completions interleaved as they actually happened).
    Returns (ok, offending_key) like ``check_linearizable_strict``; with
    the default exact settings the verdicts provably agree — the windowed
    search is the strict search applied chunk-by-chunk with all reachable
    states carried across chunk boundaries."""
    chk = WindowedChecker(flush_every=flush_every,
                          maybe_horizon=maybe_horizon)
    stream = []
    for h in history:
        done = h["complete"] if h.get("complete") is not None else h["invoke"]
        stream.append((h["invoke"], 0, h))
        stream.append((done, 1, h))
    stream.sort(key=lambda x: (x[0], x[1]))
    for _t, phase, h in stream:
        if phase == 0:
            chk.invoke(h["op"].rpc_id, h["invoke"])
        else:
            chk.complete(h)
    ok = chk.finish()
    if chk.saturated:
        # Fall back to the whole-history search rather than under-report.
        return check_linearizable_strict(history)
    return ok, (chk.violation[0] if chk.violation else None)
