"""Per-key linearizability checker (Wing & Gong search with memoization).

CURP's guarantee (§3.4) is linearizability of single-/multi-key NoSQL ops.
Our histories come from the simulator: each entry has invoke/complete times,
the op, and the externalized value.  Ops whose completion was never
externalized (client crashed / gave up / sim ended) are "maybe" ops: a valid
linearization may either include them at any legal point or exclude them.

For single-key histories (our workloads write through SET/INCR and read
through GET) linearizability decomposes per key, which keeps the NP-hard
search tractable; MSET ops are checked by projecting onto each touched key
(sound for our value-unique test workloads, where every SET value is unique).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Any, Dict, FrozenSet, List, Optional, Tuple

from repro.core.types import Op, OpType


@dataclass(frozen=True)
class HEvent:
    idx: int
    invoke: float
    complete: Optional[float]   # None => "maybe" op (no externalized response)
    op_type: OpType
    arg: Any                    # SET value / INCR delta / None
    value: Any                  # externalized result (GET value, INCR result)


def _project(history: List[dict]) -> Dict[Any, List[HEvent]]:
    per_key: Dict[Any, List[HEvent]] = {}
    idx = 0
    for h in history:
        op: Op = h["op"]
        if op.op_type not in (OpType.SET, OpType.GET, OpType.INCR, OpType.MSET,
                              OpType.DEL):
            continue
        complete = h["complete"] if not h.get("failed") else None
        for ki, key in enumerate(op.keys):
            if op.op_type == OpType.MSET:
                arg = op.args[ki]
            elif op.op_type == OpType.SET:
                arg = op.args[0]
            elif op.op_type == OpType.INCR:
                arg = op.args[0] if op.args else 1
            else:
                arg = None
            per_key.setdefault(key, []).append(HEvent(
                idx=idx, invoke=h["invoke"], complete=complete,
                op_type=(OpType.SET if op.op_type == OpType.MSET else op.op_type),
                arg=arg, value=h["value"],
            ))
            idx += 1
    return per_key


def _check_key(events: List[HEvent]) -> bool:
    """Search for a linearization of one key's history."""
    events = sorted(events, key=lambda e: e.invoke)
    n = len(events)
    if n == 0:
        return True
    all_ids = frozenset(range(n))
    ev = {i: events[i] for i in range(n)}

    def apply(state, e: HEvent):
        """Returns next state, or None if e's externalized value contradicts."""
        if e.op_type == OpType.SET:
            return ("V", e.arg)
        if e.op_type == OpType.DEL:
            return ("V", None)
        if e.op_type == OpType.INCR:
            base = state[1] if state[0] == "V" and isinstance(state[1], int) else 0
            new = base + (e.arg if e.arg is not None else 1)
            if e.complete is not None and e.value is not None and e.value != new:
                return None
            return ("V", new)
        if e.op_type == OpType.GET:
            cur = state[1] if state[0] == "V" else None
            if e.complete is not None and e.value != cur:
                return None
            return state
        return state

    import sys
    sys.setrecursionlimit(10000)
    seen = set()

    def search(remaining: FrozenSet[int], state) -> bool:
        if not remaining:
            return True
        key = (remaining, state)
        if key in seen:
            return False
        # Candidates: ops that are minimal in the real-time order, i.e. whose
        # invocation precedes every remaining op's completion.
        min_complete = min(
            (ev[i].complete for i in remaining if ev[i].complete is not None),
            default=float("inf"),
        )
        progressed = False
        for i in remaining:
            e = ev[i]
            if e.invoke > min_complete:
                continue
            nxt = apply(state, e)
            if nxt is not None and search(remaining - {i}, nxt):
                return True
            progressed = True
            # Maybe-ops can also be dropped entirely (they never took effect).
            if e.complete is None and search(remaining - {i}, state):
                return True
        seen.add(key)
        return False

    # Completed ops must all be linearized; maybe-ops may be dropped.  The
    # search above handles dropping inline.
    return search(all_ids, ("V", None))


def check_linearizable(history: List[dict]) -> Tuple[bool, Optional[Any]]:
    """Returns (ok, offending_key)."""
    per_key = _project(history)
    for key, events in per_key.items():
        if not _check_key(events):
            return False, key
    return True, None
