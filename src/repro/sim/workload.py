"""Workload generators: uniform + YCSB-style zipfian key choosers (§5.3),
plus shard-aware skew for the sharded scenarios.

The zipfian chooser follows the YCSB implementation (Gray et al.'s algorithm)
with theta = 0.99 over 1M items — the defaults of YCSB-A (50/50 read/update)
and YCSB-B (95/5).

``ShardSkewedWorkload`` routes through the same KeyRouter as the protocol and
skews load toward one hot shard — the adversarial placement case for
multi-master scaling (a uniform workload spreads ~evenly by hash design).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.client import ClientSession
from repro.core.types import Op


class ZipfianGenerator:
    """YCSB ScrambledZipfian-style generator."""

    _zeta_cache = {}

    def __init__(self, n: int, theta: float = 0.99, seed: int = 0) -> None:
        self.n = n
        self.theta = theta
        self.rng = random.Random(seed)
        key = (n, theta)
        if key not in self._zeta_cache:
            self._zeta_cache[key] = sum(1.0 / (i ** theta) for i in range(1, n + 1))
        self.zetan = self._zeta_cache[key]
        self.zeta2 = 1.0 + 0.5 ** theta
        self.alpha = 1.0 / (1.0 - theta)
        self.eta = (1 - (2.0 / n) ** (1 - theta)) / (1 - self.zeta2 / self.zetan)

    def next_rank(self) -> int:
        u = self.rng.random()
        uz = u * self.zetan
        if uz < 1.0:
            return 0
        if uz < self.zeta2:
            return 1
        return int(self.n * ((self.eta * u - self.eta + 1) ** self.alpha))

    def next_key(self) -> str:
        # Scramble so hot keys are spread over the keyspace (YCSB-style).
        from repro.core.types import splitmix64

        return f"user{splitmix64(self.next_rank()) % (self.n * 8)}"


@dataclass
class OpenLoopWorkload:
    """Open-loop arrival process for the production-armor storms.

    Unlike the closed-loop workloads (one outstanding op per client, arrival
    rate throttled by completions), this models a POPULATION of
    ``n_clients`` independent clients whose aggregate arrivals form a
    nonhomogeneous Poisson process: arrivals keep coming at ``rate_at(t)``
    whether or not earlier ops completed — the regime where overload
    actually happens.  The driver (repro.sim.curp_sim.OpenLoopDriver)
    materializes per-client RIFL sessions lazily, so 10^5–10^6 client ids
    cost memory only for clients that actually issued an op.

    Shape knobs:
      * ``rate_ops_per_us`` — base λ of the Poisson process.
      * ``diurnal_amplitude``/``diurnal_period_us`` — sinusoidal rate ramp
        (λ(t) = λ·(1 + A·sin(2πt/T))), the slow daily swell.
      * ``flash_crowds`` — ((t_start, duration, multiplier), ...): rate
        multiplied during the window, the sudden-hotspot case.
      * heavy-tailed op mix: zipfian keys (``theta``) and Pareto-tailed
        value sizes (``value_alpha``; most writes small, rare huge ones).
      * ``read_fraction``/``incr_fraction`` — op-type mix (the INCR share
        exercises the merge-lattice fast path under skew).
      * ``hot_client_frac`` — fraction of arrivals issued by client 0 (the
        misbehaving-tenant case per-client throttling exists for).
    """
    rate_ops_per_us: float
    n_clients: int = 100_000
    read_fraction: float = 0.0
    incr_fraction: float = 0.0
    n_items: int = 100_000
    theta: float = 0.99
    value_alpha: float = 1.5
    value_min: int = 16
    value_cap: int = 1024
    diurnal_amplitude: float = 0.0
    diurnal_period_us: float = 50_000.0
    flash_crowds: tuple = ()
    hot_client_frac: float = 0.0
    seed: int = 0

    def __post_init__(self) -> None:
        self.zipf = ZipfianGenerator(self.n_items, self.theta, self.seed)
        self.rng = random.Random(self.seed + 17)
        self._max_rate = self.rate_ops_per_us * (1 + self.diurnal_amplitude)
        for _t0, _dur, mult in self.flash_crowds:
            self._max_rate = max(self._max_rate, self.rate_ops_per_us * mult)

    # -- arrival process ----------------------------------------------------
    def rate_at(self, t: float) -> float:
        r = self.rate_ops_per_us
        if self.diurnal_amplitude > 0:
            r *= 1.0 + self.diurnal_amplitude * math.sin(
                2 * math.pi * t / self.diurnal_period_us
            )
        for t0, dur, mult in self.flash_crowds:
            if t0 <= t < t0 + dur:
                r *= mult
        return max(r, 1e-9)

    def next_interarrival(self, t: float) -> float:
        """Thinning (Lewis–Shedler): exact for the piecewise rate function —
        sample candidate arrivals at the peak rate, accept with
        rate(t)/peak.  Returns the gap to the next ACCEPTED arrival."""
        gap = 0.0
        while True:
            gap += self.rng.expovariate(self._max_rate)
            if self.rng.random() * self._max_rate <= self.rate_at(t + gap):
                return gap

    # -- per-arrival op shape ----------------------------------------------
    def next_client(self) -> int:
        if self.hot_client_frac > 0 and self.rng.random() < self.hot_client_frac:
            return 0
        return self.rng.randrange(self.n_clients)

    def _value(self) -> str:
        size = int(self.value_min * self.rng.paretovariate(self.value_alpha))
        return "x" * min(size, self.value_cap)

    def make_op(self, session: ClientSession) -> Op:
        key = self.zipf.next_key()
        u = self.rng.random()
        if u < self.read_fraction:
            return session.op_get(key)
        if u < self.read_fraction + self.incr_fraction:
            return session.op_incr(key, 1)
        return session.op_set(key, self._value())


@dataclass
class YcsbWorkload:
    """op_factory for run_scenario: mixed reads/updates over a zipfian keyspace."""
    read_fraction: float
    n_items: int = 1_000_000
    theta: float = 0.99
    seed: int = 0
    value_size: int = 100

    def __post_init__(self) -> None:
        self.zipf = ZipfianGenerator(self.n_items, self.theta, self.seed)
        self.rng = random.Random(self.seed + 1)
        self._value = "x" * self.value_size

    def __call__(self, session: ClientSession) -> Op:
        key = self.zipf.next_key()
        if self.rng.random() < self.read_fraction:
            return session.op_get(key)
        return session.op_set(key, self._value)


@dataclass
class UniformWriteWorkload:
    """100B random writes over a large keyspace (Figs. 5/6 workload)."""
    n_items: int = 2_000_000
    seed: int = 0
    value_size: int = 100

    def __post_init__(self) -> None:
        self.rng = random.Random(self.seed)
        self._value = "x" * self.value_size

    def __call__(self, session: ClientSession) -> Op:
        key = f"k{self.rng.randrange(self.n_items)}"
        return session.op_set(key, self._value)


@dataclass
class BatchedWorkload:
    """Batches of single-key writes for the batched client path
    (ShardedCluster.update_batch / CurpSessionStore.commit_batch).

    Each call to ``batch`` yields ``batch_size`` ops drawn from a uniform
    keyspace; ``conflict_frac`` of them re-touch a small hot keyset so a
    tunable share of the batch exercises the witness conflict path (the
    adversarial case for set-parallel records).  Ops are created through the
    session's routing constructors, so each op carries an rpc_id from its
    owning shard's RIFL space.
    """
    batch_size: int = 64
    n_items: int = 2_000_000
    conflict_frac: float = 0.0
    hot_items: int = 8
    seed: int = 0
    value_size: int = 100

    def __post_init__(self) -> None:
        self.rng = random.Random(self.seed)
        self._value = "x" * self.value_size

    def batch(self, session) -> list:
        ops = []
        for _ in range(self.batch_size):
            if self.conflict_frac > 0 and self.rng.random() < self.conflict_frac:
                key = f"hot{self.rng.randrange(self.hot_items)}"
            else:
                key = f"k{self.rng.randrange(self.n_items)}"
            ops.append(session.op_set(key, self._value))
        return ops


@dataclass
class HotKeyWorkload:
    """Contended-counter workload for the CRDT-CURP merge lattice: ``skew``
    is the probability an op targets the ONE hot key (skew -> 1.0 is the
    all-ops-one-key worst case), the rest spread over a cold keyspace.

    ``kind`` picks the op type on the hot path: ``"INCR"`` ops commute under
    the merge lattice (witnesses keep accepting, the fast path survives the
    skew), ``"SET"`` ops conflict pairwise (classic CURP collapses to the
    sync path).  SADD/APPEND/MAX are also accepted for the merge-class
    sweep scenarios.
    """
    skew: float = 1.0
    kind: str = "INCR"
    hot_key: str = "hot"
    n_items: int = 100_000
    seed: int = 0
    value_size: int = 16

    def __post_init__(self) -> None:
        self.rng = random.Random(self.seed)
        self._value = "x" * self.value_size
        self._seq = 0

    def __call__(self, session: ClientSession) -> Op:
        self._seq += 1
        if self.rng.random() < self.skew:
            key = self.hot_key
        else:
            key = f"c{self.rng.randrange(self.n_items)}"
        if self.kind == "INCR":
            return session.op_incr(key, 1)
        if self.kind == "SADD":
            return session.op_sadd(key, f"m{self._seq}")
        if self.kind == "APPEND":
            return session.op_append(key, f"a{self._seq}")
        if self.kind == "MAX":
            return session.op_max(key, self._seq)
        return session.op_set(key, self._value)


@dataclass
class TxnWorkload:
    """Mini-transaction generator for the txn subsystem (repro.core.txn).

    Each ``next_txn`` yields a (writes, reads) pair whose keys are drawn
    from per-shard pools (pre-bucketed by the protocol's own KeyRouter, like
    ShardSkewedWorkload): with probability ``cross_shard_frac`` the write
    set spans ``span_shards`` distinct shards (a true 2PC), otherwise every
    key stays on one shard (the 1-RTT short-circuit).  ``hot_frac`` of keys
    come from a tiny hot pool, so contention — and with it intent-lock
    conflicts and transaction aborts — is tunable.
    """
    n_shards: int
    cross_shard_frac: float = 0.5
    span_shards: int = 2
    keys_per_txn: int = 2
    reads_per_txn: int = 0
    n_items: int = 10_000
    hot_frac: float = 0.0
    hot_items: int = 4
    seed: int = 0
    value_size: int = 32

    def __post_init__(self) -> None:
        from repro.core.shard import KeyRouter

        router = KeyRouter(self.n_shards)
        self.rng = random.Random(self.seed)
        self._value = "x" * self.value_size
        self._pools: list = [[] for _ in range(self.n_shards)]
        for i in range(self.n_items):
            key = f"t{i}"
            self._pools[router.shard_of(key)].append(key)
        assert all(self._pools), "n_items too small to cover every shard"
        # Hot pool: the first hot_items keys of every shard's pool.
        self._hot = [pool[:self.hot_items] for pool in self._pools]
        self._seq = 0

    def _key(self, shard: int) -> str:
        if self.hot_frac > 0 and self.rng.random() < self.hot_frac:
            pool = self._hot[shard]
        else:
            pool = self._pools[shard]
        return pool[self.rng.randrange(len(pool))]

    def next_txn(self):
        """Returns (writes, reads): write values are unique per txn, so
        torn writes are observable by the strict checker."""
        self._seq += 1
        if self.n_shards > 1 and self.rng.random() < self.cross_shard_frac:
            shards = self.rng.sample(
                range(self.n_shards), min(self.span_shards, self.n_shards)
            )
        else:
            shards = [self.rng.randrange(self.n_shards)]
        keys: list = []
        seen = set()
        for i in range(self.keys_per_txn):
            k = self._key(shards[i % len(shards)])
            if k not in seen:
                seen.add(k)
                keys.append(k)
        writes = [(k, f"v{self._seq}_{k}_{self._value[:4]}") for k in keys]
        reads = []
        for i in range(self.reads_per_txn):
            k = self._key(shards[i % len(shards)])
            if k not in seen:
                seen.add(k)
                reads.append(k)
        return writes, reads


@dataclass
class ShardSkewedWorkload:
    """Writes whose *shard* distribution is skewed: ``hot_frac`` of ops land
    on ``hot_shard``, the rest spread uniformly over the other shards.

    Keys are pre-bucketed by the protocol's own KeyRouter, so the skew is
    exact with respect to actual placement (not an approximation of the
    hash).  With hot_frac = 1/n_shards this degenerates to ~uniform.
    """
    n_shards: int
    hot_frac: float = 0.8
    hot_shard: int = 0
    n_items: int = 20_000
    seed: int = 0
    value_size: int = 100
    read_fraction: float = 0.0

    def __post_init__(self) -> None:
        from repro.core.shard import KeyRouter

        router = KeyRouter(self.n_shards)
        self.rng = random.Random(self.seed)
        self._value = "x" * self.value_size
        self._pools: list = [[] for _ in range(self.n_shards)]
        for i in range(self.n_items):
            key = f"k{i}"
            self._pools[router.shard_of(key)].append(key)
        assert all(self._pools), "n_items too small to cover every shard"
        self._cold = [s for s in range(self.n_shards) if s != self.hot_shard]

    def _next_key(self) -> str:
        if self.n_shards == 1 or self.rng.random() < self.hot_frac:
            shard = self.hot_shard
        else:
            shard = self.rng.choice(self._cold)
        pool = self._pools[shard]
        return pool[self.rng.randrange(len(pool))]

    def __call__(self, session: ClientSession) -> Op:
        key = self._next_key()
        if self.read_fraction > 0 and self.rng.random() < self.read_fraction:
            return session.op_get(key)
        return session.op_set(key, self._value)
