"""Workload generators: uniform + YCSB-style zipfian key choosers (§5.3).

The zipfian chooser follows the YCSB implementation (Gray et al.'s algorithm)
with theta = 0.99 over 1M items — the defaults of YCSB-A (50/50 read/update)
and YCSB-B (95/5).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.client import ClientSession
from repro.core.types import Op


class ZipfianGenerator:
    """YCSB ScrambledZipfian-style generator."""

    _zeta_cache = {}

    def __init__(self, n: int, theta: float = 0.99, seed: int = 0) -> None:
        self.n = n
        self.theta = theta
        self.rng = random.Random(seed)
        key = (n, theta)
        if key not in self._zeta_cache:
            self._zeta_cache[key] = sum(1.0 / (i ** theta) for i in range(1, n + 1))
        self.zetan = self._zeta_cache[key]
        self.zeta2 = 1.0 + 0.5 ** theta
        self.alpha = 1.0 / (1.0 - theta)
        self.eta = (1 - (2.0 / n) ** (1 - theta)) / (1 - self.zeta2 / self.zetan)

    def next_rank(self) -> int:
        u = self.rng.random()
        uz = u * self.zetan
        if uz < 1.0:
            return 0
        if uz < self.zeta2:
            return 1
        return int(self.n * ((self.eta * u - self.eta + 1) ** self.alpha))

    def next_key(self) -> str:
        # Scramble so hot keys are spread over the keyspace (YCSB-style).
        from repro.core.types import splitmix64

        return f"user{splitmix64(self.next_rank()) % (self.n * 8)}"


@dataclass
class YcsbWorkload:
    """op_factory for run_scenario: mixed reads/updates over a zipfian keyspace."""
    read_fraction: float
    n_items: int = 1_000_000
    theta: float = 0.99
    seed: int = 0
    value_size: int = 100

    def __post_init__(self) -> None:
        self.zipf = ZipfianGenerator(self.n_items, self.theta, self.seed)
        self.rng = random.Random(self.seed + 1)
        self._value = "x" * self.value_size

    def __call__(self, session: ClientSession) -> Op:
        key = self.zipf.next_key()
        if self.rng.random() < self.read_fraction:
            return session.op_get(key)
        return session.op_set(key, self._value)


@dataclass
class UniformWriteWorkload:
    """100B random writes over a large keyspace (Figs. 5/6 workload)."""
    n_items: int = 2_000_000
    seed: int = 0
    value_size: int = 100

    def __post_init__(self) -> None:
        self.rng = random.Random(self.seed)
        self._value = "x" * self.value_size

    def __call__(self, session: ClientSession) -> Op:
        key = f"k{self.rng.randrange(self.n_items)}"
        return session.op_set(key, self._value)
