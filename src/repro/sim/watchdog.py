"""Protocol watchdog: always-on invariant monitors over the black-box journal.

CURP's correctness argument (paper §3.4, §B) rests on a handful of
invariants that the implementation is supposed to maintain at every step:

* **acked-write durability** (§3.2.2/§B.1) — a 1-RTT (fast-path) ack means
  the op is recorded on all ``f`` witnesses, or already backup-synced;
* **epoch / witness-list-version monotonicity** (§3.6) — every recovery or
  migration fence strictly advances the shard's epoch and never regresses
  its witness list version, and no master executes under a regressed epoch;
* **single owner per slot** (§3.6 reconfiguration) — between a slot's
  freeze and its handover commit, NO client op executes on that slot;
* **RIFL exactly-once** (§4.8) — a master's applied ack frontier per client
  never regresses, and no op below the frontier re-executes;
* **intent liveness** (Sinfonia-style 2PC, repro.core.txn) — a prepared
  transaction intent is decided (commit/abort) within a bounded horizon;
* **fast-path commutativity** (§2, §3.2.2) — an op acked FAST commutes
  (per the repro.core.merge lattice) with every op in the master's
  unsynced window at execution time;
* **linearizability** (§3.4) — the external history has a strict (whole-op,
  multi-key-atomic) linearization; checked online by the windowed
  incremental Wing & Gong checker (repro.sim.linearizability).

The protocol objects emit cheap events into a bounded ring journal
(repro.core.journal); this module subscribes a monitor dispatch to that
journal so every invariant is evaluated incrementally INSIDE the
discrete-event loop — a breach is caught within events of the violation,
not at teardown.  On the first breach the watchdog seals a **black box**:
the last-N journal events, a metrics-registry snapshot, a drained
flight-recorder trace slice, and the scenario seed/kwargs needed for
``replay()`` to re-run the simulation deterministically to the same breach.

``ChaosConfig`` is the watchdog's validation layer: seven one-shot protocol
mutations (skip a migration fence, ack before any witness records, leak a
txn intent, ...) wired into the sim actors, each violating EXACTLY ONE
monitor's invariant — benchmarks/fig_watchdog.py asserts every monitor
fires under its switch (non-vacuous) and none fires on clean runs.
"""
from __future__ import annotations

import copy
import json
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.journal import Event, EventJournal
from repro.core.merge import conflicts

# Chaos switch -> the single monitor that must catch it (the contract
# fig_watchdog and tests/test_watchdog.py assert, switch by switch).
CHAOS_MONITOR = {
    "early_ack": "durability",
    "skip_epoch_bump": "epoch",
    "skip_fence": "single_owner",
    "rifl_rollback": "rifl",
    "leak_intent": "intent",
    "force_commute": "commutativity",
    "corrupt_value": "linearizability",
}

_MIGRATE_OPS = ("MIGRATE_IN", "MIGRATE_OUT")
_TXN_DECIDE_OPS = ("TXN_COMMIT", "TXN_ABORT")


def _json_safe(v):
    """Best-effort JSON projection: live objects (workloads, tracers) in
    the replay coordinates become their repr in the sealed black box."""
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    try:
        json.dumps(v)
        return v
    except (TypeError, ValueError):
        return repr(v)


@dataclass
class ChaosConfig:
    """One-shot protocol mutation switches (fault injection FOR the
    watchdog, not for the protocol under test: each switch breaks exactly
    one paper invariant so the matching monitor can prove it watches).

    Sites live in repro.sim.curp_sim (timed transport) except
    ``leak_intent``, which the instant-transport harness below injects via
    the 2PC crash hook.  Every switch fires at most once per run
    (``fire``/``fired`` latches), so a run's journal contains exactly one
    seeded violation — and ``clone()`` resets the latches so a replay
    re-fires them at the same protocol step.
    """

    early_ack: bool = False        # ack a fast-path op with 0 witness records
    skip_fence: bool = False       # migrate a slot without freezing it
    leak_intent: bool = False      # crash the 2PC coordinator mid-decide
    skip_epoch_bump: bool = False  # recover a master without the epoch fence
    force_commute: bool = False    # conflicting op rides the fast path
    rifl_rollback: bool = False    # regress one client's applied ack frontier
    corrupt_value: bool = False    # return a read value nobody ever wrote
    _latched: set = field(default_factory=set, repr=False)
    # Set by Watchdog.__init__: lets ``fire`` stamp the journal seq of each
    # injection, so detection latency is measurable in journal events.
    _journal: Any = field(default=None, repr=False)
    _fire_seq: dict = field(default_factory=dict, repr=False)

    _SWITCHES = tuple(CHAOS_MONITOR)

    def any(self) -> bool:
        return any(getattr(self, s) for s in self._SWITCHES)

    def active(self) -> Tuple[str, ...]:
        return tuple(s for s in self._SWITCHES if getattr(self, s))

    def fired(self, name: str) -> bool:
        return name in self._latched

    def fire(self, name: str) -> None:
        self._latched.add(name)
        if self._journal is not None and name not in self._fire_seq:
            self._fire_seq[name] = self._journal.seq

    def clone(self) -> "ChaosConfig":
        """Same switches, fresh latches — what ``replay`` runs with."""
        return ChaosConfig(**{s: getattr(self, s) for s in self._SWITCHES})


@dataclass(frozen=True)
class Breach:
    """One detected invariant violation.  ``key()`` is the deterministic
    identity two runs of the same seed must agree on bit-for-bit."""

    monitor: str
    seq: int            # journal sequence number of the triggering event
    t: float            # journal clock at detection
    rpc: Any            # RIFL id involved, when one applies
    reason: str

    def key(self) -> Tuple:
        return (self.monitor, self.seq, self.t, self.rpc, self.reason)

    def to_jsonable(self) -> Dict[str, Any]:
        return {
            "monitor": self.monitor, "seq": self.seq, "t": self.t,
            "rpc": list(self.rpc) if isinstance(self.rpc, tuple) else self.rpc,
            "reason": self.reason,
        }


class Watchdog:
    """Always-on protocol auditor: owns the event journal, runs the
    incremental invariant monitors as a journal subscriber, feeds the
    windowed linearizability checker from the client-side hooks, and seals
    a black-box dump on the first breach.

    Attach with ``attach(sim, cluster, f=..., mode=...)`` (timed transport)
    or ``attach_cluster(cluster)`` (instant ShardedCluster).  The per-event
    cost is a dict update or two per monitor — fig_watchdog asserts the
    watched overload ramp keeps >= 95% of the unwatched goodput.
    """

    def __init__(self, chaos: Optional[ChaosConfig] = None,
                 capacity: int = 8192, intent_bound: int = 5000,
                 maybe_horizon: Optional[float] = 50_000.0,
                 flush_every: int = 256, blackbox_last_n: int = 512,
                 check_linearizability: bool = True,
                 state_cap: int = 8192) -> None:
        from .linearizability import WindowedChecker

        self.chaos = chaos if chaos is not None else ChaosConfig()
        self.journal = EventJournal(capacity=capacity)
        self.journal.subscribe(self._on_event)
        self.chaos._journal = self.journal
        self.intent_bound = intent_bound
        self.blackbox_last_n = blackbox_last_n
        self.state_cap = state_cap
        self._ctor = dict(
            capacity=capacity, intent_bound=intent_bound,
            maybe_horizon=maybe_horizon, flush_every=flush_every,
            blackbox_last_n=blackbox_last_n,
            check_linearizability=check_linearizability,
            state_cap=state_cap,
        )
        self.checker = WindowedChecker(
            flush_every=flush_every, maybe_horizon=maybe_horizon,
        ) if check_linearizability else None

        self.breaches: List[Breach] = []
        self.blackbox: Optional[Dict[str, Any]] = None
        self.events_seen = 0
        self.finalized = False
        # Scenario identity for deterministic replay (run_watched_scenario
        # fills these; None when the watchdog is attached by hand).
        self.run_args: Optional[Dict[str, Any]] = None

        self.sim = None
        self.router = None
        self._f = 0
        self._mode = "curp"
        self._commut_on = False

        # -- monitor state (all bounded) -----------------------------------
        # Per-master state is keyed on the journal ACTOR string (unique per
        # shard AND per master incarnation by construction — attach prefixes
        # it with the shard index), never on the raw master id: SimClusters
        # allocate ids from their own counters, so two shards' masters can
        # share a master_id.
        # durability: rpc -> set of witness actors that accepted it
        self._accepts: "OrderedDict[Any, set]" = OrderedDict()
        # durability: rpc -> (actor, 1-based log index) of its execution
        self._exec_at: "OrderedDict[Any, Tuple[str, int]]" = OrderedDict()
        self._synced_through: Dict[str, int] = {}    # actor -> synced index
        # epoch: shard -> (epoch, wlv) at the last fence
        self._shard_cfg: Dict[int, Tuple[int, int]] = {}
        self._mid_epoch: Dict[str, int] = {}         # actor -> last exec epoch
        self._mid_shard: Dict[str, int] = {}         # actor -> shard index
        # single-owner: slot -> freeze-event seq (open handover windows)
        self._moving: Dict[int, int] = {}
        # rifl: (actor, client) -> last journaled ack frontier
        self._frontier: Dict[Tuple[str, int], int] = {}
        # intent: (txn_id, actor) -> prepare-event seq, insertion-ordered
        self._intents: "OrderedDict[Tuple[Any, str], int]" = OrderedDict()
        self._intents_flagged: set = set()
        # commutativity: per master actor, the unsynced window mirror —
        #   actor -> OrderedDict{index -> pairs}  (insertion == index order)
        #   actor -> {key_hash -> {cls -> refcount}}
        self._win: Dict[str, "OrderedDict[int, tuple]"] = {}
        self._win_kh: Dict[str, Dict[int, Dict[int, int]]] = {}

    # ------------------------------------------------------------ attachment
    def attach(self, sim, cluster, f: int = 0, mode: str = "curp") -> None:
        """Wire the watchdog into a timed-transport run: install self on the
        Sim (actors null-check ``sim.watchdog``), point the journal clock at
        the sim clock, hand the journal to every master/witness core, and
        emit one baseline ``init`` fence per shard (the epoch monitor's
        first comparison point)."""
        self.sim = sim
        sim.watchdog = self
        self.journal.clock = lambda: sim.now
        self._f = f
        self._mode = mode
        self._commut_on = mode == "curp"
        shards = getattr(cluster, "shards", None)
        if shards is not None and hasattr(cluster, "router"):
            self.router = cluster.router
            for i, s in enumerate(shards):
                self._wire_sim_shard(s, i)
        else:
            self.router = None
            self._wire_sim_shard(cluster, 0)

    def _wire_sim_shard(self, s, shard_idx: int) -> None:
        # Actor names must be globally unique: each SimCluster allocates
        # master ids from its OWN counter, so two shards' masters share a
        # master_id and the per-master monitor state would mix their
        # windows without the shard prefix.
        s.wd_shard = shard_idx
        core = s.master_node.core
        core.journal = self.journal
        core.journal_actor = f"s{shard_idx}m{core.master_id}"
        self._mid_shard[core.journal_actor] = shard_idx
        for j, w in enumerate(s.witness_cores):
            w.journal = self.journal
            w.journal_actor = f"s{shard_idx}w{j}"
        self.journal.emit(
            "fence", actor=core.journal_actor, shard=shard_idx,
            epoch=s.epoch, wlv=s.wlv, mid=core.master_id, reason="init",
        )

    def attach_cluster(self, cluster) -> None:
        """Wire into an instant-transport ShardedCluster (repro.core.shard):
        same journal, seq-stamped clock, migration/txn events via the
        MigrationManager's journal slot."""
        self._commut_on = True
        self.router = cluster.router
        cluster.migration.journal = self.journal
        for g in cluster.shards:
            if g.retired:
                continue
            g.master.journal = self.journal
            g.master.journal_actor = f"s{g.shard_id}m{g.master.master_id}"
            self._mid_shard[g.master.journal_actor] = g.shard_id
            for j, w in enumerate(g.witnesses):
                w.journal = self.journal
                w.journal_actor = f"s{g.shard_id}w{j}"
            self.journal.emit(
                "fence", actor=g.master.journal_actor, shard=g.shard_id,
                epoch=g.master.epoch, wlv=g.master.witness_list_version,
                mid=g.master.master_id, reason="init",
            )

    # ------------------------------------------------- client-side feed hooks
    def op_invoked(self, rpc_id, t: float) -> None:
        if self.checker is not None:
            self.checker.invoke(rpc_id, t)

    def op_completed(self, entry: Dict[str, Any]) -> None:
        if self.checker is not None:
            self.checker.complete(entry)
            self._check_linearizability()

    def op_failed(self, entry: Dict[str, Any]) -> None:
        """A give-up / crash casualty: a 'maybe' op for the checker."""
        if self.checker is not None:
            self.checker.complete(entry)
            self._check_linearizability()

    def _check_linearizability(self) -> None:
        chk = self.checker
        if chk is not None and chk.violation is not None \
                and not self._has("linearizability"):
            key, detail = chk.violation
            self._breach(
                "linearizability",
                f"no valid linearization (key={key!r}, {detail})",
                rpc=None, ev=None,
            )

    # ---------------------------------------------------------------- dispatch
    def _on_event(self, ev: Event) -> None:
        self.events_seen += 1
        kind = ev.kind
        if kind == "execute":
            self._m_execute(ev)
        elif kind == "record":
            self._m_record(ev)
        elif kind == "sync":
            self._m_sync(ev)
        elif kind == "ack":
            self._m_ack(ev)
        elif kind == "fence":
            self._m_fence(ev)
        elif kind == "freeze":
            self._m_freeze(ev)
        elif kind == "handover":
            self._m_handover(ev)
        # intent liveness is clocked by EVERY event: the bound is "decided
        # within N journal events of the prepare", whatever those events are.
        self._m_intent_tick(ev)

    # ------------------------------------------------------------- monitors
    def _m_execute(self, ev: Event) -> None:
        a = ev.args
        mid = ev.actor           # unique per master incarnation, unlike a["mid"]
        op_name = a["op"]
        txn = a.get("txn")

        # epoch monotonicity, per master: an execute under an epoch lower
        # than one this master already journaled means time ran backwards.
        ep = a["epoch"]
        prev_ep = self._mid_epoch.get(mid)
        if prev_ep is not None and ep < prev_ep:
            self._breach("epoch",
                         f"master {mid} executed under epoch {ep} after "
                         f"epoch {prev_ep}", rpc=ev.rpc, ev=ev)
        self._mid_epoch[mid] = max(ep, prev_ep if prev_ep is not None else ep)

        # single owner per slot (§3.6): no client op may execute on a slot
        # between its freeze and its handover commit.  Migration transfer
        # legs and txn decide legs are the protocol's OWN traffic through
        # the window and are exempt.
        if self._moving and self.router is not None and txn is None \
                and op_name not in _MIGRATE_OPS:
            for kh, _cls in a["pairs"]:
                slot = self.router.slot_of_hash(kh)
                if slot in self._moving:
                    self._breach(
                        "single_owner",
                        f"op executed on slot {slot} mid-handover "
                        f"(frozen at event #{self._moving[slot]})",
                        rpc=ev.rpc, ev=ev)
                    break

        # RIFL exactly-once (§4.8): the applied ack frontier per (master,
        # client) never regresses, and no plain client op re-executes below
        # it (a dup the RIFL table should have absorbed).
        if ev.rpc is not None:
            client, seq = ev.rpc
            fr = a["frontier"]
            prev_fr = self._frontier.get((mid, client))
            if prev_fr is not None and fr < prev_fr:
                self._breach(
                    "rifl",
                    f"ack frontier of client {client} at master {mid} "
                    f"regressed {prev_fr} -> {fr}", rpc=ev.rpc, ev=ev)
            self._frontier[(mid, client)] = max(
                fr, prev_fr if prev_fr is not None else fr)
            if a["checked"] and txn is None and op_name not in _MIGRATE_OPS \
                    and seq < fr:
                self._breach(
                    "rifl",
                    f"op seq {seq} re-executed below ack frontier {fr}",
                    rpc=ev.rpc, ev=ev)

        # intent liveness: prepares install, decides retire.
        if txn is not None:
            if op_name == "TXN_PREPARE":
                self._intents.setdefault((txn, mid), ev.seq)
            elif op_name in _TXN_DECIDE_OPS:
                self._intents.pop((txn, mid), None)

        # fast => commutes (§2/§3.2.2): mirror the master's unsynced window
        # from the journal and re-derive the conflict verdict from the
        # merge lattice.  ``checked=False`` verdicts (MIGRATE_IN, txn
        # decide legs) reply FAST by design without a window check.
        if self._commut_on and a["checked"]:
            win_kh = self._win_kh.setdefault(mid, {})
            if a["verdict"] == "fast":
                hit = None
                for kh, cls in a["pairs"]:
                    for other_cls, n in win_kh.get(kh, {}).items():
                        if n > 0 and conflicts(cls, other_cls):
                            hit = (kh, cls, other_cls)
                            break
                    if hit:
                        break
                if hit:
                    self._breach(
                        "commutativity",
                        f"FAST ack for op conflicting (cls {hit[1]} vs "
                        f"{hit[2]}) with an unsynced op on key hash "
                        f"{hit[0]:#x}", rpc=ev.rpc, ev=ev)
            win = self._win.setdefault(mid, OrderedDict())
            win[a["index"]] = a["pairs"]
            for kh, cls in a["pairs"]:
                per = win_kh.setdefault(kh, {})
                per[cls] = per.get(cls, 0) + 1
            if len(win) > self.state_cap:   # safety valve, never hit in curp
                self._retire_window(mid, next(iter(win)))

        # durability bookkeeping: where (and at what log index) the op ran.
        if ev.rpc is not None:
            self._exec_at[ev.rpc] = (mid, a["index"])
            self._cap(self._exec_at)

    def _m_record(self, ev: Event) -> None:
        if ev.args["status"] == "accepted":
            acc = self._accepts.get(ev.rpc)
            if acc is None:
                acc = self._accepts[ev.rpc] = set()
                self._cap(self._accepts)
            acc.add(ev.actor)

    def _m_sync(self, ev: Event) -> None:
        mid = ev.actor
        through = ev.args["through"]
        self._synced_through[mid] = max(
            through, self._synced_through.get(mid, 0))
        # retire the commutativity mirror's entries now backup-durable
        win = self._win.get(mid)
        if win:
            while win and next(iter(win)) <= through:
                self._retire_window(mid, next(iter(win)))

    def _retire_window(self, mid: int, index: int) -> None:
        pairs = self._win[mid].pop(index)
        win_kh = self._win_kh[mid]
        for kh, cls in pairs:
            per = win_kh.get(kh)
            if per is not None:
                per[cls] -= 1
                if per[cls] <= 0:
                    del per[cls]
                if not per:
                    del win_kh[kh]

    def _m_ack(self, ev: Event) -> None:
        """Acked-write durability (§3.2.2/§B.1): a 1-RTT ack requires the
        op recorded at all f witnesses, or already covered by a backup
        sync.  Reads and slow-path (>=2 RTT) acks carry no fast-path
        durability claim."""
        if ev.args["rtts"] != 1 or self._mode != "curp" or self._f <= 0:
            return
        where = self._exec_at.pop(ev.rpc, None)
        accepts = self._accepts.pop(ev.rpc, None)
        if where is None:
            return   # read (no execute event): nothing to prove
        mid, index = where
        n_acc = len(accepts) if accepts else 0
        if n_acc >= self._f:
            return
        if index <= self._synced_through.get(mid, 0):
            return   # backup-synced before the ack: durable without witnesses
        self._breach(
            "durability",
            f"1-RTT ack with {n_acc}/{self._f} witness records and log "
            f"index {index} > synced_through "
            f"{self._synced_through.get(mid, 0)}", rpc=ev.rpc, ev=ev)

    def _m_fence(self, ev: Event) -> None:
        a = ev.args
        shard, epoch, wlv = a["shard"], a["epoch"], a["wlv"]
        self._mid_shard[ev.actor] = shard
        prev = self._shard_cfg.get(shard)
        if a["reason"] != "init" and prev is not None:
            pe, pw = prev
            if epoch <= pe:
                self._breach(
                    "epoch",
                    f"{a['reason']} fence on shard {shard} did not advance "
                    f"the epoch ({pe} -> {epoch})", rpc=a.get("mid"), ev=ev)
            if wlv < pw:
                self._breach(
                    "epoch",
                    f"{a['reason']} fence on shard {shard} regressed the "
                    f"witness list version ({pw} -> {wlv})",
                    rpc=a.get("mid"), ev=ev)
        self._shard_cfg[shard] = (max(epoch, prev[0] if prev else epoch),
                                  max(wlv, prev[1] if prev else wlv))

    def _m_freeze(self, ev: Event) -> None:
        for slot in self._ev_slots(ev):
            self._moving[slot] = ev.seq

    def _m_handover(self, ev: Event) -> None:
        for slot in self._ev_slots(ev):
            self._moving.pop(slot, None)

    @staticmethod
    def _ev_slots(ev: Event):
        if "slots" in ev.args:
            return tuple(ev.args["slots"])
        return (ev.args["slot"],)

    def _m_intent_tick(self, ev: Event) -> None:
        """Intent liveness: the OLDEST undecided prepare must be decided
        within ``intent_bound`` journal events (a leaked intent wedges its
        keys forever — reads and writes under it draw TXN_PENDING)."""
        if not self._intents:
            return
        (txn, mid), seq0 = next(iter(self._intents.items()))
        if ev.seq - seq0 > self.intent_bound \
                and (txn, mid) not in self._intents_flagged:
            self._intents_flagged.add((txn, mid))
            self._breach(
                "intent",
                f"txn {txn!r} intent at master {mid} undecided after "
                f"{ev.seq - seq0} events (bound {self.intent_bound})",
                rpc=txn if isinstance(txn, tuple) else None, ev=ev)

    def _cap(self, od: OrderedDict) -> None:
        """Bound a per-rpc state dict: evict oldest entries (ops that never
        acked — give-ups, crash casualties — would otherwise accumulate)."""
        while len(od) > self.state_cap:
            od.popitem(last=False)

    # --------------------------------------------------------------- breaches
    def _has(self, monitor: str) -> bool:
        return any(b.monitor == monitor for b in self.breaches)

    def fired_monitors(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for b in self.breaches:
            if b.monitor not in seen:
                seen.append(b.monitor)
        return tuple(seen)

    def _breach(self, monitor: str, reason: str, rpc, ev: Optional[Event]) -> None:
        if ev is not None:
            seq, t = ev.seq, ev.t
        else:
            seq = self.journal.seq
            t = (self.journal.clock() if self.journal.clock is not None
                 else float(seq))
        b = Breach(monitor=monitor, seq=seq, t=t, rpc=rpc, reason=reason)
        self.breaches.append(b)
        if self.blackbox is None:
            self.blackbox = self._dump(b)

    def _dump(self, breach: Breach) -> Dict[str, Any]:
        """Seal the black box: last-N journal events, metrics snapshot,
        drained trace slice, and the replay coordinates.  Everything is
        plain JSON-able data — this is what an operator (or ``replay``)
        gets when the flight recorder is pulled after a crash."""
        from repro.core.telemetry import get_registry

        box: Dict[str, Any] = {
            "breach": breach.to_jsonable(),
            "journal": self.journal.to_jsonable(last_n=self.blackbox_last_n),
            "journal_dropped": self.journal.dropped,
            "journal_seq": self.journal.seq,
            "metrics": get_registry().snapshot(),
            "chaos": {s: getattr(self.chaos, s)
                      for s in self.chaos._SWITCHES},
            "run_args": _json_safe(self.run_args),
        }
        tracer = getattr(self.sim, "tracer", None) if self.sim else None
        if tracer is not None:
            now = self.sim.now if self.sim is not None else breach.t
            box["trace_spans_sealed"] = tracer.drain(now, status="breach-dump")
            box["trace"] = tracer.export_chrome()
        return box

    # --------------------------------------------------------------- teardown
    def finalize(self, now: float) -> "Watchdog":
        """End-of-run sweep: flush the windowed checker's tail (teardown
        maybe-ops included) and record its verdict.  Idempotent."""
        if self.finalized:
            return self
        self.finalized = True
        if self.checker is not None:
            self.checker.finish()
            self._check_linearizability()
        return self

    @property
    def ok(self) -> bool:
        return not self.breaches

    def report(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "breaches": [b.to_jsonable() for b in self.breaches],
            "monitors_fired": list(self.fired_monitors()),
            "events_seen": self.events_seen,
            "journal_dropped": self.journal.dropped,
            "checker": self.checker.stats() if self.checker else None,
            "chaos_active": list(self.chaos.active()),
        }


# ---------------------------------------------------------------------------
# Watched scenario runner + deterministic replay
# ---------------------------------------------------------------------------
def run_watched_scenario(scenario: str = "openloop",
                         chaos: Optional[ChaosConfig] = None,
                         watchdog_kwargs: Optional[Dict[str, Any]] = None,
                         **kwargs):
    """Run one sim scenario with a fresh watchdog attached.

    ``scenario`` selects the harness: ``"openloop"``
    (run_openloop_scenario), ``"closed"`` (run_scenario) or ``"sharded"``
    (run_sharded_scenario); ``kwargs`` pass through unchanged.  Returns
    ``(result, watchdog)``; the watchdog records the scenario coordinates,
    so ``replay(watchdog)`` re-runs it deterministically — same seed, same
    chaos switches with fresh latches — and must reproduce the same breach
    sequence bit-for-bit (Breach.key()).
    """
    from . import curp_sim

    runners = {
        "openloop": curp_sim.run_openloop_scenario,
        "closed": curp_sim.run_scenario,
        "sharded": curp_sim.run_sharded_scenario,
    }
    if scenario not in runners:
        raise ValueError(f"unknown scenario {scenario!r}; "
                         f"one of {sorted(runners)}")
    wd = Watchdog(chaos=chaos.clone() if chaos is not None else None,
                  **(watchdog_kwargs or {}))
    # Snapshot the kwargs BEFORE the run: workload objects carry RNG state
    # the run mutates, so replaying with the live objects would diverge.
    wd.run_args = {
        "scenario": scenario,
        "kwargs": copy.deepcopy(kwargs),
        "chaos": {s: getattr(wd.chaos, s) for s in wd.chaos._SWITCHES},
        "watchdog_kwargs": dict(watchdog_kwargs or {}),
    }
    result = runners[scenario](watchdog=wd, **kwargs)
    return result, wd


def replay(wd: Watchdog):
    """Deterministically re-run a watched scenario from its black-box
    coordinates.  Returns ``(watchdog2, identical)`` where ``identical``
    means the replay produced the exact same breach sequence (monitor,
    event seq, sim time, RIFL id, reason) as the original — the property
    that makes a watchdog report debuggable offline."""
    if wd.run_args is None:
        raise ValueError("watchdog was not started by run_watched_scenario; "
                         "nothing to replay")
    ra = wd.run_args
    chaos = ChaosConfig(**ra["chaos"])
    _result, wd2 = run_watched_scenario(
        scenario=ra["scenario"], chaos=chaos,
        watchdog_kwargs=ra["watchdog_kwargs"],
        **copy.deepcopy(ra["kwargs"]),
    )
    identical = [b.key() for b in wd2.breaches] == \
        [b.key() for b in wd.breaches]
    return wd2, identical


# ---------------------------------------------------------------------------
# Intent-leak harness (instant transport: the 2PC machinery lives there)
# ---------------------------------------------------------------------------
def run_intent_leak_scenario(chaos: Optional[ChaosConfig] = None,
                             n_shards: int = 2, f: int = 1,
                             intent_bound: int = 300,
                             pump_ops: Optional[int] = None,
                             seed: int = 0):
    """Cross-shard 2PC against an instant ShardedCluster with the watchdog
    attached.  With ``chaos.leak_intent`` the coordinator is crashed after
    sending the FIRST decide leg (second leg's intent never decided) and —
    unlike the clean crash suites — nobody runs recovery resolution; the
    harness then pumps unrelated traffic until the intent monitor's event
    bound is exceeded.  Clean runs decide every intent and pump the same
    traffic: zero breaches expected.  Returns the watchdog."""
    from repro.core.shard import ShardedCluster
    from repro.core.txn import STAGE_DECIDE, CoordinatorCrash

    chaos = chaos.clone() if chaos is not None else ChaosConfig()
    cluster = ShardedCluster(n_shards=n_shards, f=f, seed=seed)
    wd = Watchdog(chaos=chaos, intent_bound=intent_bound)
    wd.attach_cluster(cluster)
    session = cluster.new_client()

    # two keys on different shards => a genuine 2-leg 2PC
    k0 = "leak-a0"
    k1 = next(f"leak-b{i}" for i in range(256)
              if cluster.shard_of(f"leak-b{i}") != cluster.shard_of(k0))

    def crash_hook(stage, shard_id, idx):
        if stage == STAGE_DECIDE and idx == 1 \
                and not chaos.fired("leak_intent"):
            chaos.fire("leak_intent")
            raise CoordinatorCrash(
                f"chaos: coordinator died before decide leg {idx}")

    hook = crash_hook if chaos.leak_intent else None
    try:
        cluster.txn(session, writes=[(k0, "v0"), (k1, "v1")],
                    on_message=hook)
    except CoordinatorCrash:
        pass

    # Unrelated traffic: every op journals events, so this advances the
    # intent monitor's event clock well past the bound.
    n_pump = pump_ops if pump_ops is not None else 2 * intent_bound
    for i in range(n_pump):
        cluster.update(session, session.op_set(f"pump{i % 64}", i))
    wd.finalize(0.0)
    return wd


__all__ = [
    "CHAOS_MONITOR", "Breach", "ChaosConfig", "Watchdog",
    "replay", "run_intent_leak_scenario", "run_watched_scenario",
]
