"""Discrete-event network + node model.

* Asynchronous network: per-message one-way delay = fixed + lognormal jitter
  + rare heavy tail; optional drops.  No ordering guarantees — messages race
  (CURP §3.1 assumes exactly this).
* Node: a single-server queue (models RAMCloud's dispatch thread, the
  bottleneck in §5.1).  ``deliver`` enqueues; the handler runs when the CPU
  frees up; sends made by the handler depart at handler completion time.
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


class Sim:
    def __init__(self, seed: int = 0) -> None:
        self.now: float = 0.0
        self.rng = random.Random(seed)
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.events_processed = 0
        # Optional flight recorder (repro.core.telemetry.Tracer).  Actors
        # null-check it, so a tracer can be attached/detached at any time.
        self.tracer = None
        # Optional protocol watchdog (repro.sim.watchdog.Watchdog): same
        # null-check idiom; actors emit journal events / checker feed points
        # through it when attached.
        self.watchdog = None

    def at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: float = math.inf, max_events: int = 50_000_000) -> None:
        while self._heap and self.events_processed < max_events:
            t, _, fn = heapq.heappop(self._heap)
            if t > until:
                self.now = until
                return
            self.now = t
            fn()
            self.events_processed += 1


class Network:
    def __init__(self, sim: Sim, params) -> None:
        from repro.core.telemetry import get_registry

        self.sim = sim
        self.p = params
        self.bytes_sent = 0
        self.msgs_sent = 0
        self._m_msgs = get_registry().counter("net.msgs")
        self._m_drops = get_registry().counter("net.drops")

    def one_way_delay(self) -> float:
        p = self.p
        d = p.one_way_delay_us
        if p.delay_jitter_sigma > 0:
            d *= self.sim.rng.lognormvariate(0.0, p.delay_jitter_sigma)
        if p.tail_prob > 0 and self.sim.rng.random() < p.tail_prob:
            d += self.sim.rng.uniform(0.3, 1.0) * p.tail_extra_us
        return d

    def send(self, dst: "Node", msg: Any, size_bytes: int = 128) -> None:
        self.msgs_sent += 1
        self.bytes_sent += size_bytes
        self._m_msgs.inc()
        if self.p.drop_prob > 0 and self.sim.rng.random() < self.p.drop_prob:
            self._m_drops.inc()
            return
        self.sim.at(self.sim.now + self.one_way_delay(),
                    lambda: dst.deliver(msg))


class Node:
    """Single-server queue: one message handled at a time.

    Subclasses implement ``service_time(msg)`` and ``handle(msg)``; sends from
    ``handle`` happen at handler-completion time (the sim clock is already
    advanced when handle runs).
    """

    def __init__(self, sim: Sim, name: str) -> None:
        self.sim = sim
        self.name = name
        self.busy_until: float = 0.0
        self.crashed: bool = False
        self.busy_time: float = 0.0   # utilization accounting

    def deliver(self, msg: Any) -> None:
        if self.crashed:
            return
        start = max(self.sim.now, self.busy_until)
        svc = self.service_time(msg)
        done = start + svc
        self.busy_until = done
        self.busy_time += svc
        self.sim.at(done, lambda: self._run(msg))

    def _run(self, msg: Any) -> None:
        if self.crashed:
            return
        self.handle(msg)

    def occupy(self, dt: float) -> None:
        """Block the server for dt more µs (e.g. §4.4 sync-poll waste)."""
        self.busy_until = max(self.busy_until, self.sim.now) + dt
        self.busy_time += dt

    # -- overridables ---------------------------------------------------------
    def service_time(self, msg: Any) -> float:
        return 0.0

    def handle(self, msg: Any) -> None:
        raise NotImplementedError
