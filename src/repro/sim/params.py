"""Simulator calibration constants.

This container has no InfiniBand cluster, so the paper's µs-scale evaluation
runs on a discrete-event simulator.  Constants below are calibrated so the
*unreplicated* RAMCloud write latency and throughput match the paper
(Table 1 hardware, §5.1), and every protocol-induced difference (1 vs 2 RTTs,
batched syncs, witness costs) then *emerges from the protocol*, not from
tuning.  Napkin math for the calibration:

  unreplicated median write  = client_send + ow + master_update + ow + client_recv
                             = 0.8 + 2.0 + 1.3 + 2.0 + 0.8            = 6.9 µs  (paper: 6.9)
  sync (original, 3-way)     = above + repl phase
    repl phase               = 3·repl_send + ow + backup_service + ow
                             = 1.2 + 2.0 + 1.6 + 2.0                  = 6.8 µs
                             -> 13.7 µs                                (paper: 13.8)
  CURP f=3                   = unreplicated + 3·client_record_send_cost
                             = 6.9 + 3·0.13                           = 7.3 µs  (paper: 7.3)
    witness reply arrives at ~0.13k + 2.0 + 0.75 + 2.0 + 0.8 ≈ 5.7 µs < master
    reply (7.3), i.e. witnesses are never the critical path (paper §5.1).

  master-throughput model (single dispatch-thread server, §4.4):
    unreplicated cost/op = master_update                        = 1.3  -> 769 k/s
    CURP (batch 50)      = 1.3 + (3·repl_send + 3·repl_ack
                                  + 3·gc_send + 3·gc_resp)/50   = 1.40 -> ~715 k/s (paper: 728 k)
    async  (no witness)  = 1.3 + (3·repl_send + 3·repl_ack)/50  = 1.34 -> ~745 k/s (CURP ≈ 4–8 % below)
    original sync        = 1.3 + 3·repl_send + 3·repl_ack
                           + poll_waste                          = 5.6  -> ~179 k/s (CURP ≈ 4×)

All absolute numbers are *simulated*; the reproduction targets are the paper's
ratios and RTT counts (see DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class SimParams:
    # --- network -------------------------------------------------------------
    one_way_delay_us: float = 2.0        # fixed propagation+switch, per hop
    delay_jitter_sigma: float = 0.03     # lognormal sigma on the one-way delay
    tail_prob: float = 0.003             # rare long-tail events (GC, IRQ, ...)
    tail_extra_us: float = 12.0          # size of a tail excursion
    drop_prob: float = 0.0               # packet loss (tests crank this up)

    # --- client --------------------------------------------------------------
    client_send_cost_us: float = 0.8     # serialize+post the primary RPC
    client_record_send_cost_us: float = 0.13  # each extra witness record RPC
    client_recv_cost_us: float = 0.8
    rpc_timeout_us: float = 1000.0
    config_fetch_us: float = 8.0         # coordinator round trip on retry

    # --- master (single dispatch-thread server) -------------------------------
    master_update_cost_us: float = 1.3   # execute + respond, one update RPC
    # Per-command execution-cost deltas on top of master_update_cost_us,
    # keyed by OpType name (Fig. 10: command types are NOT equally priced —
    # INCR carries no value payload, HMSET pays the hash-field lookup).
    # SET is the calibration anchor (delta 0), so every SET-workload figure
    # keeps the §5.1 napkin math above bit-for-bit.
    op_cost_extra_us: Dict[str, float] = field(default_factory=lambda: {
        "INCR": -0.3,
        "HMSET": 1.0,
    })
    master_read_cost_us: float = 1.0
    repl_send_cost_us: float = 0.4       # issue one backup sync RPC
    repl_ack_cost_us: float = 0.3        # process one backup ack
    gc_send_cost_us: float = 0.45        # issue one witness gc RPC
    gc_resp_cost_us: float = 0.45        # process one witness gc response
    sync_poll_waste_us: float = 2.2      # §4.4: wasted polling in sync mode
    sync_rpc_cost_us: float = 0.6        # handle a client sync RPC

    # --- backup / witness ------------------------------------------------------
    backup_service_us: float = 1.6       # per sync RPC (log append + ack)
    witness_service_us: float = 0.75     # per record RPC (1.27 M/s ≈ 0.79 µs)
    witness_gc_service_us: float = 0.5

    # --- Redis-flavoured backup cost (§5.4): fsync-on-log instead of repl RPC --
    fsync_us: float = 75.0               # NVMe fsync 50–100 µs (paper §5.4)
    redis_op_cost_us: float = 2.5        # syscall-heavy TCP path per RPC

    # --- open-loop client retry (capped exponential backoff + jitter) ---------
    ol_backoff_base_us: float = 200.0    # first retry delay
    ol_backoff_cap_us: float = 8000.0    # exponential backoff ceiling
    ol_backoff_jitter: float = 0.3       # +/- fractional jitter on each delay
    ol_max_attempts: int = 10            # give up (op becomes a "maybe")
    ol_shed_backoff_us: float = 400.0    # extra pause after an explicit shed

    # --- traffic armor (core.overload; see ArmorConfig) ------------------------
    admit_queue_depth: int = 64          # master admission bound
    admit_queue_depth_witness: int = 128
    throttle_rate_ops_per_us: float = 0.0   # per-client token rate (0 = off)
    throttle_burst: float = 8.0
    degrade_hi_frac: float = 0.75        # enter DEFER_SLOW at this fill
    degrade_lo_frac: float = 0.40        # leave it below this fill
    breaker_failures: int = 5            # consecutive failures to trip OPEN
    breaker_reset_us: float = 3000.0     # OPEN -> HALF_OPEN cooldown
    breaker_probes: int = 1              # concurrent HALF_OPEN trials

    # --- heartbeat failure detection (ConfigManager-side, §3.6-fenced) ---------
    heartbeat_interval_us: float = 100.0
    heartbeat_miss_threshold: int = 5    # intervals silent before suspect
    heartbeat_service_us: float = 0.05   # coordinator cost per beat

    # --- failure handling -------------------------------------------------------
    crash_detect_us: float = 500.0
    restore_per_entry_us: float = 0.1    # backup log replay during recovery
    recovery_fixed_us: float = 50.0

    # --- protocol ----------------------------------------------------------------
    sync_batch: int = 50                 # §4.4 (max ops between syncs)
    witness_sets: int = 1024
    witness_ways: int = 4                # §B.1: 4096 slots, 4-way
    # Per-class way budget: max ways of one set a single mergeable
    # (key_hash, class) stack may occupy, so a hot INCR storm cannot
    # monopolize a set and starve conflicting classes (None = no cap,
    # the paper's behavior).
    witness_class_budget: Optional[int] = None
    hot_key_window_us: float = 0.0       # §4.4 heuristic (off by default)


DEFAULT = SimParams()
