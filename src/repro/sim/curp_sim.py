"""Timed CURP cluster simulation: clients, master, witnesses, backups,
coordinator — with crash injection and recovery, driving the *same*
repro.core state machines as the unit harness.

Modes (the four lines of the paper's Figs. 5/6):
  * "curp"         — full protocol: witness records + batched async syncs.
  * "sync"         — original primary-backup: respond after backup sync
                      (+ §4.4 polling waste at the master).
  * "async"        — respond before sync, NO witnesses (fast but unsafe;
                      the paper's "Async" comparison).
  * "unreplicated" — no backups, no witnesses.

Sharded mode (§4, Fig. 3): ``run_sharded_scenario`` builds N independent
shard groups — each with its own master, witness group, and backups — in one
simulated network.  Clients route every op through the same KeyRouter the
protocol layer uses, so per-shard witnesses only ever see their own
partition's key hashes, and a crash on one shard replays only that shard.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.backup import Backup
from repro.core.client import ClientSession, Decision, decide
from repro.core.config import HeartbeatDetector
from repro.core.master import DUP, ERROR, FAST, SYNCED, Master
from repro.core.overload import (
    AdmissionQueue,
    ArmorConfig,
    CircuitBreaker,
    DegradeLevel,
    degrade_level,
)
from repro.core.shard import KeyRouter, ShardedClientSession, SlotRouter
from repro.core.telemetry import get_registry
from repro.core.types import ExecResult, Op, OpType, RecordStatus
from repro.core.witness import Witness

from .linearizability import check_linearizable_strict
from .network import Network, Node, Sim
from .params import DEFAULT, SimParams


# --------------------------------------------------------------------------
# Sim-level message envelopes
# --------------------------------------------------------------------------
@dataclass
class MUpdate:
    src: "SimClient"
    op: Op
    wlv: int
    acks: tuple


@dataclass
class MUpdateResp:
    rpc_id: tuple
    result: ExecResult


@dataclass
class MRead:
    src: "SimClient"
    op: Op


@dataclass
class MRecord:
    src: "SimClient"
    master_id: int
    op: Op
    attempt: int = 0


@dataclass
class MRecordResp:
    rpc_id: tuple
    status: RecordStatus
    witness: "SimWitness"
    attempt: int = 0


@dataclass
class MSyncReq:
    src: "SimClient"
    rpc_id: tuple


@dataclass
class MSyncResp:
    rpc_id: tuple


@dataclass
class MBackupSync:
    src: "SimMaster"
    req: Any
    through: int = -1    # per-op sync tag (sync mode only)


@dataclass
class MBackupAck:
    src: "SimBackup"
    ok: bool
    through: int = -1


@dataclass
class MGc:
    src: "SimMaster"
    entries: tuple


@dataclass
class MGcResp:
    stale: tuple


@dataclass
class MShedResp:
    """Explicit load-shed reply (admission queue full / client throttled).

    Sent at DELIVERY time, before any service cost — the fail-fast half of
    queue-based load leveling.  Clients back off on it instead of timing
    out and retrying into the same overload."""
    rpc_id: tuple
    kind: str           # "QUEUE" | "THROTTLE"


@dataclass
class MHeartbeat:
    shard_id: int
    master_id: int


@dataclass
class MDoSync:      # master self-message: issue the batched backup sync
    pass


@dataclass
class MDoGc:        # master self-message: issue witness gc after a sync
    entries: tuple


# --------------------------------------------------------------------------
# Actors
# --------------------------------------------------------------------------
class SimWitness(Node):
    def __init__(self, sim, net, params, core: Witness, name: str,
                 armor: Optional[ArmorConfig] = None) -> None:
        super().__init__(sim, name)
        self.net = net
        self.p = params
        self.core = core
        self.admission = armor.make_witness_queue() if armor else None

    def deliver(self, msg) -> None:
        if self.admission is not None and isinstance(msg, MRecord) \
                and not self.crashed:
            if not self.admission.admit():
                # Shed at delivery (no service cost): reply REJECTED so the
                # client falls back to the 2-RTT sync path — correct, just
                # slower, which is exactly the graceful-degradation contract.
                if self.sim.tracer is not None:
                    self.sim.tracer.instant(msg.op.rpc_id, "witness_shed",
                                            self.sim.now, actor=self.name)
                self.net.send(msg.src, MRecordResp(
                    msg.op.rpc_id, RecordStatus.REJECTED, self, msg.attempt
                ))
                return
            super().deliver(msg)
            return
        super().deliver(msg)

    def _run(self, msg) -> None:
        if self.admission is not None and isinstance(msg, MRecord):
            self.admission.release()
        super()._run(msg)

    def service_time(self, msg) -> float:
        if isinstance(msg, MRecord):
            return self.p.witness_service_us
        if isinstance(msg, MGc):
            return self.p.witness_gc_service_us
        return 0.2

    def handle(self, msg) -> None:
        tr = self.sim.tracer
        if isinstance(msg, MRecord):
            st = self.core.record(
                msg.master_id, msg.op.key_hashes(), msg.op.rpc_id, msg.op
            )
            if tr is not None:
                # The handler runs at service completion; the server span
                # covers [now - svc, now].
                svc = self.service_time(msg)
                tr.span(msg.op.rpc_id, "witness_record", self.sim.now - svc,
                        svc, actor=self.name, status=st.name.lower())
            self.net.send(
                msg.src, MRecordResp(msg.op.rpc_id, st, self, msg.attempt)
            )
        elif isinstance(msg, MGc):
            resp = self.core.gc(msg.entries)
            if tr is not None:
                svc = self.service_time(msg)
                tr.span(("gc", self.name), "witness_gc", self.sim.now - svc,
                        svc, actor=self.name,
                        args={"entries": len(msg.entries),
                              "stale": len(resp.stale_requests)}, force=True)
            self.net.send(msg.src, MGcResp(resp.stale_requests))


class SimBackup(Node):
    def __init__(self, sim, net, params, core: Backup, name: str,
                 service_us: Optional[float] = None) -> None:
        super().__init__(sim, name)
        self.net = net
        self.p = params
        self.core = core
        self._service = service_us if service_us is not None else params.backup_service_us

    def service_time(self, msg) -> float:
        return self._service

    def handle(self, msg) -> None:
        if isinstance(msg, MBackupSync):
            resp = self.core.handle_sync(msg.req)
            self.net.send(msg.src, MBackupAck(self, resp.ok, msg.through))


class SimMaster(Node):
    def __init__(self, sim, net, params, core: Master, name: str,
                 mode: str, backups: List[SimBackup],
                 witnesses: List[SimWitness],
                 armor: Optional[ArmorConfig] = None) -> None:
        super().__init__(sim, name)
        self.net = net
        self.p = params
        self.core = core
        self.mode = mode
        self.backups = backups
        self.witnesses = witnesses
        # Responses withheld until the log is synced through some index:
        self._withheld: List[Tuple[int, Node, Any]] = []
        self._sync_acks_needed = 0
        # sync mode: per-op replication RPCs, multiple outstanding.
        self._sync_issued_through = 0
        self._per_op_acks: Dict[int, int] = {}
        self._sync_scheduled = False   # an MDoSync is queued but not yet run
        self.stats = {"updates": 0, "reads": 0}
        # --- traffic armor (core.overload) --------------------------------
        self.armor = armor
        self.admission = armor.make_queue() if armor else None
        self.throttle = armor.make_throttle() if armor else None
        self.degrade = DegradeLevel.NORMAL
        self._deferred_gc: List[tuple] = []
        self._degrade_retry_scheduled = False
        # Client-RPC queue depth, tracked with or without armor so the
        # no-armor baseline's unbounded growth is measurable.
        self.qdepth = 0
        self.max_qdepth = 0
        self.armor_stats = {"shed_queue": 0, "shed_throttle": 0,
                            "deferred_syncs": 0, "deferred_gcs": 0}
        # --- flight recorder ----------------------------------------------
        # Measured client-RPC service times feed the adaptive admission
        # bound (ArmorConfig.adaptive) and the fig_obs stage attribution.
        self._h_service = get_registry().histogram("sim.master_service_us")
        self._aimd = (armor.make_aimd(self.admission, self._h_service)
                      if armor is not None and self.admission is not None
                      else None)
        self._aimd_pending = 0
        self._sync_t0 = 0.0
        self._sync_n = 0

    # -- admission (queue-based load leveling; fail fast at delivery) ---------
    def deliver(self, msg) -> None:
        if isinstance(msg, (MUpdate, MRead)) and not self.crashed:
            if self.admission is not None:
                if not self.admission.admit():
                    self.armor_stats["shed_queue"] += 1
                    if self.sim.tracer is not None:
                        self.sim.tracer.instant(
                            msg.op.rpc_id, "master_shed", self.sim.now,
                            actor=self.name, args={"reason": "QUEUE"})
                    self.net.send(msg.src,
                                  MShedResp(msg.op.rpc_id, "QUEUE"))
                    return
                if self.throttle is not None and not self.throttle.allow(
                        msg.op.rpc_id[0], self.sim.now):
                    self.admission.release()
                    self.armor_stats["shed_throttle"] += 1
                    if self.sim.tracer is not None:
                        self.sim.tracer.instant(
                            msg.op.rpc_id, "master_shed", self.sim.now,
                            actor=self.name, args={"reason": "THROTTLE"})
                    self.net.send(msg.src,
                                  MShedResp(msg.op.rpc_id, "THROTTLE"))
                    return
            self.qdepth += 1
            if self.qdepth > self.max_qdepth:
                self.max_qdepth = self.qdepth
        super().deliver(msg)

    def _run(self, msg) -> None:
        if isinstance(msg, (MUpdate, MRead)):
            self.qdepth -= 1
            self._h_service.record(self.service_time(msg))
            if self.admission is not None:
                self.admission.release()
                self.degrade = degrade_level(
                    self.admission.frac(), self.degrade,
                    self.armor.degrade_hi, self.armor.degrade_lo,
                )
                if self._aimd is not None:
                    self._aimd_pending += 1
                    if self._aimd_pending >= self.armor.adaptive_interval_ops:
                        self._aimd_pending = 0
                        self._aimd.tick()
        super()._run(msg)

    # -- service costs ----------------------------------------------------------
    def service_time(self, msg) -> float:
        p = self.p
        if isinstance(msg, MUpdate):
            # Per-command pricing (Fig. 10): the op type's execution-cost
            # delta rides on the base update cost.
            c = p.master_update_cost_us + p.op_cost_extra_us.get(
                msg.op.op_type.name, 0.0
            )
            if self.mode == "sync":
                # Original primary-backup: the per-op sync RPCs are issued
                # inside the update handler (no batching).  The §4.4 polling
                # waste is charged when the acks return (occupy), so it burns
                # master CPU without artificially delaying this op's release.
                c += len(self.backups) * p.repl_send_cost_us
            return c
        if isinstance(msg, MRead):
            return p.master_read_cost_us
        if isinstance(msg, MBackupAck):
            return p.repl_ack_cost_us
        if isinstance(msg, MSyncReq):
            return p.sync_rpc_cost_us
        if isinstance(msg, MGcResp):
            return p.gc_resp_cost_us
        if isinstance(msg, MDoSync):
            return len(self.backups) * p.repl_send_cost_us
        if isinstance(msg, MDoGc):
            return len(self.witnesses) * p.gc_send_cost_us
        return 0.2

    # -- logic --------------------------------------------------------------------
    def handle(self, msg) -> None:
        tr = self.sim.tracer
        if isinstance(msg, MUpdate):
            self.stats["updates"] += 1
            wd = self.sim.watchdog
            commutes = None
            acks = msg.acks
            if wd is not None and wd.chaos.any():
                ch = wd.chaos
                if ch.force_commute:
                    # Chaos: lie to the master — every op "commutes", so a
                    # genuinely conflicting op rides the 1-RTT fast path
                    # inside an unsynced window that cannot replay (§3.2.2
                    # violated; the commutativity monitor must notice).
                    commutes = True
                if ch.rifl_rollback and not ch.fired("rifl_rollback"):
                    cid = msg.op.rpc_id[0]
                    if self.core.rifl.acked_frontier(cid) > 0 \
                            and self.core.rifl.check_duplicate(
                                msg.op.rpc_id) is None:
                        # Chaos: regress one client's applied ack frontier
                        # (exactly-once bookkeeping corrupted).  This
                        # message's piggybacked acks are dropped too —
                        # apply_client_acks would otherwise restore the
                        # frontier before the execute event journals it.
                        ch.fire("rifl_rollback")
                        self.core.rifl._acked_below[cid] = 0
                        acks = ()
            verdict, result = self.core.handle_update(
                msg.op, msg.wlv, acks, now=self.sim.now, commutes=commutes
            )
            if tr is not None:
                svc = self.service_time(msg)
                tr.span(msg.op.rpc_id, "master_update", self.sim.now - svc,
                        svc, actor=self.name, status=verdict)
            resp = MUpdateResp(msg.op.rpc_id, result)
            if verdict == ERROR:
                self.net.send(msg.src, resp)
                return
            withhold = (self.mode == "sync" and not result.synced
                        and verdict != DUP) or (verdict == SYNCED)
            if self.mode == "unreplicated":
                withhold = False
            if withhold:
                self._withheld.append((len(self.core.log), msg.src, resp))
                self.core.want_sync = True
            else:
                self.net.send(msg.src, resp)
            if self.mode == "sync":
                # Sync RPCs depart at handler end (their cost is already in
                # this handler's service time).
                self._begin_sync_inline()
            else:
                self._maybe_sync()

        elif isinstance(msg, MRead):
            self.stats["reads"] += 1
            verdict, result = self.core.handle_read(msg.op, now=self.sim.now)
            wd = self.sim.watchdog
            if wd is not None and wd.chaos.corrupt_value and result.ok \
                    and result.value is not None \
                    and not wd.chaos.fired("corrupt_value"):
                # Chaos: return a value nobody ever wrote — only the
                # windowed linearizability checker can catch this.
                wd.chaos.fire("corrupt_value")
                result = dataclasses.replace(result, value="~corrupted~")
            if tr is not None:
                svc = self.service_time(msg)
                tr.span(msg.op.rpc_id, "master_read", self.sim.now - svc,
                        svc, actor=self.name, status=verdict)
            resp = MUpdateResp(msg.op.rpc_id, result)
            if verdict == SYNCED and self.mode != "unreplicated":
                self._withheld.append((len(self.core.log), msg.src, resp))
                self.core.want_sync = True
                self._maybe_sync()
            else:
                self.net.send(msg.src, resp)

        elif isinstance(msg, MSyncReq):
            rec = self.core.rifl.check_duplicate(msg.rpc_id)
            if rec is not None and rec.synced:
                self.net.send(msg.src, MSyncResp(msg.rpc_id))
            else:
                self._withheld.append(
                    (len(self.core.log), msg.src, MSyncResp(msg.rpc_id))
                )
                self.core.want_sync = True
                self._maybe_sync()

        elif isinstance(msg, MDoSync):
            self._sync_scheduled = False
            req = self.core.begin_sync()
            if req is None:
                return
            self._sync_t0 = self.sim.now - self.service_time(msg)
            self._sync_n = len(req.entries)
            if not self.backups:     # unreplicated: trivially synced
                gc_entries = self.core.complete_sync()
                if tr is not None:
                    tr.span(("sync", self.name), "master_sync",
                            self._sync_t0, self.sim.now - self._sync_t0,
                            actor=self.name,
                            args={"entries": self._sync_n}, force=True)
                self._release(self.core.synced_index)
                return
            self._sync_acks_needed = len(self.backups)
            for b in self.backups:
                self.net.send(b, MBackupSync(self, req), size_bytes=2048)

        elif isinstance(msg, MBackupAck):
            if self.mode == "sync":
                if msg.through in self._per_op_acks and msg.ok:
                    self._per_op_acks[msg.through] -= 1
                    if self._per_op_acks[msg.through] == 0:
                        del self._per_op_acks[msg.through]
                        self.core.force_synced_through(msg.through)
                        self._release(self.core.synced_index)
                        # §4.4: polling wasted while this sync was in flight.
                        self.occupy(self.p.sync_poll_waste_us)
                return
            if self.core.sync_in_progress is None:
                return
            if not msg.ok:
                self.core.abort_sync()
                return
            self._sync_acks_needed -= 1
            if self._sync_acks_needed == 0:
                gc_entries = self.core.complete_sync()
                if tr is not None:
                    # One span per batched sync CYCLE (begin_sync -> last
                    # backup ack), forced: syncs batch many rpc ids.
                    tr.span(("sync", self.name), "master_sync",
                            self._sync_t0, self.sim.now - self._sync_t0,
                            actor=self.name,
                            args={"entries": self._sync_n}, force=True)
                self._release(self.core.synced_index)
                if self.witnesses and gc_entries:
                    if self.degrade is DegradeLevel.DEFER_SLOW:
                        # Degraded: witness gc is slow-path work — batch it
                        # up for when the queue drains (records age a bit
                        # longer; §4.5 suspicion handles true garbage).
                        self._deferred_gc.extend(gc_entries)
                        self.armor_stats["deferred_gcs"] += 1
                    else:
                        self.deliver(MDoGc(gc_entries))
                self._maybe_sync()   # more batched work may be pending

        elif isinstance(msg, MDoGc):
            for w in self.witnesses:
                self.net.send(w, MGc(self, msg.entries), size_bytes=512)

        elif isinstance(msg, MGcResp):
            # §4.5: retry suspected uncollected garbage (RIFL will filter).
            for op in msg.stale:
                self.core.handle_update(
                    op, self.core.witness_list_version, (), now=self.sim.now
                )
            self.core.want_sync = self.core.want_sync or bool(msg.stale)
            self._maybe_sync()

    def _begin_sync_inline(self) -> None:
        """Sync mode: issue THIS op's replication RPCs immediately (original
        RAMCloud: 3 replication RPCs per write, no cross-client batching)."""
        from repro.core.types import BackupSyncReq

        through = len(self.core.log)
        if through == self._sync_issued_through:
            return
        req = BackupSyncReq(
            master_id=self.core.master_id,
            epoch=self.core.epoch,
            from_index=self._sync_issued_through,
            entries=tuple(
                (e.op, e.result)
                for e in self.core.log[self._sync_issued_through:through]
            ),
        )
        self._sync_issued_through = through
        self._per_op_acks[through] = len(self.backups)
        self.core.want_sync = False
        for b in self.backups:
            self.net.send(b, MBackupSync(self, req, through), size_bytes=2048)

    def _maybe_sync(self) -> None:
        if self._sync_scheduled:
            return
        if self.mode == "unreplicated":
            # No backups: syncs are a no-op; still release withheld (none).
            if self.core.want_sync:
                self._sync_scheduled = True
                self.deliver(MDoSync())
            return
        if self.degrade is not DegradeLevel.DEFER_SLOW and self._deferred_gc:
            # Pressure lifted: flush the witness gc batched up while degraded.
            entries = tuple(self._deferred_gc)
            self._deferred_gc = []
            self.deliver(MDoGc(entries))
        if self.core.want_sync and self.core.sync_in_progress is None:
            if self.degrade is DegradeLevel.DEFER_SLOW and not self._withheld:
                # Graceful degradation: the batch-full sync is deferrable
                # slow-path work (nobody's reply is gated on it — conflict
                # and read syncs withhold responses and are never deferred).
                # The 1-RTT witness-backed fast path stays fully alive; the
                # unsynced window just grows until pressure drops.
                self.armor_stats["deferred_syncs"] += 1
                if not self._degrade_retry_scheduled:
                    # Bounded staleness: re-check even if traffic stops.
                    self._degrade_retry_scheduled = True

                    def retry() -> None:
                        self._degrade_retry_scheduled = False
                        self._maybe_sync()
                    self.sim.after(2 * self.p.rpc_timeout_us, retry)
                return
            self._sync_scheduled = True
            self.deliver(MDoSync())

    def _release(self, synced_through: int) -> None:
        still = []
        for idx, dst, resp in self._withheld:
            if idx <= synced_through:
                if isinstance(resp, MUpdateResp):
                    resp = MUpdateResp(
                        resp.rpc_id,
                        dataclasses.replace(resp.result, synced=True),
                    )
                self.net.send(dst, resp)
            else:
                still.append((idx, dst, resp))
        self._withheld = still


@dataclass
class PendingOp:
    op: Op
    is_update: bool
    t_invoke: float            # first attempt (for linearizability history)
    t_attempt: float
    master_result: Optional[ExecResult] = None
    witness_statuses: List[RecordStatus] = field(default_factory=list)
    want_witnesses: int = 0
    sync_requested: bool = False
    retries: int = 0
    done: bool = False


class SimClient(Node):
    def __init__(self, sim, net, params, session: ClientSession, name: str,
                 cluster: "SimCluster", n_ops: int,
                 op_factory: Callable[[ClientSession], Op]) -> None:
        super().__init__(sim, name)
        self.net = net
        self.p = params
        self.session = session
        self.cluster = cluster
        self.n_ops = n_ops
        self.op_factory = op_factory
        self.completed = 0
        self.latencies: List[Tuple[float, float, bool]] = []  # (lat, t, is_update)
        self.history: List[dict] = []
        self.pending: Optional[PendingOp] = None
        self.fast_completions = 0
        self.rtt2_completions = 0

    def service_time(self, msg) -> float:
        if isinstance(msg, MRecordResp):
            return 0.1   # record responses are tiny (no payload to parse)
        return self.p.client_recv_cost_us

    # -- issuing ------------------------------------------------------------------
    def start(self) -> None:
        self.sim.after(self.sim.rng.random() * 1.0, self._issue_next)

    def _issue_next(self) -> None:
        if self.completed >= self.n_ops:
            return
        op = self.op_factory(self.session)
        self.pending = PendingOp(
            op=op, is_update=op.is_update,
            t_invoke=self.sim.now, t_attempt=self.sim.now,
        )
        if self.sim.watchdog is not None:
            self.sim.watchdog.op_invoked(op.rpc_id, self.sim.now)
        self._send_attempt()

    def _send_attempt(self) -> None:
        assert self.pending is not None
        pend = self.pending
        op = pend.op
        mode = self.cluster.mode
        # Route to the owning shard (single-shard clusters route to self).
        target = self.cluster.route(op)
        master = target.master_node
        t0 = self.sim.now
        if pend.is_update and mode == "curp":
            wits = target.witness_nodes
            pend.want_witnesses = len(wits)
            pend.witness_statuses = []
            # Client serializes the extra record sends before the update RPC
            # (the measured +0.13 µs/record of §5.1).
            att = pend.retries
            for k, w in enumerate(wits):
                self.sim.at(
                    t0 + (k + 1) * self.p.client_record_send_cost_us,
                    lambda w=w, op=op, att=att: self.net.send(
                        w, MRecord(self, target.master_id, op, att)
                    ),
                )
            t0 += len(wits) * self.p.client_record_send_cost_us
        else:
            pend.want_witnesses = 0
            pend.witness_statuses = []
        t0 += self.p.client_send_cost_us
        if pend.is_update:
            msg = MUpdate(self, op, target.wlv, self.session.acks())
        else:
            msg = MRead(self, op)
        self.sim.at(t0, lambda: self.net.send(master, msg, size_bytes=256))
        # Timeout/retry.
        rpc_id, attempt = op.rpc_id, pend.retries
        self.sim.after(self.p.rpc_timeout_us,
                       lambda: self._check_timeout(rpc_id, attempt))

    def _check_timeout(self, rpc_id, attempt) -> None:
        pend = self.pending
        if pend is None or pend.done or pend.op.rpc_id != rpc_id:
            return
        if pend.retries != attempt:
            return
        pend.retries += 1
        if pend.retries > 40:
            self._record_history(pend, value=None, failed=True)
            self.pending = None
            self._issue_next()
            return
        # Refetch config (the master may have changed), then resend.
        self.sim.after(self.p.config_fetch_us, self._resend)

    def _resend(self) -> None:
        if self.pending is None or self.pending.done:
            return
        self.pending.master_result = None
        self.pending.sync_requested = False
        self.pending.t_attempt = self.sim.now
        self._send_attempt()

    # -- responses -------------------------------------------------------------------
    def handle(self, msg) -> None:
        pend = self.pending
        if pend is None or pend.done:
            return
        if isinstance(msg, MShedResp) and msg.rpc_id == pend.op.rpc_id:
            # Explicit load-shed: back off (linearly growing, jittered)
            # instead of hammering the overloaded server until timeout.
            pend.retries += 1
            if pend.retries > 40:
                self._record_history(pend, value=None, failed=True)
                self.pending = None
                self._issue_next()
                return
            delay = min(self.p.ol_shed_backoff_us * pend.retries,
                        self.p.ol_backoff_cap_us)
            delay *= 1.0 + self.p.ol_backoff_jitter * (
                2 * self.sim.rng.random() - 1)
            self.sim.after(delay, self._resend)
            return
        if isinstance(msg, MUpdateResp) and msg.rpc_id == pend.op.rpc_id:
            if not msg.result.ok:
                # Stale config (witness list version): refetch + retry.
                pend.retries += 1
                self.sim.after(self.p.config_fetch_us, self._resend)
                return
            pend.master_result = msg.result
        elif isinstance(msg, MRecordResp) and msg.rpc_id == pend.op.rpc_id:
            if msg.attempt != pend.retries:
                return  # stale response from a pre-retry witness set
            pend.witness_statuses.append(msg.status)
        elif isinstance(msg, MSyncResp) and msg.rpc_id == pend.op.rpc_id:
            if pend.master_result is None:
                return
            self._complete(pend, pend.master_result, rtts=3)
            return
        else:
            return
        self._evaluate(pend)

    def _evaluate(self, pend: PendingOp) -> None:
        if pend.master_result is None:
            return
        if not pend.is_update or self.cluster.mode != "curp":
            self._complete(pend, pend.master_result,
                           rtts=2 if pend.master_result.synced else 1)
            return
        if pend.master_result.synced:
            # Conflict path: master synced before responding — 2 RTTs, no
            # witness accepts needed (§3.2.3).
            self._complete(pend, pend.master_result, rtts=2)
            return
        if len(pend.witness_statuses) < pend.want_witnesses:
            return
        d = decide(pend.master_result, pend.witness_statuses)
        if d is Decision.COMPLETE:
            self._complete(pend, pend.master_result, rtts=1)
        elif not pend.sync_requested:
            pend.sync_requested = True
            self.sim.after(
                self.p.client_send_cost_us,
                lambda: self.net.send(
                    self.cluster.route(pend.op).master_node,
                    MSyncReq(self, pend.op.rpc_id),
                ),
            )

    def _complete(self, pend: PendingOp, result, rtts: int) -> None:
        pend.done = True
        if self.sim.watchdog is not None:
            self.sim.watchdog.journal.emit(
                "ack", actor=self.name, rpc=pend.op.rpc_id, rtts=rtts,
            )
        lat = self.sim.now - pend.t_invoke
        self.latencies.append((lat, self.sim.now, pend.is_update))
        if rtts == 1:
            self.fast_completions += 1
        else:
            self.rtt2_completions += 1
        self.session.mark_completed(pend.op.rpc_id)
        self._record_history(pend, value=result.value if result else None)
        self.completed += 1
        self.cluster.on_completion(self.sim.now)
        self.pending = None
        self._issue_next()

    def _record_history(self, pend: PendingOp, value, failed: bool = False) -> None:
        entry = {
            "client": self.session.client_id,
            "op": pend.op,
            "invoke": pend.t_invoke,
            "complete": None if failed else self.sim.now,
            "value": value,
            "failed": failed,
        }
        wd = self.sim.watchdog
        if wd is not None:
            (wd.op_failed if failed else wd.op_completed)(entry)
        self.history.append(entry)


# --------------------------------------------------------------------------
# Cluster + scenario
# --------------------------------------------------------------------------
class SimCluster:
    def __init__(self, sim: Sim, net: Network, params: SimParams, mode: str,
                 f: int, backup_service_us: Optional[float] = None,
                 armor: Optional[ArmorConfig] = None) -> None:
        self.sim = sim
        self.net = net
        self.p = params
        self.mode = mode
        self.f = f
        self.armor = armor
        self.epoch = 0
        self.wlv = 0
        self._id = 0

        use_backups = mode in ("curp", "sync", "async")
        use_witnesses = mode == "curp"
        self.backup_cores = [Backup(self._next_id()) for _ in range(f)] \
            if use_backups else []
        self.backup_nodes = [
            SimBackup(sim, net, params, b, f"backup{i}",
                      service_us=backup_service_us)
            for i, b in enumerate(self.backup_cores)
        ]
        self.master_id = self._next_id()
        core_master = Master(
            self.master_id, epoch=0,
            sync_batch=(1 if mode == "sync" else params.sync_batch),
            hot_key_window=params.hot_key_window_us,
        )
        self.witness_cores = [
            Witness(params.witness_sets, params.witness_ways,
                    class_budget=params.witness_class_budget)
            for _ in range(f)
        ] if use_witnesses else []
        self.witness_nodes = [
            SimWitness(sim, net, params, w, f"witness{i}", armor=armor)
            for i, w in enumerate(self.witness_cores)
        ]
        for w in self.witness_cores:
            w.start(self.master_id)
        self.master_node = SimMaster(
            sim, net, params, core_master, "master", mode,
            self.backup_nodes, self.witness_nodes, armor=armor,
        )
        self.clients: List[SimClient] = []
        self.completions: List[float] = []
        self.recovery_report: Optional[dict] = None
        # Optional key-ownership filter installed on every master this
        # cluster creates (incl. post-recovery ones); the sharded wrapper
        # uses it for timed slot migration (NOT_OWNER on frozen slots).
        self.owned_filter = None
        # Heartbeat failover (SimCoordinator.watch wires these):
        self.coordinator: Optional["SimCoordinator"] = None
        self.hb_shard_id: Optional[int] = None
        self._recovering = False
        self._detect_source = "harness"
        self.master_nodes_retired: List[SimMaster] = []  # armor stats survive failover
        # Shard index under an attached watchdog (ShardedSimCluster attach
        # renumbers; single clusters are shard 0).
        self.wd_shard = 0

    def _next_id(self) -> int:
        self._id += 1
        return self._id

    def route(self, op: Op) -> "SimCluster":
        """Single-master cluster: every key lives here."""
        return self

    def on_completion(self, t: float) -> None:
        self.completions.append(t)

    def set_owned_filter(self, fn) -> None:
        """Install a key-ownership predicate on the current AND every future
        master core (timed migration: frozen/moved slots draw NOT_OWNER)."""
        self.owned_filter = fn
        self.master_node.core.owned_partition = fn

    # -- heartbeat failover (SimCoordinator-driven) -----------------------------
    def attach_heartbeat(self, shard_id: int,
                         coordinator: "SimCoordinator") -> None:
        self.coordinator = coordinator
        self.hb_shard_id = shard_id
        self._start_heartbeat_loop(self.master_node)

    def _start_heartbeat_loop(self, node: SimMaster) -> None:
        """Self-rescheduling beat from ``node`` over the (lossy, jittery)
        timed transport.  The loop dies silently with its master: beats just
        stop, and only the coordinator's miss-count detector notices."""
        def beat() -> None:
            if node.crashed or node is not self.master_node:
                return
            self.net.send(self.coordinator,
                          MHeartbeat(self.hb_shard_id, self.master_id),
                          size_bytes=32)
            self.sim.after(self.p.heartbeat_interval_us, beat)
        # Desynchronize shard beats slightly.
        self.sim.after(self.sim.rng.random() * self.p.heartbeat_interval_us,
                       beat)

    def begin_failover(self, source: str) -> None:
        """Entry point for DETECTED failures (heartbeat silence): run the
        standard recovery path exactly once."""
        if self._recovering:
            return
        self._recovering = True
        self._detect_source = source
        self._recover()

    # -- crash + recovery (timed mirror of core.recovery) -------------------------
    def crash_master_at(self, t: float) -> None:
        self.sim.at(t, self._crash)

    def fail_master_at(self, t: float) -> None:
        """Kill the master SILENTLY: no harness-scheduled recovery.  The
        node stops serving and stops heartbeating; failover happens iff a
        SimCoordinator's failure detector notices the silence."""
        def fail() -> None:
            self.master_node.crashed = True
        self.sim.at(t, fail)

    def _crash(self) -> None:
        self.master_node.crashed = True
        if self._recovering:
            return
        self._recovering = True
        self._detect_source = "harness"
        self.sim.after(self.p.crash_detect_us, self._recover)

    def _recover(self) -> None:
        p = self.p
        old_master_id = self.master_id
        # 1. restore from the longest backup log
        entries = max(
            (b.get_log() for b in self.backup_cores), key=len, default=()
        )
        restore_us = p.recovery_fixed_us + len(entries) * p.restore_per_entry_us
        new_master_core = Master(
            self._next_id(), epoch=self.epoch + 1,
            sync_batch=(1 if self.mode == "sync" else p.sync_batch),
            hot_key_window=p.hot_key_window_us,
        )
        new_master_core.restore_from_log(entries)

        def after_restore():
            # 2. getRecoveryData from one witness (freeze) — 1 RTT.
            reqs = ()
            if self.witness_cores:
                reqs = self.witness_cores[0].get_recovery_data(old_master_id)
            replayed = new_master_core.replay_from_witness(reqs)
            replay_us = 2 * p.one_way_delay_us + replayed * p.master_update_cost_us

            def after_replay():
                # 3. bump epoch; sync to backups — 1 RTT.
                wd = self.sim.watchdog
                if wd is not None and wd.chaos.skip_epoch_bump \
                        and not wd.chaos.fired("skip_epoch_bump"):
                    # Chaos: recover WITHOUT the §3.6 epoch fence — a zombie
                    # pre-crash master would no longer be fenced at the
                    # backups.  The fence below journals the stale epoch.
                    wd.chaos.fire("skip_epoch_bump")
                else:
                    self.epoch += 1
                new_master_core.epoch = self.epoch
                for b in self.backup_cores:
                    b.set_epoch(self.epoch)
                req = new_master_core.begin_sync()
                if req is not None:
                    for b in self.backup_cores:
                        b.handle_sync(req)
                    new_master_core.complete_sync()
                sync_us = 2 * p.one_way_delay_us + p.backup_service_us

                def finish():
                    # 4. fresh witnesses + publish config.
                    self.master_id = new_master_core.master_id
                    self.wlv += 1
                    new_master_core.witness_list_version = self.wlv
                    if self.owned_filter is not None:
                        new_master_core.owned_partition = self.owned_filter
                    self.witness_cores = [
                        Witness(p.witness_sets, p.witness_ways,
                                class_budget=p.witness_class_budget)
                        for _ in range(self.f)
                    ] if self.mode == "curp" else []
                    self.witness_nodes = [
                        SimWitness(self.sim, self.net, p, w, f"witness'{i}",
                                   armor=self.armor)
                        for i, w in enumerate(self.witness_cores)
                    ]
                    for w in self.witness_cores:
                        w.start(self.master_id)
                    # Black box survives failover: the new master/witness
                    # cores inherit the journal AFTER replay (recovery
                    # internals are not client-visible protocol steps), and
                    # the epoch/WLV fence is journaled for the monotonicity
                    # monitor (``mid`` lets the watchdog re-map shard
                    # ownership to the new master id).
                    jr = self.master_node.core.journal
                    new_master_core.journal = jr
                    new_master_core.journal_actor = \
                        f"s{self.wd_shard}m{new_master_core.master_id}"
                    for k, w in enumerate(self.witness_cores):
                        w.journal = jr
                        w.journal_actor = f"s{self.wd_shard}e{self.epoch}w{k}"
                    if jr is not None:
                        jr.emit("fence", actor=new_master_core.journal_actor,
                                shard=self.wd_shard, epoch=self.epoch,
                                wlv=self.wlv, mid=new_master_core.master_id,
                                reason="recovery")
                    self.master_nodes_retired.append(self.master_node)
                    self.master_node = SimMaster(
                        self.sim, self.net, p, new_master_core, "master'",
                        self.mode, self.backup_nodes, self.witness_nodes,
                        armor=self.armor,
                    )
                    self.recovery_report = {
                        "restored": len(entries), "replayed": replayed,
                        "recovered_at": self.sim.now,
                        "detected_by": self._detect_source,
                    }
                    self._recovering = False
                    if self.coordinator is not None:
                        # Re-arm the failure detector and start the new
                        # master's beat loop.
                        self.coordinator.detector.watch(
                            self.hb_shard_id, self.sim.now)
                        self._start_heartbeat_loop(self.master_node)
                self.sim.after(sync_us, finish)
            self.sim.after(replay_us, after_replay)
        self.sim.after(restore_us, after_restore)


class SimCoordinator(Node):
    """ConfigManager-side failure detector in the timed transport (§3.6).

    Masters heartbeat every ``heartbeat_interval_us`` over the same lossy
    network as client traffic; the HeartbeatDetector (repro.core.config)
    declares a master suspect after ``heartbeat_miss_threshold`` silent
    intervals, and the coordinator then drives the shard's standard
    recovery path (backup restore -> witness freeze/replay -> epoch+WLV
    bump -> fresh witnesses) with NO harness intervention.  The epoch/WLV
    fences make a falsely-suspected (or zombie) old master harmless: its
    syncs are refused by backups and clients' stale configs draw
    WRONG_WITNESS_VERSION."""

    def __init__(self, sim, net, params, name: str = "coordinator") -> None:
        super().__init__(sim, name)
        self.net = net
        self.p = params
        self.detector = HeartbeatDetector(
            params.heartbeat_interval_us, params.heartbeat_miss_threshold
        )
        self.watched: Dict[int, SimCluster] = {}
        self.failovers: List[dict] = []
        self._loop_started = False

    def service_time(self, msg) -> float:
        return self.p.heartbeat_service_us

    def watch(self, shard_id: int, cluster: SimCluster) -> None:
        self.watched[shard_id] = cluster
        self.detector.watch(shard_id, self.sim.now)
        cluster.attach_heartbeat(shard_id, self)
        if not self._loop_started:
            self._loop_started = True
            self.sim.after(self.p.heartbeat_interval_us, self._check)

    def handle(self, msg) -> None:
        if isinstance(msg, MHeartbeat):
            self.detector.beat(msg.shard_id, self.sim.now)

    def _check(self) -> None:
        for shard_id in self.detector.check(self.sim.now):
            self.failovers.append({
                "shard": shard_id, "detected_at": self.sim.now,
            })
            self.watched[shard_id].begin_failover("heartbeat")
        self.sim.after(self.p.heartbeat_interval_us, self._check)


class ShardedSimCluster:
    """N shard groups (each a full SimCluster: master + witnesses + backups)
    sharing one simulated network, behind the protocol-layer KeyRouter.

    Exposes the same client-facing surface as SimCluster (``mode``,
    ``route``, ``on_completion``), so SimClient drives either transparently.
    """

    def __init__(self, sim: Sim, net: Network, params: SimParams, mode: str,
                 f: int, n_shards: int,
                 backup_service_us: Optional[float] = None,
                 router: Optional[SlotRouter] = None,
                 armor: Optional[ArmorConfig] = None,
                 enforce_ownership: bool = False) -> None:
        self.sim = sim
        self.net = net
        self.p = params
        self.mode = mode
        self.f = f
        self.n_shards = n_shards
        # Routing is slot-table based; pass a custom router to simulate a
        # post-migration placement (e.g. fig_migration's rebalanced skew80
        # run) — the default is the uniform round-robin map.
        self.router = router if router is not None else KeyRouter(n_shards)
        self.shards = [
            SimCluster(sim, net, params, mode, f,
                       backup_service_us=backup_service_us, armor=armor)
            for _ in range(n_shards)
        ]
        self.clients: List[SimClient] = []
        self.completions: List[float] = []
        # -- timed slot migration state ------------------------------------
        self._frozen: set = set()           # slots mid-handover (NOT_OWNER)
        self.migrations: List[dict] = []
        self._mig_session = ClientSession(client_id=1)  # migration RPC ids
        if enforce_ownership:
            # Masters answer NOT_OWNER for keys their shard does not own
            # under the LIVE map (or that are frozen mid-handover) — this is
            # what makes client-cached slot maps observable: a stale cache
            # draws NOT_OWNER instead of silently landing on the old owner.
            for i, s in enumerate(self.shards):
                s.set_owned_filter(self._make_owned_filter(i))

    def _make_owned_filter(self, shard_id: int):
        def owns(key) -> bool:
            slot = self.router.slot_of(key)
            return self.router.slot_map[slot] == shard_id \
                and slot not in self._frozen
        return owns

    # -- timed slot migration (freeze -> transfer -> flip) ---------------------
    def migrate_slot_at(self, t: float, slot: int, dst: int) -> None:
        """Schedule a live handover of ``slot`` to shard ``dst`` inside the
        timed transport: the slot freezes (donor answers NOT_OWNER; clients
        with the stale map pay the §3.6 refetch), the resident keys + live
        RIFL completions transfer after a size-dependent delay as one
        MIGRATE_IN absorb on the receiver, then the map flips (version
        bump) and the slot thaws.  Requires enforce_ownership=True."""
        self.sim.at(t, lambda: self._migrate_slot(slot, dst))

    def _migrate_slot(self, slot: int, dst: int) -> None:
        src = self.router.slot_map[slot]
        if src == dst or slot in self._frozen:
            return
        donor = self.shards[src]
        recv = self.shards[dst]
        wd = self.sim.watchdog
        if wd is not None and wd.chaos.skip_fence \
                and not wd.chaos.fired("skip_fence"):
            # Chaos: start the handover WITHOUT freezing the slot — the
            # donor keeps executing client writes mid-migration (two owners;
            # the single-owner monitor must notice).  The freeze event below
            # is still journaled: it marks where the fence SHOULD hold.
            wd.chaos.fire("skip_fence")
        else:
            self._frozen.add(slot)
        if wd is not None:
            wd.journal.emit("freeze", actor="migration", slot=slot,
                            src=src, dst=dst)
        t_freeze = self.sim.now
        n_resident = sum(
            1 for k in donor.master_node.core.store.keys()
            if self.router.slot_of(k) == slot
        )
        transfer_us = 20.0 + n_resident * self.p.restore_per_entry_us \
            + 4 * self.p.one_way_delay_us

        def transfer() -> None:
            # Freeze held while the delay elapsed, so this state is exactly
            # what was durable when clients stopped landing on the donor.
            d_core = donor.master_node.core
            kvs = tuple(
                (k, d_core.store.get(k)) for k in d_core.store.keys()
                if self.router.slot_of(k) == slot
            )
            records: Dict[tuple, tuple] = {}
            for e in d_core.log:
                op = e.op
                if op.op_type in (OpType.MIGRATE_IN, OpType.MIGRATE_OUT):
                    continue
                if not op.keys or not all(
                        self.router.slot_of(k) == slot for k in op.keys):
                    continue
                rec = d_core.rifl.check_duplicate(op.rpc_id)
                if rec is None:
                    continue
                records[(op.rpc_id, op.key_hashes())] = (
                    op.rpc_id, op.key_hashes(), rec.result
                )
            for (rpc_id, khs), result in d_core.migrated_rifl.items():
                if all(self.router.slot_of_hash(kh) == slot for kh in khs):
                    records[(rpc_id, khs)] = (rpc_id, khs, result)
            # Commit point: flip the map (bumps router.version) and thaw,
            # then absorb — all inside this one callback, so no client event
            # can interleave between the flip and the MIGRATE_IN apply.  The
            # flip must come first or the receiver's own ownership filter
            # would reject the still-frozen slot.
            self.router.assign([slot], dst)
            self._frozen.discard(slot)
            if self.sim.watchdog is not None:
                self.sim.watchdog.journal.emit(
                    "handover", actor="migration", slot=slot,
                    src=src, dst=dst,
                )
            if kvs or records:
                op = Op(
                    OpType.MIGRATE_IN,
                    tuple(k for k, _ in kvs),
                    (kvs, tuple(records.values())),
                    self._mig_session.next_rpc_id(),
                )
                r_core = recv.master_node.core
                verdict, result = r_core.handle_update(
                    op, r_core.witness_list_version, (), now=self.sim.now
                )
                assert verdict in (FAST, SYNCED, DUP), (verdict, result.error)
                # The absorb is one log entry; charge the receiver for it.
                recv.master_node.occupy(
                    1.0 + len(kvs) * self.p.restore_per_entry_us
                )
            self.migrations.append({
                "slot": slot, "src": src, "dst": dst,
                "frozen_at": t_freeze, "committed_at": self.sim.now,
                "keys_moved": len(kvs) if (kvs or records) else 0,
                "rifl_moved": len(records),
            })
        self.sim.after(transfer_us, transfer)

    def route(self, op: Op) -> SimCluster:
        sids = {self.router.shard_of(k) for k in op.keys}
        if len(sids) != 1:
            # Mirror ShardedCluster._group_for: the sim models per-shard
            # placement, so a cross-shard op must fail loudly, not land
            # whole on keys[0]'s shard.
            raise ValueError(f"op spans shards {sorted(sids)}; "
                             "sharded sim clients issue single-shard ops")
        return self.shards[sids.pop()]

    def on_completion(self, t: float) -> None:
        self.completions.append(t)

    def crash_shard_at(self, t: float, shard: int) -> None:
        """Crash exactly one shard's master; the other shards keep serving
        and none of their witnesses are frozen."""
        self.shards[shard].crash_master_at(t)

    @property
    def recovery_reports(self) -> Dict[int, dict]:
        return {i: s.recovery_report for i, s in enumerate(self.shards)
                if s.recovery_report is not None}

    def master_stats(self) -> dict:
        agg: Dict[str, int] = {}
        for s in self.shards:
            for k, v in s.master_node.core.stats.items():
                agg[k] = agg.get(k, 0) + v
        return agg


@dataclass
class ScenarioResult:
    mode: str
    f: int
    n_clients: int
    update_latencies: list
    read_latencies: list
    throughput_ops_per_sec: float
    fast_fraction: float
    completed: int
    history: list
    recovery: Optional[dict]
    master_stats: dict
    sim_time_us: float


def _spawn_clients(sim, net, p, cluster, n_clients, n_ops, op_factory):
    if op_factory is None:
        counter = [0]

        def op_factory(session: ClientSession) -> Op:
            counter[0] += 1
            return session.op_set(f"key{session.client_id}_{counter[0]}", "v")

    for i in range(n_clients):
        session = ClientSession(client_id=10_000 + i)
        c = SimClient(sim, net, p, session, f"client{i}", cluster,
                      n_ops, op_factory)
        cluster.clients.append(c)
        c.start()


def _collect_run(cluster, warmup_frac: float):
    """Aggregate client-side results after sim.run: latencies, fast/slow
    counts, history (with never-completed "maybe" ops for the checker), and
    warmup-windowed aggregate throughput."""
    upd, rd = [], []
    fast = slow = 0
    history = []
    for c in cluster.clients:
        if c.pending is not None and not c.pending.done:
            # Never completed: a "maybe" op for the linearizability checker.
            c._record_history(c.pending, value=None, failed=True)
    for c in cluster.clients:
        for lat, t, is_update in c.latencies:
            (upd if is_update else rd).append(lat)
        fast += c.fast_completions
        slow += c.rtt2_completions
        history.extend(c.history)
    completions = sorted(cluster.completions)
    completed = len(completions)
    if completed > 20:
        lo = completions[int(completed * warmup_frac)]
        hi = completions[-1]
        n_mid = completed - int(completed * warmup_frac) - 1
        thr = n_mid / (hi - lo) * 1e6 if hi > lo else 0.0
    else:
        thr = 0.0
    return upd, rd, fast, slow, history, completed, thr


def run_scenario(
    mode: str = "curp",
    f: int = 3,
    n_clients: int = 1,
    n_ops: int = 2000,
    seed: int = 0,
    params: Optional[SimParams] = None,
    op_factory: Optional[Callable[[ClientSession], Op]] = None,
    crash_at_us: Optional[float] = None,
    backup_service_us: Optional[float] = None,
    warmup_frac: float = 0.1,
    watchdog: Any = None,
) -> ScenarioResult:
    p = params or DEFAULT
    sim = Sim(seed=seed)
    net = Network(sim, p)
    cluster = SimCluster(sim, net, p, mode, f,
                         backup_service_us=backup_service_us)
    if watchdog is not None:
        watchdog.attach(sim, cluster, f=f, mode=mode)
    _spawn_clients(sim, net, p, cluster, n_clients, n_ops, op_factory)

    if crash_at_us is not None:
        cluster.crash_master_at(crash_at_us)

    sim.run(until=60_000_000.0)  # 60 simulated seconds hard cap

    upd, rd, fast, slow, history, completed, thr = _collect_run(
        cluster, warmup_frac
    )
    if watchdog is not None:
        watchdog.finalize(sim.now)
    return ScenarioResult(
        mode=mode, f=f, n_clients=n_clients,
        update_latencies=upd, read_latencies=rd,
        throughput_ops_per_sec=thr,
        fast_fraction=fast / max(1, fast + slow),
        completed=completed,
        history=history,
        recovery=cluster.recovery_report,
        master_stats=dict(cluster.master_node.core.stats),
        sim_time_us=sim.now,
    )


@dataclass
class ShardedScenarioResult:
    mode: str
    f: int
    n_shards: int
    n_clients: int
    update_latencies: list
    read_latencies: list
    throughput_ops_per_sec: float   # aggregate committed-ops/s across shards
    fast_fraction: float
    completed: int
    history: list
    recoveries: Dict[int, dict]     # shard -> recovery report (crashed shards)
    master_stats: dict              # summed across shard masters
    per_shard_stats: List[dict]
    sim_time_us: float


@dataclass
class BatchedRunResult:
    """Result of a wall-clock batched-client run (see run_batched_throughput).

    Unlike ScenarioResult this is NOT simulated time: it measures the real
    host/device cost of driving the protocol through the batched client path
    (the quantity the fast-path refactor optimizes)."""
    n_shards: int
    batch_size: int
    n_batches: int
    ops: int
    wall_s: float
    ops_per_sec: float
    fast_fraction: float
    witness_accepts: int


def run_batched_throughput(
    n_shards: int = 2,
    batch_size: int = 64,
    n_batches: int = 10,
    f: int = 3,
    seed: int = 0,
    conflict_frac: float = 0.0,
    witness_backend: str = "python",
    geometry=None,
    workload=None,
    tracer=None,
) -> BatchedRunResult:
    """Drive a real ShardedCluster through the batched client path
    (update_batch) with a BatchedWorkload and measure wall-clock throughput
    + fast-path ratio.  With ``witness_backend="device"`` each shard's
    witnesses resolve every batch in one set-parallel kernel dispatch.

    ``workload`` must follow the BatchedWorkload interface — a ``batch(
    session) -> list[Op]`` method and a ``batch_size`` attribute.  The
    per-op workloads (UniformWriteWorkload etc.) are callables, not batch
    generators, and are rejected up front.
    """
    import time as _time

    from repro.core import ShardedCluster

    from .workload import BatchedWorkload

    cluster = ShardedCluster(
        n_shards=n_shards, f=f, seed=seed, witness_backend=witness_backend,
        geometry=geometry,
    )
    cluster.tracer = tracer
    session = cluster.new_client()
    wl = workload or BatchedWorkload(
        batch_size=batch_size, conflict_frac=conflict_frac, seed=seed
    )
    if not callable(getattr(wl, "batch", None)) or \
            not hasattr(wl, "batch_size"):
        raise TypeError(
            "workload must expose batch(session) and batch_size "
            "(BatchedWorkload interface); per-op workloads are not batched"
        )
    # Warm outside the timed window: two batches compile the fused
    # record/fast-path kernels, and an explicit sync on every shard compiles
    # the gc kernel at its drain-time shape — otherwise the first in-window
    # drain pays the compile and the recorded kops is cold-start noise, not
    # steady-state protocol cost.
    cluster.update_batch(session, wl.batch(session))
    cluster.update_batch(session, wl.batch(session))
    for _g in cluster.shards:
        _g.sync_now()
    fast = slow = accepts = 0
    t0 = _time.perf_counter()
    for _ in range(n_batches):
        outs = cluster.update_batch(session, wl.batch(session))
        for o in outs:
            if o.fast_path:
                fast += 1
            else:
                slow += 1
            accepts += o.witness_accepts
    wall = _time.perf_counter() - t0
    ops = n_batches * wl.batch_size
    return BatchedRunResult(
        n_shards=n_shards, batch_size=wl.batch_size, n_batches=n_batches,
        ops=ops, wall_s=wall, ops_per_sec=ops / wall if wall > 0 else 0.0,
        fast_fraction=fast / max(1, fast + slow),
        witness_accepts=accepts,
    )


# --------------------------------------------------------------------------
# Open-loop timed workload (production traffic armor)
# --------------------------------------------------------------------------
@dataclass
class _OlOp:
    """In-flight state for one open-loop op (the hub's PendingOp)."""
    op: Op
    session: ClientSession
    is_update: bool
    t_invoke: float
    shard_idx: int = 0
    attempts: int = 0
    master_result: Optional[ExecResult] = None
    witness_statuses: List[RecordStatus] = field(default_factory=list)
    want_witnesses: int = 0
    sync_requested: bool = False
    done: bool = False
    span_id: Optional[int] = None   # root trace span (tracer attached runs)


class OpenLoopDriver(Node):
    """Open-loop client tier: ops arrive on a nonhomogeneous-Poisson clock
    (diurnal ramps, flash crowds) and are issued IMMEDIATELY — no op ever
    waits for another's response, so offered load is set by the arrival
    process, not by server latency.  That is what makes overload visible:
    a closed loop self-throttles, an open loop buries a slow server.

    One hub node stands in for 10^5–10^6 client machines (sessions are
    materialized lazily per client id); its service time is ~0 so the
    client tier is never the bottleneck being measured.  Retries use
    capped exponential backoff + jitter (ol_* params); explicit MShedResp
    replies back off on a separate (linear, jittered) schedule.  The hub
    caches the slot map and pays the §3.6 config refetch only when a
    master answers NOT_OWNER, and runs one client-side circuit breaker
    per shard (armor runs only)."""

    def __init__(self, sim, net, params, cluster, workload,
                 use_breakers: bool = False,
                 record_history: bool = False) -> None:
        super().__init__(sim, "openloop-hub")
        self.net = net
        self.p = params
        self.cluster = cluster
        self.workload = workload
        self.record_history = record_history
        self.sessions: Dict[int, ClientSession] = {}
        self.inflight: Dict[tuple, _OlOp] = {}
        # Client-cached routing state (§3.6): a stale map draws NOT_OWNER
        # and only then pays config_fetch_us for a fresh snapshot.
        self._router = getattr(cluster, "router", None)
        self._slot_map = list(self._router.slot_map) if self._router else None
        self._map_version = self._router.version if self._router else 0
        self._refetching = False
        n_shards = getattr(cluster, "n_shards", 1)
        self.breakers: Dict[int, CircuitBreaker] = {
            i: CircuitBreaker(params.breaker_failures,
                              params.breaker_reset_us,
                              params.breaker_probes)
            for i in range(n_shards)
        } if use_breakers else {}
        self._t_end = 0.0
        self.stats = {
            "issued": 0, "completed": 0, "failed": 0, "timeouts": 0,
            "sheds_seen": 0, "breaker_fast_fails": 0, "refetches": 0,
            "not_owner": 0, "stale_config": 0, "sync_paths": 0,
        }
        self.fast_completions = 0
        self.rtt2_completions = 0
        self.latencies: List[Tuple[float, float, bool]] = []
        self.issue_times: List[float] = []
        self.history: List[dict] = []

    def service_time(self, msg) -> float:
        return 0.0   # the hub aggregates many machines; never the bottleneck

    # -- arrivals ---------------------------------------------------------------
    def start(self, t_end: float) -> None:
        self._t_end = t_end
        self.sim.after(self.workload.next_interarrival(self.sim.now),
                       self._arrive)

    def _arrive(self) -> None:
        if self.sim.now >= self._t_end:
            return
        self._issue()
        self.sim.after(self.workload.next_interarrival(self.sim.now),
                       self._arrive)

    def _issue(self) -> None:
        cid = self.workload.next_client()
        session = self.sessions.get(cid)
        if session is None:
            session = self.sessions[cid] = ClientSession(
                client_id=1_000_000 + cid)
        op = self.workload.make_op(session)
        st = _OlOp(op=op, session=session, is_update=op.is_update,
                   t_invoke=self.sim.now)
        self.inflight[op.rpc_id] = st
        self.stats["issued"] += 1
        self.issue_times.append(self.sim.now)
        if self.sim.watchdog is not None:
            self.sim.watchdog.op_invoked(op.rpc_id, self.sim.now)
        if self.sim.tracer is not None:
            # Root span for the whole op lifetime; every server-side span
            # for this RIFL id parents to it.
            st.span_id = self.sim.tracer.begin(
                op.rpc_id, "op", self.sim.now, actor=self.name,
                args={"type": op.op_type.name, "update": st.is_update})
        self._attempt(st)

    # -- routing (cached slot map) -----------------------------------------------
    def _shard_of(self, op: Op) -> int:
        if self._router is None:
            return 0
        return self._slot_map[self._router.slot_of(op.keys[0])]

    def _target(self, shard_idx: int):
        shards = getattr(self.cluster, "shards", None)
        return shards[shard_idx] if shards is not None else self.cluster

    def _refetch_map(self) -> None:
        if self._refetching or self._router is None:
            return
        self._refetching = True

        def done() -> None:
            self._refetching = False
            self._slot_map = list(self._router.slot_map)
            self._map_version = self._router.version
            self.stats["refetches"] += 1
        self.sim.after(self.p.config_fetch_us, done)

    # -- attempts -----------------------------------------------------------------
    def _attempt(self, st: _OlOp) -> None:
        if st.done:
            return
        st.shard_idx = self._shard_of(st.op)
        br = self.breakers.get(st.shard_idx)
        if br is not None and not br.allow(self.sim.now):
            # Breaker OPEN: fail fast locally — no packet, no server work —
            # and come back after a backoff instead of piling onto a shard
            # that is down or mid-handover.
            self.stats["breaker_fast_fails"] += 1
            self._backoff(st, self.p.ol_backoff_base_us)
            return
        target = self._target(st.shard_idx)
        master = target.master_node
        op = st.op
        t0 = self.sim.now
        wd = self.sim.watchdog
        record_wits = st.is_update and self.cluster.mode == "curp"
        if record_wits and wd is not None and wd.chaos.early_ack \
                and not wd.chaos.fired("early_ack"):
            # Chaos: skip the witness records entirely for one op — the
            # client then acks on the master result alone (0 accepts), i.e.
            # an ack without f-durability.  Only the durability monitor can
            # tell this apart from a legitimate 1-RTT completion.
            wd.chaos.fire("early_ack")
            record_wits = False
        if record_wits:
            wits = target.witness_nodes
            st.want_witnesses = len(wits)
            st.witness_statuses = []
            att = st.attempts
            for k, w in enumerate(wits):
                self.sim.at(
                    t0 + (k + 1) * self.p.client_record_send_cost_us,
                    lambda w=w, op=op, att=att: self.net.send(
                        w, MRecord(self, target.master_id, op, att)
                    ),
                )
            t0 += len(wits) * self.p.client_record_send_cost_us
        else:
            st.want_witnesses = 0
            st.witness_statuses = []
        t0 += self.p.client_send_cost_us
        if st.is_update:
            msg = MUpdate(self, op, target.wlv, st.session.acks())
        else:
            msg = MRead(self, op)
        self.sim.at(t0, lambda: self.net.send(master, msg, size_bytes=256))
        rpc_id, attempt = op.rpc_id, st.attempts
        self.sim.after(self.p.rpc_timeout_us,
                       lambda: self._check_timeout(rpc_id, attempt))

    def _check_timeout(self, rpc_id, attempt) -> None:
        st = self.inflight.get(rpc_id)
        if st is None or st.done or st.attempts != attempt:
            return
        self.stats["timeouts"] += 1
        if self.sim.tracer is not None:
            self.sim.tracer.instant(rpc_id, "timeout", self.sim.now,
                                    actor=self.name,
                                    args={"attempt": attempt})
        br = self.breakers.get(st.shard_idx)
        if br is not None:
            br.record_failure(self.sim.now)
        self._backoff(st, self.p.ol_backoff_base_us, exponential=True)

    def _backoff(self, st: _OlOp, base_us: float,
                 exponential: bool = False) -> None:
        """Count an attempt; give up past ol_max_attempts, else schedule a
        jittered retry (capped exponential for timeouts, capped linear for
        explicit sheds and breaker fast-fails)."""
        st.attempts += 1
        if st.attempts >= self.p.ol_max_attempts:
            self._give_up(st)
            return
        if exponential:
            delay = min(base_us * (2 ** (st.attempts - 1)),
                        self.p.ol_backoff_cap_us)
        else:
            delay = min(base_us * st.attempts, self.p.ol_backoff_cap_us)
        delay *= 1.0 + self.p.ol_backoff_jitter * (
            2 * self.sim.rng.random() - 1)
        self.sim.after(delay, lambda: self._resend(st))

    def _resend(self, st: _OlOp) -> None:
        if st.done:
            return
        st.master_result = None
        st.sync_requested = False
        self._attempt(st)

    def _give_up(self, st: _OlOp) -> None:
        st.done = True
        self.inflight.pop(st.op.rpc_id, None)
        self.stats["failed"] += 1
        if self.sim.watchdog is not None:
            self.sim.watchdog.op_failed({
                "client": st.session.client_id, "op": st.op,
                "invoke": st.t_invoke, "complete": None,
                "value": None, "failed": True,
            })
        if self.sim.tracer is not None:
            self.sim.tracer.end(st.span_id, self.sim.now, status="failed")
        # The client walks away: RIFL may reclaim the completion record (the
        # op stays a "maybe" for the checker — it may or may not have run).
        st.session.abandon(st.op.rpc_id)
        if self.record_history:
            self._record(st, value=None, failed=True)

    # -- responses -----------------------------------------------------------------
    def handle(self, msg) -> None:
        rpc_id = getattr(msg, "rpc_id", None)
        st = self.inflight.get(rpc_id)
        if st is None or st.done:
            return
        if isinstance(msg, MShedResp):
            # Explicit backpressure: the server is alive but full.  Back off
            # harder than a normal retry, and do NOT count it against the
            # breaker (a shed is a healthy signal, not a dead shard).
            self.stats["sheds_seen"] += 1
            self._backoff(st, self.p.ol_shed_backoff_us)
            return
        if isinstance(msg, MUpdateResp):
            if not msg.result.ok:
                br = self.breakers.get(st.shard_idx)
                if msg.result.error == "NOT_OWNER":
                    # Stale cached slot map (§3.6): refetch, then retry
                    # against the fresh map.
                    self.stats["not_owner"] += 1
                    if self.sim.tracer is not None:
                        self.sim.tracer.instant(rpc_id, "not_owner",
                                                self.sim.now,
                                                actor=self.name)
                    if br is not None:
                        br.record_failure(self.sim.now)
                    self._refetch_map()
                else:
                    self.stats["stale_config"] += 1
                st.attempts += 1
                if st.attempts >= self.p.ol_max_attempts:
                    self._give_up(st)
                    return
                self.sim.after(self.p.config_fetch_us,
                               lambda: self._resend(st))
                return
            st.master_result = msg.result
        elif isinstance(msg, MRecordResp):
            if msg.attempt != st.attempts:
                return   # stale response from a pre-retry witness set
            st.witness_statuses.append(msg.status)
        elif isinstance(msg, MSyncResp):
            if st.master_result is None:
                return
            self._complete(st, st.master_result, rtts=3)
            return
        else:
            return
        self._evaluate(st)

    def _evaluate(self, st: _OlOp) -> None:
        if st.master_result is None:
            return
        if not st.is_update or self.cluster.mode != "curp":
            self._complete(st, st.master_result,
                           rtts=2 if st.master_result.synced else 1)
            return
        if st.master_result.synced:
            self._complete(st, st.master_result, rtts=2)
            return
        if len(st.witness_statuses) < st.want_witnesses:
            return
        d = decide(st.master_result, st.witness_statuses)
        if d is Decision.COMPLETE:
            self._complete(st, st.master_result, rtts=1)
        elif not st.sync_requested:
            st.sync_requested = True
            self.stats["sync_paths"] += 1
            self.sim.after(
                self.p.client_send_cost_us,
                lambda: self.net.send(
                    self._target(st.shard_idx).master_node,
                    MSyncReq(self, st.op.rpc_id),
                ),
            )

    def _complete(self, st: _OlOp, result, rtts: int) -> None:
        st.done = True
        self.inflight.pop(st.op.rpc_id, None)
        wd = self.sim.watchdog
        if wd is not None:
            wd.journal.emit("ack", actor=self.name, rpc=st.op.rpc_id,
                            rtts=rtts)
            wd.op_completed({
                "client": st.session.client_id, "op": st.op,
                "invoke": st.t_invoke, "complete": self.sim.now,
                "value": result.value if result else None, "failed": False,
            })
        if self.sim.tracer is not None:
            self.sim.tracer.end(st.span_id, self.sim.now,
                                status=f"{rtts}rtt")
        lat = self.sim.now - st.t_invoke
        self.latencies.append((lat, self.sim.now, st.is_update))
        if rtts == 1:
            self.fast_completions += 1
        else:
            self.rtt2_completions += 1
        st.session.mark_completed(st.op.rpc_id)
        br = self.breakers.get(st.shard_idx)
        if br is not None:
            br.record_success()
        self.stats["completed"] += 1
        if self.record_history:
            self._record(st, value=result.value if result else None)

    def _record(self, st: _OlOp, value, failed: bool = False) -> None:
        self.history.append({
            "client": st.session.client_id,
            "op": st.op,
            "invoke": st.t_invoke,
            "complete": None if failed else self.sim.now,
            "value": value,
            "failed": failed,
        })


@dataclass
class OpenLoopResult:
    mode: str
    armored: bool
    duration_us: float
    issued: int
    completed: int
    failed: int
    offered_ops_per_sec: float      # arrivals in the measure window
    goodput_ops_per_sec: float      # completions in-window AND under SLO
    completed_ops_per_sec: float    # completions in-window (any latency)
    slo_us: float
    p50_us: float
    p99_us: float
    p999_us: float
    fast_fraction: float
    client_stats: dict              # OpenLoopDriver.stats
    breaker_stats: dict             # summed across per-shard breakers
    armor_stats: dict               # summed across masters (incl. retired)
    witness_sheds: int
    max_qdepth: int                 # deepest master RPC queue seen anywhere
    recoveries: Dict[int, dict]
    failovers: List[dict]           # coordinator-detected (heartbeat)
    migrations: List[dict]
    history: list
    sim_time_us: float


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[idx]


def run_openloop_scenario(
    workload=None,
    duration_us: float = 20_000.0,
    mode: str = "curp",
    f: int = 1,
    n_shards: int = 1,
    armor: Any = None,               # None/False, True, or an ArmorConfig
    params: Optional[SimParams] = None,
    seed: int = 0,
    slo_us: float = 50.0,
    heartbeat: bool = False,
    fail_master_at: Optional[Dict[int, float]] = None,
    migrate_slots: Optional[List[Tuple[float, int, int]]] = None,
    warmup_frac: float = 0.2,
    record_history: bool = False,
    tracer: Any = None,
    watchdog: Any = None,
) -> OpenLoopResult:
    """Drive an open-loop timed workload against a (possibly sharded,
    possibly armored) cluster and measure SLO survival.

    ``armor=True`` builds an ArmorConfig from params and also enables the
    client-side circuit breakers; ``armor=None/False`` is the naked
    baseline (unbounded queues, no shedding, no breakers).
    ``fail_master_at`` maps shard index -> silent-kill time; with
    ``heartbeat=True`` a SimCoordinator detects the silence and drives
    failover — the harness never schedules recovery itself.
    ``migrate_slots`` is a list of (t_us, slot, dst_shard) live handovers
    (sharded runs only; implies ownership enforcement).
    ``tracer`` (repro.core.telemetry.Tracer) attaches the flight recorder:
    every sim actor emits causal spans keyed by RIFL id, closed out at
    scenario teardown so in-flight ops never leak open spans.
    ``watchdog`` (repro.sim.watchdog.Watchdog) attaches the always-on
    protocol watchdog: journal emit hooks light up on every actor and the
    invariant monitors (incl. the windowed linearizability checker) run
    inside the event loop; ``watchdog.finalize`` is called at teardown."""
    from .workload import OpenLoopWorkload

    p = params or DEFAULT
    sim = Sim(seed=seed)
    sim.tracer = tracer
    net = Network(sim, p)
    if isinstance(armor, ArmorConfig):
        armor_cfg = armor
    elif armor:
        armor_cfg = ArmorConfig(
            queue_capacity=p.admit_queue_depth,
            witness_queue_capacity=p.admit_queue_depth_witness,
            throttle_rate=p.throttle_rate_ops_per_us,
            throttle_burst=p.throttle_burst,
            degrade_hi=p.degrade_hi_frac,
            degrade_lo=p.degrade_lo_frac,
        )
    else:
        armor_cfg = None

    if n_shards > 1:
        cluster = ShardedSimCluster(
            sim, net, p, mode, f, n_shards, armor=armor_cfg,
            enforce_ownership=bool(migrate_slots),
        )
        shard_clusters = cluster.shards
    else:
        cluster = SimCluster(sim, net, p, mode, f, armor=armor_cfg)
        shard_clusters = [cluster]

    if watchdog is not None:
        watchdog.attach(sim, cluster, f=f, mode=mode)

    coord = None
    if heartbeat:
        coord = SimCoordinator(sim, net, p)
        for i, s in enumerate(shard_clusters):
            coord.watch(i, s)
    for shard_idx, t in (fail_master_at or {}).items():
        shard_clusters[shard_idx].fail_master_at(t)
    for t, slot, dst in (migrate_slots or []):
        cluster.migrate_slot_at(t, slot, dst)

    wl = workload or OpenLoopWorkload(rate_ops_per_us=0.5, seed=seed)
    driver = OpenLoopDriver(sim, net, p, cluster, wl,
                            use_breakers=armor_cfg is not None,
                            record_history=record_history)
    driver.start(duration_us)
    # Arrivals stop at duration_us; leave room for retries/backoff to drain
    # and for any in-flight failover to finish.
    drain_us = max(20 * p.rpc_timeout_us,
                   p.ol_max_attempts * p.ol_backoff_cap_us / 4)
    sim.run(until=duration_us + drain_us)
    if tracer is not None:
        tracer.close_open(sim.now)
    if watchdog is not None:
        watchdog.finalize(sim.now)

    # -- measure window: [warmup, end of arrivals] ---------------------------
    w_lo, w_hi = duration_us * warmup_frac, duration_us
    window_s = (w_hi - w_lo) / 1e6
    offered = sum(1 for t in driver.issue_times if w_lo <= t < w_hi)
    in_window = [(lat, t) for lat, t, _ in driver.latencies
                 if w_lo <= t < w_hi]
    good = sum(1 for lat, _ in in_window if lat <= slo_us)
    lats = sorted(lat for lat, _ in in_window)

    armor_stats: Dict[str, int] = {}
    max_qdepth = 0
    witness_sheds = 0
    for s in shard_clusters:
        for m in [s.master_node] + s.master_nodes_retired:
            for k, v in m.armor_stats.items():
                armor_stats[k] = armor_stats.get(k, 0) + v
            max_qdepth = max(max_qdepth, m.max_qdepth)
        for w in s.witness_nodes:
            if w.admission is not None:
                witness_sheds += w.admission.shed
    breaker_stats: Dict[str, int] = {}
    for br in driver.breakers.values():
        for k, v in br.stats.items():
            breaker_stats[k] = breaker_stats.get(k, 0) + v

    if n_shards > 1:
        recoveries = cluster.recovery_reports
        migrations = cluster.migrations
    else:
        recoveries = ({0: cluster.recovery_report}
                      if cluster.recovery_report else {})
        migrations = []

    return OpenLoopResult(
        mode=mode,
        armored=armor_cfg is not None,
        duration_us=duration_us,
        issued=driver.stats["issued"],
        completed=driver.stats["completed"],
        failed=driver.stats["failed"],
        offered_ops_per_sec=offered / window_s if window_s > 0 else 0.0,
        goodput_ops_per_sec=good / window_s if window_s > 0 else 0.0,
        completed_ops_per_sec=(len(in_window) / window_s
                               if window_s > 0 else 0.0),
        slo_us=slo_us,
        p50_us=_percentile(lats, 0.50),
        p99_us=_percentile(lats, 0.99),
        p999_us=_percentile(lats, 0.999),
        fast_fraction=driver.fast_completions / max(
            1, driver.fast_completions + driver.rtt2_completions),
        client_stats=dict(driver.stats),
        breaker_stats=breaker_stats,
        armor_stats=armor_stats,
        witness_sheds=witness_sheds,
        max_qdepth=max_qdepth,
        recoveries=recoveries,
        failovers=list(coord.failovers) if coord else [],
        migrations=migrations,
        history=driver.history,
        sim_time_us=sim.now,
    )


# --------------------------------------------------------------------------
# Mini-transaction crash scenarios (repro.core.txn)
# --------------------------------------------------------------------------
# Message-level coordinator crash points, one per 2PC stage: the coordinator
# dies with the named message (and everything after it) unsent.
#   prepare-sent : first PREPARE sent, the rest never leave the coordinator
#   prepared     : every PREPARE sent and voted, no decision message sent
#   commit-sent  : first COMMIT sent, the rest never leave the coordinator
TXN_CRASH_STAGES = ("prepare-sent", "prepared", "commit-sent")

_STAGE_TO_HOOK = {
    "prepare-sent": ("prepare", 1),
    "prepared": ("decide", 0),
    "commit-sent": ("decide", 1),
}


@dataclass
class TxnScenarioResult:
    """Result of one crash-injected transaction run (instant transport —
    the protocol steps are the real ones; repro.sim timing is orthogonal)."""
    stage: str
    n_txns: int
    committed: int
    aborted: int
    crashed_decision: Optional[str]    # how resolution decided the orphan
    intents_after: int                 # undecided intents left anywhere (0!)
    history_ok: bool                   # strict multi-key checker verdict
    offending_key: Optional[str]
    fast_single: float                 # 1-RTT fraction of single-shard txns
    fast_multi: float                  # all-legs-fast fraction of 2PC txns
    final_reads: dict                  # key -> value after recovery


def run_txn_crash_scenario(
    stage: str = "prepared",
    n_shards: int = 3,
    n_txns: int = 20,
    crash_txn: Optional[int] = None,
    participant_crash: bool = False,
    seed: int = 0,
    witness_backend: str = "python",
    workload=None,
) -> TxnScenarioResult:
    """Drive cross-shard transactions through a real ShardedCluster with a
    coordinator crash injected at a 2PC message boundary, then recover and
    validate atomicity.

    One transaction (``crash_txn``, default: the middle one) crashes its
    coordinator at ``stage`` (see TXN_CRASH_STAGES).  If
    ``participant_crash``, a participant master holding the orphaned intent
    is then crashed and recovered (backup restore + witness replay
    re-surface the intent; recovery resolves it).  Otherwise the orphan is
    resolved lazily — the next conflicting read trips TXN_PENDING and the
    cluster applies the Sinfonia recovery rule.  Every key the workload
    touched is read back at the end, and the STRICT multi-key checker runs
    over the full history: a torn transaction write fails it.
    """
    from repro.core import CoordinatorCrash, ShardedCluster, TxnStatus

    from .workload import TxnWorkload

    assert stage in TXN_CRASH_STAGES, stage
    cluster = ShardedCluster(n_shards=n_shards, f=3, seed=seed,
                             witness_backend=witness_backend)
    session = cluster.new_client()
    wl = workload or TxnWorkload(n_shards=n_shards, cross_shard_frac=0.7,
                                 seed=seed)
    crash_txn = n_txns // 2 if crash_txn is None else crash_txn
    hook_stage, hook_idx = _STAGE_TO_HOOK[stage]

    def crash_hook(s, shard_id, idx):
        if s == hook_stage and idx == hook_idx:
            raise CoordinatorCrash()

    committed = aborted = 0
    fast = {"single": [0, 0], "multi": [0, 0]}   # [fast, total]
    touched: set = set()
    crashed_spec = None
    for i in range(n_txns):
        writes, reads = wl.next_txn()
        touched.update(k for k, _ in writes)
        touched.update(reads)
        spec = session.txn_spec(writes, reads)
        is_multi = len(spec.parts) > 1
        # Crash the first MULTI-shard txn at/after the target index (only a
        # 2PC has message boundaries to crash at).
        if crashed_spec is None and i >= crash_txn and is_multi:
            try:
                cluster.txn(session, writes, reads, spec=spec,
                            on_message=crash_hook)
                raise AssertionError("crash hook did not fire")
            except CoordinatorCrash:
                crashed_spec = spec
            continue
        out = cluster.txn(session, writes, reads, spec=spec)
        if out.status is TxnStatus.COMMITTED:
            committed += 1
            bucket = fast["multi" if is_multi else "single"]
            bucket[0] += int(out.fast_path)
            bucket[1] += 1
        else:
            aborted += 1

    crashed_decision = None
    if participant_crash and crashed_spec is not None:
        # Kill a participant master that holds the orphaned intent; its
        # recovery re-surfaces the intent and resolves it cluster-wide.
        victim = next(
            (p.shard_id for p in crashed_spec.parts
             if cluster.shards[p.shard_id].master.store.txn_intent(
                 crashed_spec.txn_id) is not None),
            crashed_spec.parts[0].shard_id,
        )
        rep = cluster.crash_master(victim)
        if rep.txn_resolved:
            crashed_decision = ("COMMITTED" if rep.txn_committed
                                else "ABORTED")
    # Final reads of every touched key: lazy resolution (TXN_PENDING ->
    # resolve -> retry) finishes any remaining orphan on first contact.
    final_reads = {}
    for k in sorted(touched):
        final_reads[k] = cluster.read(session, session.op_get(k)).value
    if crashed_spec is not None and crashed_decision is None:
        from repro.core.txn import participant_state

        states = {
            p.shard_id: participant_state(
                cluster.shards[p.shard_id].master, crashed_spec, p)
            for p in crashed_spec.parts
        }
        if any(s in ("committed", "decided") for s in states.values()):
            crashed_decision = "COMMITTED"
        elif any(s == "aborted" for s in states.values()):
            crashed_decision = "ABORTED"
    intents_after = sum(
        len(g.master.store.txn_intents()) for g in cluster.shards
    )
    ok, key = check_linearizable_strict(cluster.history)
    return TxnScenarioResult(
        stage=stage, n_txns=n_txns, committed=committed, aborted=aborted,
        crashed_decision=crashed_decision, intents_after=intents_after,
        history_ok=ok, offending_key=key,
        fast_single=fast["single"][0] / max(1, fast["single"][1]),
        fast_multi=fast["multi"][0] / max(1, fast["multi"][1]),
        final_reads=final_reads,
    )


def run_sharded_scenario(
    n_shards: int = 4,
    mode: str = "curp",
    f: int = 3,
    n_clients: int = 8,
    n_ops: int = 2000,
    seed: int = 0,
    params: Optional[SimParams] = None,
    op_factory: Optional[Callable[[ClientSession], Op]] = None,
    crash_shard_at: Optional[Tuple[float, int]] = None,
    backup_service_us: Optional[float] = None,
    warmup_frac: float = 0.1,
    router: Optional[SlotRouter] = None,
    watchdog: Any = None,
) -> ShardedScenarioResult:
    """Timed sharded run: clients route each op to its owning shard's master
    and witness group.  ``crash_shard_at=(t_us, shard)`` kills exactly that
    shard's master; the rest of the cluster keeps serving.  ``router``
    overrides the slot map (simulate a rebalanced placement)."""
    p = params or DEFAULT
    sim = Sim(seed=seed)
    net = Network(sim, p)
    cluster = ShardedSimCluster(sim, net, p, mode, f, n_shards,
                                backup_service_us=backup_service_us,
                                router=router)
    if watchdog is not None:
        watchdog.attach(sim, cluster, f=f, mode=mode)
    _spawn_clients(sim, net, p, cluster, n_clients, n_ops, op_factory)

    if crash_shard_at is not None:
        t, shard = crash_shard_at
        cluster.crash_shard_at(t, shard)

    sim.run(until=60_000_000.0)  # 60 simulated seconds hard cap

    upd, rd, fast, slow, history, completed, thr = _collect_run(
        cluster, warmup_frac
    )
    if watchdog is not None:
        watchdog.finalize(sim.now)
    return ShardedScenarioResult(
        mode=mode, f=f, n_shards=n_shards, n_clients=n_clients,
        update_latencies=upd, read_latencies=rd,
        throughput_ops_per_sec=thr,
        fast_fraction=fast / max(1, fast + slow),
        completed=completed,
        history=history,
        recoveries=cluster.recovery_reports,
        master_stats=cluster.master_stats(),
        per_shard_stats=[dict(s.master_node.core.stats)
                         for s in cluster.shards],
        sim_time_us=sim.now,
    )


# --------------------------------------------------------------------------
# Timed 2PC coordinator: concurrent prepare fan-out (ROADMAP follow-on)
# --------------------------------------------------------------------------
class SimTxnClient(Node):
    """Timed mini-transaction coordinator over the sharded sim.

    ``mode="fanout"`` sends every PREPARE leg (witness records + update RPC)
    at the same time and every decide leg at the same time — the true
    2-round transaction shape, wall-clock ≈ 2 RTTs regardless of span.
    ``mode="sequential"`` drives legs one at a time (the instant harness's
    old shape, ≈ 2·span RTTs) for comparison.  ``mode="mset"`` issues the
    same key set as per-shard MSET sub-ops concurrently (durable, NOT
    atomic) — the 1-round baseline the 2PC's extra decide round is measured
    against.

    A leg voting NO (intent conflict across concurrent coordinators) aborts
    the transaction: decide legs carry TXN_ABORT instead of TXN_COMMIT.
    """

    def __init__(self, sim, net, params, session: ShardedClientSession,
                 name: str, cluster: ShardedSimCluster, n_txns: int,
                 txn_factory, mode: str = "fanout") -> None:
        super().__init__(sim, name)
        assert mode in ("fanout", "sequential", "mset"), mode
        self.net = net
        self.p = params
        self.session = session
        self.cluster = cluster
        self.n_txns = n_txns
        self.txn_factory = txn_factory
        self.mode = mode
        self.completed = 0
        self.committed = 0
        self.aborted = 0
        self.latencies: List[float] = []
        self.pending: Optional[dict] = None

    def service_time(self, msg) -> float:
        if isinstance(msg, MRecordResp):
            return 0.1
        return self.p.client_recv_cost_us

    # -- issuing ------------------------------------------------------------
    def start(self) -> None:
        self.sim.after(self.sim.rng.random() * 1.0, self._issue_next)

    def _issue_next(self) -> None:
        if self.completed >= self.n_txns:
            return
        writes, reads = self.txn_factory()
        if self.mode == "mset":
            parts = self.session.mset_parts(writes)
            legs = {
                sid: {"op": op, "shard": sid, "result": None,
                      "statuses": [], "want": 0, "sync_req": False,
                      "done": False}
                for sid, op in parts.items()
            }
            self.pending = {"stage": "mset", "legs": legs,
                            "t0": self.sim.now, "by_rpc": {
                                leg["op"].rpc_id: leg for leg in legs.values()
                            }}
            for leg in legs.values():
                self._send_update_leg(leg, with_records=True)
            return
        from repro.core.txn import prepare_op

        spec = self.session.txn_spec(writes, reads)
        legs = {}
        for part in spec.parts:
            legs[part.shard_id] = {
                "part": part, "shard": part.shard_id,
                "op": prepare_op(spec, part), "result": None,
                "statuses": [], "want": 0, "sync_req": False, "done": False,
            }
        self.pending = {
            "stage": "prepare", "spec": spec, "legs": legs,
            "t0": self.sim.now, "order": [p.shard_id for p in spec.parts],
            "sent": 0,
            "by_rpc": {leg["op"].rpc_id: leg for leg in legs.values()},
        }
        if self.mode == "sequential":
            self._send_update_leg(legs[self.pending["order"][0]],
                                  with_records=True)
            self.pending["sent"] = 1
        else:
            for leg in legs.values():
                self._send_update_leg(leg, with_records=True)
            self.pending["sent"] = len(legs)

    def _send_update_leg(self, leg: dict, with_records: bool) -> None:
        target = self.cluster.shards[leg["shard"]]
        op = leg["op"]
        t0 = self.sim.now
        if with_records and op.is_update:
            wits = target.witness_nodes
            leg["want"] = len(wits)
            for k, w in enumerate(wits):
                self.sim.at(
                    t0 + (k + 1) * self.p.client_record_send_cost_us,
                    lambda w=w, op=op, mid=target.master_id:
                    self.net.send(w, MRecord(self, mid, op)),
                )
            t0 += len(wits) * self.p.client_record_send_cost_us
        t0 += self.p.client_send_cost_us
        msg = MUpdate(self, op, target.wlv, self.session.acks())
        self.sim.at(t0, lambda: self.net.send(target.master_node, msg,
                                              size_bytes=256))

    # -- responses ----------------------------------------------------------
    def handle(self, msg) -> None:
        p = self.pending
        if p is None:
            return
        if isinstance(msg, (MUpdateResp, MRecordResp, MSyncResp)):
            leg = p["by_rpc"].get(msg.rpc_id)
            if leg is None or leg["done"]:
                return
            if isinstance(msg, MUpdateResp):
                leg["result"] = msg.result
            elif isinstance(msg, MRecordResp):
                leg["statuses"].append(msg.status)
            else:
                leg["done"] = True
            self._evaluate_leg(leg)

    def _evaluate_leg(self, leg: dict) -> None:
        if leg["done"]:
            self._advance()
            return
        res = leg["result"]
        if res is None:
            return
        if not res.ok:
            # Vote NO (intent conflict): the leg is complete, nothing durable.
            leg["done"] = True
            leg["no"] = True
            self._advance()
            return
        if self.pending["stage"] == "decide":
            leg["done"] = True     # decide legs need no witness accepts
            self._advance()
            return
        if res.synced:
            leg["done"] = True
            self._advance()
            return
        if len(leg["statuses"]) < leg["want"]:
            return
        if decide(res, leg["statuses"]) is Decision.COMPLETE:
            leg["done"] = True
            self._advance()
        elif not leg["sync_req"]:
            leg["sync_req"] = True
            target = self.cluster.shards[leg["shard"]]
            self.sim.after(
                self.p.client_send_cost_us,
                lambda: self.net.send(target.master_node,
                                      MSyncReq(self, leg["op"].rpc_id)),
            )

    def _advance(self) -> None:
        p = self.pending
        legs = p["legs"]
        if self.mode == "sequential" and p["sent"] < len(p["order"]):
            # One leg at a time, in BOTH rounds (the pre-fan-out baseline).
            nxt = legs[p["order"][p["sent"]]]
            p["sent"] += 1
            self._send_update_leg(nxt, with_records=p["stage"] != "decide")
            return
        if not all(leg["done"] for leg in legs.values()):
            return
        if p["stage"] == "mset":
            self._complete()
            return
        if p["stage"] == "prepare":
            from repro.core.txn import abort_op, commit_op

            for leg in legs.values():
                self.session.mark_completed(leg["op"].rpc_id)
            commit = not any(leg.get("no") for leg in legs.values())
            p["stage"] = "decide"
            p["commit"] = commit
            spec = p["spec"]
            decide_legs = {}
            for part in spec.parts:
                op = (commit_op(spec, part) if commit
                      else abort_op(spec, part))
                decide_legs[part.shard_id] = {
                    "op": op, "shard": part.shard_id, "result": None,
                    "statuses": [], "want": 0, "sync_req": False,
                    "done": False,
                }
            p["legs"] = decide_legs
            p["by_rpc"] = {leg["op"].rpc_id: leg
                           for leg in decide_legs.values()}
            if self.mode == "sequential":
                p["sent"] = 1
                self._send_update_leg(decide_legs[p["order"][0]],
                                      with_records=False)
            else:
                p["sent"] = len(decide_legs)
                for leg in decide_legs.values():
                    self._send_update_leg(leg, with_records=False)
            return
        # decide stage fully acked
        self._complete()

    def _complete(self) -> None:
        p = self.pending
        for leg in p["legs"].values():
            self.session.mark_completed(leg["op"].rpc_id)
        self.latencies.append(self.sim.now - p["t0"])
        if p["stage"] == "decide" and not p.get("commit", True):
            self.aborted += 1
        else:
            self.committed += 1
        self.completed += 1
        self.cluster.on_completion(self.sim.now)
        self.pending = None
        self._issue_next()


@dataclass
class TimedTxnResult:
    """Wall-clock (simulated) latency of the timed transaction coordinator."""
    mode: str
    n_shards: int
    span: int
    completed: int
    committed: int
    aborted: int
    mean_us: float
    p50_us: float
    p99_us: float


def run_timed_txn_scenario(
    mode: str = "fanout",
    n_shards: int = 4,
    span: int = 3,
    n_txns: int = 60,
    n_clients: int = 2,
    seed: int = 0,
    params: Optional[SimParams] = None,
) -> TimedTxnResult:
    """Measure true timed 2PC latency in the discrete-event transport.

    ``fanout`` drives prepare legs concurrently (the ROADMAP follow-on);
    ``sequential`` is the one-leg-at-a-time baseline; ``mset`` is the
    non-atomic per-shard 1-round comparison on the same key pattern.
    """
    from .workload import TxnWorkload

    p = params or DEFAULT
    sim = Sim(seed=seed)
    net = Network(sim, p)
    cluster = ShardedSimCluster(sim, net, p, "curp", 3, n_shards)
    wl = TxnWorkload(n_shards=n_shards, cross_shard_frac=1.0,
                     span_shards=span, keys_per_txn=span, seed=seed + 1)
    clients = []
    for i in range(n_clients):
        session = ShardedClientSession(20_000 + i, cluster.router)
        c = SimTxnClient(sim, net, p, session, f"txn{i}", cluster,
                         n_txns, wl.next_txn, mode=mode)
        clients.append(c)
        c.start()
    sim.run(until=60_000_000.0)
    lats = sorted(l for c in clients for l in c.latencies)

    def pct(q: float) -> float:
        return lats[min(len(lats) - 1, int(q * len(lats)))] if lats else 0.0

    return TimedTxnResult(
        mode=mode, n_shards=n_shards, span=span,
        completed=sum(c.completed for c in clients),
        committed=sum(c.committed for c in clients),
        aborted=sum(c.aborted for c in clients),
        mean_us=sum(lats) / len(lats) if lats else 0.0,
        p50_us=pct(0.5), p99_us=pct(0.99),
    )


# --------------------------------------------------------------------------
# Live slot-migration scenario (repro.core.migration) under traffic + crash
# --------------------------------------------------------------------------
@dataclass
class MigrationScenarioResult:
    """One live reshard under continuous client traffic (instant transport —
    the protocol steps are the real ones, like run_txn_crash_scenario)."""
    windows: List[dict]            # per-window: phase, ops, fast, redirects
    steady_fast: float             # fast-path ratio before the reshard
    migration_fast_untouched: float  # fast ratio of NON-moving-slot ops
    redirects: int                 # retryable SlotMoving redirects seen
    redirected_retried_ok: int     # redirected writes that landed on retry
    mismatches: int                # final reads disagreeing with the shadow
    history_ok: bool
    offending_key: Optional[str]
    reports: list                  # MigrationReports of every handover
    crash: Optional[str]
    resumed: int                   # handovers that survived a crash-resume


def run_migration_scenario(
    n_shards_before: int = 2,
    n_shards_after: int = 4,
    n_slots: int = 64,
    ops_per_window: int = 30,
    n_keys: int = 160,
    n_clients: int = 3,
    crash: Optional[str] = None,     # None | "donor" | "receiver"
    seed: int = 0,
    read_frac: float = 0.25,
) -> MigrationScenarioResult:
    """Live-reshard a ShardedCluster ``n_shards_before -> n_shards_after``
    while clients keep writing/reading, optionally crashing the donor or the
    receiver master mid-handover (after the transfer, before the commit) and
    resuming.  Validates the acceptance criteria end to end: a shadow map
    catches lost/duplicated writes, the strict multi-key checker runs over
    the full history, redirected writes are re-issued and must land, and the
    fast-path ratio is tracked separately for ops on untouched slots.
    """
    import random as _random

    from repro.core import ShardedCluster
    from repro.core.migration import SlotMoving

    # A small sync batch keeps the unsynced windows (and with them the
    # baseline conflict rate) at steady state from the first measured
    # window — the fast-ratio comparison is then apples to apples.
    cluster = ShardedCluster(n_shards=n_shards_before, f=3, n_slots=n_slots,
                             sync_batch=8, seed=seed)
    sessions = [cluster.new_client() for _ in range(n_clients)]
    rng = _random.Random(seed)
    keys = [f"mk{i}" for i in range(n_keys)]
    shadow: Dict[str, str] = {}
    deferred: List[Tuple[str, str]] = []
    windows: List[dict] = []
    redirects = 0
    retried_ok = 0
    seq = 0
    # Slots scheduled to move at any point in the reshard ("touched").
    desired = [s % n_shards_after for s in range(n_slots)]
    touched = {s for s in range(n_slots)
               if desired[s] != cluster.router.slot_map[s]}

    def flush_deferred() -> None:
        nonlocal retried_ok
        still: List[Tuple[str, str]] = []
        for k, v in deferred:
            sess = rng.choice(sessions)
            op = sess.op_set(k, v)
            try:
                # Redirected ops were never accepted anywhere: re-issue
                # under a FRESH identity from the (new) owner.
                out = cluster.update(sess, op)
                assert out.value == "OK"
                shadow[k] = v
                retried_ok += 1
            except SlotMoving:
                sess.abandon(op.rpc_id)
                still.append((k, v))
        deferred[:] = still

    # Pooled fast/total counters over UNTOUCHED-slot writes, keyed by phase
    # kind — totals beat means-of-window-ratios statistically (the windows
    # are small).
    pooled = {"steady": [0, 0], "migrate": [0, 0]}

    def run_window(phase: str) -> None:
        nonlocal seq, redirects
        flush_deferred()
        fast = tot = fast_u = tot_u = n_redir = 0
        for _ in range(ops_per_window):
            sess = rng.choice(sessions)
            k = rng.choice(keys)
            untouched = cluster.router.slot_of(k) not in touched
            if rng.random() < read_frac:
                op = sess.op_get(k)
                try:
                    got = cluster.read(sess, op).value
                    assert got == shadow.get(k), (k, got, shadow.get(k))
                except SlotMoving:
                    sess.abandon(op.rpc_id)   # never transmitted
                    n_redir += 1
                continue
            seq += 1
            v = f"v{seq}"
            op = sess.op_set(k, v)
            try:
                out = cluster.update(sess, op)
            except SlotMoving:
                # Never transmitted: release the identity and re-issue
                # fresh after the handover (flush_deferred).
                sess.abandon(op.rpc_id)
                n_redir += 1
                deferred.append((k, v))
                continue
            shadow[k] = v
            tot += 1
            fast += int(out.fast_path)
            if untouched:
                tot_u += 1
                fast_u += int(out.fast_path)
                if phase.startswith("steady"):
                    pooled["steady"][0] += int(out.fast_path)
                    pooled["steady"][1] += 1
                elif phase.startswith("migrate"):
                    pooled["migrate"][0] += int(out.fast_path)
                    pooled["migrate"][1] += 1
        redirects += n_redir
        windows.append({
            "phase": phase, "t": len(windows), "ops": tot,
            "fast_frac": fast / tot if tot else None,
            "fast_frac_untouched": fast_u / tot_u if tot_u else None,
            "redirects": n_redir,
        })

    # -- warmup (unmeasured) + steady state before --------------------------
    for _ in range(2):
        run_window("warmup")
    for _ in range(4):
        run_window("steady-before")

    # -- grow + live reshard ------------------------------------------------
    for _ in range(n_shards_before, n_shards_after):
        cluster.add_shard()
    reports = []
    crashed = False
    resumed = 0
    for dst in range(n_shards_before, n_shards_after):
        slots = [s for s in range(n_slots) if desired[s] == dst]
        for mig in cluster.start_migration(slots, dst):
            while mig.stage != "done":
                stage = mig.step()
                if (crash and not crashed and stage == "handover"):
                    # Mid-handover: transfer done, commit pending.
                    victim = mig.src if crash == "donor" else mig.dst
                    cluster.crash_master(victim)
                    mig.resume()
                    crashed = True
                run_window(f"migrate->{dst}")
            resumed += mig.resumed
            reports.append(mig.report())

    # -- steady state after -------------------------------------------------
    for _ in range(4):
        run_window("steady-after")
    flush_deferred()
    assert not deferred, "redirected writes never landed"

    # -- verification -------------------------------------------------------
    sess = sessions[0]
    mismatches = 0
    for k in keys:
        got = cluster.read(sess, sess.op_get(k)).value
        if got != shadow.get(k):
            mismatches += 1
    ok, off = check_linearizable_strict(cluster.history)

    # Untouched-slot fast ratios from the POOLED counters: steady spans both
    # the before and after phases (same placement-independent workload), so
    # the comparison against the migration window is apples to apples.
    steady = (pooled["steady"][0] / pooled["steady"][1]
              if pooled["steady"][1] else 0.0)
    mig_untouched = (pooled["migrate"][0] / pooled["migrate"][1]
                     if pooled["migrate"][1] else 0.0)
    return MigrationScenarioResult(
        windows=windows,
        steady_fast=steady,
        migration_fast_untouched=mig_untouched,
        redirects=redirects,
        redirected_retried_ok=retried_ok,
        mismatches=mismatches,
        history_ok=ok,
        offending_key=off,
        reports=reports,
        crash=crash,
        resumed=resumed,
    )
