"""repro.sim — discrete-event simulation of CURP clusters.

Timing model calibrated to the paper's RAMCloud/Redis numbers (see params.py
for the napkin math); protocol logic is repro.core, unchanged.  Sharded
scenarios (multi-master, per-shard witnesses) run via run_sharded_scenario.
"""
from .curp_sim import (
    TXN_CRASH_STAGES,
    BatchedRunResult,
    MigrationScenarioResult,
    OpenLoopDriver,
    OpenLoopResult,
    ScenarioResult,
    ShardedScenarioResult,
    ShardedSimCluster,
    SimCluster,
    SimCoordinator,
    SimTxnClient,
    TimedTxnResult,
    TxnScenarioResult,
    run_batched_throughput,
    run_migration_scenario,
    run_openloop_scenario,
    run_scenario,
    run_sharded_scenario,
    run_timed_txn_scenario,
    run_txn_crash_scenario,
)
from .linearizability import (
    WindowedChecker,
    check_linearizable,
    check_linearizable_strict,
    check_linearizable_windowed,
)
from .network import Network, Node, Sim
from .params import DEFAULT, SimParams
from .watchdog import (
    CHAOS_MONITOR,
    Breach,
    ChaosConfig,
    Watchdog,
    replay,
    run_intent_leak_scenario,
    run_watched_scenario,
)
from .workload import (
    BatchedWorkload,
    HotKeyWorkload,
    OpenLoopWorkload,
    ShardSkewedWorkload,
    TxnWorkload,
    UniformWriteWorkload,
    YcsbWorkload,
    ZipfianGenerator,
)

__all__ = [
    "BatchedRunResult", "ScenarioResult", "ShardedScenarioResult",
    "ShardedSimCluster", "SimCluster", "run_batched_throughput",
    "run_scenario", "run_sharded_scenario",
    "TXN_CRASH_STAGES", "TxnScenarioResult", "run_txn_crash_scenario",
    "MigrationScenarioResult", "run_migration_scenario",
    "SimTxnClient", "TimedTxnResult", "run_timed_txn_scenario",
    "OpenLoopDriver", "OpenLoopResult", "SimCoordinator",
    "run_openloop_scenario",
    "check_linearizable", "check_linearizable_strict",
    "check_linearizable_windowed", "WindowedChecker",
    "CHAOS_MONITOR", "Breach", "ChaosConfig", "Watchdog",
    "replay", "run_intent_leak_scenario", "run_watched_scenario",
    "Network", "Node", "Sim", "DEFAULT", "SimParams",
    "BatchedWorkload", "HotKeyWorkload", "OpenLoopWorkload",
    "ShardSkewedWorkload", "TxnWorkload",
    "UniformWriteWorkload", "YcsbWorkload", "ZipfianGenerator",
]
