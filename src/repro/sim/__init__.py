"""repro.sim — discrete-event simulation of CURP clusters.

Timing model calibrated to the paper's RAMCloud/Redis numbers (see params.py
for the napkin math); protocol logic is repro.core, unchanged.
"""
from .curp_sim import ScenarioResult, SimCluster, run_scenario
from .linearizability import check_linearizable
from .network import Network, Node, Sim
from .params import DEFAULT, SimParams
from .workload import UniformWriteWorkload, YcsbWorkload, ZipfianGenerator

__all__ = [
    "ScenarioResult", "SimCluster", "run_scenario", "check_linearizable",
    "Network", "Node", "Sim", "DEFAULT", "SimParams",
    "UniformWriteWorkload", "YcsbWorkload", "ZipfianGenerator",
]
