"""The NoSQL state machine that CURP replicates (§4).

A single substrate stands in for both evaluation targets of the paper
(RAMCloud and Redis): a key->value map where values are strings, counters, or
hashmaps.  ``execute`` is deterministic, so backup replay and witness replay
reproduce master state exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from .types import Op, OpType


# --- CRDT merge-op value semantics (repro.core.merge) -----------------------
# These three pure functions ARE the merge semantics: the store executes
# them, and sim.linearizability imports THEM (not re-implementations) so the
# checker's legality model cannot drift from the state machine.  Each is
# order-insensitive over concurrent applications of its own class, which is
# what makes the widened witness admissions linearizable.

def merge_sadd(cur: Any, member: Any) -> frozenset:
    """Set-union add.  A non-set prior value is superseded (SADD || SET is
    a lattice CONFLICT, so the overwrite is only reachable sequentially)."""
    base = cur if isinstance(cur, frozenset) else frozenset()
    return base | {member}


def merge_append(cur: Any, chunk: Any) -> Tuple[Any, ...]:
    """Append under the CANONICAL sorted-chunks value: the stored value is
    the sorted tuple of appended chunks, so any serialization of concurrent
    appends — and any witness-replay order — converges bit-identically."""
    if isinstance(cur, tuple):
        base = cur
    elif cur is None:
        base = ()
    else:
        base = (cur,)
    return tuple(sorted(base + (chunk,), key=repr))


def merge_max(cur: Any, n: Any) -> Any:
    """Bounded max: commutative and idempotent over numeric values; a
    non-numeric prior value is superseded (sequential-only, as above)."""
    if isinstance(cur, (int, float)) and isinstance(n, (int, float)):
        return max(cur, n)
    return n


@dataclass
class VersionedValue:
    value: Any
    version: int = 0
    # Timestamp of last update; masters compare against last-sync timestamp to
    # decide "is this object unsynced?" when not log-structured (§4.3).
    last_update: float = 0.0


class KVStore:
    """Deterministic key-value state machine.

    Mini-transaction state (repro.core.txn) lives INSIDE the store: prepared
    intents and their key locks are installed/dropped by executing the
    TXN_PREPARE / TXN_COMMIT / TXN_ABORT ops, so backup-log restore and
    witness replay rebuild them for free — a recovered master re-surfaces
    every undecided intent without any side-channel state.
    """

    def __init__(self) -> None:
        self._data: Dict[Any, VersionedValue] = {}
        # txn_id -> (TxnSpec, TxnPart): this store's prepared intents.
        self._intents: Dict[Tuple[int, int], Tuple[Any, Any]] = {}
        # key -> txn_id holding the intent lock on it.
        self._locks: Dict[Any, Tuple[int, int]] = {}

    # -- mutation -----------------------------------------------------------
    def execute(self, op: Op, now: float = 0.0) -> Any:
        t = op.op_type
        if t == OpType.TXN:
            # Single-shard atomic read-set + write-set: reads are taken
            # BEFORE the writes land (mini-transaction compare/read rule).
            spec, shard_id = op.args
            part = spec.part_on(shard_id)
            reads = tuple(self.get(k) for k in part.read_keys)
            for key, value in part.write_kvs:
                self._set(key, value, now)
            return ("COMMITTED", reads)
        if t == OpType.TXN_PREPARE:
            spec, shard_id = op.args
            part = spec.part_on(shard_id)
            self._intents[spec.txn_id] = (spec, part)
            for k in part.keys:
                self._locks[k] = spec.txn_id
            # Read values are stable until the decision: the locks block
            # every overlapping writer, so a prepare retry re-reads the
            # same values.
            reads = tuple(self.get(k) for k in part.read_keys)
            return ("PREPARED", reads)
        if t == OpType.TXN_COMMIT:
            spec, shard_id = op.args
            part = spec.part_on(shard_id)
            self._drop_intent(spec.txn_id, part)
            for key, value in part.write_kvs:
                self._set(key, value, now)
            return "COMMITTED"
        if t == OpType.TXN_ABORT:
            spec, shard_id = op.args
            part = spec.part_on(shard_id)
            self._drop_intent(spec.txn_id, part)
            return "ABORTED"
        if t == OpType.MIGRATE_IN:
            # Slot-handover absorb (repro.core.migration): install the moved
            # key/value snapshot.  args = (kvs, rifl_records); the records
            # are master-side state (Master._install_migrated), not store
            # state.  Idempotent — a crash-resumed handover re-sends the
            # full snapshot.
            for key, value in op.args[0]:
                self._set(key, value, now)
            return "OK"
        if t == OpType.MIGRATE_OUT:
            # Donor side of the handover: durably drop the moved keys (the
            # receiver owns them now; backups replay this on restore so a
            # recovered donor never resurrects them).
            n = 0
            for key in op.keys:
                if key in self._data:
                    del self._data[key]
                    n += 1
            return n
        if t == OpType.SET:
            (key,) = op.keys
            (value,) = op.args
            self._set(key, value, now)
            return "OK"
        if t == OpType.DEL:
            (key,) = op.keys
            existed = key in self._data
            self._data.pop(key, None)
            return int(existed)
        if t == OpType.INCR:
            (key,) = op.keys
            delta = op.args[0] if op.args else 1
            cur = self._data.get(key)
            base = cur.value if cur is not None and isinstance(cur.value, int) else 0
            new = base + delta
            self._set(key, new, now)
            return new
        if t == OpType.HMSET:
            (key,) = op.keys
            fields: Tuple[Tuple[Any, Any], ...] = op.args[0]
            cur = self._data.get(key)
            h = dict(cur.value) if cur is not None and isinstance(cur.value, dict) else {}
            for f, v in fields:
                h[f] = v
            self._set(key, h, now)
            return "OK"
        if t == OpType.SADD:
            (key,) = op.keys
            (member,) = op.args
            self._set(key, merge_sadd(self.get(key), member), now)
            return "OK"
        if t == OpType.APPEND:
            (key,) = op.keys
            (chunk,) = op.args
            self._set(key, merge_append(self.get(key), chunk), now)
            return "OK"
        if t == OpType.MAX:
            (key,) = op.keys
            (n,) = op.args
            self._set(key, merge_max(self.get(key), n), now)
            return "OK"
        if t == OpType.MSET:
            for key, value in zip(op.keys, op.args):
                self._set(key, value, now)
            return "OK"
        if t == OpType.GET:
            (key,) = op.keys
            cur = self._data.get(key)
            return None if cur is None else cur.value
        if t == OpType.NOOP:
            return None
        raise ValueError(f"unknown op type {t}")

    def _set(self, key: Any, value: Any, now: float) -> None:
        cur = self._data.get(key)
        if cur is None:
            self._data[key] = VersionedValue(value, 1, now)
        else:
            cur.value = value
            cur.version += 1
            cur.last_update = now

    # -- transaction intents (repro.core.txn) --------------------------------
    def _drop_intent(self, txn_id: Tuple[int, int], part) -> None:
        self._intents.pop(txn_id, None)
        for k in part.keys:
            if self._locks.get(k) == txn_id:
                del self._locks[k]

    def txn_intent(self, txn_id: Tuple[int, int]):
        """The (spec, part) of a prepared-but-undecided intent, or None."""
        return self._intents.get(txn_id)

    def txn_intents(self) -> Dict[Tuple[int, int], Tuple[Any, Any]]:
        return dict(self._intents)

    def txn_lock_conflict(self, keys, txn_id=None):
        """The spec of a FOREIGN transaction holding an intent lock on any of
        these keys (None if unlocked or locked only by ``txn_id``)."""
        for k in keys:
            owner = self._locks.get(k)
            if owner is not None and owner != txn_id:
                return self._intents[owner][0]
        return None

    # -- introspection ------------------------------------------------------
    def keys(self):
        """All live keys (migration scans these to find a slot's residents)."""
        return list(self._data.keys())

    def get(self, key: Any) -> Any:
        cur = self._data.get(key)
        return None if cur is None else cur.value

    def last_update_time(self, key: Any) -> Optional[float]:
        cur = self._data.get(key)
        return None if cur is None else cur.last_update

    def snapshot(self) -> Dict[Any, VersionedValue]:
        import copy

        return copy.deepcopy(self._data)

    def load_snapshot(self, snap: Dict[Any, VersionedValue]) -> None:
        import copy

        self._data = copy.deepcopy(snap)

    def __len__(self) -> int:
        return len(self._data)
