"""The NoSQL state machine that CURP replicates (§4).

A single substrate stands in for both evaluation targets of the paper
(RAMCloud and Redis): a key->value map where values are strings, counters, or
hashmaps.  ``execute`` is deterministic, so backup replay and witness replay
reproduce master state exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from .types import Op, OpType


@dataclass
class VersionedValue:
    value: Any
    version: int = 0
    # Timestamp of last update; masters compare against last-sync timestamp to
    # decide "is this object unsynced?" when not log-structured (§4.3).
    last_update: float = 0.0


class KVStore:
    """Deterministic key-value state machine."""

    def __init__(self) -> None:
        self._data: Dict[Any, VersionedValue] = {}

    # -- mutation -----------------------------------------------------------
    def execute(self, op: Op, now: float = 0.0) -> Any:
        t = op.op_type
        if t == OpType.SET:
            (key,) = op.keys
            (value,) = op.args
            self._set(key, value, now)
            return "OK"
        if t == OpType.DEL:
            (key,) = op.keys
            existed = key in self._data
            self._data.pop(key, None)
            return int(existed)
        if t == OpType.INCR:
            (key,) = op.keys
            delta = op.args[0] if op.args else 1
            cur = self._data.get(key)
            base = cur.value if cur is not None and isinstance(cur.value, int) else 0
            new = base + delta
            self._set(key, new, now)
            return new
        if t == OpType.HMSET:
            (key,) = op.keys
            fields: Tuple[Tuple[Any, Any], ...] = op.args[0]
            cur = self._data.get(key)
            h = dict(cur.value) if cur is not None and isinstance(cur.value, dict) else {}
            for f, v in fields:
                h[f] = v
            self._set(key, h, now)
            return "OK"
        if t == OpType.MSET:
            for key, value in zip(op.keys, op.args):
                self._set(key, value, now)
            return "OK"
        if t == OpType.GET:
            (key,) = op.keys
            cur = self._data.get(key)
            return None if cur is None else cur.value
        if t == OpType.NOOP:
            return None
        raise ValueError(f"unknown op type {t}")

    def _set(self, key: Any, value: Any, now: float) -> None:
        cur = self._data.get(key)
        if cur is None:
            self._data[key] = VersionedValue(value, 1, now)
        else:
            cur.value = value
            cur.version += 1
            cur.last_update = now

    # -- introspection ------------------------------------------------------
    def get(self, key: Any) -> Any:
        cur = self._data.get(key)
        return None if cur is None else cur.value

    def last_update_time(self, key: Any) -> Optional[float]:
        cur = self._data.get(key)
        return None if cur is None else cur.last_update

    def snapshot(self) -> Dict[Any, VersionedValue]:
        import copy

        return copy.deepcopy(self._data)

    def load_snapshot(self, snap: Dict[Any, VersionedValue]) -> None:
        import copy

        self._data = copy.deepcopy(snap)

    def __len__(self) -> int:
        return len(self._data)
