"""Master crash recovery (§3.3, §4.6).

Two steps: (1) restore from one backup (standard primary-backup restore —
CURP doesn't change it), then (2) replay from ONE witness: freeze it via
getRecoveryData, replay all held requests in any order (they are mutually
commutative by construction; RIFL filters those already on backups), sync the
result to backups, and hand out fresh witnesses under a bumped epoch +
WitnessListVersion.

Transaction intents (repro.core.txn) ride both steps for free: TXN_PREPARE
ops in the backup log and in witness data re-install their intents when
executed, so the recovered master re-surfaces every prepared-but-undecided
transaction; the enclosing cluster then resolves them (Sinfonia recovery
rule) so no intent outlives recovery undecided.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from .backup import Backup
from .config import ConfigManager
from .master import Master
from .witness import Witness


@dataclass
class RecoveryReport:
    restored_log_entries: int
    witness_requests: int
    replayed: int            # ops actually re-executed (not RIFL-filtered)
    new_epoch: int
    new_witness_list_version: int
    shard_id: int = 0        # which shard failed over (per-shard epochs)
    # Mini-transaction recovery (repro.core.txn): intents the recovered
    # master re-surfaced from its backup log + witness replay, and how the
    # post-recovery cluster-wide resolution sweep decided them.
    txn_intents: int = 0     # undecided intents present right after replay
    txn_resolved: int = 0
    txn_committed: int = 0
    txn_aborted: int = 0


def recover_master(
    *,
    shard_id: int,
    old_master_id: int,
    new_master: Master,
    backups: Sequence[Backup],
    recovery_witness: Witness,
    new_witnesses: Sequence[Witness],
    new_witness_ids: Tuple[int, ...],
    config: ConfigManager,
) -> RecoveryReport:
    """In-process recovery orchestration (the simulator mirrors these steps as
    timed RPCs; the logic and ordering are identical)."""
    # 1. Restore from any backup (they are interchangeable for a fully-synced
    #    prefix; we pick the longest log available).
    source = max(backups, key=len)
    log = source.get_log()
    new_master.restore_from_log(log)

    # 2. Freeze ONE witness (irreversible recovery mode) and replay.
    reqs = recovery_witness.get_recovery_data(old_master_id)
    replayed = new_master.replay_from_witness(reqs)

    # 3. Bump epoch BEFORE syncing so the new master's syncs pass the fence
    #    and any zombie old master is rejected from now on.
    cfg = config.fail_over(shard_id, new_master.master_id, new_witness_ids)
    new_master.epoch = cfg.epoch
    new_master.witness_list_version = cfg.witness_list_version
    for b in backups:
        b.set_epoch(cfg.epoch)

    # 4. Sync replayed ops to backups, then open fresh witnesses.
    req = new_master.begin_sync()
    if req is not None:
        for b in backups:
            resp = b.handle_sync(req)
            assert resp.ok, "fresh-epoch sync must not be fenced"
        new_master.complete_sync()

    for w in new_witnesses:
        w.start(new_master.master_id)

    return RecoveryReport(
        restored_log_entries=len(log),
        witness_requests=len(reqs),
        replayed=replayed,
        new_epoch=cfg.epoch,
        new_witness_list_version=cfg.witness_list_version,
        shard_id=shard_id,
        # Prepared-but-undecided intents survive into the new master (via
        # log restore and witness replay); the enclosing cluster resolves
        # them (repro.core.txn.resolve_pending) right after this returns.
        txn_intents=len(new_master.store.txn_intents()),
    )
