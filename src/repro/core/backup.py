"""CURP backup replica: ordered, durable log of executed operations.

CURP does not change the backup mechanism (§3.6): this is standard
primary-backup log replication.  Entries are (op, result) in master execution
order; restoring a master = replaying the log into a fresh state machine
(which also rebuilds the RIFL completion records, since ops carry rpc_ids and
results ride along — the parenthetical in §3.3).

Zombie defense (§4.7): backups track the master epoch published by the
configuration manager and reject sync RPCs from deposed masters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

from .types import BackupSyncReq, BackupSyncResp, Op


@dataclass
class LogEntry:
    op: Op
    result: Any


class Backup:
    def __init__(self, backup_id: int) -> None:
        self.backup_id = backup_id
        self.log: List[LogEntry] = []
        self.current_epoch = 0
        # Out-of-order segments (network reordering between independent sync
        # RPCs): held durably, applied once the gap fills.  get_log() exposes
        # only the contiguous prefix.
        self._pending: dict[int, Tuple[Any, ...]] = {}
        self.stats = {"syncs": 0, "entries": 0, "rejected_epoch": 0,
                      "buffered": 0}

    def set_epoch(self, epoch: int) -> None:
        """Configuration manager bumps the epoch when a new master takes over;
        sync RPCs from older epochs (zombies) are rejected afterwards."""
        self.current_epoch = max(self.current_epoch, epoch)

    def handle_sync(self, req: BackupSyncReq) -> BackupSyncResp:
        if req.epoch < self.current_epoch:
            self.stats["rejected_epoch"] += 1
            return BackupSyncResp(ok=False, synced_through=len(self.log))
        self.current_epoch = req.epoch
        if req.from_index > len(self.log):
            # Gap: an earlier segment is still in flight (reordering).  Hold
            # this one durably and apply once contiguous.
            self._pending[req.from_index] = req.entries
            self.stats["buffered"] += 1
            return BackupSyncResp(ok=True, synced_through=len(self.log))
        # Idempotent append (retries may resend a suffix we already hold).
        new = req.entries[len(self.log) - req.from_index:]
        for op, result in new:
            self.log.append(LogEntry(op, result))
        # Drain any buffered segments that are now contiguous.
        while True:
            for start in list(self._pending):
                if start <= len(self.log):
                    ents = self._pending.pop(start)
                    for op, result in ents[len(self.log) - start:]:
                        self.log.append(LogEntry(op, result))
                    break
            else:
                break
        self.stats["syncs"] += 1
        self.stats["entries"] += len(new)
        return BackupSyncResp(ok=True, synced_through=len(self.log))

    def get_log(self) -> Tuple[LogEntry, ...]:
        return tuple(self.log)

    def __len__(self) -> int:
        return len(self.log)
