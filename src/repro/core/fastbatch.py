"""Fused cluster batches: the whole multi-shard update hot loop in ONE
device dispatch (DESIGN.md §4, paper §3.2.3 + §4.2 + §4.4).

Two pieces live here:

``DeviceRing`` — the device-resident master window.  Each shard's unsynced
keyhashes (the contents of ``Master._unsynced_keyhash``) live in one row of
a [n_shards, CAP] ring buffer of mixed 2x32 keyhash lanes.  Entries are
appended by the fused kernel itself (one slot per executed op, batch order),
and the tail advances by pure host arithmetic when a sync round moves
``Master.synced_index`` — the kernel's liveness test ``(slot - tail) % CAP <
count`` needs no device writes to expire entries.  The ring is a *cache* of
master log state: each shard carries a coherence snapshot (log list
identity, log length, synced index) and any divergence — a crash, a
migration, an op that took the unfused path — just invalidates the row,
which rebuilds from ``log[synced_index:]`` on the next fused batch.

``FusedBatchDriver`` — drives ``ShardedCluster.update_batch`` through
``repro.kernels.gang_fastpath_batch``: keyhash -> slot route -> ring
conflict scan -> ring append -> witness record at every target shard's f
stacked gang lanes, ONE dispatch for the whole routed batch.  The master
rounds then run with the kernel's conflict bit passed as the ``commutes``
override, so the host ``_unsynced_keyhash`` dict is never consulted.

The driver is an *opportunistic* fast path: ``try_update_batch`` returns
None whenever anything falls off its eligibility envelope (multi-key or txn
ops, dropped witnesses, mid-reconfiguration state, ring overflow...) and the
caller runs the regular per-shard path.  Conflict bits from the ring can
only over-approximate the host window (mixed-lane collisions; intra-batch
predicted-execute ops that later RIFL-dup) — an op is never under-synced.
RIFL duplicates are predicted exactly in preflight (acks are applied first,
mirroring ``Master.handle_update`` order), so the ring admits exactly the
ops the masters go on to log.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set

import numpy as np

from .client import Decision
from .master import DUP, ERROR, SYNCED
from .types import Op, OpType, RecordStatus, WitnessMode

_M32 = 0xFFFFFFFF

# Ops the fused kernel understands: single-key plain updates whose merge
# lattice expands to exactly ONE (key_hash, class) pair — the kernel carries
# one class lane per op slot.  Everything else (txn legs, migration ops,
# multi-key msets, HMSETs with per-field FIELD pairs) has protocol side
# effects or pair fan-out the one-dispatch pipeline doesn't model and takes
# the regular path.
_PLAIN_UPDATES = {OpType.SET, OpType.INCR, OpType.HMSET, OpType.DEL,
                  OpType.SADD, OpType.APPEND, OpType.MAX}

RING_CAP = 1024


@dataclass
class _RingSnap:
    """Coherence snapshot of one shard's master log vs its ring row.

    ``log_ref`` pins the log *list object*: the log is append-only in place,
    so (same list, same length, same synced index) implies the unsynced
    window is bit-identical to what the ring row holds.  Recovery installs
    a fresh list (``restore_from_log``), failover installs a fresh master —
    both change the identity and invalidate the row.
    """
    log_ref: List[Any]
    log_len: int
    synced: int


class DeviceRing:
    """[n_shards, CAP] device-resident unsynced-window rings (mixed lanes)."""

    def __init__(self, n_shards: int, cap: int = RING_CAP) -> None:
        import jax.numpy as jnp

        self.cap = cap
        self.n_shards = n_shards
        self.hi = jnp.zeros((n_shards, cap), jnp.uint32)
        self.lo = jnp.zeros((n_shards, cap), jnp.uint32)
        self.cls = jnp.zeros((n_shards, cap), jnp.int32)
        self.tail = np.zeros(n_shards, np.int32)
        self.count = np.zeros(n_shards, np.int32)
        self._snap: Dict[int, _RingSnap] = {}

    # -- coherence ----------------------------------------------------------
    def invalidate(self, shard_id: int) -> None:
        self._snap.pop(shard_id, None)

    def _coherent(self, shard_id: int, master) -> bool:
        snap = self._snap.get(shard_id)
        return (
            snap is not None
            and snap.log_ref is master.log
            and snap.log_len == len(master.log)
            and snap.synced == master.synced_index
        )

    def ensure(self, shard_id: int, master, reserve: int) -> bool:
        """Make the shard's row mirror ``log[synced_index:]`` with room for
        ``reserve`` more appends; False means the window doesn't fit and the
        caller must decline (or drain first)."""
        if not self._coherent(shard_id, master):
            pairs = [pair for e in master.log[master.synced_index:]
                     for pair in e.op.hash_classes()]
            n = len(pairs)
            if n + reserve > self.cap:
                return False
            self._rebuild_row(shard_id, pairs)
            self._snap[shard_id] = _RingSnap(
                master.log, len(master.log), master.synced_index
            )
        return int(self.count[shard_id]) + reserve <= self.cap

    def _rebuild_row(self, shard_id: int, pairs: Sequence) -> None:
        """Mirror ``log[synced_index:]`` as (key_hash, class) lattice pairs —
        the same expansion the master's host window refcounts, so the
        kernel's matrix consult sees exactly the host conflict set."""
        import jax.numpy as jnp

        from repro.kernels import np_keyhash2x32

        hi = np.asarray(self.hi).copy()
        lo = np.asarray(self.lo).copy()
        cl = np.asarray(self.cls).copy()
        hi[shard_id] = 0
        lo[shard_id] = 0
        cl[shard_id] = 0
        if pairs:
            khs = [kh for kh, _c in pairs]
            k_hi = np.fromiter(((k >> 32) & _M32 for k in khs),
                               np.uint32, len(khs))
            k_lo = np.fromiter((k & _M32 for k in khs), np.uint32, len(khs))
            qh, ql = np_keyhash2x32(k_hi, k_lo)
            hi[shard_id, :len(khs)] = qh
            lo[shard_id, :len(khs)] = ql
            cl[shard_id, :len(khs)] = [c for _kh, c in pairs]
        self.hi = jnp.asarray(hi)
        self.lo = jnp.asarray(lo)
        self.cls = jnp.asarray(cl)
        self.tail[shard_id] = 0
        self.count[shard_id] = len(pairs)

    def committed(self, shard_id: int, master, appended: int) -> None:
        """The fused batch's master rounds are done: verify the masters
        logged exactly the ops the kernel appended, else drop the row."""
        snap = self._snap.get(shard_id)
        if snap is None:
            return
        if (snap.log_ref is master.log
                and len(master.log) == snap.log_len + appended
                and master.synced_index == snap.synced):
            snap.log_len += appended
        else:
            self.invalidate(shard_id)

    def advance(self, shard_id: int, master) -> None:
        """Expire entries a sync round just gc'd: pure host arithmetic on
        (tail, count) — the device rows are untouched."""
        snap = self._snap.get(shard_id)
        if snap is None:
            return
        if snap.log_ref is not master.log or snap.log_len != len(master.log):
            self.invalidate(shard_id)
            return
        if master.synced_index == snap.synced:
            return
        adv = sum(len(e.op.hash_classes())
                  for e in master.log[snap.synced:master.synced_index])
        if adv > int(self.count[shard_id]):
            self.invalidate(shard_id)
            return
        self.tail[shard_id] = (self.tail[shard_id] + adv) % self.cap
        self.count[shard_id] -= adv
        snap.synced = master.synced_index


class FusedBatchDriver:
    """One-dispatch multi-shard batches over the cluster's shared gang."""

    def __init__(self, cluster) -> None:
        self.cluster = cluster
        self.ring = DeviceRing(len(cluster.shards))
        self.stats = {"fused_batches": 0, "fused_ops": 0, "declined": 0}

    # -- plumbing -----------------------------------------------------------
    def _resize(self) -> None:
        if self.ring.n_shards != len(self.cluster.shards):
            self.ring = DeviceRing(len(self.cluster.shards))

    def _eligible_group(self, shard_id: int) -> bool:
        g = self.cluster.shards[shard_id]
        if g.retired or g._dropped_witnesses:
            return False
        cfg = self.cluster.config.fetch(shard_id)
        if (cfg.master_id != g.master.master_id
                or cfg.witness_list_version != g.master.witness_list_version):
            return False
        from .device_witness import DeviceWitness

        for w in g.witnesses:
            if (not isinstance(w, DeviceWitness)
                    or w.mode is not WitnessMode.NORMAL
                    or w.gang is not self.cluster.gang
                    or w.lane is None):
                return False
        return True

    # -- the fused path -----------------------------------------------------
    def try_update_batch(self, session, ops: Sequence[Op],
                         now: float = 0.0) -> Optional[List[Any]]:
        """Run the batch through the fused kernel; None = not eligible (the
        caller falls back to the per-shard path).  Raises SlotMoving for
        mid-handover slots exactly like the unfused route."""
        out = self._try(session, ops, now)
        if out is None:
            self.stats["declined"] += 1
        return out

    def _try(self, session, ops: Sequence[Op], now: float):
        cluster = self.cluster
        if cluster.gang is None or not ops:
            return None
        for op in ops:
            if op.op_type not in _PLAIN_UPDATES or len(op.keys) != 1:
                return None
            if len(op.hash_classes()) != 1:
                # HMSET with fields fans out to FIELD sub-pairs; the fused
                # kernel carries exactly one (hash, class) lane per op.
                return None
        if len({op.rpc_id for op in ops}) != len(ops):
            # An in-batch retry of the same rpc breaks exec prediction
            # (the first copy's completion lands mid-batch); rare — punt.
            return None
        self._resize()

        # Route every op (redirects raise SlotMoving before any side effect,
        # matching ShardedCluster._group_for's contract).
        slots = [cluster.router.slot_of(op.keys[0]) for op in ops]
        for s in slots:
            cluster.migration.check_slots({s})
        shard_ids = [cluster.router.slot_map[s] for s in slots]
        touched = sorted(set(shard_ids))
        for sid in touched:
            if not self._eligible_group(sid):
                return None

        # Master-side preflight: exact RIFL-duplicate prediction (acks are
        # applied FIRST, in handle_update order — idempotent, so the real
        # rounds re-applying them is harmless) + the error gates the per-op
        # path would retry or surface (txn locks, ownership).
        acks = session.acks()
        for sid in touched:
            cluster.shards[sid].master.rifl.apply_client_acks(acks)
        exec_pred = np.zeros(len(ops), np.int32)
        for b, op in enumerate(ops):
            m = cluster.shards[shard_ids[b]].master
            if not m.owns(op):
                return None
            if m.store.txn_lock_conflict(op.keys) is not None:
                return None
            dup = ((op.rpc_id, op.key_hashes()) in m.migrated_rifl
                   or m.rifl.check_duplicate(op.rpc_id) is not None)
            exec_pred[b] = 0 if dup else 1

        # Ring coherence + capacity (reserve = this batch's appends).
        per_shard_appends = {sid: 0 for sid in touched}
        for b, sid in enumerate(shard_ids):
            per_shard_appends[sid] += int(exec_pred[b])
        for sid in touched:
            if not self.ring.ensure(sid, cluster.shards[sid].master,
                                    per_shard_appends[sid]):
                return None

        # Committed to the fused path: feed the per-slot load counters the
        # routing step normally feeds.
        for s, sid in zip(slots, shard_ids):
            g = cluster.shards[sid]
            g.slot_ops[s] = g.slot_ops.get(s, 0) + 1

        return self._run(session, ops, now, shard_ids, touched, exec_pred,
                         per_shard_appends)

    def _run(self, session, ops, now, shard_ids, touched, exec_pred,
             per_shard_appends):
        from repro.kernels import gang_fastpath_batch

        from .local import OpOutcome

        cluster = self.cluster
        gang = cluster.gang
        f = len(cluster.shards[touched[0]].witnesses)
        lane_map = np.zeros((len(cluster.shards), f), np.int32)
        for g in cluster.shards:
            for j, w in enumerate(g.witnesses[:f]):
                lane_map[g.shard_id, j] = w.lane if w.lane is not None else 0

        pairs = [op.hash_classes()[0] for op in ops]   # eligibility: 1 pair
        khs = [kh for kh, _c in pairs]
        k_hi = np.fromiter(((k >> 32) & _M32 for k in khs),
                           np.uint32, len(khs))
        k_lo = np.fromiter((k & _M32 for k in khs), np.uint32, len(khs))
        k_cls = np.fromiter((c for _kh, c in pairs), np.int32, len(pairs))
        r_hi = np.fromiter((op.rpc_id[0] & _M32 for op in ops),
                           np.uint32, len(ops))
        r_lo = np.fromiter((op.rpc_id[1] & _M32 for op in ops),
                           np.uint32, len(ops))

        res = gang_fastpath_batch(
            gang.table, gang.n_sets, k_hi, k_lo, r_hi, r_lo, exec_pred,
            np.asarray(cluster.router.slot_map, np.int32), lane_map,
            self.ring.hi, self.ring.lo, self.ring.tail, self.ring.count,
            key_cls=k_cls, ring_cls=self.ring.cls, counters=gang.counters,
        )
        gang.table = res.table
        gang.counters = res.counters
        self.ring.hi = res.ring_hi
        self.ring.lo = res.ring_lo
        self.ring.cls = res.ring_cls
        self.ring.count = np.asarray(res.counts, np.int32).copy()
        assert list(res.shard_ids) == shard_ids, \
            "device slot routing diverged from the host router"
        self.stats["fused_batches"] += 1
        self.stats["fused_ops"] += len(ops)

        # Witness settle: fold each op's per-lane reason codes into mirror +
        # stats + RecordStatus, exactly as DeviceWitness.record_batch does.
        witnesses = {sid: cluster.shards[sid].witnesses for sid in touched}
        for ws in witnesses.values():
            for w in ws:
                w.stats["kernel_batches"] += 1
        statuses_per_op: List[List[RecordStatus]] = []
        for b, op in enumerate(ops):
            key = (int(res.q_hi[b]), int(res.q_lo[b]))
            statuses_per_op.append([
                w._settle(int(res.reasons[b, j]), [key], op.rpc_id, op,
                          [int(k_cls[b])])
                for j, w in enumerate(witnesses[shard_ids[b]])
            ])

        # Master rounds in op order, the ring's conflict bit standing in for
        # the host window lookup.
        acks = session.acks()
        need_drain: Set[int] = set()
        outcomes: List[OpOutcome] = []
        for b, op in enumerate(ops):
            g = cluster.shards[shard_ids[b]]
            cfg = cluster.config.fetch(g.shard_id)
            verdict, result = g.master.handle_update(
                op, cfg.witness_list_version, acks, now,
                commutes=not bool(res.conflicts[b]),
            )
            if verdict == ERROR:
                # Preflight closed every ERROR path; reaching here means the
                # invariants broke mid-batch.
                raise RuntimeError(
                    f"fused master round failed: {result.error}"
                )
            decision, rtts, fast = g._classify(
                verdict, result, statuses_per_op[b]
            )
            if verdict == SYNCED or decision is Decision.NEED_SYNC:
                need_drain.add(g.shard_id)
            session.mark_completed(op.rpc_id)
            if verdict != DUP:   # dups re-externalize the original, once
                g.record(op, result.value, session.client_id)
            outcomes.append(OpOutcome(
                value=result.value,
                rtts=rtts,
                fast_path=fast,
                synced_path=verdict == SYNCED,
                witness_accepts=sum(
                    1 for s in statuses_per_op[b]
                    if s is RecordStatus.ACCEPTED
                ),
            ))

        # Ring bookkeeping + the batched sync/gc tail (one drain per shard).
        for sid in touched:
            g = cluster.shards[sid]
            self.ring.committed(sid, g.master, per_shard_appends[sid])
            if sid in need_drain or (g.auto_sync and g.master.want_sync):
                g._drain_syncs()
            self.ring.advance(sid, g.master)
        return outcomes
