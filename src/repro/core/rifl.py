"""RIFL: Reusable Infrastructure For Linearizability (Lee et al., SOSP'15).

Exactly-once RPC semantics: masters keep a durable *completion record*
(rpc_id -> result) per update; duplicate invocations skip execution and return
the saved result.  CURP needs the two §4.8 modifications:

1. Client acks piggybacked on requests normally let the master delete
   completion records — but acks must be IGNORED while replaying from a
   witness, because witness replay arrives in arbitrary order.
2. A client lease may only expire after all of that client's operations have
   been synced to backups (the master must sync before honoring expiry).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, Optional, Tuple

from .telemetry import get_registry
from .types import CompletionRecord, RpcId


class RiflTable:
    def __init__(self) -> None:
        # client_id -> {seq -> CompletionRecord}
        self._records: Dict[int, Dict[int, CompletionRecord]] = {}
        # client_id -> first seq NOT yet acked (records below are deletable)
        self._acked_below: Dict[int, int] = {}
        self._expired_clients: set[int] = set()
        # §4.8 (1): during witness replay, acks must not delete records.
        self.replay_mode: bool = False
        self.stats = {"dup_hits": 0}
        self._m_dup_hits = get_registry().counter("rifl.dup_hits")

    # -- duplicate detection -------------------------------------------------
    def check_duplicate(self, rpc_id: RpcId) -> Optional[CompletionRecord]:
        """Returns the completion record if this RPC already executed."""
        client_id, seq = rpc_id
        rec = self._records.get(client_id, {}).get(seq)
        if rec is not None:
            self.stats["dup_hits"] += 1
            self._m_dup_hits.inc()
            return rec
        if client_id in self._expired_clients:
            # Expired client: all records gone; request must be ignored, not
            # re-executed (the paper requires sync-before-expiry so that this
            # can never lose a completed op).
            self.stats["dup_hits"] += 1
            self._m_dup_hits.inc()
            return CompletionRecord(rpc_id, None, synced=True)
        if seq < self._acked_below.get(client_id, 0):
            # Acked => client saw the result; duplicates are ignored.
            self.stats["dup_hits"] += 1
            self._m_dup_hits.inc()
            return CompletionRecord(rpc_id, None, synced=True)
        return None

    def record_completion(self, rpc_id: RpcId, result: Any, synced: bool) -> None:
        client_id, seq = rpc_id
        self._records.setdefault(client_id, {})[seq] = CompletionRecord(
            rpc_id, result, synced
        )

    def mark_synced_through(self, rpc_ids: Iterable[RpcId]) -> None:
        for client_id, seq in rpc_ids:
            rec = self._records.get(client_id, {}).get(seq)
            if rec is not None:
                rec.synced = True

    # -- garbage collection ---------------------------------------------------
    def apply_client_acks(self, acks: Iterable[Tuple[int, int]]) -> None:
        """acks = [(client_id, first_incomplete_seq)]: delete records below.

        No-op in replay mode (§4.8 modification 1).
        """
        if self.replay_mode:
            return
        for client_id, below in acks:
            cur = self._acked_below.get(client_id, 0)
            if below > cur:
                self._acked_below[client_id] = below
                recs = self._records.get(client_id)
                if recs:
                    for seq in [s for s in recs if s < below]:
                        del recs[seq]

    def expire_client(self, client_id: int, all_synced: bool) -> bool:
        """§4.8 modification 2: only allowed once the client's ops are synced."""
        if not all_synced:
            return False
        self._records.pop(client_id, None)
        self._expired_clients.add(client_id)
        return True

    def acked_frontier(self, client_id: int) -> int:
        """The applied ack frontier for one client: every seq below it has a
        client-acknowledged completion (records there are deletable).  The
        watchdog journals this per execution — the frontier regressing, or
        an op executing below it, is an exactly-once violation."""
        return self._acked_below.get(client_id, 0)

    # -- durability plumbing ---------------------------------------------------
    def unsynced_rpc_ids(self) -> Tuple[RpcId, ...]:
        out = []
        for client_id, recs in self._records.items():
            for seq, rec in recs.items():
                if not rec.synced:
                    out.append((client_id, seq))
        return tuple(out)

    def all_synced_for(self, client_id: int) -> bool:
        recs = self._records.get(client_id, {})
        return all(r.synced for r in recs.values())

    def snapshot(self):
        import copy

        return copy.deepcopy(
            (self._records, self._acked_below, self._expired_clients)
        )

    def load_snapshot(self, snap) -> None:
        import copy

        self._records, self._acked_below, self._expired_clients = copy.deepcopy(snap)
