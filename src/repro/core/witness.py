"""CURP witness (§3.2.2, §4.1, §4.2, §4.5).

A witness guarantees durability-without-ordering: it accepts a record only if
it commutes with everything it currently holds (disjoint 64-bit key hashes).
The data structure is a W-way set-associative cache over key hashes (§4.2,
Appendix B.1: direct-mapped conflicts after ~80 inserts at 4096 slots; 4-way
associativity fixes that).

This Python object is the protocol-level reference; the TPU-side batched
version is repro/kernels/witness_record.py (validated against this semantics
via repro/kernels/ref.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .merge import CLS_OTHER, conflicts
from .telemetry import get_registry
from .types import (
    GcResp,
    Op,
    RecordStatus,
    RpcId,
    WitnessMode,
)


@dataclass
class _Slot:
    key_hash: int = 0
    rpc_id: Optional[RpcId] = None
    request: Optional[Op] = None
    occupied: bool = False
    gc_age: int = 0  # number of master gc rounds survived (§4.5 suspicion)
    op_class: int = 0  # merge-lattice class of the held pair (repro.core.merge)


class Witness:
    """One witness instance serving one master (started via ``start``)."""

    # §4.5: a surviving record is suspected as uncollected garbage after this
    # many gc rounds ("three is a good number if a master performs only one gc
    # RPC at a time").
    SUSPECT_AGE = 3

    def __init__(self, n_sets: int = 1024, n_ways: int = 4,
                 class_budget: Optional[int] = None) -> None:
        self.n_sets = n_sets
        self.n_ways = n_ways
        # Per-class way budget: cap on how many ways of ONE set a single
        # mergeable (key_hash, class) stack may occupy.  Without it a hot
        # commuting key (INCR storm) fills all W ways between gc rounds and
        # every other class mapping to that set rejects as full — the budget
        # bounds the stack so non-merge traffic keeps a seat.  None (the
        # default, and the paper's behavior) disables the cap.  Host-witness
        # knob only: the device kernels implement the uncapped semantics, so
        # parity checks run with the default.
        self.class_budget = class_budget
        self.mode = WitnessMode.ENDED
        self.master_id: Optional[int] = None
        self._slots: List[List[_Slot]] = []
        # Optional black-box journal (repro.core.journal); the watchdog's
        # durability monitor counts per-rpc witness accepts through this.
        self.journal = None
        self.journal_actor = "w?"
        self.stats = {"accepts": 0, "accepts_dup": 0, "rejects_conflict": 0,
                      "rejects_full": 0, "rejects_mode": 0,
                      "rejects_budget": 0, "gc_drops": 0}
        reg = get_registry()
        self._m_accepts = reg.counter("witness.accepts")
        self._m_dups = reg.counter("witness.dups")
        self._m_rej_conflict = reg.counter("witness.rejects_conflict")
        self._m_rej_full = reg.counter("witness.rejects_full")
        self._m_rej_mode = reg.counter("witness.rejects_mode")
        self._m_gc_drops = reg.counter("witness.gc_drops")

    # -- lifecycle (Fig. 4: coordinator -> witness) ---------------------------
    def start(self, master_id: int) -> bool:
        self.master_id = master_id
        self.mode = WitnessMode.NORMAL
        self._slots = [
            [_Slot() for _ in range(self.n_ways)] for _ in range(self.n_sets)
        ]
        return True

    def end(self) -> None:
        self.mode = WitnessMode.ENDED
        self.master_id = None
        self._slots = []

    # -- client -> witness ----------------------------------------------------
    def record(
        self,
        master_id: int,
        key_hashes: Tuple[int, ...],
        rpc_id: RpcId,
        request: Op,
    ) -> RecordStatus:
        """Accept iff commutative with all held requests AND space available.

        Commutativity is the WIDENED merge-lattice relation (repro.core.merge):
        a same-key-hash pair conflicts only if its op classes conflict, so two
        concurrent INCRs (or SADDs, APPENDs, MAXes, disjoint-field HMSETs) of
        one key coexist in different ways of the same set.

        Multi-object updates (§4.2): the commutativity and space check runs for
        every affected object; on accept the request is written n times, once
        per object.  Ways are RESERVED as the placement loop claims them —
        two pairs of one op that land in the same set take distinct free ways
        (and reject as full when the set can't seat them all), instead of the
        old compute-all-then-write aliasing that let the second key silently
        clobber the first out of gc/recovery data.
        """
        if self.mode is not WitnessMode.NORMAL or master_id != self.master_id:
            self.stats["rejects_mode"] += 1
            self._m_rej_mode.inc()
            return self._jrecord(rpc_id, master_id, RecordStatus.REJECTED,
                                 "mode")

        pairs = self._pairs(key_hashes, request)
        placements: List[Tuple[int, int, int, int]] = []  # (set, way, kh, cls)
        claimed: set = set()   # (set_idx, way) taken by earlier pairs of THIS op
        placed: set = set()    # (kh, cls) pairs of THIS op already seated
        any_dup = False
        for kh, cls in pairs:
            if (kh, cls) in placed:
                # The op lists the same key twice (e.g. MSET a=1 a=2): one
                # slot covers both occurrences — the conflict check is
                # identical and recovery dedupes by rpc_id anyway.
                continue
            placed.add((kh, cls))
            set_idx = kh % self.n_sets
            ways = self._slots[set_idx]
            free_way = None
            is_dup = False
            stack = 0   # occupied ways already holding this (kh, cls) stack
            for w, slot in enumerate(ways):
                if slot.occupied:
                    if slot.key_hash == kh and slot.rpc_id == rpc_id:
                        # Duplicate record RPC (client retry): idempotent accept.
                        free_way = w
                        is_dup = True
                        any_dup = True
                        break
                    if slot.key_hash == kh:
                        if conflicts(slot.op_class, cls):
                            # Non-commutative with a held request: must reject —
                            # the witness cannot order them (§3.2.2).
                            self.stats["rejects_conflict"] += 1
                            self._m_rej_conflict.inc()
                            self._note_suspect(slot)
                            return self._jrecord(rpc_id, master_id,
                                                 RecordStatus.REJECTED,
                                                 "conflict")
                        if slot.op_class == cls:
                            stack += 1
                elif free_way is None and (set_idx, w) not in claimed:
                    free_way = w
            if not is_dup and self.class_budget is not None \
                    and stack >= self.class_budget:
                # The mergeable stack for this (kh, cls) is at its way
                # budget: reject so the op takes the sync path instead of
                # starving other classes out of this set.
                self.stats["rejects_budget"] += 1
                return self._jrecord(rpc_id, master_id, RecordStatus.REJECTED,
                                     "budget")
            if free_way is None:
                self.stats["rejects_full"] += 1
                self._m_rej_full.inc()
                return self._jrecord(rpc_id, master_id, RecordStatus.REJECTED,
                                     "full")
            claimed.add((set_idx, free_way))
            placements.append((set_idx, free_way, kh, cls))

        for set_idx, way, kh, cls in placements:
            slot = self._slots[set_idx][way]
            slot.key_hash = kh
            slot.rpc_id = rpc_id
            slot.request = request
            slot.occupied = True
            slot.gc_age = 0
            slot.op_class = cls
        self.stats["accepts"] += 1
        self._m_accepts.inc()
        if any_dup:
            self.stats["accepts_dup"] += 1
            self._m_dups.inc()
        return self._jrecord(rpc_id, master_id, RecordStatus.ACCEPTED, "ok")

    def _jrecord(self, rpc_id: RpcId, master_id: int,
                 status: "RecordStatus", why: str) -> "RecordStatus":
        jr = self.journal
        if jr is not None:
            jr.emit("record", actor=self.journal_actor, rpc=rpc_id,
                    mid=master_id,
                    status="accepted" if status is RecordStatus.ACCEPTED
                    else "rejected", why=why)
        return status

    @staticmethod
    def _pairs(key_hashes: Tuple[int, ...], request: Optional[Op]):
        """The (key_hash, class) pairs to place.  Derived from the request
        when the caller passed its routing hashes (the Fig. 4 RPC always
        does); a bare hash list falls back to the conservative OTHER class,
        reproducing the un-widened check exactly."""
        if request is not None and \
                tuple(request.key_hashes()) == tuple(key_hashes):
            return request.hash_classes()
        return tuple((kh, CLS_OTHER) for kh in key_hashes)

    def record_batch(self, master_id: int, ops: List[Op]) -> List[RecordStatus]:
        """One witness invocation for a whole update batch (the batched
        client path): per-op accept/reject with the same in-order semantics
        as issuing ``record`` once per op.  The kernel-backed DeviceWitness
        overrides this with a single set-parallel kernel call."""
        return [
            self.record(master_id, op.key_hashes(), op.rpc_id, op)
            for op in ops
        ]

    # -- master -> witness ----------------------------------------------------
    def gc(self, entries: Tuple[Tuple[int, RpcId], ...]) -> GcResp:
        """Drop synced records; report suspected uncollected garbage (§4.5)."""
        if self.mode is not WitnessMode.NORMAL:
            return GcResp(stale_requests=())
        for kh, rpc_id in entries:
            set_idx = kh % self.n_sets
            for slot in self._slots[set_idx]:
                if slot.occupied and slot.key_hash == kh and slot.rpc_id == rpc_id:
                    slot.occupied = False
                    slot.request = None
                    slot.rpc_id = None
                    self.stats["gc_drops"] += 1
                    self._m_gc_drops.inc()
        # Age all survivors; collect suspects.
        stale: List[Op] = []
        seen: set = set()
        for ways in self._slots:
            for slot in ways:
                if slot.occupied:
                    slot.gc_age += 1
                    if slot.gc_age >= self.SUSPECT_AGE and slot.rpc_id not in seen:
                        seen.add(slot.rpc_id)
                        stale.append(slot.request)
        jr = self.journal
        if jr is not None:
            jr.emit("gc", actor=self.journal_actor, mid=self.master_id,
                    entries=len(entries), stale=len(stale))
        return GcResp(stale_requests=tuple(stale))

    def get_recovery_data(self, master_id: int) -> Tuple[Op, ...]:
        """Irreversibly freeze (recovery mode) and return all held requests."""
        if self.master_id != master_id or self.mode is WitnessMode.ENDED:
            return ()
        self.mode = WitnessMode.RECOVERY
        out: Dict[RpcId, Op] = {}
        for ways in self._slots:
            for slot in ways:
                if slot.occupied and slot.request is not None:
                    out[slot.rpc_id] = slot.request  # dedupe multi-key entries
        return tuple(out.values())

    # -- §A.1 consistent reads from backups ------------------------------------
    def commutes_with_all(self, key_hashes: Tuple[int, ...],
                          classes: Optional[Tuple[int, ...]] = None) -> bool:
        """True iff no held request CONFLICTS with any of these pairs under
        the merge lattice.  Without ``classes`` the query is the conservative
        OTHER class — it conflicts with every held class, i.e. the original
        "no held request touches these keys" read check."""
        if self.mode is not WitnessMode.NORMAL:
            return False
        if classes is None:
            classes = (CLS_OTHER,) * len(key_hashes)
        for kh, cls in zip(key_hashes, classes):
            set_idx = kh % self.n_sets
            for slot in self._slots[set_idx]:
                if slot.occupied and slot.key_hash == kh \
                        and conflicts(slot.op_class, cls):
                    return False
        return True

    # -- internals -------------------------------------------------------------
    def _note_suspect(self, slot: _Slot) -> None:
        # Rejection against an old record hints at uncollected garbage; the
        # aging in gc() will surface it to the master.
        pass

    @property
    def occupancy(self) -> int:
        return sum(1 for ways in self._slots for s in ways if s.occupied)
