"""CURP witness (§3.2.2, §4.1, §4.2, §4.5).

A witness guarantees durability-without-ordering: it accepts a record only if
it commutes with everything it currently holds (disjoint 64-bit key hashes).
The data structure is a W-way set-associative cache over key hashes (§4.2,
Appendix B.1: direct-mapped conflicts after ~80 inserts at 4096 slots; 4-way
associativity fixes that).

This Python object is the protocol-level reference; the TPU-side batched
version is repro/kernels/witness_record.py (validated against this semantics
via repro/kernels/ref.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .types import (
    GcResp,
    Op,
    RecordStatus,
    RpcId,
    WitnessMode,
)


@dataclass
class _Slot:
    key_hash: int = 0
    rpc_id: Optional[RpcId] = None
    request: Optional[Op] = None
    occupied: bool = False
    gc_age: int = 0  # number of master gc rounds survived (§4.5 suspicion)


class Witness:
    """One witness instance serving one master (started via ``start``)."""

    # §4.5: a surviving record is suspected as uncollected garbage after this
    # many gc rounds ("three is a good number if a master performs only one gc
    # RPC at a time").
    SUSPECT_AGE = 3

    def __init__(self, n_sets: int = 1024, n_ways: int = 4) -> None:
        self.n_sets = n_sets
        self.n_ways = n_ways
        self.mode = WitnessMode.ENDED
        self.master_id: Optional[int] = None
        self._slots: List[List[_Slot]] = []
        self.stats = {"accepts": 0, "rejects_conflict": 0, "rejects_full": 0,
                      "rejects_mode": 0, "gc_drops": 0}

    # -- lifecycle (Fig. 4: coordinator -> witness) ---------------------------
    def start(self, master_id: int) -> bool:
        self.master_id = master_id
        self.mode = WitnessMode.NORMAL
        self._slots = [
            [_Slot() for _ in range(self.n_ways)] for _ in range(self.n_sets)
        ]
        return True

    def end(self) -> None:
        self.mode = WitnessMode.ENDED
        self.master_id = None
        self._slots = []

    # -- client -> witness ----------------------------------------------------
    def record(
        self,
        master_id: int,
        key_hashes: Tuple[int, ...],
        rpc_id: RpcId,
        request: Op,
    ) -> RecordStatus:
        """Accept iff commutative with all held requests AND space available.

        Multi-object updates (§4.2): the commutativity and space check runs for
        every affected object; on accept the request is written n times, once
        per object.
        """
        if self.mode is not WitnessMode.NORMAL or master_id != self.master_id:
            self.stats["rejects_mode"] += 1
            return RecordStatus.REJECTED

        placements: List[Tuple[int, int]] = []  # (set_idx, way_idx) per key
        for kh in key_hashes:
            set_idx = kh % self.n_sets
            ways = self._slots[set_idx]
            free_way = None
            for w, slot in enumerate(ways):
                if slot.occupied:
                    if slot.key_hash == kh and slot.rpc_id != rpc_id:
                        # Non-commutative with a held request: must reject —
                        # the witness cannot order them (§3.2.2).
                        self.stats["rejects_conflict"] += 1
                        self._note_suspect(slot)
                        return RecordStatus.REJECTED
                    if slot.rpc_id == rpc_id and slot.key_hash == kh:
                        # Duplicate record RPC (client retry): idempotent accept.
                        free_way = w
                        break
                elif free_way is None:
                    free_way = w
            if free_way is None:
                self.stats["rejects_full"] += 1
                return RecordStatus.REJECTED
            placements.append((set_idx, free_way))

        for kh, (set_idx, way) in zip(key_hashes, placements):
            slot = self._slots[set_idx][way]
            slot.key_hash = kh
            slot.rpc_id = rpc_id
            slot.request = request
            slot.occupied = True
            slot.gc_age = 0
        self.stats["accepts"] += 1
        return RecordStatus.ACCEPTED

    def record_batch(self, master_id: int, ops: List[Op]) -> List[RecordStatus]:
        """One witness invocation for a whole update batch (the batched
        client path): per-op accept/reject with the same in-order semantics
        as issuing ``record`` once per op.  The kernel-backed DeviceWitness
        overrides this with a single set-parallel kernel call."""
        return [
            self.record(master_id, op.key_hashes(), op.rpc_id, op)
            for op in ops
        ]

    # -- master -> witness ----------------------------------------------------
    def gc(self, entries: Tuple[Tuple[int, RpcId], ...]) -> GcResp:
        """Drop synced records; report suspected uncollected garbage (§4.5)."""
        if self.mode is not WitnessMode.NORMAL:
            return GcResp(stale_requests=())
        for kh, rpc_id in entries:
            set_idx = kh % self.n_sets
            for slot in self._slots[set_idx]:
                if slot.occupied and slot.key_hash == kh and slot.rpc_id == rpc_id:
                    slot.occupied = False
                    slot.request = None
                    slot.rpc_id = None
                    self.stats["gc_drops"] += 1
        # Age all survivors; collect suspects.
        stale: List[Op] = []
        seen: set = set()
        for ways in self._slots:
            for slot in ways:
                if slot.occupied:
                    slot.gc_age += 1
                    if slot.gc_age >= self.SUSPECT_AGE and slot.rpc_id not in seen:
                        seen.add(slot.rpc_id)
                        stale.append(slot.request)
        return GcResp(stale_requests=tuple(stale))

    def get_recovery_data(self, master_id: int) -> Tuple[Op, ...]:
        """Irreversibly freeze (recovery mode) and return all held requests."""
        if self.master_id != master_id or self.mode is WitnessMode.ENDED:
            return ()
        self.mode = WitnessMode.RECOVERY
        out: Dict[RpcId, Op] = {}
        for ways in self._slots:
            for slot in ways:
                if slot.occupied and slot.request is not None:
                    out[slot.rpc_id] = slot.request  # dedupe multi-key entries
        return tuple(out.values())

    # -- §A.1 consistent reads from backups ------------------------------------
    def commutes_with_all(self, key_hashes: Tuple[int, ...]) -> bool:
        """True iff no held request touches any of these keys (read check)."""
        if self.mode is not WitnessMode.NORMAL:
            return False
        for kh in key_hashes:
            set_idx = kh % self.n_sets
            for slot in self._slots[set_idx]:
                if slot.occupied and slot.key_hash == kh:
                    return False
        return True

    # -- internals -------------------------------------------------------------
    def _note_suspect(self, slot: _Slot) -> None:
        # Rejection against an old record hints at uncollected garbage; the
        # aging in gc() will surface it to the master.
        pass

    @property
    def occupancy(self) -> int:
        return sum(1 for ways in self._slots for s in ways if s.occupied)
