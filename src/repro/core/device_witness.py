"""Kernel-backed CURP witness: the accept/reject hot path runs on device.

``DeviceWitness`` is a drop-in for :class:`repro.core.witness.Witness` whose
conflict/capacity decisions come from the Pallas witness kernels
(repro.kernels).  Since the gang refactor the kernel table holds MORE than
the keyhash lanes: every slot carries the recording op's RIFL identity
(rpc_hi/rpc_lo) and a §4.5 gc-age counter, so

  * duplicate record retries (same rpc_id, same key) are accepted
    idempotently IN-KERNEL (reason code 2),
  * gc entries whose rpc_id doesn't match the held record are ignored
    IN-KERNEL (the clear requires key AND rpc to match), so a stale gc can
    never drop a newer same-key record,
  * survivors age in-kernel per gc round.

The host mirror (mixed keyhash lanes -> (rpc_id, Op)) is demoted to a
RECOVERY-TIME VIEW: it stores the Op objects the device cannot hold (replay
data for ``get_recovery_data``), answers ``commutes_with_all`` for backup
reads, and carries the suspect ages reported to the master — it is never
consulted to decide accept/reject/gc outcomes on the hot path.

Many witness instances share one device-resident **gang**
(:class:`WitnessGang`): all shards' x all witnesses' tables stacked into a
single [n_lanes*S, W] array, so a routed cross-shard batch records at every
target lane in ONE dispatch (repro.kernels.ops.gang_fastpath_batch) and a
sync round gc's every witness of a shard in ONE dispatch (``gc_many``).

Set placement differs from the Python witness (keyhash2x32-mixed low lane
masked by S-1, vs ``kh % n_sets`` on the raw 64-bit hash), so occupancy
patterns differ between backends; accept/reject *semantics* do not.

Multi-key ops resolve all-or-nothing through the grouped record kernel
(repro.kernels.gang_record_groups): every key's conflict/capacity verdict is
computed against the pre-op table and writes happen only when the whole op
accepted — ONE dispatch whether the op accepts or rejects, for a whole batch
of multi-key ops at once.  The pre-refactor record-then-rollback scheme
(2 dispatches on the reject path) is kept as ``_record_keys_rollback`` for
benchmarks/fig_txn.py's old-vs-new comparison.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .merge import CLS_OTHER, conflicts
from .types import GcResp, Op, RecordStatus, RpcId, WitnessMode

_M32 = 0xFFFFFFFF

# Reason codes emitted by the gang kernels (repro.kernels.ref).
_R_INSERT = 1
_R_DUP = 2
_R_CONFLICT = 3
_R_FULL = 4

_REASON_STAT = {
    _R_INSERT: "reason_insert",
    _R_DUP: "reason_dup",
    _R_CONFLICT: "reason_conflict",
    _R_FULL: "reason_full",
}


@dataclass
class _Held:
    rpc_id: RpcId
    request: Op
    gc_age: int = 0
    op_class: int = 0


def _op_pairs(key_hashes, request: Optional[Op]):
    """The (key_hash, class) pairs to place — same derivation rule as
    ``Witness._pairs``: trust the request's lattice expansion only when the
    caller passed its own routing hashes; bare hash lists get the
    conservative OTHER class (un-widened CURP check)."""
    if request is not None and tuple(request.key_hashes()) == tuple(key_hashes):
        return request.hash_classes()
    return tuple((kh, CLS_OTHER) for kh in key_hashes)


def _lanes(khs) -> Tuple[np.ndarray, np.ndarray]:
    hi = np.fromiter(((kh >> 32) & _M32 for kh in khs), np.uint32, len(khs))
    lo = np.fromiter((kh & _M32 for kh in khs), np.uint32, len(khs))
    return hi, lo


def _rpc_lanes(rpc_ids: Sequence[RpcId]) -> Tuple[np.ndarray, np.ndarray]:
    hi = np.fromiter((r[0] & _M32 for r in rpc_ids), np.uint32, len(rpc_ids))
    lo = np.fromiter((r[1] & _M32 for r in rpc_ids), np.uint32, len(rpc_ids))
    return hi, lo


class WitnessGang:
    """Device-resident stack of witness tables (one lane per instance).

    Owns the single :class:`repro.kernels.GangTable` that every attached
    ``DeviceWitness`` records into; lanes are allocated on ``start`` and
    recycled on ``end``.  The lane count grows by doubling (a host-side
    concat of zero rows) so the flattened row space stays a power of two —
    the set-parallel kernel's tiling requirement.
    """

    def __init__(self, n_sets: int = 1024, n_ways: int = 4,
                 n_lanes: int = 4) -> None:
        import jax.numpy as jnp

        from repro.kernels import (  # deferred: keeps jax import lazy
            N_REASON_CODES,
            GangTable,
        )

        assert n_lanes & (n_lanes - 1) == 0, "n_lanes must be a power of two"
        self.n_sets = n_sets
        self.n_ways = n_ways
        self.n_lanes = n_lanes
        self.table = GangTable.empty(n_sets, n_ways, n_lanes)
        # In-dispatch telemetry plane: [n_lanes, 5] reason-code counters the
        # record kernels scatter-accumulate into (flight recorder).  Drained
        # and zeroed host-side by ``drain_counters``.
        self.counters = jnp.zeros((n_lanes, N_REASON_CODES), jnp.int32)
        self._free = list(range(n_lanes - 1, -1, -1))
        self._dirty: set = set()

    def drain_counters(self) -> np.ndarray:
        """Materialize the per-lane reason-code counters and zero the plane.

        Returns an [n_lanes, 5] int32 numpy array (columns indexed by the
        kernel reason codes; column 0 is unused).  Bit-exact with the host
        ``DeviceWitness.stats["reason_*"]`` accounting over the same drain
        interval — tests assert the parity.
        """
        import jax.numpy as jnp

        out = np.asarray(self.counters)
        self.counters = jnp.zeros_like(self.counters)
        return out

    def alloc(self) -> int:
        if not self._free:
            self._grow()
        lane = self._free.pop()
        if lane in self._dirty:
            self._zero(lane)
            self._dirty.discard(lane)
        return lane

    def free(self, lane: int) -> None:
        self._dirty.add(lane)
        self._free.append(lane)

    def _grow(self) -> None:
        import jax.numpy as jnp

        from repro.kernels import GangTable

        old = self.n_lanes
        self.n_lanes = old * 2
        pad = ((0, old * self.n_sets), (0, 0))
        self.table = GangTable(*(
            jnp.asarray(np.pad(np.asarray(a), pad)) for a in self.table
        ))
        self.counters = jnp.asarray(
            np.pad(np.asarray(self.counters), ((0, old), (0, 0)))
        )
        self._free.extend(range(self.n_lanes - 1, old - 1, -1))

    def _zero(self, lane: int) -> None:
        # Only occupancy and age gate kernel decisions; stale key/rpc lanes
        # under occ == 0 are never read.
        import jax.numpy as jnp

        occ = np.asarray(self.table.occ).copy()
        age = np.asarray(self.table.age).copy()
        rows = slice(lane * self.n_sets, (lane + 1) * self.n_sets)
        occ[rows] = 0
        age[rows] = 0
        self.table = self.table._replace(
            occ=jnp.asarray(occ), age=jnp.asarray(age)
        )
        # A recycled lane starts its telemetry from zero too, so per-lane
        # counters always describe the CURRENT tenant.
        cnt = np.asarray(self.counters).copy()
        cnt[lane] = 0
        self.counters = jnp.asarray(cnt)


class DeviceWitness:
    """One witness instance serving one master; table state lives in one
    lane of a (possibly shared) device-resident gang."""

    SUSPECT_AGE = 3

    def __init__(self, n_sets: int = 1024, n_ways: int = 4,
                 gang: Optional[WitnessGang] = None) -> None:
        self.n_sets = n_sets
        self.n_ways = n_ways
        self.mode = WitnessMode.ENDED
        self.master_id: Optional[int] = None
        self.gang = gang          # shared gang, or private (made on start)
        self.lane: Optional[int] = None
        # mixed (q_hi, q_lo) -> {rpc_id -> metadata}: the recovery-time
        # view.  Nested because the merge lattice lets several MERGEABLE
        # records of one key coexist (one device slot each, one rpc each).
        self._held: Dict[Tuple[int, int], Dict[RpcId, _Held]] = {}
        self.stats = {"accepts": 0, "rejects_conflict": 0, "rejects_full": 0,
                      "rejects_mode": 0, "gc_drops": 0, "kernel_batches": 0,
                      # Host-side mirror of the device reason-counter plane
                      # (same granularity as the kernel's accumulation: one
                      # count per settled outcome).  Parity-asserted against
                      # ``WitnessGang.drain_counters`` by the telemetry
                      # tests.
                      "reason_insert": 0, "reason_dup": 0,
                      "reason_conflict": 0, "reason_full": 0}

    # -- lifecycle (Fig. 4: coordinator -> witness) ---------------------------
    def start(self, master_id: int) -> bool:
        if self.gang is None:
            self.gang = WitnessGang(self.n_sets, self.n_ways, n_lanes=1)
        elif (self.gang.n_sets, self.gang.n_ways) != (self.n_sets,
                                                      self.n_ways):
            raise ValueError("witness geometry does not match its gang")
        if self.lane is None:
            self.lane = self.gang.alloc()
        self.master_id = master_id
        self.mode = WitnessMode.NORMAL
        self._held = {}
        return True

    def end(self) -> None:
        self.mode = WitnessMode.ENDED
        self.master_id = None
        if self.lane is not None:
            self.gang.free(self.lane)
            self.lane = None
        self._held = {}

    # -- client -> witness ----------------------------------------------------
    def record(
        self, master_id: int, key_hashes: Tuple[int, ...], rpc_id: RpcId,
        request: Op,
    ) -> RecordStatus:
        """Single-op record: a group of one through the grouped kernel."""
        if self.mode is not WitnessMode.NORMAL or master_id != self.master_id:
            self.stats["rejects_mode"] += 1
            return RecordStatus.REJECTED
        return self._record_keys(key_hashes, rpc_id, request)

    def record_batch(self, master_id: int, ops: List[Op]) -> List[RecordStatus]:
        """Whole-batch record, ONE kernel dispatch, any mix of group sizes.

        All-single-key batches (the batched client path's common case) go
        through the set-parallel kernel; batches containing multi-key ops go
        through the grouped all-or-nothing kernel.  Batch order is preserved
        exactly in both (the set-parallel prep keeps per-set order; the
        grouped kernel is sequential in group index)."""
        if self.mode is not WitnessMode.NORMAL or master_id != self.master_id:
            self.stats["rejects_mode"] += len(ops)
            return [RecordStatus.REJECTED] * len(ops)
        if not ops:
            return []
        from repro.kernels import gang_record

        pairs = [op.hash_classes() for op in ops]
        if any(len(p) != 1 for p in pairs):
            return self._record_groups(ops, pairs)
        khs = [p[0][0] for p in pairs]
        kcls = np.fromiter((p[0][1] for p in pairs), np.int32, len(pairs))
        hi, lo = _lanes(khs)
        rhi, rlo = _rpc_lanes([op.rpc_id for op in ops])
        lanes = np.full(len(ops), self.lane, np.int32)
        rsn, qh, ql, table, counters = gang_record(
            self.gang.table, self.n_sets, hi, lo, lanes, rhi, rlo, kcls,
            counters=self.gang.counters,
        )
        self.gang.table = table
        self.gang.counters = counters
        self.stats["kernel_batches"] += 1
        return [
            self._settle(int(rsn[i]), [(int(qh[i]), int(ql[i]))],
                         ops[i].rpc_id, ops[i], [int(kcls[i])])
            for i in range(len(ops))
        ]

    def _record_groups(self, ops: List[Op], pairs=None) -> List[RecordStatus]:
        """Batch of (possibly multi-pair) ops via the grouped kernel: every
        op resolves all-or-nothing, whole batch in ONE dispatch.  Groups are
        the ops' lattice pairs — HMSET contributes its derived per-field
        FIELD sub-hashes, so field overlap conflicts in-kernel."""
        from repro.kernels import gang_record_groups

        if pairs is None:
            pairs = [op.hash_classes() for op in ops]
        G = len(pairs)
        K = max(len(p) for p in pairs)
        khi = np.zeros((G, K), np.uint32)
        klo = np.zeros((G, K), np.uint32)
        kval = np.zeros((G, K), np.int32)
        kcls = np.zeros((G, K), np.int32)
        for g, p in enumerate(pairs):
            hi, lo = _lanes([kh for kh, _c in p])
            khi[g, :len(p)] = hi
            klo[g, :len(p)] = lo
            kval[g, :len(p)] = 1
            kcls[g, :len(p)] = [c for _kh, c in p]
        rhi, rlo = _rpc_lanes([op.rpc_id for op in ops])
        lanes = np.full(G, self.lane, np.int32)
        res = gang_record_groups(
            self.gang.table, self.n_sets, khi, klo, kval, lanes, rhi, rlo,
            kcls, counters=self.gang.counters,
        )
        self.gang.table = res.table
        self.gang.counters = res.counters
        self.stats["kernel_batches"] += 1
        out = []
        for g, op in enumerate(ops):
            keys = [(int(res.q_hi[g, k]), int(res.q_lo[g, k]))
                    for k in range(len(pairs[g]))]
            out.append(self._settle(int(res.reasons[g]), keys,
                                    op.rpc_id, op,
                                    [c for _kh, c in pairs[g]]))
        return out

    def _settle(self, reason: int, keys: List[Tuple[int, int]],
                rpc_id: RpcId, request: Op,
                classes: List[int]) -> RecordStatus:
        """Fold a kernel reason code into protocol status + mirror + stats.

        The mirror write mirrors the Python reference's slot overwrite: on
        any accept (fresh insert or idempotent dup) every key's entry is
        re-stamped with age 0.  Entries nest per rpc so mergeable same-key
        records (each holding its own device slot) coexist in the mirror."""
        self.stats[_REASON_STAT[reason]] += 1
        if reason in (_R_INSERT, _R_DUP):
            for key, cls in zip(keys, classes):
                self._held.setdefault(key, {})[rpc_id] = _Held(
                    rpc_id, request, op_class=cls
                )
            self.stats["accepts"] += 1
            return RecordStatus.ACCEPTED
        if reason == _R_CONFLICT:
            self.stats["rejects_conflict"] += 1
        else:
            self.stats["rejects_full"] += 1
        return RecordStatus.REJECTED

    def _record_keys(self, key_hashes: Tuple[int, ...], rpc_id: RpcId,
                     request: Op) -> RecordStatus:
        """All-or-nothing multi-pair record: ONE grouped-kernel dispatch
        whether the op accepts or rejects (the kernel leaves the table
        bit-identical on reject, so no rollback gc).  Dup/conflict verdicts
        come from the kernel-held rpc lanes — no host mirror input."""
        from repro.kernels import gang_record_groups

        pairs = _op_pairs(key_hashes, request)
        hi, lo = _lanes([kh for kh, _c in pairs])
        kcls = np.fromiter((c for _kh, c in pairs), np.int32, len(pairs))
        res = gang_record_groups(
            self.gang.table, self.n_sets,
            hi[None, :], lo[None, :], np.ones((1, len(pairs)), np.int32),
            np.array([self.lane], np.int32),
            np.array([rpc_id[0] & _M32], np.uint32),
            np.array([rpc_id[1] & _M32], np.uint32),
            kcls[None, :], counters=self.gang.counters,
        )
        self.gang.table = res.table
        self.gang.counters = res.counters
        self.stats["kernel_batches"] += 1
        keys = [(int(res.q_hi[0, k]), int(res.q_lo[0, k]))
                for k in range(len(pairs))]
        return self._settle(int(res.reasons[0]), keys, rpc_id, request,
                            [c for _kh, c in pairs])

    def _record_keys_rollback(self, key_hashes: Tuple[int, ...], rpc_id: RpcId,
                              request: Op) -> RecordStatus:
        """Pre-refactor record-then-rollback scheme, kept only for the
        old-vs-new dispatch comparison in benchmarks/fig_txn.py: the keys
        record individually (set-parallel dispatch) and any accepted prefix
        is rolled back by a second gc dispatch when the op rejects."""
        from repro.kernels import gang_gc, gang_record

        khs = list(dict.fromkeys(key_hashes))
        hi, lo = _lanes(khs)
        K = len(khs)
        lanes = np.full(K, self.lane, np.int32)
        rhi = np.full(K, rpc_id[0] & _M32, np.uint32)
        rlo = np.full(K, rpc_id[1] & _M32, np.uint32)
        rsn, qh, ql, table = gang_record(
            self.gang.table, self.n_sets, hi, lo, lanes, rhi, rlo
        )
        self.stats["kernel_batches"] += 1
        ok = all(int(r) in (_R_INSERT, _R_DUP) for r in rsn)
        if ok:
            self.gang.table = table
            for k in range(K):
                key = (int(qh[k]), int(ql[k]))
                self._held.setdefault(key, {})[rpc_id] = _Held(
                    rpc_id, request, op_class=0
                )
            self.stats["accepts"] += 1
            return RecordStatus.ACCEPTED
        # Roll back freshly inserted keys (the second dispatch on reject);
        # dup hits predate this op and must survive.  No aging: a rollback
        # is not a §4.5 gc round.
        ins = [k for k in range(K) if int(rsn[k]) == _R_INSERT]
        if ins:
            _clr, table = gang_gc(
                table, self.n_sets,
                qh[ins], ql[ins], rhi[ins], rlo[ins], lanes[ins],
                np.zeros(self.gang.n_lanes, np.int32), do_age=False,
            )
        self.gang.table = table
        if any(int(r) == _R_CONFLICT for r in rsn):
            self.stats["rejects_conflict"] += 1
        else:
            self.stats["rejects_full"] += 1
        return RecordStatus.REJECTED

    # -- master -> witness ----------------------------------------------------
    def gc(self, entries: Tuple[Tuple[int, RpcId], ...]) -> GcResp:
        """Drop synced records (one gang gc dispatch); report suspects."""
        if self.mode is not WitnessMode.NORMAL:
            return GcResp(stale_requests=())
        resps = gc_many([self], entries)
        return resps[0]

    def _apply_gc(self, keys: List[Tuple[int, int]],
                  rpc_ids: List[RpcId], cleared) -> GcResp:
        """Fold per-entry cleared bits into mirror + stats; age survivors
        host-side for suspect reporting (the kernel ages its lanes too —
        that state drives device-side suspicion on TPU)."""
        for (key, rpc_id, clr) in zip(keys, rpc_ids, cleared):
            if not clr:
                continue
            by_rpc = self._held.get(key)
            if by_rpc is not None and rpc_id in by_rpc:
                del by_rpc[rpc_id]
                if not by_rpc:
                    del self._held[key]
            self.stats["gc_drops"] += 1
        stale: List[Op] = []
        seen: set = set()
        for by_rpc in self._held.values():
            for held in by_rpc.values():
                held.gc_age += 1
                if held.gc_age >= self.SUSPECT_AGE and held.rpc_id not in seen:
                    seen.add(held.rpc_id)
                    stale.append(held.request)
        return GcResp(stale_requests=tuple(stale))

    def get_recovery_data(self, master_id: int) -> Tuple[Op, ...]:
        """Irreversibly freeze (recovery mode) and return all held requests."""
        if self.master_id != master_id or self.mode is WitnessMode.ENDED:
            return ()
        self.mode = WitnessMode.RECOVERY
        out: Dict[RpcId, Op] = {}
        for by_rpc in self._held.values():
            for held in by_rpc.values():
                out[held.rpc_id] = held.request  # dedupe multi-key entries
        return tuple(out.values())

    # -- §A.1 consistent reads from backups ------------------------------------
    def commutes_with_all(self, key_hashes: Tuple[int, ...],
                          classes: Optional[Tuple[int, ...]] = None) -> bool:
        """True iff no held record CONFLICTS with any query pair under the
        merge lattice.  Without ``classes`` the query is the conservative
        OTHER class (conflicts with every held class) — the original "no
        held request touches these keys" read check."""
        if self.mode is not WitnessMode.NORMAL:
            return False
        if not key_hashes:
            return True
        from repro.kernels import np_keyhash2x32

        if classes is None:
            classes = (CLS_OTHER,) * len(key_hashes)
        hi, lo = _lanes(list(key_hashes))
        qh, ql = np_keyhash2x32(hi, lo)
        for i, cls in enumerate(classes):
            by_rpc = self._held.get((int(qh[i]), int(ql[i])))
            if by_rpc and any(
                conflicts(h.op_class, cls) for h in by_rpc.values()
            ):
                return False
        return True

    @property
    def occupancy(self) -> int:
        return sum(len(by_rpc) for by_rpc in self._held.values())


def gc_many(witnesses: Sequence[DeviceWitness],
            entries: Tuple[Tuple[int, RpcId], ...]) -> List[GcResp]:
    """Gc the same sync batch at MANY witnesses of one gang in ONE dispatch.

    Entries are lane-expanded (every witness gets its own copy targeting its
    lane) and deduplicated per (key, rpc) — the Python reference clears a
    slot once however many times the pair appears.  Aging covers exactly
    the participating lanes.  Returns one GcResp per witness, in order.
    """
    from repro.kernels import gang_gc, np_keyhash2x32

    assert witnesses, "gc_many needs at least one witness"
    gang = witnesses[0].gang
    assert all(w.gang is gang for w in witnesses), "witnesses must share a gang"
    assert all(w.mode is WitnessMode.NORMAL for w in witnesses)
    uniq = list(dict.fromkeys((kh, rpc) for kh, rpc in entries))
    if not uniq:
        # Pure aging round: Python gc ages survivors even with no entries.
        return [w._apply_gc([], [], []) for w in witnesses]
    hi, lo = _lanes([kh for kh, _rpc in uniq])
    qh, ql = np_keyhash2x32(hi, lo)
    rhi, rlo = _rpc_lanes([rpc for _kh, rpc in uniq])
    E, L = len(uniq), len(witnesses)
    g_qh = np.tile(qh, L)
    g_ql = np.tile(ql, L)
    g_rh = np.tile(rhi, L)
    g_rl = np.tile(rlo, L)
    g_lane = np.repeat(
        np.fromiter((w.lane for w in witnesses), np.int32, L), E
    )
    aged = np.zeros(gang.n_lanes, np.int32)
    for w in witnesses:
        aged[w.lane] = 1
    cleared, table = gang_gc(
        gang.table, gang.n_sets, g_qh, g_ql, g_rh, g_rl, g_lane, aged
    )
    gang.table = table
    for w in witnesses:
        w.stats["kernel_batches"] += 1
    keys = [(int(qh[e]), int(ql[e])) for e in range(E)]
    rpcs = [rpc for _kh, rpc in uniq]
    return [
        w._apply_gc(keys, rpcs, [bool(c) for c in cleared[i * E:(i + 1) * E]])
        for i, w in enumerate(witnesses)
    ]
