"""Kernel-backed CURP witness: the accept/reject hot path runs on device.

``DeviceWitness`` is a drop-in for :class:`repro.core.witness.Witness` whose
conflict/capacity decisions come from the Pallas set-parallel witness table
(repro.kernels): one ``record_batch`` call is ONE fused kernel dispatch for
the whole batch (keyhash2x32 mix -> set-parallel record), instead of a Python
slot walk per op.  A small host-side mirror (keyhash -> (rpc_id, Op, age))
carries the protocol metadata the table doesn't hold — recovery replay data,
RIFL-duplicate idempotence, and §4.5 gc-age suspicion — so the semantics
match the Python reference witness:

  * duplicate record retries (same rpc_id, same key) are accepted
    idempotently: the kernel rejects the same-key probe, and the mirror
    recognises the rpc and upgrades the verdict;
  * gc entries whose rpc_id doesn't match the held record are ignored (the
    mirror filters them before the gc kernel runs), so a stale gc can never
    drop a newer record for the same key;
  * survivors age per gc round and are reported as suspected uncollected
    garbage once they reach ``SUSPECT_AGE``.

Set placement differs from the Python witness (keyhash2x32-mixed low lane
masked by S-1, vs ``kh % n_sets`` on the raw 64-bit hash), so occupancy
patterns differ between backends; accept/reject *semantics* do not.

Multi-key ops take an all-or-nothing path through the transactional probe
kernel (repro.kernels.txn_probe): the op's distinct keys resolve in ONE
dispatch whether the op accepts or rejects — the kernel computes every key's
conflict/capacity verdict against the pre-op table and only writes when the
whole op accepted, so there is never an accepted prefix to roll back.  Keys
already held under the op's own rpc_id are passed as ``own`` bits (resolved
from the host mirror) and count as placed, not as conflicts.  The
pre-refactor record-then-rollback scheme (2 dispatches on the reject path)
is kept as ``_record_keys_rollback`` for benchmarks/fig_txn.py's old-vs-new
comparison.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from .types import GcResp, Op, RecordStatus, RpcId, WitnessMode

_M32 = 0xFFFFFFFF


@dataclass
class _Held:
    rpc_id: RpcId
    request: Op
    gc_age: int = 0


def _lanes(khs) -> Tuple[np.ndarray, np.ndarray]:
    hi = np.fromiter(((kh >> 32) & _M32 for kh in khs), np.uint32, len(khs))
    lo = np.fromiter((kh & _M32 for kh in khs), np.uint32, len(khs))
    return hi, lo


def _pad_repeat(a: np.ndarray) -> np.ndarray:
    """Pad to the record path's jit-cache bucket size by repeating the first
    element — gc clears are idempotent, so repeats are semantically free
    while keeping the gc kernel's jit cache to O(log G) entries."""
    from repro.kernels.ops import _bucket

    b = _bucket(len(a))
    if b == len(a):
        return a
    return np.concatenate([a, np.full(b - len(a), a[0], a.dtype)])


class DeviceWitness:
    """One witness instance serving one master, table state on device."""

    SUSPECT_AGE = 3

    def __init__(self, n_sets: int = 1024, n_ways: int = 4) -> None:
        from repro.kernels import WitnessTable  # deferred: keeps jax import lazy

        self.n_sets = n_sets
        self.n_ways = n_ways
        self.mode = WitnessMode.ENDED
        self.master_id: Optional[int] = None
        self._table_cls = WitnessTable
        self._table = None
        # keyhash -> protocol metadata for every occupied slot.
        self._held: Dict[int, _Held] = {}
        self.stats = {"accepts": 0, "rejects_conflict": 0, "rejects_full": 0,
                      "rejects_mode": 0, "gc_drops": 0, "kernel_batches": 0}

    # -- lifecycle (Fig. 4: coordinator -> witness) ---------------------------
    def start(self, master_id: int) -> bool:
        self.master_id = master_id
        self.mode = WitnessMode.NORMAL
        self._table = self._table_cls.empty(self.n_sets, self.n_ways)
        self._held = {}
        return True

    def end(self) -> None:
        self.mode = WitnessMode.ENDED
        self.master_id = None
        self._table = None
        self._held = {}

    # -- client -> witness ----------------------------------------------------
    def record(
        self, master_id: int, key_hashes: Tuple[int, ...], rpc_id: RpcId,
        request: Op,
    ) -> RecordStatus:
        """Single-op record: a batch of one (multi-key ops roll back the
        accepted prefix if any key rejects)."""
        if self.mode is not WitnessMode.NORMAL or master_id != self.master_id:
            self.stats["rejects_mode"] += 1
            return RecordStatus.REJECTED
        return self._record_keys(key_hashes, rpc_id, request)

    def record_batch(self, master_id: int, ops: List[Op]) -> List[RecordStatus]:
        """Whole-batch record: ONE fused kernel dispatch resolves every
        single-key op's accept bit.  Multi-key ops take the all-or-nothing
        per-op path; batch order is preserved exactly (consecutive
        single-key runs batch together, so an all-single-key batch — the
        batched client path's common case — is still one dispatch)."""
        from repro.kernels import fastpath_batch

        if self.mode is not WitnessMode.NORMAL or master_id != self.master_id:
            self.stats["rejects_mode"] += len(ops)
            return [RecordStatus.REJECTED] * len(ops)
        out: List[RecordStatus] = [RecordStatus.REJECTED] * len(ops)
        i = 0
        while i < len(ops):
            if len(ops[i].key_hashes()) != 1:
                out[i] = self._record_keys(
                    ops[i].key_hashes(), ops[i].rpc_id, ops[i]
                )
                i += 1
                continue
            j = i
            while j < len(ops) and len(ops[j].key_hashes()) == 1:
                j += 1
            khs = [op.key_hashes()[0] for op in ops[i:j]]
            hi, lo = _lanes(khs)
            res = fastpath_batch(self._table, hi, lo)
            self._table = res.table
            self.stats["kernel_batches"] += 1
            accepted = np.asarray(res.accepted)
            for k, idx in enumerate(range(i, j)):
                out[idx] = self._settle(
                    khs[k], bool(accepted[k]), ops[idx].rpc_id, ops[idx]
                )
            i = j
        return out

    def _settle(self, kh: int, accepted: bool, rpc_id: RpcId,
                request: Op) -> RecordStatus:
        """Fold a kernel accept bit into protocol-level status + mirror."""
        if accepted:
            self._held[kh] = _Held(rpc_id, request)
            self.stats["accepts"] += 1
            return RecordStatus.ACCEPTED
        held = self._held.get(kh)
        if held is not None and held.rpc_id == rpc_id:
            # Duplicate record RPC (client retry): idempotent accept; the
            # table already holds the key.
            held.gc_age = 0
            self.stats["accepts"] += 1
            return RecordStatus.ACCEPTED
        if held is not None:
            self.stats["rejects_conflict"] += 1
        else:
            self.stats["rejects_full"] += 1
        return RecordStatus.REJECTED

    def _record_keys(self, key_hashes: Tuple[int, ...], rpc_id: RpcId,
                     request: Op) -> RecordStatus:
        """All-or-nothing multi-key record via the transactional probe
        kernel: ONE dispatch whether the op accepts or rejects (the kernel
        leaves the table bit-identical on reject, so no rollback gc)."""
        from repro.kernels import txn_probe

        # A key repeated within ONE op occupies one slot and trivially
        # commutes with itself (Python Witness semantics): probe each
        # distinct key once, in first-occurrence order.
        khs = list(dict.fromkeys(key_hashes))
        hi, lo = _lanes(khs)
        # Host mirror resolves RIFL-retry idempotence BEFORE the dispatch: a
        # key already held under this exact rpc_id is an expected hit
        # (§3.2.2 duplicate record), not a conflict.
        own = np.fromiter(
            (1 if (h := self._held.get(kh)) is not None
             and h.rpc_id == rpc_id else 0 for kh in khs),
            np.int32, len(khs),
        )
        res = txn_probe(self._table, hi, lo, own)
        self._table = res.table
        self.stats["kernel_batches"] += 1
        if res.accepted:
            for kh, o in zip(khs, own):
                if o:
                    self._held[kh].gc_age = 0
                else:
                    self._held[kh] = _Held(rpc_id, request)
            self.stats["accepts"] += 1
            return RecordStatus.ACCEPTED
        if any(
            (h := self._held.get(kh)) is not None and h.rpc_id != rpc_id
            for kh in khs
        ):
            self.stats["rejects_conflict"] += 1
        else:
            self.stats["rejects_full"] += 1
        return RecordStatus.REJECTED

    def _record_keys_rollback(self, key_hashes: Tuple[int, ...], rpc_id: RpcId,
                              request: Op) -> RecordStatus:
        """Pre-refactor record-then-rollback scheme, kept only for the
        old-vs-new dispatch comparison in benchmarks/fig_txn.py: the batch
        record dispatch is followed by a gc dispatch whenever a partial
        accept must be rolled back (2 dispatches on the reject path)."""
        from repro.kernels import fastpath_batch, witness_gc

        khs = list(dict.fromkeys(key_hashes))
        hi, lo = _lanes(khs)
        res = fastpath_batch(self._table, hi, lo)
        acc = np.asarray(res.accepted)
        self.stats["kernel_batches"] += 1
        ok = all(
            bool(a)
            or ((h := self._held.get(kh)) is not None and h.rpc_id == rpc_id)
            for kh, a in zip(khs, acc)
        )
        if ok:
            self._table = res.table
            for kh, a in zip(khs, acc):
                if a:
                    self._held[kh] = _Held(rpc_id, request)
                else:
                    self._held[kh].gc_age = 0
            self.stats["accepts"] += 1
            return RecordStatus.ACCEPTED
        # Roll back any accepted prefix (the second dispatch on reject).
        table = res.table
        if any(bool(a) for a in acc):
            keep = acc.astype(bool)
            table = witness_gc(
                table,
                _pad_repeat(np.asarray(res.q_hi)[keep]),
                _pad_repeat(np.asarray(res.q_lo)[keep]),
            )
        self._table = table
        if any(
            (h := self._held.get(kh)) is not None and h.rpc_id != rpc_id
            for kh in khs
        ):
            self.stats["rejects_conflict"] += 1
        else:
            self.stats["rejects_full"] += 1
        return RecordStatus.REJECTED

    # -- master -> witness ----------------------------------------------------
    def gc(self, entries: Tuple[Tuple[int, RpcId], ...]) -> GcResp:
        """Drop synced records (one gc kernel dispatch); report suspects."""
        from repro.kernels import witness_gc

        from .shard import mix2x32

        if self.mode is not WitnessMode.NORMAL:
            return GcResp(stale_requests=())
        # The mirror filters entries to those actually held under the synced
        # rpc_id — a stale gc can never drop a newer same-key record.
        drop = [kh for kh, rpc_id in entries
                if (h := self._held.get(kh)) is not None and h.rpc_id == rpc_id]
        if drop:
            mixed = [mix2x32((kh >> 32) & _M32, kh & _M32) for kh in drop]
            mh = _pad_repeat(np.asarray([m[0] for m in mixed], np.uint32))
            ml = _pad_repeat(np.asarray([m[1] for m in mixed], np.uint32))
            self._table = witness_gc(self._table, mh, ml)
            for kh in drop:
                del self._held[kh]
            self.stats["gc_drops"] += len(drop)
        # Age survivors; collect suspects (§4.5), dedup by rpc.
        stale: List[Op] = []
        seen: set = set()
        for held in self._held.values():
            held.gc_age += 1
            if held.gc_age >= self.SUSPECT_AGE and held.rpc_id not in seen:
                seen.add(held.rpc_id)
                stale.append(held.request)
        return GcResp(stale_requests=tuple(stale))

    def get_recovery_data(self, master_id: int) -> Tuple[Op, ...]:
        """Irreversibly freeze (recovery mode) and return all held requests."""
        if self.master_id != master_id or self.mode is WitnessMode.ENDED:
            return ()
        self.mode = WitnessMode.RECOVERY
        out: Dict[RpcId, Op] = {}
        for held in self._held.values():
            out[held.rpc_id] = held.request     # dedupe multi-key entries
        return tuple(out.values())

    # -- §A.1 consistent reads from backups ------------------------------------
    def commutes_with_all(self, key_hashes: Tuple[int, ...]) -> bool:
        if self.mode is not WitnessMode.NORMAL:
            return False
        return all(kh not in self._held for kh in key_hashes)

    @property
    def occupancy(self) -> int:
        return len(self._held)
