"""Black-box event journal: a bounded ring buffer of protocol events.

The watchdog (repro.sim.watchdog) audits CURP's paper invariants *online*;
this module is its sensor bus.  Protocol objects (Master, Witness,
SlotMigration, TxnCoordinator) and the sim actors carry an optional
``journal`` attribute (default None) and emit one cheap event per protocol
step — execute, sync, record, gc, fence, freeze, handover, intent, ack —
keyed by RIFL id where one applies.  Emission is O(1) and allocation-light;
with no journal attached the hook is a single attribute load + None check,
so the hooks are safe to leave in the hot path permanently.

The buffer is a fixed-capacity ring: old events are overwritten, never
reallocated, so a million-op storm journals in constant memory.  ``dropped``
counts the overwritten prefix; ``last(n)`` / ``to_jsonable()`` feed the
black-box dump a breach produces (the flight-recorder "last N seconds").

Subscribers (the watchdog's monitors) observe every event at emit time —
they run *inside* the discrete-event loop, which is what makes the
invariant checks incremental rather than post-hoc.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

# RIFL identity: (client_id, seq) — the key the journal indexes events by.
RpcKey = Optional[Tuple[int, int]]


class Event:
    """One journal entry.  ``seq`` is the global emission counter (never
    wraps — only the ring storage does), ``t`` the emitting clock's time
    (sim µs when attached to a Sim; the seq itself otherwise)."""

    __slots__ = ("seq", "t", "kind", "actor", "rpc", "args")

    def __init__(self, seq: int, t: float, kind: str, actor: str,
                 rpc: RpcKey, args: Dict[str, Any]) -> None:
        self.seq = seq
        self.t = t
        self.kind = kind
        self.actor = actor
        self.rpc = rpc
        self.args = args

    def to_jsonable(self) -> Dict[str, Any]:
        def enc(v):
            if isinstance(v, (str, int, float, bool)) or v is None:
                return v
            if isinstance(v, (tuple, list)):
                return [enc(x) for x in v]
            return repr(v)

        return {
            "seq": self.seq, "t": self.t, "kind": self.kind,
            "actor": self.actor,
            "rpc": list(self.rpc) if self.rpc is not None else None,
            "args": {k: enc(v) for k, v in self.args.items()},
        }

    def __repr__(self) -> str:  # diagnostics only
        return (f"Event(#{self.seq} t={self.t:.1f} {self.kind} "
                f"{self.actor} rpc={self.rpc} {self.args})")


class EventJournal:
    """Bounded-memory protocol event ring (see module docstring).

    ``clock`` is an optional zero-arg callable returning the current time
    (the sim harness installs ``lambda: sim.now``); without one, events are
    stamped with their own sequence number, which keeps the instant
    harnesses' journals totally ordered too.
    """

    def __init__(self, capacity: int = 8192,
                 clock: Optional[Callable[[], float]] = None) -> None:
        assert capacity >= 1
        self.capacity = capacity
        self.clock = clock
        self.seq = 0
        self._buf: List[Optional[Event]] = [None] * capacity
        self._subs: List[Callable[[Event], None]] = []

    # ------------------------------------------------------------------ emit
    def emit(self, kind: str, actor: str = "", rpc: RpcKey = None,
             **args: Any) -> Event:
        clock = self.clock
        t = clock() if clock is not None else float(self.seq)
        ev = Event(self.seq, t, kind, actor, rpc, args)
        self._buf[self.seq % self.capacity] = ev
        self.seq += 1
        for fn in self._subs:
            fn(ev)
        return ev

    def subscribe(self, fn: Callable[[Event], None]) -> None:
        """Register an observer called synchronously on every emit (the
        watchdog's monitor dispatch)."""
        self._subs.append(fn)

    # ------------------------------------------------------------------ read
    @property
    def dropped(self) -> int:
        """Events overwritten by the ring (total emitted minus retained)."""
        return max(0, self.seq - self.capacity)

    def events(self) -> List[Event]:
        """Surviving events, oldest first."""
        if self.seq <= self.capacity:
            return [e for e in self._buf[:self.seq]]
        head = self.seq % self.capacity
        return [e for e in self._buf[head:] + self._buf[:head]
                if e is not None]

    def last(self, n: int) -> List[Event]:
        evs = self.events()
        return evs[-n:] if n < len(evs) else evs

    def to_jsonable(self, last_n: Optional[int] = None) -> List[Dict[str, Any]]:
        evs = self.events() if last_n is None else self.last(last_n)
        return [e.to_jsonable() for e in evs]
