"""repro.core — CURP: Consistent Unordered Replication Protocol.

Faithful implementation of Park & Ousterhout, "Exploiting Commutativity For
Practical Fast Replication": witnesses (durability without ordering),
speculative masters with commutativity-bounded unsynced windows, batched
backup syncs, RIFL exactly-once semantics, crash recovery, reconfiguration,
and the §A.1 (backup reads) / §A.2 (consensus) extensions — plus the
cross-shard mini-transaction subsystem (repro.core.txn): a RIFL-identified
2PC over the per-shard fast paths, Sinfonia-style, with single-shard
transactions short-circuiting to the 1-RTT path.
"""
from .backup import Backup, LogEntry
from .client import (
    ClientSession,
    Decision,
    combine_decisions,
    decide,
    decide_commit,
    decide_multi,
)
from .config import ConfigManager, HeartbeatDetector, WitnessGeometry
from .consensus import ConsensusCluster, replay_threshold, superquorum
from .device_witness import DeviceWitness
from .local import LocalCluster, OpOutcome
from .master import DUP, ERROR, FAST, SYNCED, Master
from .overload import (
    AdmissionQueue,
    AimdBound,
    ArmorConfig,
    BreakerState,
    CircuitBreaker,
    ClientThrottle,
    DegradeLevel,
    TokenBucket,
    degrade_level,
)
from .migration import (
    MigrationManager,
    MigrationReport,
    SlotMigration,
    SlotMoving,
    plan_rebalance,
)
from .recovery import RecoveryReport, recover_master
from .rifl import RiflTable
from .shard import (
    N_SLOTS,
    ClusterRecoveryReport,
    KeyRouter,
    ShardedClientSession,
    ShardedCluster,
    ShardGroup,
    SlotRouter,
    mix2x32,
)
from .store import KVStore
from .txn import (
    CoordinatorCrash,
    TxnCoordinator,
    TxnOutcome,
    TxnPart,
    TxnPending,
    TxnSpec,
    TxnStatus,
    resolve_pending,
    resolve_txn,
)
from .types import (
    ClusterConfig,
    ExecResult,
    Op,
    OpType,
    RecordStatus,
    RpcId,
    WitnessMode,
    keyhash,
    splitmix64,
)
from .witness import Witness

__all__ = [
    "Backup", "LogEntry", "ClientSession", "Decision", "decide",
    "decide_multi", "decide_commit", "combine_decisions",
    "ConfigManager", "HeartbeatDetector", "WitnessGeometry", "DeviceWitness",
    "AdmissionQueue", "AimdBound", "ArmorConfig", "BreakerState",
    "CircuitBreaker",
    "ClientThrottle", "DegradeLevel", "TokenBucket", "degrade_level",
    "ConsensusCluster", "replay_threshold", "superquorum",
    "LocalCluster", "OpOutcome", "Master", "FAST", "SYNCED", "DUP", "ERROR",
    "RecoveryReport", "recover_master", "RiflTable", "KVStore",
    "ClusterRecoveryReport", "KeyRouter", "SlotRouter", "N_SLOTS",
    "ShardedClientSession", "ShardedCluster", "ShardGroup", "mix2x32",
    "MigrationManager", "MigrationReport", "SlotMigration", "SlotMoving",
    "plan_rebalance",
    "CoordinatorCrash", "TxnCoordinator", "TxnOutcome", "TxnPart",
    "TxnPending", "TxnSpec", "TxnStatus", "resolve_pending", "resolve_txn",
    "ClusterConfig", "ExecResult", "Op", "OpType", "RecordStatus", "RpcId",
    "WitnessMode", "keyhash", "splitmix64", "Witness",
]
