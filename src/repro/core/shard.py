"""Sharded CURP: multi-master partitioning (§4, Fig. 3).

CURP is designed for partitioned stores: each master owns a key partition and
has its *own* witness group and backups; commutativity is judged per shard, so
disjoint partitions proceed entirely in parallel and one master crash only
replays that shard's witnesses.

Three pieces live here:

  * ``SlotRouter`` — slot-table placement.  The mix is the pure-Python
    mirror of the Pallas ``keyhash2x32`` kernel (repro.kernels.keyhash): the
    64-bit splitmix key hash is split into (hi, lo) uint32 lanes, pushed
    through the murmur3 fmix32 chain, and the low output lane mod
    ``n_slots`` picks a SLOT; a slot -> shard table names the owner.  Live
    reconfiguration (repro.core.migration) moves slots between shards by
    editing the table — the hash never changes.  ``repro.kernels.ops.
    shard_route`` computes the same placement batched on-device (table
    gather); Python and Pallas must agree bit-for-bit on ANY slot map.
    ``KeyRouter`` survives as the mod-N compatibility constructor (the
    round-robin default map).
  * ``ShardGroup`` — one master + its witness group + its backups, with the
    full protocol drive loop (speculative update, witness records, batched
    syncs + gc, crash recovery, witness reconfiguration).  This is the unit
    ``LocalCluster`` wraps exactly once and ``ShardedCluster`` wraps N times.
  * ``ShardedCluster`` — a set of shards behind a ``SlotRouter``, with
    cross-shard multi-key ops (``mset``): each shard's sub-op takes the
    per-shard 1-RTT fast path; if any shard's witnesses reject, only that
    shard falls back to an explicit sync (2 RTTs overall).  The cluster also
    owns the live-reconfiguration control plane (``migrate_slots`` /
    ``add_shard`` / ``remove_shard`` / ``rebalance``), per-slot op counters
    feeding the hot-shard auto-split policy, and the retryable-redirect
    check for mid-handover slots.

Client identity (``ShardedClientSession``) is ONE RIFL space per client,
shared across shards: (client_id, seq) pairs are globally unique, which is
what lets a completion record MIGRATE with its key's slot and still dedup a
retry at the new owner without ever colliding with the receiver's own
records.  (The earlier per-shard sequence spaces reused (client_id, seq)
across shards — safe while placement was static, fatally ambiguous once
records can move.)
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .backup import Backup
from .client import ClientSession, Decision, combine_decisions, decide
from .config import ConfigManager, WitnessGeometry
from .master import DUP, ERROR, FAST, SYNCED, Master
from .recovery import RecoveryReport, recover_master
from .txn import (
    CoordinatorCrash,
    TxnCoordinator,
    TxnOutcome,
    TxnPart,
    TxnPending,
    TxnSpec,
    TxnStatus,
    TxnVote,
    resolve_pending,
    resolve_txn,
)
from .types import ClusterConfig, ExecResult, Op, OpType, RecordStatus, keyhash
from .witness import Witness

_M32 = 0xFFFFFFFF


def _fmix32(x: int) -> int:
    """murmur3 32-bit finalizer — must match kernels/ref.py ``fmix32``."""
    x &= _M32
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & _M32
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & _M32
    x ^= x >> 16
    return x


def mix2x32(hi: int, lo: int) -> Tuple[int, int]:
    """Pure-Python mirror of ``ref_keyhash2x32``: (hi, lo) -> (h2, h3)."""
    h1 = _fmix32((lo + 0x9E3779B9) & _M32)
    h2 = _fmix32(hi ^ h1)
    h3 = _fmix32((h1 + h2 * 5 + 0xE6546B64) & _M32)
    return h2, h3


# Default slot-table size.  Must match repro.kernels.ops.DEFAULT_N_SLOTS —
# the Pallas shard_route gather and this router share the table layout.
N_SLOTS = 256


class SlotRouter:
    """Deterministic key -> shard placement shared by Python and Pallas.

    Two-stage: the canonical 64-bit key hash (types.keyhash) is split into
    uint32 lanes and keyhash2x32-mixed; the low lane mod ``n_slots`` picks a
    SLOT, and ``slot_map[slot]`` names the owning shard.  The slot is the
    unit of live migration (repro.core.migration): a handover edits the
    table (``assign``) and bumps ``version`` so cached placements (e.g. the
    serving store's session cache) know to refetch.  ``repro.kernels.ops.
    shard_route`` computes the same placement batched on-device from the
    same table.
    """

    def __init__(self, slot_map: Sequence[int],
                 n_shards: Optional[int] = None) -> None:
        self.slot_map = list(slot_map)
        self.n_slots = len(self.slot_map)
        assert self.n_slots >= 1
        self.n_shards = (max(self.slot_map) + 1) if n_shards is None \
            else n_shards
        self.version = 0

    @classmethod
    def uniform(cls, n_shards: int, n_slots: int = N_SLOTS) -> "SlotRouter":
        """The round-robin default map (slot i -> shard i % N).  For
        power-of-two shard counts dividing ``n_slots`` this is bit-identical
        to the pre-slot-map mod-N placement."""
        assert n_shards >= 1
        return cls([i % n_shards for i in range(n_slots)], n_shards=n_shards)

    # ------------------------------------------------------------ placement
    def slot_of_hash(self, kh64: int) -> int:
        _, h3 = mix2x32((kh64 >> 32) & _M32, kh64 & _M32)
        return h3 % self.n_slots

    def slot_of(self, key: Any) -> int:
        return self.slot_of_hash(keyhash(key))

    def shard_of_hash(self, kh64: int) -> int:
        return self.slot_map[self.slot_of_hash(kh64)]

    def shard_of(self, key: Any) -> int:
        return self.slot_map[self.slot_of(key)]

    def slots_of_shard(self, shard_id: int) -> List[int]:
        return [s for s, owner in enumerate(self.slot_map)
                if owner == shard_id]

    def split_keys(self, keys: Sequence[Any]) -> Dict[int, List[int]]:
        """Group key *positions* by owning shard (stable within a shard)."""
        parts: Dict[int, List[int]] = {}
        for i, k in enumerate(keys):
            parts.setdefault(self.shard_of(k), []).append(i)
        return parts

    # ------------------------------------------------------ reconfiguration
    def assign(self, slots: Sequence[int], shard_id: int) -> None:
        """Flip slots to a new owner (a handover's commit point) and bump
        the map version so cached placements refetch."""
        for s in slots:
            self.slot_map[s] = shard_id
        self.version += 1


class KeyRouter(SlotRouter):
    """Mod-N compatibility constructor: a SlotRouter over the uniform map."""

    def __init__(self, n_shards: int, n_slots: int = N_SLOTS) -> None:
        super().__init__([i % n_shards for i in range(n_slots)],
                         n_shards=n_shards)


class HistoryRecorder:
    """Linearizability-checkable op log shared by the in-process harnesses.

    Entries carry logical (invoke, complete) windows: sequential ops get
    disjoint windows; sub-ops of one multi-shard op share a window (they ran
    concurrently, and linearizability decomposes per key).  The entry shape
    is what repro.sim.linearizability's checker consumes.
    """

    def __init__(self) -> None:
        self.history: List[dict] = []
        self._tick = 0

    def next_window(self) -> Tuple[float, float]:
        t = float(self._tick)
        self._tick += 1
        return (t, t + 0.5)

    def __call__(self, op: Op, value: Any, client_id: int,
                 window: Optional[Tuple[float, float]] = None) -> None:
        if window is None:
            window = self.next_window()
        self.history.append({
            "op": op, "value": value, "client": client_id,
            "invoke": window[0], "complete": window[1], "failed": False,
        })


# ---------------------------------------------------------------------------
# One shard = one master group
# ---------------------------------------------------------------------------
class ShardGroup:
    """One CURP replica group: master + f witnesses + f backups.

    Transport is instant function calls (the timed mirror is repro.sim); the
    protocol steps are the real ones.  The enclosing cluster owns node-id
    allocation (``alloc_id``), the shared ConfigManager, and history
    recording (``record``).
    """

    def __init__(
        self,
        shard_id: int,
        config: ConfigManager,
        alloc_id: Callable[[], int],
        f: int = 3,
        sync_batch: int = 50,
        witness_sets: int = 1024,
        witness_ways: int = 4,
        hot_key_window: float = 0.0,
        auto_sync: bool = True,
        record: Optional[Callable[[Op, Any, int], None]] = None,
        geometry: Optional[WitnessGeometry] = None,
        witness_backend: str = "python",
        gang=None,
    ) -> None:
        self.shard_id = shard_id
        self.config = config
        self.alloc_id = alloc_id
        self.f = f
        self.auto_sync = auto_sync
        self.record = record or (lambda op, value, client_id: None)
        if geometry is None:
            geometry = WitnessGeometry(witness_sets, witness_ways)
        self.geometry = geometry
        assert witness_backend in ("python", "device"), witness_backend
        self.witness_backend = witness_backend
        # Device witnesses stack their tables into one device-resident gang
        # (repro.core.device_witness.WitnessGang): cluster-provided when the
        # group belongs to a ShardedCluster (all shards share one gang so a
        # routed batch is ONE dispatch), group-local otherwise.
        self.gang = gang
        if witness_backend == "device" and self.gang is None:
            from .device_witness import WitnessGang

            lanes = 1
            while lanes < f:
                lanes <<= 1
            self.gang = WitnessGang(geometry.n_sets, geometry.n_ways, lanes)
        self.master = Master(
            alloc_id(), epoch=0, sync_batch=sync_batch,
            hot_key_window=hot_key_window,
        )
        self.backups = [Backup(alloc_id()) for _ in range(f)]
        self.witnesses = [self._new_witness() for _ in range(f)]
        self._witness_ids = tuple(alloc_id() for _ in range(f))
        for w in self.witnesses:
            w.start(self.master.master_id)
        config.publish(shard_id, ClusterConfig(
            master_id=self.master.master_id,
            epoch=0,
            backup_ids=tuple(b.backup_id for b in self.backups),
            witness_ids=self._witness_ids,
            witness_list_version=0,
        ))
        self._dropped_witnesses: set[int] = set()
        # Live-reconfiguration state (repro.core.migration): per-slot op
        # counters feeding the hot-shard rebalance policy (kept on the group
        # so they survive master failovers), the ownership filter re-applied
        # to every recovered master (§3.6: replayed ops for migrated slots
        # are ignored), and the retired flag a drained-and-removed shard
        # carries.
        self.slot_ops: Dict[int, int] = {}
        self.owned_filter: Optional[Callable[[Any], bool]] = None
        self.retired = False

    def _new_witness(self):
        """Build one witness at this group's geometry: the protocol-reference
        Python witness, or the kernel-backed device witness (one Pallas
        dispatch per record batch; see repro.core.device_witness)."""
        if self.witness_backend == "device":
            from .device_witness import DeviceWitness

            return DeviceWitness(self.geometry.n_sets, self.geometry.n_ways,
                                 gang=self.gang)
        return Witness(self.geometry.n_sets, self.geometry.n_ways)

    # ------------------------------------------------------------------ faults
    def witness_drop(self, witness_idx: int, dropped: bool = True) -> None:
        if dropped:
            self._dropped_witnesses.add(witness_idx)
        else:
            self._dropped_witnesses.discard(witness_idx)

    # ----------------------------------------------------------------- updates
    def _master_round(
        self, op: Op, acks: Tuple[Tuple[int, int], ...], now: float,
    ) -> Tuple[str, ExecResult, ClusterConfig]:
        """Master half of one update round, retrying stale-config errors
        (§3.6).  Shared by the per-op and batched paths."""
        for _attempt in range(4):
            cfg = self.config.fetch(self.shard_id)
            verdict, result = self.master.handle_update(
                op, cfg.witness_list_version, acks, now
            )
            if verdict != ERROR:
                return verdict, result, cfg
            if result.error == "TXN_PENDING":
                # Blocked by an undecided transaction intent: retrying at
                # the master is useless — the caller must resolve the
                # transaction (the blocking spec rides in result.value).
                raise TxnPending(result.value)
        raise RuntimeError("update retries exhausted")

    @staticmethod
    def _classify(verdict: str, result: ExecResult,
                  statuses: Sequence[RecordStatus]) -> Tuple[Decision, int, bool]:
        """Fold (master verdict, witness statuses) into the client view:
        (decision, rtts, fast).  Single source of truth for both the per-op
        and batched paths' accounting."""
        if verdict == SYNCED:
            return Decision.COMPLETE, 2, False
        decision = decide(result, statuses)
        if decision is Decision.COMPLETE:
            return decision, 1, verdict == FAST
        return decision, 2, False

    def attempt_update(
        self, op: Op, acks: Tuple[Tuple[int, int], ...], now: float = 0.0,
    ) -> Tuple[str, ExecResult, List[RecordStatus]]:
        """One 1-RTT round: update RPC to the master + parallel witness
        records.  Retries internally on stale-config errors (§3.6)."""
        verdict, result, cfg = self._master_round(op, acks, now)
        statuses: List[RecordStatus] = []
        for i, w in enumerate(self.witnesses):
            if i in self._dropped_witnesses:
                statuses.append(RecordStatus.REJECTED)  # timeout == reject
            else:
                statuses.append(
                    w.record(cfg.master_id, op.key_hashes(), op.rpc_id, op)
                )
        return verdict, result, statuses

    def update(self, session: ClientSession, op: Op, now: float = 0.0):
        """Full CURP update; returns an OpOutcome (see local.py)."""
        from .local import OpOutcome

        verdict, result, statuses = self.attempt_update(op, session.acks(), now)
        decision, rtts, fast = self._classify(verdict, result, statuses)

        if verdict == SYNCED or decision is Decision.NEED_SYNC:
            # Conflict path / slow path: sync before the reply externalizes.
            self._drain_syncs()

        if self.auto_sync and self.master.want_sync:
            self._drain_syncs()

        session.mark_completed(op.rpc_id)
        if verdict != DUP:
            # A RIFL-duplicate retry re-externalizes the ORIGINAL completion;
            # the op already has its one history entry — recording again
            # would demand two linearization points for one invocation.
            self.record(op, result.value, session.client_id)
        return OpOutcome(
            value=result.value,
            rtts=rtts,
            fast_path=fast,
            synced_path=verdict == SYNCED,
            witness_accepts=sum(
                1 for s in statuses if s is RecordStatus.ACCEPTED
            ),
        )

    def update_batch(self, session: ClientSession, ops: Sequence[Op],
                     now: float = 0.0) -> List["OpOutcome"]:
        """Batched CURP updates: one master round (ops executed in order) +
        ONE record invocation per witness for the whole batch (a single
        set-parallel kernel dispatch on the device backend).

        Per-op accept/reject and fast/slow-path accounting are preserved —
        op j's witness statuses see exactly the accepts of ops < j, as the
        per-op path would.  Syncs and gc don't interleave inside a batch
        (that's the batching window); any op that needs a sync is drained
        once before the batch returns, so nothing is externalized early.
        """
        from .local import OpOutcome

        results = [self._master_round(op, session.acks(), now) for op in ops]
        cfg = self.config.fetch(self.shard_id)
        per_witness: List[List[RecordStatus]] = []
        for i, w in enumerate(self.witnesses):
            if i in self._dropped_witnesses:
                per_witness.append([RecordStatus.REJECTED] * len(ops))
            else:
                per_witness.append(w.record_batch(cfg.master_id, list(ops)))

        outcomes: List[OpOutcome] = []
        need_drain = False
        for j, op in enumerate(ops):
            verdict, result, _cfg = results[j]
            statuses = [pw[j] for pw in per_witness]
            decision, rtts, fast = self._classify(verdict, result, statuses)
            if verdict == SYNCED or decision is Decision.NEED_SYNC:
                need_drain = True
            session.mark_completed(op.rpc_id)
            if verdict != DUP:   # see update(): dups re-externalize, once
                self.record(op, result.value, session.client_id)
            outcomes.append(OpOutcome(
                value=result.value,
                rtts=rtts,
                fast_path=fast,
                synced_path=verdict == SYNCED,
                witness_accepts=sum(
                    1 for s in statuses if s is RecordStatus.ACCEPTED
                ),
            ))
        if need_drain or (self.auto_sync and self.master.want_sync):
            self._drain_syncs()
        return outcomes

    def read(self, session: ClientSession, op: Op, now: float = 0.0):
        from .local import OpOutcome

        verdict, result = self.master.handle_read(op, now)
        if verdict == ERROR and result.error == "TXN_PENDING":
            raise TxnPending(result.value)
        if verdict == SYNCED:
            self._drain_syncs()
        self.record(op, result.value, session.client_id)
        return OpOutcome(
            value=result.value,
            rtts=1 if verdict == FAST else 2,
            fast_path=verdict == FAST,
            synced_path=verdict == SYNCED,
            witness_accepts=0,
        )

    def read_from_backup(
        self, session: ClientSession, op: Op, backup_idx: int = 0,
        witness_idx: int = 0,
    ) -> Tuple[Any, bool]:
        """§A.1 consistent read from a (local) backup: check commutativity with
        a (local) witness first.  Returns (value, served_by_backup)."""
        w = self.witnesses[witness_idx]
        if w.commutes_with_all(op.key_hashes()):
            from .store import KVStore

            view = KVStore()
            for e in self.backups[backup_idx].get_log():
                view.execute(e.op)
            return view.get(op.keys[0]), True
        out = self.read(session, op)
        return out.value, False

    # ---------------------------------------------- 2PC participant (txn.py)
    def txn_prepare(self, session: ClientSession, op: Op,
                    now: float = 0.0) -> TxnVote:
        """One PREPARE leg: speculative intent install at the master +
        parallel witness records of the leg's keys (the tombstoned intents
        that keep commutativity checks sound during the window).

        The leg is durably prepared on return: 1 RTT when the master was
        fast AND every witness accepted, otherwise via an explicit backup
        sync (2 RTTs for this leg only).  A vote NO (foreign intent lock or
        an existing decision tombstone) installs nothing.
        """
        for _attempt in range(4):
            cfg = self.config.fetch(self.shard_id)
            verdict, result = self.master.handle_update(
                op, cfg.witness_list_version, session.acks(), now
            )
            if verdict != ERROR or result.error != "WRONG_WITNESS_VERSION":
                break
        if verdict == ERROR:
            # TXN_LOCKED carries the blocking spec: the coordinator's
            # wound/wait policy (repro.core.txn) needs the holder's txn_id.
            return TxnVote(
                granted=False, error=result.error,
                blocking=result.value if result.error == "TXN_LOCKED"
                else None,
            )
        statuses: List[RecordStatus] = []
        for i, w in enumerate(self.witnesses):
            if i in self._dropped_witnesses:
                statuses.append(RecordStatus.REJECTED)
            else:
                statuses.append(
                    w.record(cfg.master_id, op.key_hashes(), op.rpc_id, op)
                )
        decision, rtts, fast = self._classify(verdict, result, statuses)
        if verdict == SYNCED or decision is Decision.NEED_SYNC:
            # Slow path: the intent reaches the backups before the vote is
            # externalized, so the prepare is durable either way.
            self._drain_syncs()
        session.mark_completed(op.rpc_id)
        if result.value is None:
            # RIFL already acked this leg away (a retry of a transaction
            # that fully completed): the vote stands, the read values were
            # externalized on the original run.
            reads = ()
        else:
            _status, reads = result.value
        return TxnVote(granted=True, fast=fast, rtts=rtts, read_values=reads)

    def txn_decide(self, op: Op,
                   session: Optional[ClientSession] = None) -> str:
        """Apply one COMMIT/ABORT leg.  No witness records and no pre-reply
        sync — the decision re-derives from durable prepare state on crash
        (see repro.core.txn).  ``session=None`` is the recovery-resolution
        path (the coordinator is gone; no acks, no completion marking)."""
        acks = session.acks() if session is not None else ()
        for _attempt in range(4):
            cfg = self.config.fetch(self.shard_id)
            verdict, result = self.master.handle_update(
                op, cfg.witness_list_version, acks, 0.0
            )
            if verdict != ERROR:
                break
        assert verdict != ERROR, f"decide leg failed: {result.error}"
        if session is not None:
            session.mark_completed(op.rpc_id)
        if self.auto_sync and self.master.want_sync:
            self._drain_syncs()
        return result.value

    # ------------------------------------------------------------------ syncs
    def _drain_syncs(self) -> None:
        """Run batched backup syncs + witness gc until quiescent (§4.4, §3.5)."""
        while True:
            req = self.master.begin_sync()
            if req is None:
                return
            ok = True
            for b in self.backups:
                resp = b.handle_sync(req)
                ok = ok and resp.ok
            if not ok:
                self.master.abort_sync()
                return
            gc_entries = self.master.complete_sync()
            live = [w for i, w in enumerate(self.witnesses)
                    if i not in self._dropped_witnesses]
            for resp in self._gc_witnesses(live, gc_entries):
                # §4.5: retry suspected uncollected garbage through RIFL.
                for op in resp.stale_requests:
                    self.master.handle_update(
                        op,
                        self.config.fetch(self.shard_id).witness_list_version,
                        (), 0.0,
                    )

    def _gc_witnesses(self, witnesses, gc_entries):
        """One sync round's witness gc: device witnesses sharing a gang
        clear + age in ONE stacked dispatch (lane-expanded entries); any
        remaining witness gc's individually.  Responses in witness order."""
        if self.witness_backend == "device" and len(witnesses) > 1:
            from .device_witness import DeviceWitness, gc_many
            from .types import WitnessMode

            gang = self.gang
            stacked = [w for w in witnesses
                       if isinstance(w, DeviceWitness)
                       and w.mode is WitnessMode.NORMAL and w.gang is gang]
            if len(stacked) > 1:
                resp = dict(zip((id(w) for w in stacked),
                                gc_many(stacked, gc_entries)))
                return [resp[id(w)] if id(w) in resp else w.gc(gc_entries)
                        for w in witnesses]
        return [w.gc(gc_entries) for w in witnesses]

    def sync_now(self) -> None:
        self.master.want_sync = True
        self._drain_syncs()

    # --------------------------------------------------------------- recovery
    def crash_master(self) -> RecoveryReport:
        """Kill this shard's master (unsynced state lost) and recover a new
        one from this shard's backups + one of its witnesses (§3.3).  Other
        shards are untouched by construction."""
        old_id = self.master.master_id
        new_master = Master(
            self.alloc_id(),
            sync_batch=self.master.sync_batch,
            hot_key_window=self.master.hot_key_window,
        )
        # Re-apply the cluster's ownership filter BEFORE witness replay:
        # §3.6 — replayed requests for slots migrated away are ignored.
        new_master.owned_partition = self.owned_filter
        live = [i for i in range(self.f) if i not in self._dropped_witnesses]
        assert live, "no witness reachable: recovery must wait (§3.3)"
        recovery_witness = self.witnesses[live[0]]
        new_witnesses = [self._new_witness() for _ in range(self.f)]
        new_ids = tuple(self.alloc_id() for _ in range(self.f))
        report = recover_master(
            shard_id=self.shard_id,
            old_master_id=old_id,
            new_master=new_master,
            backups=self.backups,
            recovery_witness=recovery_witness,
            new_witnesses=new_witnesses,
            new_witness_ids=new_ids,
            config=self.config,
        )
        # The black box survives the crash: the replacement master and
        # witnesses inherit the journal AFTER replay (recovery internals are
        # not client-visible protocol steps), and the epoch fence is
        # journaled so the monotonicity monitor sees every bump.
        jr = self.master.journal
        new_master.journal = jr
        new_master.journal_actor = f"m{new_master.master_id}"
        for w_old, w_new in zip(self.witnesses, new_witnesses):
            w_new.journal = getattr(w_old, "journal", None)
            w_new.journal_actor = getattr(w_old, "journal_actor", "w?")
        if jr is not None:
            cfg = self.config.fetch(self.shard_id)
            jr.emit("fence", actor=f"m{new_master.master_id}",
                    shard=self.shard_id, epoch=cfg.epoch,
                    wlv=cfg.witness_list_version, reason="recovery")
        self.master = new_master
        self.witnesses = new_witnesses
        self._witness_ids = new_ids
        self._dropped_witnesses.clear()
        return report

    def replace_witness(self, witness_idx: int) -> None:
        """§3.6 case 2: decommission a witness, install a fresh one, bump the
        WitnessListVersion; master syncs before the new config goes live."""
        dead_id = self._witness_ids[witness_idx]
        new_w = self._new_witness()
        new_id = self.alloc_id()
        self.sync_now()  # master must sync to restore f fault tolerance
        cfg = self.config.replace_witness(self.shard_id, dead_id, new_id)
        self.master.witness_list_version = cfg.witness_list_version
        new_w.start(self.master.master_id)
        self.witnesses[witness_idx] = new_w
        ids = list(self._witness_ids)
        ids[witness_idx] = new_id
        self._witness_ids = tuple(ids)


# ---------------------------------------------------------------------------
# Client sessions: one RIFL identity space per client, shared across shards
# ---------------------------------------------------------------------------
class ShardedClientSession:
    """One logical client talking to N shards through ONE RIFL space.

    (client_id, seq) pairs are allocated from a single per-client sequence,
    so every rpc_id is globally unique across shards.  That is the property
    live migration needs: a completion record can move with its key's slot
    (Master.migrated_rifl) and still dedup a cross-move retry without ever
    being confusable with the new owner's native records.  Acks stay safe to
    apply at any master: completion is tracked globally, so ``seq < N`` in
    an ack means the op completed wherever it ran — a master deleting its
    own records below N deletes only completed ops.
    """

    def __init__(self, client_id: int, router: SlotRouter) -> None:
        self.client_id = client_id
        self.router = router
        self._ids = ClientSession(client_id=client_id)
        self._txn_seq = 0

    def session_for(self, shard_id: int) -> ClientSession:
        """The identity space used when talking to ``shard_id`` — the SAME
        shared space for every shard (see class docstring)."""
        return self._ids

    def acks(self) -> Tuple[Tuple[int, int], ...]:
        return self._ids.acks()

    def mark_completed(self, rpc_id) -> None:
        self._ids.mark_completed(rpc_id)

    def abandon(self, rpc_id) -> None:
        """Release a never-transmitted identity (see ClientSession.abandon):
        callers that created an op and then drew a SlotMoving redirect call
        this before re-issuing fresh, so the ack frontier keeps moving."""
        self._ids.abandon(rpc_id)

    # convenience constructors (the route only decides WHERE the op goes;
    # the identity comes from the shared space)
    def _sub(self, key) -> ClientSession:
        return self.session_for(self.router.shard_of(key))

    def op_set(self, key, value) -> Op:
        return self._sub(key).op_set(key, value)

    def op_get(self, key) -> Op:
        return self._sub(key).op_get(key)

    def op_incr(self, key, delta: int = 1) -> Op:
        return self._sub(key).op_incr(key, delta)

    def op_hmset(self, key, fields) -> Op:
        return self._sub(key).op_hmset(key, fields)

    def op_del(self, key) -> Op:
        return self._sub(key).op_del(key)

    def op_sadd(self, key, member) -> Op:
        return self._sub(key).op_sadd(key, member)

    def op_append(self, key, chunk) -> Op:
        return self._sub(key).op_append(key, chunk)

    def op_max(self, key, n) -> Op:
        return self._sub(key).op_max(key, n)

    def mset_parts(self, kvs,
                   prev: Optional[Dict[int, Op]] = None) -> Dict[int, Op]:
        """Split a multi-key set into per-shard MSET sub-ops, each carrying
        its own rpc_id from the client's (shared, globally-unique) space.

        ``prev`` is the part map of an earlier attempt of the SAME mset: a
        retry after a partial failure must reuse the original sub-ops so
        already-applied legs RIFL-dedup instead of re-executing under fresh
        identities (which would double-apply and double-record).  The retry
        re-routes each ORIGINAL leg to its key set's CURRENT owner — a leg
        whose slots migrated whole between attempts still dedups at the new
        owner (its completion record moved with the slots).  A migration
        that SPLITS a leg's keys across shards (or folds two legs onto one
        shard) makes the original identities unreplayable; that raises a
        descriptive error rather than double-applying.
        """
        kvs = list(kvs)
        if prev is not None:
            want = {k: v for k, v in kvs}
            got = {k: v for sub in prev.values()
                   for k, v in zip(sub.keys, sub.args)}
            assert want == got, "mset retry must carry the same kvs"
            for sub in prev.values():
                owners = {self.router.shard_of(k) for k in sub.keys}
                if len(owners) != 1:
                    raise ValueError(
                        "mset retry invalidated by a live migration: leg "
                        f"{sub.rpc_id} now spans shards {sorted(owners)}; "
                        "use ShardedCluster.txn for atomic retries, or "
                        "re-issue fresh only if no leg ever reached a master"
                    )
            # The keys of the returned map are LEG ids (the shard ids at
            # allocation time) — the executor re-resolves each leg's current
            # owner, so several original legs may legally land on one shard
            # after a migration.
            return dict(prev)
        parts = self.router.split_keys([k for k, _ in kvs])
        return {
            shard_id: self.session_for(shard_id).op_mset(
                [kvs[i] for i in idxs]
            )
            for shard_id, idxs in parts.items()
        }

    def txn_spec(self, writes, reads=()) -> TxnSpec:
        """Build a transaction spec: split read/write sets by the router and
        fix every leg's RIFL identities (prepare_rpc + decide_rpc) up front,
        so any retry of any leg — by this client or by crash resolution —
        is a RIFL-dedup'd replay."""
        writes = list(writes)
        reads = list(reads)
        by_shard: Dict[int, Tuple[List, List]] = {}
        for k, v in writes:
            by_shard.setdefault(self.router.shard_of(k), ([], []))[0].append(
                (k, v)
            )
        for k in reads:
            by_shard.setdefault(self.router.shard_of(k), ([], []))[1].append(k)
        self._txn_seq += 1
        parts = tuple(
            TxnPart(
                shard_id=sid,
                prepare_rpc=self.session_for(sid).next_rpc_id(),
                decide_rpc=self.session_for(sid).next_rpc_id(),
                write_kvs=tuple(w),
                read_keys=tuple(r),
            )
            for sid, (w, r) in sorted(by_shard.items())
        )
        return TxnSpec(txn_id=(self.client_id, self._txn_seq), parts=parts)


@dataclass
class ClusterRecoveryReport:
    """Aggregate of per-shard RecoveryReports (serving-level crash).

    The txn_* counts are CLUSTER-level: the post-recovery resolution sweep
    decides orphaned transactions whose intents may span several shards, so
    they are reported here rather than attributed to any one shard."""
    per_shard: Tuple[RecoveryReport, ...]
    txn_resolved: int = 0
    txn_committed: int = 0
    txn_aborted: int = 0

    @property
    def replayed(self) -> int:
        return sum(r.replayed for r in self.per_shard)

    @property
    def restored_log_entries(self) -> int:
        return sum(r.restored_log_entries for r in self.per_shard)

    @property
    def witness_requests(self) -> int:
        return sum(r.witness_requests for r in self.per_shard)


# ---------------------------------------------------------------------------
# The sharded cluster
# ---------------------------------------------------------------------------
class ShardedCluster:
    """N CURP shards behind a KeyRouter (paper §4, Fig. 3 deployment shape).

    Single-shard ops behave exactly like LocalCluster ops against the owning
    shard.  ``mset`` fans sub-ops out to every touched shard; it completes in
    1 RTT iff every shard's witnesses accepted, otherwise only the rejecting
    shards pay the sync fallback.
    """

    def __init__(
        self,
        n_shards: int = 4,
        f: int = 3,
        sync_batch: int = 50,
        witness_sets: int = 1024,
        witness_ways: int = 4,
        hot_key_window: float = 0.0,
        seed: int = 0,
        auto_sync: bool = True,
        geometry: Optional[WitnessGeometry] = None,
        witness_backend: str = "python",
        n_slots: int = N_SLOTS,
    ) -> None:
        from .migration import MigrationManager

        self.n_shards = n_shards
        self.f = f
        self.rng = random.Random(seed)
        self.config = ConfigManager()
        self.router = SlotRouter.uniform(n_shards, n_slots)
        self._record = HistoryRecorder()
        self.history = self._record.history   # linearizability-checkable log
        self._next_node_id = 0
        if geometry is None:
            geometry = WitnessGeometry(witness_sets, witness_ways)
        self.geometry = geometry
        self.witness_backend = witness_backend
        # One device-resident gang for the WHOLE cluster: every shard's
        # witnesses stack into it, so a routed cross-shard batch records at
        # all its target lanes in ONE dispatch (see update_batch).
        self.gang = None
        if witness_backend == "device":
            from .device_witness import WitnessGang

            lanes = 1
            while lanes < n_shards * f:
                lanes <<= 1
            self.gang = WitnessGang(geometry.n_sets, geometry.n_ways, lanes)
        # Kept for add_shard: a grown shard is built like the seed shards.
        self._group_kwargs = dict(
            f=f, sync_batch=sync_batch, hot_key_window=hot_key_window,
            auto_sync=auto_sync,
        )
        self.shards = [
            ShardGroup(
                shard_id=i, config=self.config, alloc_id=self._node_id,
                record=self._record, geometry=geometry,
                witness_backend=witness_backend, gang=self.gang,
                **self._group_kwargs,
            )
            for i in range(n_shards)
        ]
        self.migration = MigrationManager(self)
        self._apply_ownership()
        self._fused = None
        if witness_backend == "device":
            from .fastbatch import FusedBatchDriver

            self._fused = FusedBatchDriver(self)
        # Optional flight recorder (repro.core.telemetry.Tracer): when
        # attached, update_batch emits wall-clock batch spans + per-op
        # sampled spans keyed by RIFL id.
        self.tracer = None
        self._batch_seq = 0

    def _node_id(self) -> int:
        self._next_node_id += 1
        return self._next_node_id

    def _apply_ownership(self) -> None:
        """Install the router-backed ownership filter on every live master
        (§3.6: a master ignores replayed/incoming ops for slots it no longer
        owns).  The filter closes over the LIVE router, so a slot-map flip
        changes every master's view at once."""
        for g in self.shards:
            if g.retired:
                continue
            flt = (lambda key, sid=g.shard_id:
                   self.router.shard_of(key) == sid)
            g.owned_filter = flt
            g.master.owned_partition = flt

    # ----------------------------------------------------------------- client
    def new_client(self) -> ShardedClientSession:
        return ShardedClientSession(self._node_id(), self.router)

    def shard_of(self, key: Any) -> int:
        return self.router.shard_of(key)

    def slot_of(self, key: Any) -> int:
        return self.router.slot_of(key)

    def _group_for(self, op: Op) -> ShardGroup:
        """Route an op: redirect if any touched slot is mid-handover, feed
        the per-slot load counters, and require a single owning shard."""
        slots = {self.router.slot_of(k) for k in op.keys}
        self.migration.check_slots(slots)
        sids = {self.router.slot_map[s] for s in slots}
        if len(sids) != 1:
            raise ValueError(
                f"op spans shards {sorted(sids)}; use ShardedCluster.mset"
            )
        group = self.shards[sids.pop()]
        for s in slots:
            group.slot_ops[s] = group.slot_ops.get(s, 0) + 1
        return group

    def update(self, session: ShardedClientSession, op: Op, now: float = 0.0):
        group = self._group_for(op)
        return self._with_txn_resolution(
            lambda: group.update(session.session_for(group.shard_id), op, now)
        )

    def read(self, session: ShardedClientSession, op: Op, now: float = 0.0):
        group = self._group_for(op)
        return self._with_txn_resolution(
            lambda: group.read(session.session_for(group.shard_id), op, now)
        )

    def _with_txn_resolution(self, fn):
        """Run a protocol call; whenever it hits keys locked by an undecided
        transaction intent (an orphaned 2PC — its coordinator crashed),
        resolve that transaction from participant state and retry.  Each
        distinct orphan is resolved at most once (an op spanning several
        orphans' locks resolves them all); a repeat of the same txn_id
        re-raises instead of looping."""
        seen: set = set()
        while True:
            try:
                return fn()
            except TxnPending as pend:
                if pend.spec.txn_id in seen:
                    raise
                seen.add(pend.spec.txn_id)
                resolve_txn(self, pend.spec)

    def update_batch(self, session: ShardedClientSession, ops: Sequence[Op],
                     now: float = 0.0) -> List["OpOutcome"]:
        """Batched client path: group ops by owning shard, drive each shard's
        batch through ShardGroup.update_batch (one witness-record invocation
        — one kernel dispatch on the device backend — per witness per shard),
        and return per-op outcomes in the input order.

        On the device backend a routed cross-shard batch of plain updates
        first tries the fused driver (core/fastbatch.py): ONE stacked-gang
        dispatch covers hashing, slot routing, the device-resident master
        window conflict check, and every shard's every witness record.  The
        driver declines (returns None) whenever any op or shard falls off
        its eligibility envelope, and the per-shard path below runs."""
        if self.tracer is not None:
            return self._update_batch_traced(session, ops, now)
        return self._update_batch(session, ops, now)

    def _update_batch_traced(self, session, ops, now):
        """Wall-clock batch + sampled per-op spans around the real path
        (times in µs since an arbitrary perf_counter origin)."""
        import time as _time

        t0 = _time.perf_counter()
        fused_before = (self._fused.stats["fused_batches"]
                        if self._fused is not None else 0)
        out = self._update_batch(session, ops, now)
        t1 = _time.perf_counter()
        tr = self.tracer
        self._batch_seq += 1
        fused = (self._fused is not None
                 and self._fused.stats["fused_batches"] > fused_before)
        tr.span(("batch", self._batch_seq), "update_batch", t0 * 1e6,
                (t1 - t0) * 1e6, actor="cluster",
                args={"ops": len(ops), "fused": fused}, force=True)
        per_op = (t1 - t0) * 1e6 / max(1, len(ops))
        for i, op in enumerate(ops):
            tr.span(op.rpc_id, "op", t0 * 1e6 + i * per_op, per_op,
                    actor="cluster",
                    status="fast" if out[i].fast_path else "slow")
        return out

    def _update_batch(self, session: ShardedClientSession, ops: Sequence[Op],
                      now: float = 0.0) -> List["OpOutcome"]:
        if self._fused is not None:
            fused = self._fused.try_update_batch(session, ops, now)
            if fused is not None:
                return fused
        groups: Dict[int, List[int]] = {}
        for idx, op in enumerate(ops):
            groups.setdefault(self._group_for(op).shard_id, []).append(idx)
        out: List[Optional["OpOutcome"]] = [None] * len(ops)
        for shard_id, idxs in groups.items():
            sub = session.session_for(shard_id)
            res = self._with_txn_resolution(
                lambda shard_id=shard_id, sub=sub, idxs=idxs:
                self.shards[shard_id].update_batch(
                    sub, [ops[i] for i in idxs], now
                )
            )
            for i, outcome in zip(idxs, res):
                out[i] = outcome
        return out  # type: ignore[return-value]

    def mset(self, session: ShardedClientSession, kvs, now: float = 0.0,
             parts: Optional[Dict[int, Op]] = None):
        """Cross-shard multi-key set: per-shard 1-RTT fast path when every
        shard's sub-op is accepted, per-shard sync fallback otherwise.

        Durability is per shard, atomicity is per KEY only — a client crash
        mid-mset can leave a torn cross-shard write (use ``txn``/
        ``mset_atomic`` for all-or-nothing semantics).  ``parts`` replays an
        earlier attempt's per-shard sub-ops (same rpc_ids), so a retry after
        a partial failure RIFL-dedups instead of double-applying.
        """
        from .local import OpOutcome
        from .migration import SlotMoving

        fresh = parts is None
        parts = session.mset_parts(kvs, prev=parts)
        # Redirect before ANY leg is attempted: a mid-handover slot fails the
        # whole mset client-side (nothing recorded anywhere), so the caller
        # can re-issue fresh once the map settles.  Identities this call
        # just allocated are released (never transmitted) so the ack
        # frontier keeps moving; replayed ``parts`` identities are live and
        # stay reserved.
        try:
            self.migration.check_keys(k for sub in parts.values()
                                      for k in sub.keys)
        except SlotMoving:
            if fresh:
                for sub in parts.values():
                    session.abandon(sub.rpc_id)
            raise
        # A leg blocked by an orphaned transaction intent resolves + retries
        # the whole mset; the fixed per-shard rpc_ids make that idempotent.
        return self._with_txn_resolution(
            lambda: self._mset_once(session, parts, now)
        )

    def _mset_once(self, session: ShardedClientSession,
                   parts: Dict[int, Op], now: float):
        from .local import OpOutcome

        # Resolve each leg's CURRENT owner (a retried leg may have migrated
        # since allocation — its dict key is the historical leg id, not
        # necessarily today's shard; see mset_parts).
        owners: Dict[int, ShardGroup] = {}
        for leg_id, sub_op in parts.items():
            sids = {self.router.shard_of(k) for k in sub_op.keys}
            assert len(sids) == 1, "validated in mset_parts"
            owners[leg_id] = self.shards[sids.pop()]
        # Round 1 (parallel in a real deployment): speculative execute + record
        # at every touched shard.
        attempts: Dict[int, Tuple[str, ExecResult, List[RecordStatus]]] = {}
        decisions: Dict[int, Decision] = {}
        for leg_id, sub_op in parts.items():
            group = owners[leg_id]
            for k in sub_op.keys:
                s = self.router.slot_of(k)
                group.slot_ops[s] = group.slot_ops.get(s, 0) + 1
            attempt = group.attempt_update(sub_op, session.acks(), now)
            attempts[leg_id] = attempt
            decisions[leg_id] = decide(attempt[1], attempt[2])
        # A SYNCED verdict means that master must finish its sync before the
        # reply is externalized; the harness performs the master's sync here.
        for leg_id, (verdict, _res, _sts) in attempts.items():
            if verdict == SYNCED:
                owners[leg_id]._drain_syncs()
        # Client completion rule across shards (§3.2.1, same fold as
        # decide_multi): if not COMPLETE, round 2 sends explicit syncs to the
        # NEED_SYNC shards only.
        overall = combine_decisions(decisions.values())
        if overall is Decision.NEED_SYNC:
            for leg_id, d in decisions.items():
                if d is Decision.NEED_SYNC:
                    owners[leg_id]._drain_syncs()
        # 1 RTT only if every shard was fast AND fully witness-accepted.
        all_fast = all(
            attempts[lid][0] == FAST and d is Decision.COMPLETE
            for lid, d in decisions.items()
        )
        accepts = sum(
            1 for (_v, _r, statuses) in attempts.values()
            for s in statuses if s is RecordStatus.ACCEPTED
        )
        any_synced = any(v == SYNCED for (v, _r, _s) in attempts.values())
        window = self._record.next_window()
        for leg_id, sub_op in parts.items():
            session.mark_completed(sub_op.rpc_id)
            group = owners[leg_id]
            if group.auto_sync and group.master.want_sync:
                group._drain_syncs()
            if attempts[leg_id][0] != DUP:   # dup legs already recorded
                self._record(sub_op, attempts[leg_id][1].value,
                             session.client_id, window=window)
        return OpOutcome(
            value="OK",
            rtts=1 if all_fast else 2,
            fast_path=all_fast,
            synced_path=any_synced,
            witness_accepts=accepts,
        )

    # ----------------------------------------------- transactions (core.txn)
    def txn(
        self,
        session: ShardedClientSession,
        writes,
        reads=(),
        now: float = 0.0,
        on_message=None,
        spec: Optional[TxnSpec] = None,
        wound_wait: bool = True,
    ) -> TxnOutcome:
        """Atomic cross-shard mini-transaction (RIFL-identified 2PC over the
        per-shard fast paths; see repro.core.txn).

        Single-shard transactions short-circuit to one 1-RTT op.  ``spec``
        replays an earlier attempt (same RIFL identities — idempotent);
        ``on_message(stage, shard_id, idx)`` is the crash-injection hook
        (raise CoordinatorCrash to kill the coordinator at that message).
        ``wound_wait`` enables the deterministic intent-conflict policy
        (lower txn_id wins; see TxnCoordinator) — pass False for the
        pre-policy vote-NO-on-any-foreign-intent behavior.
        """
        from .migration import SlotMoving

        fresh_spec = spec is None
        if spec is None:
            spec = session.txn_spec(writes, reads)
        # Redirect before any PREPARE leaves: a leg pinned to a mid-handover
        # slot would land on the wrong owner after the flip.  A spec this
        # call just built is released (its identities never left the
        # client); a replayed spec stays reserved.
        try:
            self.migration.check_keys(
                k for part in spec.parts for k in part.keys
            )
        except SlotMoving:
            if fresh_spec:
                for part in spec.parts:
                    session.abandon(part.prepare_rpc)
                    session.abandon(part.decide_rpc)
            raise
        coord = TxnCoordinator(self, session, wound_wait=wound_wait)
        coord.journal = self.migration.journal
        window = self._record.next_window()
        try:
            out = self._with_txn_resolution(
                lambda: coord.run(spec, now=now, on_message=on_message)
            )
        except CoordinatorCrash:
            # The coordinator died mid-2PC: its effects may or may not land
            # (resolution decides later) — a "maybe" op for the checker.
            self.history.append({
                "op": self._txn_history_op(spec), "value": None,
                "client": session.client_id,
                "invoke": window[0], "complete": window[1], "failed": True,
            })
            raise
        if out.status is TxnStatus.COMMITTED and len(spec.parts) > 1:
            # Multi-shard commits record ONE whole-transaction entry here.
            # The single-shard short-circuit already recorded its (only)
            # entry inside ShardGroup.update — recording again would put
            # two must-linearize points for one atomic op into the history
            # and make the strict checker reject correct executions.
            reads_in_spec_order = tuple(
                out.reads.get(k) for k in spec.read_keys
            ) if out.reads is not None else ()
            self._record(
                self._txn_history_op(spec),
                ("COMMITTED", reads_in_spec_order),
                session.client_id, window=window,
            )
        return out

    @staticmethod
    def _txn_history_op(spec: TxnSpec) -> Op:
        """One history entry for the WHOLE transaction (every shard's leg),
        so the strict linearizability checker treats it atomically."""
        keys = tuple(k for k, _ in spec.write_kvs) + spec.read_keys
        return Op(OpType.TXN, keys, (spec,), spec.txn_id)

    def mset_atomic(self, session: ShardedClientSession, kvs,
                    now: float = 0.0) -> TxnOutcome:
        """All-or-nothing multi-key set: atomic across shards via the
        transaction subsystem (unlike ``mset``, which is only per-shard
        durable).  Single-shard key sets keep the 1-RTT fast path."""
        return self.txn(session, writes=kvs, now=now)

    def resolve_txn(self, spec: TxnSpec) -> TxnStatus:
        """Finish one orphaned transaction (Sinfonia recovery rule)."""
        return resolve_txn(self, spec)

    def resolve_pending_txns(self) -> Dict[str, int]:
        """Sweep and resolve every undecided intent on every shard."""
        return resolve_pending(self)

    # ----------------------------------------- live reconfiguration (§3.6)
    def start_migration(self, slots: Sequence[int], dst_shard: int):
        """Begin moving ``slots`` to ``dst_shard``; returns SlotMigration
        handles (one per donor) to drive stepwise — harnesses interleave
        client traffic between ``step()`` calls.  The slots redirect
        (SlotMoving) from this call until their handover commits."""
        return self.migration.start(slots, dst_shard)

    def migrate_slots(self, slots: Sequence[int], dst_shard: int):
        """Move ``slots`` to ``dst_shard``, running each donor's handover to
        completion.  Returns the MigrationReports."""
        return self.migration.migrate(slots, dst_shard)

    def add_shard(self) -> int:
        """Grow the cluster by one (initially slot-less) shard group; move
        load onto it with ``migrate_slots``/``rebalance``.  Returns the new
        shard id."""
        sid = len(self.shards)
        group = ShardGroup(
            shard_id=sid, config=self.config, alloc_id=self._node_id,
            record=self._record, geometry=self.geometry,
            witness_backend=self.witness_backend, gang=self.gang,
            **self._group_kwargs,
        )
        self.shards.append(group)
        self.n_shards += 1
        if sid >= self.router.n_shards:
            self.router.n_shards = sid + 1
        self._apply_ownership()
        return sid

    def remove_shard(self, shard_id: int) -> List[Any]:
        """Drain a shard: live-migrate every slot it owns round-robin onto
        the remaining shards, then retire the group.  Returns the
        MigrationReports."""
        victim = self.shards[shard_id]
        if victim.retired:
            raise ValueError(f"shard {shard_id} already retired")
        targets = [g.shard_id for g in self.shards
                   if not g.retired and g.shard_id != shard_id]
        if not targets:
            raise ValueError("cannot remove the last shard")
        by_dst: Dict[int, List[int]] = {}
        for i, slot in enumerate(self.router.slots_of_shard(shard_id)):
            by_dst.setdefault(targets[i % len(targets)], []).append(slot)
        reports = []
        for dst, slots in sorted(by_dst.items()):
            reports.extend(self.migrate_slots(slots, dst))
        victim.retired = True
        victim.owned_filter = lambda key: False
        victim.master.owned_partition = victim.owned_filter
        self.n_shards -= 1
        return reports

    def slot_loads(self) -> List[int]:
        """Per-slot op counts summed across shard groups (the rebalance
        policy's input)."""
        loads = [0] * self.router.n_slots
        for g in self.shards:
            for s, c in g.slot_ops.items():
                loads[s] += c
        return loads

    def rebalance(self, max_moves: int = 64,
                  tolerance: float = 1.1) -> Dict[str, Any]:
        """Hot-shard auto-split: plan moves from the per-slot op counters
        (plan_rebalance) and execute them as live handovers.  Counters reset
        afterwards so the next window measures the new placement.  Returns
        {'moves': {dst: [slots]}, 'reports': [MigrationReport...]}."""
        from .migration import plan_rebalance

        live = [g.shard_id for g in self.shards if not g.retired]
        moves = plan_rebalance(
            self.slot_loads(), self.router.slot_map, live,
            max_moves=max_moves, tolerance=tolerance,
        )
        reports = []
        for dst, slots in sorted(moves.items()):
            reports.extend(self.migrate_slots(slots, dst))
        for g in self.shards:
            g.slot_ops.clear()
        return {"moves": moves, "reports": reports}

    # ------------------------------------------------------------------ admin
    def sync_all(self) -> None:
        for g in self.shards:
            if not g.retired:
                g.sync_now()

    def crash_master(self, shard_id: int) -> RecoveryReport:
        """Crash exactly one shard's master; only that shard's witnesses are
        frozen and replayed (per-shard epochs via the ConfigManager).
        Undecided transaction intents the recovered master re-surfaced (from
        its backup log and witness replay) are resolved cluster-wide before
        returning — no intent outlives recovery undecided."""
        report = self.shards[shard_id].crash_master()
        resolved = self.resolve_pending_txns()
        report.txn_resolved = resolved["resolved"]
        report.txn_committed = resolved["committed"]
        report.txn_aborted = resolved["aborted"]
        return report

    def crash_all(self) -> ClusterRecoveryReport:
        reports = tuple(g.crash_master() for g in self.shards
                        if not g.retired)
        resolved = self.resolve_pending_txns()
        return ClusterRecoveryReport(
            per_shard=reports,
            txn_resolved=resolved["resolved"],
            txn_committed=resolved["committed"],
            txn_aborted=resolved["aborted"],
        )

    def epochs(self) -> Dict[int, int]:
        return self.config.epochs()

    def stats(self) -> Dict[str, int]:
        """Aggregate master stats across shards (per-shard in .shards[i])."""
        out: Dict[str, int] = {}
        for g in self.shards:
            if g.retired:
                continue
            for k, v in g.master.stats.items():
                out[k] = out.get(k, 0) + v
        return out
