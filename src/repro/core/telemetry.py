"""Flight recorder: metrics registry + causal RPC tracing for the CURP stack.

Two cooperating facilities, both dependency-free and cheap enough to stay on
by default:

* ``MetricsRegistry`` — named ``Counter``/``Gauge``/``Histogram`` instruments.
  Histograms are log-bucketed (HDR-style: 2^SUB sub-buckets per power-of-two
  octave, so relative quantile error is bounded at ~1/2^SUB) and record in
  O(1) with no allocation on the hot path.  Every layer of the stack
  (witness, master, RIFL, admission control, migration, 2PC, kernels, sim)
  increments instruments obtained from the process-global registry
  (``get_registry()``); ``snapshot()`` turns the whole registry into a
  JSON-able dict for BENCH merging.

* ``Tracer`` — causal RPC spans keyed by RIFL id ``(client_id, seq)``.  The
  client's issue..complete window is the root span; witness records, master
  speculative execution, batched syncs, and gc rounds attach as children (or
  as instant detour events: sheds, NOT_OWNER redirects, timeouts).  Spans
  carry explicit µs timestamps supplied by the caller (the discrete-event
  sim passes ``sim.now``; wall-clock callers pass ``time.perf_counter()``
  µs), and ``export_chrome()`` writes Chrome-trace/Perfetto JSON so a 1-RTT
  vs 2-RTT write is visually attributable.

Sampling: ``Tracer(sample=0.01)`` keeps 1% of traces, chosen by a
deterministic hash of the trace id (NOT Python's randomized ``hash``), so
every actor in a distributed flow makes the same keep/drop decision with no
coordination.  Spans outside the per-RPC id space (sync batches, gc rounds)
pass ``force=True`` and are always kept.

Overhead discipline: instruments are plain attribute bumps; tracing does one
dict insert per span.  ``disable()`` swaps ``get_registry()`` to a null
registry whose instruments are no-ops — used by benchmarks/fig_obs.py to
measure the (near-zero) registry cost on the device fast path.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span", "Tracer",
    "get_registry", "registry", "reset_registry", "enable", "disable",
    "enabled",
]


# --------------------------------------------------------------------------
# Instruments
# --------------------------------------------------------------------------
class Counter:
    """Monotonic event count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def to_dict(self) -> Dict[str, Any]:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-set value plus its high watermark (queue depths, occupancy)."""

    __slots__ = ("name", "value", "max")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0
        self.max = 0.0

    def set(self, v: float) -> None:
        self.value = v
        if v > self.max:
            self.max = v

    def reset(self) -> None:
        self.value = 0.0
        self.max = 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {"type": "gauge", "value": self.value, "max": self.max}


# Sub-bucket resolution: 2^_SUB buckets per octave -> relative quantile
# error bounded by 2^(1/2^_SUB) - 1 ~= 2.2% at _SUB = 5.
_SUB = 5
_SUB_N = 1 << _SUB


class Histogram:
    """Log-bucketed HDR-style histogram for non-negative values.

    Bucket index for v >= 1 is ``octave * 2^SUB + sub`` where octave =
    floor(log2 v) and sub refines the octave linearly; values in [0, 1) and
    exact zeros share bucket 0.  ``record`` is O(1); ``percentile`` walks
    the sparse bucket dict (len <= 64*2^SUB in practice).
    """

    __slots__ = ("name", "count", "sum", "min", "max", "_buckets")

    def __init__(self, name: str) -> None:
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = 0.0
        self._buckets: Dict[int, int] = {}

    @staticmethod
    def _index(v: float) -> int:
        if v < 1.0:
            return 0
        m, e = math.frexp(v)            # v = m * 2^e, m in [0.5, 1)
        octave = e - 1                  # floor(log2 v)
        sub = int((m * 2.0 - 1.0) * _SUB_N)  # linear refine within octave
        if sub >= _SUB_N:
            sub = _SUB_N - 1
        return octave * _SUB_N + sub + 1

    @staticmethod
    def _upper_edge(idx: int) -> float:
        if idx == 0:
            return 1.0
        idx -= 1
        octave, sub = divmod(idx, _SUB_N)
        return (2.0 ** octave) * (1.0 + (sub + 1) / _SUB_N)

    def record(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        idx = self._index(v)
        b = self._buckets
        b[idx] = b.get(idx, 0) + 1

    def percentile(self, q: float) -> float:
        """Nearest-rank quantile estimate (bucket upper edge), q in [0, 1]."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for idx in sorted(self._buckets):
            seen += self._buckets[idx]
            if seen >= rank:
                return min(self._upper_edge(idx), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def reset(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = 0.0
        self._buckets.clear()

    def to_dict(self) -> Dict[str, Any]:
        return {
            "type": "histogram", "count": self.count, "mean": self.mean,
            "min": self.min if self.count else 0.0, "max": self.max,
            "p50": self.percentile(0.50), "p90": self.percentile(0.90),
            "p99": self.percentile(0.99),
        }


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
class MetricsRegistry:
    """Name -> instrument map.  Fetch-or-create handles once (at object
    construction), then bump them on the hot path; ``reset()`` zeroes every
    instrument IN PLACE so held handles stay live across scenario runs."""

    def __init__(self) -> None:
        self._instruments: Dict[str, Any] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = cls(name)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} is {type(inst).__name__}, wanted {cls.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def reset(self) -> None:
        for inst in self._instruments.values():
            inst.reset()

    def snapshot(self, prefix: str = "") -> Dict[str, Dict[str, Any]]:
        return {
            name: inst.to_dict()
            for name, inst in sorted(self._instruments.items())
            if name.startswith(prefix)
        }


class _NullInstrument:
    """No-op stand-in handed out while telemetry is disabled."""

    __slots__ = ()
    name = "null"
    value = 0
    max = 0.0
    count = 0
    sum = 0.0
    min = 0.0
    mean = 0.0

    def inc(self, n: int = 1) -> None: ...
    def set(self, v: float) -> None: ...
    def record(self, v: float) -> None: ...
    def reset(self) -> None: ...
    def percentile(self, q: float) -> float:
        return 0.0
    def to_dict(self) -> Dict[str, Any]:
        return {"type": "null"}


class _NullRegistry:
    _NULL = _NullInstrument()

    def counter(self, name: str) -> Any:
        return self._NULL

    gauge = counter
    histogram = counter

    def reset(self) -> None: ...
    def snapshot(self, prefix: str = "") -> Dict[str, Any]:
        return {}


_REGISTRY = MetricsRegistry()
_NULL_REGISTRY = _NullRegistry()
_ENABLED = True


def get_registry():
    """The process-global registry (a null registry while disabled).
    Instrumented objects fetch handles at construction time, so a
    disable()/enable() flip takes effect for objects built after it."""
    return _REGISTRY if _ENABLED else _NULL_REGISTRY


def registry() -> MetricsRegistry:
    """The real registry, regardless of the enabled flag (for readers:
    benchmarks, snapshots, the dispatch-count shims)."""
    return _REGISTRY


def reset_registry() -> None:
    _REGISTRY.reset()


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


# --------------------------------------------------------------------------
# Tracing
# --------------------------------------------------------------------------
def _mix_id(tid: Any) -> int:
    """Deterministic 64-bit mix of a trace id (Python's ``hash`` is
    per-process randomized for strings, so it cannot make the keep/drop
    sampling decision)."""
    if isinstance(tid, tuple):
        h = 0x9E3779B97F4A7C15
        for e in tid:
            h = (h * 0x100000001B3) ^ (_mix_id(e) & 0xFFFFFFFFFFFFFFFF)
            h &= 0xFFFFFFFFFFFFFFFF
    elif isinstance(tid, int):
        h = tid & 0xFFFFFFFFFFFFFFFF
    else:
        import zlib

        h = zlib.crc32(repr(tid).encode())
    # splitmix64 finalizer
    h = (h + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return h ^ (h >> 31)


class Span:
    __slots__ = ("span_id", "trace_id", "name", "actor", "start", "end",
                 "parent", "status", "args")

    def __init__(self, span_id: int, trace_id: Any, name: str, actor: str,
                 start: float, parent: Optional[int],
                 args: Optional[Dict[str, Any]]) -> None:
        self.span_id = span_id
        self.trace_id = trace_id
        self.name = name
        self.actor = actor
        self.start = start
        self.end: Optional[float] = None
        self.parent = parent
        self.status: Optional[str] = None
        self.args = args


class Tracer:
    """Causal span collector with deterministic trace-id sampling.

    ``begin``/``end`` bracket a span whose close site differs from its open
    site (the client root span); ``span`` records a complete child span in
    one call (server-side actors know their service window when the handler
    runs); ``instant`` marks detours (shed, NOT_OWNER, timeout).  Children
    parent to the root span of their trace id by default, so the Perfetto
    flow for one RIFL id reads top-down: issue -> witness record -> master
    execute -> sync -> gc.
    """

    def __init__(self, sample: float = 1.0) -> None:
        self.sample = sample
        self.spans: List[Span] = []
        self.instants: List[Dict[str, Any]] = []
        self._open: Dict[int, Span] = {}
        self._roots: Dict[Any, int] = {}
        self._next_id = 1
        self.dropped = 0   # unsampled begin/span/instant calls

    # -- sampling ----------------------------------------------------------
    def sampled(self, trace_id: Any) -> bool:
        if self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        return (_mix_id(trace_id) % 10_000) < self.sample * 10_000

    # -- span lifecycle ----------------------------------------------------
    def begin(self, trace_id: Any, name: str, ts: float, actor: str = "",
              parent: Optional[int] = None, args: Optional[Dict] = None,
              force: bool = False) -> Optional[int]:
        if not force and not self.sampled(trace_id):
            self.dropped += 1
            return None
        sid = self._next_id
        self._next_id += 1
        span = Span(sid, trace_id, name, actor, ts, parent, args)
        self._open[sid] = span
        self.spans.append(span)
        if trace_id not in self._roots:
            self._roots[trace_id] = sid
        return sid

    def end(self, span_id: Optional[int], ts: float,
            status: Optional[str] = None) -> None:
        if span_id is None:
            return
        span = self._open.pop(span_id, None)
        if span is None:
            return
        span.end = ts
        span.status = status

    def span(self, trace_id: Any, name: str, ts: float, dur: float,
             actor: str = "", status: Optional[str] = None,
             args: Optional[Dict] = None, force: bool = False) -> Optional[int]:
        """One-call complete span, parented to the trace's root (if any)."""
        if not force and not self.sampled(trace_id):
            self.dropped += 1
            return None
        sid = self._next_id
        self._next_id += 1
        span = Span(sid, trace_id, name, actor, ts,
                    self._roots.get(trace_id), args)
        span.end = ts + dur
        span.status = status
        self.spans.append(span)
        if trace_id not in self._roots:
            self._roots[trace_id] = sid
        return sid

    def instant(self, trace_id: Any, name: str, ts: float, actor: str = "",
                args: Optional[Dict] = None, force: bool = False) -> None:
        if not force and not self.sampled(trace_id):
            self.dropped += 1
            return
        self.instants.append({
            "trace_id": trace_id, "name": name, "ts": ts, "actor": actor,
            "args": args,
        })

    def root_id(self, trace_id: Any) -> Optional[int]:
        return self._roots.get(trace_id)

    def open_spans(self) -> List[Span]:
        return list(self._open.values())

    def drain(self, ts: float, status: str = "unfinished") -> int:
        """Close every still-open span and return how many there were.

        Two callers, one discipline: scenario teardown (ops in flight at
        the horizon never complete — they must not leak unclosed spans) and
        the watchdog's black-box dump (a breach snapshots the trace MID-run,
        so in-flight spans must be sealed at breach time for the slice to
        be well-formed).  Idempotent: a teardown after a breach dump finds
        nothing left open."""
        n = len(self._open)
        for sid in list(self._open):
            self.end(sid, ts, status)
        return n

    def close_open(self, ts: float, status: str = "unfinished") -> int:
        """Teardown-time alias of ``drain`` (kept for existing callers)."""
        return self.drain(ts, status)

    # -- derived views -----------------------------------------------------
    def by_trace(self) -> Dict[Any, List[Span]]:
        out: Dict[Any, List[Span]] = {}
        for s in self.spans:
            out.setdefault(s.trace_id, []).append(s)
        return out

    # -- export ------------------------------------------------------------
    def export_chrome(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Chrome-trace JSON (load in Perfetto / chrome://tracing).

        Actors map to tids (named via metadata events); spans are ``ph: X``
        complete events with µs timestamps; instants are ``ph: i``.
        """
        tids: Dict[str, int] = {}

        def tid_of(actor: str) -> int:
            t = tids.get(actor)
            if t is None:
                t = tids[actor] = len(tids) + 1
            return t

        events: List[Dict[str, Any]] = []
        for s in self.spans:
            end = s.end if s.end is not None else s.start
            args = {"trace_id": repr(s.trace_id), "span_id": s.span_id}
            if s.parent is not None:
                args["parent"] = s.parent
            if s.status is not None:
                args["status"] = s.status
            if s.args:
                args.update(s.args)
            events.append({
                "name": s.name, "ph": "X", "pid": 1,
                "tid": tid_of(s.actor or "main"),
                "ts": s.start, "dur": max(end - s.start, 0.0),
                "cat": "curp", "args": args,
            })
        for ev in self.instants:
            args = {"trace_id": repr(ev["trace_id"])}
            if ev["args"]:
                args.update(ev["args"])
            events.append({
                "name": ev["name"], "ph": "i", "pid": 1,
                "tid": tid_of(ev["actor"] or "main"),
                "ts": ev["ts"], "s": "t", "cat": "curp", "args": args,
            })
        for actor, t in sorted(tids.items(), key=lambda kv: kv[1]):
            events.append({
                "name": "thread_name", "ph": "M", "pid": 1, "tid": t,
                "args": {"name": actor},
            })
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


def stage_attribution(tracer: Tracer,
                      tail_q: float = 0.99) -> Dict[str, Any]:
    """Where does tail latency go?  Groups closed ROOT spans by duration,
    takes the ops at/above the ``tail_q`` quantile, and attributes their
    child-span time by stage name.  Returns per-stage µs means for the tail
    cohort vs the full population (the fig_obs report body)."""
    by_trace = tracer.by_trace()
    roots: List[Tuple[float, Any]] = []
    for tid, spans in by_trace.items():
        root = spans[0]
        if root.end is None or root.status == "unfinished":
            continue
        roots.append((root.end - root.start, tid))
    if not roots:
        return {"n_ops": 0, "tail_n": 0, "p99_us": 0.0,
                "stages_all": {}, "stages_tail": {}}
    roots.sort()
    durs = [d for d, _ in roots]
    cut = durs[min(len(durs) - 1, max(0, math.ceil(tail_q * len(durs)) - 1))]
    tail = [tid for d, tid in roots if d >= cut]
    tail_set = set(tail)

    def stage_sums(which: Optional[set]) -> Dict[str, float]:
        sums: Dict[str, float] = {}
        n = 0
        for tid, spans in by_trace.items():
            if which is not None and tid not in which:
                continue
            n += 1
            for s in spans[1:]:
                if s.end is None:
                    continue
                sums[s.name] = sums.get(s.name, 0.0) + (s.end - s.start)
        return {k: v / max(n, 1) for k, v in sorted(sums.items())}

    return {
        "n_ops": len(roots),
        "tail_n": len(tail),
        "p99_us": cut,
        "mean_us": sum(durs) / len(durs),
        "stages_all": stage_sums(None),
        "stages_tail": stage_sums(tail_set),
    }
