"""The CRDT-CURP merge lattice: per-op-type commutativity widening.

CURP's fast path (paper §2, §3.2.2) treats ANY two concurrent writes of the
same key as conflicting.  That is the right call for SET — last-writer-wins
makes order observable — but it is strictly conservative for typed ops that
commute *by construction* (Shapiro's CRDTs, Kuznetsov's wait-free RDTs in
PAPERS.md): two INCRs produce the same counter in either order, two SADDs
the same set, two bounded-MAXes the same maximum, and two HMSETs over
DISJOINT fields the same hash.  This module is the single source of truth
for that widened commutativity relation, consulted by every mirrored layer:

- ``Witness.record`` / ``commutes_with_all`` (core/witness.py),
- the device witness gang + fused fast-path kernels (kernels/ops.py,
  kernels/witness_record.py, kernels/conflict_scan.py) — the kernels bake
  ``CONFLICT_MATRIX`` in as a static constant and consult it in-dispatch,
- the master's unsynced-window check (core/master.py) and witness-replay
  recovery merge-fold.

Encoding
--------
Every op expands to ``(key_hash, op_class)`` pairs via ``op_hash_classes``;
the pair list is what witnesses record and masters refcount.  Classes:

====  =======  ==========================================================
cls   op       merge rule
====  =======  ==========================================================
0     SET      conflicts with everything (incl. itself): order observable
1     DEL      conflicts with everything
2     INCR     INCR || INCR merges (addition commutes)
3     HMSET    HMSET || HMSET merges at the BASE hash; field overlap is
               caught by the per-field FIELD sub-hash pairs
4     FIELD    derived per-field sub-key of an HMSET; FIELD || FIELD
               conflicts, so two HMSETs overlap iff they share a field
5     SADD     set-add commutes (union)
6     APPEND   commutes under the canonical sorted-chunks value
7     MAX      max commutes and is idempotent
8     OTHER    conservative catch-all (reads, TXN legs, migration ops)
====  =======  ==========================================================

``CONFLICT_MATRIX[a]`` is a 16-bit row: bit ``b`` set iff class ``a``
conflicts with class ``b``.  The matrix is built FROM ``MERGEABLE`` —
conflict(a, b) = NOT (a == b AND a in MERGEABLE) — so the Python
predicate, the packed rows, and the kernels' in-dispatch consults cannot
drift apart (tests assert all three agree over all 16x16 pairs).

Class 0 is deliberately SET: the device tables pack a slot's class into
the occupancy plane as ``occ = 0 (empty) | 1 + class``, so every
pre-lattice all-SET workload keeps its exact occ values (occ == 1) and the
historical kernels' bit-exactness tests hold unchanged.
"""
from __future__ import annotations

from typing import List, Tuple

# --- op classes -------------------------------------------------------------
CLS_SET = 0
CLS_DEL = 1
CLS_INCR = 2
CLS_HMSET = 3
CLS_FIELD = 4
CLS_SADD = 5
CLS_APPEND = 6
CLS_MAX = 7
CLS_OTHER = 8
N_CLASSES = 16          # matrix rows; headroom for future classes

#: Classes whose ops merge with a concurrent op of the SAME class.
MERGEABLE = frozenset({CLS_INCR, CLS_HMSET, CLS_SADD, CLS_APPEND, CLS_MAX})

#: Bit c set iff class c is mergeable — the kernels' scalar shortcut.
MERGE_MASK = 0
for _c in MERGEABLE:
    MERGE_MASK |= 1 << _c

#: CONFLICT_MATRIX[a] bit b == 1 iff class a conflicts with class b.
#: Built from MERGEABLE: only the diagonal of a mergeable class clears.
CONFLICT_MATRIX: Tuple[int, ...] = tuple(
    (0xFFFF & ~(1 << a)) if a in MERGEABLE else 0xFFFF
    for a in range(N_CLASSES)
)


def conflicts(a: int, b: int) -> bool:
    """True iff concurrent ops of classes ``a`` and ``b`` on the same key
    hash must take the slow path (the §2 commutativity test, widened)."""
    return bool((CONFLICT_MATRIX[a] >> b) & 1)


def field_subkey(key, field) -> str:
    """Derived sub-key naming one HMSET field of ``key``.  Two HMSETs of
    the same key share a FIELD pair iff they share a field name, which is
    exactly the §2 overlap that makes them non-commutative."""
    return f"{key!r}\x1fhf\x1f{field!r}"


def op_hash_classes(op) -> List[Tuple[int, int]]:
    """Expand an op into the ``(key_hash, op_class)`` pairs the lattice
    reasons over.  Single source of truth — ``Op.hash_classes()`` memoizes
    this, and every witness/master/kernel layer consumes those pairs."""
    from .types import OpType, keyhash

    t = op.op_type
    if t == OpType.SET:
        return [(keyhash(k), CLS_SET) for k in op.keys]
    if t == OpType.DEL:
        return [(keyhash(k), CLS_DEL) for k in op.keys]
    if t == OpType.INCR:
        return [(keyhash(k), CLS_INCR) for k in op.keys]
    if t == OpType.SADD:
        return [(keyhash(k), CLS_SADD) for k in op.keys]
    if t == OpType.APPEND:
        return [(keyhash(k), CLS_APPEND) for k in op.keys]
    if t == OpType.MAX:
        return [(keyhash(k), CLS_MAX) for k in op.keys]
    if t == OpType.MSET:
        return [(keyhash(k), CLS_SET) for k in op.keys]
    if t == OpType.HMSET:
        k = op.keys[0]
        fields = op.args[0] if op.args else ()
        pairs = [(keyhash(k), CLS_HMSET)]
        pairs.extend(
            (keyhash(field_subkey(k, f)), CLS_FIELD) for f, _v in fields
        )
        return pairs
    # Reads, NOOP, TXN legs, migration ops: conservative — OTHER conflicts
    # with every class, reproducing the un-widened CURP check exactly.
    return [(keyhash(k), CLS_OTHER) for k in op.keys]


__all__ = [
    "CLS_SET", "CLS_DEL", "CLS_INCR", "CLS_HMSET", "CLS_FIELD",
    "CLS_SADD", "CLS_APPEND", "CLS_MAX", "CLS_OTHER", "N_CLASSES",
    "MERGEABLE", "MERGE_MASK", "CONFLICT_MATRIX",
    "conflicts", "field_subkey", "op_hash_classes",
]
