"""Cross-shard atomic mini-transactions over the per-shard CURP fast paths.

CURP (§3.6, §B) entangles only ordering and durability per key range, so the
sharded cluster's ``mset`` gives per-shard durability but no cross-shard
atomicity — a client crash mid-``mset`` can leave a torn multi-key write.
This module layers a Sinfonia-style mini-transaction (the paper's related
work) on top: a RIFL-identified two-phase commit where the COORDINATOR is the
client session and the PARTICIPANTS are the shard masters.

Shape of a transaction
----------------------
A ``TxnSpec`` is a read-set + write-set split into per-shard ``TxnPart`` legs
by the cluster's KeyRouter.  Every leg carries TWO rpc_ids from that shard's
RIFL space, allocated up front at spec-build time:

  * ``prepare_rpc`` — identity of the PREPARE leg, and
  * ``decide_rpc``  — identity of the decision (COMMIT or ABORT; one
    decision per transaction, so one identity suffices).

Because both identities are fixed in the spec (and the spec itself rides
inside every leg's payload), any retry — by the client or by crash recovery
— replays the same RPCs and RIFL dedupes them: decisions apply exactly once.

Protocol
--------
1. **Single-shard short-circuit**: a transaction whose keys all route to one
   shard is ONE ``OpType.TXN`` op through the untouched 1-RTT fast path
   (speculative master execution + witness records of all keys, §4.2
   multi-object rules) — no prepare/commit round at all.
2. **PREPARE** (multi-shard): each participant master installs a txn intent
   (write-set + read values, keys locked against overlapping ops) and the
   client records the prepare op at that shard's witnesses — the tombstoned
   intent that keeps commutativity checks sound during the window: any
   overlapping record conflicts until the intent is gc'ed.  A prepare is
   durable the usual CURP way: all-f witness accepts (1 RTT) or a synced
   backup round (2 RTTs).  A participant votes NO if a key is locked by
   another transaction's intent or if a decision tombstone already exists.
3. **DECIDE**: commit iff every participant voted yes.  The decision op
   applies/drops the intent and replies immediately WITHOUT witness records
   or a sync: the decision is a deterministic function of durable prepare
   state ("commit iff all prepared"), so a crashed participant re-derives it
   during recovery instead of needing it pre-logged.

Recovery
--------
``resolve_txn`` implements the Sinfonia recovery-coordinator rule from
participant intent state alone: COMMIT iff some participant already
committed or every participant holds a prepared intent; ABORT otherwise.
Aborting also tombstones not-yet-prepared participants (the abort decision
lands in their RIFL tables under ``decide_rpc``), so a straggling PREPARE
from a crashed-and-revived coordinator is refused — the classic 2PC
prepare/resolve race cannot commit a resolved-aborted transaction.
``resolve_pending`` sweeps every shard after a crash; masters re-surface
intents from backup logs and witness replay, so no intent outlives recovery
undecided.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .telemetry import get_registry
from .types import Op, OpType, RpcId


class TxnStatus(enum.Enum):
    COMMITTED = "COMMITTED"
    ABORTED = "ABORTED"


class CoordinatorCrash(Exception):
    """Raised by a crash-injection hook to kill the coordinator between two
    2PC messages (the transaction is left for ``resolve_txn`` to finish)."""


class TxnPending(Exception):
    """An op touched keys locked by an undecided transaction intent; the
    caller should resolve the transaction (``resolve_txn``) and retry."""

    def __init__(self, spec: "TxnSpec") -> None:
        super().__init__(f"keys locked by pending txn {spec.txn_id}")
        self.spec = spec


@dataclass(frozen=True)
class TxnPart:
    """One shard's leg of a transaction (its slice of the read/write sets)."""
    shard_id: int
    prepare_rpc: RpcId
    decide_rpc: RpcId
    write_kvs: Tuple[Tuple[Any, Any], ...]
    read_keys: Tuple[Any, ...] = ()

    @property
    def keys(self) -> Tuple[Any, ...]:
        """All keys this leg touches (write first, then read) — the lock set
        and the witness-record key set."""
        return tuple(k for k, _ in self.write_kvs) + tuple(self.read_keys)


@dataclass(frozen=True)
class TxnSpec:
    """The full transaction: every participant's leg, with all RIFL
    identities fixed up front.  The spec rides inside every leg's op payload
    (Sinfonia-style), so ANY single surviving intent names every participant
    — that is what makes coordinator-crash resolution possible."""
    txn_id: Tuple[int, int]          # (client_id, txn_seq) — intent-table key
    parts: Tuple[TxnPart, ...]

    def part_on(self, shard_id: int) -> TxnPart:
        """The leg for one shard.  Legs are addressed by shard, not by
        rpc_id: the per-shard RIFL spaces share (client_id, seq) pairs, so
        the same rpc_id can legitimately name different legs on different
        shards — every leg op carries its shard_id for exactly this."""
        for p in self.parts:
            if p.shard_id == shard_id:
                return p
        raise KeyError(f"no part on shard {shard_id}")

    @property
    def write_kvs(self) -> Tuple[Tuple[Any, Any], ...]:
        return tuple(kv for p in self.parts for kv in p.write_kvs)

    @property
    def read_keys(self) -> Tuple[Any, ...]:
        return tuple(k for p in self.parts for k in p.read_keys)


# ---------------------------------------------------------------------------
# Leg op constructors (the only places TXN_* ops are built)
# ---------------------------------------------------------------------------
def prepare_op(spec: TxnSpec, part: TxnPart) -> Op:
    return Op(OpType.TXN_PREPARE, part.keys, (spec, part.shard_id),
              part.prepare_rpc)


def commit_op(spec: TxnSpec, part: TxnPart) -> Op:
    return Op(OpType.TXN_COMMIT, part.keys, (spec, part.shard_id),
              part.decide_rpc)


def abort_op(spec: TxnSpec, part: TxnPart) -> Op:
    return Op(OpType.TXN_ABORT, part.keys, (spec, part.shard_id),
              part.decide_rpc)


def single_shard_op(spec: TxnSpec) -> Op:
    """The 1-RTT short-circuit: the whole transaction as one atomic op on
    its only shard, under the prepare identity (a retry that got promoted to
    2PC, or vice versa, can never double-apply)."""
    (part,) = spec.parts
    return Op(OpType.TXN, part.keys, (spec, part.shard_id), part.prepare_rpc)


@dataclass
class TxnOutcome:
    status: TxnStatus
    reads: Optional[Dict[Any, Any]]   # read-set values; None unless committed
    rtts: int                         # message rounds the coordinator paid
    fast_path: bool                   # every prepare leg completed in 1 RTT
    n_shards: int
    abort_reason: Optional[str] = None


# ---------------------------------------------------------------------------
# Participant state + recovery resolution (Sinfonia recovery-coordinator)
# ---------------------------------------------------------------------------
def participant_state(master, spec: TxnSpec, part: TxnPart) -> str:
    """One participant's view: 'committed' / 'aborted' / 'decided' (decision
    applied but result since acked away) / 'prepared' / 'none'."""
    rec = master.rifl.check_duplicate(part.decide_rpc)
    if rec is not None:
        if rec.result == "COMMITTED":
            return "committed"
        if rec.result == "ABORTED":
            return "aborted"
        return "decided"
    if master.store.txn_intent(spec.txn_id) is not None:
        return "prepared"
    return "none"


def resolve_txn(cluster, spec: TxnSpec) -> TxnStatus:
    """Finish an orphaned transaction from participant intent state alone.

    Rule: COMMIT iff some participant already committed (the coordinator may
    have externalized success) or EVERY participant holds a prepared intent
    (the coordinator was bound to commit); ABORT otherwise.  The decision is
    applied at every participant — including 'none' ones, where the abort
    lands as a RIFL tombstone that refuses any straggling PREPARE.
    """
    states = {
        p.shard_id: participant_state(cluster.shards[p.shard_id].master,
                                       spec, p)
        for p in spec.parts
    }
    if any(s == "committed" for s in states.values()):
        decision = TxnStatus.COMMITTED
    elif any(s == "aborted" for s in states.values()):
        decision = TxnStatus.ABORTED
    elif all(s in ("prepared", "decided") for s in states.values()):
        decision = TxnStatus.COMMITTED
    else:
        decision = TxnStatus.ABORTED
    for part in spec.parts:
        if states[part.shard_id] in ("committed", "aborted"):
            continue  # decision already durable at this participant
        group = cluster.shards[part.shard_id]
        op = (commit_op(spec, part) if decision is TxnStatus.COMMITTED
              else abort_op(spec, part))
        group.txn_decide(op)
    return decision


def resolve_pending(cluster) -> Dict[str, int]:
    """Sweep every shard for undecided intents (post-crash hygiene) and
    resolve each.  Returns {'resolved', 'committed', 'aborted'} counts."""
    seen: Dict[Tuple[int, int], TxnSpec] = {}
    for group in cluster.shards:
        for txn_id, (spec, _part) in group.master.store.txn_intents().items():
            seen.setdefault(txn_id, spec)
    out = {"resolved": 0, "committed": 0, "aborted": 0}
    for spec in seen.values():
        decision = resolve_txn(cluster, spec)
        out["resolved"] += 1
        out["committed" if decision is TxnStatus.COMMITTED else "aborted"] += 1
    return out


# ---------------------------------------------------------------------------
# The coordinator (client side of the 2PC)
# ---------------------------------------------------------------------------
# Stages passed to the crash-injection hook, in message order.  The hook is
# called BEFORE each message leaves the coordinator; raising CoordinatorCrash
# models the client dying with that message (and everything after) unsent.
STAGE_PREPARE = "prepare"     # about to send leg k's PREPARE
STAGE_DECIDE = "decide"       # about to send leg k's COMMIT/ABORT


class TxnCoordinator:
    """Drives one transaction through a ShardedCluster.

    The coordinator is client-session state: all RIFL identities come from
    the session's space, so a re-run with the same spec is a RIFL-dedup'd
    retry, not a new transaction.

    Intent-conflict policy (``wound_wait``, default on): instead of voting
    NO on ANY foreign intent, conflicts order deterministically by txn_id —
    **lower txn_id wins**.  A prepare that hits a HIGHER-id holder wounds it
    through the safe resolve primitive (``resolve_txn`` commits the holder
    iff it was already fully prepared, aborts-with-tombstones otherwise —
    either way its locks drop) and retries; a prepare that hits a LOWER-id
    holder waits-by-retry up to ``wait_retries`` times (the older holder
    decides soon under live interleaving), then falls back to the vote-NO
    abort.  Deadlock-free: in any conflict cycle the lowest txn wounds its
    way through, and waits are bounded.
    """

    def __init__(self, cluster, session, wound_wait: bool = True,
                 wait_retries: int = 3) -> None:
        self.cluster = cluster
        self.session = session
        self.wound_wait = wound_wait
        self.wait_retries = wait_retries
        self.wounds = 0          # holders resolved out of the way
        self.waits = 0           # bounded prepare retries spent waiting
        # Optional black-box journal: intent begin/decide events bracket the
        # 2PC window the watchdog's intent-leak monitor bounds.
        self.journal = None
        reg = get_registry()
        self._m_leg = {
            "prepare_granted": reg.counter("txn.legs.prepare_granted"),
            "prepare_refused": reg.counter("txn.legs.prepare_refused"),
            "commit": reg.counter("txn.legs.commit"),
            "abort": reg.counter("txn.legs.abort"),
            "single_1rtt": reg.counter("txn.legs.single_1rtt"),
            "wounds": reg.counter("txn.wounds"),
            "waits": reg.counter("txn.waits"),
        }

    def run(
        self,
        spec: TxnSpec,
        now: float = 0.0,
        on_message: Optional[Callable[[str, int, int], None]] = None,
    ) -> TxnOutcome:
        hook = on_message or (lambda stage, shard_id, idx: None)
        if len(spec.parts) == 1:
            return self._run_single(spec, now, hook)
        return self._run_2pc(spec, now, hook)

    # -- single-shard short-circuit (1 RTT, untouched fast path) -------------
    def _run_single(self, spec: TxnSpec, now: float, hook) -> TxnOutcome:
        (part,) = spec.parts
        hook(STAGE_PREPARE, part.shard_id, 0)
        group = self.cluster.shards[part.shard_id]
        sub = self.session.session_for(part.shard_id)
        out = group.update(sub, single_shard_op(spec), now)
        self._m_leg["single_1rtt"].inc()
        _status, read_vals = out.value
        return TxnOutcome(
            status=TxnStatus.COMMITTED,
            reads=dict(zip(part.read_keys, read_vals)),
            rtts=out.rtts,
            fast_path=out.fast_path,
            n_shards=1,
        )

    def _prepare_leg(self, spec: TxnSpec, part: TxnPart,
                     now: float) -> "TxnVote":
        """One PREPARE leg under the wound/wait policy (class docstring).
        Retrying re-sends the SAME op (same prepare_rpc): a refused prepare
        recorded nothing, so the identity is still fresh."""
        group = self.cluster.shards[part.shard_id]
        sub = self.session.session_for(part.shard_id)
        vote = group.txn_prepare(sub, prepare_op(spec, part), now)
        waited = 0
        while (self.wound_wait and not vote.granted
               and vote.error == "TXN_LOCKED" and vote.blocking is not None):
            if spec.txn_id < vote.blocking.txn_id:
                # We are older: wound the younger holder (safe — resolve
                # commits it iff it was already fully prepared).
                resolve_txn(self.cluster, vote.blocking)
                self.wounds += 1
                self._m_leg["wounds"].inc()
            else:
                # We are younger: wait-by-retry for the older holder.
                if waited >= self.wait_retries:
                    break
                waited += 1
                self.waits += 1
                self._m_leg["waits"].inc()
            vote = group.txn_prepare(sub, prepare_op(spec, part), now)
        self._m_leg[
            "prepare_granted" if vote.granted else "prepare_refused"
        ].inc()
        return vote

    # -- the 2PC proper ------------------------------------------------------
    def _run_2pc(self, spec: TxnSpec, now: float, hook) -> TxnOutcome:
        jr = self.journal
        if jr is not None:
            jr.emit("intent", actor="txn", phase="begin", txn=spec.txn_id,
                    parts=len(spec.parts))
        votes: Dict[int, Any] = {}
        all_fast = True
        max_rtts = 1
        abort_reason = None
        for idx, part in enumerate(spec.parts):
            hook(STAGE_PREPARE, part.shard_id, idx)
            vote = self._prepare_leg(spec, part, now)
            votes[part.shard_id] = vote
            if not vote.granted:
                abort_reason = vote.error
                break
            all_fast = all_fast and vote.fast
            max_rtts = max(max_rtts, vote.rtts)

        from .client import decide_commit

        commit = decide_commit(votes.values(), len(spec.parts))
        if jr is not None:
            jr.emit("intent", actor="txn", phase="decide", txn=spec.txn_id,
                    commit=commit)
        for idx, part in enumerate(spec.parts):
            hook(STAGE_DECIDE, part.shard_id, idx)
            op = commit_op(spec, part) if commit else abort_op(spec, part)
            self.cluster.shards[part.shard_id].txn_decide(
                op, self.session.session_for(part.shard_id)
            )
            self._m_leg["commit" if commit else "abort"].inc()
        if not commit:
            return TxnOutcome(
                status=TxnStatus.ABORTED, reads=None,
                rtts=max_rtts + 1, fast_path=False,
                n_shards=len(spec.parts), abort_reason=abort_reason,
            )
        reads: Dict[Any, Any] = {}
        for part in spec.parts:
            reads.update(zip(part.read_keys, votes[part.shard_id].read_values))
        # Prepare round (1 RTT when every leg was witness-fast) + decide
        # round: the multi-shard floor is 2 message rounds.
        return TxnOutcome(
            status=TxnStatus.COMMITTED, reads=reads,
            rtts=max_rtts + 1, fast_path=all_fast,
            n_shards=len(spec.parts),
        )


@dataclass
class TxnVote:
    """A participant's PREPARE reply, folded with its witness statuses."""
    granted: bool
    fast: bool = False                 # leg completed via 1-RTT witness path
    rtts: int = 1
    read_values: Tuple[Any, ...] = ()
    error: Optional[str] = None
    # On a TXN_LOCKED refusal: the holder's spec, so the coordinator's
    # wound/wait policy can order the conflict by txn_id.
    blocking: Optional["TxnSpec"] = None
