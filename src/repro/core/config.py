"""Cluster configuration manager (§3.6) + witness table geometry.

Owns the authoritative mapping master -> (epoch, backups, witnesses,
WitnessListVersion).  Clients cache configs; masters reject updates carrying a
stale WitnessListVersion, which forces clients to refetch — this is the §3.6
mechanism that makes witness reconfiguration safe.

``WitnessGeometry`` is the single knob for the witness table shape (S sets x
W ways, §4.2/§B.1), threaded from ServeConfig through ShardedCluster down to
the Pallas kernels so every layer agrees on capacity and VMEM footprint.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

from .types import ClusterConfig


@dataclass(frozen=True)
class WitnessGeometry:
    """Witness table shape: ``n_sets`` x ``n_ways`` keyhash slots (§4.2).

    The paper's default is 1024x4 (§B.1: 4096 slots, 4-way associativity —
    direct-mapped tables start conflicting after ~80 inserts).  ``n_sets``
    must be a power of two: the device kernels pick the probed set with
    ``lo & (n_sets - 1)``.
    """
    n_sets: int = 1024
    n_ways: int = 4

    def __post_init__(self) -> None:
        if self.n_sets < 1 or self.n_sets & (self.n_sets - 1):
            raise ValueError(f"n_sets must be a power of two, got {self.n_sets}")
        if self.n_ways < 1:
            raise ValueError(f"n_ways must be >= 1, got {self.n_ways}")

    @property
    def slots(self) -> int:
        return self.n_sets * self.n_ways

    @property
    def vmem_bytes(self) -> int:
        """Device footprint of one table: keys_hi + keys_lo (uint32) + occ
        (int32), the whole-table figure the kernels keep VMEM-resident."""
        return 3 * 4 * self.slots


class HeartbeatDetector:
    """ConfigManager-side failure detector: timeout-count heartbeats.

    Masters send a heartbeat every ``interval`` time units over the same
    (lossy, jittery) transport as everything else; the detector declares a
    shard's master suspect once no beat has arrived for ``miss_threshold``
    consecutive intervals.  The threshold trades detection latency against
    false positives under jitter/drops — with drop probability p the false-
    suspect probability per check is ~p^miss_threshold.

    Pure state machine (caller supplies ``now``), so the discrete-event sim
    drives it deterministically.  ``check`` returns each newly suspected
    shard exactly once; ``reset`` re-arms a shard after its failover
    completes (the new master's beats then keep it alive).
    """

    def __init__(self, interval: float, miss_threshold: int = 5) -> None:
        self.interval = interval
        self.miss_threshold = miss_threshold
        self._last: Dict[int, float] = {}
        self._suspected: set = set()
        self.detections: Dict[int, float] = {}  # shard -> detection time

    def beat(self, shard_id: int, now: float) -> None:
        if shard_id not in self._suspected:
            self._last[shard_id] = now

    def watch(self, shard_id: int, now: float) -> None:
        """Start (or restart) monitoring a shard, treating ``now`` as a beat."""
        self._suspected.discard(shard_id)
        self._last[shard_id] = now

    def check(self, now: float):
        """Return shards newly declared suspect as of ``now``."""
        newly = []
        deadline = self.miss_threshold * self.interval
        for shard_id, last in self._last.items():
            if shard_id in self._suspected:
                continue
            if now - last >= deadline:
                self._suspected.add(shard_id)
                self.detections[shard_id] = now
                newly.append(shard_id)
        return newly

    def suspected(self, shard_id: int) -> bool:
        return shard_id in self._suspected


class ConfigManager:
    def __init__(self) -> None:
        self._configs: Dict[int, ClusterConfig] = {}  # shard_id -> config

    def publish(self, shard_id: int, config: ClusterConfig) -> None:
        self._configs[shard_id] = config

    def fetch(self, shard_id: int = 0) -> ClusterConfig:
        return self._configs[shard_id]

    def epoch(self, shard_id: int = 0) -> int:
        """Per-shard epoch: each shard fails over independently, so epochs
        advance per shard — a master crash on shard k fences only shard k's
        zombies and leaves every other shard's epoch untouched."""
        return self._configs[shard_id].epoch

    def epochs(self) -> Dict[int, int]:
        return {sid: cfg.epoch for sid, cfg in self._configs.items()}

    def replace_witness(
        self, shard_id: int, dead_witness: int, new_witness: int
    ) -> ClusterConfig:
        """Decommission a crashed witness, install a new one, bump the
        WitnessListVersion (§3.6 case 2).  The master must sync to backups and
        acknowledge before the new config is considered live; callers drive
        that handshake."""
        cfg = self._configs[shard_id]
        wl = tuple(new_witness if w == dead_witness else w for w in cfg.witness_ids)
        cfg = replace(
            cfg, witness_ids=wl, witness_list_version=cfg.witness_list_version + 1
        )
        self._configs[shard_id] = cfg
        return cfg

    def migration_fence(self, shard_id: int) -> ClusterConfig:
        """§3.6 slot handover: bump epoch AND WitnessListVersion on one side
        of a migration.  The WitnessListVersion bump fences in-flight records
        — an update that recorded at the old witness set before the handover
        is refused by the master (WRONG_WITNESS_VERSION) and the client
        refetches, re-routing to the new owner; the epoch bump fences any
        zombie pre-handover master at the backups.  Callers must push the
        new epoch/version into the live master and its backups (the
        MigrationManager drives that handshake)."""
        cfg = self._configs[shard_id]
        cfg = replace(
            cfg,
            epoch=cfg.epoch + 1,
            witness_list_version=cfg.witness_list_version + 1,
        )
        self._configs[shard_id] = cfg
        return cfg

    def fail_over(
        self,
        shard_id: int,
        new_master_id: int,
        new_witness_ids: Tuple[int, ...],
    ) -> ClusterConfig:
        """Master crash: bump epoch (fences zombies at backups), assign fresh
        witnesses, bump WitnessListVersion."""
        cfg = self._configs[shard_id]
        cfg = replace(
            cfg,
            master_id=new_master_id,
            epoch=cfg.epoch + 1,
            witness_ids=new_witness_ids,
            witness_list_version=cfg.witness_list_version + 1,
        )
        self._configs[shard_id] = cfg
        return cfg
